"""Checkpoint substrate tests: atomicity, integrity, retention, resume."""

import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    CheckpointManager,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.checkpoint.checkpoint import all_steps


def _tree(key=0):
    k = jax.random.PRNGKey(key)
    return {"w": jax.random.normal(k, (32, 8)),
            "nested": {"b": jnp.arange(5, dtype=jnp.float32)},
            "count": jnp.asarray(3, jnp.int32)}


def test_save_restore_roundtrip(tmp_path):
    tree = _tree()
    save_checkpoint(tmp_path, 7, tree)
    assert latest_step(tmp_path) == 7
    out = restore_checkpoint(tmp_path, 7, jax.eval_shape(lambda: _tree()))
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_tmp_dirs_are_not_checkpoints(tmp_path):
    (tmp_path / "step_00000009.tmp").mkdir(parents=True)
    assert latest_step(tmp_path) is None  # torn save never shadows


def test_corruption_detected(tmp_path):
    tree = _tree()
    d = save_checkpoint(tmp_path, 1, tree)
    victim = next(d.glob("leaf_*.npy"))
    data = bytearray(victim.read_bytes())
    data[-1] ^= 0xFF
    victim.write_bytes(bytes(data))
    with pytest.raises(IOError):
        restore_checkpoint(tmp_path, 1, jax.eval_shape(lambda: _tree()))


def test_shape_mismatch_detected(tmp_path):
    save_checkpoint(tmp_path, 1, _tree())
    bad = jax.eval_shape(lambda: {"w": jnp.zeros((4, 4)),
                                  "nested": {"b": jnp.zeros(5)},
                                  "count": jnp.zeros((), jnp.int32)})
    with pytest.raises(ValueError):
        restore_checkpoint(tmp_path, 1, bad)


def test_retention_keeps_last_n(tmp_path):
    for s in range(5):
        save_checkpoint(tmp_path, s, _tree(), keep_last=2)
    assert all_steps(tmp_path) == [3, 4]


def test_manager_resume_cycle(tmp_path):
    mgr = CheckpointManager(tmp_path, save_every=2, keep_last=3)
    tree = _tree()
    for step in range(6):
        tree = jax.tree_util.tree_map(
            lambda x: x + 1 if x.dtype == jnp.float32 else x, tree)
        mgr.maybe_save(step, tree)
    mgr.wait()
    step, restored = mgr.restore_latest(jax.eval_shape(lambda: _tree()))
    assert step == 4  # last multiple of save_every
    # Values reflect 5 increments (steps 0..4).
    np.testing.assert_allclose(
        np.asarray(restored["nested"]["b"]),
        np.arange(5, dtype=np.float32) + 5)
