"""Sharding-rule tests on a tiny host mesh (divisibility guards, role
resolution, batch/cache rules) -- no 512-device requirement."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.ps import act_sharding, sharding as shd


@pytest.fixture(scope="module")
def mesh():
    from repro.launch.mesh import make_host_mesh

    return make_host_mesh()


def test_lm_rules_cover_all_params(mesh):
    from repro.configs import registry
    from repro.models import transformer as tf

    for arch in ("qwen1.5-0.5b", "deepseek-v2-236b", "granite-moe-1b-a400m"):
        cfg = registry.get_smoke_config(arch)
        abstract = jax.eval_shape(lambda: tf.init_params(cfg, jax.random.PRNGKey(0)))
        tree = shd.param_shardings(mesh, abstract, "lm")
        for leaf in jax.tree_util.tree_leaves(tree):
            assert leaf.mesh.shape == mesh.shape


def _abstract_mesh(shape=(1, 4)):
    # Rule logic only consults mesh.shape; AbstractMesh avoids needing
    # real devices (this host has one CPU).
    from repro.launch.mesh import make_abstract_mesh

    return make_abstract_mesh(shape, ("data", "model"))


def test_divisibility_guard_degrades_to_replicated():
    mesh4 = _abstract_mesh((1, 4))
    # 7 heads not divisible by model=4 -> falls to head_dim (128 divides).
    spec = shd._lm_rule(mesh4, "layers/attn/w_q", (2, 64, 7, 128))
    assert spec[2] is None and spec[3] == "model"
    # 8 IS divisible -> heads shard over model.
    spec = shd._lm_rule(mesh4, "layers/attn/w_q", (2, 64, 8, 128))
    assert spec[2] == "model"
    # tiny tensors stay replicated
    assert shd._lm_rule(mesh4, "layers/attn/w_q", (2, 8, 4, 8)) == ()


def test_batch_shardings_leading_dim(mesh):
    batch = {"tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32),
             "odd": jax.ShapeDtypeStruct((7,), jnp.float32)}
    tree = shd.batch_shardings(mesh, batch)
    assert tree["tokens"].spec == P("data")
    # On a wider mesh the divisibility guard replicates the odd leaf.
    mesh4 = _abstract_mesh((4, 1))
    tree4 = shd.batch_shardings(mesh4, batch)
    assert tree4["odd"].spec == P()
    assert tree4["tokens"].spec == P("data")


def test_act_constrain_noop_without_context():
    x = jnp.ones((4, 4))
    y = act_sharding.constrain(x, "dp", "tp")
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert not act_sharding.enabled()


def test_act_constrain_applies_in_context(mesh):
    with act_sharding.activate(mesh):
        assert act_sharding.enabled()

        @jax.jit
        def f(x):
            return act_sharding.constrain(x, "dp", None)

        out = f(jnp.ones((4, 4)))
        np.testing.assert_array_equal(np.asarray(out), np.ones((4, 4)))
    assert not act_sharding.enabled()


def test_kv_cache_sharding_batch_vs_seq(mesh):
    cache = {"k": jax.ShapeDtypeStruct((2, 4, 8, 2, 4), jnp.float32),
             "length": jax.ShapeDtypeStruct((), jnp.int32)}
    tree = shd.kv_cache_shardings(mesh, cache, batch=4)
    assert tree["length"].spec == P()
    assert tree["k"].spec[1] == "data"  # batch dim over data
