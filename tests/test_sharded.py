"""Sharded aggregation spaces: per-Aggregator shard plans, the sharded
runtime/engine, load-driven elastic scaling, and sharded checkpoints.

Parity notes.  All cross-LAYOUT comparisons (sharded vs flat runtime,
autoscaled vs static) run EAGER on both sides: per-element Adam math is
identical across layouts, so trajectories must agree bit-for-bit; jitted
runs add XLA:CPU's documented ~1-ulp cross-program fusion rounding and
are only compared against themselves.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ParameterService
from repro.ps.autoscaler import AutoscalerConfig, ElasticScaler
from repro.ps.elastic import migrate_sharded_state, sharded_transition_summary
from repro.ps.plan import (
    compile_sharded_plan,
    sharded_plan_from_json,
    sharded_plan_to_json,
)
from repro.ps.service_runtime import ServiceRuntime, ShardedServiceRuntime


def _tree(key, sizes):
    ks = jax.random.split(key, len(sizes))
    return {f"t{i}": jax.random.normal(k, (n,))
            for i, (k, n) in enumerate(zip(ks, sizes))}


def _loss(params, batch):
    return sum(jnp.sum((params[k] - batch["target"][k]) ** 2)
               for k in params)


TREES = {
    "a": _tree(jax.random.PRNGKey(0), (48, 16, 32)),
    "b": _tree(jax.random.PRNGKey(1), (32, 16)),
}
TARGETS = {j: jax.tree_util.tree_map(lambda p: p * 0 + 1.0, t)
           for j, t in TREES.items()}
PROBE = _tree(jax.random.PRNGKey(7), (24,))
PROBE_TARGET = jax.tree_util.tree_map(lambda p: p * 0 + 1.0, PROBE)


def _service():
    return ParameterService(total_budget=16, n_clusters=1, plan_pad_to=16)


def _add_jobs(rt, trees=TREES, slack=0.2):
    for jid, t in trees.items():
        nbytes = sum(4 * v.size for v in t.values())
        rt.add_job(jid, t, _loss, lr=0.05, required_servers=1,
                   agg_throughput=nbytes / slack)


def _runtime(engine=None, jit=False):
    rt = ShardedServiceRuntime(_service(), jit=jit)
    eng = rt.attach_engine(**engine) if engine is not None else None
    _add_jobs(rt)
    return rt, eng


def _assert_params_equal(rt_a, rt_b, jobs=TREES):
    for j in jobs:
        pa, pb = rt_a.params_of(j), rt_b.params_of(j)
        for k in pa:
            np.testing.assert_array_equal(np.asarray(pa[k]),
                                          np.asarray(pb[k]))


# ------------------------------------------------------------------- plan
def test_compile_sharded_plan_structure():
    svc = _service()
    rt = ShardedServiceRuntime(svc)
    _add_jobs(rt)
    splan = rt.splan
    assert splan.n_shards == svc.n_aggregators
    assert splan.shard_ids == tuple(a.agg_id for a in svc.aggregators)
    # Each shard space is its own single-shard FlatPlan, individually
    # padded, with block exclusivity inside.
    for sp in splan.shards:
        assert sp.n_shards == 1
        assert sp.shard_len % sp.block_align == 0
        for j in sp.job_ids:
            sp.job_layout(j)  # raises if not block-exclusive
    # Combined job layout covers every leaf exactly once, shard by shard.
    for jid, t in TREES.items():
        layout = splan.job_layout(jid)
        assert set(k for k, *_ in layout.slots) == set(t)
        assert layout.packed_len == sum(
            l.packed_len for l in layout.layouts)
        assert len(layout.shard_ids) == len(set(layout.shard_ids))


def test_sharded_plan_json_roundtrip():
    rt, _ = _runtime()
    splan = rt.splan
    again = sharded_plan_from_json(sharded_plan_to_json(splan))
    assert again == splan


def test_single_aggregator_shard_plan_matches_flat_plan():
    """With ONE Aggregator the shard space is bit-identical to the flat
    plan's single shard: same segments, same shard_len, same alignment."""
    svc = _service()
    rt = ShardedServiceRuntime(svc)
    _add_jobs(rt)
    if svc.n_aggregators != 1:
        pytest.skip("packing spread jobs; single-shard identity untestable")
    flat = svc.compile_plan()
    shard = rt.splan.shards[0]
    assert shard == flat


# ------------------------------------------------- trajectory bit-parity
def _drive(rt, n_steps=12, probe_at=(4, 9), stepper=None):
    """Step all jobs n times; a probe job arrives and exits, forcing two
    replan migrations mid-trajectory."""
    step = stepper or rt.step
    arrive, leave = probe_at
    for i in range(n_steps):
        if i == arrive:
            nb = sum(4 * v.size for v in PROBE.values())
            rt.add_job("probe", PROBE, _loss, lr=0.05, required_servers=1,
                       agg_throughput=nb / 0.3)
        if i == leave:
            rt.remove_job("probe")
        for jid in TREES:
            step(jid, {"target": TARGETS[jid]})
        if arrive <= i < leave:
            step("probe", {"target": PROBE_TARGET})
    return rt


def test_sharded_runtime_bit_exact_vs_flat_through_replans():
    """Tentpole acceptance: the sharded runtime reproduces the flat
    single-space trajectory bit-exactly (eager), including through a
    probe job's arrival/exit replans."""
    rt_flat = _drive(
        (lambda rt: (_add_jobs(rt), rt)[1])(ServiceRuntime(_service(),
                                                           jit=False)))
    rt_sh, _ = _runtime()
    _drive(rt_sh)
    assert rt_sh.n_replans >= 2
    _assert_params_equal(rt_flat, rt_sh)
    # Counts advanced identically.
    for j in TREES:
        assert int(rt_sh.counts[j]) == int(
            jax.device_get(rt_flat.state["counts"][j]))


def test_scale_out_in_bit_exact_and_moves_only_delta_bytes():
    """Tentpole acceptance: a load-driven shard split (and the merge
    back) moves exactly the compiled transition summary's bytes, and the
    trajectory across both transitions stays bit-exact with the flat
    reference."""
    rt_flat = ServiceRuntime(_service(), jit=False)
    _add_jobs(rt_flat)
    rt_sh, _ = _runtime()

    def both(n):
        for _ in range(n):
            for j in TREES:
                rt_flat.step(j, {"target": TARGETS[j]})
                rt_sh.step(j, {"target": TARGETS[j]})

    both(4)
    old = rt_sh.splan
    params_before = {j: rt_sh.params_of(j) for j in TREES}
    assert rt_sh.service.scale_out(1) == 1
    assert rt_sh.n_shards == old.n_shards + 1
    moved_elems, touched = sharded_transition_summary(old, rt_sh.splan)
    assert rt_sh.last_relayout_bytes == moved_elems * 12
    assert rt_sh.last_replan_touched == touched
    assert moved_elems > 0  # a split really ships bytes across shards
    # The migration itself must not perturb any job's parameters.
    for j in TREES:
        after = rt_sh.params_of(j)
        for k in after:
            np.testing.assert_array_equal(
                np.asarray(params_before[j][k]), np.asarray(after[k]))
    both(4)
    _assert_params_equal(rt_flat, rt_sh)

    old = rt_sh.splan
    assert rt_sh.service.scale_in(1) == 1
    moved_elems, touched = sharded_transition_summary(old, rt_sh.splan)
    assert rt_sh.last_relayout_bytes == moved_elems * 12
    assert rt_sh.last_replan_touched == touched
    both(3)
    _assert_params_equal(rt_flat, rt_sh)


def test_migrate_sharded_state_matches_summary_accounting():
    """Property: the executed sharded migration's element count and
    touched set equal the O(segments) summary's, on a real split."""
    rt, _ = _runtime()
    for _ in range(3):
        for j in TREES:
            rt.step(j, {"target": TARGETS[j]})
    old = rt.splan
    states_before = {sid: dict(st) for sid, st in rt.states.items()}
    rt.service.scale_out(1)
    new = rt.splan
    # Re-execute the migration from the snapshot and compare accounting.
    _, moved, touched = migrate_sharded_state(states_before, old, new)
    sum_moved, sum_touched = sharded_transition_summary(old, new)
    assert moved == sum_moved
    assert touched == sum_touched


# --------------------------------------------------------- sharded engine
def test_sharded_engine_bsp_bit_exact_through_scaling():
    """Engine-driven (BSP) sharded training == per-job sharded steps ==
    flat runtime, bit-exact, straight through a split."""
    rt_ref, _ = _runtime()
    rt_eng, eng = _runtime(engine=dict(max_staleness=0, jit=False))

    def both(n):
        for _ in range(n):
            for j in TREES:
                rt_ref.step(j, {"target": TARGETS[j]})
                eng.step(j, {"target": TARGETS[j]})
        eng.drain()

    both(4)
    rt_ref.service.scale_out(1)
    rt_eng.service.scale_out(1)
    both(4)
    _assert_params_equal(rt_ref, rt_eng)
    assert eng.stats.n_applied > 0
    # Per-shard lanes really ran independently sized tick loops.
    per_shard = eng.shard_stats()
    assert len(per_shard) == rt_eng.n_shards
    assert all(s.n_applied > 0 for s in per_shard.values())


def test_sharded_engine_independent_cadence_and_multipart_futures():
    """A hot shard ticking never stalls a cold one: ticking ONE hosting
    shard applies only that shard's piece; the future resolves only when
    every hosting shard applied its piece."""
    rt, eng = _runtime(engine=dict(max_staleness=2, jit=False))
    rt.service.scale_out(1)
    layout = rt.splan.job_layout("a")
    if len(layout.shard_ids) < 2:
        pytest.skip("split left job 'a' on one shard")
    fut = eng.step("a", {"target": TARGETS["a"]})["future"]
    first, rest = layout.shard_ids[0], layout.shard_ids[1:]
    assert eng.tick_shard(first) == 1
    assert not fut.done()  # other shards' pieces still queued
    assert eng.outstanding("a") == 1
    for sid in rest:
        eng.tick_shard(sid)
    assert fut.done()
    assert fut.result() == 1
    # The cold lane was never ticked beyond its pending work.
    stats = eng.shard_stats()
    assert stats[first].n_ticks == 1


def test_sharded_engine_staleness_bound_forces_rounds():
    rt, eng = _runtime(engine=dict(max_staleness=1, jit=False))
    eng.step("a", {"target": TARGETS["a"]})
    eng.step("a", {"target": TARGETS["a"]})
    assert eng.outstanding("a") <= 2
    before = eng.stats.n_forced_staleness
    eng.step("a", {"target": TARGETS["a"]})  # must force a tick round
    assert eng.stats.n_forced_staleness > before
    assert eng.outstanding("a") <= 2
    eng.drain()
    assert eng.outstanding("a") == 0


def test_sharded_engine_epoch_fence_raises_on_stale_piece():
    rt, eng = _runtime(engine=dict(max_staleness=1, jit=False))
    eng.step("a", {"target": TARGETS["a"]})
    # Corrupt the fence: pretend a replan bumped the epoch without
    # draining (protocol violation).
    eng._epoch += 1
    with pytest.raises(RuntimeError, match="epoch fence"):
        eng.drain()


# ------------------------------------------------------------- autoscaler
def test_autoscaler_follows_load_and_merges_back():
    rt, eng = _runtime(engine=dict(max_staleness=0, jit=False))
    scaler = ElasticScaler(rt, AutoscalerConfig(
        shard_capacity=8.0, max_shards=4, cooldown=1))

    def window(steps):
        for _ in range(steps):
            for j in TREES:
                eng.step(j, {"target": TARGETS[j]})
        eng.drain()
        return scaler.observe()

    for _ in range(2):
        d = window(1)
        assert d.action == "hold" and rt.n_shards == 1
    grew = False
    for _ in range(4):
        d = window(8)
        grew = grew or d.action == "grow"
    assert grew and rt.n_shards > 1
    peak = rt.n_shards
    for _ in range(5):
        d = window(1)
    assert rt.n_shards < peak
    assert rt.n_shards == 1
    # Decision log carries the per-shard loads and migration bytes.
    assert scaler.n_actions >= 2
    assert any(dec.relayout_bytes > 0 for dec in scaler.decisions)
    assert scaler.shard_timeline()[-1] == 1


def test_autoscaler_requires_engine():
    rt = ShardedServiceRuntime(_service())
    _add_jobs(rt)
    scaler = ElasticScaler(rt)
    with pytest.raises(RuntimeError, match="ShardedTickEngine"):
        scaler.observe()


# ------------------------------------------------------------ debug stats
def test_debug_stats_unifies_cache_and_per_shard_ticks():
    """Satellite: debug_stats() = plan-pair cache + runtime counters +
    per-shard TickStats, for both runtimes."""
    rt_flat = ServiceRuntime(_service(), jit=False)
    flat_eng = rt_flat.attach_engine(max_staleness=0, jit=False)
    _add_jobs(rt_flat)
    flat_eng.step("a", {"target": TARGETS["a"]})
    flat_eng.drain()
    stats = rt_flat.debug_stats()
    assert {"plan_cache", "runtime", "engine"} <= set(stats)
    assert {"hits", "misses", "entries"} <= set(stats["plan_cache"])
    assert stats["engine"]["n_applied"] >= 1
    assert stats["runtime"]["n_jobs"] == 2

    rt, eng = _runtime(engine=dict(max_staleness=0, jit=False))
    for _ in range(2):
        for j in TREES:
            eng.step(j, {"target": TARGETS[j]})
    eng.drain()
    stats = rt.debug_stats()
    assert {"plan_cache", "runtime", "engine", "shards"} <= set(stats)
    assert stats["runtime"]["n_shards"] == rt.n_shards
    assert set(stats["shards"]) <= set(rt.shard_ids)
    assert sum(s["n_applied"] for s in stats["shards"].values()) \
        == stats["engine"]["n_applied"] > 0


# ------------------------------------------------------------- checkpoint
def test_sharded_checkpoint_roundtrip_across_replan(tmp_path):
    """Satellite: an engine-attached sharded runtime checkpoints and
    restores bit-exactly -- plan, every shard space, and step counters --
    and the restored runtime replays a replan-crossing continuation to
    the identical trajectory."""
    def build():
        rt = ShardedServiceRuntime(_service(), jit=False)
        eng = rt.attach_engine(max_staleness=1, jit=False)
        _add_jobs(rt)
        return rt, eng

    def continuation(rt, eng):
        nb = sum(4 * v.size for v in PROBE.values())
        rt.add_job("probe", PROBE, _loss, lr=0.05, required_servers=1,
                   agg_throughput=nb / 0.3)  # REPLAN after the restore point
        for _ in range(3):
            for j in TREES:
                eng.step(j, {"target": TARGETS[j]})
            eng.step("probe", {"target": PROBE_TARGET})
        eng.drain()

    rt1, eng1 = build()
    for _ in range(5):
        for j in TREES:
            eng1.step(j, {"target": TARGETS[j]})
    eng1.drain()
    rt1.save_checkpoint(tmp_path, 5)
    continuation(rt1, eng1)

    rt2, eng2 = build()
    for _ in range(2):  # diverge before restoring
        for j in TREES:
            eng2.step(j, {"target": TARGETS[j]})
    eng2.drain()
    rt2.restore_checkpoint(tmp_path, 5)
    for j in TREES:  # counters restored exactly
        assert int(jax.device_get(rt2.counts[j])) == 5
    continuation(rt2, eng2)
    _assert_params_equal(rt1, rt2, jobs=list(TREES) + ["probe"])
    for j in TREES:
        assert int(jax.device_get(rt1.counts[j])) == int(
            jax.device_get(rt2.counts[j]))


def test_sharded_checkpoint_restores_across_fleet_resize(tmp_path):
    """A checkpoint taken under one fleet size restores under another:
    the saved shard map migrates onto the live plan."""
    rt1, _ = _runtime()
    for _ in range(4):
        for j in TREES:
            rt1.step(j, {"target": TARGETS[j]})
    rt1.save_checkpoint(tmp_path, 4)
    ref = {j: rt1.params_of(j) for j in TREES}

    rt2, _ = _runtime()
    rt2.service.scale_out(1)  # restoring fleet is BIGGER than the saver's
    assert rt2.n_shards > rt1.n_shards
    rt2.restore_checkpoint(tmp_path, 4)
    for j in TREES:
        q = rt2.params_of(j)
        for k in ref[j]:
            np.testing.assert_array_equal(np.asarray(ref[j][k]),
                                          np.asarray(q[k]))


# ------------------------------------------------------------ remove_job
def test_remove_job_unknown_leaves_sharded_runtime_untouched():
    rt, _ = _runtime()
    with pytest.raises(ValueError, match="unknown job"):
        rt.remove_job("nope")
    assert set(rt.job_ids) == set(TREES)
