"""Push-path compression (PR 8): quantization round-trip bounds, block
scaling edge cases, the error-feedback invariant, and the wire-size
model -- property-based where randomness helps (hypothesis, or the
seeded shim when it is not installed).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # fallback shim; see requirements-dev.txt
    from _hypothesis_shim import given, settings, strategies as st

from repro.ps.compression import (
    BLOCK,
    ErrorFeedback,
    _block_scales,
    compress_decompress,
    dequantize_int8,
    ef_transform,
    quantize_int8,
    wire_bytes,
)


def _vec(seed, n, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(seed), (n,)) * scale


# ------------------------------------------------------------ int8 round trip
@settings(deadline=None, max_examples=25)
@given(seed=st.integers(min_value=0, max_value=10_000),
       n=st.integers(min_value=1, max_value=5000),
       scale=st.floats(min_value=1e-3, max_value=1e3))
def test_int8_round_trip_error_bound(seed, n, scale):
    """Dequantized values sit within half a quantization step of the
    input: |x - deq(q(x))| <= block_scale / 127 / 2 elementwise (the
    round() in quantize_int8 picks the nearest of 255 levels)."""
    x = _vec(seed, n, scale)
    q, scales = quantize_int8(x)
    err = np.abs(np.asarray(x - dequantize_int8(q, scales)))
    per_elem = np.repeat(np.asarray(scales), BLOCK)[:n]
    assert np.all(err <= per_elem / 127.0 * 0.5 + 1e-7)


@settings(deadline=None, max_examples=25)
@given(seed=st.integers(min_value=0, max_value=10_000),
       n=st.integers(min_value=1, max_value=5000))
def test_int8_quantizer_outputs(seed, n):
    x = _vec(seed, n)
    q, scales = quantize_int8(x)
    assert q.dtype == jnp.int8 and q.shape == (n,)
    assert scales.shape == (-(-n // BLOCK),)
    # clip keeps the code range symmetric: the max |x| of a block maps to
    # exactly +-127, never -128.
    assert int(jnp.max(jnp.abs(q.astype(jnp.int32)))) <= 127


# ------------------------------------------------------- _block_scales edges
def test_block_scales_all_zero_block():
    """A zero block quantizes to zeros and dequantizes to zeros (the
    safe-scale guard, not a 0/0 NaN)."""
    x = jnp.zeros((100,))
    scales = _block_scales(x, 32)
    np.testing.assert_array_equal(np.asarray(scales), 0.0)
    q, s = quantize_int8(x, block=32)
    np.testing.assert_array_equal(np.asarray(q), 0)
    np.testing.assert_array_equal(
        np.asarray(dequantize_int8(q, s, block=32)), 0.0)


def test_block_scales_length_one():
    scales = _block_scales(jnp.asarray([-3.5]), 8)
    np.testing.assert_allclose(np.asarray(scales), [3.5])
    q, s = quantize_int8(jnp.asarray([-3.5]), block=8)
    np.testing.assert_allclose(np.asarray(dequantize_int8(q, s, block=8)),
                               [-3.5], rtol=1e-6)


@settings(deadline=None, max_examples=20)
@given(n=st.integers(min_value=1, max_value=300),
       block=st.sampled_from([1, 3, 7, 32, 256]))
def test_block_scales_ragged_lengths(n, block):
    """Lengths not a multiple of the block: the pad must not leak into
    any block's max (zero-padding |x| is safe because scales are maxes
    of absolute values)."""
    x = jnp.arange(1, n + 1, dtype=jnp.float32) * jnp.where(
        jnp.arange(n) % 2 == 0, 1.0, -1.0)
    scales = np.asarray(_block_scales(x, block))
    assert scales.shape == (-(-n // block),)
    xa = np.abs(np.asarray(x))
    for b in range(scales.size):
        np.testing.assert_allclose(
            scales[b], xa[b * block:(b + 1) * block].max())


# ------------------------------------------------------------- kind dispatch
def test_compress_decompress_unknown_kind_raises():
    with pytest.raises(ValueError, match="unknown compression"):
        compress_decompress(jnp.ones((4,)), "fp8")


def test_bf16_round_trip_is_cast():
    x = _vec(3, 257)
    np.testing.assert_array_equal(
        np.asarray(compress_decompress(x, "bf16")),
        np.asarray(x.astype(jnp.bfloat16).astype(jnp.float32)))


# ------------------------------------------------------------ error feedback
@settings(deadline=None, max_examples=15)
@given(seed=st.integers(min_value=0, max_value=10_000),
       n=st.integers(min_value=1, max_value=3000),
       kind=st.sampled_from(["bf16", "int8"]),
       steps=st.integers(min_value=1, max_value=12))
def test_error_feedback_invariant(seed, n, kind, steps):
    """EF-SGD telescopes: sum of emitted updates + final residual ==
    sum of gradients EXACTLY (each round satisfies q_t + r_t = g_t +
    r_{t-1} by construction), so cumulative applied updates track
    cumulative gradients within ONE quantization step (the residual)."""
    ks = jax.random.split(jax.random.PRNGKey(seed), steps)
    grads = [jax.random.normal(k, (n,)) for k in ks]
    ef = ErrorFeedback((n,))
    total_q = jnp.zeros((n,))
    for g in grads:
        total_q = total_q + ef.step(g, kind)
    total_g = sum(grads)
    np.testing.assert_allclose(np.asarray(total_q + ef.residual),
                               np.asarray(total_g), rtol=1e-5, atol=1e-5)
    # The gap is the LAST round's quantization error -- bounded by one
    # step of the last compressed value, never an accumulating drift.
    gap = np.abs(np.asarray(total_g - total_q))
    if kind == "int8":
        bound = np.repeat(np.asarray(
            _block_scales(jnp.abs(total_g) + np.abs(np.asarray(total_q)),
                          BLOCK)), BLOCK)[:n]
        assert np.all(gap <= bound / 127.0 + 1e-5)


def test_ef_transform_matches_manual_recurrence():
    g, ef = _vec(5, 400), _vec(6, 400) * 0.01
    q, resid = ef_transform(g, ef, "int8")
    np.testing.assert_array_equal(
        np.asarray(q), np.asarray(compress_decompress(g + ef, "int8")))
    np.testing.assert_array_equal(np.asarray(resid),
                                  np.asarray(g + ef - q))


# ------------------------------------------------------------ wire-size model
def test_wire_bytes_model():
    assert wire_bytes(100, None) == 400
    assert wire_bytes(100, "bf16") == 200
    assert wire_bytes(100, "int8") == 100 + 4  # one scale block
    assert wire_bytes(BLOCK + 1, "int8") == BLOCK + 1 + 8  # two blocks
    assert wire_bytes(0, "int8") == 0
    with pytest.raises(ValueError, match="unknown compression"):
        wire_bytes(10, "fp8")
    with pytest.raises(ValueError):
        wire_bytes(-1, None)


@settings(deadline=None, max_examples=20)
@given(n=st.integers(min_value=1, max_value=100_000))
def test_wire_bytes_int8_under_half(n):
    """The acceptance ratio the wire benchmark asserts: int8 payload +
    scales always costs well under half the fp32 bytes."""
    assert wire_bytes(n, "int8") <= 0.5 * wire_bytes(n, None)
    assert wire_bytes(n, "bf16") == 0.5 * wire_bytes(n, None)
