"""Control-plane unit + property tests: Pseudocode 1, cyclic execution,
scaling, migration protocol, IP model."""

import math

import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # fallback shim; see requirements-dev.txt
    from _hypothesis_shim import given, settings, strategies as st

from repro.core import (
    AggTask,
    Aggregator,
    AssignmentConfig,
    JobProfile,
    assign_job,
    balanced_shard_assignment,
    cyclic_loss,
    effective_iteration,
    iterations_per_cycle,
    round_robin_shard_assignment,
    shard_imbalance,
)
from repro.core import ip_model, perf_model, scaling
from repro.core.cyclic import admit_late_request, build_schedule
from repro.core.migration import (
    MigrationState,
    ProtocolError,
    TensorMigration,
    checkpoint_restart_cost,
    migration_cost,
)


def _job(job_id, duration, exec_times, n_workers=2, required=1):
    tasks = [
        AggTask(job_id, i, f"t{i}", nbytes=int(e * 1e9), exec_time=e)
        for i, e in enumerate(exec_times)
    ]
    return JobProfile(job_id, "m", duration, tasks, n_workers, required)


def _alloc_factory():
    counter = [0]

    def alloc():
        counter[0] += 1
        return Aggregator(agg_id=f"a{counter[0]}")

    return alloc


# ---------------------------------------------------------------- cyclic math
def test_paper_toy_example_cycles():
    """Figure 5: J1 iter=6 agg=2, J2 iter=12 agg=3; packed cycle is 12 and J1
    runs twice per cycle."""
    assert iterations_per_cycle(12.0, 6.0) == 2
    assert effective_iteration(12.0, 6.0) == 6.0  # no loss: 12 divides evenly
    assert cyclic_loss(12.0, 6.0) == 0.0


def test_paper_17pct_loss_example():
    """§3.3.1: a task with D=5 joining a cycle of 12 gets d=6 -> ~17% loss."""
    d = effective_iteration(12.0, 5.0)
    assert d == 6.0
    assert abs(cyclic_loss(12.0, 5.0) - 1.0 / 6.0) < 1e-12


@given(
    cycle=st.floats(0.01, 1e3),
    duration=st.floats(0.01, 1e3),
)
def test_effective_iteration_invariants(cycle, duration):
    c = max(cycle, duration)  # cycle is always >= any member's D
    d = effective_iteration(c, duration)
    reps = iterations_per_cycle(c, duration)
    assert d >= duration - 1e-9  # never faster than standalone
    assert reps * d == pytest.approx(c)  # executions tile the cycle exactly
    assert 0.0 <= cyclic_loss(c, duration) < 1.0


# ------------------------------------------------------------- Pseudocode 1
def test_assignment_packs_when_it_fits():
    aggs = []
    alloc = _alloc_factory()
    j1 = _job("j1", 1.0, [0.3, 0.2])
    j2 = _job("j2", 1.0, [0.25, 0.15])
    assign_job(j1, aggs, alloc)
    assign_job(j2, aggs, alloc)
    assert len(aggs) == 1  # total load 0.9 fits one server
    assert aggs[0].utilization <= 1.0 + 1e-9


def test_assignment_spills_on_capacity():
    aggs = []
    alloc = _alloc_factory()
    assign_job(_job("j1", 1.0, [0.7]), aggs, alloc)
    assign_job(_job("j2", 1.0, [0.7]), aggs, alloc)
    assert len(aggs) == 2  # 1.4 load cannot fit one unit server


def test_assignment_rejects_cyclic_loss():
    """A job with D=5 must not join an Aggregator whose cycle is 12 (17% loss
    >= LossLimit)."""
    aggs = []
    alloc = _alloc_factory()
    assign_job(_job("slow", 12.0, [0.5]), aggs, alloc)
    assign_job(_job("fast", 5.0, [0.1]), aggs, alloc)
    assert len(aggs) == 2  # forced onto its own Aggregator


def test_assignment_accepts_harmonic_periods():
    aggs = []
    alloc = _alloc_factory()
    assign_job(_job("slow", 12.0, [0.5]), aggs, alloc)
    assign_job(_job("fast", 6.0, [0.1]), aggs, alloc)  # 12/6 integral: no loss
    assert len(aggs) == 1


def test_best_fit_prefers_fullest_fitting_aggregator():
    aggs = []
    alloc = _alloc_factory()
    assign_job(_job("j1", 1.0, [0.6]), aggs, alloc)
    assign_job(_job("j2", 1.0, [0.2]), aggs, alloc)  # packs with j1 (best fit)
    assert len(aggs) == 1
    assign_job(_job("j3", 1.0, [0.5]), aggs, alloc)  # must spill
    assert len(aggs) == 2
    # j4 task of 0.15: best fit is the fuller aggregator that still fits.
    assign_job(_job("j4", 1.0, [0.15]), aggs, alloc)
    assert len(aggs) == 2
    loads = sorted(a.busy_time() for a in aggs)
    assert loads == pytest.approx([0.5, 0.95])


@settings(deadline=None, max_examples=60)
@given(
    execs=st.lists(st.floats(0.01, 0.5), min_size=1, max_size=12),
    duration=st.floats(0.5, 4.0),
)
def test_assignment_never_overloads(execs, duration):
    """Property: after any single-job assignment, every Aggregator satisfies
    the App. C capacity constraint W_n <= capacity * C_n."""
    aggs = []
    assign_job(_job("j", duration, execs), aggs, _alloc_factory())
    for a in aggs:
        assert a.busy_time() <= a.capacity * a.cycle + 1e-9
    # and every task landed exactly once
    placed = sum(len(a.tasks) for a in aggs)
    assert placed == len(execs)


@settings(deadline=None, max_examples=25)
@given(
    n_jobs=st.integers(1, 4),
    seed=st.integers(0, 10_000),
)
def test_multi_job_losses_bounded(n_jobs, seed):
    """Property: predicted loss of every packed job stays below LossLimit
    after admission with the feedback loop."""
    import random

    rng = random.Random(seed)
    aggs, jobs = [], {}
    alloc = _alloc_factory()
    for i in range(n_jobs):
        duration = rng.choice([0.5, 1.0, 2.0, 4.0])
        execs = [rng.uniform(0.02, 0.3) for _ in range(rng.randint(1, 8))]
        job = _job(f"j{i}", duration, execs)
        scaling.admit_job(job, aggs, jobs, alloc)
        jobs[job.job_id] = job
    losses = perf_model.predict_all_losses(jobs, aggs)
    assert max(losses.values()) < AssignmentConfig().loss_limit + 1e-9


# ------------------------------------------------------ balanced vs RR shards
def test_balanced_beats_round_robin_on_skew():
    """Fig. 7: AutoPS's balanced placement beats ps-lite round-robin on models
    with skewed tensor sizes (the up-to-1.17x single-job speedup)."""
    job = _job("j", 1.0, [0.5, 0.04, 0.04, 0.3, 0.02, 0.1])
    rr = shard_imbalance(round_robin_shard_assignment(job, 2))
    bal = shard_imbalance(balanced_shard_assignment(job, 2))
    assert bal <= rr
    assert bal < 1.1  # LPT greedy is near-balanced here


# ------------------------------------------------------------------- scaling
def test_job_exit_recycles_aggregators():
    aggs, jobs = [], {}
    alloc = _alloc_factory()
    for i in range(3):
        job = _job(f"j{i}", 1.0, [0.4])
        scaling.admit_job(job, aggs, jobs, alloc)
        jobs[job.job_id] = job
    assert len(aggs) == 2  # 1.2 load over unit servers
    jobs.pop("j0")
    scaling.release_job("j0", aggs, jobs)
    assert len(aggs) == 1  # 0.8 load consolidates after exit


def test_recycle_respects_loss_limit():
    aggs, jobs = [], {}
    alloc = _alloc_factory()
    j_slow = _job("slow", 12.0, [0.5])
    j_fast = _job("fast", 5.0, [0.4])
    for j in (j_slow, j_fast):
        scaling.admit_job(j, aggs, jobs, alloc)
        jobs[j.job_id] = j
    assert len(aggs) == 2
    # Nothing exits; recycling must not merge them (17% cyclic loss).
    n = scaling.recycle_aggregators(aggs, jobs)
    assert n == 0 and len(aggs) == 2


# ----------------------------------------------------------------- outliers
def test_late_request_executes_in_spare_slots():
    agg = Aggregator("a0")
    job = _job("j", 1.0, [0.2, 0.1])
    for t in job.tasks:
        agg.add_task(t, job.iteration_duration)
    sched = build_schedule(agg)
    assert sched.utilization == pytest.approx(0.3)
    out = admit_late_request(sched, arrival=0.5, exec_time=0.1)
    assert out.executed_now and out.postponed_iterations == 0


def test_late_request_postpones_when_full():
    agg = Aggregator("a0")
    job = _job("j", 1.0, [0.5, 0.45])
    for t in job.tasks:
        agg.add_task(t, job.iteration_duration)
    sched = build_schedule(agg)
    out = admit_late_request(sched, arrival=0.9, exec_time=0.3)
    assert not out.executed_now
    assert out.postponed_iterations == 1  # worst case: one iteration (paper)


# ---------------------------------------------------------------- migration
def test_migration_protocol_order_enforced():
    m = TensorMigration("j", 0, "a0", "a1")
    with pytest.raises(ProtocolError):
        m.advance(MigrationState.COPYING)  # must repoint Agents first
    m.advance(MigrationState.INIT)
    assert not m.update_allowed_on("a1")  # I2: stale master copy
    m.advance(MigrationState.REPOINTED)
    m.advance(MigrationState.COPYING)
    assert not m.update_allowed_on("a1")
    m.advance(MigrationState.COPY_DONE)
    assert m.update_allowed_on("a1")  # now legal
    assert not m.update_allowed_on("a0")  # old owner must never update again
    m.run_to_completion()
    assert m.state is MigrationState.COMPLETE


def test_migration_hidden_by_compute_window():
    """Table 3: migration visible stall is tens of ms, vs tens of seconds for
    checkpoint-restart."""
    # VGG19-scale: 575 MB over a 100 Gbps link inside a 0.5 s fwd/bwd window.
    cost = migration_cost(575_000_000, link_bandwidth=12.5e9, compute_window=0.5)
    assert cost.visible_stall < 0.050  # paper: 21.5 ms for VGG19
    naive = checkpoint_restart_cost(575_000_000, storage_bandwidth=1e9)
    assert naive > 10.0
    assert naive / max(cost.visible_stall, 1e-9) > 100


# ----------------------------------------------------------------- IP model
def test_heuristic_close_to_bruteforce_optimum():
    jobs = [
        _job("j1", 2.0, [0.6, 0.3]),
        _job("j2", 3.0, [0.5, 0.2]),
    ]
    best = ip_model.brute_force(jobs, n_aggregators=2)
    assert best is not None
    _, ev_opt = best

    aggs = []
    alloc = _alloc_factory()
    running = {}
    for j in jobs:
        scaling.admit_job(j, aggs, running, alloc)
        running[j.job_id] = j
    assignment = {}
    ids = {a.agg_id: i for i, a in enumerate(aggs)}
    for a in aggs:
        for key in a.tasks:
            assignment[key] = ids[a.agg_id]
    ev_h = ip_model.evaluate(jobs, assignment, len(aggs))
    assert ev_h.feasible
    # Heuristic stays within LossLimit of the optimum (usually equal).
    assert ev_h.max_loss <= ev_opt.max_loss + 0.1
