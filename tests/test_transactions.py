"""Replan transactions (PR 9): commit-or-abort registry mutations with
rollback and shared-policy retry.

Every ``ParameterService`` mutator (register/exit/scale/evacuate) now
runs as a transaction: the registry is snapshotted, the replan runs, and
any listener failure rolls the snapshot back before the shared
``RetryPolicy`` decides whether to retry with a FRESH snapshot or raise
``ReplanAbortedError``.  The invariant under test everywhere: after any
outcome -- commit, retried commit, or abort -- the control plane
(``service.compile_sharded_plan()``) and the data plane (``rt.splan``)
describe the SAME layout, and training continues bit-exact on it.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ParameterService
from repro.core.service import _ReplanFailure
from repro.ps.faults import (
    FaultInjector,
    InjectedFault,
    ReplanAbortedError,
    RetryPolicy,
)
from repro.ps.service_runtime import ServiceRuntime, ShardedServiceRuntime


def _tree(key, sizes):
    ks = jax.random.split(key, len(sizes))
    return {f"t{i}": jax.random.normal(k, (n,))
            for i, (k, n) in enumerate(zip(ks, sizes))}


def _loss(params, batch):
    return sum(jnp.sum((params[k] - batch["target"][k]) ** 2)
               for k in params)


TREES = {
    "a": _tree(jax.random.PRNGKey(0), (48, 16, 32)),
    "b": _tree(jax.random.PRNGKey(1), (32, 16)),
    "c": _tree(jax.random.PRNGKey(2), (48, 16)),
}
TARGETS = {j: jax.tree_util.tree_map(lambda p: p * 0 + 1.0, t)
           for j, t in TREES.items()}


def _add_jobs(rt, trees=TREES):
    for jid, t in trees.items():
        nbytes = sum(4 * v.size for v in t.values())
        rt.add_job(jid, t, _loss, lr=0.05, required_servers=1,
                   agg_throughput=nbytes / 0.2)


def _sharded(n_shards=2, trees=TREES, **engine_opts):
    svc = ParameterService(total_budget=16, n_clusters=1, plan_pad_to=16)
    rt = ShardedServiceRuntime(svc, jit=False)
    engine_opts.setdefault("max_staleness", 0)
    eng = rt.attach_engine(jit=False, **engine_opts)
    _add_jobs(rt, trees)
    if n_shards > 1:
        svc.scale_out(n_shards - 1)
    return rt, eng


def _drive(eng, n, trees=TREES):
    for _ in range(n):
        for j in trees:
            eng.step(j, {"target": TARGETS[j]})
    eng.drain()


def _assert_params_equal(rt_a, rt_b, jobs=TREES):
    for j in jobs:
        pa, pb = rt_a.params_of(j), rt_b.params_of(j)
        for k in pa:
            np.testing.assert_array_equal(np.asarray(pa[k]),
                                          np.asarray(pb[k]))


def _agree(rt):
    """Control plane and data plane describe the same layout."""
    assert rt.service.compile_sharded_plan() == rt.splan
    assert set(rt.service._jobs) == set(rt._jobs)


# ----------------------------------------------------------- retry policy
def test_retry_policy_backoff_and_budget():
    slept = []
    pol = RetryPolicy(max_retries=3, base_delay=0.1, max_delay=0.25,
                      sleep=slept.append)
    assert pol.should_retry(1) and pol.should_retry(3)
    assert not pol.should_retry(4)
    assert pol.delay(1) == pytest.approx(0.1)
    assert pol.delay(2) == pytest.approx(0.2)
    assert pol.delay(3) == pytest.approx(0.25)  # capped
    for i in (1, 2, 3):
        pol.backoff(i)
    assert slept == pytest.approx([0.1, 0.2, 0.25])
    # zero base_delay (the test default) never sleeps
    quiet = RetryPolicy(max_retries=2, sleep=slept.append)
    quiet.backoff(1)
    assert len(slept) == 3


# -------------------------------------------- divergence regression (sat 1)
def test_transient_migration_fault_retries_and_planes_agree():
    """THE regression: a fault inside the replan used to leave the
    registry scaled out while the data plane kept the old layout.  Now
    the abort rolls the registry back and the retry lands both planes on
    the new layout together."""
    inj = FaultInjector()
    rt, eng = _sharded(n_shards=2, fault_injector=inj)
    ref, ref_eng = _sharded(n_shards=2)
    _drive(eng, 2)
    _drive(ref_eng, 2)

    inj.fail_migration(at=1)  # transient: first attempt dies, retry wins
    assert rt.service.scale_out(1) == 1
    assert rt.service.n_replan_aborts == 1
    assert rt.service.n_replan_retries == 1
    assert rt.n_shards == 3
    _agree(rt)

    ref_rt_svc = ref.service
    assert ref_rt_svc.scale_out(1) == 1  # fault-free twin, same transition
    _drive(eng, 3)
    _drive(ref_eng, 3)
    _assert_params_equal(rt, ref)


def test_persistent_migration_fault_aborts_and_rolls_back():
    inj = FaultInjector()
    rt, eng = _sharded(n_shards=2, fault_injector=inj,
                       retry_policy=RetryPolicy(max_retries=2))
    ref, ref_eng = _sharded(n_shards=2)
    _drive(eng, 2)
    _drive(ref_eng, 2)

    inj.fail_migration(at=1, times=math.inf)
    with pytest.raises(ReplanAbortedError) as ei:
        rt.service.scale_out(1)
    assert ei.value.op == "scale_out"
    assert ei.value.attempts == 3  # 1 try + 2 retries
    assert isinstance(ei.value.original, InjectedFault)
    assert "rolled back" in str(ei.value)
    assert rt.service.n_replan_aborts == 3
    assert rt.service.n_replan_retries == 2

    # Both planes still on the OLD layout; training unaffected.
    assert rt.n_shards == 2
    _agree(rt)
    inj.rules.clear()
    _drive(eng, 3)
    _drive(ref_eng, 3)
    _assert_params_equal(rt, ref)


def test_mid_migration_fault_is_abort_safe():
    """``after_shards=1`` kills the migration AFTER one shard of the new
    plan is relaid: the transaction must still leave the committed states
    untouched (migrate_sharded_state is functional over its inputs)."""
    inj = FaultInjector()
    rt, eng = _sharded(n_shards=2, fault_injector=inj,
                       retry_policy=RetryPolicy(max_retries=0))
    ref, ref_eng = _sharded(n_shards=2)
    _drive(eng, 2)
    _drive(ref_eng, 2)

    inj.fail_migration(at=1, after_shards=1, times=math.inf)
    with pytest.raises(ReplanAbortedError):
        rt.service.scale_out(1)
    assert rt.n_shards == 2
    _agree(rt)
    inj.rules.clear()
    _drive(eng, 3)
    _drive(ref_eng, 3)
    _assert_params_equal(rt, ref)


def test_register_and_exit_aborts_restore_both_planes():
    inj = FaultInjector()
    rt, eng = _sharded(n_shards=2, fault_injector=inj,
                       retry_policy=RetryPolicy(max_retries=0))
    _drive(eng, 1)

    # register_job: the new job must not exist anywhere after the abort.
    inj.fail_migration(at=1, times=math.inf)
    tree_d = _tree(jax.random.PRNGKey(7), (24, 24))
    with pytest.raises(ReplanAbortedError):
        rt.add_job("d", tree_d, _loss, lr=0.05, required_servers=1,
                   agg_throughput=sum(4 * v.size
                                      for v in tree_d.values()) / 0.2)
    assert "d" not in rt._jobs
    _agree(rt)

    # job_exit: the departing job must STAY everywhere after the abort.
    with pytest.raises(ReplanAbortedError):
        rt.remove_job("a")
    assert "a" in rt._jobs
    assert "a" in rt.service._jobs
    _agree(rt)

    # ... and still trains after the rules clear.
    inj.rules.clear()
    _drive(eng, 2)
    rt.remove_job("a")
    _agree(rt)


def test_validation_errors_bypass_retry():
    """Control-plane validation failures are not transactions to retry:
    they raise unchanged with zero abort/retry counted."""
    rt, _eng = _sharded(n_shards=1)
    with pytest.raises(KeyError):
        rt.service.job_exit("nope")
    with pytest.raises(ValueError):
        rt.service.evacuate_aggregator("c9/a99")
    assert rt.service.n_replan_aborts == 0
    assert rt.service.n_replan_retries == 0


def test_replan_failure_marker_wraps_original():
    boom = RuntimeError("boom")
    wrapped = _ReplanFailure(boom)
    assert wrapped.original is boom


# -------------------------------------------------- debug stats (sat 3)
def test_debug_stats_surface_transactions_and_faults():
    inj = FaultInjector()
    rt, eng = _sharded(n_shards=2, fault_injector=inj)
    inj.fail_apply(None, at=1)
    inj.fail_migration(at=1)
    _drive(eng, 2)
    assert rt.service.scale_out(1) == 1

    stats = rt.debug_stats()
    assert stats["transactions"] == {
        "n_replan_commits": rt.service.n_replan_commits,
        "n_replan_aborts": 1,
        "n_replan_retries": 1,
    }
    assert stats["faults"]["n_fired"] == inj.n_fired >= 2
    assert stats["faults"]["by_kind"] == inj.fire_counts()
    assert stats["faults"]["by_kind"]["fail_migration"] == 1
    assert stats["engine"]["n_lease_expirations"] == 0

    # Flat runtime surfaces the same sections (faults None when detached
    # from any injector).
    flat = ServiceRuntime(
        ParameterService(total_budget=16, n_clusters=1, plan_pad_to=16),
        jit=False)
    flat.attach_engine(max_staleness=0, jit=False)
    _add_jobs(flat, {"a": TREES["a"]})
    fstats = flat.debug_stats()
    assert fstats["transactions"]["n_replan_commits"] >= 1
    assert fstats["transactions"]["n_replan_aborts"] == 0
    assert fstats["faults"] is None
    assert fstats["engine"]["n_lease_expirations"] == 0
