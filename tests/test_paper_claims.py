"""Reproduction tests for the paper's headline evaluation numbers.

These drive ParameterService with the calibrated paper workload profiles and
assert the claims of §5.2: Fig. 2 utilizations, Fig. 8 Aggregator counts,
Table 2 CPU-reduction ratios, Fig. 9 loss bounds, and the single-job Fig. 7
balanced-placement effect.
"""

import pytest

from repro.core import ParameterService
from repro.core.assignment import (
    balanced_shard_assignment,
    round_robin_shard_assignment,
    shard_imbalance,
)
from repro.configs.paper_workloads import make_job, standalone_utilization


def _service(**kw):
    return ParameterService(total_budget=64, n_clusters=1, **kw)


def _run_multi_job(model, n_jobs, servers, workers):
    svc = _service()
    for i in range(n_jobs):
        svc.register_job(make_job(model, f"{model}-{i}", servers, workers))
    return svc


# --------------------------------------------------------------------- Fig 2
def test_fig2_cpu_underutilization():
    """Dedicated-PS average CPU utilization is far below 100%; VGG19 1s-2w is
    the paper's headline ~16%."""
    utils = {m: standalone_utilization(m, 1, 2) for m in
             ("alexnet", "vgg19", "awd-lm", "bert")}
    assert utils["vgg19"] == pytest.approx(0.16, abs=0.02)
    assert all(u < 0.6 for u in utils.values())
    assert sum(utils.values()) / 4 < 0.5  # "more than half ... unused"


# --------------------------------------------------------------------- Fig 7
def test_fig7_balanced_placement_beats_round_robin():
    """AutoPS standalone outperforms ps-lite by up to 1.17x via balance. The
    slowest shard paces each iteration, so speedup ~= RR imbalance /
    balanced imbalance on skewed models (VGG19's fc6 is 72% of bytes)."""
    for model, servers in (("vgg19", 2), ("alexnet", 2), ("bert", 4)):
        job = make_job(model, "j", servers, 2, chunk_bytes=1 << 62)  # whole tensors
        rr = shard_imbalance(round_robin_shard_assignment(job, servers))
        bal = shard_imbalance(balanced_shard_assignment(job, servers))
        assert bal <= rr + 1e-9
    # VGG19 whole-tensor RR is badly imbalanced -> AutoPS speedup headroom.
    vgg = make_job("vgg19", "j", 2, 2, chunk_bytes=1 << 62)
    rr = shard_imbalance(round_robin_shard_assignment(vgg, 2))
    assert rr > 1.15  # >= the paper's observed 1.17x-class headroom


# --------------------------------------------------------------------- Fig 8
@pytest.mark.parametrize(
    "model,n_jobs,expected_aggs",
    [
        ("alexnet", 2, 3),   # the one model that needs an extra Aggregator
        ("vgg19", 2, 2),
        ("vgg19", 4, 2),     # "2 Aggregators can serve 4 VGG19 jobs"
        ("awd-lm", 2, 2),
        ("awd-lm", 4, 2),
        ("bert", 2, 2),
    ],
)
def test_fig8_aggregator_counts_2s2w(model, n_jobs, expected_aggs):
    svc = _run_multi_job(model, n_jobs, servers=2, workers=2)
    assert svc.n_aggregators == expected_aggs


def test_fig8_reduction_band():
    """CPU-server savings across 2s-2w groups land in the paper's 25-75%."""
    ratios = []
    for model in ("alexnet", "vgg19", "awd-lm", "bert"):
        for n_jobs in (2, 3, 4):
            svc = _run_multi_job(model, n_jobs, 2, 2)
            ratios.append(svc.cpu_reduction())
    assert min(ratios) == pytest.approx(0.25, abs=1e-6)  # AlexNet 2-job
    assert max(ratios) == pytest.approx(0.75, abs=1e-6)  # VGG19/AWD-LM 4-job


# -------------------------------------------------------------------- Table 2
@pytest.mark.parametrize(
    "model,expected_ratio",
    [("alexnet", 0.375), ("vgg19", 0.5), ("awd-lm", 0.5), ("bert", 0.5)],
)
def test_table2_reduction_ratio_4s4w(model, expected_ratio):
    svc = _run_multi_job(model, 2, servers=4, workers=4)
    assert svc.cpu_reduction() == pytest.approx(expected_ratio, abs=1e-6)


# --------------------------------------------------------------------- Fig 9
def test_fig9_loss_bounded_by_losslimit():
    """Sharing AutoPS costs at most ~9% of training speed (paper Fig. 9)."""
    for model in ("alexnet", "vgg19", "awd-lm", "bert"):
        for n_jobs in (2, 4):
            svc = _run_multi_job(model, n_jobs, 2, 2)
            losses = svc.predicted_losses()
            assert max(losses.values()) <= 0.09 + 1e-9


# ------------------------------------------------------- utilization benefit
def test_packing_improves_mean_utilization():
    """The whole point: shared Aggregators run hotter than dedicated ones."""
    solo = _run_multi_job("vgg19", 1, 2, 2)
    packed = _run_multi_job("vgg19", 4, 2, 2)
    mean_u = lambda s: sum(s.utilizations().values()) / s.n_aggregators
    assert mean_u(packed) > 2.5 * mean_u(solo)
