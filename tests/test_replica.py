"""Read tier (PR 10): publish-on-tick snapshots, pull-only replicas,
batched lookup, staleness bounds, and degraded serving.

Parity notes.  Publishes fire PRE-apply (co-located with the PR-7
rollback snapshot), so a replica legitimately trails the live state by
the in-flight tick; ``ReplicaSet.refresh()`` force-publishes the CURRENT
state and every replica-vs-engine comparison below refreshes first --
after that the two must match bit for bit.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ParameterService
from repro.ps.faults import (
    QUARANTINED,
    EngineQuarantinedError,
    FaultInjector,
)
from repro.ps.replica import ParameterReplica, ReadStats, ReplicaSet
from repro.ps.service_runtime import ServiceRuntime, ShardedServiceRuntime


def _tree(key, sizes):
    ks = jax.random.split(key, len(sizes))
    return {f"t{i}": jax.random.normal(k, (n,))
            for i, (k, n) in enumerate(zip(ks, sizes))}


def _loss(params, batch):
    return sum(jnp.sum((params[k] - batch["target"][k]) ** 2)
               for k in params)


TREES = {
    "a": _tree(jax.random.PRNGKey(0), (48, 16, 32)),
    "b": _tree(jax.random.PRNGKey(1), (32, 16)),
    "c": _tree(jax.random.PRNGKey(2), (48, 16)),
}
TARGETS = {j: jax.tree_util.tree_map(lambda p: p * 0 + 1.0, t)
           for j, t in TREES.items()}


def _add_jobs(rt):
    for jid, t in TREES.items():
        nbytes = sum(4 * v.size for v in t.values())
        rt.add_job(jid, t, _loss, lr=0.05, required_servers=1,
                   agg_throughput=nbytes / 0.2)


def _flat(**engine_opts):
    rt = ServiceRuntime(
        ParameterService(total_budget=16, n_clusters=1, plan_pad_to=16),
        jit=False)
    engine_opts.setdefault("max_staleness", 0)
    eng = rt.attach_engine(jit=False, **engine_opts)
    _add_jobs(rt)
    return rt, eng


def _sharded(n_shards=3, **engine_opts):
    svc = ParameterService(total_budget=16, n_clusters=1, plan_pad_to=16)
    rt = ShardedServiceRuntime(svc, jit=False)
    engine_opts.setdefault("max_staleness", 0)
    eng = rt.attach_engine(jit=False, **engine_opts)
    _add_jobs(rt)
    if n_shards > 1:
        svc.scale_out(n_shards - 1)
    return rt, eng


def _drive(eng, n):
    for _ in range(n):
        for j in TREES:
            eng.step(j, {"target": TARGETS[j]})
    eng.drain()


def _assert_trees_equal(a, b):
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))


# ------------------------------------------------------------- construction
def test_replica_set_validates_arguments():
    rt, eng = _flat()
    with pytest.raises(ValueError, match="n_replicas"):
        ReplicaSet(eng, n_replicas=0)
    with pytest.raises(ValueError, match="publish_interval"):
        ReplicaSet(eng, publish_interval=0)
    with pytest.raises(ValueError, match="max_staleness_ticks"):
        ReplicaSet(eng, max_staleness_ticks=-1)
    rs = ReplicaSet(eng, n_replicas=3)
    assert len(rs.replicas) == 3
    assert all(isinstance(r, ParameterReplica) for r in rs.replicas)
    with pytest.raises(ValueError, match="already has a ReplicaSet"):
        ReplicaSet(eng)


# ------------------------------------------------------- publish + parity
@pytest.mark.parametrize("build", [_flat, _sharded],
                         ids=["flat", "sharded"])
def test_tree_pull_parity_after_refresh(build):
    rt, eng = build()
    rs = ReplicaSet(eng, n_replicas=2)
    _drive(eng, 4)
    assert rs.n_publishes > 0  # every applying tick offered a publish
    rs.refresh()
    for j in TREES:
        _assert_trees_equal(eng.pull(j), rs.pull(j))
    for rep in rs.replicas:
        assert rep.stats.n_snapshots_seen > 0


@pytest.mark.parametrize("build", [_flat, _sharded],
                         ids=["flat", "sharded"])
def test_versioned_pull_and_diff_chain_parity(build):
    rt, eng = build()
    rs = ReplicaSet(eng, n_replicas=1)
    _drive(eng, 3)
    rs.refresh()
    rep = rs.replicas[0]
    for j in TREES:
        de = eng.pull(j, since_version=0)
        d0 = rep.pull(j, since_version=0)
        assert d0.full and d0.bytes_full == de.bytes_full
        np.testing.assert_array_equal(np.asarray(d0.data),
                                      np.asarray(de.data))
        # Chain: step only "a", diff against the held vector, patch.
    for _ in range(2):
        eng.step("a", {"target": TARGETS["a"]})
    eng.drain()
    rs.refresh()
    held = rep.pull("a", since_version=0)
    base = rep.pull("b", since_version=0)
    d1 = rep.pull("b", since_version=base.version)
    assert not d1.full and d1.block_ids.size == 0  # "b" never moved
    d2 = rep.pull("a", since_version=held.version)
    # held was served at the same refresh: the extra steps landed after,
    # so this diff is empty too; now move "a" and diff again.
    eng.step("a", {"target": TARGETS["a"]})
    eng.drain()
    rs.refresh()
    d3 = rep.pull("a", since_version=d2.version)
    assert not d3.full and d3.block_ids.size > 0
    assert d3.bytes_wire == 4 * d3.block_ids.size * d3.block
    patched = d3.apply(d2.apply(held.data))
    np.testing.assert_array_equal(
        np.asarray(patched), np.asarray(eng.pull("a",
                                                 since_version=0).data))


def test_pull_batch_matches_sequential_pulls():
    rt, eng = _sharded()
    rs = ReplicaSet(eng, n_replicas=1)
    _drive(eng, 3)
    rs.refresh()
    rep = rs.replicas[0]
    boot = rep.pull_batch([(j, 0) for j in TREES])
    assert [d.job_id for d in boot] == list(TREES)
    for d in boot:
        ref = eng.pull(d.job_id, since_version=0)
        assert d.full
        np.testing.assert_array_equal(np.asarray(d.data),
                                      np.asarray(ref.data))
    vec = {d.job_id: d.version for d in boot}
    for _ in range(2):  # only "a" moves
        eng.step("a", {"target": TARGETS["a"]})
    eng.drain()
    rs.refresh()
    batch = rep.pull_batch([(j, vec[j]) for j in TREES])
    for d in batch:
        ref = rep.pull(d.job_id, since_version=vec[d.job_id])
        assert d.full == ref.full
        np.testing.assert_array_equal(d.block_ids, ref.block_ids)
        np.testing.assert_array_equal(np.asarray(d.data),
                                      np.asarray(ref.data))
        assert d.bytes_wire == ref.bytes_wire
    moved = {d.job_id: d.block_ids.size for d in batch}
    assert moved["a"] > 0 and moved["b"] == 0 and moved["c"] == 0
    assert rep.stats.n_batches == 2
    assert rep.stats.n_batch_jobs == 2 * len(TREES)


# ------------------------------------------------------------ epoch fence
def test_replan_fences_snapshots_and_resubscribes():
    rt, eng = _sharded(n_shards=2)
    rs = ReplicaSet(eng, n_replicas=2)
    _drive(eng, 3)
    rs.refresh()
    before = rs.epoch
    assert rt.service.scale_out(1) == 1  # replan: epoch bump
    assert rs.epoch > before
    # New-geometry ticks resubscribe as they apply: the epoch check in
    # on_tick overrides publish_interval.
    _drive(eng, 2)
    assert all(rep._snaps[k].epoch == rs.epoch
               for rep in rs.replicas for k in rep._snaps)
    rs.refresh()
    for j in TREES:  # post-replan serve is bit-exact on the new geometry
        _assert_trees_equal(eng.pull(j), rs.pull(j))


def test_stale_epoch_pull_forces_refresh_not_stale_serve():
    rt, eng = _sharded(n_shards=2)
    rs = ReplicaSet(eng, n_replicas=1, publish_interval=1000)
    rep = rs.replicas[0]
    _drive(eng, 2)
    rs.refresh()
    rep.pull("a")
    assert rt.service.scale_out(1) == 1
    # No tick has run at the new epoch: the held snapshots still carry
    # the OLD epoch (a post-replan tick would have resubscribed -- the
    # epoch check overrides publish_interval); the fence must force a
    # refresh rather than serve the wrong geometry.
    n_before = rep.stats.n_forced_refreshes
    _assert_trees_equal(eng.pull("a"), rep.pull("a"))
    assert rep.stats.n_forced_refreshes == n_before + 1


# -------------------------------------------------------- staleness bound
def test_staleness_bound_forces_refresh():
    rt, eng = _flat()
    rs = ReplicaSet(eng, n_replicas=1, publish_interval=1000,
                    max_staleness_ticks=1)
    rep = rs.replicas[0]
    _drive(eng, 1)
    rs.refresh()
    _drive(eng, 4)  # way past the bound, nothing republished
    n_before = rep.stats.n_forced_refreshes
    _assert_trees_equal(eng.pull("a"), rep.pull("a"))
    assert rep.stats.n_forced_refreshes == n_before + 1
    assert max(rep.stats.staleness_hist) <= 1


def test_unbounded_staleness_serves_old_snapshot():
    rt, eng = _flat()
    rs = ReplicaSet(eng, n_replicas=1, publish_interval=1000,
                    max_staleness_ticks=None)
    rep = rs.replicas[0]
    _drive(eng, 1)
    rs.refresh()
    held = {j: rep.pull(j) for j in TREES}
    _drive(eng, 4)
    for j in TREES:  # no bound: the old snapshot keeps serving
        _assert_trees_equal(held[j], rep.pull(j))
    assert rep.stats.n_forced_refreshes == 0
    assert max(rep.stats.staleness_hist) > 1


def test_client_ahead_of_replica_forces_refresh():
    rt, eng = _flat()
    rs = ReplicaSet(eng, n_replicas=1, publish_interval=1000)
    rep = rs.replicas[0]
    _drive(eng, 2)
    rs.refresh()
    _drive(eng, 2)
    # The client bootstrapped off the ENGINE (live state): its vector is
    # AHEAD of the replica's held snapshot.  A naive diff would report
    # "no change"; the replica must refresh to at least the client view.
    ahead = eng.pull("a", since_version=0)
    d = rep.pull("a", since_version=ahead.version)
    assert rep.stats.n_forced_refreshes >= 1
    assert not d.full and d.block_ids.size == 0
    np.testing.assert_array_equal(d.version.versions,
                                  ahead.version.versions)


# ------------------------------------------------------ degraded serving
def test_quarantined_lane_serves_last_good_degraded():
    inj = FaultInjector()
    rt, eng = _sharded(fault_injector=inj)
    rs = ReplicaSet(eng, n_replicas=1)
    rep = rs.replicas[0]
    victim = rt.shard_ids[-1]
    _drive(eng, 2)
    rs.refresh()
    inj.kill_shard(victim, at=1)
    with pytest.raises(EngineQuarantinedError):
        _drive(eng, 8)
    assert eng.shard_health()[victim] == QUARANTINED
    hosted = [j for j in TREES
              if victim in rt.splan.job_layout(j).shard_ids]
    assert hosted, "placement left no job on the victim shard"
    frozen = rep._snaps[victim]  # the dead lane's last-good snapshot
    for j in hosted:
        # Direct engine pulls die with the lane; the replica keeps
        # serving -- the victim's rows off its last-good snapshot
        # (healthy lanes' rows stay current), flagged degraded.
        with pytest.raises(EngineQuarantinedError):
            eng.pull(j)
        served = rep.pull(j)
        assert victim in rep.degraded_lanes
        _assert_trees_equal(served, rep.pull(j))  # deterministic
    assert rep._snaps[victim] is frozen  # nothing republished the lane
    assert rep.stats.n_degraded_serves >= len(hosted)
    # refresh() skips the dead lane instead of touching its buffers.
    published = rs.refresh()
    assert victim not in published


def test_quarantined_lane_without_snapshot_raises():
    inj = FaultInjector()
    rt, eng = _sharded(fault_injector=inj)
    victim = rt.shard_ids[-1]
    inj.kill_shard(victim, at=1)
    with pytest.raises(EngineQuarantinedError):
        _drive(eng, 8)
    # Subscribing AFTER the lane died: no last-good snapshot exists, so
    # a pull of a hosted job propagates the lane's quarantine error.
    rs = ReplicaSet(eng, n_replicas=1)
    hosted = [j for j in TREES
              if victim in rt.splan.job_layout(j).shard_ids]
    with pytest.raises(EngineQuarantinedError) as ei:
        rs.pull(hosted[0])
    assert ei.value.shard_id == victim


# ------------------------------------------------------- publish interval
def test_publish_interval_batches_publishes():
    rt, eng = _flat()
    every = ReplicaSet(eng, n_replicas=1, publish_interval=1)
    _drive(eng, 6)
    n_every = every.n_publishes

    rt2, eng2 = _flat()
    sparse = ReplicaSet(eng2, n_replicas=1, publish_interval=4)
    _drive(eng2, 6)
    assert 0 < sparse.n_publishes < n_every


def test_publish_reuses_rollback_snapshot_copy():
    rt, eng = _flat(snapshot_interval=2)
    rs = ReplicaSet(eng, n_replicas=2)
    _drive(eng, 6)
    # Publishes co-located with a PR-7 anchor refresh ride that copy.
    assert rs.n_reused_snapshot_copies > 0
    assert rs.n_reused_snapshot_copies <= rs.n_publishes


def test_snapshots_are_shared_not_copied_per_replica():
    rt, eng = _flat()
    rs = ReplicaSet(eng, n_replicas=4)
    _drive(eng, 2)
    rs.refresh()
    snaps = [rep._snaps[None] for rep in rs.replicas]
    assert all(s is snaps[0] for s in snaps[1:])


# ------------------------------------------------------------------ stats
@pytest.mark.parametrize("build", [_flat, _sharded],
                         ids=["flat", "sharded"])
def test_debug_stats_surfaces_read_tier(build):
    rt, eng = build()
    assert rt.debug_stats()["replicas"] is None
    rs = ReplicaSet(eng, n_replicas=2, max_staleness_ticks=8)
    _drive(eng, 2)
    rs.refresh()
    rs.pull("a")
    rs.pull_batch([("b", 0)])
    out = rt.debug_stats()["replicas"]
    assert out["n_replicas"] == 2
    assert out["max_staleness_ticks"] == 8
    assert out["n_publishes"] == rs.n_publishes
    r0 = out["replica_0"]
    assert set(r0) >= {"n_pulls", "n_batches", "bytes_served",
                       "staleness_hist", "pulls_per_sec"}
    assert r0["n_pulls"] == 1 and r0["bytes_served"] > 0
    assert out["replica_1"]["n_batches"] == 1
    assert isinstance(ReadStats().pulls_per_sec, float)


def test_round_robin_spreads_load():
    rt, eng = _flat()
    rs = ReplicaSet(eng, n_replicas=3)
    _drive(eng, 2)
    rs.refresh()
    for _ in range(6):
        rs.pull("a")
    assert [rep.stats.n_pulls for rep in rs.replicas] == [2, 2, 2]
