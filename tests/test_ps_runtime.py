"""PS data-plane tests: flat plan round-trips, PS training step,
compression with error feedback, migration equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # fallback shim; see requirements-dev.txt
    from _hypothesis_shim import given, settings, strategies as st

from repro.ps.compression import ErrorFeedback, compress_decompress, quantize_int8, dequantize_int8
from repro.ps.elastic import migrate_flat_state, migration_bytes
from repro.ps.runtime import (
    build_flat_plan,
    flatten_tree,
    init_ps_state,
    make_ps_train_step,
    plan_padding_waste,
    unflatten_tree,
)


def _params(key, sizes=(100, 37, 260, 8)):
    ks = jax.random.split(key, len(sizes))
    return {f"t{i}": jax.random.normal(k, (n,)) for i, (k, n) in
            enumerate(zip(ks, sizes))}


# ------------------------------------------------------------ plan round-trip
@settings(deadline=None, max_examples=25)
@given(
    sizes=st.lists(st.integers(1, 300), min_size=1, max_size=8),
    n_shards=st.integers(1, 4),
    mode=st.sampled_from(["balanced", "round_robin"]),
)
def test_flatten_unflatten_roundtrip(sizes, n_shards, mode):
    params = _params(jax.random.PRNGKey(0), tuple(sizes))
    plan = build_flat_plan(params, n_shards, mode=mode)
    flat = flatten_tree(plan, params)
    assert flat.shape[0] == plan.total_len
    back = unflatten_tree(plan, flat, params)
    for k in params:
        np.testing.assert_array_equal(np.asarray(back[k]), np.asarray(params[k]))


def test_balanced_plan_wastes_less_padding():
    # Skewed tensor sizes: round-robin's biggest shard forces more padding.
    params = {f"t{i}": jnp.zeros((n,)) for i, n in
              enumerate([1000, 10, 10, 900, 20, 15])}
    bal = plan_padding_waste(build_flat_plan(params, 2, mode="balanced", pad_to=1))
    rr = plan_padding_waste(build_flat_plan(params, 2, mode="round_robin", pad_to=1))
    assert bal <= rr


# --------------------------------------------------------------- PS training
def _quad_loss(params, batch):
    # Simple convex problem: params should move toward batch["target"].
    diffs = [jnp.sum((params[k] - batch["target"][k]) ** 2) for k in params]
    return sum(diffs)


@pytest.mark.parametrize("compression", [None, "bf16", "int8"])
def test_ps_train_step_converges(compression):
    params = _params(jax.random.PRNGKey(0))
    target = jax.tree_util.tree_map(lambda p: p * 0 + 1.0, params)
    plan = build_flat_plan(params, n_shards=2)
    state = init_ps_state(plan, params, push_compression=compression)
    step = jax.jit(make_ps_train_step(
        _quad_loss, plan, params, lr=0.05, push_compression=compression))
    losses = []
    for _ in range(60):
        state, m = step(state, {"target": target})
        losses.append(float(m["loss"]))
    assert losses[-1] < 0.05 * losses[0], (losses[0], losses[-1])


def test_migration_preserves_training_state():
    params = _params(jax.random.PRNGKey(1))
    plan_a = build_flat_plan(params, 2, mode="round_robin")
    plan_b = build_flat_plan(params, 3, mode="balanced")
    state = init_ps_state(plan_a, params)
    state["mu"] = state["mu"] + 0.5  # non-trivial moments
    migrated = migrate_flat_state(state, plan_a, plan_b)
    # Every tensor readable identically from the new layout.
    a = unflatten_tree(plan_a, state["flat"], params)
    b = unflatten_tree(plan_b, migrated["flat"], params)
    for k in params:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))
    assert migration_bytes(plan_a, plan_b) >= 0


def test_ps_training_survives_live_migration():
    """Train - migrate mid-run - keep training: loss keeps decreasing and
    matches an unmigrated run exactly (migration is semantically free)."""
    params = _params(jax.random.PRNGKey(0))
    target = jax.tree_util.tree_map(lambda p: p * 0 + 1.0, params)
    batch = {"target": target}

    plan_a = build_flat_plan(params, 2, mode="round_robin")
    plan_b = build_flat_plan(params, 2, mode="balanced")
    step_a = jax.jit(make_ps_train_step(_quad_loss, plan_a, params, lr=0.05))
    step_b = jax.jit(make_ps_train_step(_quad_loss, plan_b, params, lr=0.05))

    s_mig = init_ps_state(plan_a, params)
    s_ref = init_ps_state(plan_a, params)
    for i in range(20):
        s_ref, m_ref = step_a(s_ref, batch)
        if i == 10:
            s_mig = migrate_flat_state(s_mig, plan_a, plan_b)
        s_mig, m_mig = (step_b if i >= 10 else step_a)(s_mig, batch)
        np.testing.assert_allclose(float(m_ref["loss"]), float(m_mig["loss"]),
                                   rtol=1e-5)


# --------------------------------------------------------------- compression
def test_int8_quantization_bounded_error():
    x = jax.random.normal(jax.random.PRNGKey(0), (10000,)) * 3.0
    q, s = quantize_int8(x)
    back = dequantize_int8(q, s)
    blockmax = jnp.max(jnp.abs(x))
    assert float(jnp.max(jnp.abs(back - x))) <= float(blockmax) / 127.0 + 1e-6


def test_error_feedback_unbiased_over_time():
    """EF compensates quantization: the accumulated transmitted signal
    tracks the accumulated true gradient."""
    rng = np.random.default_rng(0)
    ef = ErrorFeedback((512,))
    total_true = np.zeros(512)
    total_sent = np.zeros(512)
    for _ in range(50):
        g = jnp.asarray(rng.standard_normal(512).astype(np.float32))
        total_true += np.asarray(g)
        total_sent += np.asarray(ef.step(g, "int8"))
    # Residual is bounded by one round's worth of quantization error.
    err = np.abs(total_sent - total_true).max()
    assert err < 0.2, err
