"""Fault tolerance (PR 7): deterministic fault injection, snapshot-based
rollback recovery, per-lane quarantine, and shard-loss recovery.

Parity notes.  All recovered-vs-oracle comparisons run EAGER at
``max_staleness=0``: rollback replays the identical (piece, count)
sequence through the identical appliers, so a recovered trajectory must
match a fault-free twin bit for bit -- any divergence is a recovery bug,
not rounding.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ParameterService
from repro.ps.autoscaler import AutoscalerConfig, ElasticScaler
from repro.ps.faults import (
    HEALTHY,
    QUARANTINED,
    EngineQuarantinedError,
    FaultInjector,
    InjectedFault,
)
from repro.ps.service_runtime import (
    RecoveryReport,
    ServiceRuntime,
    ShardedServiceRuntime,
)


def _tree(key, sizes):
    ks = jax.random.split(key, len(sizes))
    return {f"t{i}": jax.random.normal(k, (n,))
            for i, (k, n) in enumerate(zip(ks, sizes))}


def _loss(params, batch):
    return sum(jnp.sum((params[k] - batch["target"][k]) ** 2)
               for k in params)


TREES = {
    "a": _tree(jax.random.PRNGKey(0), (48, 16, 32)),
    "b": _tree(jax.random.PRNGKey(1), (32, 16)),
    "c": _tree(jax.random.PRNGKey(2), (48, 16)),
}
TARGETS = {j: jax.tree_util.tree_map(lambda p: p * 0 + 1.0, t)
           for j, t in TREES.items()}


def _add_jobs(rt, trees=TREES):
    for jid, t in trees.items():
        nbytes = sum(4 * v.size for v in t.values())
        rt.add_job(jid, t, _loss, lr=0.05, required_servers=1,
                   agg_throughput=nbytes / 0.2)


def _flat(trees=TREES, **engine_opts):
    rt = ServiceRuntime(
        ParameterService(total_budget=16, n_clusters=1, plan_pad_to=16),
        jit=False)
    engine_opts.setdefault("max_staleness", 0)
    eng = rt.attach_engine(jit=False, **engine_opts)
    _add_jobs(rt, trees)
    return rt, eng


def _sharded(n_shards=3, trees=TREES, **engine_opts):
    svc = ParameterService(total_budget=16, n_clusters=1, plan_pad_to=16)
    rt = ShardedServiceRuntime(svc, jit=False)
    engine_opts.setdefault("max_staleness", 0)
    eng = rt.attach_engine(jit=False, **engine_opts)
    _add_jobs(rt, trees)
    if n_shards > 1:
        svc.scale_out(n_shards - 1)
    return rt, eng


def _drive(eng, n, trees=TREES):
    for _ in range(n):
        for j in trees:
            eng.step(j, {"target": TARGETS[j]})
    eng.drain()


def _assert_params_equal(rt_a, rt_b, jobs=TREES):
    for j in jobs:
        pa, pb = rt_a.params_of(j), rt_b.params_of(j)
        for k in pa:
            np.testing.assert_array_equal(np.asarray(pa[k]),
                                          np.asarray(pb[k]))


# --------------------------------------------------------------- injector
def test_injector_schedule_is_deterministic():
    def fire_points(inj):
        hits = []
        for i in range(1, 25):
            try:
                inj.on_apply("s0")
            except InjectedFault:
                hits.append(i)
        return hits

    a = FaultInjector(seed=3).random_apply_faults(4, ["s0"])
    b = FaultInjector(seed=3).random_apply_faults(4, ["s0"])
    assert [(r.kind, r.shard_id, r.at) for r in a.rules] == \
        [(r.kind, r.shard_id, r.at) for r in b.rules]
    assert fire_points(a) == fire_points(b)
    assert a.n_fired == len(a.log) > 0


def test_injector_rules_match_shard_and_occurrence():
    inj = FaultInjector()
    inj.fail_apply("s1", at=2)
    inj.on_apply("s1")  # occurrence 1: armed at 2, no fire
    inj.on_apply("s0")  # different lane: not even counted
    with pytest.raises(InjectedFault) as ei:
        inj.on_apply("s1")
    assert ei.value.kind == "fail_apply"
    assert ei.value.shard_id == "s1"
    assert ei.value.occurrence == 2
    inj.on_apply("s1")  # times=1: spent
    # kill = permanent
    inj.kill_shard("s0", at=1)
    for _ in range(3):
        with pytest.raises(InjectedFault):
            inj.on_apply("s0")
    # push rules return an action instead of raising
    inj.drop_push(job_id="a", at=1)
    assert inj.on_push("b") == "deliver"
    assert inj.on_push("a") == "drop"
    assert inj.on_push("a") == "deliver"
    inj.duplicate_push(job_id="a", at=1)
    assert inj.on_push("a") == "duplicate"


# ------------------------------------------------------ flat engine faults
def test_flat_transient_fault_recovers_bit_exact():
    inj = FaultInjector()
    inj.fail_apply(at=4).fail_apply(at=9)
    rt, eng = _flat(snapshot_interval=4, fault_injector=inj)
    twin, teng = _flat(snapshot_interval=4)
    _drive(eng, 8)
    _drive(teng, 8)
    assert inj.n_fired == 2
    assert eng.stats.n_rollbacks >= 2
    assert eng.stats.n_replayed >= 2
    assert eng.stats.n_quarantines == 0
    assert eng.health == HEALTHY
    _assert_params_equal(rt, twin)


def test_flat_persistent_fault_quarantines_with_context():
    inj = FaultInjector()
    inj.kill_shard(None, at=3)  # the flat engine's single unnamed lane
    rt, eng = _flat(snapshot_interval=4, max_apply_retries=1,
                    fault_injector=inj)
    with pytest.raises(EngineQuarantinedError) as ei:
        _drive(eng, 6)
    err = ei.value
    assert eng.health == QUARANTINED
    assert err.shard_id is None
    assert err.tick >= 0
    assert set(err.job_ids) <= set(TREES)
    assert isinstance(err.original, InjectedFault)
    # Every subsequent tick/drain re-raises the SAME carried context.
    with pytest.raises(EngineQuarantinedError) as again:
        eng.tick()
    assert again.value is err
    with pytest.raises(EngineQuarantinedError):
        eng.drain()


def test_flat_eager_without_snapshots_reraises_original():
    inj = FaultInjector()
    inj.fail_apply(at=1)
    rt, eng = _flat(snapshot_interval=0, fault_injector=inj)
    # No snapshot to roll back to, eager buffers intact: the original
    # fault propagates (pre-PR-7 behavior minus the poisoning).
    with pytest.raises(InjectedFault):
        _drive(eng, 2)


# -------------------------------------------------- sharded engine faults
def test_sharded_transient_fault_fleet_falls_back_bit_exact():
    inj = FaultInjector()
    rt, eng = _sharded(fault_injector=inj, snapshot_interval=4)
    twin, teng = _sharded(snapshot_interval=4)
    victim = rt.shard_ids[-1]
    inj.fail_apply(victim, at=2)
    _drive(eng, 8)
    _drive(teng, 8)
    assert inj.n_fired == 1
    # The fused fleet launch cannot attribute the failure: it rolls every
    # participant back and replays per shard.
    assert eng.stats.n_fleet_fallbacks >= 1
    assert eng.stats.n_rollbacks >= 1
    assert eng.stats.n_quarantines == 0
    assert set(eng.shard_health().values()) == {HEALTHY}
    _assert_params_equal(rt, twin)


def test_quarantine_isolates_one_lane_neighbors_tick_on():
    inj = FaultInjector()
    rt, eng = _sharded(fault_injector=inj)
    victim = rt.shard_ids[-1]
    inj.kill_shard(victim, at=2)
    with pytest.raises(EngineQuarantinedError) as ei:
        _drive(eng, 12)
    assert ei.value.shard_id == victim
    assert eng.shard_health()[victim] == QUARANTINED
    assert eng.quarantined_shards() == (victim,)
    # Jobs with no blocks on the dead shard keep training.
    untouched = [j for j in TREES
                 if victim not in rt.splan.job_layout(j).shard_ids]
    assert untouched, "placement left no job off the victim shard"
    before = eng.stats.n_applied
    for _ in range(4):
        for j in untouched:
            eng.step(j, {"target": TARGETS[j]})
    assert eng.stats.n_applied > before
    for sid, health in eng.shard_health().items():
        if sid != victim:
            assert health == HEALTHY
    # Engine-wide drain is blocked on the dead lane's queued pieces and
    # says WHICH lane, but a drain scoped to untouched jobs succeeds.
    with pytest.raises(EngineQuarantinedError) as de:
        eng.drain()
    assert de.value.shard_id == victim
    eng.drain(only=untouched)


def test_chaos_seeded_schedules_recover_bit_exact():
    # Property-style: seeded random transient schedules over the job mix
    # must always recover to the fault-free trajectory at s=0.
    for seed in range(4):
        inj = FaultInjector(seed=seed)
        rt, eng = _sharded(fault_injector=inj, snapshot_interval=4,
                           max_apply_retries=3)
        twin, teng = _sharded(snapshot_interval=4)
        inj.random_apply_faults(3, rt.shard_ids, max_at=15)
        _drive(eng, 10)
        _drive(teng, 10)
        assert eng.stats.n_quarantines == 0, f"seed {seed} quarantined"
        _assert_params_equal(rt, twin)
        if inj.n_fired:
            assert eng.stats.n_rollbacks >= 1


# ----------------------------------------------------- push-piece faults
def test_dropped_piece_times_out_push_future():
    inj = FaultInjector()
    rt, eng = _sharded(fault_injector=inj, max_staleness=8)
    job = "a"
    inj.drop_push(job_id=job, at=1)
    grads = jax.tree_util.tree_map(jnp.ones_like, TREES[job])
    fut = eng.submit_push(job, grads)
    t0 = time.monotonic()
    with pytest.raises(TimeoutError):
        fut.result(timeout=0.2)
    assert time.monotonic() - t0 < 5.0
    assert not fut.done()


def test_duplicate_piece_applies_untracked():
    inj = FaultInjector()
    rt, eng = _sharded(fault_injector=inj, max_staleness=8)
    job = "a"
    inj.duplicate_push(job_id=job, at=1)
    grads = jax.tree_util.tree_map(jnp.ones_like, TREES[job])
    fut = eng.submit_push(job, grads)
    step = fut.result()
    assert step == 1
    applied_before = eng.stats.n_applied
    eng.drain()  # the duplicate is an extra untracked piece
    assert eng.stats.n_applied >= applied_before
    assert not any(q for lane in eng._lanes.values()
                   for q in lane.queues.values())


# -------------------------------------------------- shard-loss recovery
def test_recover_shard_rehosts_and_training_continues():
    inj = FaultInjector()
    rt, eng = _sharded(fault_injector=inj, snapshot_interval=4)
    victim = rt.shard_ids[-1]
    inj.kill_shard(victim, at=2)
    with pytest.raises(EngineQuarantinedError):
        _drive(eng, 10)
    n_before = rt.n_shards
    report = rt.recover_shard(victim)
    assert isinstance(report, RecoveryReport)
    assert report.shard_id == victim
    assert report.seeded_from == "snapshot"
    assert report.moved_tasks >= 1
    assert report.rehosted_elements > 0
    assert rt.n_shards == n_before - 1
    assert victim not in rt.shard_ids
    assert victim not in eng._lanes
    # The rollback window is bounded: at most snapshot_interval ticks of
    # pushes were discarded or cancelled with the lane.
    assert (report.rolled_back_pushes + report.cancelled_pushes
            <= 4 * len(TREES) + len(TREES))
    # The fleet is whole again: every job trains and drains.
    _drive(eng, 3)
    assert set(eng.shard_health().values()) == {HEALTHY}


def test_recover_healthy_shard_is_a_lossless_decommission():
    rt, eng = _sharded()
    _drive(eng, 4)
    params_before = {j: rt.params_of(j) for j in TREES}
    victim = rt.shard_ids[-1]
    report = rt.recover_shard(victim)
    assert report.seeded_from == "live"
    assert report.rolled_back_pushes == 0
    assert report.cancelled_pushes == 0
    for j in TREES:
        after = rt.params_of(j)
        for k in after:
            np.testing.assert_array_equal(np.asarray(after[k]),
                                          np.asarray(params_before[j][k]))
    _drive(eng, 2)


def test_recover_shard_unknown_id_raises():
    rt, _ = _sharded()
    with pytest.raises(ValueError, match="unknown shard"):
        rt.recover_shard("nope/agg9")


# --------------------------------------------------- scaler + migration
def test_autoscaler_holds_on_quarantined_fleet():
    inj = FaultInjector()
    rt, eng = _sharded(fault_injector=inj)
    victim = rt.shard_ids[-1]
    scaler = ElasticScaler(rt, AutoscalerConfig(
        shard_capacity=1.0, max_shards=8, cooldown=1))
    inj.kill_shard(victim, at=1)
    with pytest.raises(EngineQuarantinedError):
        _drive(eng, 8)
    n_before = rt.n_shards
    decision = scaler.observe()  # load >> capacity, would grow
    assert decision.quarantined == (victim,)
    assert decision.action == "hold"
    assert rt.n_shards == n_before
    # Recovered fleet scales again.
    rt.recover_shard(victim)
    _drive(eng, 4)
    decision = scaler.observe()
    assert decision.quarantined == ()
    assert decision.action == "grow"


def test_migration_fault_hook_fires_on_replan():
    """A migration fault during a replan no longer escapes: the replan
    transaction (PR 9) rolls the registry back and retries, so the
    scale-out SUCCEEDS and both planes agree on the new layout."""
    inj = FaultInjector()
    rt, eng = _sharded(n_shards=2, fault_injector=inj)
    inj.fail_migration(at=1)
    assert rt.service.scale_out(1) == 1
    assert inj.n_fired == 1
    assert inj.log[0]["kind"] == "fail_migration"
    assert rt.service.n_replan_aborts == 1
    assert rt.service.n_replan_retries == 1
    assert rt.service.compile_sharded_plan() == rt.splan
    assert rt.n_shards == 3


def test_checkpoint_records_shard_health(tmp_path):
    from repro.checkpoint.checkpoint import load_aux

    rt, eng = _sharded(n_shards=2)
    _drive(eng, 2)
    rt.save_checkpoint(tmp_path, step=1)
    aux = load_aux(tmp_path, 1)
    assert aux["shard_health"] == {sid: HEALTHY for sid in rt.shard_ids}


# --------------------------------------------- compressed-push faults (PR 8)
def _sharded_mixed(n_shards=3, compressed=("a",), **engine_opts):
    """Sharded fleet with a MIX of compressed and plain jobs."""
    svc = ParameterService(total_budget=16, n_clusters=1, plan_pad_to=16)
    rt = ShardedServiceRuntime(svc, jit=False)
    engine_opts.setdefault("max_staleness", 0)
    eng = rt.attach_engine(jit=False, **engine_opts)
    for jid, t in TREES.items():
        nbytes = sum(4 * v.size for v in t.values())
        rt.add_job(jid, t, _loss, lr=0.05, required_servers=1,
                   agg_throughput=nbytes / 0.2,
                   **({"push_compression": "int8"}
                      if jid in compressed else {}))
    if n_shards > 1:
        svc.scale_out(n_shards - 1)
    return rt, eng


def test_rollback_restores_ef_buffer_bit_exact():
    """The error-feedback buffer lives in the lane's donated state, so a
    snapshot rollback restores it with flat/mu/nu: a compressed job
    recovered via replay matches a fault-free compressed twin at s=0 --
    params AND the residual itself, bit for bit."""
    inj = FaultInjector(seed=5)
    rt, eng = _sharded_mixed(fault_injector=inj, snapshot_interval=4)
    twin, teng = _sharded_mixed(snapshot_interval=4)
    victim = rt.splan.job_layout("a").shard_ids[0]  # hosts the EF rows
    inj.fail_apply(victim, at=3).fail_apply(victim, at=8)

    _drive(eng, 12)
    _drive(teng, 12)

    assert inj.n_fired >= 1
    assert eng.stats.n_rollbacks >= 1
    assert eng.stats.n_quarantines == 0
    _assert_params_equal(rt, twin)
    for sid in rt.states:
        st, tw = rt.states[sid], twin.states[sid]
        assert ("ef" in st) == ("ef" in tw)
        if "ef" in st:
            np.testing.assert_array_equal(np.asarray(st["ef"]),
                                          np.asarray(tw["ef"]))


# ------------------------------------------- versioned pulls under faults
def test_versioned_pull_after_rollback_restamp_patches_to_full():
    """A rollback replay re-stamps every replayed block (PR 8), so a
    client vector held from BEFORE the fault sees exactly the replayed
    blocks in its next diff -- never a silently-skipped stale block:
    patching the held payload must land on a fresh full pull bit for
    bit, and a job that never stepped stays an empty diff."""
    inj = FaultInjector()
    rt, eng = _flat(snapshot_interval=2, fault_injector=inj)
    for _ in range(3):  # only a and b move; c's blocks never stamp
        for j in ("a", "b"):
            eng.step(j, {"target": TARGETS[j]})
    eng.drain()
    held = {j: eng.pull(j, since_version=0) for j in TREES}
    inj.fail_apply(at=1)  # rules count from arming: the NEXT apply dies
    for j in ("a", "b"):
        eng.step(j, {"target": TARGETS[j]})
    eng.drain()
    assert inj.n_fired == 1
    assert eng.stats.n_rollbacks >= 1
    da = eng.pull("a", since_version=held["a"].version)
    assert not da.full and da.block_ids.size > 0
    for j in ("a", "b"):
        d = (da if j == "a"
             else eng.pull(j, since_version=held[j].version))
        fresh = eng.pull(j, since_version=0)
        np.testing.assert_array_equal(
            np.asarray(d.apply(held[j].data)), np.asarray(fresh.data))
    dc = eng.pull("c", since_version=held["c"].version)
    assert not dc.full and dc.block_ids.size == 0


def test_versioned_pull_against_quarantined_lane_raises():
    """Direct versioned pulls die with the hosting lane (the read tier's
    replicas are the degraded-serving path); jobs off the dead shard
    keep serving diffs."""
    inj = FaultInjector()
    rt, eng = _sharded(fault_injector=inj)
    victim = rt.shard_ids[-1]
    inj.kill_shard(victim, at=2)
    with pytest.raises(EngineQuarantinedError):
        _drive(eng, 12)
    hosted = [j for j in TREES
              if victim in rt.splan.job_layout(j).shard_ids]
    spared = [j for j in TREES
              if victim not in rt.splan.job_layout(j).shard_ids]
    assert hosted and spared, "placement left nothing to compare"
    with pytest.raises(EngineQuarantinedError) as ei:
        eng.pull(hosted[0], since_version=0)
    assert ei.value.shard_id == victim
    with pytest.raises(EngineQuarantinedError):
        eng.pull(hosted[0])  # the plain tree pull dies the same way
    d = eng.pull(spared[0], since_version=0)
    assert d.full and d.bytes_full > 0


def test_chaos_mixed_compression_stays_quarantine_free():
    """Seeded chaos over a mixed compressed/plain job fleet: transient
    schedules must recover in place (no lane quarantined) and land on
    the fault-free mixed twin bit for bit."""
    for seed in (1, 3):
        inj = FaultInjector(seed=seed)
        rt, eng = _sharded_mixed(fault_injector=inj, snapshot_interval=4,
                                 max_apply_retries=3)
        twin, teng = _sharded_mixed(snapshot_interval=4)
        inj.random_apply_faults(3, rt.shard_ids, max_at=15)
        _drive(eng, 10)
        _drive(teng, 10)
        assert eng.stats.n_quarantines == 0, f"seed {seed} quarantined"
        assert all(lane.health == HEALTHY
                   for lane in eng._lanes.values())
        _assert_params_equal(rt, twin)
        if inj.n_fired:
            assert eng.stats.n_rollbacks >= 1
