"""Trace simulator tests: determinism, invariants, paper-band results."""

import numpy as np
import pytest

from repro.sim import ClusterSimulator, SimConfig, philly_like_trace


def _run(n_jobs=120, seed=3, **cfg):
    trace = philly_like_trace(n_jobs=n_jobs, seed=seed)
    sim = ClusterSimulator(SimConfig(n_clusters=2, **cfg))
    return sim.run(trace)


def test_simulator_deterministic():
    a, b = _run(), _run()
    assert a.allocated == b.allocated
    assert a.cpu_time_saving == b.cpu_time_saving


def test_all_jobs_complete():
    res = _run()
    assert res.n_jobs_done == 120


def test_loss_limit_respected():
    res = _run()
    assert res.max_loss_seen <= 0.1 + 1e-9


def test_saves_cpu_time_at_scale():
    """The headline Fig.-11 property: packing saves a large fraction of the
    CPU-time ps-lite would reserve (paper: 52.7%). Uses the benchmark
    configuration (4 clusters, seed 1: high concurrency -- low-concurrency
    valleys at small n_clusters inflate the allocated/required ratio)."""
    trace = philly_like_trace(n_jobs=400, seed=1)
    res = ClusterSimulator(SimConfig(n_clusters=4)).run(trace)
    assert res.cpu_time_saving > 0.40, res.cpu_time_saving
    r = np.array(res.ratio_series())
    assert (r < 1).mean() > 0.95  # paper: >99% of samples under 1


def test_periodic_scaling_can_overshoot():
    """Idle Aggregators held until the scaling tick occasionally push the
    allocated/required ratio over 1 (the paper's >1 spikes)."""
    res = _run(n_jobs=250, scaling_period=3600.0)
    assert max(res.ratio_series()) > 1.0


def test_allocated_never_negative_and_bounded():
    res = _run()
    assert all(a >= 0 for a in res.allocated)
    assert all(a <= SimConfig().total_budget for a in res.allocated)


def test_simulator_config_not_shared():
    """Regression: `cfg` must not default to a single shared SimConfig."""
    a, b = ClusterSimulator(), ClusterSimulator()
    assert a.cfg is not b.cfg
    a.cfg.total_budget = 1
    assert b.cfg.total_budget != 1


def test_simulator_tracks_compiled_plans():
    """track_plans=True accounts migration bytes + padding waste from the
    plans the service actually compiled."""
    trace = philly_like_trace(n_jobs=40, seed=3)
    res = ClusterSimulator(
        SimConfig(n_clusters=2, track_plans=True)).run(trace)
    assert res.n_replans > 0
    assert res.migration_bytes_total >= 0
    assert res.padding_waste and all(0.0 <= w < 1.0 for w in res.padding_waste)


def test_simulator_tracks_delta_migration_and_touched_stalls():
    """track_plans=True also accounts the delta-migration view: bytes the
    run-copy path actually moves, and replan stalls charged to the
    TOUCHED resident jobs only (the stall-free fraction is what the
    hard-quiesce engine could never report: it always stalled everyone)."""
    trace = philly_like_trace(n_jobs=40, seed=3)
    res = ClusterSimulator(
        SimConfig(n_clusters=2, track_plans=True)).run(trace)
    assert res.relayout_bytes_total >= 0
    assert 0 <= res.replan_stalled_jobs <= res.replan_coresident_jobs
    assert res.replan_coresident_jobs > 0
    assert 0.0 <= res.replan_stall_free_fraction <= 1.0
    # Without plan tracking the delta accounting stays silent.
    res_off = ClusterSimulator(SimConfig(n_clusters=2)).run(trace)
    assert res_off.relayout_bytes_total == 0
    assert res_off.replan_coresident_jobs == 0
    assert res_off.replan_stall_free_fraction == 1.0
