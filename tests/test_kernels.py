"""Pallas kernel tests: shape/dtype sweeps vs the pure-jnp oracles
(interpret mode on CPU; TPU is the lowering target)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # fallback shim; see requirements-dev.txt
    from _hypothesis_shim import given, settings, strategies as st

from repro.kernels.agg_adam import ops as agg_ops, ref as agg_ref
from repro.kernels.embed_bag import ops as eb_ops, ref as eb_ref
from repro.kernels.flash_attn import ops as fa_ops, ref as fa_ref


# ------------------------------------------------------------------ agg_adam
@pytest.mark.parametrize("shape", [(128,), (1000, 33), (7, 11, 13)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("workers", [0, 1, 4])
def test_agg_adam_matches_ref(shape, dtype, workers):
    key = jax.random.PRNGKey(0)
    p = jax.random.normal(key, shape).astype(dtype)
    gshape = (workers,) + shape if workers else shape
    g = jax.random.normal(jax.random.PRNGKey(1), gshape).astype(dtype)
    mu = jnp.zeros(shape, jnp.float32)
    nu = jnp.zeros(shape, jnp.float32)
    cnt = jnp.array(5, jnp.int32)
    kw = dict(lr=1e-3, b1=0.9, b2=0.999, eps=1e-8, wd=0.01)
    out_k = agg_ops.aggregate_adam(p, g, mu, nu, cnt, **kw)
    out_r = agg_ref.aggregate_adam_ref(p, g, mu, nu, cnt, **kw)
    for a, b in zip(out_k, out_r):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=2e-5, atol=2e-6)


@settings(deadline=None, max_examples=20)
@given(
    n=st.integers(1, 5000),
    steps=st.integers(1, 3),
)
def test_agg_adam_chain_property(n, steps):
    """Property: chaining kernel steps == chaining reference steps (state
    threading is consistent), for arbitrary (non-aligned) sizes."""
    key = jax.random.PRNGKey(n)
    p_k = p_r = jax.random.normal(key, (n,))
    mu_k = mu_r = jnp.zeros((n,))
    nu_k = nu_r = jnp.zeros((n,))
    for t in range(1, steps + 1):
        g = jax.random.normal(jax.random.PRNGKey(t), (n,))
        cnt = jnp.array(t, jnp.int32)
        p_k, mu_k, nu_k = agg_ops.aggregate_adam(p_k, g, mu_k, nu_k, cnt, lr=1e-2)
        p_r, mu_r, nu_r = agg_ref.aggregate_adam_ref(p_r, g, mu_r, nu_r, cnt, lr=1e-2)
    np.testing.assert_allclose(np.asarray(p_k), np.asarray(p_r), rtol=1e-5, atol=1e-6)


def test_agg_adam_equals_unfused_optimizer():
    """fused=True in repro.optim.adam routes through the kernel and matches
    the unfused reference path."""
    from repro.optim import adam

    params = {"a": jnp.ones((300,)), "b": {"c": jnp.full((4, 40), 2.0)}}
    grads = jax.tree_util.tree_map(lambda x: 0.1 * x, params)
    o1, o2 = adam(1e-2), adam(1e-2, fused=True)
    s1, s2 = o1.init(params), o2.init(params)
    p1, _ = o1.step(params, grads, s1)
    p2, _ = o2.step(params, grads, s2)
    for a, b in zip(jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


# ---------------------------------------------------------------- flash_attn
@pytest.mark.parametrize("seq,heads,kv_heads,d", [
    (128, 4, 4, 64),
    (256, 4, 2, 64),   # GQA
    (256, 2, 2, 128),
    (384, 2, 1, 64),   # MQA
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(seq, heads, kv_heads, d, causal):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (2, seq, heads, d), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (2, seq, kv_heads, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, seq, kv_heads, d))
    out = fa_ops.flash_attention(q, k, v, causal=causal)
    rep = heads // kv_heads
    kr = jnp.repeat(k, rep, axis=2).transpose(0, 2, 1, 3)
    vr = jnp.repeat(v, rep, axis=2).transpose(0, 2, 1, 3)
    ref = fa_ref.flash_attention_ref(
        q.transpose(0, 2, 1, 3), kr, vr, causal=causal
    ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16():
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 256, 2, 64)).astype(jnp.bfloat16)
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 256, 2, 64)).astype(jnp.bfloat16)
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 256, 2, 64)).astype(jnp.bfloat16)
    out = fa_ops.flash_attention(q, k, v, causal=True)
    ref = fa_ref.flash_attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=True).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=2e-2, atol=2e-2)


def test_flash_attention_matches_model_chunked_attention():
    """Cross-validation: the Pallas kernel and the model's jnp
    chunked_attention (the dry-run path) agree."""
    from repro.models.attention import chunked_attention

    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (2, 256, 4, 64))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 256, 2, 64))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 256, 2, 64))
    out_kernel = fa_ops.flash_attention(q, k, v, causal=True)
    out_jnp = chunked_attention(q, k, v, causal=True, chunk_k=64)
    np.testing.assert_allclose(np.asarray(out_kernel), np.asarray(out_jnp),
                               rtol=2e-5, atol=2e-5)


@settings(deadline=None, max_examples=10)
@given(
    sq=st.sampled_from([64, 128, 192, 320]),
    d=st.sampled_from([64, 128]),
)
def test_flash_attention_shape_sweep(sq, d):
    key = jax.random.PRNGKey(sq + d)
    q = jax.random.normal(key, (1, sq, 2, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, sq, 2, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, sq, 2, d))
    out = fa_ops.flash_attention(q, k, v, causal=True)
    ref = fa_ref.flash_attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=True).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# ----------------------------------------------------------------- embed_bag
@pytest.mark.parametrize("vocab,dim,bags,bag_len", [
    (512, 32, 16, 5),
    (1024, 128, 8, 1),
    (128, 64, 32, 20),
])
def test_embed_bag_matches_ref(vocab, dim, bags, bag_len):
    key = jax.random.PRNGKey(0)
    table = jax.random.normal(key, (vocab, dim))
    idx = jax.random.randint(jax.random.PRNGKey(1), (bags, bag_len), 0, vocab)
    out = eb_ops.embedding_bag(table, idx)
    ref = eb_ref.embedding_bag_ref(table, idx)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)


def test_embed_bag_matches_system_embedding_bag():
    """Cross-validation vs the system EmbeddingBag (take + segment_sum)."""
    from repro.models.recsys import embedding_bag as sys_bag

    key = jax.random.PRNGKey(0)
    table = jax.random.normal(key, (256, 16))
    idx = jax.random.randint(jax.random.PRNGKey(1), (8, 4), 0, 256)
    # rtol covers f32 accumulation-order differences (take+segment_sum vs
    # the kernel's in-bag loop), which exceed 1e-6 on some backends.
    np.testing.assert_allclose(
        np.asarray(eb_ops.embedding_bag(table, idx)),
        np.asarray(sys_bag(table, idx)), rtol=1e-4, atol=1e-6)
