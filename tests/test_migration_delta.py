"""Delta-migration tests: compiled MigrationDelta == full-gather oracle
bit-exactly across randomized plan pairs (arrival / exit / rebalance /
no-op), run-copy kernel vs numpy ref, bounded plan-pair cache."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - CI installs requirements-dev
    from _hypothesis_shim import given, settings, strategies as st

from repro.core import ParameterService
from repro.kernels.relayout import kernel as rl_kernel
from repro.kernels.relayout import ops as rl_ops
from repro.kernels.relayout import ref as rl_ref
from repro.ps import elastic
from repro.ps.elastic import (
    clear_plan_cache,
    compile_migration_delta,
    migrate_flat_state,
    migrate_flat_state_delta,
    plan_cache_stats,
    set_plan_cache_limit,
)
from repro.ps.plan import segment_mask
from repro.ps.runtime import (
    init_shared_state,
    job_profile_from_tree,
    seed_job_params,
)


def _tree(seed, sizes):
    ks = jax.random.split(jax.random.PRNGKey(seed), len(sizes))
    return {f"t{i}": jax.random.normal(k, (n,))
            for i, (k, n) in enumerate(zip(ks, sizes))}


def _register(svc, jid, tree, required=2, busy=0.45):
    nbytes = sum(4 * v.size for v in tree.values())
    profile, specs = job_profile_from_tree(
        jid, tree, required_servers=required, agg_throughput=nbytes / busy)
    svc.register_job(profile, specs=specs)


def _valid_state(plan, rng):
    """A VALID shared state: random values on payload lanes, zero
    elsewhere (the invariant every runtime state satisfies)."""
    mask = np.asarray(segment_mask(plan))
    state = init_shared_state(plan)
    for name in ("flat", "mu", "nu"):
        vals = rng.standard_normal(plan.total_len).astype(np.float32)
        state[name] = jnp.asarray(np.where(mask, vals, 0.0))
    return state


def _assert_delta_matches_gather(state, old, new):
    oracle = migrate_flat_state(state, old, new)
    copy = {k: (v.copy() if hasattr(v, "copy") else v)
            for k, v in state.items()}
    got = migrate_flat_state_delta(copy, old, new)
    for name in ("flat", "mu", "nu"):
        np.testing.assert_array_equal(np.asarray(oracle[name]),
                                      np.asarray(got[name]))
    return compile_migration_delta(old, new)


# ------------------------------------------------------------ property test
@settings(deadline=None, max_examples=20)
@given(
    seed=st.integers(min_value=0, max_value=10_000),
    op=st.sampled_from(["arrival", "exit", "rebalance", "noop"]),
    n_jobs=st.integers(min_value=1, max_value=3),
    pad=st.sampled_from([8, 16]),
)
def test_delta_bit_exact_vs_full_gather_randomized(seed, op, n_jobs, pad):
    """Tentpole acceptance: for a randomized live-service plan pair --
    a job arriving, exiting, a periodic rebalance, or a no-op recompile
    -- the delta path reproduces the full-gather migration bit-exactly
    on a valid state."""
    rng = np.random.default_rng(seed)
    svc = ParameterService(total_budget=16, n_clusters=1, plan_pad_to=pad)
    jobs = {}
    for i in range(n_jobs):
        sizes = tuple(int(rng.integers(5, 90))
                      for _ in range(int(rng.integers(1, 4))))
        jobs[f"j{i}"] = _tree(seed + i, sizes)
        _register(svc, f"j{i}", jobs[f"j{i}"],
                  required=int(rng.integers(1, 3)))
    old = svc.compile_plan()
    state = _valid_state(old, rng)

    if op == "arrival":
        probe_sizes = tuple(int(rng.integers(4, 60))
                            for _ in range(int(rng.integers(1, 3))))
        _register(svc, "probe", _tree(seed + 99, probe_sizes), required=1)
    elif op == "exit" and n_jobs > 1:
        svc.job_exit(f"j{int(rng.integers(0, n_jobs))}")
    elif op == "rebalance":
        svc.periodic_rebalance()
    new = svc.compile_plan()

    delta = _assert_delta_matches_gather(state, old, new)
    # Accounting self-consistency: the run list carries exactly the
    # moved-lane count the delta reports, and the simulator's O(segments)
    # summary agrees with the lane-exact compile.
    assert delta.moved_elements == sum(n for _, _, n in delta.moves)
    assert delta.zeroed_elements == sum(n for _, n in delta.zeros)
    moved, touched = elastic.plan_transition_summary(old, new)
    assert moved == delta.moved_elements
    assert touched == delta.touched_jobs
    if new == old:
        assert delta.identity


def test_delta_equal_plans_is_identity_and_untouched():
    svc = ParameterService(total_budget=16, n_clusters=1, plan_pad_to=8)
    _register(svc, "a", _tree(0, (40, 17)))
    plan = svc.compile_plan()
    delta = compile_migration_delta(plan, plan)
    assert delta.identity and not delta.touched_jobs
    state = _valid_state(plan, np.random.default_rng(0))
    assert migrate_flat_state_delta(state, plan, plan) is state


def test_delta_arrival_touches_only_the_arriving_job():
    """A small arrival that fits existing padding leaves every resident
    job's layout -- and bytes -- untouched: the delta names only the
    arriver, moves nothing, and matches migration_bytes (= 0)."""
    svc = ParameterService(total_budget=16, n_clusters=1, plan_pad_to=16)
    trees = {"a": _tree(0, (48, 16, 32)), "b": _tree(1, (32, 16))}
    for jid, t in trees.items():
        _register(svc, jid, t)
    old = svc.compile_plan()
    _register(svc, "zz", _tree(7, (32,)), required=1, busy=0.6)
    new = svc.compile_plan()
    delta = compile_migration_delta(old, new)
    assert delta.touched_jobs == ("zz",)
    assert delta.moved_elements == 0 and not delta.moves
    assert delta.moved_bytes() == elastic.migration_bytes(old, new) == 0

    state = _valid_state(old, np.random.default_rng(3))
    _assert_delta_matches_gather(state, old, new)


def test_delta_runs_are_coalesced_and_disjoint():
    """Runs are maximal (constant shift, contiguous) and never overlap a
    zero run; the exit/consolidation scenario produces O(segments) runs,
    not O(lanes)."""
    svc = ParameterService(total_budget=16, n_clusters=1, plan_pad_to=8)
    for i, sizes in enumerate(((60, 30), (40, 20), (25,))):
        _register(svc, f"j{i}", _tree(i, sizes))
    old = svc.compile_plan()
    svc.job_exit("j0")
    new = svc.compile_plan()
    delta = compile_migration_delta(old, new)
    assert 0 < len(delta.moves) <= len(new.segments) + new.n_shards
    covered = np.zeros(delta.new_len, bool)
    for src, dst, n in delta.moves:
        assert 0 <= src and src + n <= delta.old_len
        assert not covered[dst: dst + n].any()
        covered[dst: dst + n] = True
    for dst, n in delta.zeros:
        assert not covered[dst: dst + n].any()
        covered[dst: dst + n] = True


# ------------------------------------------------------------- kernel paths
def _mini_delta():
    svc = ParameterService(total_budget=16, n_clusters=1, plan_pad_to=8)
    for i, sizes in enumerate(((60, 30), (40, 20), (25,))):
        _register(svc, f"j{i}", _tree(i, sizes))
    old = svc.compile_plan()
    svc.job_exit("j1")
    svc.periodic_rebalance()
    new = svc.compile_plan()
    delta = compile_migration_delta(old, new)
    assert delta.moves  # scenario must actually move something
    rng = np.random.default_rng(5)
    leaves = [jnp.asarray(np.where(np.asarray(segment_mask(old)),
                                   rng.standard_normal(old.total_len), 0.0)
                          .astype(np.float32)) for _ in range(3)]
    return delta, leaves


def test_relayout_kernel_interpret_matches_ref():
    """The one-launch Pallas scatter (interpret mode) reproduces the
    numpy oracle on all leaves at once, and leaves untouched blocks in
    place (aliased outputs)."""
    delta, leaves = _mini_delta()
    bases = [rl_ops._resize(x, delta.old_len, delta.new_len) for x in leaves]
    staged = [rl_ops._stage(x, delta) for x in leaves]
    outs = rl_kernel.relayout_scatter(
        bases, staged, jnp.asarray(delta.touched_blocks),
        block=delta.block, interpret=True)
    refs = rl_ref.relayout_ref(leaves, delta)
    assert len(outs) == len(refs) == 3
    for a, b in zip(outs, refs):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_relayout_staged_jnp_path_matches_runs_path(monkeypatch):
    """The many-runs staged gather/scatter program is bit-equal to the
    unrolled dynamic-slice program (and the ref)."""
    delta, leaves = _mini_delta()
    runs_out = rl_ops.relayout([x.copy() for x in leaves], delta,
                               interpret=True)
    monkeypatch.setattr(rl_ops, "RUNS_UNROLL_MAX", -1)  # force staged path
    staged_out = rl_ops.relayout([x.copy() for x in leaves], delta,
                                 interpret=True)
    refs = rl_ref.relayout_ref(leaves, delta)
    for a, b, c in zip(runs_out, staged_out, refs):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))


# ------------------------------------------------------------ bounded cache
def test_plan_cache_bounded_eviction_and_stats():
    """The per-pair cache evicts by size (a long-lived service can not
    leak one index array per replan) and exposes a stats hook."""
    clear_plan_cache()
    old_limit = plan_cache_stats()["max_bytes"]
    try:
        set_plan_cache_limit(64 << 10)
        before = plan_cache_stats()
        plans = []
        for order in (("a", "b"), ("b", "a")):
            svc = ParameterService(total_budget=16, n_clusters=1,
                                   plan_pad_to=8)
            trees = {"a": _tree(0, (700, 300)), "b": _tree(1, (500, 200))}
            for jid in order:
                _register(svc, jid, trees[jid])
            plans.append(svc.compile_plan())
        state = _valid_state(plans[0], np.random.default_rng(0))
        for _ in range(4):  # keep re-deriving pair structures both ways
            _assert_delta_matches_gather(state, plans[0], plans[1])
            _assert_delta_matches_gather(
                _valid_state(plans[1], np.random.default_rng(1)),
                plans[1], plans[0])
        stats = plan_cache_stats()
        assert stats["bytes"] <= stats["max_bytes"]
        assert stats["evictions"] > before["evictions"]
        assert stats["hits"] > before["hits"]
        assert stats["entries"] >= 1
    finally:
        set_plan_cache_limit(old_limit)


def test_plan_cache_hit_on_repeated_pair():
    clear_plan_cache()
    svc = ParameterService(total_budget=16, n_clusters=1, plan_pad_to=8)
    _register(svc, "a", _tree(0, (64, 32)))
    old = svc.compile_plan()
    _register(svc, "b", _tree(1, (48,)))
    new = svc.compile_plan()
    before = plan_cache_stats()
    compile_migration_delta(old, new)
    compile_migration_delta(old, new)
    after = plan_cache_stats()
    assert after["hits"] - before["hits"] >= 1


def test_delta_rejects_resized_segment():
    """A segment changing size between plans is a protocol violation the
    compile must refuse (same contract as the permutation oracle)."""
    from repro.ps.plan import FlatPlan, Segment

    seg = dict(key="t0", shard=0, offset=0, shape=(10,), dtype=np.float32,
               job_id="a", tensor_id=0)
    old = FlatPlan(1, 16, (Segment(size=10, **seg),))
    new = FlatPlan(1, 16, (Segment(size=12, **{**seg, "shape": (12,)}),))
    with pytest.raises(ValueError, match="changed size"):
        compile_migration_delta(old, new)
