"""PR-6 single-launch fused tick: the fused-scatter multi-job kernel,
its jnp fallback, and the fleet-wide one-launch tick.

Parity discipline (same as tests/test_sharded.py): all cross-PATH
comparisons (fused vs unfused+scatter, fused fleet vs per-shard oracle)
run EAGER -- per-element math is identical across paths, so results must
agree bit-for-bit; the kernel-vs-ref comparison tolerates the documented
reciprocal-vs-division rounding of the hp table.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ParameterService
from repro.kernels.agg_adam import kernel as agg_kernel
from repro.kernels.agg_adam import ops as agg_ops
from repro.kernels.agg_adam import ref as agg_ref
from repro.ps.service_runtime import ShardedServiceRuntime


def _tree(key, sizes):
    ks = jax.random.split(key, len(sizes))
    return {f"t{i}": jax.random.normal(k, (n,))
            for i, (k, n) in enumerate(zip(ks, sizes))}


def _loss(params, batch):
    return sum(jnp.sum((params[k] - batch["target"][k]) ** 2)
               for k in params)


# Uneven job sizes on purpose: shard spaces come out unevenly sized after
# a split, and one tensor (t0 of "c") packs into a SINGLE 16-element
# block -- the degenerate table entries the fused launch must handle.
TREES = {
    "a": _tree(jax.random.PRNGKey(0), (48, 16, 32)),
    "b": _tree(jax.random.PRNGKey(1), (32, 16)),
    "c": _tree(jax.random.PRNGKey(2), (16,)),
}
TARGETS = {j: jax.tree_util.tree_map(lambda p: p * 0 + 1.0, t)
           for j, t in TREES.items()}


def _service():
    return ParameterService(total_budget=16, n_clusters=1, plan_pad_to=16)


def _add_jobs(rt, trees=TREES, slack=0.2):
    for jid, t in trees.items():
        nbytes = sum(4 * v.size for v in t.values())
        rt.add_job(jid, t, _loss, lr=0.05, required_servers=1,
                   agg_throughput=nbytes / slack)


def _runtime(engine=None, jit=False, trees=TREES):
    rt = ShardedServiceRuntime(_service(), jit=jit)
    eng = rt.attach_engine(**engine) if engine is not None else None
    _add_jobs(rt, trees)
    return rt, eng


def _assert_params_equal(rt_a, rt_b, jobs=TREES):
    for j in jobs:
        pa, pb = rt_a.params_of(j), rt_b.params_of(j)
        for k in pa:
            np.testing.assert_array_equal(np.asarray(pa[k]),
                                          np.asarray(pb[k]))


# ----------------------------------------------------------- kernel level
@pytest.mark.parametrize("workers", [0, 3])
def test_fused_kernel_interpret_matches_sequential_ref(workers):
    """aggregate_adam_multijob_fused (interpret mode) == sequential
    per-job block updates scattered into the full buffers, including a
    single-block job, with every unowned block untouched bit-for-bit."""
    block, n_blocks = 8, 16
    n = block * n_blocks
    bi = [np.array([1, 2, 5], np.int32), np.array([9], np.int32),
          np.array([0, 3, 10], np.int32)]
    block_idx = np.concatenate(bi)
    sizes = tuple(b.size for b in bi)
    m = block_idx.size * block
    p = jax.random.normal(jax.random.PRNGKey(0), (n,))
    mu = jnp.abs(jax.random.normal(jax.random.PRNGKey(1), (n,))) * 0.1
    nu = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (n,))) * 0.01
    gshape = (workers, m) if workers else (m,)
    g = jax.random.normal(jax.random.PRNGKey(3), gshape)
    counts = [jnp.array(5, jnp.int32), jnp.array(1, jnp.int32),
              jnp.array(2, jnp.int32)]
    kw = dict(lr=(1e-2, 2e-2, 3e-3), b1=0.9, b2=0.999, eps=1e-8,
              wd=(0.01, 0.0, 0.0))
    hp = agg_ops.multi_job_hp(counts, **kw)
    job_slot = jnp.asarray(np.repeat(np.arange(3, dtype=np.int32), sizes))
    out_k = agg_kernel.aggregate_adam_multijob_fused(
        p, g, mu, nu, hp, jnp.asarray(block_idx), job_slot, block=block,
        interpret=True)
    out_r = agg_ref.aggregate_adam_multijob_fused_ref(
        p, g, mu, nu, counts, block_idx, sizes, block=block, **kw)
    owned = np.zeros(n, bool)
    owned[(block_idx[:, None] * block + np.arange(block)).reshape(-1)] = True
    for a, b, orig in zip(out_k, out_r, (p, mu, nu)):
        assert a.shape == (n,)  # FULL buffers come back, not packed
        np.testing.assert_allclose(np.asarray(a)[owned],
                                   np.asarray(b)[owned],
                                   rtol=2e-5, atol=2e-6)
        # The aliased in-place form must leave unowned lanes untouched.
        np.testing.assert_array_equal(np.asarray(a)[~owned],
                                      np.asarray(orig)[~owned])


def test_fused_kernel_rejects_packed_p():
    """The fused form writes into the FULL buffers: a packed p (the
    unfused entry point's shape) must be rejected, not misread."""
    block = 8
    n = block * 4
    block_idx = jnp.asarray(np.array([0, 2], np.int32))
    job_slot = jnp.zeros((2,), jnp.int32)
    hp = agg_ops.multi_job_hp([jnp.array(1, jnp.int32)], lr=0.1)
    full = jnp.zeros((n,))
    packed = jnp.zeros((2 * block,))
    with pytest.raises(AssertionError, match="full"):
        agg_kernel.aggregate_adam_multijob_fused(
            packed, packed, full, full, hp, block_idx, job_slot,
            block=block, interpret=True)


# -------------------------------------------------------------- ops level
def test_fused_ops_bit_exact_vs_unfused_plus_scatter():
    """multi_job_adam_update_fused (jnp fallback) == the PR-3 pipeline
    (packed multi_job_adam_update + caller-side row scatter), bit-exact:
    the fusion is a pure program-shape change."""
    block = 16
    bi = [np.array([1, 2, 5], np.int32), np.array([7], np.int32)]
    block_idx = np.concatenate(bi)
    sizes = tuple(b.size for b in bi)
    n = block * 12
    p = jax.random.normal(jax.random.PRNGKey(0), (n,))
    mu = jnp.abs(jax.random.normal(jax.random.PRNGKey(1), (n,))) * 0.1
    nu = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (n,))) * 0.01
    g = jax.random.normal(jax.random.PRNGKey(3), (block_idx.size * block,))
    gs = (g[:sizes[0] * block], g[sizes[0] * block:])
    counts = [jnp.array(4, jnp.int32), jnp.array(9, jnp.int32)]
    kw = dict(block_idx=block_idx, job_sizes=sizes, block=block,
              lr=(1e-2, 3e-3))
    fused = agg_ops.multi_job_adam_update_fused(p, gs, mu, nu, counts, **kw)
    packed = agg_ops.multi_job_adam_update(p, gs, mu, nu, counts, **kw)
    unfused = tuple(agg_ops.scatter_rows(buf, out, block_idx, block)
                    for buf, out in zip((p, mu, nu), packed))
    for a, b in zip(fused, unfused):
        assert a.shape == (n,)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ----------------------------------------------------------- fleet ticks
def _spread_fleet(rt):
    """Split until the fleet has >= 2 shards (skip if packing refuses)."""
    if rt.n_shards < 2:
        rt.service.scale_out(1)
    if rt.n_shards < 2:
        pytest.skip("control plane kept every job on one Aggregator")


def test_fleet_tick_is_one_launch_and_bit_exact_vs_per_shard_oracle():
    """Tentpole acceptance: with pending pushes spread over S shards, one
    fused fleet tick executes exactly ONE launch (TickStats.n_launches)
    and leaves every shard state bit-exact with the per-shard oracle
    loop -- through uneven shard sizes and a mid-trajectory split."""
    rt_f, eng_f = _runtime(engine=dict(max_staleness=0, jit=False))
    rt_o, eng_o = _runtime(engine=dict(max_staleness=0, jit=False,
                                       fleet_tick="per_shard"))
    assert eng_f.fleet_tick == "fused"

    def both(n):
        for _ in range(n):
            for j in TREES:
                eng_f.step(j, {"target": TARGETS[j]})
                eng_o.step(j, {"target": TARGETS[j]})
        eng_f.drain()
        eng_o.drain()

    both(3)
    rt_f.service.scale_out(1)
    rt_o.service.scale_out(1)
    _spread_fleet(rt_f)
    # Shard spaces really came out unevenly sized (the concatenated-view
    # offsets are not a trivial stride).
    lens = {sp.total_len for sp in rt_f.splan.shards}
    assert len(lens) > 1 or rt_f.n_shards == 1
    both(3)
    _assert_params_equal(rt_f, rt_o)
    for j in TREES:
        assert int(jax.device_get(rt_f.counts[j])) == int(
            jax.device_get(rt_o.counts[j]))

    # Now the launch-count acceptance: queue one push for every job, find
    # how many lanes have pending pieces, and tick the fleet ONCE.
    futs = [eng_f.step(j, {"target": TARGETS[j]})["future"] for j in TREES]
    pending_lanes = [sid for sid, lane in eng_f._lanes.items()
                     if any(lane.queues.get(j) for j in TREES)]
    assert len(pending_lanes) == rt_f.n_shards >= 2
    launches_before = eng_f.stats.n_launches
    applied = eng_f.tick()
    assert applied == sum(len(rt_f.splan.job_layout(j).shard_ids)
                          for j in TREES)
    assert eng_f.stats.n_launches == launches_before + 1
    assert all(f.done() for f in futs)

    # The oracle path spends >= S launches on the same work.
    [eng_o.step(j, {"target": TARGETS[j]}) for j in TREES]
    launches_before = eng_o.stats.n_launches
    eng_o.tick()
    assert eng_o.stats.n_launches - launches_before >= len(pending_lanes)
    _assert_params_equal(rt_f, rt_o)


def test_fleet_tick_spanning_job_resolves_multipart_future_in_one_tick():
    """A job split across >= 2 shards has ALL its pieces applied by the
    single fleet launch: the multi-part future resolves in one tick."""
    rt, eng = _runtime(engine=dict(max_staleness=2, jit=False))
    rt.service.scale_out(1)
    spanning = [j for j in TREES
                if len(rt.splan.job_layout(j).shard_ids) >= 2]
    if not spanning:
        pytest.skip("split left every job on one shard")
    j = spanning[0]
    fut = eng.step(j, {"target": TARGETS[j]})["future"]
    assert not fut.done()
    before = eng.stats.n_launches
    assert eng.tick_fleet() == len(rt.splan.job_layout(j).shard_ids)
    assert eng.stats.n_launches == before + 1
    assert fut.done() and fut.result() >= 1
    assert int(jax.device_get(rt.counts[j])) == fut.result()


def test_fleet_tick_skips_empty_lanes_mid_table():
    """Lanes with nothing pending contribute neither state movement nor
    tick counters: only the pending lanes' stats advance, and the launch
    still counts as ONE."""
    rt, eng = _runtime(engine=dict(max_staleness=2, jit=False))
    _spread_fleet(rt)
    # Pick the job hosted on the FEWEST shards so at least one lane stays
    # idle (every job spanning every shard would defeat the point).
    j = min(TREES, key=lambda j: len(rt.splan.job_layout(j).shard_ids))
    hosting = set(rt.splan.job_layout(j).shard_ids)
    if hosting == set(rt.splan.shard_ids):
        pytest.skip("every job spans every shard; no idle lane to skip")
    eng.step(j, {"target": TARGETS[j]})
    ticks_before = {sid: lane.stats.n_ticks
                    for sid, lane in eng._lanes.items()}
    before = eng.stats.n_launches
    assert eng.tick_fleet() == len(hosting)
    assert eng.stats.n_launches == before + 1
    for sid, lane in eng._lanes.items():
        expect = 1 if sid in hosting else 0
        assert lane.stats.n_ticks - ticks_before.get(sid, 0) == expect
    # An empty fleet tick is free: no launch, no tick.
    assert eng.tick_fleet() == 0
    assert eng.stats.n_launches == before + 1


def test_fleet_tick_survives_replans_and_caches_invalidate():
    """The fused path rides through scale_out/scale_in replans: fleet
    appliers (which bake every shard's concat offset) are rebuilt, the
    epoch fence holds, and the trajectory stays bit-exact with a fused
    twin that never scaled -- plus the per-shard oracle."""
    rt_f, eng_f = _runtime(engine=dict(max_staleness=0, jit=False))
    rt_o, eng_o = _runtime(engine=dict(max_staleness=0, jit=False,
                                       fleet_tick="per_shard"))

    def both(n):
        for _ in range(n):
            for j in TREES:
                eng_f.step(j, {"target": TARGETS[j]})
                eng_o.step(j, {"target": TARGETS[j]})
        eng_f.drain()
        eng_o.drain()

    both(2)
    assert eng_f._fleet_appliers  # the fused path really built one
    rt_f.service.scale_out(1)
    rt_o.service.scale_out(1)
    assert not eng_f._fleet_appliers  # replan cleared the concat layout
    both(2)
    rt_f.service.scale_in(1)
    rt_o.service.scale_in(1)
    both(2)
    _assert_params_equal(rt_f, rt_o)


def test_fleet_tick_mode_validation_and_flip():
    rt, _ = _runtime()
    with pytest.raises(ValueError, match="fleet_tick"):
        rt.attach_engine(fleet_tick="bogus")
    rt2, eng = _runtime(engine=dict(max_staleness=0, jit=False))
    eng.step("a", {"target": TARGETS["a"]})
    eng.drain()
    eng.fleet_tick = "per_shard"  # benchmarks flip modes on one engine
    eng.step("a", {"target": TARGETS["a"]})
    eng.drain()
    assert eng.stats.n_applied >= 2


# ------------------------------------------------------ engine satellites
def test_flat_engine_launch_accounting():
    """n_launches gauges the dispatch shape: one per batched tick at or
    above the crossover, one per job below it."""
    from repro.ps.service_runtime import ServiceRuntime

    def flat(min_batch_jobs):
        rt = ServiceRuntime(_service(), jit=False)
        eng = rt.attach_engine(max_staleness=1, jit=False,
                               min_batch_jobs=min_batch_jobs)
        _add_jobs(rt, {j: TREES[j] for j in ("a", "b")})
        for j in ("a", "b"):
            eng.step(j, {"target": TARGETS[j]})
        eng.tick()
        return eng.stats

    batched = flat(min_batch_jobs=2)
    assert (batched.n_ticks, batched.n_launches) == (1, 1)
    per_job = flat(min_batch_jobs=3)  # 2 pending < 3: per-job dispatch
    assert (per_job.n_ticks, per_job.n_launches) == (1, 2)
    assert per_job.n_per_job_dispatch == 1


def test_push_compression_accepted_on_sharded_engine():
    """Satellite: a push_compression job flows through the sharded engine
    (PR 8) -- every hosting shard's state gains an error-feedback buffer,
    the job trains through fused fleet ticks, and the wire counters land
    on both the fleet stats and the hosting lanes'."""
    rt, eng = _runtime(engine=dict(max_staleness=0, jit=False))
    tree_z = _tree(jax.random.PRNGKey(9), (32, 16))
    nbytes = sum(4 * v.size for v in tree_z.values())
    rt.add_job("z", tree_z, _loss, lr=0.05,
               required_servers=2, agg_throughput=nbytes / 0.2,
               push_compression="int8")
    target_z = jax.tree_util.tree_map(lambda p: p * 0 + 1.0, tree_z)
    losses = []
    for _ in range(30):
        losses.append(float(eng.step("z", {"target": target_z})["loss"]))
        for j in TREES:  # plain jobs tick through the same fused passes
            eng.step(j, {"target": TARGETS[j]})
    eng.drain()
    assert losses[-1] < 0.5 * losses[0]
    hosting = rt.splan.job_layout("z").shard_ids
    for sid in hosting:
        assert "ef" in rt.states[sid]
        assert 0 < eng._lane(sid).stats.push_bytes_wire \
            < eng._lane(sid).stats.push_bytes_raw
    assert 0 < eng.stats.push_bytes_wire < eng.stats.push_bytes_raw


def test_mixed_compression_fleet_matches_direct_step():
    """Parity: compressed and plain jobs co-resident in one fused fleet
    tick land bit-exact on the sequential ShardedServiceRuntime.step
    twin -- the compressed path must be invisible to plain jobs and
    identical (shared per-shard ef_transform) for compressed ones."""
    def build(with_engine):
        rt = ShardedServiceRuntime(_service(), jit=False)
        eng = (rt.attach_engine(max_staleness=0, jit=False)
               if with_engine else None)
        for i, (jid, t) in enumerate(TREES.items()):
            nbytes = sum(4 * v.size for v in t.values())
            rt.add_job(jid, t, _loss, lr=0.05, required_servers=1,
                       agg_throughput=nbytes / 0.2,
                       **({"push_compression": "int8"} if i == 0 else {}))
        rt.service.scale_out(2)
        return rt, eng

    rt_eng, eng = build(with_engine=True)
    rt_seq, _ = build(with_engine=False)
    for _ in range(10):
        for j in TREES:
            eng.step(j, {"target": TARGETS[j]})
            rt_seq.step(j, {"target": TARGETS[j]})
    eng.drain()
    assert eng.stats.n_applied >= len(TREES)  # the fused path really ran
    _assert_params_equal(rt_eng, rt_seq)
    for sid in rt_eng.states:
        st, tw = rt_eng.states[sid], rt_seq.states[sid]
        assert ("ef" in st) == ("ef" in tw)
        if "ef" in st:
            np.testing.assert_array_equal(np.asarray(st["ef"]),
                                          np.asarray(tw["ef"]))


def test_sharded_versioned_pull_diffs_and_epoch_fence():
    """Sharded diff pulls: a held vector pays only for blocks later
    ticks touched (zero for an untouched job), the diff chain
    reconstructs the full payload bit-exactly, and a replan's epoch
    bump sends stale vectors to the full-pull fallback."""
    rt, eng = _runtime(engine=dict(max_staleness=0, jit=False))
    for j in TREES:
        eng.step(j, {"target": TARGETS[j]})
    eng.drain()

    d0 = eng.pull("a", since_version=0)
    assert d0.full
    eng.step("b", {"target": TARGETS["b"]})  # "a" untouched
    eng.drain()
    d1 = eng.pull("a", since_version=d0.version)
    assert not d1.full and d1.block_ids.size == 0 and d1.bytes_wire == 0
    eng.step("a", {"target": TARGETS["a"]})
    eng.drain()
    d2 = eng.pull("a", since_version=d1.version)
    assert not d2.full and 0 < d2.bytes_wire <= d2.bytes_full
    packed = d2.apply(d1.apply(d0.data))
    np.testing.assert_array_equal(
        np.asarray(packed),
        np.asarray(eng.pull("a", since_version=0).data))

    nb = sum(4 * v.size for v in TREES["a"].values())
    rt.add_job("probe", _tree(jax.random.PRNGKey(9), (16,)), _loss,
               lr=0.05, required_servers=1, agg_throughput=nb / 0.2)
    d3 = eng.pull("a", since_version=d2.version)
    assert d3.full and d3.version.epoch != d2.version.epoch


def test_n_launches_surfaced_in_debug_stats():
    """Satellite: both runtimes' debug_stats() expose n_launches -- the
    fleet aggregate and each shard lane's own counter."""
    from repro.ps.service_runtime import ServiceRuntime

    rt_flat = ServiceRuntime(_service(), jit=False)
    feng = rt_flat.attach_engine(max_staleness=0, jit=False)
    _add_jobs(rt_flat, {"a": TREES["a"]})
    feng.step("a", {"target": TARGETS["a"]})
    feng.drain()
    assert rt_flat.debug_stats()["engine"]["n_launches"] >= 1

    rt, eng = _runtime(engine=dict(max_staleness=0, jit=False))
    for j in TREES:
        eng.step(j, {"target": TARGETS[j]})
    eng.drain()
    stats = rt.debug_stats()
    assert stats["engine"]["n_launches"] >= 1
    assert all("n_launches" in s for s in stats["shards"].values())
    # Fused fleet ticks count on the ENGINE, not per lane: the aggregate
    # launch count stays below the per-lane tick total once >= 2 lanes
    # share a launch.
    if rt.n_shards >= 2:
        lane_ticks = sum(s["n_ticks"] for s in stats["shards"].values())
        assert stats["engine"]["n_launches"] <= lane_ticks
