"""Service-tick engine tests: batched multi-job ticks vs sequential PR-2
block steps, bounded-staleness enforcement, drain-on-replan quiescing, and
the multi-job kernel vs a per-job sequential oracle.

Parity notes.  Block exclusivity makes the batched pass a pure
execution-order change, so the engine is bit-exact with K sequential
block steps BY CONSTRUCTION: eager engine == eager sequential
bit-for-bit at any tensor sizes, through replans (the acceptance test),
and the jitted batched APPLY program matches jitted sequential
``_adam_math`` block updates bit-for-bit at the shipped SIMD-even block
sizes.  Comparing two fully-jitted END-TO-END runtimes adds XLA:CPU's
cross-program fusion rounding on top (the fused grads+update loop may
reround ~1 ulp between program shapes -- the same caveat PR 2 documents
for jitted block-vs-masked), so that comparison gets a 1-ulp tolerance.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ParameterService
from repro.kernels.agg_adam import kernel as agg_kernel
from repro.kernels.agg_adam import ops as agg_ops, ref as agg_ref
from repro.ps.service_runtime import ServiceRuntime


def _tree(key, sizes):
    ks = jax.random.split(key, len(sizes))
    return {f"t{i}": jax.random.normal(k, (n,))
            for i, (k, n) in enumerate(zip(ks, sizes))}


def _quad_loss(params, batch):
    return sum(jnp.sum((params[k] - batch["target"][k]) ** 2)
               for k in params)


# SIMD-even sizes (multiples of 16): jitted cross-program bit-exactness.
TREES_EVEN = {
    "a": _tree(jax.random.PRNGKey(0), (48, 16, 32)),
    "b": _tree(jax.random.PRNGKey(1), (32, 16)),
}
PROBE_EVEN = _tree(jax.random.PRNGKey(7), (32,))
# Ragged sizes: eager stays bit-exact, jitted gets the 1-ulp tolerance.
TREES_RAGGED = {
    "a": _tree(jax.random.PRNGKey(2), (40, 17, 8)),
    "b": _tree(jax.random.PRNGKey(3), (33, 21)),
}
PROBE_RAGGED = _tree(jax.random.PRNGKey(8), (29,))


def _targets(trees):
    return {jid: jax.tree_util.tree_map(lambda p: p * 0 + 1.0, t)
            for jid, t in trees.items()}


def _runtime(trees, jit=True, engine=None):
    svc = ParameterService(total_budget=16, n_clusters=1, plan_pad_to=16)
    rt = ServiceRuntime(svc, jit=jit)
    eng = rt.attach_engine(**engine) if engine is not None else None
    for jid, tree in trees.items():
        nbytes = sum(4 * v.size for v in tree.values())
        rt.add_job(jid, tree, _quad_loss, lr=0.05, required_servers=2,
                   agg_throughput=nbytes / 0.45)
    return rt, eng


def _drive(rt, trees, probe, eng=None, n_steps=14):
    """Step all jobs n times; a probe job arrives at 5 and exits at 10,
    forcing two replan migrations (with queued pushes pending when the
    engine drives, so the quiesce/drain path is exercised)."""
    targets = _targets(trees)
    probe_target = jax.tree_util.tree_map(lambda p: p * 0 + 1.0, probe)
    step = eng.step if eng is not None else rt.step
    for i in range(n_steps):
        if i == 5:
            nb = sum(4 * v.size for v in probe.values())
            rt.add_job("probe", probe, _quad_loss, lr=0.05,
                       required_servers=1, agg_throughput=nb / 0.6)
        if i == 10:
            rt.remove_job("probe")
        for jid in trees:
            step(jid, {"target": targets[jid]})
        if 5 <= i < 10:
            step("probe", {"target": probe_target})
    if eng is not None:
        eng.drain()
    return rt


# ----------------------------------------------------------- acceptance
def test_batched_tick_bit_exact_vs_sequential_through_replans():
    """Tentpole acceptance: K co-resident jobs' updates applied by ONE
    batched tick are bit-exact with K sequential PR-2 block steps --
    including through a probe job's arrival/exit replans, whose
    migrations quiesce (drain) the engine first.  Driven eagerly on both
    sides so every arithmetic op is the pure per-op IEEE result -- the
    comparison pins the engine's SEMANTICS, free of XLA's per-program
    fusion rounding (covered with a 1-ulp tolerance below)."""
    rt_seq = _drive(_runtime(TREES_EVEN, jit=False)[0], TREES_EVEN,
                    PROBE_EVEN)
    rt_eng, eng = _runtime(TREES_EVEN, jit=False,
                           engine=dict(max_staleness=0, jit=False))
    _drive(rt_eng, TREES_EVEN, PROBE_EVEN, eng=eng)
    assert rt_seq.n_replans == rt_eng.n_replans >= 2
    # The ticks really batched: strictly fewer passes than pushes.
    assert eng.stats.n_ticks < eng.stats.n_applied
    assert eng.stats.mean_batch > 1.0
    for name in ("flat", "mu", "nu"):
        np.testing.assert_array_equal(np.asarray(rt_seq.state[name]),
                                      np.asarray(rt_eng.state[name]))


def test_batched_tick_eager_bit_exact_any_sizes():
    """Eager engine == eager sequential at RAGGED sizes too: the batched
    pass is semantically a pure execution-order change."""
    rt_seq = _drive(_runtime(TREES_RAGGED, jit=False)[0], TREES_RAGGED,
                    PROBE_RAGGED)
    rt_eng, eng = _runtime(TREES_RAGGED, jit=False,
                           engine=dict(max_staleness=0, jit=False))
    _drive(rt_eng, TREES_RAGGED, PROBE_RAGGED, eng=eng)
    for name in ("flat", "mu", "nu"):
        np.testing.assert_array_equal(np.asarray(rt_seq.state[name]),
                                      np.asarray(rt_eng.state[name]))


@pytest.mark.parametrize("trees,probe", [
    (TREES_EVEN, PROBE_EVEN), (TREES_RAGGED, PROBE_RAGGED)])
def test_batched_tick_jitted_within_ulp(trees, probe):
    """Fully-jitted engine vs fully-jitted sequential runtime: XLA:CPU's
    fusion emitter may reround one update expression ~1 ulp between the
    two program shapes (same caveat as the PR-2 jitted block-vs-masked
    comparison); never more."""
    rt_seq = _drive(_runtime(trees)[0], trees, probe)
    rt_eng, eng = _runtime(trees, engine=dict(max_staleness=0))
    _drive(rt_eng, trees, probe, eng=eng)
    assert rt_seq.n_replans == rt_eng.n_replans >= 2
    for name in ("flat", "mu", "nu"):
        np.testing.assert_allclose(np.asarray(rt_seq.state[name]),
                                   np.asarray(rt_eng.state[name]),
                                   rtol=1e-6, atol=1e-6)


# ------------------------------------------------------ bounded staleness
def test_staleness_bound_blocks_pull():
    """A job may run max_staleness steps ahead; the pull that would put it
    s+1 ahead blocks on (forces) a tick."""
    rt, eng = _runtime(TREES_EVEN,
                       engine=dict(max_staleness=1, queue_capacity=10))
    targets = _targets(TREES_EVEN)
    batch = {"target": targets["a"]}
    eng.step("a", batch)  # outstanding 1
    assert eng.stats.n_ticks == 0 and eng.outstanding("a") == 1
    eng.step("a", batch)  # pull at 1 <= s: allowed; outstanding 2 = s+1
    assert eng.stats.n_ticks == 0 and eng.outstanding("a") == 2
    m = eng.step("a", batch)  # pull at 2 > s: forced tick
    assert eng.stats.n_forced_staleness == 1
    assert eng.stats.n_ticks == 1
    assert eng.outstanding("a") == 2  # 3 submitted, 1 applied
    assert not m["future"].done()
    # result() forces the remaining ticks and reports the step count.
    assert m["future"].result() == 3
    assert eng.outstanding("a") == 0


def test_zero_staleness_is_bsp():
    """max_staleness=0: every pull beyond the first outstanding push
    forces the tick -- bulk-synchronous semantics."""
    rt, eng = _runtime(TREES_EVEN,
                       engine=dict(max_staleness=0, queue_capacity=10))
    batch = {"target": _targets(TREES_EVEN)["a"]}
    eng.step("a", batch)
    eng.step("a", batch)
    assert eng.stats.n_forced_staleness == 1
    assert eng.stats.n_ticks == 1


def test_queue_capacity_backpressure():
    """A full per-job queue exerts backpressure on submit_push."""
    rt, eng = _runtime(TREES_EVEN,
                       engine=dict(max_staleness=10, queue_capacity=2))
    grads = jax.tree_util.tree_map(jnp.ones_like, TREES_EVEN["a"])
    futs = [eng.submit_push("a", grads) for _ in range(3)]
    assert eng.stats.n_forced_capacity == 1
    assert eng.outstanding("a") == 2
    assert futs[0].done() and not futs[2].done()
    assert eng.drain() == 2
    assert all(f.done() for f in futs)


def test_future_resolves_with_job_step_count():
    rt, eng = _runtime(TREES_EVEN, engine=dict(max_staleness=0))
    batch = {"target": _targets(TREES_EVEN)["b"]}
    steps = [eng.step("b", batch)["future"].result() for _ in range(3)]
    assert steps == [1, 2, 3]


# ------------------------------------------------------ replan quiescing
def test_replan_quiesces_only_touched_jobs():
    """add_job/remove_job fence only the jobs the migration delta names
    as TOUCHED: their queued pushes apply against the OLD plan before
    the state migrates; untouched jobs' queues ride straight through the
    replan (re-tagged by the epoch fence) and apply at later ticks."""
    rt, eng = _runtime(TREES_EVEN,
                       engine=dict(max_staleness=2, queue_capacity=4))
    targets = _targets(TREES_EVEN)
    for jid in TREES_EVEN:
        eng.step(jid, {"target": targets[jid]})
        eng.step(jid, {"target": targets[jid]})
    assert eng.outstanding("a") == 2 and eng.outstanding("b") == 2
    nb = sum(4 * v.size for v in PROBE_EVEN.values())
    rt.add_job("probe", PROBE_EVEN, _quad_loss, lr=0.05,
               required_servers=1, agg_throughput=nb / 0.6)
    assert rt.n_replans >= 1
    touched = set(rt.last_replan_touched)
    assert "probe" in touched
    for jid in TREES_EVEN:
        if jid in touched:
            assert eng.outstanding(jid) == 0  # fenced: drained pre-move
        else:
            assert eng.outstanding(jid) == 2  # stall-free: queue survived
    rt.remove_job("probe")
    eng.drain()
    # Counts survived the round trips: both jobs applied their 2 pushes.
    assert int(jax.device_get(rt.state["counts"]["a"])) == 2
    assert "probe" not in rt.state["counts"]


def test_untouched_jobs_never_stall_through_replan():
    """Tentpole acceptance: a replan that does not move a job's layout
    must be INVISIBLE to it -- zero forced ticks, queue and compiled
    programs intact, and a trajectory bit-identical to a run where the
    neighbor never arrived.  (The probe sorts after every resident job
    and fits existing padding, so the delta touches only the probe.)"""
    probe = _tree(jax.random.PRNGKey(7), (32,))

    def drive(with_probe):
        rt, eng = _runtime(TREES_EVEN, jit=False,
                           engine=dict(max_staleness=2, queue_capacity=4,
                                       jit=False))
        targets = _targets(TREES_EVEN)
        probe_target = jax.tree_util.tree_map(lambda p: p * 0 + 1.0, probe)
        checks = {}
        for i in range(4):
            for jid in TREES_EVEN:
                eng.step(jid, {"target": targets[jid]})
            if i == 1 and with_probe:
                outstanding = {j: eng.outstanding(j) for j in TREES_EVEN}
                grad_fns = {j: eng._grad_fns.get(j) for j in TREES_EVEN}
                forced_before = eng.stats.n_forced_replan
                nb = sum(4 * v.size for v in probe.values())
                rt.add_job("zz", probe, _quad_loss, lr=0.05,
                           required_servers=1, agg_throughput=nb / 0.6)
                checks = dict(outstanding=outstanding, grad_fns=grad_fns,
                              forced_before=forced_before)
            if i >= 2 and with_probe:
                eng.step("zz", {"target": probe_target})
        eng.drain()
        return rt, eng, checks

    rt_p, eng_p, checks = drive(with_probe=True)
    rt_n, _, _ = drive(with_probe=False)

    # The arrival fenced only itself...
    assert rt_p.last_replan_touched == ("zz",)
    # ...stalled nobody (no replan-forced ticks, queues rode through)...
    assert eng_p.stats.n_forced_replan == checks["forced_before"] == 0
    for jid in TREES_EVEN:
        assert eng_p.outstanding(jid) == 0  # drained at the END only
        assert checks["outstanding"][jid] > 0  # queued ACROSS the replan
        # ...and kept every compiled program alive (no retrace stall).
        assert eng_p._grad_fns.get(jid) is checks["grad_fns"][jid]
    assert eng_p.stats.n_retagged >= sum(checks["outstanding"].values())

    # Bit-identical trajectory for the untouched jobs, moments included.
    from repro.ps.runtime import unflatten_tree
    for jid, tree in TREES_EVEN.items():
        for name in ("flat", "mu", "nu"):
            with_p = unflatten_tree(rt_p.plan, rt_p.state[name], tree,
                                    job_id=jid)
            without = unflatten_tree(rt_n.plan, rt_n.state[name], tree,
                                     job_id=jid)
            for k in tree:
                np.testing.assert_array_equal(np.asarray(with_p[k]),
                                              np.asarray(without[k]))


def test_epoch_fence_rejects_cross_layout_push():
    """The fence: a queued push whose epoch does not match the engine's
    can never reach the apply -- a replan that migrated a job's layout
    without draining its queue is a protocol violation, not a silently
    corrupted update."""
    rt, eng = _runtime(TREES_EVEN,
                       engine=dict(max_staleness=3, queue_capacity=4))
    eng.step("a", {"target": _targets(TREES_EVEN)["a"]})
    eng._epoch += 1  # simulate a replan that skipped the drain
    with pytest.raises(RuntimeError, match="epoch fence"):
        eng.tick()


def test_small_k_tick_dispatches_per_job_and_stays_exact():
    """Below min_batch_jobs a tick dispatches per-job passes (the
    measured small-K crossover); the applied result is identical and the
    stats record the dispatch decision."""
    rt, eng = _runtime(TREES_EVEN,
                       engine=dict(max_staleness=0, min_batch_jobs=3))
    targets = _targets(TREES_EVEN)
    for jid in TREES_EVEN:
        eng.step(jid, {"target": targets[jid]})
    assert eng.drain() == 2
    assert eng.stats.n_per_job_dispatch >= 1  # 2 pending < crossover 3

    rt_b, eng_b = _runtime(TREES_EVEN,
                           engine=dict(max_staleness=0, min_batch_jobs=2))
    for jid in TREES_EVEN:
        eng_b.step(jid, {"target": targets[jid]})
    assert eng_b.drain() == 2
    assert eng_b.stats.n_per_job_dispatch == 0  # fused pass took it
    for name in ("flat", "mu", "nu"):
        np.testing.assert_allclose(np.asarray(rt.state[name]),
                                   np.asarray(rt_b.state[name]),
                                   rtol=1e-6, atol=1e-6)


def test_engine_rejects_unknown_and_accepts_compressed_jobs():
    """Unknown jobs still fail loudly; compressed-push jobs flow through
    the batched tick (PR 8): the shared state gains an error-feedback
    buffer, the job trains, and the push-byte counters price the wire."""
    rt, eng = _runtime(TREES_EVEN, jit=False,
                       engine=dict(max_staleness=0, jit=False))
    with pytest.raises(ValueError, match="unknown job"):
        eng.submit_push("nope", {})
    with pytest.raises(ValueError, match="unknown job"):
        eng.pull("nope")
    assert "ef" not in rt.state
    tree_z = _tree(jax.random.PRNGKey(9), (32, 16))
    nb = sum(4 * v.size for v in tree_z.values())
    rt.add_job("z", tree_z, _quad_loss, lr=0.05, required_servers=1,
               agg_throughput=nb / 0.6, push_compression="int8")
    target = jax.tree_util.tree_map(lambda p: p * 0 + 1.0, tree_z)
    losses = [float(eng.step("z", {"target": target})["loss"])
              for _ in range(30)]
    eng.drain()
    assert "ef" in rt.state  # widened when the compressed push queued
    assert losses[-1] < 0.5 * losses[0]
    assert 0 < eng.stats.push_bytes_wire < eng.stats.push_bytes_raw


def test_flat_engine_compressed_matches_runtime_step():
    """Parity: a compressed job stepped through the engine lands bit-
    exact on runtime.step()'s compressed path (both run the shared
    ef_transform recurrence; eager, s=0)."""
    targets = _targets(TREES_EVEN)

    def build():
        svc = ParameterService(total_budget=16, n_clusters=1,
                               plan_pad_to=16)
        rt = ServiceRuntime(svc, jit=False)
        for i, (jid, tree) in enumerate(TREES_EVEN.items()):
            nb = sum(4 * v.size for v in tree.values())
            rt.add_job(jid, tree, _quad_loss, lr=0.05, required_servers=2,
                       agg_throughput=nb / 0.45,
                       **({"push_compression": "int8"} if i == 0 else {}))
        return rt

    rt_eng = build()
    eng = rt_eng.attach_engine(max_staleness=0, jit=False)
    rt_seq = build()
    for _ in range(10):
        for jid in TREES_EVEN:
            eng.step(jid, {"target": targets[jid]})
            rt_seq.step(jid, {"target": targets[jid]})
    eng.drain()
    for name in ("flat", "mu", "nu", "ef"):
        np.testing.assert_array_equal(np.asarray(rt_eng.state[name]),
                                      np.asarray(rt_seq.state[name]))


# ------------------------------------------------- versioned pulls (PR 8)
def test_versioned_pull_diffs_reconstruct_full_pull():
    """since_version=0 bootstraps full; held vectors then diff-pull only
    the blocks later ticks touched, and applying the chain reconstructs
    the full payload bit-exactly.  An untouched job's diff is empty."""
    rt, eng = _runtime(TREES_EVEN, jit=False,
                       engine=dict(max_staleness=0, jit=False))
    targets = _targets(TREES_EVEN)
    for jid in TREES_EVEN:
        eng.step(jid, {"target": targets[jid]})
    eng.drain()

    d0 = eng.pull("a", since_version=0)
    assert d0.full and d0.bytes_wire == d0.bytes_full
    eng.step("b", {"target": targets["b"]})  # "a" untouched this tick
    eng.drain()
    d1 = eng.pull("a", since_version=d0.version)
    assert not d1.full and d1.block_ids.size == 0 and d1.bytes_wire == 0
    eng.step("a", {"target": targets["a"]})
    eng.drain()
    d2 = eng.pull("a", since_version=d1.version)
    assert not d2.full and d2.block_ids.size > 0
    assert d2.bytes_wire <= d2.bytes_full
    packed = d2.apply(d1.apply(d0.data))
    np.testing.assert_array_equal(
        np.asarray(packed), np.asarray(eng.pull("a", since_version=0).data))
    assert eng.stats.n_diff_pulls == 2 and eng.stats.n_full_pulls == 2


def test_versioned_pull_falls_back_full_across_replans():
    """A replan invalidates every held vector (blocks renumber): the next
    versioned pull of a stale vector is served full, with the new epoch."""
    rt, eng = _runtime(TREES_EVEN, jit=False,
                       engine=dict(max_staleness=0, jit=False))
    targets = _targets(TREES_EVEN)
    eng.step("a", {"target": targets["a"]})
    eng.drain()
    d0 = eng.pull("a", since_version=0)
    nb = sum(4 * v.size for v in PROBE_EVEN.values())
    rt.add_job("probe", PROBE_EVEN, _quad_loss, lr=0.05,
               required_servers=1, agg_throughput=nb / 0.6)  # replan
    d1 = eng.pull("a", since_version=d0.version)
    assert d1.full and d1.version.epoch != d0.version.epoch
    np.testing.assert_array_equal(np.asarray(d1.data),
                                  np.asarray(d0.data))  # a never stepped


# --------------------------------------------------- multi-job kernel
@pytest.mark.parametrize("workers", [0, 4])
def test_multijob_kernel_matches_sequential_oracle(workers):
    """aggregate_adam_multijob (interpret mode) == applying each job's
    block-owned update sequentially (per-job oracle), with per-job
    hyperparameters and step counts."""
    block, n_blocks = 8, 16
    n = block * n_blocks
    bi = [np.array([1, 2, 5], np.int32), np.array([0, 3, 9, 10], np.int32)]
    block_idx = np.concatenate(bi)
    sizes = tuple(b.size for b in bi)
    m = block_idx.size * block
    p = jax.random.normal(jax.random.PRNGKey(0), (n,))
    mu = jnp.abs(jax.random.normal(jax.random.PRNGKey(1), (n,))) * 0.1
    nu = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (n,))) * 0.01
    gshape = (workers, m) if workers else (m,)
    g = jax.random.normal(jax.random.PRNGKey(3), gshape)
    counts = [jnp.array(5, jnp.int32), jnp.array(2, jnp.int32)]
    kw = dict(lr=(1e-2, 3e-3), b1=0.9, b2=0.999, eps=1e-8, wd=(0.01, 0.0))
    hp = agg_ops.multi_job_hp(counts, **kw)
    job_slot = jnp.asarray(np.repeat(np.arange(2, dtype=np.int32), sizes))
    out_k = agg_kernel.aggregate_adam_multijob(
        p, g, mu, nu, hp, jnp.asarray(block_idx), job_slot, block=block,
        interpret=True)
    out_r = agg_ref.aggregate_adam_multijob_ref(
        p, g, mu, nu, counts, block_idx, sizes, block=block, **kw)
    for a, b in zip(out_k, out_r):
        assert a.shape == (m,)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-6)


def test_multijob_jnp_fallback_bit_exact_vs_sequential_blocks():
    """The fused-scatter jnp fallback is bit-exact with sequential
    per-job _adam_math block updates at SIMD-even block sizes, jitted."""
    from repro.ps.runtime import _adam_math

    block = 16
    bi = [np.array([1, 2, 5], np.int32), np.array([0, 3, 9, 10], np.int32)]
    block_idx = np.concatenate(bi)
    sizes = tuple(b.size for b in bi)
    n = block * 16
    p = jax.random.normal(jax.random.PRNGKey(0), (n,))
    mu = jnp.abs(jax.random.normal(jax.random.PRNGKey(1), (n,))) * 0.1
    nu = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (n,))) * 0.01
    g = jax.random.normal(jax.random.PRNGKey(3), (block_idx.size * block,))
    counts = [jnp.array(5, jnp.int32), jnp.array(2, jnp.int32)]
    lrs = (1e-2, 3e-3)

    def rows(v, b):
        return v.reshape(-1, block)[jnp.asarray(b)].reshape(-1)

    batched = jax.jit(lambda p, g, mu, nu, c0, c1: agg_ops.multi_job_adam_update(
        p, (g[:sizes[0] * block], g[sizes[0] * block:]), mu, nu, [c0, c1],
        block_idx=block_idx, job_sizes=sizes, block=block, lr=lrs))
    out_b = batched(p, g, mu, nu, *counts)
    outs = []
    for j, (b, cnt, lr) in enumerate(zip(bi, counts, lrs)):
        lo = sum(sizes[:j]) * block
        hi = lo + sizes[j] * block
        fn = jax.jit(lambda p, g, mu, nu, c, _b=b, _lr=lr: _adam_math(
            rows(p, _b), g, rows(mu, _b), rows(nu, _b), c, lr=_lr,
            b1=0.9, b2=0.999, eps=1e-8))
        outs.append(fn(p, g[lo:hi], mu, nu, cnt))
    for i in range(3):
        cat = np.concatenate([np.asarray(o[i]) for o in outs])
        np.testing.assert_array_equal(np.asarray(out_b[i]), cat)


def test_multijob_p_packed_disambiguation():
    """Regression: when the jobs jointly own EVERY block, packed and full
    p have the same length but different lane order -- the explicit
    p_packed flag must keep them apart (shape inference once misread the
    full buffer as packed and corrupted every parameter)."""
    block = 16
    bi = [np.array([2, 3], np.int32), np.array([0, 1], np.int32)]
    block_idx = np.concatenate(bi)  # NOT the identity order
    sizes = (2, 2)
    n = block * 4  # jobs cover the whole space: m == n
    p = jax.random.normal(jax.random.PRNGKey(0), (n,))
    mu = jnp.zeros((n,))
    nu = jnp.zeros((n,))
    g = jax.random.normal(jax.random.PRNGKey(3), (n,))
    counts = [jnp.array(1, jnp.int32)] * 2
    out = agg_ops.multi_job_adam_update(
        p, (g[:sizes[0] * block], g[sizes[0] * block:]), mu, nu, counts,
        block_idx=block_idx, job_sizes=sizes, block=block, lr=0.1)
    ref = agg_ref.aggregate_adam_multijob_ref(
        p, g, mu, nu, counts, block_idx, sizes, block=block, lr=0.1)
    for a, b in zip(out, ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)


# ------------------------------------------------- remove_job regression
def test_remove_job_drains_queued_pushes_before_replan():
    """Regression: removing a job while the engine holds its queued
    pushes must drain (apply) them against the old layout BEFORE the
    replan -- every held future resolves, nothing is silently dropped,
    and co-resident jobs keep training."""
    rt, eng = _runtime(TREES_EVEN, jit=False,
                       engine=dict(max_staleness=2, jit=False))
    targets = _targets(TREES_EVEN)
    futs = [eng.step("b", {"target": targets["b"]})["future"]
            for _ in range(2)]
    assert eng.outstanding("b") == 2
    rt.remove_job("b")
    assert all(f.done() for f in futs)
    assert [f.result() for f in futs] == [1, 2]
    assert "b" not in rt.job_ids
    assert eng.outstanding("b") == 0
    # The survivor still trains through the post-exit plan.
    eng.step("a", {"target": targets["a"]})["future"].result()


def test_dropped_push_future_raises_cleanly():
    """Regression: a push dropped WITHOUT applying (drain bypassed) must
    cancel its future -- result() raises instead of forcing ticks
    forever on a job the engine no longer knows."""
    rt, eng = _runtime(TREES_EVEN, jit=False,
                       engine=dict(max_staleness=2, jit=False))
    targets = _targets(TREES_EVEN)
    fut = eng.step("b", {"target": targets["b"]})["future"]
    eng._forget_job("b")  # simulate a drop that bypassed the drain
    assert not fut.done()
    assert fut.cancelled()
    with pytest.raises(RuntimeError, match="never apply"):
        fut.result()
