"""ServicePlan tests: compiled plans match the live assignment, multi-job
migration round-trips, shared-runtime training is replan-proof, checkpoints
restore across packings."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore_ps_checkpoint, save_ps_checkpoint
from repro.core import ParameterService
from repro.ps.elastic import migrate_flat_state, migration_bytes
from repro.ps.plan import (
    plan_from_json,
    plan_migration_bytes,
    plan_padding_waste,
    plan_to_json,
    segment_mask,
)
from repro.ps.runtime import (
    flatten_tree,
    init_shared_state,
    job_profile_from_tree,
    seed_job_params,
    unflatten_tree,
)
from repro.ps.service_runtime import ServiceRuntime


def _tree(key, sizes):
    ks = jax.random.split(key, len(sizes))
    return {f"t{i}": jax.random.normal(k, (n,))
            for i, (k, n) in enumerate(zip(ks, sizes))}


def _service_with_jobs(order=("a", "b"), required=2, busy=0.45):
    """A real service with two jobs registered in the given order (order
    changes packing, so different orders give relocated layouts)."""
    svc = ParameterService(total_budget=16, n_clusters=1, plan_pad_to=8)
    trees = {
        "a": _tree(jax.random.PRNGKey(0), (300, 120, 77, 30)),
        "b": _tree(jax.random.PRNGKey(1), (250, 90, 60)),
    }
    for jid in order:
        nbytes = sum(4 * v.size for v in trees[jid].values())
        profile, specs = job_profile_from_tree(
            jid, trees[jid], required_servers=required,
            agg_throughput=nbytes / busy)
        svc.register_job(profile, specs=specs)
    return svc, trees


# ------------------------------------------------------------- compilation
def test_compile_plan_matches_live_assignment():
    """Acceptance: segment->shard mapping exactly equals Aggregator.tasks."""
    svc, _ = _service_with_jobs()
    plan = svc.compile_plan()

    from_plan = {
        (s.job_id, s.tensor_id): plan.shard_ids[s.shard] for s in plan.segments
    }
    from_service = {
        key: agg.agg_id for agg in svc.aggregators for key in agg.tasks
    }
    assert from_plan == from_service
    assert len(plan.segments) == len(from_service)
    # placement() (the Agent mapping table) agrees too.
    for jid in ("a", "b"):
        expect = {s.tensor_id: plan.shard_ids[s.shard]
                  for s in plan.segments_of(jid)}
        assert svc.placement(jid) == expect


def test_compiled_plan_layout_is_block_aligned_and_disjoint():
    """Within a shard, each job's run of segments is contiguous and starts
    on a block_align boundary (gaps appear ONLY between different jobs'
    runs, and only to round up to the boundary) -- the invariant that makes
    every block_align-sized block single-job (block-owned updates)."""
    svc, _ = _service_with_jobs()
    plan = svc.compile_plan()
    assert plan.block_align == 8  # plan_pad_to flows through
    for shard_idx in plan.shard_segments:
        off = 0
        prev_job = None
        for i in shard_idx:
            seg = plan.segments[i]
            if prev_job is None or seg.job_id == prev_job:
                assert seg.offset == off  # contiguous within a job's run
            else:
                aligned = -(-off // plan.block_align) * plan.block_align
                assert seg.offset == aligned  # next run: aligned, no waste
            prev_job = seg.job_id
            off = seg.offset + seg.size
        assert off <= plan.shard_len
    assert 0.0 <= plan_padding_waste(plan) < 1.0


def test_multijob_flatten_unflatten_roundtrip():
    svc, trees = _service_with_jobs()
    plan = svc.compile_plan()
    flat = jnp.zeros((plan.total_len,))
    for jid, tree in trees.items():
        vec = flatten_tree(plan, tree, job_id=jid)
        flat = jnp.where(jnp.asarray(segment_mask(plan, jid)), vec, flat)
    for jid, tree in trees.items():
        back = unflatten_tree(plan, flat, tree, job_id=jid)
        for k in tree:
            np.testing.assert_array_equal(np.asarray(back[k]),
                                          np.asarray(tree[k]))


def test_plan_json_roundtrip():
    svc, _ = _service_with_jobs()
    plan = svc.compile_plan()
    assert plan_from_json(plan_to_json(plan)) == plan


# -------------------------------------------------------------- migration
def test_migrate_roundtrip_is_identity():
    """Acceptance: migrate A->B->A is the identity on every segment, and
    bytes-moved counts exactly the segments whose shard changed."""
    svc_ab, trees = _service_with_jobs(order=("a", "b"))
    svc_ba, _ = _service_with_jobs(order=("b", "a"))
    plan_a, plan_b = svc_ab.compile_plan(), svc_ba.compile_plan()

    state = init_shared_state(plan_a)
    for jid, tree in trees.items():
        state = seed_job_params(plan_a, state, jid, tree)
    state["mu"] = jax.random.normal(jax.random.PRNGKey(3),
                                    state["mu"].shape)
    # Zero non-payload lanes so the round trip is exactly the identity.
    mask = jnp.asarray(segment_mask(plan_a))
    state["mu"] = jnp.where(mask, state["mu"], 0.0)

    there = migrate_flat_state(state, plan_a, plan_b)
    back = migrate_flat_state(there, plan_b, plan_a)
    for k in ("flat", "mu", "nu"):
        np.testing.assert_array_equal(np.asarray(back[k]),
                                      np.asarray(state[k]))

    expected = sum(
        s.size * 12
        for s in plan_b.segments
        if plan_a.shard_ids[plan_a.by_skey[s.skey].shard]
        != plan_b.shard_ids[s.shard]
    )
    assert migration_bytes(plan_a, plan_b) == expected
    assert migration_bytes(plan_a, plan_a) == 0
    # Symmetric cross-Aggregator traffic for a pure relayout of the same jobs.
    assert plan_migration_bytes(plan_b, plan_a) == expected


def test_migration_bytes_ignores_pure_index_shift():
    """A shard-index shift (an emptied Aggregator dropping out of the list)
    moves no bytes off the surviving segments' actual host."""
    from repro.ps.plan import FlatPlan, Segment

    seg = dict(key="t0", offset=0, size=10, shape=(10,), dtype=np.float32,
               job_id="b", tensor_id=0)
    old = FlatPlan(2, 16, (Segment(shard=1, **seg),),
                   shard_ids=("agg0", "agg1"))
    same_host = FlatPlan(1, 16, (Segment(shard=0, **seg),),
                         shard_ids=("agg1",))
    other_host = FlatPlan(1, 16, (Segment(shard=0, **seg),),
                          shard_ids=("agg2",))
    assert plan_migration_bytes(old, same_host) == 0
    assert plan_migration_bytes(old, other_host) == 10 * 12


def test_migration_zero_fills_new_jobs_segments():
    svc, trees = _service_with_jobs(order=("a",))
    plan_a = svc.compile_plan()
    state = init_shared_state(plan_a)
    state = seed_job_params(plan_a, state, "a", trees["a"])

    nbytes = sum(4 * v.size for v in trees["b"].values())
    profile, specs = job_profile_from_tree(
        "b", trees["b"], required_servers=2, agg_throughput=nbytes / 0.45)
    svc.register_job(profile, specs=specs)
    plan_ab = svc.compile_plan()

    migrated = migrate_flat_state(state, plan_a, plan_ab)
    back = unflatten_tree(plan_ab, migrated["flat"], trees["a"], job_id="a")
    for k in trees["a"]:  # job a's tensors survive the arrival bit-exactly
        np.testing.assert_array_equal(np.asarray(back[k]),
                                      np.asarray(trees["a"][k]))
    b_mask = jnp.asarray(segment_mask(plan_ab, "b"))
    assert not np.any(np.asarray(migrated["flat"])[np.asarray(b_mask)])


# --------------------------------------------------- shared-service runtime
def _quad_loss(params, batch):
    return sum(jnp.sum((params[k] - batch["target"][k]) ** 2)
               for k in params)


def _add_quad_job(rt, jid, tree, required=2, busy=0.45):
    nbytes = sum(4 * v.size for v in tree.values())
    rt.add_job(jid, tree, _quad_loss, lr=0.05, required_servers=required,
               agg_throughput=nbytes / busy)


def test_shared_runtime_two_jobs_replan_bit_exact():
    """Acceptance: two jobs train through ONE shared flat space; a third
    job's arrival + exit forces live replans; unmoved AND moved segments of
    the survivors match a no-replan reference run bit-exactly."""
    trees = {
        "a": _tree(jax.random.PRNGKey(0), (40, 17, 8)),
        "b": _tree(jax.random.PRNGKey(1), (33, 21)),
    }
    targets = {jid: jax.tree_util.tree_map(lambda p: p * 0 + 1.0, t)
               for jid, t in trees.items()}

    def run(with_third_job):
        svc = ParameterService(total_budget=16, n_clusters=1, plan_pad_to=8)
        rt = ServiceRuntime(svc)
        for jid, tree in trees.items():
            _add_quad_job(rt, jid, tree)
        losses = {jid: [] for jid in trees}
        for i in range(24):
            if with_third_job and i == 8:
                _add_quad_job(rt, "probe", _tree(jax.random.PRNGKey(7), (29,)),
                              required=1, busy=0.6)
            if with_third_job and i == 16:
                rt.remove_job("probe")
            for jid in trees:
                m = rt.step(jid, {"target": targets[jid]})
                losses[jid].append(float(m["loss"]))
            if with_third_job and 8 <= i < 16:
                rt.step("probe", {"target": jax.tree_util.tree_map(
                    lambda p: p * 0 + 1.0, _tree(jax.random.PRNGKey(7), (29,)))})
        return rt, losses

    rt_replan, losses_replan = run(with_third_job=True)
    rt_ref, losses_ref = run(with_third_job=False)

    # Both runs replan when job b joins job a; only one rides through the
    # probe's arrival + exit migrations as well.
    assert rt_replan.n_replans >= rt_ref.n_replans + 2

    for jid in trees:
        # Losses identical step by step (migration is semantically free)...
        np.testing.assert_array_equal(losses_replan[jid], losses_ref[jid])
        assert losses_replan[jid][-1] < 0.35 * losses_replan[jid][0]
        # ...and the full optimizer state matches bit-exactly per tensor.
        for name in ("flat", "mu", "nu"):
            moved = unflatten_tree(rt_replan.plan, rt_replan.state[name],
                                   trees[jid], job_id=jid)
            ref = unflatten_tree(rt_ref.plan, rt_ref.state[name],
                                 trees[jid], job_id=jid)
            for k in trees[jid]:
                np.testing.assert_array_equal(np.asarray(moved[k]),
                                              np.asarray(ref[k]))


def test_shared_runtime_isolates_jobs():
    """One job stepping must not perturb a co-resident job's segments."""
    trees = {
        "a": _tree(jax.random.PRNGKey(0), (40, 17)),
        "b": _tree(jax.random.PRNGKey(1), (33,)),
    }
    svc = ParameterService(total_budget=16, n_clusters=1, plan_pad_to=8)
    rt = ServiceRuntime(svc)
    for jid, tree in trees.items():
        _add_quad_job(rt, jid, tree)
    target = jax.tree_util.tree_map(lambda p: p * 0 + 1.0, trees["a"])
    before = jax.tree_util.tree_map(np.asarray, rt.params_of("b"))
    for _ in range(3):
        rt.step("a", {"target": target})
    after = rt.params_of("b")
    for k in trees["b"]:
        np.testing.assert_array_equal(np.asarray(after[k]), before[k])
    assert int(rt.state["counts"]["a"]) == 3
    assert int(rt.state["counts"]["b"]) == 0


def test_shared_runtime_push_compression():
    """Compressed jobs get a shared error-feedback buffer, including when a
    compressed job joins a runtime whose state predates compression."""
    svc = ParameterService(total_budget=16, n_clusters=1, plan_pad_to=8)
    rt = ServiceRuntime(svc)
    tree_a = _tree(jax.random.PRNGKey(0), (40, 17))
    rt.add_job("a", tree_a, _quad_loss, lr=0.05, required_servers=1)
    assert "ef" not in rt.state
    target_a = jax.tree_util.tree_map(lambda p: p * 0 + 1.0, tree_a)
    first = float(rt.step("a", {"target": target_a})["loss"])

    tree_c = _tree(jax.random.PRNGKey(2), (25,))
    rt.add_job("c", tree_c, _quad_loss, lr=0.05, required_servers=1,
               push_compression="int8")
    assert "ef" in rt.state  # added on the replan a's state rode through
    target_c = jax.tree_util.tree_map(lambda p: p * 0 + 1.0, tree_c)
    losses = [float(rt.step("c", {"target": target_c})["loss"])
              for _ in range(20)]
    assert losses[-1] < 0.5 * losses[0]
    # The uncompressed job keeps training against the widened state.
    assert float(rt.step("a", {"target": target_a})["loss"]) < first


def test_runtime_last_job_exit_clears_state():
    svc = ParameterService(total_budget=8, n_clusters=1)
    rt = ServiceRuntime(svc)
    _add_quad_job(rt, "a", _tree(jax.random.PRNGKey(0), (16,)), required=1)
    assert rt.plan is not None
    rt.remove_job("a")
    assert rt.plan is None and rt.state is None


# -------------------------------------------------------------- checkpoint
def test_ps_checkpoint_restores_across_packings(tmp_path):
    """Acceptance: a checkpoint taken under one packing restores under
    another -- every tensor (and moment) reads back identically."""
    svc_ab, trees = _service_with_jobs(order=("a", "b"))
    svc_ba, _ = _service_with_jobs(order=("b", "a"))
    plan_a, plan_b = svc_ab.compile_plan(), svc_ba.compile_plan()
    assert plan_a != plan_b

    state = init_shared_state(plan_a)
    for jid, tree in trees.items():
        state = seed_job_params(plan_a, state, jid, tree)
    state["mu"] = jnp.where(jnp.asarray(segment_mask(plan_a)),
                            jax.random.normal(jax.random.PRNGKey(5),
                                              state["mu"].shape), 0.0)

    save_ps_checkpoint(tmp_path, 3, plan_a, state)
    saved_plan, same = restore_ps_checkpoint(tmp_path, 3)
    assert saved_plan == plan_a

    got_plan, restored = restore_ps_checkpoint(tmp_path, 3, plan=plan_b)
    assert got_plan == plan_b
    for jid, tree in trees.items():
        for name in ("flat", "mu"):
            a = unflatten_tree(plan_a, state[name], tree, job_id=jid)
            b = unflatten_tree(plan_b, restored[name], tree, job_id=jid)
            for k in tree:
                np.testing.assert_array_equal(np.asarray(a[k]),
                                              np.asarray(b[k]))
