"""Tiny fallback for `hypothesis` so tier-1 tests collect without it.

Implements just the surface these tests use -- @given/@settings and the
floats/integers/lists/sampled_from strategies -- drawing a fixed number of
examples from a seeded RNG (deterministic across runs).  Install the real
thing (`pip install -r requirements-dev.txt`) for shrinking, edge-case
generation, and the full API.
"""

from __future__ import annotations

import functools
import inspect
import random
from typing import Any, Callable, List

_DEFAULT_EXAMPLES = 20


class _Strategy:
    def __init__(self, sample: Callable[[random.Random], Any]):
        self._sample = sample


class strategies:  # mirrors `hypothesis.strategies` as used in this repo
    @staticmethod
    def floats(min_value: float, max_value: float) -> _Strategy:
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def lists(elements: _Strategy, min_size: int = 0,
              max_size: int = 10) -> _Strategy:
        def sample(rng: random.Random) -> List[Any]:
            n = rng.randint(min_size, max_size)
            return [elements._sample(rng) for _ in range(n)]

        return _Strategy(sample)

    @staticmethod
    def sampled_from(seq) -> _Strategy:
        options = list(seq)
        return _Strategy(lambda rng: rng.choice(options))


def settings(deadline=None, max_examples: int = _DEFAULT_EXAMPLES, **_ignored):
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn

    return deco


def given(**strats: _Strategy):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            rng = random.Random(0)
            n = getattr(wrapper, "_shim_max_examples", _DEFAULT_EXAMPLES)
            for i in range(n):
                drawn = {k: s._sample(rng) for k, s in strats.items()}
                try:
                    fn(*args, **kwargs, **drawn)
                except Exception as e:  # noqa: BLE001 - annotate the example
                    raise AssertionError(
                        f"falsifying example (shim, draw {i}): {drawn!r}"
                    ) from e

        # Hide the drawn parameters from pytest's fixture resolution (any
        # remaining parameters still resolve as fixtures, like hypothesis).
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(parameters=[
            p for p in sig.parameters.values() if p.name not in strats
        ])
        return wrapper

    return deco
