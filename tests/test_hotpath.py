"""O(job)-cost data-plane tests: block-owned vs masked step parity (incl.
the fused scalar-prefetch kernel and post-replan migration), plan-time
access structures, elastic permutation caching, and HLO-level O(1) claims.

Parity notes.  The fused (Pallas interpret) and unfused masked paths share
one arithmetic form (repro.ps.runtime._adam_math mirrors the kernel's
grouping, bias-correction scalars are barrier-materialized), so their
donated jitted steps agree bit-for-bit.  The unfused BLOCK program is
semantically identical too -- eager execution matches the eager masked
path exactly -- but XLA's fusion emitter may round one update expression
differently per program shape (~1 ulp), so jitted block-vs-masked is
compared with a 1-ulp tolerance rather than bit equality.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ParameterService
from repro.kernels.agg_adam import ops as agg_ops, ref as agg_ref
from repro.ps.elastic import (
    clear_plan_cache,
    migrate_flat_state,
    plan_cache_stats,
)
from repro.ps.plan import segment_mask
from repro.ps.runtime import (
    flatten_tree,
    init_shared_state,
    make_ps_train_step,
    seed_job_params,
    unflatten_tree,
)
from repro.ps.service_runtime import ServiceRuntime


def _tree(key, sizes):
    ks = jax.random.split(key, len(sizes))
    return {f"t{i}": jax.random.normal(k, (n,))
            for i, (k, n) in enumerate(zip(ks, sizes))}


def _quad_loss(params, batch):
    return sum(jnp.sum((params[k] - batch["target"][k]) ** 2)
               for k in params)


TREES = {
    "a": _tree(jax.random.PRNGKey(0), (40, 17, 8)),
    "b": _tree(jax.random.PRNGKey(1), (33, 21)),
}
TARGETS = {jid: jax.tree_util.tree_map(lambda p: p * 0 + 1.0, t)
           for jid, t in TREES.items()}
PROBE = _tree(jax.random.PRNGKey(7), (29,))
PROBE_TARGET = jax.tree_util.tree_map(lambda p: p * 0 + 1.0, PROBE)


def _runtime(jit=True, **opts):
    svc = ParameterService(total_budget=16, n_clusters=1, plan_pad_to=8)
    rt = ServiceRuntime(svc, jit=jit)
    for jid, tree in TREES.items():
        nbytes = sum(4 * v.size for v in tree.values())
        rt.add_job(jid, tree, _quad_loss, lr=0.05, required_servers=2,
                   agg_throughput=nbytes / 0.45, **opts)
    return rt


def _drive(rt, n_steps=14, replan=True, **probe_opts):
    """Step both jobs n times; mid-run a probe job arrives and exits."""
    for i in range(n_steps):
        if replan and i == 5:
            nb = sum(4 * v.size for v in PROBE.values())
            rt.add_job("probe", PROBE, _quad_loss, lr=0.05,
                       required_servers=1, agg_throughput=nb / 0.6,
                       **probe_opts)
        if replan and i == 10:
            rt.remove_job("probe")
        for jid in TREES:
            rt.step(jid, {"target": TARGETS[jid]})
        if replan and 5 <= i < 10:
            rt.step("probe", {"target": PROBE_TARGET})
    return rt


# ---------------------------------------------------------- parity (tentpole)
def test_fused_block_step_matches_masked_bit_exact_through_replans():
    """Acceptance: the donated jitted block-owned FUSED step (Pallas
    scalar-prefetch kernel, interpret mode on CPU) matches the unfused
    MASKED path bit-exactly, with 2+ co-resident jobs, including after a
    probe job's arrival and exit forced live replan migrations."""
    rt_masked = _drive(_runtime(update_mode="masked"),
                       update_mode="masked")
    rt_fused = _drive(_runtime(fused_kernel=True), fused_kernel=True)
    assert rt_masked.n_replans == rt_fused.n_replans >= 2
    for name in ("flat", "mu", "nu"):
        np.testing.assert_array_equal(np.asarray(rt_masked.state[name]),
                                      np.asarray(rt_fused.state[name]))


def test_block_step_matches_masked_semantics():
    """The unfused block program is semantically identical to the masked
    one: eager-vs-eager is bit-exact; the jitted programs may differ by
    XLA's per-program-shape fusion rounding (~1 ulp), never more."""
    rt_eager_masked = _drive(_runtime(jit=False, update_mode="masked"),
                             replan=False)
    rt_eager_block = _drive(_runtime(jit=False), replan=False)
    for name in ("flat", "mu", "nu"):
        np.testing.assert_array_equal(
            np.asarray(rt_eager_masked.state[name]),
            np.asarray(rt_eager_block.state[name]))

    rt_jit_masked = _drive(_runtime(update_mode="masked"),
                           update_mode="masked")
    rt_jit_block = _drive(_runtime())
    assert rt_jit_masked.n_replans == rt_jit_block.n_replans >= 2
    for name in ("flat", "mu", "nu"):
        np.testing.assert_allclose(np.asarray(rt_jit_masked.state[name]),
                                   np.asarray(rt_jit_block.state[name]),
                                   rtol=1e-6, atol=1e-6)


def test_block_step_isolates_co_resident_jobs():
    """A block-owned step must not touch a single lane outside the job's
    owned blocks -- checked on the raw buffers, not just the tensors."""
    rt = _runtime()
    plan, before = rt.plan, {
        k: np.asarray(rt.state[k]) for k in ("flat", "mu", "nu")}
    own = plan.job_layout("a").own_idx
    outside = np.setdiff1d(np.arange(plan.total_len), own)
    for _ in range(3):
        rt.step("a", {"target": TARGETS["a"]})
    for k in ("flat", "mu", "nu"):
        np.testing.assert_array_equal(np.asarray(rt.state[k])[outside],
                                      before[k][outside])


# --------------------------------------------------- block-owned Pallas kernel
@pytest.mark.parametrize("workers", [0, 4])
def test_block_kernel_matches_ref(workers):
    """aggregate_adam_blocks == gather + dense reference on owned blocks."""
    block, n_blocks = 8, 12
    n = block * n_blocks
    block_idx = np.array([1, 2, 5, 9, 10], np.int32)
    m = block_idx.size * block
    key = jax.random.PRNGKey(0)
    p = jax.random.normal(key, (n,))
    mu = jnp.abs(jax.random.normal(jax.random.PRNGKey(1), (n,))) * 0.1
    nu = jnp.abs(jax.random.normal(jax.random.PRNGKey(2), (n,))) * 0.01
    gshape = (workers, m) if workers else (m,)
    g = jax.random.normal(jax.random.PRNGKey(3), gshape)
    cnt = jnp.array(5, jnp.int32)
    kw = dict(lr=1e-2, b1=0.9, b2=0.999, eps=1e-8, wd=0.01)
    out_k = agg_ops.block_adam_update(p, g, mu, nu, cnt,
                                      block_idx=block_idx, block=block, **kw)
    out_r = agg_ref.aggregate_adam_blocks_ref(p, g, mu, nu, cnt, block_idx,
                                              block=block, **kw)
    for a, b in zip(out_k, out_r):
        assert a.shape == (m,)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-6)


# ------------------------------------------------------ plan access structures
def test_job_layout_blocks_are_exclusive_and_cover_payload():
    rt = _runtime()
    plan = rt.plan
    assert plan.block_align == 8
    owners = {}
    for jid in plan.job_ids:
        lay = plan.job_layout(jid)
        assert lay.packed_len == lay.blocks.size * lay.block
        assert lay.packed_len >= lay.payload_elements
        # Owned blocks cover every payload lane of the job...
        payload = plan.payload_index(jid)
        assert np.isin(payload, lay.own_idx).all()
        # ...and no block is claimed by two jobs.
        for b in lay.blocks:
            assert b not in owners, (b, jid, owners[b])
            owners[b] = jid


def test_job_layout_rejects_non_exclusive_blocks():
    rt = _runtime()
    plan = rt.plan
    # At block = shard_len every shard hosts both jobs -> not exclusive.
    with pytest.raises(ValueError, match="not block-exclusive"):
        plan.job_layout("a", block=plan.shard_len)
    with pytest.raises(ValueError, match="does not divide"):
        plan.job_layout("a", block=plan.shard_len - 1)
    with pytest.raises(ValueError, match="no segments"):
        plan.job_layout("nope")


def test_packed_pull_roundtrips_through_slots():
    rt = _runtime()
    plan = rt.plan
    for jid, tree in TREES.items():
        lay = plan.job_layout(jid)
        packed = np.asarray(rt.state["flat"])[lay.own_idx]
        for key, start, size, shape, _ in lay.slots:
            np.testing.assert_array_equal(
                packed[start:start + size].reshape(shape),
                np.asarray(tree[key]))


# ------------------------------------------------------------- elastic caching
def test_migrate_same_plan_is_identity_and_cached():
    rt = _runtime()
    plan, state = rt.plan, rt.state
    # Equal plans: the state object passes through untouched.
    assert migrate_flat_state(state, plan, plan) is state

    svc2 = ParameterService(total_budget=16, n_clusters=1, plan_pad_to=8)
    rt2 = ServiceRuntime(svc2)
    for jid in reversed(list(TREES)):  # reversed order -> relocated layout
        tree = TREES[jid]
        nbytes = sum(4 * v.size for v in tree.values())
        rt2.add_job(jid, tree, _quad_loss, lr=0.05, required_servers=2,
                    agg_throughput=nbytes / 0.45)
    plan_b = rt2.plan
    assert plan_b != plan
    clear_plan_cache()
    before = plan_cache_stats()
    migrate_flat_state(state, plan, plan_b)
    migrate_flat_state(state, plan, plan_b)
    after = plan_cache_stats()
    assert after["misses"] - before["misses"] == 1
    assert after["hits"] - before["hits"] >= 1


# ------------------------------------------------------------------ satellites
def test_remove_job_unknown_id_raises_and_leaves_state_untouched():
    rt = _runtime()
    plan, counts = rt.plan, dict(rt.state["counts"])
    with pytest.raises(ValueError, match="unknown job 'nope'"):
        rt.remove_job("nope")
    assert rt.job_ids == ("a", "b")
    assert rt.plan is plan
    assert set(rt.state["counts"]) == set(counts)
    # Both jobs still step fine afterwards.
    for jid in TREES:
        m = rt.step(jid, {"target": TARGETS[jid]})
        assert np.isfinite(float(m["loss"]))


def test_init_shared_state_needs_ef_flag():
    rt = _runtime()
    assert "ef" not in init_shared_state(rt.plan)
    assert "ef" in init_shared_state(rt.plan, needs_ef=True)


# ------------------------------------------------------------ O(1) HLO claims
def _hlo_op_count(text: str) -> int:
    return sum(1 for line in text.splitlines() if " = " in line)


def _shared_plan_and_state(n_jobs, pad_to=8):
    """n_jobs quad jobs in one service; returns (plan, state, trees)."""
    svc = ParameterService(total_budget=64, n_clusters=1, plan_pad_to=pad_to)
    trees = {f"j{i}": _tree(jax.random.PRNGKey(i), (24, 9, 40))
             for i in range(n_jobs)}
    from repro.ps.runtime import job_profile_from_tree

    for jid, tree in trees.items():
        nbytes = sum(4 * v.size for v in tree.values())
        profile, specs = job_profile_from_tree(
            jid, tree, required_servers=2, agg_throughput=nbytes / 0.4)
        svc.register_job(profile, specs=specs)
    plan = svc.compile_plan()
    state = init_shared_state(plan)
    for jid, tree in trees.items():
        state = seed_job_params(plan, state, jid, tree)
    return plan, state, trees


def test_block_step_hlo_ops_constant_in_co_resident_jobs():
    """Tentpole acceptance: the per-job step's HLO op count must not grow
    with the number of co-resident jobs/segments sharing the space (the
    masked path grows by ~3 ops per extra segment; the block path's op
    count only wobbles a few ops with XLA's size-dependent lowering)."""
    counts = {}
    for n_jobs in (2, 4, 8):
        plan, state, trees = _shared_plan_and_state(n_jobs)
        abstract = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), trees["j0"])
        step = make_ps_train_step(_quad_loss, plan, abstract, lr=0.05,
                                  job_id="j0")
        batch = {"target": jax.tree_util.tree_map(
            lambda p: p * 0 + 1.0, trees["j0"])}
        text = jax.jit(step).lower(state, batch).compile().as_text()
        counts[n_jobs] = _hlo_op_count(text)
    # 2 -> 8 co-resident jobs quadruples the segment count; the fixed job's
    # step op count may wobble a few ops with XLA's size-dependent
    # lowering but must not grow with it.
    assert counts[8] <= 1.05 * counts[2], counts
    assert counts[4] <= 1.05 * counts[2], counts


def test_flatten_op_count_independent_of_co_residents():
    """flatten's concatenate takes O(job segments + shards) operands --
    consecutive foreign lanes merge into one zero chunk -- so its HLO op
    count does not grow with co-resident jobs (the old path emitted one
    chunk per co-resident segment)."""
    counts = {}
    for n_jobs in (2, 4, 8):
        plan, state, trees = _shared_plan_and_state(n_jobs)
        tree = trees["j0"]
        text = jax.jit(
            lambda t, plan=plan: flatten_tree(plan, t, job_id="j0")) \
            .lower(tree).as_text()
        counts[n_jobs] = _hlo_op_count(text)
        # No per-lane scatter anywhere: pure concat of chunks.
        assert text.count('"stablehlo.scatter"') == 0
    # Gap chunks are bounded by the job's runs (one per shard), not by the
    # co-resident segment count: 2 -> 8 jobs adds ~96 segments but at most
    # a couple of chunk ops.
    assert counts[8] <= counts[2] + 4, counts
    assert counts[4] <= counts[2] + 4, counts

    # And the flatten/unflatten pair still round-trips bit-exactly.
    plan, state, trees = _shared_plan_and_state(2)
    tree = trees["j0"]
    abstract = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    back = unflatten_tree(plan, flatten_tree(plan, tree, job_id="j0"),
                          abstract, job_id="j0")
    for k in tree:
        np.testing.assert_array_equal(np.asarray(back[k]),
                                      np.asarray(tree[k]))


def test_block_step_uses_row_gathers_not_per_lane():
    """The block step's pull/write-back are block-structured row gathers/
    scatters (a memcpy per owned block), never per-lane index maps."""
    plan, state, trees = _shared_plan_and_state(2)
    abstract = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), trees["j0"])
    step = make_ps_train_step(_quad_loss, plan, abstract, lr=0.05,
                              job_id="j0")
    batch = {"target": jax.tree_util.tree_map(
        lambda p: p * 0 + 1.0, trees["j0"])}
    text = jax.jit(step).lower(state, batch).as_text()
    lay = plan.job_layout("j0")
    n_rows = lay.blocks.size
    # Row-structured operands appear as (n_rows, block)-shaped tensors.
    assert f"tensor<{n_rows}x{lay.block}xf32>" in text


def test_masked_path_still_respects_segment_mask():
    """Legacy masked path stays available and correct (benchmark baseline)."""
    plan, state, trees = _shared_plan_and_state(2)
    abstract = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), trees["j1"])
    step = jax.jit(make_ps_train_step(
        _quad_loss, plan, abstract, lr=0.05, job_id="j1",
        update_mode="masked"))
    batch = {"target": jax.tree_util.tree_map(
        lambda p: p * 0 + 1.0, trees["j1"])}
    new_state, _ = step(state, batch)
    outside = ~segment_mask(plan, "j1")
    np.testing.assert_array_equal(np.asarray(new_state["flat"])[outside],
                                  np.asarray(state["flat"])[outside])
