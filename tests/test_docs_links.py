"""Docs stay wired: no dead relative links, and the docs/ tree the README
points at actually exists (satellite of the service-tick PR)."""

import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "scripts"))

from check_links import dead_links  # noqa: E402


def test_no_dead_relative_links_in_readme_and_docs():
    assert dead_links(ROOT) == []


def test_docs_tree_exists_and_readme_links_it():
    readme = (ROOT / "README.md").read_text()
    for doc in ("docs/architecture.md", "docs/paper_map.md",
                "docs/benchmarks.md"):
        assert (ROOT / doc).is_file(), doc
        assert doc in readme, f"README does not link {doc}"
