"""Per-architecture smoke tests: reduced same-family configs, one
forward/train step on CPU, asserting output shapes + no NaNs (the FULL
configs are exercised only via the dry-run)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.optim import adagrad, adam

LM_ARCHS = [
    "command-r-plus-104b", "qwen1.5-0.5b", "granite-8b",
    "granite-moe-1b-a400m", "deepseek-v2-236b",
]


def _assert_finite(tree):
    for leaf in jax.tree_util.tree_leaves(tree):
        assert jnp.all(jnp.isfinite(leaf)), "non-finite value in output"


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_train_and_decode(arch):
    from repro.models import transformer as tf

    cfg = registry.get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = tf.init_params(cfg, key)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 32), dtype=np.int32))
    batch = {"tokens": toks, "labels": toks}

    opt = adam(1e-3)
    step = jax.jit(tf.make_train_step(cfg, opt))
    state = {"params": params, "opt": opt.init(params)}
    state, metrics = step(state, batch)
    assert metrics["loss"].shape == ()
    assert jnp.isfinite(metrics["loss"])
    _assert_finite(state["params"])

    serve = jax.jit(tf.make_serve_step(cfg))
    cache = tf.init_kv_cache(cfg, 2, 16)
    logits, cache = serve(state["params"], cache, toks[:, :1])
    assert logits.shape == (2, cfg.vocab)
    _assert_finite(logits)
    assert int(cache["length"]) == 1


def test_gin_smoke_all_tasks():
    from repro.models import gnn
    from repro.data import molecule_batch, random_graph

    cfg = registry.get_smoke_config("gin-tu")
    rng = np.random.default_rng(0)
    params = gnn.init_params(cfg, jax.random.PRNGKey(0))

    g = random_graph(rng, 64, 256, cfg.d_feat, cfg.n_classes)
    opt = adam(1e-3)
    step = jax.jit(gnn.make_train_step(cfg, opt))
    state = {"params": params, "opt": opt.init(params)}
    state, m = step(state, {k: jnp.asarray(v) for k, v in g.items()})
    assert jnp.isfinite(m["loss"])

    gcfg = dataclasses.replace(cfg, task="graph")
    gparams = gnn.init_params(gcfg, jax.random.PRNGKey(1))
    mb = molecule_batch(rng, 8, 10, 20, cfg.d_feat, cfg.n_classes)
    loss = gnn.loss_fn(gcfg, gparams, {k: jnp.asarray(v) for k, v in mb.items()})
    assert jnp.isfinite(loss)


def test_gin_minibatch_sampler_pipeline():
    from repro.models import gnn
    from repro.data import NeighborSampler, random_graph

    cfg = registry.get_smoke_config("gin-tu")
    rng = np.random.default_rng(0)
    g = random_graph(rng, 500, 4000, cfg.d_feat, cfg.n_classes)
    sampler = NeighborSampler(g["edge_src"], g["edge_dst"], 500, fanouts=(5, 3))
    block = sampler.sample(np.arange(16))
    batch = sampler.make_batch(block, g["feats"], g["labels"])
    assert batch["feats"].shape[0] == sampler.max_sizes(16)[0]
    loss = gnn.loss_fn(
        cfg,
        gnn.init_params(cfg, jax.random.PRNGKey(0)),
        {k: jnp.asarray(v) for k, v in batch.items()},
    )
    assert jnp.isfinite(loss)


@pytest.mark.parametrize("arch", ["dlrm-rm2", "dlrm-mlperf"])
def test_dlrm_smoke(arch):
    from repro.models import recsys
    from repro.data import recsys_batch

    cfg = registry.get_smoke_config(arch)
    rng = np.random.default_rng(0)
    params = recsys.dlrm_init(cfg, jax.random.PRNGKey(0))
    batch = recsys_batch(rng, 16, cfg.n_dense, cfg.vocab_sizes)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}

    opt = adagrad(0.01)
    step = jax.jit(recsys.make_train_step(
        lambda p, b: recsys.dlrm_loss(cfg, p, b), opt))
    state = {"params": params, "opt": opt.init(params)}
    state, m = step(state, batch)
    assert jnp.isfinite(m["loss"])

    logits = recsys.dlrm_forward(cfg, state["params"], batch["dense"], batch["sparse"])
    assert logits.shape == (16,)
    _assert_finite(logits)

    scores = recsys.dlrm_retrieval(
        cfg, state["params"], batch["dense"][:1], batch["sparse"][:1, :-1],
        jnp.arange(32) % cfg.vocab_sizes[-1])
    assert scores.shape == (32,)


def test_sasrec_smoke():
    from repro.models import recsys
    from repro.data import sasrec_batch

    cfg = registry.get_smoke_config("sasrec")
    rng = np.random.default_rng(0)
    params = recsys.sasrec_init(cfg, jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v) for k, v in
             sasrec_batch(rng, 8, cfg.seq_len, cfg.n_items).items()}
    opt = adam(1e-3)
    step = jax.jit(recsys.make_train_step(
        lambda p, b: recsys.sasrec_loss(cfg, p, b), opt))
    state = {"params": params, "opt": opt.init(params)}
    state, m = step(state, batch)
    assert jnp.isfinite(m["loss"])
    scores = recsys.sasrec_retrieval(cfg, state["params"], batch["seq"], jnp.arange(64))
    assert scores.shape == (8, 64)
    _assert_finite(scores)


def test_dien_smoke():
    from repro.models import recsys
    from repro.data import dien_batch

    cfg = registry.get_smoke_config("dien")
    rng = np.random.default_rng(0)
    params = recsys.dien_init(cfg, jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v) for k, v in
             dien_batch(rng, 8, cfg.seq_len, cfg.n_items, cfg.n_cats).items()}
    opt = adam(1e-3)
    step = jax.jit(recsys.make_train_step(
        lambda p, b: recsys.dien_loss(cfg, p, b), opt))
    state = {"params": params, "opt": opt.init(params)}
    state, m = step(state, batch)
    assert jnp.isfinite(m["loss"])
    scores = recsys.dien_retrieval(
        cfg, state["params"], batch["hist_items"][0], batch["hist_cats"][0],
        jnp.arange(16), jnp.zeros(16, jnp.int32))
    assert scores.shape == (16,)


def test_registry_covers_all_assigned_archs():
    assert sorted(registry.ARCHS) == sorted([
        "command-r-plus-104b", "qwen1.5-0.5b", "granite-8b",
        "granite-moe-1b-a400m", "deepseek-v2-236b", "gin-tu",
        "dlrm-rm2", "sasrec", "dien", "dlrm-mlperf",
    ])
    for arch in registry.ARCHS:
        spec = registry._module(arch).spec()
        assert len(spec.cells) == 4  # 10 archs x 4 shapes = 40 cells
