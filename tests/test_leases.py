"""Job leases (PR 9): every push/pull renews, an injected clock drives
deterministic expiry, and a silent trainer is reclaimed gracefully --
queued futures cancelled with a contextual error, the job removed
through the TRANSACTIONAL replan path, and the freed load visible to the
autoscaler.
"""

import math

import jax
import jax.numpy as jnp
import pytest

from repro.core import ParameterService
from repro.ps.autoscaler import AutoscalerConfig, ElasticScaler
from repro.ps.faults import (
    EngineQuarantinedError,
    FaultInjector,
    LeaseExpiredError,
    ReplanAbortedError,
    RetryPolicy,
)
from repro.ps.service_runtime import ServiceRuntime, ShardedServiceRuntime


class Clock:
    def __init__(self, now=0.0):
        self.now = float(now)

    def __call__(self):
        return self.now


def _tree(key, sizes):
    ks = jax.random.split(key, len(sizes))
    return {f"t{i}": jax.random.normal(k, (n,))
            for i, (k, n) in enumerate(zip(ks, sizes))}


def _loss(params, batch):
    return sum(jnp.sum((params[k] - batch["target"][k]) ** 2)
               for k in params)


TREES = {
    "a": _tree(jax.random.PRNGKey(0), (48, 16, 32)),
    "b": _tree(jax.random.PRNGKey(1), (32, 16)),
    "c": _tree(jax.random.PRNGKey(2), (48, 16)),
}
TARGETS = {j: jax.tree_util.tree_map(lambda p: p * 0 + 1.0, t)
           for j, t in TREES.items()}


def _add_jobs(rt, trees=TREES):
    for jid, t in trees.items():
        nbytes = sum(4 * v.size for v in t.values())
        rt.add_job(jid, t, _loss, lr=0.05, required_servers=1,
                   agg_throughput=nbytes / 0.2)


def _sharded(n_shards=2, trees=TREES, **engine_opts):
    svc = ParameterService(total_budget=16, n_clusters=1, plan_pad_to=16)
    rt = ShardedServiceRuntime(svc, jit=False)
    engine_opts.setdefault("max_staleness", 0)
    eng = rt.attach_engine(jit=False, **engine_opts)
    _add_jobs(rt, trees)
    if n_shards > 1:
        svc.scale_out(n_shards - 1)
    return rt, eng


def _flat(trees=TREES, **engine_opts):
    rt = ServiceRuntime(
        ParameterService(total_budget=16, n_clusters=1, plan_pad_to=16),
        jit=False)
    engine_opts.setdefault("max_staleness", 0)
    eng = rt.attach_engine(jit=False, **engine_opts)
    _add_jobs(rt, trees)
    return rt, eng


def _grads(job):
    return jax.tree_util.tree_map(jnp.ones_like, TREES[job])


# ---------------------------------------------------------------- renewal
@pytest.mark.parametrize("build", [_flat, _sharded], ids=["flat", "sharded"])
def test_pushes_and_pulls_renew_the_lease(build):
    clock = Clock()
    rt, eng = build(lease_interval=5.0, clock=clock)
    assert eng.lease_deadline("a") is None  # no contact yet
    eng.step("a", {"target": TARGETS["a"]})
    assert eng.lease_deadline("a") == pytest.approx(5.0)
    clock.now = 3.0
    eng.pull("a")
    assert eng.lease_deadline("a") == pytest.approx(8.0)
    clock.now = 4.0
    fut = eng.submit_push("a", _grads("a"))
    assert eng.lease_deadline("a") == pytest.approx(9.0)
    eng.drain()
    assert fut.done()
    # An active trainer never expires.
    clock.now = 8.9
    assert eng.expire_leases() == ()
    assert "a" in rt._jobs


@pytest.mark.parametrize("build", [_flat, _sharded], ids=["flat", "sharded"])
def test_silent_trainer_is_reclaimed_through_the_replan_path(build):
    clock = Clock()
    rt, eng = build(lease_interval=2.0, clock=clock)
    for j in TREES:
        eng.step(j, {"target": TARGETS[j]})
    eng.drain()
    # a and b keep renewing; c goes silent.
    for t in (1.0, 2.0, 3.0):
        clock.now = t
        eng.step("a", {"target": TARGETS["a"]})
        eng.step("b", {"target": TARGETS["b"]})
        assert eng.expire_leases() == (("c",) if t == 2.0 else ())
    assert eng.stats.n_lease_expirations == 1
    assert "c" not in rt._jobs
    assert "c" not in rt.service._jobs
    assert eng.lease_deadline("c") is None
    if isinstance(rt, ShardedServiceRuntime):
        assert rt.service.compile_sharded_plan() == rt.splan
    # Survivors train on.
    eng.step("a", {"target": TARGETS["a"]})
    eng.drain()


def test_lease_interval_validated_and_off_by_default():
    rt, eng = _sharded()
    assert eng.lease_interval is None
    assert eng.expire_leases() == ()  # no-op with leases off
    with pytest.raises(ValueError):
        _sharded(lease_interval=0.0)


# ------------------------------------------------- graceful cancellation
def test_expired_jobs_queued_futures_raise_lease_expired():
    clock = Clock()
    rt, eng = _sharded(max_staleness=8, lease_interval=2.0, clock=clock)
    fut = eng.submit_push("c", _grads("c"))
    clock.now = 5.0
    assert eng.expire_leases() == ("c",)
    assert fut.cancelled() and not fut.done()
    with pytest.raises(LeaseExpiredError) as ei:
        fut.result(timeout=1.0)
    assert ei.value.job_id == "c"
    assert "lease" in str(ei.value)
    # Immediate re-raise: the stored error, not a timeout wait.
    with pytest.raises(LeaseExpiredError):
        fut.result(timeout=30.0)


def test_quarantined_lane_future_raises_quarantine_not_timeout():
    """``result(timeout=...)`` is contextual the other way too: a push
    stuck behind a lane that died mid-wait raises that lane's
    ``EngineQuarantinedError`` at the deadline, not a bare timeout."""
    inj = FaultInjector()
    rt, eng = _sharded(max_staleness=8, fault_injector=inj)
    victim = rt.shard_ids[-1]
    job = next(j for j in TREES
               if victim in rt.splan.job_layout(j).shard_ids)
    inj.kill_shard(victim, at=1)
    fut = eng.submit_push(job, _grads(job))
    # Tick until the kill lands (the victim quarantines on its first
    # failed apply + exhausted retry) so the deadline below races
    # nothing; the piece on the dead lane keeps the future pending.
    for _ in range(8):
        if victim in eng.quarantined_shards():
            break
        eng.tick()
    assert victim in eng.quarantined_shards()
    assert not fut.done()
    with pytest.raises(EngineQuarantinedError) as ei:
        fut.result(timeout=0.3)
    assert ei.value.shard_id == victim


def test_reclaim_frees_load_the_autoscaler_sees():
    clock = Clock()
    rt, eng = _sharded(max_staleness=64, lease_interval=2.0, clock=clock)
    scaler = ElasticScaler(rt, AutoscalerConfig(
        shard_capacity=4.0, max_shards=4, cooldown=1))
    for _ in range(8):
        eng.submit_push("c", _grads("c"))
    assert scaler.queued_pieces() > 0
    clock.now = 5.0
    assert eng.expire_leases() == ("c",)
    # The dead trainer's queued pieces are gone with it: the drain
    # occupancy half of the load signal drops to zero, so the next
    # window scales from the survivors' (idle) load alone.
    assert scaler.queued_pieces() == 0
    decision = scaler.observe()
    assert decision.action in ("hold", "shrink")


def test_failed_reclaim_rearms_the_lease_and_retries():
    clock = Clock()
    inj = FaultInjector()
    rt, eng = _sharded(n_shards=2, lease_interval=2.0, clock=clock,
                       fault_injector=inj,
                       retry_policy=RetryPolicy(max_retries=0))
    for j in TREES:
        eng.step(j, {"target": TARGETS[j]})
    eng.drain()
    inj.fail_migration(at=1, times=math.inf)
    clock.now = 5.0
    with pytest.raises(ReplanAbortedError):
        eng.expire_leases()
    # The job leaked nowhere: still registered on BOTH planes, lease
    # re-armed one interval out so the next sweep tries again.
    for j in TREES:
        assert j in rt._jobs and j in rt.service._jobs
    assert eng.lease_deadline("a") == pytest.approx(7.0)
    assert rt.service.compile_sharded_plan() == rt.splan
    inj.rules.clear()
    clock.now = 8.0
    assert set(eng.expire_leases()) == set(TREES)
    assert not rt._jobs and not rt.service._jobs
