"""Unit tests for the trip-weighted HLO cost model (the roofline's source)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_cost import HloCostModel, analyze


def _compiled_text(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


def test_scan_flops_multiplied_by_trip_count():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, None, length=10)
        return out

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    cost = analyze(_compiled_text(f, x, w))
    assert cost.flops == pytest.approx(10 * 2 * 128 * 256 * 256)


def test_nested_scan_flops():
    def g(x, w):
        def outer(c, _):
            def inner(c2, _):
                return jnp.tanh(c2 @ w), None
            c, _ = jax.lax.scan(inner, c, None, length=5)
            return c, None
        out, _ = jax.lax.scan(outer, x, None, length=4)
        return out

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    cost = analyze(_compiled_text(g, x, w))
    assert cost.flops == pytest.approx(20 * 2 * 64 * 128 * 128)


def test_plain_matmul_flops_and_bytes():
    def f(a, b):
        return a @ b

    a = jax.ShapeDtypeStruct((64, 32), jnp.float32)
    b = jax.ShapeDtypeStruct((32, 16), jnp.float32)
    cost = analyze(_compiled_text(f, a, b))
    assert cost.flops == pytest.approx(2 * 64 * 32 * 16)
    # operands + result, give or take copies
    min_bytes = (64 * 32 + 32 * 16 + 64 * 16) * 4
    assert cost.bytes >= min_bytes


def test_dynamic_slice_counted_at_slice_size():
    """Scan xs-indexing must not charge the full stacked tensor per trip."""
    def f(stack):
        def body(acc, row):
            return acc + jnp.sum(row), None
        out, _ = jax.lax.scan(body, jnp.zeros(()), stack)
        return out

    stack = jax.ShapeDtypeStruct((64, 1024, 32), jnp.float32)
    cost = analyze(_compiled_text(f, stack))
    full = 64 * 1024 * 32 * 4
    # Traffic should be O(one pass over the stack), not O(trips x stack).
    assert cost.bytes < 10 * full, cost.bytes


def test_tuple_shape_lines_parse():
    """Tuple results with /*index=N*/ comments (the historical parser bug)."""
    def f(x):
        def body(c, _):
            a, b, d, e, g, h, i = c
            return (a + 1, b * 2, d - 1, e, g, h, jnp.tanh(i @ i)), None
        init = tuple(jnp.ones((4,)) * x[0] for _ in range(6)) + (
            jnp.ones((8, 8)) * x[0],)
        out, _ = jax.lax.scan(body, init, None, length=3)
        return sum(o.sum() for o in out)  # keep every carry element alive

    cost = analyze(_compiled_text(f, jax.ShapeDtypeStruct((1,), jnp.float32)))
    assert cost.flops == pytest.approx(3 * 2 * 8 * 8 * 8)
