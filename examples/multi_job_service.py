"""Multi-job elastic aggregation: two real JAX training jobs sharing one
PS-mode data plane, with a live tensor migration between steps.

Job A (an MLP regressor) and job B (a small LM) both train through the
flat-PS runtime (pull -> compute -> push -> aggregate). Mid-run, job A's
tensors are migrated to a different owner layout (balanced vs round-robin)
WITHOUT stopping training -- losses keep decreasing across the migration,
demonstrating the paper's zero-interruption reassignment on the data plane.

Run: PYTHONPATH=src python examples/multi_job_service.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.ps.elastic import migrate_flat_state, migration_bytes
from repro.ps.runtime import (
    build_flat_plan,
    init_ps_state,
    make_ps_train_step,
    unflatten_tree,
)

rng = np.random.default_rng(0)


# ----------------------------------------------------------- job A: MLP
def mlp_init(key):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w1": jax.random.normal(k1, (16, 64)) / 4.0, "b1": jnp.zeros(64),
        "w2": jax.random.normal(k2, (64, 64)) / 8.0, "b2": jnp.zeros(64),
        "w3": jax.random.normal(k3, (64, 1)) / 8.0, "b3": jnp.zeros(1),
    }


def mlp_loss(params, batch):
    h = jnp.tanh(batch["x"] @ params["w1"] + params["b1"])
    h = jnp.tanh(h @ params["w2"] + params["b2"])
    pred = (h @ params["w3"] + params["b3"])[:, 0]
    return jnp.mean((pred - batch["y"]) ** 2)


def mlp_batch():
    x = rng.standard_normal((64, 16)).astype(np.float32)
    y = np.sin(x.sum(1))
    return {"x": jnp.asarray(x), "y": jnp.asarray(y)}


# ------------------------------------------------------------ job B: tiny LM
from repro.models import transformer as tf  # noqa: E402

lm_cfg = tf.LMConfig(name="tiny", n_layers=2, d_model=64, n_heads=4,
                     n_kv_heads=2, d_ff=128, vocab=256, loss_chunk=16,
                     tie_embeddings=True)
corpus = rng.integers(0, 256, size=(32, 32), dtype=np.int32)


def lm_batch():
    toks = jnp.asarray(corpus[rng.integers(0, 32, size=8)])
    return {"tokens": toks, "labels": toks}


def lm_loss(params, batch):
    return tf.loss_fn(lm_cfg, params, batch)


# ------------------------------------------------- register both with the PS
jobs = {}
for job_id, init, loss, batch_fn in (
    ("mlp", lambda: mlp_init(jax.random.PRNGKey(0)), mlp_loss, mlp_batch),
    ("lm", lambda: tf.init_params(lm_cfg, jax.random.PRNGKey(1)), lm_loss, lm_batch),
):
    params = init()
    plan = build_flat_plan(params, n_shards=4, mode="round_robin")
    state = init_ps_state(plan, params)
    step = jax.jit(make_ps_train_step(loss, plan, params, lr=3e-3),
                   donate_argnums=(0,))
    jobs[job_id] = dict(params=params, plan=plan, state=state, step=step,
                        loss=loss, batch=batch_fn)

print(f"{'step':>4s} {'mlp loss':>10s} {'lm loss':>10s}")
for i in range(60):
    if i == 30:
        # Tensor migration for the MLP job: round-robin -> balanced owners.
        j = jobs["mlp"]
        new_plan = build_flat_plan(j["params"], n_shards=4, mode="balanced")
        moved = migration_bytes(j["plan"], new_plan)
        j["state"] = migrate_flat_state(j["state"], j["plan"], new_plan)
        j["step"] = jax.jit(
            make_ps_train_step(j["loss"], new_plan, j["params"], lr=3e-3),
            donate_argnums=(0,))
        j["plan"] = new_plan
        print(f"-- migrated mlp owner layout ({moved / 1e3:.1f} kB moved), "
              f"training continues --")
    losses = {}
    for job_id, j in jobs.items():
        j["state"], m = j["step"](j["state"], j["batch"]())
        losses[job_id] = float(m["loss"])
    if i % 10 == 0 or i == 59:
        print(f"{i:4d} {losses['mlp']:10.4f} {losses['lm']:10.4f}")

print("both jobs trained through the shared aggregation service; "
      "the mid-run migration did not interrupt either.")
