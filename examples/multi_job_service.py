"""Multi-job elastic aggregation: two real JAX training jobs sharing ONE
PS-mode flat aggregation space, surviving live replans.

Job A (an MLP regressor) and job B (a small LM) register with a single
ParameterService; its compiled ServicePlan lays both jobs' tensors into one
shared flat state (ServiceRuntime), and both train through the SERVICE-TICK
ENGINE: each step submits its push into the job's bounded queue, and the
engine applies all pending jobs' pushes per tick in one batched pass over
the shared space (bounded staleness: a job may run max_staleness steps
ahead before its pull blocks on the tick).  Mid-run a third job arrives
and later exits -- both placement changes quiesce the engine (drain every
queued push against the old layout), recompile the plan, and migrate
everyone's Adam state WITHOUT stopping training: losses keep decreasing
across the migrations, demonstrating the paper's zero-interruption elastic
reassignment end to end (control-plane packing -> ServicePlan -> shared
data plane -> batched service ticks).

Run: PYTHONPATH=src python examples/multi_job_service.py
"""

import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore_ps_checkpoint, save_ps_checkpoint
from repro.core import ParameterService
from repro.ps.service_runtime import ServiceRuntime

rng = np.random.default_rng(0)


# ----------------------------------------------------------- job A: MLP
def mlp_init(key, d_in=16):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w1": jax.random.normal(k1, (d_in, 64)) / 4.0, "b1": jnp.zeros(64),
        "w2": jax.random.normal(k2, (64, 64)) / 8.0, "b2": jnp.zeros(64),
        "w3": jax.random.normal(k3, (64, 1)) / 8.0, "b3": jnp.zeros(1),
    }


def mlp_loss(params, batch):
    h = jnp.tanh(batch["x"] @ params["w1"] + params["b1"])
    h = jnp.tanh(h @ params["w2"] + params["b2"])
    pred = (h @ params["w3"] + params["b3"])[:, 0]
    return jnp.mean((pred - batch["y"]) ** 2)


def make_mlp_batches(d_in=16, n=256):
    """Sample minibatches from a fixed pool so the loss curve is a clean
    optimization signal (fresh random data every step would dominate it)."""
    x = rng.standard_normal((n, d_in)).astype(np.float32)
    y = np.sin(x.sum(1))

    def batch():
        sel = rng.integers(0, n, size=64)
        return {"x": jnp.asarray(x[sel]), "y": jnp.asarray(y[sel])}

    return batch


# ------------------------------------------------------------ job B: tiny LM
from repro.models import transformer as tf  # noqa: E402

lm_cfg = tf.LMConfig(name="tiny", n_layers=2, d_model=64, n_heads=4,
                     n_kv_heads=2, d_ff=128, vocab=256, loss_chunk=16,
                     tie_embeddings=True)
corpus = rng.integers(0, 256, size=(32, 32), dtype=np.int32)


def lm_batch():
    toks = jnp.asarray(corpus[rng.integers(0, 32, size=8)])
    return {"tokens": toks, "labels": toks}


def lm_loss(params, batch):
    return tf.loss_fn(lm_cfg, params, batch)


def _throughput(params, busy=0.45):
    """Aggregation throughput making this job occupy `busy` CPU-seconds per
    iteration, so the control plane's packing decisions are non-trivial."""
    nbytes = sum(4 * int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(params))
    return nbytes / busy


# ------------------------------------------- ONE service, ONE shared space
svc = ParameterService(total_budget=16, n_clusters=1, plan_pad_to=128)
rt = ServiceRuntime(svc)
# Batched service ticks: every job one step ahead at most; each tick
# applies all pending jobs' pushes in ONE fused pass.
eng = rt.attach_engine(max_staleness=1)

mlp_params = mlp_init(jax.random.PRNGKey(0))
rt.add_job("mlp", mlp_params, mlp_loss, required_servers=2, lr=3e-3,
           agg_throughput=_throughput(mlp_params))
lm_params = tf.init_params(lm_cfg, jax.random.PRNGKey(1))
rt.add_job("lm", lm_params, lm_loss, required_servers=2, lr=1e-3,
           agg_throughput=_throughput(lm_params))

batches = {"mlp": make_mlp_batches(), "lm": lm_batch}
print(f"plan: {rt.plan.n_shards} shards x {rt.plan.shard_len} elements, "
      f"{len(rt.plan.segments)} segments from jobs {list(rt.plan.job_ids)}")

print(f"{'step':>4s} {'mlp loss':>10s} {'lm loss':>10s} {'probe loss':>11s}")
for i in range(60):
    if i == 20:
        # A third job arrives: the service replans, every resident job's
        # segments migrate onto the new layout, training never stops.
        probe_params = mlp_init(jax.random.PRNGKey(7), d_in=8)
        rt.add_job("probe", probe_params, mlp_loss, required_servers=1,
                   lr=3e-3, agg_throughput=_throughput(probe_params, busy=0.6))
        batches["probe"] = make_mlp_batches(d_in=8)
        print(f"-- probe job arrived: replanned to {rt.plan.n_shards} shards "
              f"({rt.last_migration_bytes / 1e3:.1f} kB migrated) --")
    if i == 40:
        # ... and exits: freed Aggregators are recycled, survivors' tensors
        # consolidate (another live migration).
        rt.remove_job("probe")
        batches.pop("probe")
        print(f"-- probe job exited: replanned to {rt.plan.n_shards} shards "
              f"({rt.last_migration_bytes / 1e3:.1f} kB migrated) --")
    losses = {jid: float(eng.step(jid, fn())["loss"])
              for jid, fn in batches.items()}
    if i % 10 == 0 or i == 59:
        probe = f"{losses['probe']:11.4f}" if "probe" in losses else f"{'-':>11s}"
        print(f"{i:4d} {losses['mlp']:10.4f} {losses['lm']:10.4f} {probe}")

eng.drain()  # settle every queued push before checkpointing

# A checkpoint taken under one packing restores under another.
with tempfile.TemporaryDirectory() as d:
    save_ps_checkpoint(d, 59, rt.plan, rt.state)
    svc.periodic_rebalance()
    _, restored = restore_ps_checkpoint(d, 59, plan=rt.plan)
    np.testing.assert_array_equal(np.asarray(restored["flat"]),
                                  np.asarray(rt.state["flat"]))
print(f"both jobs trained through ONE shared aggregation space across "
      f"{rt.n_replans} live replans ({rt.total_migration_bytes / 1e3:.1f} kB "
      f"migrated total); no job was interrupted.")
print(f"service ticks: {eng.stats.n_ticks} batched passes applied "
      f"{eng.stats.n_applied} pushes (mean batch "
      f"{eng.stats.mean_batch:.1f} jobs/tick, "
      f"{eng.stats.n_forced_staleness} pulls blocked on the staleness "
      f"bound)")
