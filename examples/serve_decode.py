"""Batched LM serving with a KV cache (smoke-size granite-8b), reading
its decode weights through the Parameter Service read tier: the model's
parameters are hosted as one service job and pulled -- bit-exact --
from a two-replica ``repro.ps.replica.ReplicaSet`` before decoding.

Run: PYTHONPATH=src python examples/serve_decode.py
"""

import subprocess
import sys

subprocess.run(
    [sys.executable, "-m", "repro.launch.serve", "--arch", "granite-8b",
     "--smoke", "--batch", "4", "--prompt-len", "8", "--gen", "24",
     "--temperature", "0.8", "--replicas", "2"],
    check=True,
)
