"""Batched LM serving with a KV cache (smoke-size granite-8b).

Run: PYTHONPATH=src python examples/serve_decode.py
"""

import subprocess
import sys

subprocess.run(
    [sys.executable, "-m", "repro.launch.serve", "--arch", "granite-8b",
     "--smoke", "--batch", "4", "--prompt-len", "8", "--gen", "24",
     "--temperature", "0.8"],
    check=True,
)
