"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

Exercises the full substrate: synthetic token pipeline, scan-over-layers
transformer, Adam (optionally the fused Pallas aggregation kernel),
checkpoint/restart (kill it mid-run and relaunch: it resumes), loss should
drop markedly from random-init (~ln(vocab)) within a few hundred steps.

Run: PYTHONPATH=src python examples/train_lm_e2e.py [--steps 300]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.data import lm_batch
from repro.models import transformer as tf
from repro.optim import adam

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--batch", type=int, default=16)
ap.add_argument("--seq", type=int, default=128)
ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_e2e")
ap.add_argument("--fused-adam", action="store_true",
                help="route updates through the Pallas agg_adam kernel")
args = ap.parse_args()

# ~100M params: 12L x d=640 x heads 10 (GQA kv=5), vocab 32k, tied.
cfg = tf.LMConfig(
    name="lm-100m", n_layers=12, d_model=640, n_heads=10, n_kv_heads=5,
    d_ff=1708, vocab=32000, tie_embeddings=True, loss_chunk=64,
)
opt = adam(3e-4, fused=args.fused_adam)
step = jax.jit(tf.make_train_step(cfg, opt), donate_argnums=(0,))

print(f"model: {cfg.param_count / 1e6:.1f}M params")

def init_state():
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    return {"params": params, "opt": opt.init(params)}

mgr = CheckpointManager(args.ckpt_dir, save_every=100, keep_last=2)
start = 0
found, restored = mgr.restore_latest(jax.eval_shape(init_state))
if found is not None:
    start, state = found + 1, restored
    print(f"resumed from checkpoint step {found}")
else:
    state = init_state()

rng = np.random.default_rng(0)
# A repeating synthetic corpus so the model can actually fit it (loss drops).
corpus = rng.integers(0, cfg.vocab, size=(64, args.seq), dtype=np.int32)

t0, first_loss = time.time(), None
for i in range(start, args.steps):
    rows = rng.integers(0, corpus.shape[0], size=args.batch)
    toks = jnp.asarray(corpus[rows])
    labels = jnp.concatenate([toks[:, 1:], -jnp.ones((args.batch, 1), jnp.int32)], 1)
    state, m = step(state, {"tokens": toks, "labels": labels})
    mgr.maybe_save(i, state)
    if i % 25 == 0 or i == args.steps - 1:
        loss = float(m["loss"])
        first_loss = loss if first_loss is None else first_loss
        tput = args.batch * args.seq * (i - start + 1) / (time.time() - t0)
        print(f"step={i:4d} loss={loss:.4f} tok/s={tput:,.0f}")
mgr.wait()
print(f"loss: {first_loss:.3f} -> {float(m['loss']):.3f} "
      f"(random-init ~= ln(vocab) = {np.log(cfg.vocab):.2f})")
