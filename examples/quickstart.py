"""Quickstart: the Parameter Service in 60 lines.

1. Profile two training jobs (the paper's VGG19 + AlexNet testbed models).
2. Register them with the shared ParameterService -- watch the packing.
3. See the per-tensor placement an Agent would route by, and what happens
   on job exit (elastic recycle).

Run: PYTHONPATH=src python examples/quickstart.py
"""

from repro.configs.paper_workloads import make_job
from repro.core import ParameterService

svc = ParameterService(total_budget=16, n_clusters=1, loss_limit=0.1)

# A VGG19 job that would need 2 dedicated parameter servers under ps-lite.
vgg = make_job("vgg19", "vgg-0", n_servers=2, n_workers=2)
svc.register_job(vgg)
print(f"vgg-0 registered: {svc.n_aggregators} Aggregators "
      f"(ps-lite would use {vgg.required_servers})")

# An AlexNet job arrives; AutoPS packs it into the same Aggregators.
alex = make_job("alexnet", "alex-0", n_servers=2, n_workers=2)
svc.register_job(alex)
print(f"alex-0 packed:   {svc.n_aggregators} Aggregators "
      f"(ps-lite total would be {vgg.required_servers + alex.required_servers})")
print(f"CPU reduction ratio: {svc.cpu_reduction():.2f}")
print(f"predicted per-job loss: "
      f"{ {k: round(v, 3) for k, v in svc.predicted_losses().items()} }")

# The Agent mapping table (tensor -> Aggregator) for the AlexNet job.
placement = svc.placement("alex-0")
ids = sorted(set(placement.values()))
print(f"alex-0 tensors spread over Aggregators: {ids}")

# Job exit: Aggregators are recycled opportunistically.
svc.job_exit("alex-0")
print(f"alex-0 exited:   {svc.n_aggregators} Aggregators remain")
print(f"utilizations: { {k: round(v, 2) for k, v in svc.utilizations().items()} }")
