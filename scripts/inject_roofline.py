"""Regenerate the EXPERIMENTS.md roofline table from the dry-run JSONs."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.roofline import markdown_table  # noqa: E402

MARK = "<!-- ROOFLINE_TABLE -->"

exp = Path("EXPERIMENTS.md")
text = exp.read_text()
table = markdown_table("pod256")
if MARK in text:
    head, _, tail = text.partition(MARK)
    # Drop any previously injected table (up to the next blank-line+"Reading").
    tail_lines = tail.split("\n")
    idx = 0
    while idx < len(tail_lines) and (
        not tail_lines[idx].strip() or tail_lines[idx].startswith("|")
    ):
        idx += 1
    rest = "\n".join(tail_lines[idx:])
    text = head + MARK + "\n\n" + table + "\n\n" + rest
    exp.write_text(text)
    print(f"injected {len(table.splitlines()) - 2} rows")
else:
    print("marker not found", file=sys.stderr)
    sys.exit(1)
