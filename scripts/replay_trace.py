#!/usr/bin/env python
"""Chaos-soak trace replay CLI (PR 9).

Replays the synthetic Philly-like trace (``repro.sim.trace``) through the
REAL sharded data plane -- ``ShardedServiceRuntime`` + sharded tick
engine + ``ElasticScaler`` + ``FaultInjector`` -- twice:

1. chaos on: seeded apply/migration/kill/drop faults plus a dead trainer
   reclaimed by its lease; every window asserts the control plane and
   the data plane agree on the layout.
2. chaos off: the same replay vs a flat eager twin, bit-exact at s=0.

A per-window read consumer drives one versioned pull per live job, so
the report (and the ``--verbose`` window log) also carries the PR-8 wire
counters -- full vs diff pulls and ``pull_bytes_wire`` -- pricing the
read path through the same chaos.

Exits non-zero if any invariant fails (registry/runtime divergence,
parity violation, lease reclaim slower than one interval, a read path
that drove zero pulls), and seeds ``BENCH_chaos.json`` with the same row
payload shape as ``benchmarks/run.py --json``.

Usage:
    PYTHONPATH=src python scripts/replay_trace.py --smoke
    PYTHONPATH=src python scripts/replay_trace.py --windows 24 \
        --jobs 30 --seed 3 --json BENCH_chaos.json
"""

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="shrink the soak for CI (8 windows, 10 jobs)")
    ap.add_argument("--windows", type=int, default=12,
                    help="replay windows (default 12)")
    ap.add_argument("--jobs", type=int, default=14,
                    help="trace jobs generated (default 14)")
    ap.add_argument("--seed", type=int, default=0,
                    help="trace + fault-schedule seed (default 0)")
    ap.add_argument("--json", default="BENCH_chaos.json", metavar="PATH",
                    help="write benchmark rows here (default "
                         "BENCH_chaos.json; '-' to skip)")
    ap.add_argument("--verbose", action="store_true",
                    help="print the per-window log")
    args = ap.parse_args(argv)
    if args.smoke:
        args.windows, args.jobs = min(args.windows, 8), min(args.jobs, 10)

    from repro.sim.replay import (ReplayConfig, replan_overhead_micro,
                                  report_rows, run_replay)

    print(f"chaos soak: {args.jobs} trace jobs, {args.windows} windows, "
          f"seed {args.seed}")
    chaos = run_replay(ReplayConfig(chaos=True, max_windows=args.windows,
                                    n_jobs=args.jobs, seed=args.seed))
    parity = run_replay(ReplayConfig(chaos=False, parity_twin=True,
                                     max_windows=args.windows,
                                     n_jobs=args.jobs, seed=args.seed))
    micro = replan_overhead_micro(n_cycles=2 if args.smoke else 3)
    if args.verbose:
        for w in chaos["windows"]:
            print("  " + " ".join(f"{k}={v}" for k, v in w.items()))

    rows = report_rows(chaos, parity, micro)
    for name, value, derived in rows:
        print(f'{name},{value},"{derived}"')

    failures = []
    if chaos["registry_divergence_windows"] != 0:
        failures.append(
            f"registry/runtime divergence in "
            f"{chaos['registry_divergence_windows']} window(s)")
    if parity["parity_violations"] != 0:
        failures.append(
            f"{parity['parity_violations']} no-fault parity violation(s) "
            f"vs the flat twin")
    if chaos["dead_window"] is not None:
        lat = chaos["reclaim_latency_windows"]
        if lat is None or lat > int(chaos["lease_interval"]) + 1:
            failures.append(
                f"dead trainer reclaim latency {lat} windows exceeds the "
                f"lease interval ({chaos['lease_interval']})")
    if chaos["n_replan_aborts"] != chaos["n_replan_retries"]:
        failures.append(
            f"{chaos['n_replan_aborts']} replan abort(s) but only "
            f"{chaos['n_replan_retries']} retried -- some replan died "
            f"without recovery")
    if chaos["n_reads"] == 0:
        failures.append(
            "read consumer drove zero versioned pulls -- the soak no "
            "longer prices the pull wire")

    if args.json != "-":
        payload = {"smoke": bool(args.smoke), "modules": ["chaos"],
                   "rows": [{"name": n, "value": v, "derived": d}
                            for n, v, d in rows]}
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
        print(f'json/written,{len(rows)},"{args.json}"')

    if failures:
        for msg in failures:
            print(f"FAIL: {msg}", file=sys.stderr)
        return 1
    print(f"OK: {chaos['n_faults_fired']} faults absorbed, "
          f"{chaos['n_replan_aborts']} replan(s) rolled back and retried, "
          f"dead trainer reclaimed in {chaos['reclaim_latency_windows']} "
          f"window(s), zero divergence, parity bit-exact")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
