"""Docs link checker: fail on dead RELATIVE links in README.md and docs/.

Checks every markdown link/image target that is not an absolute URL or a
pure in-page anchor: the referenced file must exist relative to the file
containing the link (anchors on existing files are accepted; validating
heading anchors is out of scope).

Usage: python scripts/check_links.py [repo_root]
Exit status 1 if any dead link is found (CI gate); also importable --
``dead_links(root)`` returns the offending (file, target) pairs, which is
how tests/test_docs_links.py runs it under pytest.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def _md_files(root: Path):
    yield root / "README.md"
    docs = root / "docs"
    if docs.is_dir():
        yield from sorted(docs.glob("**/*.md"))


def dead_links(root: Path):
    """(markdown file, link target) pairs whose target does not exist."""
    dead = []
    for md in _md_files(root):
        if not md.is_file():
            continue
        for target in LINK_RE.findall(md.read_text()):
            if target.startswith(SKIP_PREFIXES):
                continue
            path = target.split("#", 1)[0]
            if not path:  # pure anchor
                continue
            if not (md.parent / path).exists():
                dead.append((str(md.relative_to(root)), target))
    return dead


def main(argv=None) -> int:
    root = Path(argv[0]) if argv else Path(__file__).resolve().parents[1]
    dead = dead_links(root)
    for md, target in dead:
        print(f"DEAD LINK: {md}: ({target})")
    if dead:
        return 1
    n = sum(1 for _ in _md_files(root))
    print(f"docs links OK across {n} markdown files")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
