import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Perf-iteration lab: build a cell with overrides, compile, report the
roofline terms + top collective sources. Used by the EXPERIMENTS.md section-
Perf hypothesis->change->measure loop.

  PYTHONPATH=src python scripts/perf_lab.py --arch command-r-plus-104b \
      --shape train_4k --microbatches 4 --tag mb4
"""

import argparse
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402

from repro.launch import hlo_cost  # noqa: E402
from repro.launch.cells import build_cell  # noqa: E402
from repro.launch.dryrun import HBM_BW, ICI_BW, PEAK_FLOPS  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--microbatches", type=int)
    ap.add_argument("--model-override", action="append", default=[],
                    help="key=value applied to the model config (repeatable)")
    ap.add_argument("--no-act-shard", action="store_true")
    ap.add_argument("--tag", default="iter")
    ap.add_argument("--top", type=int, default=10)
    ap.add_argument("--out", default="results/perf")
    args = ap.parse_args()

    # Patch overrides into the arch spec's cell before building.
    from repro.configs import registry

    spec = registry._module(args.arch).spec()
    cell_desc = spec.cell(args.shape)
    if args.microbatches is not None:
        cell_desc.run_overrides["n_microbatches"] = args.microbatches
    for kv in args.model_override:
        k, v = kv.split("=", 1)
        try:
            v = json.loads(v)
        except json.JSONDecodeError:
            pass
        cell_desc.model_overrides[k] = v

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    n_chips = 512 if args.multi_pod else 256
    from repro.launch import cells as cells_mod

    if spec.family == "lm":
        cell = cells_mod._lm_cell(spec, cell_desc, mesh)
    elif spec.family == "gnn":
        cell = cells_mod._gnn_cell(spec, cell_desc, mesh)
    else:
        cell = cells_mod._recsys_cell(spec, cell_desc, mesh)
    if args.no_act_shard:
        cell.act_shard = False

    t0 = time.time()
    with mesh:
        compiled = cell.lower().compile()
        hlo = compiled.as_text()
        ma = compiled.memory_analysis()
    cost = hlo_cost.analyze(hlo)
    tc = cost.flops / PEAK_FLOPS
    tm = cost.bytes / HBM_BW
    tx = cost.total_collective / ICI_BW
    bound = max(tc, tm, tx)
    model_t = cell.model_flops_per_step / PEAK_FLOPS / n_chips
    peak_gb = (ma.argument_size_in_bytes + ma.output_size_in_bytes
               + ma.temp_size_in_bytes - ma.alias_size_in_bytes) / 1e9

    rec = {
        "tag": args.tag, "arch": args.arch, "shape": args.shape,
        "compile_s": round(time.time() - t0, 1),
        "t_compute_s": tc, "t_memory_s": tm, "t_collective_s": tx,
        "bound_s": bound, "dominant": max(
            ("compute", tc), ("memory", tm), ("collective", tx),
            key=lambda kv: kv[1])[0],
        "roofline_fraction": model_t / bound if bound else 0.0,
        "peak_gb": peak_gb,
        "collectives_gb": {k: v / 1e9 for k, v in cost.coll_traffic.items()},
    }
    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / f"{args.arch}__{args.shape}__{args.tag}.json").write_text(
        json.dumps(rec, indent=1))

    print(f"[{args.tag}] {args.arch} {args.shape}: "
          f"tc={tc:.2f}s tm={tm:.2f}s tx={tx:.2f}s bound={bound:.2f}s "
          f"dom={rec['dominant']} frac={rec['roofline_fraction']:.2%} "
          f"peak={peak_gb:.1f}GB compile={rec['compile_s']}s")
    print("top collective sources (weighted per-device GB):")
    for tr, kind, shape, mult, name in hlo_cost.top_collectives(hlo, args.top):
        print(f"  {tr / 1e9:9.2f}GB x{mult:5.0f} {kind:14s} {shape:40s} {name}")


if __name__ == "__main__":
    main()
