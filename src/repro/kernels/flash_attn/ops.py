"""Jit'd wrapper: GQA-aware flash attention with interpret fallback."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import kernel as K


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, causal=True, scale=None, interpret=None):
    """q: (B, S, HQ, D); k, v: (B, S, HK, D) (model layout). Expands GQA KV
    heads, transposes to (B, H, S, D), and pads S to the tile size."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    b, sq, hq, d = q.shape
    hk = k.shape[2]
    if hk != hq:
        rep = hq // hk
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    bq = min(K.BQ, sq)
    bk = min(K.BK, kt.shape[2])
    pad_q = (-sq) % bq
    pad_k = (-kt.shape[2]) % bk
    if pad_q:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    out = K.flash_attention(qt, kt, vt, causal=causal, scale=scale,
                            bq=bq, bk=bk, interpret=interpret)
    if pad_q:
        out = out[:, :, :sq]
    return out.transpose(0, 2, 1, 3)
