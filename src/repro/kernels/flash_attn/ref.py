"""Pure-jnp oracle for blockwise attention (MHA layout, fp32 softmax)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, causal: bool = True, scale=None):
    """q,k,v: (B, H, S, D) -> (B, H, S, D). Plain softmax attention."""
    d = q.shape[-1]
    scale = d ** -0.5 if scale is None else scale
    s = jnp.einsum("bhqd,bhkd->bhqk", q * scale, k).astype(jnp.float32)
    if causal:
        sq, sk = q.shape[2], k.shape[2]
        mask = jnp.tril(jnp.ones((sq, sk), bool), k=sk - sq)
        s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v)
