"""Pallas TPU flash attention (forward): blockwise online softmax.

Grid: (B*H, S_q / BQ). Each grid step holds one (BQ, D) query tile in VMEM
and loops over (BK, D) key/value tiles with the online-softmax recurrence --
the (S, S) score matrix never exists in HBM. MXU-aligned tiles: BQ = BK =
128, D in {64, 128, 192, 256}. fp32 accumulators (acc, m, l) live in VMEM
scratch for the duration of a query tile.

Causal masking skips fully-masked KV tiles by bounding the fori_loop at the
query tile's diagonal -- ~2x fewer tiles at long S (the IO-aware scheduling
the TPU build relies on; interp-mode tests validate against ref.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30
BQ = 128
BK = 128


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, *, scale, causal, bk, seq_k):
    bq, d = q_ref.shape
    q = q_ref[...].astype(jnp.float32) * scale
    qi = pl.program_id(1)  # query tile index

    n_kv = seq_k // bk
    if causal:
        # Last KV tile that intersects this query tile's causal frontier.
        hi = jnp.minimum(((qi + 1) * bq + bk - 1) // bk, n_kv)
    else:
        hi = n_kv

    def body(j, carry):
        acc, m, l = carry
        k_tile = pl.load(k_ref, (pl.dslice(j * bk, bk), slice(None)))
        v_tile = pl.load(v_ref, (pl.dslice(j * bk, bk), slice(None)))
        s = q @ k_tile.astype(jnp.float32).T  # (BQ, BK)
        if causal:
            q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l * alpha + jnp.sum(p, axis=1)
        acc = acc * alpha[:, None] + p @ v_tile.astype(jnp.float32)
        return acc, m_new, l_new

    acc0 = jnp.zeros((bq, d), jnp.float32)
    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, hi, body, (acc0, m0, l0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "scale", "bq", "bk", "interpret")
)
def flash_attention(q, k, v, *, causal=True, scale=None, bq=BQ, bk=BK,
                    interpret=False):
    """q,k,v: (B, H, S, D); S % bq == 0 == S % bk. Forward only."""
    b, h, sq, d = q.shape
    sk = k.shape[2]
    assert sq % bq == 0 and sk % bk == 0, (sq, sk, bq, bk)
    scale = d ** -0.5 if scale is None else scale

    qr = q.reshape(b * h, sq, d)
    kr = k.reshape(b * h, sk, d)
    vr = v.reshape(b * h, sk, d)

    kernel = functools.partial(
        _fwd_kernel, scale=scale, causal=causal, bk=bk, seq_k=sk
    )
    out = pl.pallas_call(
        kernel,
        grid=(b * h, sq // bq),
        in_specs=[
            pl.BlockSpec((None, bq, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((None, sk, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((None, sk, d), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, bq, d), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, h, sq, d)
