"""Pure-jnp oracle for the fused aggregation + Adam update."""

from __future__ import annotations

import jax.numpy as jnp


def aggregate_adam_ref(p, grads, mu, nu, count, *, lr, b1=0.9, b2=0.999,
                       eps=1e-8, wd=0.0, grad_scale=None):
    """grads: (W, N) worker pushes (sum-aggregated) or (N,) single gradient.

    Returns (new_p, new_mu, new_nu), computed in fp32, cast back to p.dtype.
    """
    # Worker pushes accumulate in fp32 (matching the kernel's VPU sum).
    if grads.ndim == p.ndim + 1:
        g = grads.astype(jnp.float32).sum(axis=0)
    else:
        g = grads.astype(jnp.float32)
    if grad_scale is not None:
        g = g * grad_scale
    mu = b1 * mu + (1.0 - b1) * g
    nu = b2 * nu + (1.0 - b2) * jnp.square(g)
    t = count.astype(jnp.float32)
    mu_hat = mu / (1.0 - b1 ** t)
    nu_hat = nu / (1.0 - b2 ** t)
    upd = mu_hat / (jnp.sqrt(nu_hat) + eps)
    if wd:
        upd = upd + wd * p.astype(jnp.float32)
    new_p = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
    return new_p, mu, nu


def aggregate_adam_blocks_ref(p, grads, mu, nu, count, block_idx, *, block,
                              lr, b1=0.9, b2=0.999, eps=1e-8, wd=0.0):
    """Oracle for the block-owned kernel: gather the owned blocks of the
    full p/mu/nu buffers, run the dense reference on the packed domain.

    grads is already packed ((M,) or (W, M) with M = len(block_idx)*block);
    returns packed (new_p, new_mu, new_nu)."""
    import numpy as np

    own = (np.asarray(block_idx, np.int64)[:, None] * block
           + np.arange(block)).reshape(-1)
    return aggregate_adam_ref(
        jnp.take(p, own), grads, jnp.take(mu, own), jnp.take(nu, own),
        count, lr=lr, b1=b1, b2=b2, eps=eps, wd=wd)


def aggregate_adam_multijob_ref(p, grads, mu, nu, counts, block_idx,
                                job_sizes, *, block, lr, b1=0.9, b2=0.999,
                                eps=1e-8, wd=0.0):
    """Per-job SEQUENTIAL oracle for the multi-job (service-tick) kernel:
    apply each participating job's block-owned update one after another,
    then concatenate the packed results in block-table order.

    ``block_idx`` concatenates the jobs' owned-block lists (``job_sizes[j]``
    blocks each); ``counts`` is one 1-based step count per job; the scalar
    hyperparameters accept a float or a per-job sequence.  Because blocks
    are exclusive, sequential-vs-batched is a pure execution-order change;
    the outputs must match.
    """
    import numpy as np

    def per_job(val):
        if isinstance(val, (int, float)):
            return [float(val)] * len(job_sizes)
        return [float(v) for v in val]

    lrs, b1s, b2s = per_job(lr), per_job(b1), per_job(b2)
    epss, wds = per_job(eps), per_job(wd)
    outs_p, outs_mu, outs_nu = [], [], []
    off = 0
    for j, nb in enumerate(job_sizes):
        idx = np.asarray(block_idx)[off:off + nb]
        lo, hi = off * block, (off + nb) * block
        off += nb
        gj = grads[..., lo:hi]
        new_p, new_mu, new_nu = aggregate_adam_blocks_ref(
            p, gj, mu, nu, counts[j], idx, block=block, lr=lrs[j],
            b1=b1s[j], b2=b2s[j], eps=epss[j], wd=wds[j])
        outs_p.append(new_p)
        outs_mu.append(new_mu)
        outs_nu.append(new_nu)
    return (jnp.concatenate(outs_p), jnp.concatenate(outs_mu),
            jnp.concatenate(outs_nu))


def aggregate_adam_multijob_fused_ref(p, grads, mu, nu, counts, block_idx,
                                      job_sizes, *, block, lr, b1=0.9,
                                      b2=0.999, eps=1e-8, wd=0.0):
    """Per-job SEQUENTIAL oracle for the fused-scatter (single-launch)
    multi-job kernel: each job's block-owned update is computed against
    the current full buffers and scattered back before the next job runs,
    so the result is what K sequential shard-lane ticks would leave in
    the full buffers.  Block exclusivity makes the order irrelevant --
    the fused one-launch result must match bit-for-bit.

    Returns FULL (new_p, new_mu, new_nu), each shaped like p/mu/nu, with
    every non-owned lane untouched.
    """
    import numpy as np

    def per_job(val):
        if isinstance(val, (int, float)):
            return [float(val)] * len(job_sizes)
        return [float(v) for v in val]

    lrs, b1s, b2s = per_job(lr), per_job(b1), per_job(b2)
    epss, wds = per_job(eps), per_job(wd)
    p, mu, nu = jnp.asarray(p), jnp.asarray(mu), jnp.asarray(nu)
    off = 0
    for j, nb in enumerate(job_sizes):
        idx = np.asarray(block_idx)[off:off + nb]
        lo, hi = off * block, (off + nb) * block
        off += nb
        gj = grads[..., lo:hi]
        new_p, new_mu, new_nu = aggregate_adam_blocks_ref(
            p, gj, mu, nu, counts[j], idx, block=block, lr=lrs[j],
            b1=b1s[j], b2=b2s[j], eps=epss[j], wd=wds[j])
        own = (idx.astype(np.int64)[:, None] * block
               + np.arange(block)).reshape(-1)
        p = p.at[own].set(new_p)
        mu = mu.at[own].set(new_mu)
        nu = nu.at[own].set(new_nu)
    return p, mu, nu
