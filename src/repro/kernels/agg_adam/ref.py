"""Pure-jnp oracle for the fused aggregation + Adam update."""

from __future__ import annotations

import jax.numpy as jnp


def aggregate_adam_ref(p, grads, mu, nu, count, *, lr, b1=0.9, b2=0.999,
                       eps=1e-8, wd=0.0, grad_scale=None):
    """grads: (W, N) worker pushes (sum-aggregated) or (N,) single gradient.

    Returns (new_p, new_mu, new_nu), computed in fp32, cast back to p.dtype.
    """
    # Worker pushes accumulate in fp32 (matching the kernel's VPU sum).
    if grads.ndim == p.ndim + 1:
        g = grads.astype(jnp.float32).sum(axis=0)
    else:
        g = grads.astype(jnp.float32)
    if grad_scale is not None:
        g = g * grad_scale
    mu = b1 * mu + (1.0 - b1) * g
    nu = b2 * nu + (1.0 - b2) * jnp.square(g)
    t = count.astype(jnp.float32)
    mu_hat = mu / (1.0 - b1 ** t)
    nu_hat = nu / (1.0 - b2 ** t)
    upd = mu_hat / (jnp.sqrt(nu_hat) + eps)
    if wd:
        upd = upd + wd * p.astype(jnp.float32)
    new_p = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
    return new_p, mu, nu


def aggregate_adam_blocks_ref(p, grads, mu, nu, count, block_idx, *, block,
                              lr, b1=0.9, b2=0.999, eps=1e-8, wd=0.0):
    """Oracle for the block-owned kernel: gather the owned blocks of the
    full p/mu/nu buffers, run the dense reference on the packed domain.

    grads is already packed ((M,) or (W, M) with M = len(block_idx)*block);
    returns packed (new_p, new_mu, new_nu)."""
    import numpy as np

    own = (np.asarray(block_idx, np.int64)[:, None] * block
           + np.arange(block)).reshape(-1)
    return aggregate_adam_ref(
        jnp.take(p, own), grads, jnp.take(mu, own), jnp.take(nu, own),
        count, lr=lr, b1=b1, b2=b2, eps=eps, wd=wd)
