"""Jit'd wrappers for the fused aggregation-Adam kernel.

`adam_update` matches repro.optim.adam's per-tensor signature so the fused
path is a drop-in (used with fused=True). Handles arbitrary shapes by
flattening + padding to the kernel block size; on CPU the kernel runs in
interpret mode (TPU is the lowering target).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import kernel as K


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_flat(x, block):
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % block
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat, pad


def aggregate_adam(p, grads, mu, nu, count, *, lr, b1=0.9, b2=0.999,
                   eps=1e-8, wd=0.0, block=K.BLOCK, interpret=None):
    """grads: (W,) + p.shape worker stack, or p.shape single gradient."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    shape = p.shape
    pf, _ = _pad_flat(p, block)
    muf, _ = _pad_flat(mu, block)
    nuf, _ = _pad_flat(nu, block)
    if grads.ndim == p.ndim + 1:
        w = grads.shape[0]
        gf = grads.reshape(w, -1)
        pad = (-gf.shape[1]) % block
        if pad:
            gf = jnp.concatenate([gf, jnp.zeros((w, pad), gf.dtype)], axis=1)
    else:
        gf, _ = _pad_flat(grads, block)
    new_p, new_mu, new_nu = K.aggregate_adam(
        pf, gf, muf, nuf, count, lr=lr, b1=b1, b2=b2, eps=eps, wd=wd,
        block=block, interpret=interpret)
    n = 1
    for s in shape:
        n *= s
    return (new_p[:n].reshape(shape), new_mu[:n].reshape(shape),
            new_nu[:n].reshape(shape))


def adam_update(p, g, mu, nu, count, *, lr, b1=0.9, b2=0.999, eps=1e-8,
                wd=0.0):
    """Drop-in for the optim.adam per-tensor update (single gradient)."""
    return aggregate_adam(p, g, mu, nu, count, lr=lr, b1=b1, b2=b2,
                          eps=eps, wd=wd)


def _per_job(val, n_jobs):
    """Broadcast a scalar hyperparameter to a length-K tuple of floats."""
    if isinstance(val, (int, float)):
        return (float(val),) * n_jobs
    vals = tuple(float(v) for v in val)
    assert len(vals) == n_jobs, (len(vals), n_jobs)
    return vals


def _bias_corr(count, b1, b2):
    """Barrier-materialized bias-correction reciprocals for ONE job.

    Scalar (not vectorized-over-jobs) ``b1 ** t`` on purpose: XLA's
    vectorized pow approximation differs from the scalar lowering in the
    last ulp, and the per-job sequential step (repro.ps.runtime._adam_math)
    uses the scalar form -- the service tick must match it bit-for-bit.
    """
    t = count.astype(jnp.float32)
    bc1 = jax.lax.optimization_barrier(1.0 / (1.0 - b1 ** t))
    bc2 = jax.lax.optimization_barrier(1.0 / (1.0 - b2 ** t))
    return bc1, bc2


def multi_job_hp(counts, *, lr, b1=0.9, b2=0.999, eps=1e-8, wd=0.0):
    """Build the (K, HP_COLS) per-job hyperparameter table the multi-job
    kernel prefetches: ``[lr, b1, 1-b1, b2, 1-b2, eps, bc1, bc2, wd, ...]``
    per job (``1-b*`` pre-folded in python doubles for bit-parity with the
    constant-hyperparameter kernels).

    ``counts`` is a sequence of K 1-based int32 step counts (traced ok);
    the scalar hyperparameters accept a float (shared) or a length-K
    sequence (per-job, e.g. each job's own learning rate).
    """
    k = len(counts)
    lrs, b1s = _per_job(lr, k), _per_job(b1, k)
    b2s, epss, wds = _per_job(b2, k), _per_job(eps, k), _per_job(wd, k)
    rows = []
    for j in range(k):
        bc1, bc2 = _bias_corr(jnp.asarray(counts[j]), b1s[j], b2s[j])
        cols = [jnp.float32(lrs[j]), jnp.float32(b1s[j]),
                jnp.float32(1.0 - b1s[j]), jnp.float32(b2s[j]),
                jnp.float32(1.0 - b2s[j]), jnp.float32(epss[j]),
                bc1.astype(jnp.float32), bc2.astype(jnp.float32),
                jnp.float32(wds[j])]
        cols += [jnp.float32(0.0)] * (K.HP_COLS - len(cols))
        rows.append(jnp.stack(cols))
    return jnp.stack(rows)


def _rows(vec, block_idx, block):
    """One block-structured row gather out of a full flat buffer."""
    return vec.reshape(-1, block)[block_idx].reshape(-1)


def _multi_job_jnp(p, g_cat, mu, nu, counts, *, block_idx, job_sizes, block,
                   p_packed, lr, b1, b2, eps, wd):
    """Fused-scatter jnp fallback for the multi-job tick (interpret mode /
    CPU): ONE row gather per shared buffer, per-job Adam arithmetic on
    static slices of the packed concatenation (identical scalar constants
    and op grouping as repro.ps.runtime._adam_math, so the batched pass is
    bit-exact with K sequential block steps), then the caller's single row
    scatter writes everything back.
    """
    k = len(counts)
    lrs, b1s = _per_job(lr, k), _per_job(b1, k)
    b2s, epss, wds = _per_job(b2, k), _per_job(eps, k), _per_job(wd, k)
    m = int(block_idx.shape[0]) * block
    rows = jnp.asarray(block_idx, jnp.int32)
    # p_packed is EXPLICIT: when the jobs jointly own every block, packed
    # and full have the same length but different lane order.
    assert p.shape[-1] == (m if p_packed else int(mu.shape[-1])), (
        p.shape, m, mu.shape, p_packed)
    # Identity block table (jobs jointly own the whole space IN ORDER):
    # packed == full, so skip the no-op p gather -- block_idx is a host
    # array, decided at trace time.
    identity = (int(mu.shape[-1]) == m and
                np.array_equal(np.asarray(block_idx),
                               np.arange(m // block)))
    p_p = p if (p_packed or identity) else _rows(p, rows, block)
    mu_p = _rows(mu, rows, block)
    nu_p = _rows(nu, rows, block)
    g = g_cat.astype(jnp.float32)
    if g.ndim == 2:
        g = g.sum(axis=0)
    outs_p, outs_mu, outs_nu = [], [], []
    off = 0
    for j, nb in enumerate(job_sizes):
        lo, hi = off * block, (off + nb) * block
        off += nb
        p32 = p_p[lo:hi].astype(jnp.float32)
        gj, mu0, nu0 = g[lo:hi], mu_p[lo:hi], nu_p[lo:hi]
        mu_j = b1s[j] * mu0 + (1.0 - b1s[j]) * gj
        nu_j = b2s[j] * nu0 + (1.0 - b2s[j]) * gj * gj
        bc1, bc2 = _bias_corr(jnp.asarray(counts[j]), b1s[j], b2s[j])
        mu_hat = mu_j * bc1
        nu_hat = nu_j * bc2
        upd = (lrs[j] * mu_hat) / (jnp.sqrt(nu_hat) + epss[j])
        if wds[j]:
            upd = upd + (lrs[j] * wds[j]) * p32
        outs_p.append((p32 - upd).astype(p.dtype))
        outs_mu.append(mu_j)
        outs_nu.append(nu_j)

    def cat(parts):
        return jnp.concatenate(parts) if len(parts) > 1 else parts[0]

    return cat(outs_p), cat(outs_mu), cat(outs_nu)


def multi_job_adam_update(p, gs, mu, nu, counts, *, block_idx, job_sizes,
                          block, p_packed=False, lr, b1=0.9, b2=0.999,
                          eps=1e-8, wd=0.0, interpret=None):
    """One service tick: K co-resident jobs' Adam updates in one pass.

    mu/nu are the FULL shared (N,) buffers; p is full unless
    ``p_packed=True`` says it is already packed in block-table order (the
    flag is explicit -- when the jobs jointly own the whole space the two
    layouts have equal length but different order, so shape inference
    would silently misread one as the other).  ``block_idx`` concatenates
    the participating
    jobs' owned-block lists back to back (``job_sizes[j]`` blocks for job
    j, in the same order as ``counts`` and any per-job hyperparameter
    sequences); ``gs`` is either the matching per-job sequence of packed
    gradients or one pre-concatenated (M,) vector.  Returns PACKED
    (new_p, new_mu, new_nu) of length ``len(block_idx) * block`` for the
    caller to scatter back in one go.

    On TPU this is a single launch of ``kernel.aggregate_adam_multijob``;
    elsewhere (interpret mode) it falls back to the fused-scatter jnp path,
    which is bit-exact with K sequential block steps.
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    assert sum(job_sizes) == int(block_idx.shape[0]), (job_sizes, block_idx.shape)
    assert len(job_sizes) == len(counts), (job_sizes, len(counts))
    job_sizes = tuple(int(s) for s in job_sizes)
    if isinstance(gs, (list, tuple)):
        g_cat = (jnp.concatenate(gs, axis=-1) if len(gs) > 1
                 else gs[0])
    else:  # pre-concatenated
        g_cat = gs
    if interpret:
        return _multi_job_jnp(
            p, g_cat, mu, nu, counts, block_idx=block_idx,
            job_sizes=job_sizes, block=int(block), p_packed=bool(p_packed),
            lr=lr, b1=b1, b2=b2, eps=eps, wd=wd)
    hp = multi_job_hp(counts, lr=lr, b1=b1, b2=b2, eps=eps, wd=wd)
    job_slot = jnp.asarray(
        np.repeat(np.arange(len(job_sizes), dtype=np.int32),
                  np.asarray(job_sizes, np.int64)))
    return K.aggregate_adam_multijob(
        p, g_cat, mu, nu, hp, jnp.asarray(block_idx, jnp.int32), job_slot,
        block=int(block), p_packed=bool(p_packed), interpret=False)


def scatter_rows(buf, packed, block_idx, block):
    """Write packed block tiles back onto their owned rows of a full
    buffer: the post-apply scatter the fused launch makes redundant (kept
    as the jnp half of the fallback and for the per-shard oracle path)."""
    rows = jnp.asarray(block_idx, jnp.int32)
    return buf.reshape(-1, block).at[rows].set(
        packed.reshape(-1, block), unique_indices=True
    ).reshape(buf.shape)


def multi_job_adam_update_fused(p, gs, mu, nu, counts, *, block_idx,
                                job_sizes, block, lr, b1=0.9, b2=0.999,
                                eps=1e-8, wd=0.0, interpret=None):
    """One service tick with the row scatters fused into the launch.

    Same contract as :func:`multi_job_adam_update` except p/mu/nu must be
    the FULL shared (N,) buffers and the returned (new_p, new_mu, new_nu)
    are full too: every non-owned lane rides through untouched.  On TPU
    this is ONE launch of ``kernel.aggregate_adam_multijob_fused``
    (aliased in-place block writes -- no separate scatter pass);
    elsewhere the fused-scatter jnp path computes the identical packed
    update and applies the identical row scatter, so the result is
    bit-exact with the unfused ``multi_job_adam_update`` + caller-side
    scatter at any sizes.
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    assert sum(job_sizes) == int(block_idx.shape[0]), (
        job_sizes, block_idx.shape)
    assert len(job_sizes) == len(counts), (job_sizes, len(counts))
    job_sizes = tuple(int(s) for s in job_sizes)
    if isinstance(gs, (list, tuple)):
        g_cat = jnp.concatenate(gs, axis=-1) if len(gs) > 1 else gs[0]
    else:
        g_cat = gs
    if interpret:
        new_p, new_mu, new_nu = _multi_job_jnp(
            p, g_cat, mu, nu, counts, block_idx=block_idx,
            job_sizes=job_sizes, block=int(block), p_packed=False,
            lr=lr, b1=b1, b2=b2, eps=eps, wd=wd)
        return (scatter_rows(p, new_p, block_idx, int(block)),
                scatter_rows(mu, new_mu, block_idx, int(block)),
                scatter_rows(nu, new_nu, block_idx, int(block)))
    hp = multi_job_hp(counts, lr=lr, b1=b1, b2=b2, eps=eps, wd=wd)
    job_slot = jnp.asarray(
        np.repeat(np.arange(len(job_sizes), dtype=np.int32),
                  np.asarray(job_sizes, np.int64)))
    return K.aggregate_adam_multijob_fused(
        p, g_cat, mu, nu, hp, jnp.asarray(block_idx, jnp.int32), job_slot,
        block=int(block), interpret=False)


def block_adam_update(p, g_packed, mu, nu, count, *, block_idx, block,
                      lr, b1=0.9, b2=0.999, eps=1e-8, wd=0.0,
                      interpret=None):
    """Shared-space block-owned update (see kernel.aggregate_adam_blocks).

    mu/nu are the FULL shared (N,) buffers; p may be full or already
    packed (the pull usually has it in hand).  Only the blocks named by
    ``block_idx`` (a host-side int array, e.g. FlatPlan.job_layout().blocks)
    are read, and the returned new_p/new_mu/new_nu are PACKED
    (len(block_idx)*block,) vectors for the caller to scatter back.
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    block_idx = jnp.asarray(block_idx, jnp.int32)
    return K.aggregate_adam_blocks(
        p, g_packed, mu, nu, count, block_idx, lr=lr, b1=b1, b2=b2,
        eps=eps, wd=wd, block=int(block), interpret=interpret)
