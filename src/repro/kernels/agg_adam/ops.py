"""Jit'd wrappers for the fused aggregation-Adam kernel.

`adam_update` matches repro.optim.adam's per-tensor signature so the fused
path is a drop-in (used with fused=True). Handles arbitrary shapes by
flattening + padding to the kernel block size; on CPU the kernel runs in
interpret mode (TPU is the lowering target).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import kernel as K


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_flat(x, block):
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % block
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat, pad


def aggregate_adam(p, grads, mu, nu, count, *, lr, b1=0.9, b2=0.999,
                   eps=1e-8, wd=0.0, block=K.BLOCK, interpret=None):
    """grads: (W,) + p.shape worker stack, or p.shape single gradient."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    shape = p.shape
    pf, _ = _pad_flat(p, block)
    muf, _ = _pad_flat(mu, block)
    nuf, _ = _pad_flat(nu, block)
    if grads.ndim == p.ndim + 1:
        w = grads.shape[0]
        gf = grads.reshape(w, -1)
        pad = (-gf.shape[1]) % block
        if pad:
            gf = jnp.concatenate([gf, jnp.zeros((w, pad), gf.dtype)], axis=1)
    else:
        gf, _ = _pad_flat(grads, block)
    new_p, new_mu, new_nu = K.aggregate_adam(
        pf, gf, muf, nuf, count, lr=lr, b1=b1, b2=b2, eps=eps, wd=wd,
        block=block, interpret=interpret)
    n = 1
    for s in shape:
        n *= s
    return (new_p[:n].reshape(shape), new_mu[:n].reshape(shape),
            new_nu[:n].reshape(shape))


def adam_update(p, g, mu, nu, count, *, lr, b1=0.9, b2=0.999, eps=1e-8,
                wd=0.0):
    """Drop-in for the optim.adam per-tensor update (single gradient)."""
    return aggregate_adam(p, g, mu, nu, count, lr=lr, b1=b1, b2=b2,
                          eps=eps, wd=wd)


def block_adam_update(p, g_packed, mu, nu, count, *, block_idx, block,
                      lr, b1=0.9, b2=0.999, eps=1e-8, wd=0.0,
                      interpret=None):
    """Shared-space block-owned update (see kernel.aggregate_adam_blocks).

    mu/nu are the FULL shared (N,) buffers; p may be full or already
    packed (the pull usually has it in hand).  Only the blocks named by
    ``block_idx`` (a host-side int array, e.g. FlatPlan.job_layout().blocks)
    are read, and the returned new_p/new_mu/new_nu are PACKED
    (len(block_idx)*block,) vectors for the caller to scatter back.
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    block_idx = jnp.asarray(block_idx, jnp.int32)
    return K.aggregate_adam_blocks(
        p, g_packed, mu, nu, count, block_idx, lr=lr, b1=b1, b2=b2,
        eps=eps, wd=wd, block=int(block), interpret=interpret)
