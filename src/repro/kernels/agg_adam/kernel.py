"""Pallas TPU kernel: fused W-way gradient aggregation + Adam update.

The PS Update op. Naive XLA path reads/writes p, mu, nu and reads W grad
buffers in separate HBM passes; this kernel makes one pass: each grid step
streams a (BLOCK,) tile of every operand into VMEM, sums the W worker
gradients on the VPU, applies the Adam update, and writes p/mu/nu tiles
back -- arithmetic intensity goes from ~1/7 to ~1 fused op per byte, which
is what makes aggregation burst-friendly on a shared Aggregator core.

``aggregate_adam`` is the dense form (every block of the space belongs to
the caller).  ``aggregate_adam_blocks`` is the SHARED-space form: the flat
space hosts many jobs, and the grid iterates only the calling job's owned
blocks -- a scalar-prefetched block-index operand drives the BlockSpec
index maps, so the DMA engine gathers exactly the job's tiles of p/mu/nu
out of the full buffers and the update costs O(job bytes) regardless of
how much co-resident state shares the space.

``aggregate_adam_multijob`` is the SERVICE-TICK form: K co-resident jobs'
pending updates run as ONE launch.  Two scalar-prefetched operands drive
the grid -- a concatenated owned-block index table (all participating
jobs' blocks back to back) and a per-block job-slot map -- so grid step i
DMAs block ``block_idx[i]`` of the shared buffers and row ``job_slot[i]``
of a (K, HP_COLS) per-job hyperparameter table (lr, betas and their
pre-folded complements, eps, bias-correction reciprocals, weight decay).
Block exclusivity (every block belongs to at
most one job) is what makes the batched pass semantically identical to K
sequential per-job updates.

``aggregate_adam_multijob_fused`` is the SINGLE-LAUNCH form: same grid,
but the outputs are the full shared buffers -- out-specs index by the
prefetched block table and ``input_output_aliases`` pins each buffer in
place (the kernels/relayout pattern), so the three post-apply row
scatters disappear and a whole service tick is ONE kernel launch.

VMEM budget at BLOCK=16384 fp32: (W + 5) x 64 KiB tiles -- e.g. W=8 -> 832
KiB, comfortably inside the ~16 MiB v5e VMEM with double buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK = 16384  # elements per tile; 128-aligned for VPU lanes


def _kernel(p_ref, g_ref, mu_ref, nu_ref, bc_ref, out_p, out_mu, out_nu,
            *, lr, b1, b2, eps, wd):
    g = g_ref[...].astype(jnp.float32)
    if g.ndim == 2:  # (W, BLOCK) worker pushes -> sum-aggregate
        g = g.sum(axis=0)
    mu = b1 * mu_ref[...] + (1.0 - b1) * g
    nu = b2 * nu_ref[...] + (1.0 - b2) * g * g
    mu_hat = mu * bc_ref[0]  # 1/(1-b1^t)
    nu_hat = nu * bc_ref[1]  # 1/(1-b2^t)
    p32 = p_ref[...].astype(jnp.float32)
    # (lr*mu_hat)/denom keeps the final subtract free of a direct multiply
    # operand, so XLA cannot FMA-contract it differently from the unfused
    # paths (repro.ps.runtime._adam_math uses the same grouping).
    upd = (lr * mu_hat) / (jnp.sqrt(nu_hat) + eps)
    if wd:
        upd = upd + (lr * wd) * p32
    out_p[...] = (p32 - upd).astype(out_p.dtype)
    out_mu[...] = mu
    out_nu[...] = nu


@functools.partial(
    jax.jit,
    static_argnames=("lr", "b1", "b2", "eps", "wd", "block", "interpret"),
)
def aggregate_adam(p, grads, mu, nu, count, *, lr, b1=0.9, b2=0.999,
                   eps=1e-8, wd=0.0, block=BLOCK, interpret=False):
    """p, mu, nu: (N,); grads: (N,) or (W, N); count: int32 scalar (1-based).

    N must be a multiple of `block` (ops.py pads)."""
    n = p.shape[-1]
    assert n % block == 0, f"N={n} not a multiple of block={block}"
    grid = (n // block,)
    t = count.astype(jnp.float32)
    bc = jnp.stack([1.0 / (1.0 - b1 ** t), 1.0 / (1.0 - b2 ** t)])

    if grads.ndim == 2:
        g_spec = pl.BlockSpec((grads.shape[0], block), lambda i: (0, i))
    else:
        g_spec = pl.BlockSpec((block,), lambda i: (i,))
    vec = pl.BlockSpec((block,), lambda i: (i,))
    bc_spec = pl.BlockSpec((2,), lambda i: (0,))

    kernel = functools.partial(_kernel, lr=lr, b1=b1, b2=b2, eps=eps, wd=wd)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[vec, g_spec, vec, vec, bc_spec],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(p.shape, p.dtype),
            jax.ShapeDtypeStruct(mu.shape, jnp.float32),
            jax.ShapeDtypeStruct(nu.shape, jnp.float32),
        ],
        interpret=interpret,
    )(p, grads, mu, nu, bc)


def _block_kernel(bidx_ref, *refs, **kw):
    # The scalar-prefetched block indices are consumed by the BlockSpec
    # index maps only; the tile math is identical to the dense kernel.
    del bidx_ref
    _kernel(*refs, **kw)


@functools.partial(
    jax.jit,
    static_argnames=("lr", "b1", "b2", "eps", "wd", "block", "interpret"),
)
def aggregate_adam_blocks(p, grads, mu, nu, count, block_idx, *, lr, b1=0.9,
                          b2=0.999, eps=1e-8, wd=0.0, block=BLOCK,
                          interpret=False):
    """Block-owned shared-space update: touch only the caller's blocks.

    mu, nu: (N,) FULL shared buffers (N a multiple of `block`);
    p: (N,) full, or already PACKED (M,) -- the caller usually has the
    packed parameters in hand from the pull, so re-gathering them here
    would cost an extra O(job bytes) pass; grads: (M,) or (W, M) PACKED
    job-domain gradient with M = len(block_idx) * block; block_idx:
    (n_own,) int32 owned block ids; count: int32 scalar (1-based, this
    job's step counter).

    Grid step i DMAs tile ``block_idx[i]`` of mu/nu (and of p when full --
    scalar prefetch makes the indices available to the index maps before
    the body runs) and tile ``i`` of the packed operands, then writes tile
    ``i`` of the PACKED outputs -- the caller scatters them back onto its
    owned lanes.  Returns (new_p, new_mu, new_nu), each (M,).
    """
    n = mu.shape[-1]
    assert n % block == 0, f"N={n} not a multiple of block={block}"
    n_own = block_idx.shape[0]
    m = grads.shape[-1]
    assert m == n_own * block, (
        f"packed gradient length {m} != n_own*block = {n_own}*{block}")
    assert p.shape[-1] in (n, m), (
        f"p length {p.shape[-1]} is neither full ({n}) nor packed ({m})")
    t = count.astype(jnp.float32)
    bc = jnp.stack([1.0 / (1.0 - b1 ** t), 1.0 / (1.0 - b2 ** t)])

    owned = pl.BlockSpec((block,), lambda i, bidx: (bidx[i],))
    packed = pl.BlockSpec((block,), lambda i, bidx: (i,))
    if grads.ndim == 2:
        g_spec = pl.BlockSpec((grads.shape[0], block), lambda i, bidx: (0, i))
    else:
        g_spec = packed
    p_spec = packed if p.shape[-1] == m else owned
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_own,),
        in_specs=[p_spec, g_spec, owned, owned,
                  pl.BlockSpec((2,), lambda i, bidx: (0,))],
        out_specs=[packed, packed, packed],
    )
    kernel = functools.partial(_block_kernel, lr=lr, b1=b1, b2=b2, eps=eps,
                               wd=wd)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((m,), p.dtype),
            jax.ShapeDtypeStruct((m,), jnp.float32),
            jax.ShapeDtypeStruct((m,), jnp.float32),
        ],
        interpret=interpret,
    )(block_idx.astype(jnp.int32), p, grads, mu, nu, bc)


HP_COLS = 16  # (lr, b1, 1-b1, b2, 1-b2, eps, bc1, bc2, wd, pad...) per job


def _multijob_kernel(bidx_ref, jslot_ref, p_ref, g_ref, mu_ref, nu_ref,
                     hp_ref, out_p, out_mu, out_nu):
    # bidx/jslot are consumed by the BlockSpec index maps; the hyperparams
    # arrive as this block's owner-job row of the (K, HP_COLS) table.
    # Same arithmetic form as _kernel, with the compile-time constants
    # replaced by the prefetched per-job scalars; 1-b1 / 1-b2 come
    # PRE-FOLDED from the table because the dense kernels fold them from
    # python doubles at trace time -- recomputing them here in f32
    # (1.0 - 0.9f != f32(1.0 - 0.9)) would break bit-parity.
    del bidx_ref, jslot_ref
    lr, b1, omb1 = hp_ref[0, 0], hp_ref[0, 1], hp_ref[0, 2]
    b2, omb2, eps = hp_ref[0, 3], hp_ref[0, 4], hp_ref[0, 5]
    bc1, bc2, wd = hp_ref[0, 6], hp_ref[0, 7], hp_ref[0, 8]
    g = g_ref[...].astype(jnp.float32)
    if g.ndim == 2:  # (W, BLOCK) worker pushes -> sum-aggregate
        g = g.sum(axis=0)
    mu = b1 * mu_ref[...] + omb1 * g
    nu = b2 * nu_ref[...] + omb2 * g * g
    mu_hat = mu * bc1
    nu_hat = nu * bc2
    p32 = p_ref[...].astype(jnp.float32)
    upd = (lr * mu_hat) / (jnp.sqrt(nu_hat) + eps)
    upd = upd + (lr * wd) * p32
    out_p[...] = (p32 - upd).astype(out_p.dtype)
    out_mu[...] = mu
    out_nu[...] = nu


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def aggregate_adam_multijob_fused(p, grads, mu, nu, hp, block_idx, job_slot,
                                  *, block=BLOCK, interpret=False):
    """Multi-job Adam with the row scatters FUSED into the launch.

    Same grid and tile math as :func:`aggregate_adam_multijob`, but the
    outputs are the FULL shared buffers instead of packed vectors: the
    out-specs index by the scalar-prefetched block table (grid step i
    writes tile ``block_idx[i]``), and ``input_output_aliases`` pins each
    full input buffer to its output -- the kernels/relayout pattern -- so
    stationary blocks are never read, copied, or written and the caller
    needs NO post-apply scatter pass.  The in-place write is hazard-free:
    step i reads and writes the SAME block (exclusive by construction),
    and distinct grid steps touch distinct blocks.

    p, mu, nu: (N,) FULL shared buffers (p cannot arrive packed here: its
    untouched lanes must ride through the launch).  Returns the updated
    full (new_p, new_mu, new_nu), each (N,).
    """
    n = mu.shape[-1]
    assert n % block == 0, f"N={n} not a multiple of block={block}"
    n_own = block_idx.shape[0]
    assert job_slot.shape == (n_own,), (job_slot.shape, n_own)
    m = grads.shape[-1]
    assert m == n_own * block, (
        f"packed gradient length {m} != n_own*block = {n_own}*{block}")
    assert p.shape[-1] == n, (
        f"p length {p.shape[-1]} != full length {n} (the fused-scatter "
        f"form writes into the full buffers; pass the packed p to "
        f"aggregate_adam_multijob instead)")
    assert hp.ndim == 2 and hp.shape[1] == HP_COLS, hp.shape

    owned = pl.BlockSpec((block,), lambda i, bidx, jslot: (bidx[i],))
    if grads.ndim == 2:
        g_spec = pl.BlockSpec((grads.shape[0], block),
                              lambda i, bidx, jslot: (0, i))
    else:
        g_spec = pl.BlockSpec((block,), lambda i, bidx, jslot: (i,))
    hp_spec = pl.BlockSpec((1, HP_COLS), lambda i, bidx, jslot: (jslot[i], 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_own,),
        in_specs=[owned, g_spec, owned, owned, hp_spec],
        out_specs=[owned, owned, owned],
    )
    # Inputs 2/4/5 are p/mu/nu (0 and 1 are the prefetched tables); alias
    # them onto outputs 0/1/2 so untouched blocks stay in place.
    return pl.pallas_call(
        _multijob_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct(p.shape, p.dtype),
            jax.ShapeDtypeStruct(mu.shape, jnp.float32),
            jax.ShapeDtypeStruct(nu.shape, jnp.float32),
        ],
        input_output_aliases={2: 0, 4: 1, 5: 2},
        interpret=interpret,
    )(block_idx.astype(jnp.int32), job_slot.astype(jnp.int32),
      p, grads, mu, nu, hp.astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("block", "p_packed",
                                              "interpret"))
def aggregate_adam_multijob(p, grads, mu, nu, hp, block_idx, job_slot, *,
                            block=BLOCK, p_packed=False, interpret=False):
    """K co-resident jobs' Adam updates in one launch (one service tick).

    mu, nu: (N,) FULL shared buffers; p: (N,) full, or -- with
    ``p_packed=True`` -- (M,) already packed in block-table order (the
    flag is EXPLICIT because when the jobs jointly own every block M == N
    and the two layouts are indistinguishable by shape yet differently
    ordered); grads: (M,) or (W, M) concatenation of the participating
    jobs' packed gradients, in ``block_idx`` order with
    M = len(block_idx) * block; hp: (K, HP_COLS) float32 per-job
    hyperparameter table ``[lr, b1, 1-b1, b2, 1-b2, eps, bc1, bc2, wd,
    0...]`` (bc* are the bias-correction *reciprocals* for that job's
    1-based step count); block_idx: (n_own,) int32 concatenated
    owned-block table; job_slot: (n_own,) int32 row of ``hp`` owning each
    block.

    Grid step i DMAs tile ``block_idx[i]`` of the shared buffers, tile i of
    the packed operands, and row ``job_slot[i]`` of hp, then writes tile i
    of the PACKED outputs.  Returns (new_p, new_mu, new_nu), each (M,).
    """
    n = mu.shape[-1]
    assert n % block == 0, f"N={n} not a multiple of block={block}"
    n_own = block_idx.shape[0]
    assert job_slot.shape == (n_own,), (job_slot.shape, n_own)
    m = grads.shape[-1]
    assert m == n_own * block, (
        f"packed gradient length {m} != n_own*block = {n_own}*{block}")
    assert p.shape[-1] == (m if p_packed else n), (
        f"p length {p.shape[-1]} != {'packed' if p_packed else 'full'} "
        f"length {(m if p_packed else n)}")
    assert hp.ndim == 2 and hp.shape[1] == HP_COLS, hp.shape

    owned = pl.BlockSpec((block,), lambda i, bidx, jslot: (bidx[i],))
    packed = pl.BlockSpec((block,), lambda i, bidx, jslot: (i,))
    if grads.ndim == 2:
        g_spec = pl.BlockSpec((grads.shape[0], block),
                              lambda i, bidx, jslot: (0, i))
    else:
        g_spec = packed
    p_spec = packed if p_packed else owned
    hp_spec = pl.BlockSpec((1, HP_COLS), lambda i, bidx, jslot: (jslot[i], 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_own,),
        in_specs=[p_spec, g_spec, owned, owned, hp_spec],
        out_specs=[packed, packed, packed],
    )
    return pl.pallas_call(
        _multijob_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((m,), p.dtype),
            jax.ShapeDtypeStruct((m,), jnp.float32),
            jax.ShapeDtypeStruct((m,), jnp.float32),
        ],
        interpret=interpret,
    )(block_idx.astype(jnp.int32), job_slot.astype(jnp.int32),
      p, grads, mu, nu, hp.astype(jnp.float32))
