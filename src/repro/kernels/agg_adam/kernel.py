"""Pallas TPU kernel: fused W-way gradient aggregation + Adam update.

The PS Update op. Naive XLA path reads/writes p, mu, nu and reads W grad
buffers in separate HBM passes; this kernel makes one pass: each grid step
streams a (BLOCK,) tile of every operand into VMEM, sums the W worker
gradients on the VPU, applies the Adam update, and writes p/mu/nu tiles
back -- arithmetic intensity goes from ~1/7 to ~1 fused op per byte, which
is what makes aggregation burst-friendly on a shared Aggregator core.

VMEM budget at BLOCK=16384 fp32: (W + 5) x 64 KiB tiles -- e.g. W=8 -> 832
KiB, comfortably inside the ~16 MiB v5e VMEM with double buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 16384  # elements per tile; 128-aligned for VPU lanes


def _kernel(p_ref, g_ref, mu_ref, nu_ref, bc_ref, out_p, out_mu, out_nu,
            *, lr, b1, b2, eps, wd):
    g = g_ref[...].astype(jnp.float32)
    if g.ndim == 2:  # (W, BLOCK) worker pushes -> sum-aggregate
        g = g.sum(axis=0)
    mu = b1 * mu_ref[...] + (1.0 - b1) * g
    nu = b2 * nu_ref[...] + (1.0 - b2) * g * g
    mu_hat = mu * bc_ref[0]  # 1/(1-b1^t)
    nu_hat = nu * bc_ref[1]  # 1/(1-b2^t)
    p32 = p_ref[...].astype(jnp.float32)
    upd = mu_hat / (jnp.sqrt(nu_hat) + eps)
    if wd:
        upd = upd + wd * p32
    out_p[...] = (p32 - lr * upd).astype(out_p.dtype)
    out_mu[...] = mu
    out_nu[...] = nu


@functools.partial(
    jax.jit,
    static_argnames=("lr", "b1", "b2", "eps", "wd", "block", "interpret"),
)
def aggregate_adam(p, grads, mu, nu, count, *, lr, b1=0.9, b2=0.999,
                   eps=1e-8, wd=0.0, block=BLOCK, interpret=False):
    """p, mu, nu: (N,); grads: (N,) or (W, N); count: int32 scalar (1-based).

    N must be a multiple of `block` (ops.py pads)."""
    n = p.shape[-1]
    assert n % block == 0, f"N={n} not a multiple of block={block}"
    grid = (n // block,)
    t = count.astype(jnp.float32)
    bc = jnp.stack([1.0 / (1.0 - b1 ** t), 1.0 / (1.0 - b2 ** t)])

    if grads.ndim == 2:
        g_spec = pl.BlockSpec((grads.shape[0], block), lambda i: (0, i))
    else:
        g_spec = pl.BlockSpec((block,), lambda i: (i,))
    vec = pl.BlockSpec((block,), lambda i: (i,))
    bc_spec = pl.BlockSpec((2,), lambda i: (0,))

    kernel = functools.partial(_kernel, lr=lr, b1=b1, b2=b2, eps=eps, wd=wd)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[vec, g_spec, vec, vec, bc_spec],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(p.shape, p.dtype),
            jax.ShapeDtypeStruct(mu.shape, jnp.float32),
            jax.ShapeDtypeStruct(nu.shape, jnp.float32),
        ],
        interpret=interpret,
    )(p, grads, mu, nu, bc)
