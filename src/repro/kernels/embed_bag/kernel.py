"""Pallas TPU embedding-bag kernel: scalar-prefetched row streaming.

The recsys lookup hot path: out[b] = sum_l table[idx[b, l]]. The bag
indices are scalar-prefetched (available before the grid runs), so each
grid step's BlockSpec index_map points the table block AT the row to
gather -- the row is DMA'd HBM->VMEM by the pipeline itself; no giant
gather materializes and the table never passes through registers wholesale.

Grid: (B, L): step (b, l) streams table row idx[b, l] (a (1, D) block) and
accumulates into out[b]; the output block for row b is revisited across the
L inner steps (accumulate-in-place idiom: zero at l == 0).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(idx_ref, row_ref, out_ref):
    l = pl.program_id(1)

    @pl.when(l == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += row_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def embedding_bag(table, indices, *, interpret=False):
    """table: (V, D); indices: (B, L) int32 -> (B, D) sum-bags (fp32)."""
    v, d = table.shape
    b, l = indices.shape
    flat_idx = indices.reshape(-1)

    grid_spec = pl.GridSpec(
        grid=(b, l),
        in_specs=[
            # one table row per step, selected by the prefetched indices
            pl.BlockSpec((1, d), lambda i, j, idx: (idx[i * l + j], 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda i, j, idx: (i, 0)),
    )
    try:
        from jax.experimental.pallas import tpu as pltpu

        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b, l),
            in_specs=[
                pl.BlockSpec((1, d), lambda i, j, idx: (idx[i * l + j], 0)),
            ],
            out_specs=pl.BlockSpec((1, d), lambda i, j, idx: (i, 0)),
        )
    except ImportError:  # pragma: no cover
        pass

    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, d), jnp.float32),
        interpret=interpret,
    )(flat_idx, table)
