"""Jit'd wrapper for the embedding-bag kernel with interpret fallback."""

from __future__ import annotations

import jax

from . import kernel as K


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def embedding_bag(table, indices, interpret=None):
    interpret = (not _on_tpu()) if interpret is None else interpret
    return K.embedding_bag(table, indices, interpret=interpret)
