"""Pure-jnp oracle for the embedding-bag gather-reduce."""

from __future__ import annotations

import jax.numpy as jnp


def embedding_bag_ref(table, indices, weights=None, mode="sum"):
    """table: (V, D); indices: (B, L) -> (B, D) reduced bags."""
    rows = jnp.take(table, indices, axis=0)  # (B, L, D)
    if weights is not None:
        rows = rows * weights[..., None]
    out = jnp.sum(rows, axis=1)
    if mode == "mean":
        out = out / indices.shape[1]
    return out
