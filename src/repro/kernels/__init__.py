"""Pallas TPU kernels for the perf-critical compute hot spots.

agg_adam    fused W-way gradient aggregation + Adam update -- the paper's
            model-aggregation op (PS Update): one VMEM pass per tile instead
            of 3 + W HBM round-trips.
flash_attn  blockwise online-softmax attention (training/prefill shapes);
            the jnp chunked_attention in models/attention.py is its oracle.
embed_bag   embedding-bag gather-reduce with scalar-prefetch row streaming
            (recsys lookup hot path).
relayout    one-launch run-copy for plan-pair migrations: scatter every
            state leaf's touched blocks in place (aliased outputs), so a
            replan costs O(moved bytes) instead of O(total state).

Each package: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit wrapper
with interpret fallback on CPU), ref.py (pure-jnp oracle). All validated in
interpret mode on CPU; TPU is the lowering target.
"""
