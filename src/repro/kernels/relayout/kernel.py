"""Pallas TPU kernel: one-launch run-copy for plan-pair migrations.

A replan's :class:`repro.ps.elastic.MigrationDelta` names the new-plan
blocks whose content changes (moved runs + vacated lanes); everything
else is stationary.  This kernel executes the whole transition for ALL
of the state's 1-D leaves (flat/mu/nu/ef) in ONE launch:

  * the caller stages each leaf's touched blocks as a packed
    ``(n_touched * block,)`` buffer (an O(moved bytes) gather through
    the delta's per-lane source map -- see ops.py);
  * grid step i writes tile i of every staged buffer into block
    ``dst_blocks[i]`` of the corresponding full-length base buffer,
    with the destination blocks scalar-prefetched so the DMA engine
    knows the scatter pattern up front;
  * ``input_output_aliases`` pins each base buffer to its output, so
    stationary blocks are never read, copied, or written -- the launch
    cost is O(touched bytes) regardless of how much co-resident state
    shares the space.

Staging is what makes the in-place scatter hazard-free: sources are
read from a separate packed buffer, never from the aliased outputs, so
a run may move a block onto another run's source without ordering
constraints on the grid.

VMEM budget: 2 x n_leaves tiles of ``block`` fp32 lanes -- at the
shipped block_align (128..16384) this is KBs, far inside v5e VMEM.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(dst_ref, *refs):
    # refs = (base_0..base_{L-1}, staged_0..staged_{L-1}, out_0..out_{L-1});
    # the bases are aliased to the outputs and never read here -- they only
    # carry the stationary blocks through the launch.
    del dst_ref
    n = len(refs) // 3
    staged, outs = refs[n : 2 * n], refs[2 * n :]
    for s, o in zip(staged, outs):
        o[...] = s[...]


def relayout_scatter(bases, staged, dst_blocks, *, block, interpret=False):
    """Scatter every leaf's staged touched-block tiles into its base.

    bases: sequence of (N,) full new-layout buffers (stationary content
    already in place; N a multiple of ``block``); staged: matching
    sequence of (n_touched * block,) packed buffers holding the final
    content of the touched blocks, in ``dst_blocks`` order; dst_blocks:
    (n_touched,) int32 new-plan block ids.

    Returns the updated buffers (same shapes/dtypes as ``bases``).  The
    bases are donated into the outputs (in-place update); only the
    touched blocks are written.
    """
    bases = list(bases)
    staged = list(staged)
    n_leaves = len(bases)
    assert n_leaves == len(staged) and n_leaves >= 1
    n_t = int(dst_blocks.shape[0])
    n = bases[0].shape[-1]
    assert n % block == 0, f"N={n} not a multiple of block={block}"
    for b, s in zip(bases, staged):
        assert b.shape == (n,), (b.shape, n)
        assert s.shape == (n_t * block,), (s.shape, n_t, block)

    any_spec = pl.BlockSpec(memory_space=pl.ANY)
    packed = pl.BlockSpec((block,), lambda i, d: (i,))
    out = pl.BlockSpec((block,), lambda i, d: (d[i],))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_t,),
        in_specs=[any_spec] * n_leaves + [packed] * n_leaves,
        out_specs=[out] * n_leaves,
    )
    # Input k+1 is base k (index 0 is the prefetched dst table); alias it
    # onto output k so stationary blocks stay in place.
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct(b.shape, b.dtype) for b in bases],
        input_output_aliases={1 + k: k for k in range(n_leaves)},
        interpret=interpret,
    )(dst_blocks.astype(jnp.int32), *bases, *staged)
