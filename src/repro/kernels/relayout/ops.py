"""Dispatch for the run-copy relayout: Pallas on TPU, jnp elsewhere.

``relayout(leaves, delta)`` executes a compiled
:class:`repro.ps.elastic.MigrationDelta` over every 1-D state leaf in
one pass, costing O(moved bytes):

  * TPU: stage each leaf's touched blocks with one gather through the
    delta's per-lane source map, then ONE scalar-prefetched
    ``kernel.relayout_scatter`` launch writes all leaves' touched
    blocks in place (aliased outputs -- stationary blocks never move).
  * off-TPU / interpret: a compiled jnp program -- an unrolled
    ``dynamic_slice``/``dynamic_update_slice`` chain per run when the
    run list is short, or the same staged block gather + one row
    scatter when it is not (both donate the inputs, so stationary
    lanes stay in place under jit).

Both paths are bit-exact with the full-gather oracle
(``repro.ps.elastic.migrate_flat_state``) on valid states (non-payload
lanes zero); ``ref.relayout_ref`` is the numpy oracle used by the
kernel tests.
"""

from __future__ import annotations

import functools
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp

from . import kernel as K

# Above this many runs the unrolled dynamic-slice program stops paying
# for itself (compile time grows with every run); the staged block
# gather/scatter handles the rest at the same O(touched bytes).
RUNS_UNROLL_MAX = 128


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resize(x, old_len: int, new_len: int):
    """Old buffer viewed at the new length (pad zeros / truncate)."""
    if new_len == old_len:
        return x
    if new_len > old_len:
        return jnp.concatenate([x, jnp.zeros((new_len - old_len,), x.dtype)])
    return jax.lax.slice(x, (0,), (new_len,))


@functools.lru_cache(maxsize=64)
def _runs_applier(moves, zeros, old_len, new_len, dtypes):
    """Jitted unrolled run program for one (delta, leaf-dtypes) pair.

    Donates the leaves: stationary lanes are carried by the (possibly
    in-place) resize, and only the run bytes are rewritten.
    """

    def apply(leaves):
        outs = []
        for x in leaves:
            base = _resize(x, old_len, new_len)
            for dst, length in zeros:
                base = jax.lax.dynamic_update_slice(
                    base, jnp.zeros((length,), x.dtype), (dst,))
            for src, dst, length in moves:
                # Reads come from the ORIGINAL x, never from base: a run
                # may land on another run's source without ordering
                # hazards (XLA inserts the minimal copy if regions alias
                # under donation).
                base = jax.lax.dynamic_update_slice(
                    base, jax.lax.dynamic_slice(x, (src,), (length,)), (dst,))
            outs.append(base)
        return outs

    # Donation only pays when the space keeps its length (in-place run
    # rewrite); a resize can't reuse the buffers and would just warn.
    donate = (0,) if old_len == new_len else ()
    return jax.jit(apply, donate_argnums=donate)


def _stage(x, delta):
    """Final content of the delta's touched blocks, packed in block order:
    one O(touched-bytes) gather through the per-lane source map."""
    # stage_src is always in-bounds: unset (non-kept) lanes carry index 0,
    # and a non-empty touched set implies the old plan had payload.
    gathered = jnp.take(x, jnp.asarray(delta.stage_src), axis=0)
    return jnp.where(jnp.asarray(delta.stage_keep), gathered,
                     jnp.zeros((), x.dtype))


@functools.lru_cache(maxsize=64)
def _staged_applier(delta_key, old_len, new_len, block, dtypes):
    """Jitted staged block gather + row scatter (the many-runs jnp path)."""
    delta = _STAGE_DELTAS[delta_key]
    rows = jnp.asarray(delta.touched_blocks)

    def apply(leaves):
        outs = []
        for x in leaves:
            base = _resize(x, old_len, new_len)
            staged = _stage(x, delta)
            outs.append(
                base.reshape(-1, block).at[rows].set(
                    staged.reshape(-1, block), unique_indices=True,
                    indices_are_sorted=True).reshape(base.shape))
        return outs

    donate = (0,) if old_len == new_len else ()
    return jax.jit(apply, donate_argnums=donate)


# The staged applier needs the delta's numpy arrays at trace time but
# lru_cache needs hashable keys; park the delta under its content key.
_STAGE_DELTAS = {}


def _delta_key(delta):
    return (delta.old_len, delta.new_len, delta.block, delta.moves,
            delta.zeros, delta.touched_blocks.tobytes())


def relayout(leaves: Sequence, delta, *,
             interpret: Optional[bool] = None) -> List:
    """Execute one compiled MigrationDelta over every given 1-D leaf.

    Returns the migrated leaves (length ``delta.new_len`` each), in
    order.  O(moved bytes) on every path; the leaves may be donated.
    """
    leaves = list(leaves)
    if delta.identity or not leaves:
        return leaves
    for x in leaves:
        assert x.ndim == 1 and x.shape[0] == delta.old_len, (
            f"leaf shape {x.shape} != old_len {delta.old_len}")
    dtypes = tuple(jnp.dtype(x.dtype).name for x in leaves)
    if not delta.touched_blocks.size:
        # Pure resize (e.g. a shard appended for an arriving job): no
        # content moves at all.
        return [_resize(x, delta.old_len, delta.new_len) for x in leaves]

    use_kernel = (_on_tpu() if interpret is None else not interpret)
    if use_kernel and delta.new_len % delta.block == 0:
        bases = [_resize(x, delta.old_len, delta.new_len) for x in leaves]
        staged = [_stage(x, delta) for x in leaves]
        return list(K.relayout_scatter(
            bases, staged, jnp.asarray(delta.touched_blocks),
            block=delta.block, interpret=False))

    if (delta.n_runs <= RUNS_UNROLL_MAX
            or delta.new_len % delta.block != 0):
        fn = _runs_applier(delta.moves, delta.zeros, delta.old_len,
                           delta.new_len, dtypes)
        return fn(leaves)
    key = _delta_key(delta)
    if len(_STAGE_DELTAS) > 256:  # appliers re-park their key on demand
        _STAGE_DELTAS.clear()
    _STAGE_DELTAS.setdefault(key, delta)
    fn = _staged_applier(key, delta.old_len, delta.new_len, delta.block,
                         dtypes)
    return fn(leaves)
