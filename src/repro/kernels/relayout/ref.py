"""Pure-numpy oracle for the run-copy relayout."""

from __future__ import annotations

from typing import List, Sequence

import numpy as np


def relayout_ref(leaves: Sequence, delta) -> List[np.ndarray]:
    """Apply a MigrationDelta's runs with plain numpy slice assignment.

    Semantics mirror ops.relayout exactly: resize the buffer (pad zeros /
    truncate), zero the vacated runs, copy the moved runs from the
    ORIGINAL buffer.  Lanes outside every run are untouched.
    """
    outs = []
    for x in leaves:
        x = np.asarray(x)
        assert x.ndim == 1 and x.shape[0] == delta.old_len
        base = np.zeros(delta.new_len, dtype=x.dtype)
        n = min(delta.old_len, delta.new_len)
        base[:n] = x[:n]
        for dst, length in delta.zeros:
            base[dst : dst + length] = 0
        for src, dst, length in delta.moves:
            base[dst : dst + length] = x[src : src + length]
        outs.append(base)
    return outs
