"""Fault-tolerant checkpointing (no orbax/tensorstore offline).

Design for 1000+ node runs:
  * per-leaf .npy files under a step directory + JSON manifest with tree
    structure, shapes, dtypes, and SHA-256 content hashes;
  * atomic commit: write into step_XXXX.tmp, fsync, rename -- a crashed
    save can never shadow a good checkpoint;
  * elastic restore: leaves are loaded as full arrays and re-sharded onto
    whatever mesh the restoring job runs (mesh shape may differ from the
    saving job's -- checkpoint format is placement-free);
  * plan-aware PS checkpoints: ``save_ps_checkpoint`` commits the shared
    flat state together with the ServicePlan that laid it out, and
    ``restore_ps_checkpoint`` migrates the state onto whatever plan the
    restoring service compiled -- a checkpoint taken under one packing
    restores under another;
  * integrity: restore verifies hashes (configurable off for speed);
  * retention: keep_last N steps, old steps garbage-collected after a
    successful commit;
  * async save: a background thread handles serialization of host copies
    so the train loop only blocks for the device->host transfer.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional

import jax
import numpy as np

MANIFEST = "manifest.json"
AUX = "aux.json"  # side-channel metadata committed atomically with the step


def _leaf_key(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def _tree_paths(tree):
    return jax.tree_util.tree_flatten_with_path(tree)


def save_checkpoint(directory, step: int, tree, keep_last: Optional[int] = None,
                    verify: bool = True, aux: Optional[Dict[str, Any]] = None) -> Path:
    """Atomically save `tree` under directory/step_{step:08d}.

    ``aux`` is arbitrary JSON metadata (e.g. the ServicePlan) committed in
    the same atomic rename as the tensor data."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    final = directory / f"step_{step:08d}"
    tmp = directory / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves, _ = _tree_paths(tree)
    manifest: Dict[str, Any] = {"step": step, "created": time.time(), "leaves": {}}
    for i, (path, leaf) in enumerate(leaves):
        key = _leaf_key(path)
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        np.save(tmp / fname, arr, allow_pickle=False)
        digest = (
            hashlib.sha256((tmp / fname).read_bytes()).hexdigest() if verify else ""
        )
        manifest["leaves"][key] = {
            "file": fname,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "sha256": digest,
        }
    if aux is not None:
        (tmp / AUX).write_text(json.dumps(aux))
        with open(tmp / AUX, "rb") as f:
            os.fsync(f.fileno())
    (tmp / MANIFEST).write_text(json.dumps(manifest, indent=1))
    # fsync the manifest then atomically publish
    with open(tmp / MANIFEST, "rb") as f:
        os.fsync(f.fileno())
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)

    if keep_last is not None:
        steps = sorted(all_steps(directory))
        for old in steps[:-keep_last]:
            shutil.rmtree(directory / f"step_{old:08d}", ignore_errors=True)
    return final


def all_steps(directory) -> List[int]:
    directory = Path(directory)
    out = []
    if not directory.exists():
        return out
    for p in directory.iterdir():
        if p.is_dir() and p.name.startswith("step_") and not p.name.endswith(".tmp"):
            if (p / MANIFEST).exists():  # only committed checkpoints count
                out.append(int(p.name[5:]))
    return sorted(out)


def latest_step(directory) -> Optional[int]:
    steps = all_steps(directory)
    return steps[-1] if steps else None


def restore_checkpoint(directory, step: int, abstract_tree,
                       shardings=None, verify: bool = True):
    """Restore into the structure of `abstract_tree`; optionally place each
    leaf onto `shardings` (a matching pytree) -- the elastic-re-mesh path."""
    directory = Path(directory) / f"step_{step:08d}"
    manifest = json.loads((directory / MANIFEST).read_text())

    leaves, _ = _tree_paths(abstract_tree)
    out = []
    shard_leaves = (
        jax.tree_util.tree_leaves(shardings) if shardings is not None else None
    )
    for i, (path, leaf) in enumerate(leaves):
        key = _leaf_key(path)
        if key not in manifest["leaves"]:
            raise KeyError(f"checkpoint missing leaf {key}")
        entry = manifest["leaves"][key]
        fpath = directory / entry["file"]
        if verify and entry["sha256"]:
            digest = hashlib.sha256(fpath.read_bytes()).hexdigest()
            if digest != entry["sha256"]:
                raise IOError(f"checksum mismatch for {key} in {directory}")
        arr = np.load(fpath, allow_pickle=False)
        if list(arr.shape) != list(leaf.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs model {leaf.shape}"
            )
        if shard_leaves is not None:
            out.append(jax.device_put(arr, shard_leaves[i]))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(abstract_tree), out
    )


def load_aux(directory, step: int) -> Optional[Dict[str, Any]]:
    """Read the aux metadata committed with a step (None if absent)."""
    path = Path(directory) / f"step_{step:08d}" / AUX
    if not path.exists():
        return None
    return json.loads(path.read_text())


def _abstract_from_manifest(manifest) -> Dict[str, Any]:
    """Rebuild the (nested-dict) state structure from a manifest's leaf
    keys, as ShapeDtypeStructs -- so PS states restore without the caller
    reconstructing the exact counts/ef layout by hand."""
    root: Dict[str, Any] = {}
    for key, entry in manifest["leaves"].items():
        parts = key.split("/")
        d = root
        for p in parts[:-1]:
            d = d.setdefault(p, {})
        d[parts[-1]] = jax.ShapeDtypeStruct(
            tuple(entry["shape"]), np.dtype(entry["dtype"])
        )
    return root


def save_ps_checkpoint(directory, step: int, plan, state,
                       keep_last: Optional[int] = None,
                       verify: bool = True) -> Path:
    """Save a (ServicePlan, shared flat state) pair atomically."""
    from repro.ps.plan import plan_to_json

    return save_checkpoint(directory, step, state, keep_last, verify,
                           aux={"plan": plan_to_json(plan)})


def restore_ps_checkpoint(directory, step: int, plan=None, verify: bool = True):
    """Restore a PS checkpoint; returns ``(plan, state)``.

    With ``plan`` given (the restoring service's compiled plan), the state
    is migrated from the saved layout onto it -- a checkpoint taken under
    one packing restores under another.  Otherwise the saved plan is used
    as-is."""
    from repro.ps.elastic import migrate_flat_state
    from repro.ps.plan import plan_from_json

    aux = load_aux(directory, step)
    if aux is None or "plan" not in aux:
        raise IOError(f"step {step} in {directory} is not a PS checkpoint")
    saved_plan = plan_from_json(aux["plan"])
    manifest = json.loads(
        (Path(directory) / f"step_{step:08d}" / MANIFEST).read_text()
    )
    abstract = _abstract_from_manifest(manifest)
    state = restore_checkpoint(directory, step, abstract, verify=verify)
    if isinstance(state, dict) and "count" not in state:
        state.setdefault("counts", {})  # shared state with no steps taken yet
    if plan is not None and plan != saved_plan:
        return plan, migrate_flat_state(state, saved_plan, plan)
    return saved_plan, state


def save_sharded_checkpoint(directory, step: int, splan, states, counts,
                            keep_last: Optional[int] = None,
                            verify: bool = True,
                            extra_aux: Optional[Dict[str, Any]] = None
                            ) -> Path:
    """Save a sharded-runtime snapshot: the ShardedPlan (shard map), every
    shard space's buffers, and the per-job global step counters, in one
    atomic commit.  ``states`` maps ``agg_id`` -> per-shard state dict;
    ``counts`` maps ``job_id`` -> step counter.  ``extra_aux`` merges
    additional JSON-able metadata into the aux record (e.g. the sharded
    runtime stamps ``shard_health`` so restore tooling can tell a
    checkpoint was taken on a degraded fleet); reserved keys are
    rejected."""
    from repro.ps.plan import sharded_plan_to_json

    tree = {"shards": dict(states), "counts": dict(counts)}
    aux = {
        "sharded_plan": sharded_plan_to_json(splan),
        "shard_leaves": {sid: sorted(st) for sid, st in states.items()},
        "jobs": sorted(counts),
    }
    if extra_aux:
        clash = sorted(set(extra_aux) & set(aux))
        if clash:
            raise ValueError(f"extra_aux may not override reserved aux "
                             f"keys {clash}")
        aux.update(extra_aux)
    return save_checkpoint(directory, step, tree, keep_last, verify, aux=aux)


def restore_sharded_checkpoint(directory, step: int, splan=None,
                               verify: bool = True):
    """Restore a sharded checkpoint; returns ``(splan, states, counts)``.

    With ``splan`` given (the restoring service's compiled ShardedPlan),
    shard states are migrated from the saved shard map onto it with the
    O(moved-bytes) sharded delta path -- a checkpoint taken under one
    fleet size restores under another (the elastic-restart path).  The
    abstract restore tree is rebuilt from the saved plan itself, so
    ``agg_id``s containing '/' round-trip exactly."""
    from repro.ps.elastic import migrate_sharded_state
    from repro.ps.plan import sharded_plan_from_json

    aux = load_aux(directory, step)
    if aux is None or "sharded_plan" not in aux:
        raise IOError(f"step {step} in {directory} is not a sharded "
                      f"PS checkpoint")
    saved_plan = sharded_plan_from_json(aux["sharded_plan"])
    abstract = {
        "shards": {
            sid: {
                k: jax.ShapeDtypeStruct((sp.total_len,), np.float32)
                for k in aux["shard_leaves"][sid]
            }
            for sid, sp in zip(saved_plan.shard_ids, saved_plan.shards)
        },
        "counts": {j: jax.ShapeDtypeStruct((), np.int32)
                   for j in aux["jobs"]},
    }
    tree = restore_checkpoint(directory, step, abstract, verify=verify)
    states, counts = tree["shards"], tree["counts"]
    if splan is not None and splan != saved_plan:
        states, _, _ = migrate_sharded_state(states, saved_plan, splan)
        return splan, states, counts
    return saved_plan, states, counts


class CheckpointManager:
    """Async saves + restart bookkeeping for the train driver."""

    def __init__(self, directory, keep_last: int = 3, save_every: int = 100):
        self.directory = Path(directory)
        self.keep_last = keep_last
        self.save_every = save_every
        self._thread: Optional[threading.Thread] = None

    def maybe_save(self, step: int, tree, blocking: bool = False) -> bool:
        if step % self.save_every != 0:
            return False
        self.wait()  # one in-flight save at a time
        host_tree = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), tree
        )
        if blocking:
            save_checkpoint(self.directory, step, host_tree, self.keep_last)
            return True
        self._thread = threading.Thread(
            target=save_checkpoint,
            args=(self.directory, step, host_tree, self.keep_last),
            daemon=True,
        )
        self._thread.start()
        return True

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore_latest(self, abstract_tree, shardings=None):
        step = latest_step(self.directory)
        if step is None:
            return None, None
        return step, restore_checkpoint(
            self.directory, step, abstract_tree, shardings
        )
