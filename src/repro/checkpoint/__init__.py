from .checkpoint import (
    save_checkpoint,
    restore_checkpoint,
    save_ps_checkpoint,
    restore_ps_checkpoint,
    save_sharded_checkpoint,
    restore_sharded_checkpoint,
    load_aux,
    latest_step,
    CheckpointManager,
)

__all__ = ["save_checkpoint", "restore_checkpoint", "save_ps_checkpoint",
           "restore_ps_checkpoint", "save_sharded_checkpoint",
           "restore_sharded_checkpoint", "load_aux", "latest_step",
           "CheckpointManager"]
