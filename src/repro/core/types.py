"""Core datatypes for the Parameter Service control plane.

The vocabulary follows the paper (§3): a *job* submits one model-aggregation
*task* per tensor; tasks are hosted by *Aggregators*; Aggregators belong to
*clusters* managed by a central *pMaster*.

Units: time in seconds, CPU in "server units" (1.0 == one Aggregator server's
CPU capacity, matching the paper's normalized free-slot arithmetic), tensor
sizes in bytes.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Tuple

# Numerical guard for floor(C / D) on floats (11.9999999 / 4 must count as 3).
_EPS = 1e-9


def iterations_per_cycle(cycle: float, duration: float) -> int:
    """Number of times a job with iteration `duration` executes per `cycle`.

    Paper §3.3.1: jobs with smaller iteration duration get executed for
    multiple iterations within one Aggregator execution cycle.
    """
    if duration <= 0:
        raise ValueError(f"iteration duration must be positive, got {duration}")
    if cycle + _EPS < duration:
        # Cycle shorter than the job's iteration: executes once per cycle by
        # definition (the cycle will be extended to max(D) by the caller).
        return 1
    return max(1, int(math.floor(cycle / duration + _EPS)))


def effective_iteration(cycle: float, duration: float) -> float:
    """Effective iteration duration d_j = C / floor(C / D_j)  (App. C)."""
    return cycle / iterations_per_cycle(cycle, duration)


def cyclic_loss(cycle: float, duration: float) -> float:
    """Performance loss L_j = (d_j - D_j) / d_j caused by cyclic execution."""
    d = effective_iteration(cycle, duration)
    if d <= 0:
        return 0.0
    return max(0.0, (d - duration) / d)


@dataclass(frozen=True)
class AggTask:
    """One model-aggregation task == one tensor of one job (paper footnote 1:

    each task produces one aggregation request per training iteration).
    `exec_time` is the profiled CPU time e_t to aggregate + update the tensor
    once (sum of worker pushes + optimizer update).
    """

    job_id: str
    tensor_id: int
    name: str
    nbytes: int
    exec_time: float

    @property
    def key(self) -> Tuple[str, int]:
        return (self.job_id, self.tensor_id)


@dataclass
class JobProfile:
    """Profiled characteristics of a training job (pMaster's job profiler).

    `iteration_duration` is the standalone iteration time D_j measured during
    the initial profiling phase; `required_servers` is the number of parameter
    servers the job would allocate under ps-lite (the paper's baseline and the
    denominator of the CPU-reduction ratio).
    """

    job_id: str
    model: str
    iteration_duration: float
    tasks: List[AggTask]
    n_workers: int = 2
    required_servers: int = 1

    def __post_init__(self) -> None:
        if self.iteration_duration <= 0:
            raise ValueError("iteration_duration must be positive")
        for t in self.tasks:
            if t.job_id != self.job_id:
                raise ValueError(f"task {t.name} belongs to {t.job_id}, not {self.job_id}")

    @property
    def total_exec_time(self) -> float:
        return sum(t.exec_time for t in self.tasks)

    @property
    def total_bytes(self) -> int:
        return sum(t.nbytes for t in self.tasks)

    @property
    def standalone_utilization(self) -> float:
        """Average CPU utilization if served by `required_servers` dedicated
        servers (the Fig. 2 quantity)."""
        return self.total_exec_time / (self.iteration_duration * self.required_servers)


@dataclass
class Aggregator:
    """A model-aggregation server hosting master tensor copies.

    Tracks its assigned tasks, the iteration duration of every job with tasks
    on it (needed for the execution-cycle math), and exposes the paper's
    cyclic-execution quantities: cycle C_n, busy time W_n, free slots F_n.
    """

    agg_id: str
    capacity: float = 1.0  # CPU units; 1.0 == one server
    cluster_id: Optional[str] = None
    tasks: Dict[Tuple[str, int], AggTask] = field(default_factory=dict)
    job_durations: Dict[str, float] = field(default_factory=dict)

    # ------------------------------------------------------------------ state
    def add_task(self, task: AggTask, job_duration: float) -> None:
        self.tasks[task.key] = task
        self.job_durations[task.job_id] = job_duration

    def remove_task(self, key: Tuple[str, int]) -> AggTask:
        task = self.tasks.pop(key)
        if not any(k[0] == task.job_id for k in self.tasks):
            self.job_durations.pop(task.job_id, None)
        return task

    def remove_job(self, job_id: str) -> List[AggTask]:
        removed = [t for k, t in list(self.tasks.items()) if k[0] == job_id]
        for t in removed:
            self.tasks.pop(t.key)
        self.job_durations.pop(job_id, None)
        return removed

    # -------------------------------------------------------------- quantities
    @property
    def job_ids(self) -> List[str]:
        return sorted(self.job_durations)

    @property
    def is_empty(self) -> bool:
        return not self.tasks

    def tasks_of(self, job_id: str) -> List[AggTask]:
        return [t for k, t in self.tasks.items() if k[0] == job_id]

    @property
    def cycle(self) -> float:
        """Execution cycle C_n = max iteration duration among hosted jobs."""
        if not self.job_durations:
            return 0.0
        return max(self.job_durations.values())

    def busy_time(self, cycle: Optional[float] = None) -> float:
        """W_n = sum over jobs of (executions per cycle * per-iter exec time)."""
        c = self.cycle if cycle is None else cycle
        if c <= 0:
            return 0.0
        total = 0.0
        for job_id, duration in self.job_durations.items():
            reps = iterations_per_cycle(c, duration)
            total += reps * sum(t.exec_time for t in self.tasks_of(job_id))
        return total

    def free_slots(self, cycle: Optional[float] = None) -> float:
        """F_n = capacity * C_n - W_n (free CPU-time within one cycle)."""
        c = self.cycle if cycle is None else cycle
        return self.capacity * c - self.busy_time(c)

    @property
    def utilization(self) -> float:
        c = self.cycle
        if c <= 0:
            return 0.0
        return self.busy_time(c) / (self.capacity * c)

    def clone(self) -> "Aggregator":
        return Aggregator(
            agg_id=self.agg_id,
            capacity=self.capacity,
            cluster_id=self.cluster_id,
            tasks=dict(self.tasks),
            job_durations=dict(self.job_durations),
        )


@dataclass
class AssignmentDecision:
    """Result of assigning a single task."""

    task: AggTask
    aggregator_id: str
    newly_allocated: bool


def cpu_reduction_ratio(required_servers: int, allocated_aggregators: int) -> float:
    """Paper §5.1 metric: (#param servers - #Aggregators) / #param servers."""
    if required_servers <= 0:
        return 0.0
    return (required_servers - allocated_aggregators) / required_servers
