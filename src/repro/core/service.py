"""ParameterService facade: the user-visible surface of the control plane.

Ties together pMaster + cluster controllers (cluster.py), the assignment
scheme (assignment.py), scaling (scaling.py), and migration bookkeeping
(migration.py).  It is also the *single source of truth* for the data
plane: ``compile_plan()`` compiles the live tensor->Aggregator assignment
into a multi-job ``FlatPlan`` (repro.ps.plan), and every placement-changing
event (``register_job``, ``job_exit``, ``periodic_rebalance``) emits an
``(old_plan, new_plan)`` pair to replan listeners so the data-plane runtime
(repro.ps.service_runtime.ServiceRuntime) can migrate all co-resident jobs'
flat Adam state without a restart.  The simulator (repro.sim) drives the
same object with job arrival/exit events.

Replan transactions (PR 9).  Every registry mutation (``register_job``,
``job_exit``, ``scale_out``, ``scale_in``, ``evacuate_aggregator``,
``periodic_rebalance``) runs as a commit-or-abort transaction: the task
registry (pMaster + job tables + last plan) is snapshotted, the mutation
and its replan notification run, and if a replan LISTENER fails -- i.e.
the data plane's quiesce -> migrate -> commit sequence died, e.g. on an
injected migration fault -- the registry is rolled back to the snapshot
and the whole mutation retried under ``retry_policy``
(:class:`repro.ps.faults.RetryPolicy`).  Exhausted retries raise
:class:`repro.ps.faults.ReplanAbortedError` with the registry restored,
so control and data plane always agree on a single layout.  Control
plane errors (duplicate job, unknown aggregator, over budget) and
``EngineQuarantinedError`` (a liveness failure retrying cannot fix)
propagate unchanged -- the rollback still runs for the latter.
"""

from __future__ import annotations

import copy
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Mapping, Optional, Tuple

from .assignment import AssignmentConfig
from .cluster import PMaster
from .migration import TensorMigration
from .perf_model import predict_all_losses, predict_iteration
from .types import Aggregator, JobProfile, cpu_reduction_ratio

# (old_plan | None, new_plan | None) -> None; plans are repro.ps.plan.FlatPlan
ReplanListener = Callable[[object, object], None]


class _ReplanFailure(Exception):
    """Internal marker: a replan LISTENER failed (retryable data-plane
    fault) -- distinguishes transaction retries from control-plane
    validation errors, which propagate unchanged."""

    def __init__(self, original: BaseException):
        self.original = original
        super().__init__(str(original))


@dataclass
class ParameterService:
    """Cluster-wide shared model-aggregation service (the paper's system)."""

    total_budget: int = 1024
    n_clusters: int = 1
    loss_limit: float = 0.1
    strict_paper: bool = False
    preserve_spread: bool = False
    plan_pad_to: int = 128  # shard padding granularity of compiled plans
    # Replan-transaction retry schedule; None -> RetryPolicy() defaults
    # (2 retries, no sleeping).  Shared type with the engines' apply
    # retries (repro.ps.faults.RetryPolicy).
    retry_policy: Optional[object] = None

    def __post_init__(self) -> None:
        self._config = AssignmentConfig(
            loss_limit=self.loss_limit, strict_paper=self.strict_paper,
            preserve_spread=self.preserve_spread,
        )
        self._pmaster = PMaster(
            total_budget=self.total_budget,
            n_clusters=self.n_clusters,
            config=self._config,
        )
        self._jobs: Dict[str, JobProfile] = {}
        self._migrations: List[TensorMigration] = []
        self._specs: Dict[str, Mapping[int, object]] = {}  # job -> {tid: TensorSpec}
        self._plan = None  # last compiled FlatPlan handed to listeners
        self._listeners: List[ReplanListener] = []
        # Transaction counters, surfaced in the runtimes' debug_stats().
        self.n_replan_commits = 0
        self.n_replan_aborts = 0
        self.n_replan_retries = 0

    # ------------------------------------------------------- replan txn
    def _resolve_retry_policy(self):
        if self.retry_policy is None:
            from repro.ps.faults import RetryPolicy

            self.retry_policy = RetryPolicy()
        return self.retry_policy

    def _registry_snapshot(self):
        """Deep-copy the task registry: everything a mutation + replan
        may touch (cheap -- the control plane is metadata-sized)."""
        return (copy.deepcopy(self._pmaster), dict(self._jobs),
                {j: dict(s) for j, s in self._specs.items()},
                list(self._migrations), self._plan)

    def _restore_registry(self, snap) -> None:
        (self._pmaster, self._jobs, self._specs,
         self._migrations, self._plan) = snap

    def _transact(self, op: str, mutate: Callable[[], object]):
        """Run ``mutate`` (a registry mutation ending in ``_replan()``)
        as a commit-or-abort transaction.  ``mutate`` must re-derive any
        registry references on each call: after an abort the snapshot's
        deep copies are installed, so objects from a failed attempt are
        stale."""
        policy = self._resolve_retry_policy()
        attempt = 0
        while True:
            attempt += 1
            snap = self._registry_snapshot()
            try:
                out = mutate()
            except _ReplanFailure as fail:
                self._restore_registry(snap)
                self.n_replan_aborts += 1
                if not policy.should_retry(attempt):
                    from repro.ps.faults import ReplanAbortedError

                    raise ReplanAbortedError(
                        op, attempt, fail.original) from fail.original
                self.n_replan_retries += 1
                policy.backoff(attempt)
            except Exception:
                # Control-plane error or a non-retryable liveness
                # failure: roll back, propagate unchanged.
                self._restore_registry(snap)
                raise
            else:
                self.n_replan_commits += 1
                return out

    # ------------------------------------------------------------------- API
    def register_job(self, job: JobProfile, specs=None) -> str:
        """Admit a job (assign all its model aggregations); returns cluster id.

        ``specs`` optionally binds the job's data-plane tensor metadata
        (``{tensor_id: repro.ps.plan.TensorSpec}``) so compiled plans carry
        real shapes/dtypes instead of nbytes-derived 1-D placeholders."""
        if job.job_id in self._jobs:
            raise ValueError(f"job {job.job_id} already registered")

        def mutate():
            cluster_id = self._pmaster.submit_job(job)
            self._jobs[job.job_id] = job
            if specs is not None:
                self._specs[job.job_id] = dict(specs)
            self._replan()
            return cluster_id

        return self._transact("register_job", mutate)

    def job_exit(self, job_id: str) -> None:
        if job_id not in self._jobs:
            raise KeyError(job_id)

        def mutate():
            self._jobs.pop(job_id)
            self._specs.pop(job_id, None)
            self._pmaster.job_exit(job_id)
            self._replan()

        self._transact("job_exit", mutate)

    def placement(self, job_id: str) -> Dict[int, str]:
        """tensor_id -> aggregator_id for a job (the Agent mapping table)."""
        out: Dict[int, str] = {}
        for agg in self.aggregators:
            for (jid, tid) in agg.tasks:
                if jid == job_id:
                    out[tid] = agg.agg_id
        return out

    # ----------------------------------------------------------- ServicePlan
    def compile_plan(self, pad_to: Optional[int] = None):
        """Compile the live Aggregator.tasks assignment into a multi-job
        FlatPlan: one shard per allocated Aggregator, segments keyed by
        ``(job_id, tensor_key)``.  This is the plan the data plane executes;
        ``build_flat_plan`` is only the standalone single-job path."""
        from repro.ps.plan import compile_service_plan

        return compile_service_plan(
            self.aggregators, self._specs,
            pad_to=self.plan_pad_to if pad_to is None else pad_to,
        )

    def compile_sharded_plan(self, pad_to: Optional[int] = None):
        """Compile the live assignment into per-Aggregator shard SPACES
        (``repro.ps.plan.ShardedPlan``): one independently sized flat
        layout per allocated Aggregator -- the sharded data plane's view
        of the same placement ``compile_plan`` flattens into one space."""
        from repro.ps.plan import compile_sharded_plan

        return compile_sharded_plan(
            self.aggregators, self._specs,
            pad_to=self.plan_pad_to if pad_to is None else pad_to,
        )

    # ------------------------------------------------------- elastic scaling
    def scale_out(self, n: int = 1) -> int:
        """Load-driven scale-out: split the busiest Aggregator's workload
        onto a freshly allocated one, ``n`` times (§3.3.2's growth arm,
        driven by the data plane's measured load instead of a job event).
        Returns how many Aggregators were actually added; every successful
        split triggers a replan so the data plane re-shards live."""
        from .cluster import OverBudget
        from .scaling import split_aggregator

        def mutate():
            added = 0
            for _ in range(max(0, n)):
                busiest = None
                for ctrl in self._pmaster.clusters.values():
                    for agg in ctrl.aggregators:
                        if len(agg.tasks) > 1 and (
                                busiest is None
                                or agg.busy_time() > busiest[1].busy_time()):
                            busiest = (ctrl, agg)
                if busiest is None:
                    break
                ctrl = busiest[0]
                try:
                    fresh = ctrl._allocate()
                except OverBudget:
                    if not self._pmaster._grant_budget(ctrl):
                        break
                    fresh = ctrl._allocate()
                if not split_aggregator(ctrl.aggregators, fresh, ctrl.jobs,
                                        self._config):
                    break
                added += 1
            if added:
                self._replan()
            return added

        return self._transact("scale_out", mutate)

    def scale_in(self, n: int = 1) -> int:
        """Load-driven scale-in: drain the least-loaded Aggregator into
        the rest of its cluster (no new allocations), ``n`` times --
        exactly the paper's recycling move, here triggered by low measured
        load.  Returns Aggregators recycled; replans on any change."""
        from .scaling import recycle_aggregators

        def mutate():
            removed = 0
            for _ in range(max(0, n)):
                ctrl = max(
                    (c for c in self._pmaster.clusters.values()
                     if c.n_aggregators > 1),
                    key=lambda c: c.n_aggregators, default=None)
                if ctrl is None:
                    break
                got = recycle_aggregators(ctrl.aggregators, ctrl.jobs,
                                          self._config, max_rounds=1)
                if not got:
                    break
                removed += got
            if removed:
                self._replan()
            return removed

        return self._transact("scale_in", mutate)

    def evacuate_aggregator(self, agg_id: str) -> int:
        """Declare ONE Aggregator lost and re-host its tasks on the rest
        of its cluster -- the control-plane half of shard-loss recovery
        (the data-plane half, state migration, rides the replan this
        triggers; see ``ShardedServiceRuntime.recover_shard``).

        Unlike ``scale_in`` this names its victim and cannot refuse:
        tasks are force-placed on survivors even past the loss limit,
        and a fresh Aggregator is allocated only if the victim was the
        cluster's last one.  Returns the number of tasks moved; raises
        ``ValueError`` for an unknown ``agg_id``."""
        from .cluster import OverBudget
        from .scaling import evacuate_aggregator

        if all(a.agg_id != agg_id for a in self.aggregators):
            raise ValueError(
                f"unknown aggregator {agg_id!r} "
                f"(have {[a.agg_id for a in self.aggregators]})")

        def mutate():
            for ctrl in self._pmaster.clusters.values():
                victim = next((a for a in ctrl.aggregators
                               if a.agg_id == agg_id), None)
                if victim is None:
                    continue

                def _allocate():
                    try:
                        return ctrl._allocate()
                    except OverBudget:
                        if not self._pmaster._grant_budget(ctrl):
                            raise
                        return ctrl._allocate()

                moved = evacuate_aggregator(
                    ctrl.aggregators, victim, ctrl.jobs, self._config,
                    allocator=_allocate)
                self._replan()
                return moved
            raise ValueError(f"unknown aggregator {agg_id!r}")

        return self._transact("evacuate_aggregator", mutate)

    @property
    def current_plan(self):
        """Plan as of the last placement change (None before any job)."""
        return self._plan

    def on_replan(self, listener: ReplanListener) -> None:
        """Subscribe to ``(old_plan, new_plan)`` placement changes.  If jobs
        are already placed, the listener immediately sees (None, plan)."""
        self._listeners.append(listener)
        if self._jobs:
            if self._plan is None:
                self._plan = self.compile_plan()
            listener(None, self._plan)

    def _replan(self) -> None:
        if not self._listeners:
            return
        new = self.compile_plan() if self._jobs else None
        if new == self._plan:
            return
        old, self._plan = self._plan, new
        try:
            for listener in self._listeners:
                listener(old, new)
        except Exception as exc:
            from repro.ps.faults import EngineQuarantinedError

            if isinstance(exc, EngineQuarantinedError):
                # A dead lane blocks the quiesce; retrying the replan
                # cannot revive it -- roll back, surface for recovery.
                raise
            # Data-plane failure mid-replan: mark it retryable so the
            # enclosing transaction rolls the registry back and retries.
            raise _ReplanFailure(exc) from exc

    # ------------------------------------------------------------ inspection
    @property
    def aggregators(self) -> List[Aggregator]:
        return [
            a
            for ctrl in self._pmaster.clusters.values()
            for a in ctrl.aggregators
        ]

    @property
    def n_aggregators(self) -> int:
        return len(self.aggregators)

    def predicted_losses(self) -> Dict[str, float]:
        return predict_all_losses(self._jobs, self.aggregators)

    def predicted_iteration(self, job_id: str) -> float:
        return predict_iteration(self._jobs[job_id], self.aggregators)

    def cpu_reduction(self) -> float:
        required = sum(j.required_servers for j in self._jobs.values())
        return cpu_reduction_ratio(required, self.n_aggregators)

    def utilizations(self) -> Dict[str, float]:
        return {a.agg_id: a.utilization for a in self.aggregators}

    def periodic_rebalance(self) -> None:
        def mutate():
            self._pmaster.periodic_rebalance()
            self._replan()

        self._transact("periodic_rebalance", mutate)

    def stats(self) -> Dict[str, float]:
        s = self._pmaster.stats()
        losses = self.predicted_losses()
        s["max_loss"] = max(losses.values(), default=0.0)
        s["mean_utilization"] = (
            sum(self.utilizations().values()) / max(1, self.n_aggregators)
        )
        return s
