"""ParameterService facade: the user-visible surface of the control plane.

Ties together pMaster + cluster controllers (cluster.py), the assignment
scheme (assignment.py), scaling (scaling.py), and migration bookkeeping
(migration.py). The data plane (repro.ps) asks this object where each
tensor's aggregation lives; the simulator (repro.sim) drives it with job
arrival/exit events.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from .assignment import AssignmentConfig
from .cluster import PMaster
from .migration import TensorMigration
from .perf_model import predict_all_losses, predict_iteration
from .types import Aggregator, JobProfile, cpu_reduction_ratio


@dataclass
class ParameterService:
    """Cluster-wide shared model-aggregation service (the paper's system)."""

    total_budget: int = 1024
    n_clusters: int = 1
    loss_limit: float = 0.1
    strict_paper: bool = False
    preserve_spread: bool = False

    def __post_init__(self) -> None:
        self._config = AssignmentConfig(
            loss_limit=self.loss_limit, strict_paper=self.strict_paper,
            preserve_spread=self.preserve_spread,
        )
        self._pmaster = PMaster(
            total_budget=self.total_budget,
            n_clusters=self.n_clusters,
            config=self._config,
        )
        self._jobs: Dict[str, JobProfile] = {}
        self._migrations: List[TensorMigration] = []

    # ------------------------------------------------------------------- API
    def register_job(self, job: JobProfile) -> str:
        """Admit a job (assign all its model aggregations); returns cluster id."""
        if job.job_id in self._jobs:
            raise ValueError(f"job {job.job_id} already registered")
        cluster_id = self._pmaster.submit_job(job)
        self._jobs[job.job_id] = job
        return cluster_id

    def job_exit(self, job_id: str) -> None:
        self._jobs.pop(job_id)
        self._pmaster.job_exit(job_id)

    def placement(self, job_id: str) -> Dict[int, str]:
        """tensor_id -> aggregator_id for a job (the Agent mapping table)."""
        out: Dict[int, str] = {}
        for agg in self.aggregators:
            for (jid, tid) in agg.tasks:
                if jid == job_id:
                    out[tid] = agg.agg_id
        return out

    # ------------------------------------------------------------ inspection
    @property
    def aggregators(self) -> List[Aggregator]:
        return [
            a
            for ctrl in self._pmaster.clusters.values()
            for a in ctrl.aggregators
        ]

    @property
    def n_aggregators(self) -> int:
        return len(self.aggregators)

    def predicted_losses(self) -> Dict[str, float]:
        return predict_all_losses(self._jobs, self.aggregators)

    def predicted_iteration(self, job_id: str) -> float:
        return predict_iteration(self._jobs[job_id], self.aggregators)

    def cpu_reduction(self) -> float:
        required = sum(j.required_servers for j in self._jobs.values())
        return cpu_reduction_ratio(required, self.n_aggregators)

    def utilizations(self) -> Dict[str, float]:
        return {a.agg_id: a.utilization for a in self.aggregators}

    def periodic_rebalance(self) -> None:
        self._pmaster.periodic_rebalance()

    def stats(self) -> Dict[str, float]:
        s = self._pmaster.stats()
        losses = self.predicted_losses()
        s["max_loss"] = max(losses.values(), default=0.0)
        s["mean_utilization"] = (
            sum(self.utilizations().values()) / max(1, self.n_aggregators)
        )
        return s
