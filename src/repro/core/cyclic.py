"""Cyclic execution of an Aggregator (paper §3.3.1).

Builds the concrete per-cycle timetable of aggregation slots for the tasks
packed on one Aggregator, and implements the paper's outlier policy for late
(straggler-delayed) requests: run in the current cycle iff enough spare CPU
remains after reserving the still-scheduled slots, otherwise postpone one
cycle (worst case: the job is delayed by exactly one iteration).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from .types import Aggregator, iterations_per_cycle


@dataclass(frozen=True)
class Slot:
    """One scheduled execution of one task within the cycle."""

    job_id: str
    tensor_id: int
    start: float
    duration: float
    repetition: int  # which of the job's floor(C/D) executions this is

    @property
    def end(self) -> float:
        return self.start + self.duration


@dataclass
class CyclicSchedule:
    """Concrete timetable for one Aggregator cycle.

    Slots are laid out earliest-deadline-first: repetition r of job j becomes
    *available* at r * d_j (the gradients exist only after that iteration's
    backward pass) and must finish by (r + 1) * d_j to not delay the next
    iteration. We schedule greedily by deadline, which is optimal for a single
    machine with release times when preemption is allowed (we allow slot
    splitting implicitly by tracking cumulative lateness instead).
    """

    cycle: float
    capacity: float
    slots: List[Slot] = field(default_factory=list)

    @property
    def busy_time(self) -> float:
        return sum(s.duration for s in self.slots)

    @property
    def utilization(self) -> float:
        if self.cycle <= 0:
            return 0.0
        return self.busy_time / (self.capacity * self.cycle)

    def free_after(self, t: float) -> float:
        """Free CPU-time in [t, cycle] after reserving remaining slots."""
        remaining = sum(s.duration for s in self.slots if s.end > t)
        return max(0.0, self.capacity * (self.cycle - t) - remaining)


def build_schedule(agg: Aggregator) -> CyclicSchedule:
    """Lay out all task executions of one cycle, EDF by repetition deadline."""
    cycle = agg.cycle
    sched = CyclicSchedule(cycle=cycle, capacity=agg.capacity)
    if cycle <= 0:
        return sched

    # (release, deadline, job, tensor, duration, repetition)
    pending: List[Tuple[float, float, str, int, float, int]] = []
    for job_id, duration_j in agg.job_durations.items():
        reps = iterations_per_cycle(cycle, duration_j)
        d_eff = cycle / reps
        for task in agg.tasks_of(job_id):
            for r in range(reps):
                pending.append(
                    (r * d_eff, (r + 1) * d_eff, job_id, task.tensor_id, task.exec_time, r)
                )
    pending.sort(key=lambda p: (p[1], p[0]))  # EDF

    clock = 0.0
    for release, _deadline, job_id, tensor_id, dur, rep in pending:
        start = max(clock, release)
        sched.slots.append(Slot(job_id, tensor_id, start, dur, rep))
        clock = start + dur / max(agg.capacity, 1e-12)
    return sched


@dataclass(frozen=True)
class LateRequestOutcome:
    executed_now: bool
    postponed_iterations: int  # 0 or 1 (paper: "worst case... one iteration")


def admit_late_request(
    sched: CyclicSchedule, arrival: float, exec_time: float
) -> LateRequestOutcome:
    """Paper §3.3.1 'Handling Outliers in Cyclic Execution'.

    A request arriving `arrival` seconds into the cycle (late vs its slot) is
    executed now iff the Aggregator still has `exec_time` of spare CPU after
    reserving every remaining scheduled slot; otherwise it is postponed to the
    next cycle so co-located aggregations are unaffected.
    """
    if sched.free_after(arrival) >= exec_time - 1e-12:
        return LateRequestOutcome(executed_now=True, postponed_iterations=0)
    return LateRequestOutcome(executed_now=False, postponed_iterations=1)
