"""The assignment problem as an integer program (paper Appendix C).

Variables: p_tn in {0,1} -- task t assigned to Aggregator n.
Objective: minimize max_j L_j with
    C_n = max_{t on n} D_{job(t)}
    d_j = max_{t of j on n} C_n / floor(C_n / D_j)
    W_n = sum_j sum_{t of j on n} e_t * floor(C_n / d_j)
    L_j = (d_j - D_j) / d_j
Constraints: each task on exactly one Aggregator; W_n <= capacity * C_n.

The paper calls the IP NP-hard and infeasible at scale; we ship an exact
brute-force solver for tiny instances (used by tests to bound the heuristic's
optimality gap) plus the shared objective evaluator.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from .types import AggTask, JobProfile, effective_iteration, iterations_per_cycle

Assignment = Dict[Tuple[str, int], int]  # task key -> aggregator index


@dataclass(frozen=True)
class Evaluation:
    feasible: bool
    max_loss: float
    per_job_loss: Dict[str, float]
    n_aggregators: int


def evaluate(
    jobs: Sequence[JobProfile],
    assignment: Assignment,
    n_aggregators: int,
    capacity: float = 1.0,
) -> Evaluation:
    """Evaluate the App.-C objective/constraints for a complete assignment."""
    by_job = {j.job_id: j for j in jobs}
    # Aggregator -> job ids/tasks hosted.
    hosted: Dict[int, List[AggTask]] = {n: [] for n in range(n_aggregators)}
    for job in jobs:
        for task in job.tasks:
            n = assignment.get(task.key)
            if n is None:
                return Evaluation(False, float("inf"), {}, n_aggregators)
            hosted[n].append(task)

    cycles: Dict[int, float] = {}
    for n, tasks in hosted.items():
        if tasks:
            cycles[n] = max(by_job[t.job_id].iteration_duration for t in tasks)

    # d_j = max over aggregators hosting any of j's tasks.
    per_job_d: Dict[str, float] = {}
    for job in jobs:
        d = job.iteration_duration
        for task in job.tasks:
            n = assignment[task.key]
            d = max(d, effective_iteration(cycles[n], job.iteration_duration))
        per_job_d[job.job_id] = d

    # W_n <= capacity * C_n
    feasible = True
    for n, tasks in hosted.items():
        if not tasks:
            continue
        c = cycles[n]
        w = 0.0
        job_ids = {t.job_id for t in tasks}
        for jid in job_ids:
            reps = iterations_per_cycle(c, by_job[jid].iteration_duration)
            w += reps * sum(t.exec_time for t in tasks if t.job_id == jid)
        if w > capacity * c + 1e-9:
            feasible = False

    losses = {
        jid: max(0.0, (d - by_job[jid].iteration_duration) / d)
        for jid, d in per_job_d.items()
    }
    return Evaluation(feasible, max(losses.values(), default=0.0), losses, n_aggregators)


def brute_force(
    jobs: Sequence[JobProfile],
    n_aggregators: int,
    capacity: float = 1.0,
) -> Optional[Tuple[Assignment, Evaluation]]:
    """Exact search over all placements (tiny instances only: n_tasks^n small)."""
    tasks = [t for j in jobs for t in j.tasks]
    if n_aggregators ** len(tasks) > 2_000_000:
        raise ValueError("instance too large for brute force")
    best: Optional[Tuple[Assignment, Evaluation]] = None
    for combo in itertools.product(range(n_aggregators), repeat=len(tasks)):
        assignment = {t.key: n for t, n in zip(tasks, combo)}
        ev = evaluate(jobs, assignment, n_aggregators, capacity)
        if not ev.feasible:
            continue
        if best is None or ev.max_loss < best[1].max_loss - 1e-12:
            best = (assignment, ev)
    return best
