"""Parameter Service control plane (the paper's primary contribution).

Public surface:
  ParameterService      cluster-wide shared aggregation service facade
  JobProfile / AggTask  profiled job description
  assignment            Pseudocode-1 heuristic + ps-lite/AutoPS placements
  cyclic                cyclic execution schedules + straggler outliers
  migration             tensor-migration protocol + overlap cost model
  ip_model              Appendix-C IP evaluator + exact tiny-instance solver
"""

from .types import (
    AggTask,
    Aggregator,
    AssignmentDecision,
    JobProfile,
    cpu_reduction_ratio,
    cyclic_loss,
    effective_iteration,
    iterations_per_cycle,
)
from .assignment import (
    AssignmentConfig,
    DEFAULT_LOSS_LIMIT,
    assign_job,
    assign_task,
    balanced_shard_assignment,
    round_robin_shard_assignment,
    shard_imbalance,
)
from .service import ParameterService
from .perf_model import predict_iteration, predict_loss, predict_all_losses

__all__ = [
    "AggTask",
    "Aggregator",
    "AssignmentDecision",
    "AssignmentConfig",
    "DEFAULT_LOSS_LIMIT",
    "JobProfile",
    "ParameterService",
    "assign_job",
    "assign_task",
    "balanced_shard_assignment",
    "round_robin_shard_assignment",
    "shard_imbalance",
    "cpu_reduction_ratio",
    "cyclic_loss",
    "effective_iteration",
    "iterations_per_cycle",
    "predict_iteration",
    "predict_loss",
    "predict_all_losses",
]
