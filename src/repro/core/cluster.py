"""Two-level Aggregator-cluster management (paper §3.3.3).

pMaster no longer scans every Aggregator: the pool is split into independent
clusters, each run by a ClusterController that performs per-task assignment
(Pseudocode 1) within its own Aggregators. pMaster only does best-fit
*cluster* selection per arriving job (sufficient but least free CPU), which
bounds assignment work and confines reassignment blast radius to one cluster.

Hybrid resource scaling: controllers request allocations on demand (job
events) subject to pMaster approval; pMaster additionally rebalances cluster
budgets on a fixed period from demand measured over the last period.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from . import perf_model, scaling
from .assignment import AssignmentConfig
from .types import Aggregator, JobProfile, cpu_reduction_ratio


@dataclass
class ClusterController:
    """Owns one cluster's Aggregators and its jobs' placements."""

    cluster_id: str
    budget: int  # max Aggregators pMaster currently approves for this cluster
    config: AssignmentConfig = field(default_factory=AssignmentConfig)
    aggregators: List[Aggregator] = field(default_factory=list)
    jobs: Dict[str, JobProfile] = field(default_factory=dict)
    _ids: "itertools.count[int]" = field(default_factory=itertools.count)
    # demand accounting for pMaster's periodic rebalance
    denied_allocations: int = 0

    def _allocate(self) -> Aggregator:
        if len(self.aggregators) >= self.budget:
            self.denied_allocations += 1
            raise OverBudget(self.cluster_id)
        return Aggregator(agg_id=f"{self.cluster_id}/agg{next(self._ids)}",
                          cluster_id=self.cluster_id)

    # The allocator passed into assignment must append nothing itself --
    # assign_task appends. It may raise OverBudget, surfaced to pMaster.
    def admit_job(self, job: JobProfile) -> int:
        try:
            if not self.aggregators:
                # First job in the cluster: standalone mode. AutoPS gives the
                # job its parameter-server requirement, placed balanced
                # (Fig. 7 / Fig. 10: "following its parameter server
                # requirement, AutoPS allocates 2 Aggregators for it").
                new = self._admit_standalone(job)
            else:
                new, _ = scaling.admit_job(
                    job, self.aggregators, self.jobs, self._allocate, self.config
                )
        except OverBudget:
            # Atomic admission: roll back partial placements so a budget-
            # granted retry starts clean (otherwise duplicate task copies
            # inflate busy time and admission never converges).
            scaling.remove_job(self.aggregators, job.job_id)
            self.aggregators[:] = [a for a in self.aggregators if not a.is_empty]
            raise
        self.jobs[job.job_id] = job
        return new

    def _admit_standalone(self, job: JobProfile) -> int:
        from .assignment import balanced_shard_assignment

        n = max(1, job.required_servers)
        fresh = [self._allocate() for _ in range(n)]
        shards = balanced_shard_assignment(job, n)
        for idx, agg in enumerate(fresh):
            for task in shards[idx]:
                agg.add_task(task, job.iteration_duration)
        self.aggregators.extend(fresh)
        return n

    def release_job(self, job_id: str) -> Tuple[int, int]:
        self.jobs.pop(job_id, None)
        return scaling.release_job(job_id, self.aggregators, self.jobs, self.config)

    @property
    def free_cpu(self) -> float:
        """Free CPU slots across the cluster, counting unallocated budget."""
        used = sum(a.utilization * a.capacity for a in self.aggregators)
        return self.budget - used

    @property
    def n_aggregators(self) -> int:
        return len(self.aggregators)

    def losses(self) -> Dict[str, float]:
        return perf_model.predict_all_losses(self.jobs, self.aggregators)


class OverBudget(Exception):
    def __init__(self, cluster_id: str):
        super().__init__(f"cluster {cluster_id} at Aggregator budget")
        self.cluster_id = cluster_id


@dataclass
class PMaster:
    """Centralized manager: cluster bookkeeping + best-fit job forwarding.

    `total_budget` is the machine pool available for Aggregators; it is
    divided into `n_clusters` controller budgets, periodically rebalanced
    toward measured demand and topped-up on demand when denials exceed
    `on_demand_threshold` (hybrid scaling, §3.3.3).
    """

    total_budget: int
    n_clusters: int = 1
    config: AssignmentConfig = field(default_factory=AssignmentConfig)
    on_demand_threshold: int = 1
    clusters: Dict[str, ClusterController] = field(init=False)
    job_to_cluster: Dict[str, str] = field(default_factory=dict)

    def __post_init__(self) -> None:
        per = max(1, self.total_budget // self.n_clusters)
        self.clusters = {}
        for i in range(self.n_clusters):
            cid = f"c{i}"
            self.clusters[cid] = ClusterController(cid, budget=per, config=self.config)

    # ------------------------------------------------------------- forwarding
    def _best_fit_cluster(self, job: JobProfile) -> ClusterController:
        """Sufficient but least free CPU (paper: best-fit by total job CPU)."""
        demand = job.total_exec_time / job.iteration_duration  # avg CPU units
        fitting = [c for c in self.clusters.values() if c.free_cpu >= demand]
        pool = fitting or list(self.clusters.values())
        return min(pool, key=lambda c: c.free_cpu)

    def submit_job(self, job: JobProfile) -> str:
        ctrl = self._best_fit_cluster(job)
        attempts = 0
        while True:
            try:
                ctrl.admit_job(job)
                break
            except OverBudget:
                # On-demand scaling: approve extra budget if the pool allows.
                # Grant the job's full server requirement at once so a burst
                # arrival converges in O(1) retries.
                attempts += 1
                granted = 0
                for _ in range(max(1, job.required_servers)):
                    if self._grant_budget(ctrl):
                        granted += 1
                if granted == 0 or attempts > 64:
                    raise
        self.job_to_cluster[job.job_id] = ctrl.cluster_id
        return ctrl.cluster_id

    def job_exit(self, job_id: str) -> None:
        cid = self.job_to_cluster.pop(job_id)
        self.clusters[cid].release_job(job_id)

    def _grant_budget(self, ctrl: ClusterController) -> bool:
        if self.allocated_budget < self.total_budget:
            ctrl.budget += 1
            return True
        # Reclaim slack from the most over-provisioned other cluster.
        donor = max(
            (c for c in self.clusters.values() if c is not ctrl),
            key=lambda c: c.budget - c.n_aggregators,
            default=None,
        )
        if donor is not None and donor.budget - donor.n_aggregators > 0:
            donor.budget -= 1
            ctrl.budget += 1
            return True
        return False

    # ------------------------------------------------------------- accounting
    @property
    def allocated_budget(self) -> int:
        return sum(c.budget for c in self.clusters.values())

    @property
    def n_aggregators(self) -> int:
        return sum(c.n_aggregators for c in self.clusters.values())

    def periodic_rebalance(self) -> None:
        """Shift budget toward clusters that saw denials last period."""
        for ctrl in self.clusters.values():
            while ctrl.denied_allocations > 0:
                ctrl.denied_allocations -= 1
                if not self._grant_budget(ctrl):
                    break
            ctrl.denied_allocations = 0
        # Shrink budgets back toward usage (release idle machines).
        for ctrl in self.clusters.values():
            slack = ctrl.budget - max(ctrl.n_aggregators, 1)
            if slack > 0:
                ctrl.budget -= slack

    def stats(self) -> Dict[str, float]:
        required = 0
        for ctrl in self.clusters.values():
            required += sum(j.required_servers for j in ctrl.jobs.values())
        return {
            "n_jobs": float(len(self.job_to_cluster)),
            "n_aggregators": float(self.n_aggregators),
            "required_servers": float(required),
            "cpu_reduction_ratio": cpu_reduction_ratio(required, self.n_aggregators),
        }
