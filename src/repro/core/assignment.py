"""Model-aggregation assignment (paper §3.3.1, Pseudocode 1).

Given a new task t of job k and the set of allocated Aggregators N:

1. For every Aggregator n, estimate the new execution cycle
   C_n_est = max(C_n, D_k) and the resulting effective iteration duration of
   every job already on n (plus k). If any job's estimated loss reaches
   LossLimit, n is disqualified.
2. Compute estimated free CPU slots F_n_est under C_n_est.
3. Among qualified Aggregators, pick the *best fit*: sufficient but least
   free CPU slots (paper line 16-21).
4. If none qualifies or none fits, allocate a new Aggregator.

`strict_paper=True` reproduces the paper's literal fit test F >= e_t; the
default additionally accounts for the task executing floor(C/d_k) times per
cycle (the occupancy the task actually adds), which is strictly safer and is
recorded as a beyond-paper correction in DESIGN.md.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .types import (
    AggTask,
    Aggregator,
    AssignmentDecision,
    JobProfile,
    cyclic_loss,
    effective_iteration,
    iterations_per_cycle,
)

DEFAULT_LOSS_LIMIT = 0.1  # paper: "LossLimit, default is 0.1"

AggregatorAllocator = Callable[[], Aggregator]


@dataclass
class AssignmentConfig:
    loss_limit: float = DEFAULT_LOSS_LIMIT
    strict_paper: bool = False
    # Refuse placements that would overload an Aggregator's cycle even if the
    # literal free-slot test passes (W <= capacity * C, paper App. C constraint 2).
    enforce_capacity: bool = True
    # Optional bandwidth-provisioning mode: recycling never consolidates a
    # job below its parameter-server requirement. The paper's Fig.-11 numbers
    # (52.7% saving) require full consolidation, so this defaults off.
    preserve_spread: bool = False


def _estimate(
    agg: Aggregator, job_duration: float
) -> Tuple[float, float]:
    """(C_n_est, F_n_est) if a task of a job with `job_duration` joins `agg`."""
    cycle_est = max(agg.cycle, job_duration)
    free_est = agg.capacity * cycle_est - agg.busy_time(cycle_est)
    return cycle_est, free_est


def _loss_ok(agg: Aggregator, new_duration: float, loss_limit: float,
             extra_busy: float = 0.0, cyclic_only: bool = False) -> bool:
    """Check every co-located job's estimated TOTAL loss under the new cycle.

    Pseudocode 1 checks only the cyclic term; we additionally fold in the
    calibrated contention estimate at the post-assignment utilization so the
    admission filter and the feedback perf model agree (strict_paper mode
    keeps the literal cyclic-only check)."""
    from .perf_model import contention_factor

    cycle_est = max(agg.cycle, new_duration)
    rho = 1.0
    if not cyclic_only and cycle_est > 0:
        rho = (agg.busy_time(cycle_est) + extra_busy) / (agg.capacity * cycle_est)
    cf = 1.0 if cyclic_only else contention_factor(rho)
    durations = list(agg.job_durations.values()) + [new_duration]
    for d in durations:
        cyc = cyclic_loss(cycle_est, d)
        total = 1.0 - (1.0 - cyc) / cf
        if total >= loss_limit:
            return False
    return True


def assign_task(
    task: AggTask,
    job: JobProfile,
    aggregators: List[Aggregator],
    allocator: AggregatorAllocator,
    config: AssignmentConfig = AssignmentConfig(),
) -> AssignmentDecision:
    """Pseudocode 1: place one task, allocating a new Aggregator if needed."""
    if config.strict_paper:
        required = lambda cycle_est: task.exec_time  # noqa: E731  (paper line 17)
    else:
        def required(cycle_est: float) -> float:
            reps = iterations_per_cycle(cycle_est, job.iteration_duration)
            return reps * task.exec_time

    candidates: List[Tuple[float, Aggregator]] = []  # (F_n_est, aggregator)
    for agg in aggregators:
        cycle_est, free_est = _estimate(agg, job.iteration_duration)
        if not _loss_ok(agg, job.iteration_duration, config.loss_limit,
                        extra_busy=required(cycle_est),
                        cyclic_only=config.strict_paper):
            continue  # line 5-7: estimated loss >= LossLimit -> drop n
        candidates.append((free_est, agg))

    # Best fit: sufficient but least free CPU slots.
    best: Optional[Aggregator] = None
    best_free = float("inf")
    for free_est, agg in candidates:
        cycle_est = max(agg.cycle, job.iteration_duration)
        need = required(cycle_est)
        if free_est >= need and free_est < best_free:
            best, best_free = agg, free_est

    if best is not None:
        best.add_task(task, job.iteration_duration)
        if config.enforce_capacity and best.free_slots() < -1e-9:
            # The literal test admitted an overload (possible in strict mode
            # when a fast job repeats within the cycle) -- revert.
            best.remove_task(task.key)
        else:
            return AssignmentDecision(task, best.agg_id, newly_allocated=False)

    fresh = allocator()
    fresh.add_task(task, job.iteration_duration)
    aggregators.append(fresh)
    return AssignmentDecision(task, fresh.agg_id, newly_allocated=True)


def assign_job(
    job: JobProfile,
    aggregators: List[Aggregator],
    allocator: AggregatorAllocator,
    config: AssignmentConfig = AssignmentConfig(),
) -> List[AssignmentDecision]:
    """Assign all tasks of a job, largest exec time first (best-fit decreasing).

    Descending order matters: big tensors (e.g. VGG19's fc6 at ~72% of model
    bytes) must claim space before small ones fragment it.
    """
    decisions = []
    for task in sorted(job.tasks, key=lambda t: -t.exec_time):
        decisions.append(assign_task(task, job, aggregators, allocator, config))
    return decisions


def remove_job(aggregators: Sequence[Aggregator], job_id: str) -> List[AggTask]:
    removed: List[AggTask] = []
    for agg in aggregators:
        removed.extend(agg.remove_job(job_id))
    return removed


def balanced_shard_assignment(
    job: JobProfile, n_shards: int
) -> Dict[int, List[AggTask]]:
    """AutoPS standalone placement: greedy balance of task exec time across a
    fixed number of shards (the Fig. 7 'better balanced load distribution').

    Longest-processing-time-first greedy: 4/3-approximation of makespan.
    """
    loads = [0.0] * n_shards
    shards: Dict[int, List[AggTask]] = {i: [] for i in range(n_shards)}
    for task in sorted(job.tasks, key=lambda t: -t.exec_time):
        i = min(range(n_shards), key=lambda s: loads[s])
        loads[i] += task.exec_time
        shards[i].append(task)
    return shards


def round_robin_shard_assignment(
    job: JobProfile, n_shards: int
) -> Dict[int, List[AggTask]]:
    """ps-lite baseline placement: round-robin by tensor id (paper §5.2.1)."""
    shards: Dict[int, List[AggTask]] = {i: [] for i in range(n_shards)}
    for idx, task in enumerate(sorted(job.tasks, key=lambda t: t.tensor_id)):
        shards[idx % n_shards].append(task)
    return shards


def shard_imbalance(shards: Dict[int, List[AggTask]]) -> float:
    """max shard load / mean shard load; 1.0 == perfectly balanced.

    The paper's single-job speedup (<=1.17x, Fig. 7) comes from reducing this
    imbalance, because the slowest shard paces the Pull barrier.
    """
    loads = [sum(t.exec_time for t in ts) for ts in shards.values()]
    mean = sum(loads) / len(loads) if loads else 0.0
    if mean <= 0:
        return 1.0
    return max(loads) / mean
