"""Analytic performance model for packed model aggregation.

Predicts each job's effective iteration duration d_j given the current
task->Aggregator packing. Two effects are modelled:

1. **Cyclic execution** (paper §3.3.1 / App. C): an Aggregator executes with
   cycle C_n = max_j D_j over jobs hosted on it; a job executes
   floor(C_n / D_j) iterations per cycle, so its effective iteration is
   d_j^n = C_n / floor(C_n / D_j) >= D_j.

2. **Contention** (calibrated): the paper measures up to 9% residual loss at
   full packing (Fig. 9) that the pure cyclic model does not capture (equal-
   duration jobs have zero cyclic loss). We model it as a convex function of
   Aggregator utilization rho: contention(rho) = ALPHA * rho**P, calibrated so
   rho=1.0 -> 9% (the paper's observed worst case) and low utilization is
   nearly free. Overload (W_n > capacity * C_n) additionally stretches the
   cycle by the overload factor, because the CPU simply cannot finish the
   packed work in time.

The model is used by the assignment feedback loop (§3.3.2: revert + allocate
when observed loss exceeds LossLimit), by Aggregator recycling, and by the
discrete-event simulator.
"""

from __future__ import annotations

from typing import Dict, Iterable, Mapping

from .types import Aggregator, JobProfile, effective_iteration

# Contention calibration: loss(rho=1.0) == 0.09, matching the paper's measured
# worst-case multi-job loss (Fig. 9: "may lose up to 9% training speed").
CONTENTION_ALPHA = 0.09
CONTENTION_POWER = 3.0


def contention_factor(rho: float) -> float:
    """Multiplicative slowdown (>=1) from CPU contention at utilization rho."""
    rho = max(0.0, rho)
    slowdown = 1.0 + CONTENTION_ALPHA * min(rho, 1.0) ** CONTENTION_POWER
    if rho > 1.0:
        # Overloaded: the cycle stretches so all packed work fits.
        slowdown *= rho
    return slowdown


def predict_iteration(
    job: JobProfile, aggregators: Iterable[Aggregator]
) -> float:
    """Effective iteration duration of `job` under the current packing.

    A job is paced by its slowest aggregation path: the max over Aggregators
    hosting any of its tensors of (cyclic effective iteration x contention).
    Aggregators hosting none of the job's tensors are ignored.
    """
    d = job.iteration_duration
    for agg in aggregators:
        if not any(k[0] == job.job_id for k in agg.tasks):
            continue
        cycle = agg.cycle
        if cycle <= 0:
            continue
        rho = agg.busy_time(cycle) / (agg.capacity * cycle)
        d_n = effective_iteration(cycle, job.iteration_duration)
        d = max(d, d_n * contention_factor(rho))
    return d


def predict_loss(job: JobProfile, aggregators: Iterable[Aggregator]) -> float:
    """Predicted performance loss L_j = (d_j - D_j) / d_j."""
    d = predict_iteration(job, aggregators)
    if d <= 0:
        return 0.0
    return max(0.0, (d - job.iteration_duration) / d)


def predict_all_losses(
    jobs: Mapping[str, JobProfile], aggregators: Iterable[Aggregator]
) -> Dict[str, float]:
    aggs = list(aggregators)
    return {job_id: predict_loss(job, aggs) for job_id, job in jobs.items()}
