"""Aggregator scaling (paper §3.3.2).

Job arrival: pack via the assignment scheme; if the predicted performance of
the new job (or any co-located job) is worse than standalone by more than
LossLimit, revert, allocate one more Aggregator, and re-assign the whole job
— repeating until the loss is within bounds (the Fig. 10 case study path).

Job exit: remove the job's tasks, return empty Aggregators, then opportunist-
ically drain the least-loaded Aggregator into the others *without* new
allocations; recycle on success and repeat on the next least-loaded one.
"""

from __future__ import annotations

from typing import Callable, Dict, List, Optional, Tuple

from . import perf_model
from .assignment import (
    AssignmentConfig,
    AggregatorAllocator,
    assign_job,
    assign_task,
    remove_job,
)
from .types import AggTask, Aggregator, JobProfile


def admit_job(
    job: JobProfile,
    aggregators: List[Aggregator],
    jobs: Dict[str, JobProfile],
    allocator: AggregatorAllocator,
    config: AssignmentConfig = AssignmentConfig(),
    max_retries: int = 16,
) -> Tuple[int, int]:
    """Admit a job with the feedback-revert loop.

    Returns (n_new_aggregators, n_retries). `jobs` must already contain every
    running job's profile (used for loss prediction) but NOT the new job.
    """
    jobs_after = dict(jobs)
    jobs_after[job.job_id] = job

    pinned_new = 0  # Aggregators force-allocated by the feedback loop
    retries = 0
    while True:
        n_before = len(aggregators)
        decisions = assign_job(job, aggregators, allocator, config)
        new_from_packing = len(aggregators) - n_before

        losses = perf_model.predict_all_losses(jobs_after, aggregators)
        if max(losses.values(), default=0.0) < config.loss_limit or retries >= max_retries:
            return pinned_new + new_from_packing, retries

        # Revert the whole job, allocate one more dedicated Aggregator, retry
        # (paper: "add a new Aggregator and re-assign the entire job").
        retries += 1
        remove_job(aggregators, job.job_id)
        # Drop any aggregators that became empty from the failed packing.
        aggregators[:] = [a for a in aggregators if not a.is_empty or _is_pinned(a)]
        fresh = allocator()
        fresh.pinned = True  # type: ignore[attr-defined]  # keep across revert
        aggregators.append(fresh)
        pinned_new += 1


def _is_pinned(agg: Aggregator) -> bool:
    return bool(getattr(agg, "pinned", False))


def release_job(
    job_id: str,
    aggregators: List[Aggregator],
    jobs: Dict[str, JobProfile],
    config: AssignmentConfig = AssignmentConfig(),
) -> Tuple[int, int]:
    """Handle job exit. Returns (n_released_empty, n_recycled)."""
    remove_job(aggregators, job_id)
    released = [a for a in aggregators if a.is_empty]
    aggregators[:] = [a for a in aggregators if not a.is_empty]
    recycled = recycle_aggregators(aggregators, jobs, config)
    return len(released), recycled


def recycle_aggregators(
    aggregators: List[Aggregator],
    jobs: Dict[str, JobProfile],
    config: AssignmentConfig = AssignmentConfig(),
    max_rounds: int = 4,
) -> int:
    """Drain least-loaded Aggregators into the rest, no new allocations.

    Paper §3.3.2: "Starting from the least-loaded Aggregator, Parameter
    Service reassigns its workload to other Aggregators without new
    allocations allowed. If it succeeds ... repeat on the next least-loaded."
    `max_rounds` bounds the O(aggs * tasks) trial work per exit event.
    """
    recycled = 0
    while len(aggregators) > 1 and recycled < max_rounds:
        victim = min(aggregators, key=lambda a: a.busy_time())
        survivors = [a for a in aggregators if a is not victim]
        trial = [a.clone() for a in survivors]

        ok = True
        for task in sorted(victim.tasks.values(), key=lambda t: -t.exec_time):
            job = jobs.get(task.job_id)
            if job is None:
                ok = False
                break
            try:
                assign_task(task, job, trial, allocator=_refuse_allocation, config=config)
            except _NoAllocation:
                ok = False
                break
        if ok:
            losses = perf_model.predict_all_losses(jobs, trial)
            ok = max(losses.values(), default=0.0) < config.loss_limit
        if ok and config.preserve_spread:
            # Optional: keep each job's aggregation spread at its parameter-
            # server requirement (pull-bandwidth provisioning). Off by
            # default -- the paper's Fig.-11 savings require consolidation.
            for job in jobs.values():
                hosting = sum(
                    1 for a in trial if any(k[0] == job.job_id for k in a.tasks)
                )
                before = sum(
                    1 for a in aggregators
                    if any(k[0] == job.job_id for k in a.tasks)
                )
                floor = min(job.required_servers, before)
                if hosting < floor:
                    ok = False
                    break

        if not ok:
            return recycled
        # Commit the trial placement.
        aggregators[:] = trial
        recycled += 1
    return recycled


def split_aggregator(
    aggregators: List[Aggregator],
    fresh: Aggregator,
    jobs: Dict[str, JobProfile],
    config: AssignmentConfig = AssignmentConfig(),
) -> bool:
    """Shard split: offload ~half the busiest Aggregator onto ``fresh``.

    The load-driven half of §3.3.2's elasticity: where :func:`admit_job`
    grows the fleet on job ARRIVAL and :func:`recycle_aggregators` shrinks
    it on EXIT, this grows it on measured LOAD -- the autoscaler's
    scale-out action.  Tasks move greedily (largest exec_time first) from
    the busiest Aggregator until the fresh one carries half its busy time;
    ``fresh`` is appended to ``aggregators`` on success.  Returns False --
    and allocates nothing -- when no Aggregator has two tasks to split.
    """
    candidates = [a for a in aggregators if len(a.tasks) > 1]
    if not candidates:
        return False
    victim = max(candidates, key=lambda a: a.busy_time())
    target = victim.busy_time() / 2.0
    # Largest-first gives the halving greedy its classic 2/3 bound; skim
    # from a sorted snapshot so removal during iteration is safe.
    tasks = sorted(victim.tasks.values(), key=lambda t: -t.exec_time)
    for task in tasks:
        if len(victim.tasks) <= 1 or fresh.busy_time() >= target:
            break
        job = jobs.get(task.job_id)
        duration = (job.iteration_duration if job is not None
                    else victim.job_durations.get(task.job_id, 1.0))
        victim.remove_task(task.key)
        fresh.add_task(task, duration)
    if fresh.is_empty:
        return False
    aggregators.append(fresh)
    return True


def evacuate_aggregator(
    aggregators: List[Aggregator],
    victim: Aggregator,
    jobs: Dict[str, JobProfile],
    config: AssignmentConfig = AssignmentConfig(),
    allocator: Optional[AggregatorAllocator] = None,
) -> int:
    """Forced drain of ONE named Aggregator: the shard-loss recovery move.

    Unlike :func:`recycle_aggregators` -- an opportunistic shrink that
    backs off whenever the trial placement would degrade performance --
    evacuation must not fail: the victim is already lost (or condemned),
    so its tasks are re-hosted on the survivors even if that overloads
    them.  Tasks move largest ``exec_time`` first through the normal
    assignment scheme; when nothing fits under the loss limit the task
    is force-placed on the least-busy survivor (degraded beats down).
    ``allocator`` is consulted only when the victim was the ONLY
    Aggregator (recovery must produce *some* host).  Returns the number
    of tasks moved; ``victim`` is removed from ``aggregators``.
    """
    survivors = [a for a in aggregators if a is not victim]
    if not survivors:
        if allocator is None:
            raise _NoAllocation(
                f"cannot evacuate {victim.agg_id!r}: it is the only "
                f"Aggregator and no allocator was provided")
        survivors = [allocator()]
    moved = 0
    for task in sorted(victim.tasks.values(), key=lambda t: -t.exec_time):
        job = jobs.get(task.job_id)
        if job is not None and _safe_assign(task, job, survivors, config):
            moved += 1
            continue
        duration = (job.iteration_duration if job is not None
                    else victim.job_durations.get(task.job_id, 1.0))
        host = min(survivors, key=lambda a: a.busy_time())
        host.add_task(task, duration)
        moved += 1
    aggregators[:] = survivors
    return moved


def _refuse_allocation() -> Aggregator:
    raise _NoAllocation()


class _NoAllocation(Exception):
    pass


# assign_task calls allocator() when nothing fits; catch that as "failed".
def _safe_assign(task: AggTask, job: JobProfile, aggs: List[Aggregator], config) -> bool:
    try:
        assign_task(task, job, aggs, _refuse_allocation, config)
        return True
    except _NoAllocation:
        return False
