"""Tensor migration protocol (paper §3.2 + Appendix B).

State machine, data-consistency invariants, and an analytic overlap model of
worker-visible stall. The data-plane counterpart (actual JAX resharding of
parameter + optimizer-state arrays) lives in `repro.ps.elastic`; this module
is the control-plane protocol both the simulator and the runtime drive.

Protocol (App. B, Fig. 13):
  MIGRATE_INIT   pMaster -> old owner: remember (tensor, new owner)
  PULL_RESPONSE  old owner piggybacks new-owner identity on the next Pull;
                 every Agent updates its mapping table on receipt
  TENSOR_COPY    old -> new owner, overlapped with the worker's fwd/bwd window
  TENSOR_COPY_DONE  old owner -> pMaster
  PUSH           workers push this iteration's gradient to the NEW owner
  WORKER_DONE    new owner -> pMaster once pushes arrive
  COMPLETE       pMaster saw both notifications

Consistency invariants (App. B "Data Consistency"):
  I1  Agents route by mapping table; the table is updated atomically with the
      Pull response, so no Agent can push to the old owner after repointing.
  I2  The new owner must not run Update on the tensor before TENSOR_COPY_DONE
      (the master copy would be stale).
"""

from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import List, Optional


class MigrationState(enum.Enum):
    IDLE = "idle"
    INIT = "migrate_init"
    REPOINTED = "pull_piggybacked"  # Agents know the new owner
    COPYING = "tensor_copy"
    COPY_DONE = "tensor_copy_done"
    WORKER_DONE = "worker_done"
    COMPLETE = "complete"


_VALID = {
    MigrationState.IDLE: {MigrationState.INIT},
    MigrationState.INIT: {MigrationState.REPOINTED},
    MigrationState.REPOINTED: {MigrationState.COPYING},
    MigrationState.COPYING: {MigrationState.COPY_DONE},
    MigrationState.COPY_DONE: {MigrationState.WORKER_DONE},
    MigrationState.WORKER_DONE: {MigrationState.COMPLETE},
    MigrationState.COMPLETE: set(),
}


class ProtocolError(RuntimeError):
    pass


@dataclass
class TensorMigration:
    """Tracks one tensor's migration through the protocol."""

    job_id: str
    tensor_id: int
    src_aggregator: str
    dst_aggregator: str
    state: MigrationState = MigrationState.IDLE
    history: List[MigrationState] = field(default_factory=list)

    def advance(self, to: MigrationState) -> None:
        if to not in _VALID[self.state]:
            raise ProtocolError(
                f"invalid transition {self.state.value} -> {to.value} "
                f"for tensor {self.tensor_id} of {self.job_id}"
            )
        self.history.append(self.state)
        self.state = to

    # Invariant I2: Update is legal on dst only after the copy landed.
    def update_allowed_on(self, aggregator_id: str) -> bool:
        if aggregator_id == self.dst_aggregator:
            return self.state in (
                MigrationState.COPY_DONE,
                MigrationState.WORKER_DONE,
                MigrationState.COMPLETE,
            )
        if aggregator_id == self.src_aggregator:
            # The old owner may still serve Pull until repoint, but must not
            # apply updates once migration started (gradients now route to dst).
            return self.state == MigrationState.IDLE
        return False

    def run_to_completion(self) -> None:
        while self.state != MigrationState.COMPLETE:
            self.advance(_next(self.state))


def _next(state: MigrationState) -> MigrationState:
    (nxt,) = _VALID[state] or {state}
    return nxt


@dataclass(frozen=True)
class MigrationCost:
    """Analytic overlap model of one migration batch (App. B, Table 3)."""

    copy_time: float  # raw tensor-copy time (bytes / link bandwidth)
    window: float  # fwd/bwd window the copy can hide inside
    protocol_overhead: float  # serialization etc. ("several milliseconds")

    @property
    def visible_stall(self) -> float:
        """Worker-visible suspension: copy time beyond the hideable window
        plus the unavoidable per-migration protocol overhead."""
        return max(0.0, self.copy_time - self.window) + self.protocol_overhead


def migration_cost(
    nbytes: int,
    link_bandwidth: float,
    compute_window: float,
    protocol_overhead: float = 5e-3,
) -> MigrationCost:
    """Cost of migrating `nbytes` while the workers compute for
    `compute_window` seconds (the Pull->Update idle window of Fig. 1b)."""
    return MigrationCost(
        copy_time=nbytes / max(link_bandwidth, 1.0),
        window=compute_window,
        protocol_overhead=protocol_overhead,
    )


def checkpoint_restart_cost(
    model_bytes: int,
    storage_bandwidth: float,
    restart_overhead: float = 10.0,
) -> float:
    """The strawman the paper compares against (§3.2): pause, checkpoint,
    resume with the new assignment — 'tens of seconds' of full-job stall."""
    return 2 * model_bytes / max(storage_bandwidth, 1.0) + restart_overhead
