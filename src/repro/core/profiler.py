"""Job and server profilers (pMaster components, paper §3.1/Fig. 4).

The job profiler turns observed iteration timestamps into a robust iteration-
duration estimate D_j and per-tensor aggregation costs e_t; the server
profiler tracks each Aggregator's busy time so utilization can be reported
and fed to the scaling policy. The paper profiles a job standalone for ~100
iterations before packing (Fig. 10 case study: "after monitoring enough
iterations (default is 100)").
"""

from __future__ import annotations

import statistics
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from .types import AggTask, JobProfile

DEFAULT_PROFILE_ITERS = 100  # paper default monitoring window


@dataclass
class JobProfiler:
    """Accumulates per-iteration observations for one job."""

    job_id: str
    model: str = ""
    n_workers: int = 2
    required_servers: int = 1
    iteration_times: List[float] = field(default_factory=list)
    tensor_bytes: Dict[int, int] = field(default_factory=dict)
    tensor_exec: Dict[int, List[float]] = field(default_factory=list)  # type: ignore[assignment]

    def __post_init__(self) -> None:
        if not isinstance(self.tensor_exec, dict):
            self.tensor_exec = {}

    def record_iteration(self, duration: float) -> None:
        self.iteration_times.append(duration)

    def record_tensor(self, tensor_id: int, nbytes: int, exec_time: float) -> None:
        self.tensor_bytes[tensor_id] = nbytes
        self.tensor_exec.setdefault(tensor_id, []).append(exec_time)

    @property
    def ready(self) -> bool:
        return len(self.iteration_times) >= min(DEFAULT_PROFILE_ITERS, 3)

    def iteration_duration(self) -> float:
        """Median is robust to transient stragglers (§3.3.1 outliers)."""
        if not self.iteration_times:
            raise ValueError("no iterations recorded")
        return statistics.median(self.iteration_times)

    def finalize(self) -> JobProfile:
        tasks = []
        for tid in sorted(self.tensor_bytes):
            execs = self.tensor_exec.get(tid, [0.0])
            tasks.append(
                AggTask(
                    job_id=self.job_id,
                    tensor_id=tid,
                    name=f"t{tid}",
                    nbytes=self.tensor_bytes[tid],
                    exec_time=statistics.median(execs),
                )
            )
        return JobProfile(
            job_id=self.job_id,
            model=self.model,
            iteration_duration=self.iteration_duration(),
            tasks=tasks,
            n_workers=self.n_workers,
            required_servers=self.required_servers,
        )


@dataclass
class ServerProfiler:
    """Sliding-window busy/idle accounting for one Aggregator."""

    agg_id: str
    window: float = 60.0
    samples: List[Tuple[float, float]] = field(default_factory=list)  # (t, busy_frac)

    def record(self, t: float, busy_fraction: float) -> None:
        self.samples.append((t, busy_fraction))
        cutoff = t - self.window
        while self.samples and self.samples[0][0] < cutoff:
            self.samples.pop(0)

    def utilization(self) -> float:
        if not self.samples:
            return 0.0
        return sum(b for _, b in self.samples) / len(self.samples)


def profile_from_bytes(
    job_id: str,
    model: str,
    tensor_sizes: Sequence[int],
    iteration_duration: float,
    n_workers: int,
    required_servers: int,
    agg_throughput: float,
) -> JobProfile:
    """Synthesize a JobProfile from tensor byte sizes.

    e_t = n_workers * nbytes / agg_throughput: each aggregation sums
    `n_workers` pushed gradients and applies the update, so CPU time scales
    with total pushed bytes (the model behind Fig. 2/3's spikes).
    """
    tasks = [
        AggTask(
            job_id=job_id,
            tensor_id=i,
            name=f"t{i}",
            nbytes=int(nb),
            exec_time=n_workers * nb / agg_throughput,
        )
        for i, nb in enumerate(tensor_sizes)
    ]
    return JobProfile(
        job_id=job_id,
        model=model,
        iteration_duration=iteration_duration,
        tasks=tasks,
        n_workers=n_workers,
        required_servers=required_servers,
    )
