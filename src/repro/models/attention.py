"""Attention variants.

- `full_attention`: einsum GQA attention (training shapes; S x S scores).
- `chunked_attention`: online-softmax over KV chunks via lax.scan -- never
  materializes S x S; used for 32k prefill and as the jnp reference for the
  Pallas flash kernel.
- `decode_attention`: one new query token against a (possibly sequence-
  sharded) KV cache. Written as plain reductions so GSPMD partitions the
  softmax across cache shards (flash-decoding semantics fall out of the
  partitioner: partial max/sum get combined with collectives).

All support GQA: q heads HQ, kv heads HK, HQ % HK == 0.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _expand_kv(k, n_rep: int):
    """(B,S,HK,D) -> (B,S,HK*n_rep,D) by head repetition (GQA)."""
    if n_rep == 1:
        return k
    b, s, hk, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, hk, n_rep, d)).reshape(
        b, s, hk * n_rep, d
    )


def full_attention(q, k, v, causal: bool = True, scale: Optional[float] = None):
    """q: (B,S,HQ,D); k,v: (B,S,HK,D). Returns (B,S,HQ,D)."""
    b, sq, hq, d = q.shape
    hk = k.shape[2]
    scale = scale if scale is not None else d ** -0.5
    g = hq // hk
    qg = q.reshape(b, sq, hk, g, d)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg * scale, k).astype(jnp.float32)
    if causal:
        sk = k.shape[1]
        mask = jnp.tril(jnp.ones((sq, sk), bool), k=sk - sq)
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    return out.reshape(b, sq, hq, v.shape[-1])


def chunked_attention(
    q, k, v, causal: bool = True, chunk_k: int = 1024, scale: Optional[float] = None
):
    """Online-softmax attention, scanning KV chunks. Memory O(S * chunk).

    Under activation sharding (TP on the head dim), GQA KV heads are expanded
    to the full query-head count so every intermediate carries the tp-sharded
    head dim (hk alone is usually not divisible by the model axis)."""
    from repro.ps import act_sharding

    b, sq, hq, d = q.shape
    sk, hk = k.shape[1], k.shape[2]
    scale = scale if scale is not None else d ** -0.5
    if act_sharding.enabled() and hk != hq:
        k = _expand_kv(k, hq // hk)
        v = _expand_kv(v, hq // hk)
        hk = hq
    g = hq // hk
    n_chunks = max(1, sk // chunk_k)
    chunk_k = sk // n_chunks
    qg = (q * scale).reshape(b, sq, hk, g, d)
    kc = k.reshape(b, n_chunks, chunk_k, hk, d).swapaxes(0, 1)  # (n,B,c,hk,d)
    vc = v.reshape(b, n_chunks, chunk_k, hk, v.shape[-1]).swapaxes(0, 1)
    q_pos = jnp.arange(sq) + (sk - sq)  # aligned to the END of the kv sequence

    def body(carry, xs):
        acc, m, l = carry  # acc:(B,S,hk,g,d) fp32; m,l:(B,hk,g,S)
        k_i, v_i, base = xs
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k_i).astype(jnp.float32)
        if causal:
            kv_pos = base + jnp.arange(chunk_k)
            mask = q_pos[:, None] >= kv_pos[None, :]
            s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_i = jnp.max(s, axis=-1)  # (B,hk,g,S)
        m_new = jnp.maximum(m, m_i)
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha.transpose(0, 3, 1, 2)[..., None] + jnp.einsum(
            "bhgqk,bkhd->bqhgd", p.astype(v_i.dtype), v_i
        ).astype(jnp.float32)
        return (acc, m_new, l_new), None

    dv = v.shape[-1]
    acc0 = jnp.zeros((b, sq, hk, g, dv), jnp.float32)
    m0 = jnp.full((b, hk, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hk, g, sq), jnp.float32)
    bases = jnp.arange(n_chunks) * chunk_k
    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), (kc, vc, bases))
    out = acc / jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
    return out.reshape(b, sq, hq, dv).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cache_len=None, scale: Optional[float] = None):
    """q: (B,1,HQ,D); caches: (B,Smax,HK,D); cache_len: scalar or (B,) valid
    lengths (positions >= cache_len are masked). Softmax reductions are plain
    jnp ops so a sequence-sharded cache partitions into partial-softmax +
    collective combine under GSPMD."""
    b, _, hq, d = q.shape
    smax, hk = k_cache.shape[1], k_cache.shape[2]
    scale = scale if scale is not None else d ** -0.5
    g = hq // hk
    qg = (q * scale).reshape(b, hk, g, d)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache).astype(jnp.float32)
    if cache_len is not None:
        pos = jnp.arange(smax)
        valid = pos[None] < jnp.broadcast_to(jnp.asarray(cache_len), (b,))[:, None]
        s = jnp.where(valid[:, None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhgk,bkhd->bhgd", (p / l).astype(v_cache.dtype), v_cache)
    return out.reshape(b, 1, hq, d)
