"""Mixture-of-Experts FFN (token-choice top-k, capacity-based dispatch).

Dispatch uses sort-free position assignment (segment counts + stable ranks)
and k scatter-adds of (T, d) into an (E, C+1, d) buffer -- no (T*k, d) or
(T, E, C) materialization. With experts sharded over the "model" mesh axis
and tokens over "data", GSPMD lowers the scatter/gather pair to the expert-
parallel all-to-all exchange.

Aggregation relevance (the paper): every expert tensor is an independent
aggregation task; the PS control plane treats experts as first-class
migration units (hot-expert rebalancing).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import silu


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int  # per-expert hidden width
    n_shared: int = 0
    d_ff_shared: int = 0  # total shared-expert hidden width (= n_shared * d_ff usually)
    capacity_factor: float = 1.25
    aux_loss_coef: float = 0.01
    router_z_coef: float = 1e-3
    normalize_gates: bool = True  # DeepSeek/Mixtral renormalize top-k probs


def expert_positions(eid: jnp.ndarray, n_experts: int) -> jnp.ndarray:
    """Position of each slot within its expert's queue, preserving slot order.

    eid: (N,) int32 expert ids. Returns (N,) int32 ranks. Uses a stable
    argsort + exclusive segment starts; O(N log N), O(N) memory.
    """
    n = eid.shape[0]
    order = jnp.argsort(eid, stable=True)
    sorted_eid = eid[order]
    counts = jax.ops.segment_sum(jnp.ones_like(eid), eid, num_segments=n_experts)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    rank_sorted = jnp.arange(n, dtype=eid.dtype) - starts[sorted_eid]
    return jnp.zeros_like(eid).at[order].set(rank_sorted)


def route(x, router_w, cfg: MoEConfig):
    """Router: returns (gates (T,k) fp32, idx (T,k) int32, aux_loss, z_loss)."""
    logits = (x.astype(jnp.float32) @ router_w.astype(jnp.float32))  # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)
    if cfg.normalize_gates:
        gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss: E * sum_e f_e * P_e
    pe = jnp.mean(probs, axis=0)  # (E,)
    fe = jnp.mean(
        jax.nn.one_hot(idx[:, 0], cfg.n_experts, dtype=jnp.float32), axis=0
    )
    aux = cfg.n_experts * jnp.sum(fe * pe)
    z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    return gates, idx, aux, z


def moe_ffn(
    x: jnp.ndarray,  # (T, d)
    params: dict,
    cfg: MoEConfig,
    capacity: Optional[int] = None,
    n_groups: int = 1,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y (T, d), aux_losses scalar).

    GShard-style grouped dispatch: tokens are split into `n_groups` groups
    (sharded over the data axes), each with its own capacity C_g, so the
    dispatch scatter and combine gather never cross data shards -- the only
    communication left is the expert-parallel exchange around the expert
    GEMM. A global (ungrouped) scatter lowers to full-buffer all-reduces
    under GSPMD (measured: 9.3 TB/step on granite-moe train_4k).
    """
    from repro.ps import act_sharding as act

    t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    g = n_groups if t % n_groups == 0 else 1
    tg = t // g
    if capacity is None:
        capacity = max(1, int(cfg.capacity_factor * tg * k / e))

    gates, idx, aux, z = route(x, params["router"], cfg)

    # Per-group slot positions within each expert queue.
    idx_g = idx.reshape(g, tg, k)
    gates_g = gates.reshape(g, tg, k)
    pos_g = jax.vmap(
        lambda ei: expert_positions(ei.reshape(-1), e).reshape(tg, k)
    )(idx_g)  # (g, tg, k)

    xg = act.constrain(x.reshape(g, tg, d), "dp", None, None)
    gidx = jnp.arange(g, dtype=idx.dtype)[:, None]  # (g, 1) broadcast index

    # Dispatch: k group-local scatter-adds; overflow lands in slot C (dropped).
    buf = jnp.zeros((g, e, capacity + 1, d), x.dtype)
    for j in range(k):
        safe = jnp.minimum(pos_g[:, :, j], capacity)
        buf = buf.at[gidx, idx_g[:, :, j], safe].add(xg, mode="drop")
    buf = buf[:, :, :capacity]  # (g, E, C, d)
    buf = act.constrain(buf, "dp", "tp", None, None)  # EP exchange happens here

    # Expert computation (SwiGLU), experts sharded over "model".
    h = jnp.einsum("gecd,edf->gecf", buf, params["w_gate"])
    h = act.constrain(h, "dp", "tp", None, None)
    u = jnp.einsum("gecd,edf->gecf", buf, params["w_up"])
    out = jnp.einsum("gecf,efd->gecd", silu(h) * u, params["w_down"])
    out = act.constrain(out, "dp", "tp", None, None)
    out = jnp.concatenate([out, jnp.zeros((g, e, 1, d), out.dtype)], axis=2)
    out = act.constrain(out, "dp", None, None, None)  # back to group-local

    # Combine: k group-local gathers, gate-weighted.
    y = jnp.zeros((g, tg, d), x.dtype)
    for j in range(k):
        slot = jnp.minimum(pos_g[:, :, j], capacity)
        slot = jnp.where(pos_g[:, :, j] >= capacity, capacity, slot)
        y = y + gates_g[:, :, j, None].astype(x.dtype) * out[gidx, idx_g[:, :, j], slot]
    y = y.reshape(t, d)

    # Shared experts (always-on path, DeepSeek-style).
    if cfg.d_ff_shared > 0:
        sh = silu(x @ params["shared_gate"]) * (x @ params["shared_up"])
        sh = act.constrain(sh, "dp", "tp")
        y = y + sh @ params["shared_down"]

    losses = cfg.aux_loss_coef * aux + cfg.router_z_coef * z
    return y, losses


def moe_ffn_sharded(
    x3d: jnp.ndarray,  # (B, S, d): B % dp == 0 and (ideally) S % tp == 0
    params: dict,
    cfg: MoEConfig,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Expert-parallel MoE via shard_map with all-to-all exchange
    (production path).

    Tokens stay in their sequence-parallel layout (B over data axes, S over
    the model axis) all the way through -- flattening (B, S/tp, d) to a
    global (T, d) forces GSPMD to replicate the whole token tensor
    (measured: 4.9 TB/step of backward psum on deepseek-v2 train_4k).

    Per device: local routing + capacity dispatch into an (E, C_loc, d)
    buffer, all-to-all over the model axis so each shard receives the rows
    bound for its E/tp experts from every peer, local expert GEMM,
    all-to-all back, local gate-weighted combine. Per-layer exchange is
    2 x E x C_loc x d -- proportional to the DEVICE's tokens, not the step's.

    Capacity is per device (C_loc = cf * t_loc * k / E), the semantics of
    deployed EP systems.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.ps import act_sharding as act

    ctx = act._current()
    mesh = ctx["mesh"]
    dp_axes, tp_axes = ctx["dp"], ctx["tp"]
    tp = tp_axes[0]
    n_tp = mesh.shape[tp]

    b, s, d = x3d.shape
    e, k = cfg.n_experts, cfg.top_k
    assert e % n_tp == 0, f"experts {e} must divide model axis {n_tp}"
    s_sharded = s % n_tp == 0

    # Routing on the SP-sharded tensor (einsum over unsharded d: no comm).
    logits = (x3d.astype(jnp.float32)
              @ params["router"].astype(jnp.float32))  # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)
    if cfg.normalize_gates:
        gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    gates = gates.astype(x3d.dtype)
    pe = jnp.mean(probs, axis=(0, 1))
    fe = jnp.mean(jax.nn.one_hot(idx[..., 0], e, dtype=jnp.float32), axis=(0, 1))
    aux = e * jnp.sum(fe * pe)
    z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))

    dp_spec = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    s_spec = tp if s_sharded else None

    def body(x_loc, gates_loc, idx_loc, w_gate, w_up, w_down):
        bl, sl, _ = x_loc.shape
        t_loc = bl * sl
        x2 = x_loc.reshape(t_loc, d)
        cap = max(1, -(-int(cfg.capacity_factor * t_loc * k) // e))
        eid = idx_loc.reshape(-1)  # (t*k,)
        pos = expert_positions(eid, e)
        safe = jnp.minimum(pos, cap)
        x_rep = jnp.broadcast_to(x2[:, None, :], (t_loc, k, d)).reshape(-1, d)
        buf = jnp.zeros((e, cap + 1, d), x2.dtype)
        buf = buf.at[eid, safe].add(x_rep, mode="drop")[:, :cap]

        # EP exchange: send each peer its expert block, receive my experts'
        # rows from every peer.  (n_tp, E/tp, cap, d) <-> all_to_all.
        send = buf.reshape(n_tp, e // n_tp, cap, d)
        recv = jax.lax.all_to_all(send, tp, split_axis=0, concat_axis=0,
                                  tiled=False)
        rows = recv.transpose(1, 0, 2, 3).reshape(e // n_tp, n_tp * cap, d)

        h = jnp.einsum("ecd,edf->ecf", rows, w_gate)
        u = jnp.einsum("ecd,edf->ecf", rows, w_up)
        out = jnp.einsum("ecf,efd->ecd", silu(h) * u, w_down)

        back = out.reshape(e // n_tp, n_tp, cap, d).transpose(1, 0, 2, 3)
        mine = jax.lax.all_to_all(back, tp, split_axis=0, concat_axis=0,
                                  tiled=False)  # (n_tp, E/tp, cap, d)
        out_full = mine.reshape(e, cap, d)
        out_full = jnp.concatenate(
            [out_full, jnp.zeros((e, 1, d), out_full.dtype)], axis=1)

        slot = jnp.where(pos >= cap, cap, safe)
        picked = out_full[eid, slot].reshape(t_loc, k, d)
        y = jnp.einsum("tk,tkd->td", gates_loc.reshape(t_loc, k), picked)
        return y.reshape(bl, sl, d)

    y = shard_map(
        body,
        mesh=mesh,
        in_specs=(P(dp_spec, s_spec, None), P(dp_spec, s_spec, None),
                  P(dp_spec, s_spec, None),
                  P(tp, None, None), P(tp, None, None), P(tp, None, None)),
        out_specs=P(dp_spec, s_spec, None),
        check_rep=False,
    )(x3d, gates, idx, params["w_gate"], params["w_up"], params["w_down"])

    if cfg.d_ff_shared > 0:
        sh = silu(x3d @ params["shared_gate"]) * (x3d @ params["shared_up"])
        sh = act.constrain(sh, "dp", None, "tp")
        y = y + sh @ params["shared_down"]

    losses = cfg.aux_loss_coef * aux + cfg.router_z_coef * z
    return y, losses


def init_moe_params(key, d_model: int, cfg: MoEConfig, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 7)
    scale_in = d_model ** -0.5
    scale_ff = cfg.d_ff ** -0.5
    p = {
        "router": (scale_in * jax.random.normal(ks[0], (d_model, cfg.n_experts))).astype(jnp.float32),
        "w_gate": (scale_in * jax.random.normal(ks[1], (cfg.n_experts, d_model, cfg.d_ff))).astype(dtype),
        "w_up": (scale_in * jax.random.normal(ks[2], (cfg.n_experts, d_model, cfg.d_ff))).astype(dtype),
        "w_down": (scale_ff * jax.random.normal(ks[3], (cfg.n_experts, cfg.d_ff, d_model))).astype(dtype),
    }
    if cfg.d_ff_shared > 0:
        p["shared_gate"] = (scale_in * jax.random.normal(ks[4], (d_model, cfg.d_ff_shared))).astype(dtype)
        p["shared_up"] = (scale_in * jax.random.normal(ks[5], (d_model, cfg.d_ff_shared))).astype(dtype)
        p["shared_down"] = ((cfg.d_ff_shared ** -0.5) * jax.random.normal(ks[6], (cfg.d_ff_shared, d_model))).astype(dtype)
    return p
