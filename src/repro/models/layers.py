"""Shared layers and initializers (pure JAX)."""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp


def normal_init(key, shape, stddev=0.02, dtype=jnp.float32):
    return (stddev * jax.random.normal(key, shape)).astype(dtype)


def lecun_init(key, shape, fan_in=None, dtype=jnp.float32):
    fan_in = fan_in or shape[0]
    return (jax.random.normal(key, shape) / jnp.sqrt(float(fan_in))).astype(dtype)


def layer_norm(x, gamma, beta, eps=1e-5):
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mean) * jax.lax.rsqrt(var + eps)
    return y * gamma + beta


def rms_norm(x, gamma, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * gamma).astype(x.dtype)


def silu(x):
    return x * jax.nn.sigmoid(x)


def swiglu(x, w_gate, w_up, w_down):
    """LLaMA-style gated MLP: down( silu(x@gate) * (x@up) )."""
    return jnp.einsum("...f,fd->...d", silu(x @ w_gate) * (x @ w_up), w_down)


def mlp(x, weights: Sequence, biases: Sequence, act=jax.nn.relu, final_act=None):
    """Plain MLP used by recsys towers."""
    h = x
    for i, (w, b) in enumerate(zip(weights, biases)):
        h = h @ w + b
        if i < len(weights) - 1:
            h = act(h)
        elif final_act is not None:
            h = final_act(h)
    return h


# ------------------------------------------------------------------- RoPE
def rope_row(position, d_head: int, theta: float = 10000.0):
    """cos/sin tables with a single row for `position` (decode path: avoids
    materializing a (max_len, d/2) table per step). Returns ((1,d/2), (1,d/2))."""
    import jax.numpy as jnp  # local to avoid cycle at import time

    inv = 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))
    ang = position.astype(jnp.float32) * inv  # (d/2,)
    return jnp.cos(ang)[None], jnp.sin(ang)[None]


def rope_frequencies(d_head: int, max_len: int, theta: float = 10000.0):
    inv = 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))
    t = jnp.arange(max_len, dtype=jnp.float32)
    freqs = jnp.outer(t, inv)  # (max_len, d_head/2)
    return jnp.cos(freqs), jnp.sin(freqs)


def apply_rope(x, cos, sin, positions=None):
    """x: (..., S, H, D). cos/sin: (max_len, D/2). positions: (..., S) or None."""
    if positions is None:
        s = x.shape[-3]
        cos_p, sin_p = cos[:s], sin[:s]  # (S, D/2)
        cos_p = cos_p[:, None, :]
        sin_p = sin_p[:, None, :]
    else:
        cos_p = cos[positions][..., None, :]  # (..., S, 1, D/2)
        sin_p = sin[positions][..., None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    out1 = x1 * cos_p - x2 * sin_p
    out2 = x2 * cos_p + x1 * sin_p
    return jnp.concatenate([out1, out2], axis=-1).astype(x.dtype)


# ------------------------------------------------------- chunked cross-entropy
def chunked_softmax_xent(
    hidden, unembed, labels, chunk: int = 512, label_smoothing: float = 0.0,
    real_vocab: Optional[int] = None,
):
    """Cross-entropy over a huge vocab without materializing full (B,S,V)
    logits: scan over sequence chunks. hidden: (B,S,D); unembed: (D,V);
    labels: (B,S) int32. Returns mean loss (fp32).

    Positions with label < 0 are masked out. If `real_vocab` < V (padded
    embedding for shardability), the padding columns are masked to -inf.
    """
    b, s, d = hidden.shape
    v = unembed.shape[-1]
    n_chunks = max(1, s // chunk)
    chunk = s // n_chunks  # require divisibility; configs ensure it
    hid = hidden.reshape(b, n_chunks, chunk, d).swapaxes(0, 1)  # (n,B,c,d)
    lab = labels.reshape(b, n_chunks, chunk).swapaxes(0, 1)

    def body(carry, xs):
        from repro.ps import act_sharding as act

        h, y = xs
        logits = jnp.einsum("bcd,dv->bcv", h, unembed).astype(jnp.float32)
        logits = act.constrain(logits, "dp", None, "tp")  # vocab over tp
        if real_vocab is not None and real_vocab < v:
            pad_mask = jnp.arange(v) < real_vocab
            logits = jnp.where(pad_mask[None, None], logits, -1e30)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(y, 0)[..., None], axis=-1
        )[..., 0]
        mask = (y >= 0).astype(jnp.float32)
        nll = (lse - gold) * mask
        if label_smoothing:
            nll = (1 - label_smoothing) * nll + label_smoothing * mask * (
                lse - jnp.mean(logits, axis=-1)
            )
        loss_sum, count = carry
        return (loss_sum + jnp.sum(nll), count + jnp.sum(mask)), None

    (loss_sum, count), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)), (hid, lab)
    )
    return loss_sum / jnp.maximum(count, 1.0)
