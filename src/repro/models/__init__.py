"""Model definitions (pure JAX, no flax).

transformer.py  decoder-only LMs: GQA / QKV-bias / MLA attention, dense or
                MoE FFN, lax.scan over layers, KV-cache prefill/decode.
gnn.py          GIN message passing via segment_sum.
recsys.py       DLRM (dot interaction), SASRec, DIEN (AUGRU), EmbeddingBag.
attention.py    full / chunked online-softmax / decode attention.
layers.py       norms, MLPs, RoPE, initializers.
"""
