"""RecSys models: DLRM (dot interaction), SASRec, DIEN (GRU + AUGRU).

JAX has no nn.EmbeddingBag: `embedding_bag` below builds it from jnp.take +
jax.ops.segment_sum (a first-class system component, also available as a
Pallas kernel in repro.kernels.embed_bag). Embedding tables are the paper's
best-case workload: 26 tables of wildly different vocab make per-tensor
balanced aggregation placement matter (ps-lite round-robin is provably bad).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from .layers import mlp, normal_init


# ------------------------------------------------------------- EmbeddingBag
def embedding_bag(table, indices, offsets=None, weights=None, mode="sum"):
    """torch.nn.EmbeddingBag semantics from take + segment_sum.

    table: (V, D). With offsets=None, indices is (B, L) (fixed-size bags);
    otherwise indices is flat (N,) and offsets (B,) marks bag starts.
    """
    if offsets is None:
        rows = jnp.take(table, indices, axis=0)  # (B, L, D)
        if weights is not None:
            rows = rows * weights[..., None]
        out = jnp.sum(rows, axis=1)
        if mode == "mean":
            out = out / indices.shape[1]
        return out
    n = indices.shape[0]
    b = offsets.shape[0]
    seg = jnp.cumsum(
        jnp.zeros((n,), jnp.int32).at[offsets].add(1)
    ) - 1  # bag id per element
    rows = jnp.take(table, indices, axis=0)
    if weights is not None:
        rows = rows * weights[:, None]
    out = jax.ops.segment_sum(rows, seg, num_segments=b)
    if mode == "mean":
        counts = jax.ops.segment_sum(jnp.ones((n,), jnp.float32), seg, num_segments=b)
        out = out / jnp.maximum(counts, 1.0)[:, None]
    return out


# ------------------------------------------------- sharded embedding lookup
def sharded_embedding_lookup(tables, ids, chunk: int = 65536):
    """PS-style model-parallel embedding lookup.

    tables: list of (V_i_padded, D) row-sharded over the FULL mesh;
    ids: (B, n_fields) int32. Every device computes partial rows for the
    table rows it owns (ids broadcast), then a psum_scatter over the batch
    dim combines partials and leaves the result batch-sharded -- the
    pull/push pattern of a sharded parameter server. Batches larger than
    `chunk` are processed in a lax.map to bound the partial buffer.

    Requires act_sharding context; falls back to plain takes on 1 device.
    GSPMD cannot partition a gather from row-sharded operands (it
    replicates the tables: measured 96 GB/device on dlrm-mlperf).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.ps import act_sharding as act

    ctx = act._current()
    if ctx is None:
        return jnp.stack(
            [jnp.take(t, ids[:, i], axis=0) for i, t in enumerate(tables)],
            axis=1,
        )

    mesh = ctx["mesh"]
    axes_all = ctx["all"]
    n_dev = 1
    for a in axes_all:
        n_dev *= mesh.shape[a]
    b, n_fields = ids.shape
    d = tables[0].shape[1]
    spec_all = axes_all if len(axes_all) > 1 else axes_all[0]

    def body(ids_rep, *tables_loc):
        flat = jnp.zeros((), jnp.int32)
        for a in axes_all:
            flat = flat * mesh.shape[a] + jax.lax.axis_index(a)
        parts = []
        for i, tl in enumerate(tables_loc):
            vloc = tl.shape[0]
            local = ids_rep[:, i] - flat * vloc
            ok = (local >= 0) & (local < vloc)
            rows = jnp.take(tl, jnp.clip(local, 0, vloc - 1), axis=0)
            parts.append(rows * ok[:, None].astype(rows.dtype))
        part = jnp.stack(parts, axis=1)  # (chunk, F, D) partial
        return jax.lax.psum_scatter(
            part, axes_all, scatter_dimension=0, tiled=True
        )  # (chunk/n_dev, F, D)

    lookup = shard_map(
        body,
        mesh=mesh,
        in_specs=(P(None, None),) + tuple(P(spec_all, None) for _ in tables),
        out_specs=P(spec_all, None, None),
        check_rep=False,
    )

    # psum_scatter needs b (or the chunk) divisible by the device count;
    # large batches pad to a whole number of chunks.
    pad_unit = chunk if b > chunk else n_dev
    pad = (-b) % pad_unit
    if pad:
        ids = jnp.concatenate([ids, jnp.zeros((pad, n_fields), ids.dtype)])
    bp = b + pad
    if bp <= chunk or bp % chunk != 0:
        out = lookup(ids, *tables)
    else:
        ids_c = ids.reshape(bp // chunk, chunk, n_fields)
        out = jax.lax.map(lambda c: lookup(c, *tables), ids_c)
        out = out.reshape(bp, n_fields, d)
    if pad:
        out = out[:b]
    return act.constrain(out, "dp", None, None)


def pad_vocab(v: int, multiple: int = 512) -> int:
    """Row-shardable table size (rows padded up; ids never reach padding)."""
    return -(-v // multiple) * multiple


# ======================================================================= DLRM
@dataclass(frozen=True)
class DLRMConfig:
    name: str
    n_dense: int = 13
    n_sparse: int = 26
    embed_dim: int = 64
    bot_mlp: Tuple[int, ...] = (512, 256, 64)
    top_mlp: Tuple[int, ...] = (512, 512, 256, 1)
    vocab_sizes: Tuple[int, ...] = ()
    dtype: str = "float32"

    @property
    def jdtype(self):
        return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[self.dtype]

    @property
    def n_features(self) -> int:
        return self.n_sparse + 1  # embeddings + bottom-MLP output

    @property
    def n_pairs(self) -> int:
        f = self.n_features
        return f * (f - 1) // 2


def _init_mlp(key, dims: Sequence[int], dtype):
    ks = jax.random.split(key, len(dims) - 1)
    ws = [ (dims[i] ** -0.5) * jax.random.normal(ks[i], (dims[i], dims[i + 1]))
          for i in range(len(dims) - 1)]
    return {
        "w": [w.astype(dtype) for w in ws],
        "b": [jnp.zeros((dims[i + 1],), dtype) for i in range(len(dims) - 1)],
    }


def dlrm_init(cfg: DLRMConfig, key) -> Dict:
    assert len(cfg.vocab_sizes) == cfg.n_sparse
    ks = jax.random.split(key, cfg.n_sparse + 2)
    dt = cfg.jdtype
    # Rows padded to a shardable multiple; ids never reach the padding.
    tables = [
        normal_init(ks[i], (pad_vocab(v), cfg.embed_dim),
                    stddev=1.0 / jnp.sqrt(float(v)), dtype=dt)
        for i, v in enumerate(cfg.vocab_sizes)
    ]
    bot_dims = (cfg.n_dense,) + cfg.bot_mlp
    top_in = cfg.bot_mlp[-1] + cfg.n_pairs
    top_dims = (top_in,) + cfg.top_mlp
    return {
        "tables": tables,
        "bot": _init_mlp(ks[-2], bot_dims, dt),
        "top": _init_mlp(ks[-1], top_dims, dt),
    }


def dlrm_forward(cfg: DLRMConfig, params, dense, sparse_ids):
    """dense: (B, n_dense) float; sparse_ids: (B, n_sparse) int32 -> logits (B,)."""
    dt = cfg.jdtype
    bot = mlp(dense.astype(dt), params["bot"]["w"], params["bot"]["b"])  # (B, D)
    embs = sharded_embedding_lookup(params["tables"], sparse_ids)  # (B, n_sparse, D)
    z = jnp.concatenate([bot[:, None, :], embs], axis=1)  # (B, F, D)
    inter = jnp.einsum("bfd,bgd->bfg", z, z)  # (B, F, F)
    f = cfg.n_features
    iu, ju = jnp.triu_indices(f, k=1)
    pairs = inter[:, iu, ju]  # (B, F*(F-1)/2)
    top_in = jnp.concatenate([bot, pairs.astype(dt)], axis=1)
    logit = mlp(top_in, params["top"]["w"], params["top"]["b"])
    return logit[:, 0]


def dlrm_loss(cfg: DLRMConfig, params, batch) -> jnp.ndarray:
    logits = dlrm_forward(cfg, params, batch["dense"], batch["sparse"]).astype(jnp.float32)
    y = batch["labels"].astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


def dlrm_retrieval(cfg: DLRMConfig, params, dense_1, user_sparse, candidate_ids):
    """Score one user against N candidate items (retrieval_cand shape).

    dense_1: (1, n_dense); user_sparse: (1, n_sparse - 1) fixed user fields;
    candidate_ids: (N,) ids into the LAST table (the item table).
    """
    n = candidate_ids.shape[0]
    dense = jnp.broadcast_to(dense_1, (n, cfg.n_dense))
    user = jnp.broadcast_to(user_sparse, (n, cfg.n_sparse - 1))
    sparse = jnp.concatenate([user, candidate_ids[:, None]], axis=1)
    return dlrm_forward(cfg, params, dense, sparse)


# ===================================================================== SASRec
@dataclass(frozen=True)
class SASRecConfig:
    name: str
    n_items: int = 1_000_000
    embed_dim: int = 50
    n_blocks: int = 2
    n_heads: int = 1
    seq_len: int = 50
    dtype: str = "float32"

    @property
    def jdtype(self):
        return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[self.dtype]


def sasrec_init(cfg: SASRecConfig, key) -> Dict:
    ks = jax.random.split(key, 2 + cfg.n_blocks)
    dt, d = cfg.jdtype, cfg.embed_dim
    blocks = []
    for i in range(cfg.n_blocks):
        bk = jax.random.split(ks[2 + i], 6)
        s = d ** -0.5
        blocks.append({
            "ln1_g": jnp.ones((d,), jnp.float32), "ln1_b": jnp.zeros((d,), jnp.float32),
            "w_q": (s * jax.random.normal(bk[0], (d, d))).astype(dt),
            "w_k": (s * jax.random.normal(bk[1], (d, d))).astype(dt),
            "w_v": (s * jax.random.normal(bk[2], (d, d))).astype(dt),
            "w_o": (s * jax.random.normal(bk[3], (d, d))).astype(dt),
            "ln2_g": jnp.ones((d,), jnp.float32), "ln2_b": jnp.zeros((d,), jnp.float32),
            "w_ff1": (s * jax.random.normal(bk[4], (d, d))).astype(dt),
            "b_ff1": jnp.zeros((d,), dt),
            "w_ff2": (s * jax.random.normal(bk[5], (d, d))).astype(dt),
            "b_ff2": jnp.zeros((d,), dt),
        })
    return {
        "item_emb": normal_init(ks[0], (cfg.n_items, d), 0.02, dt),
        "pos_emb": normal_init(ks[1], (cfg.seq_len, d), 0.02, dt),
        "blocks": blocks,
        "final_ln_g": jnp.ones((d,), jnp.float32),
        "final_ln_b": jnp.zeros((d,), jnp.float32),
    }


def _ln(x, g, b, eps=1e-6):
    m = jnp.mean(x, -1, keepdims=True)
    v = jnp.var(x, -1, keepdims=True)
    return ((x - m) * jax.lax.rsqrt(v + eps)) * g + b


def sasrec_states(cfg: SASRecConfig, params, item_seq):
    """item_seq: (B, S) int32 (0 = padding) -> hidden states (B, S, D)."""
    b, s = item_seq.shape
    h = jnp.take(params["item_emb"], item_seq, axis=0) + params["pos_emb"][None, :s]
    h = h * (item_seq != 0)[..., None].astype(h.dtype)
    causal = jnp.tril(jnp.ones((s, s), bool))
    for blk in params["blocks"]:
        q = _ln(h, blk["ln1_g"], blk["ln1_b"]).astype(h.dtype)
        scores = jnp.einsum("bqd,bkd->bqk", q @ blk["w_q"], h @ blk["w_k"])
        scores = scores / jnp.sqrt(float(cfg.embed_dim))
        scores = jnp.where(causal[None], scores.astype(jnp.float32), -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(h.dtype)
        att = jnp.einsum("bqk,bkd->bqd", probs, h @ blk["w_v"]) @ blk["w_o"]
        h = h + att
        f = _ln(h, blk["ln2_g"], blk["ln2_b"]).astype(h.dtype)
        h = h + jax.nn.relu(f @ blk["w_ff1"] + blk["b_ff1"]) @ blk["w_ff2"] + blk["b_ff2"]
    return _ln(h, params["final_ln_g"], params["final_ln_b"]).astype(h.dtype)


def sasrec_loss(cfg: SASRecConfig, params, batch) -> jnp.ndarray:
    """batch: seq (B,S), pos (B,S) next items, neg (B,S) sampled negatives.

    BCE over positive/negative next-item scores (the SASRec objective)."""
    h = sasrec_states(cfg, params, batch["seq"])
    pos_e = jnp.take(params["item_emb"], batch["pos"], axis=0)
    neg_e = jnp.take(params["item_emb"], batch["neg"], axis=0)
    pos_s = jnp.sum(h * pos_e, -1).astype(jnp.float32)
    neg_s = jnp.sum(h * neg_e, -1).astype(jnp.float32)
    mask = (batch["pos"] != 0).astype(jnp.float32)
    loss = -jnp.log(jax.nn.sigmoid(pos_s) + 1e-12) - jnp.log(1 - jax.nn.sigmoid(neg_s) + 1e-12)
    return jnp.sum(loss * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def sasrec_retrieval(cfg: SASRecConfig, params, item_seq, candidate_ids):
    """(B,S) history x (N,) candidates -> (B,N) scores (batched dot)."""
    h = sasrec_states(cfg, params, item_seq)[:, -1]  # (B, D)
    cand = jnp.take(params["item_emb"], candidate_ids, axis=0)  # (N, D)
    return jnp.einsum("bd,nd->bn", h, cand)


# ======================================================================= DIEN
@dataclass(frozen=True)
class DIENConfig:
    name: str
    n_items: int = 1_000_000
    n_cats: int = 10_000
    embed_dim: int = 18  # per-field; item+cat concat -> 36
    seq_len: int = 100
    gru_dim: int = 108
    mlp_dims: Tuple[int, ...] = (200, 80)
    dtype: str = "float32"

    @property
    def jdtype(self):
        return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[self.dtype]

    @property
    def d_in(self) -> int:
        return 2 * self.embed_dim  # item emb + category emb


def _gru_params(key, d_in, d_h, dtype):
    ks = jax.random.split(key, 3)
    s = (d_in + d_h) ** -0.5
    mk = lambda k: (s * jax.random.normal(k, (d_in + d_h, d_h))).astype(dtype)
    return {"wz": mk(ks[0]), "wr": mk(ks[1]), "wh": mk(ks[2]),
            "bz": jnp.zeros((d_h,), dtype), "br": jnp.zeros((d_h,), dtype),
            "bh": jnp.zeros((d_h,), dtype)}


def _gru_cell(p, h, x, att=None):
    xh = jnp.concatenate([x, h], axis=-1)
    z = jax.nn.sigmoid(xh @ p["wz"] + p["bz"])
    r = jax.nn.sigmoid(xh @ p["wr"] + p["br"])
    xh2 = jnp.concatenate([x, r * h], axis=-1)
    h_tilde = jnp.tanh(xh2 @ p["wh"] + p["bh"])
    if att is not None:  # AUGRU: attention scales the update gate
        z = z * att[:, None]
    return (1 - z) * h + z * h_tilde


def dien_init(cfg: DIENConfig, key) -> Dict:
    ks = jax.random.split(key, 6)
    dt = cfg.jdtype
    att_in = cfg.gru_dim + cfg.d_in
    return {
        "item_emb": normal_init(ks[0], (cfg.n_items, cfg.embed_dim), 0.02, dt),
        "cat_emb": normal_init(ks[1], (cfg.n_cats, cfg.embed_dim), 0.02, dt),
        "gru1": _gru_params(ks[2], cfg.d_in, cfg.gru_dim, dt),
        "augru": _gru_params(ks[3], cfg.gru_dim, cfg.gru_dim, dt),
        "att": _init_mlp(ks[4], (att_in, 80, 1), dt),
        "head": _init_mlp(
            ks[5], (cfg.gru_dim + 2 * cfg.d_in,) + cfg.mlp_dims + (1,), dt
        ),
    }


def _embed_pair(cfg, params, items, cats):
    return jnp.concatenate(
        [jnp.take(params["item_emb"], items, axis=0),
         jnp.take(params["cat_emb"], cats, axis=0)], axis=-1)


def dien_forward(cfg: DIENConfig, params, batch):
    """batch: hist_items/hist_cats (B,S), target_item/target_cat (B,) ->
    logits (B,). Interest extraction GRU -> target attention -> AUGRU."""
    hist = _embed_pair(cfg, params, batch["hist_items"], batch["hist_cats"])  # (B,S,36)
    target = _embed_pair(cfg, params, batch["target_item"], batch["target_cat"])  # (B,36)
    b, s, _ = hist.shape

    def gru_scan(h, x):
        h = _gru_cell(params["gru1"], h, x)
        return h, h

    h0 = jnp.zeros((b, cfg.gru_dim), hist.dtype)
    _, states = jax.lax.scan(gru_scan, h0, hist.swapaxes(0, 1))  # (S,B,H)

    # Attention of each interest state vs the target ad.
    tgt = jnp.broadcast_to(target[None], (s, b, cfg.d_in))
    att_in = jnp.concatenate([states, tgt], axis=-1)
    scores = mlp(att_in, params["att"]["w"], params["att"]["b"])[..., 0]  # (S,B)
    att = jax.nn.softmax(scores.astype(jnp.float32), axis=0).astype(hist.dtype)

    def augru_scan(h, xs):
        x, a = xs
        h = _gru_cell(params["augru"], h, x, att=a)
        return h, None

    hT, _ = jax.lax.scan(augru_scan, h0, (states, att))  # final interest (B,H)

    hist_mean = jnp.mean(hist, axis=1)
    head_in = jnp.concatenate([hT, target, hist_mean], axis=-1)
    return mlp(head_in, params["head"]["w"], params["head"]["b"])[:, 0]


def dien_loss(cfg: DIENConfig, params, batch) -> jnp.ndarray:
    logits = dien_forward(cfg, params, batch).astype(jnp.float32)
    y = batch["labels"].astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


def dien_retrieval(cfg: DIENConfig, params, hist_items, hist_cats, cand_items, cand_cats):
    """1 user x N candidates: shared interest GRU, per-candidate AUGRU."""
    n = cand_items.shape[0]
    batch = {
        "hist_items": jnp.broadcast_to(hist_items, (n,) + hist_items.shape[-1:]),
        "hist_cats": jnp.broadcast_to(hist_cats, (n,) + hist_cats.shape[-1:]),
        "target_item": cand_items,
        "target_cat": cand_cats,
    }
    return dien_forward(cfg, params, batch)


def make_train_step(loss, optimizer):
    """Generic recsys train step from a loss(params, batch) closure."""

    def train_step(state, batch):
        l, grads = jax.value_and_grad(loss)(state["params"], batch)
        new_params, new_opt = optimizer.step(state["params"], grads, state["opt"])
        return {"params": new_params, "opt": new_opt}, {"loss": l}

    return train_step
