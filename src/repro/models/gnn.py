"""GIN (Graph Isomorphism Network, arXiv:1810.00826) via segment_sum.

JAX has no sparse SpMM beyond BCOO, so message passing is built from the
edge-index scatter primitive: agg[i] = sum_{(j->i) in E} h[j] implemented as
`jax.ops.segment_sum(h[src], dst, n_nodes)` -- this IS the system's GNN
substrate (kernel regime: SpMM-by-scatter).

Supports node classification (full-graph + sampled-subgraph training) and
graph classification (batched small graphs, sum readout).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class GINConfig:
    name: str
    n_layers: int = 5
    d_hidden: int = 64
    d_feat: int = 1433
    n_classes: int = 7
    learnable_eps: bool = True  # eps=learnable per the assigned config
    task: str = "node"  # "node" | "graph"
    dtype: str = "float32"

    @property
    def jdtype(self):
        return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[self.dtype]


def init_params(cfg: GINConfig, key) -> Dict:
    ks = jax.random.split(key, cfg.n_layers * 2 + 2)
    dt = cfg.jdtype
    layers = []
    d_in = cfg.d_feat
    for i in range(cfg.n_layers):
        w1 = (d_in ** -0.5) * jax.random.normal(ks[2 * i], (d_in, cfg.d_hidden))
        w2 = (cfg.d_hidden ** -0.5) * jax.random.normal(ks[2 * i + 1], (cfg.d_hidden, cfg.d_hidden))
        layers.append({
            "w1": w1.astype(dt), "b1": jnp.zeros((cfg.d_hidden,), dt),
            "w2": w2.astype(dt), "b2": jnp.zeros((cfg.d_hidden,), dt),
            "eps": jnp.zeros((), jnp.float32),
        })
        d_in = cfg.d_hidden
    head = (cfg.d_hidden ** -0.5) * jax.random.normal(ks[-1], (cfg.d_hidden, cfg.n_classes))
    return {"layers": layers, "head_w": head.astype(dt),
            "head_b": jnp.zeros((cfg.n_classes,), dt)}


def gin_layer(p, h, edge_src, edge_dst, n_nodes: int, edge_mask=None):
    """h' = MLP((1 + eps) * h + sum_{j in N(i)} h_j)."""
    msgs = h[edge_src]
    if edge_mask is not None:
        msgs = msgs * edge_mask[:, None].astype(h.dtype)
    agg = jax.ops.segment_sum(msgs, edge_dst, num_segments=n_nodes)
    z = (1.0 + p["eps"]).astype(h.dtype) * h + agg
    z = jax.nn.relu(z @ p["w1"] + p["b1"])
    return jax.nn.relu(z @ p["w2"] + p["b2"])


def forward(cfg: GINConfig, params, feats, edge_src, edge_dst, edge_mask=None):
    """feats: (N, d_feat); edges: (E,) src/dst int32. Returns node states (N, d)."""
    n = feats.shape[0]
    h = feats.astype(cfg.jdtype)
    for p in params["layers"]:
        h = gin_layer(p, h, edge_src, edge_dst, n, edge_mask)
    return h


def node_logits(cfg: GINConfig, params, feats, edge_src, edge_dst, edge_mask=None):
    h = forward(cfg, params, feats, edge_src, edge_dst, edge_mask)
    return h @ params["head_w"] + params["head_b"]


def graph_logits(cfg: GINConfig, params, feats, edge_src, edge_dst, graph_ids,
                 n_graphs: int, edge_mask=None):
    """Sum-readout per graph then classify (batched small molecules)."""
    h = forward(cfg, params, feats, edge_src, edge_dst, edge_mask)
    pooled = jax.ops.segment_sum(h, graph_ids, num_segments=n_graphs)
    return pooled @ params["head_w"] + params["head_b"]


def node_loss(cfg: GINConfig, params, batch) -> jnp.ndarray:
    """batch: feats (N,d), edge_src/dst (E,), labels (N,), label_mask (N,)."""
    logits = node_logits(cfg, params, batch["feats"], batch["edge_src"],
                         batch["edge_dst"], batch.get("edge_mask"))
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, jnp.maximum(batch["labels"], 0)[:, None], 1)[:, 0]
    mask = batch["label_mask"].astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def graph_loss(cfg: GINConfig, params, batch) -> jnp.ndarray:
    """batch: feats (N,d), edges, graph_ids (N,), labels (G,)."""
    n_graphs = batch["labels"].shape[0]
    logits = graph_logits(cfg, params, batch["feats"], batch["edge_src"],
                          batch["edge_dst"], batch["graph_ids"], n_graphs,
                          batch.get("edge_mask"))
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, batch["labels"][:, None], 1)[:, 0]
    return jnp.mean(nll)


def loss_fn(cfg: GINConfig, params, batch) -> jnp.ndarray:
    if cfg.task == "graph":
        return graph_loss(cfg, params, batch)
    return node_loss(cfg, params, batch)


def make_train_step(cfg: GINConfig, optimizer):
    def train_step(state, batch):
        loss, grads = jax.value_and_grad(lambda p: loss_fn(cfg, p, batch))(state["params"])
        new_params, new_opt = optimizer.step(state["params"], grads, state["opt"])
        return {"params": new_params, "opt": new_opt}, {"loss": loss}

    return train_step
