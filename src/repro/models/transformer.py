"""Decoder-only LM supporting the five assigned LM architectures.

Features: GQA (command-r-plus, granite-8b, qwen), QKV bias (qwen), parallel
attention+FFN residual (command-r family), MLA compressed-KV attention with
absorbed decode (deepseek-v2), MoE FFN with shared experts (granite-moe,
deepseek-v2), tied embeddings, RoPE, RMS/LayerNorm, lax.scan over layers
(keeps HLO size flat in depth), microbatched gradient accumulation, chunked
cross-entropy, and KV-cache prefill/decode for serving.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.ps import act_sharding as act

from . import attention as attn_lib
from .layers import (
    apply_rope,
    chunked_softmax_xent,
    layer_norm,
    rms_norm,
    rope_frequencies,
    rope_row,
    silu,
)
from .moe import MoEConfig, init_moe_params, moe_ffn


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: Optional[int] = None  # default d_model // n_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    parallel_block: bool = False  # command-r: x + attn(norm x) + ffn(norm x)
    norm: str = "rmsnorm"  # or "layernorm"
    rope_theta: float = 10000.0
    max_seq_len: int = 8192
    moe: Optional[MoEConfig] = None
    first_k_dense: int = 0  # leading layers use dense FFN even in MoE models
    mla: Optional[MLAConfig] = None
    dtype: str = "float32"
    remat: bool = True
    loss_chunk: int = 512
    attn_chunk_k: int = 0  # 0 -> full attention; >0 -> online-softmax chunks
    moe_capacity_factor_override: Optional[float] = None
    moe_groups: int = 1  # GShard-style dispatch groups (shard-local scatter)

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded to a shardable multiple (embedding rows + logit
        columns); CE masks the padding columns so semantics are unchanged.
        (granite-moe's 49155 is prime-ish -- unsharded it costs a 24 GB/step
        fp32 all-reduce in the CE backward.)"""
        return -(-self.vocab // 256) * 256

    @property
    def jdtype(self):
        return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[self.dtype]

    @property
    def param_count(self) -> int:
        """Total parameters (for roofline MODEL_FLOPS)."""
        return sum(
            int(np_prod(l.shape))
            for l in jax.tree_util.tree_leaves(
                jax.eval_shape(lambda: init_params(self, jax.random.PRNGKey(0)))
            )
        )

    @property
    def active_param_count(self) -> int:
        """Active params per token (MoE: routed top-k + shared only)."""
        total = self.param_count
        if self.moe is None:
            return total
        m = self.moe
        per_expert = 3 * self.d_model * m.d_ff
        n_moe_layers = self.n_layers - self.first_k_dense
        inactive = n_moe_layers * (m.n_experts - m.top_k) * per_expert
        return total - inactive


def np_prod(shape) -> int:
    out = 1
    for s in shape:
        out *= int(s)
    return out


# =============================================================== init
def _norm_params(cfg, d):
    if cfg.norm == "layernorm":
        return {"g": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)}
    return {"g": jnp.ones((d,), jnp.float32)}


def _apply_norm(cfg, p, x):
    if cfg.norm == "layernorm":
        return layer_norm(x, p["g"], p["b"]).astype(x.dtype)
    return rms_norm(x, p["g"])


def _init_attn(cfg: LMConfig, key) -> Dict[str, Any]:
    d, hq, hk, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = cfg.jdtype
    ks = jax.random.split(key, 8)
    s = d ** -0.5
    if cfg.mla is not None:
        m = cfg.mla
        dqk = m.qk_nope_dim + m.qk_rope_dim
        p = {
            "w_dq": (s * jax.random.normal(ks[0], (d, m.q_lora_rank))).astype(dt),
            "q_norm": jnp.ones((m.q_lora_rank,), jnp.float32),
            "w_uq": ((m.q_lora_rank ** -0.5) * jax.random.normal(ks[1], (m.q_lora_rank, hq, dqk))).astype(dt),
            "w_dkv": (s * jax.random.normal(ks[2], (d, m.kv_lora_rank))).astype(dt),
            "kv_norm": jnp.ones((m.kv_lora_rank,), jnp.float32),
            "w_kr": (s * jax.random.normal(ks[3], (d, m.qk_rope_dim))).astype(dt),
            "w_uk": ((m.kv_lora_rank ** -0.5) * jax.random.normal(ks[4], (m.kv_lora_rank, hq, m.qk_nope_dim))).astype(dt),
            "w_uv": ((m.kv_lora_rank ** -0.5) * jax.random.normal(ks[5], (m.kv_lora_rank, hq, m.v_head_dim))).astype(dt),
            "w_o": (((hq * m.v_head_dim) ** -0.5) * jax.random.normal(ks[6], (hq, m.v_head_dim, d))).astype(dt),
        }
        return p
    p = {
        "w_q": (s * jax.random.normal(ks[0], (d, hq, dh))).astype(dt),
        "w_k": (s * jax.random.normal(ks[1], (d, hk, dh))).astype(dt),
        "w_v": (s * jax.random.normal(ks[2], (d, hk, dh))).astype(dt),
        "w_o": (((hq * dh) ** -0.5) * jax.random.normal(ks[3], (hq, dh, d))).astype(dt),
    }
    if cfg.qkv_bias:
        p["b_q"] = jnp.zeros((hq, dh), dt)
        p["b_k"] = jnp.zeros((hk, dh), dt)
        p["b_v"] = jnp.zeros((hk, dh), dt)
    return p


def _init_dense_ffn(cfg: LMConfig, key, d_ff: Optional[int] = None) -> Dict[str, Any]:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = cfg.jdtype
    ks = jax.random.split(key, 3)
    return {
        "w_gate": ((d ** -0.5) * jax.random.normal(ks[0], (d, f))).astype(dt),
        "w_up": ((d ** -0.5) * jax.random.normal(ks[1], (d, f))).astype(dt),
        "w_down": ((f ** -0.5) * jax.random.normal(ks[2], (f, d))).astype(dt),
    }


def _init_layer(cfg: LMConfig, key, dense: bool) -> Dict[str, Any]:
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": _norm_params(cfg, cfg.d_model),
        "attn": _init_attn(cfg, k1),
    }
    if not cfg.parallel_block:
        p["ln2"] = _norm_params(cfg, cfg.d_model)
    if dense or cfg.moe is None:
        p["ffn"] = _init_dense_ffn(cfg, k2)
    else:
        p["moe"] = init_moe_params(k2, cfg.d_model, cfg.moe, cfg.jdtype)
    return p


def init_params(cfg: LMConfig, key) -> Dict[str, Any]:
    keys = jax.random.split(key, cfg.n_layers + 3)
    dt = cfg.jdtype
    params: Dict[str, Any] = {
        "embed": ((cfg.d_model ** -0.5) * jax.random.normal(keys[0], (cfg.padded_vocab, cfg.d_model))).astype(dt),
        "final_norm": _norm_params(cfg, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = ((cfg.d_model ** -0.5) * jax.random.normal(keys[1], (cfg.d_model, cfg.padded_vocab))).astype(dt)
    # Leading dense layers (unrolled), then a stacked scan block.
    for i in range(cfg.first_k_dense):
        params[f"dense_layer_{i}"] = _init_layer(cfg, keys[2 + i], dense=True)
    n_scan = cfg.n_layers - cfg.first_k_dense
    if n_scan > 0:
        scan_keys = jax.random.split(keys[-1], n_scan)
        layers = [_init_layer(cfg, k, dense=False) for k in scan_keys]
        params["layers"] = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *layers
        )
    return params


# ============================================================ forward pieces
def _attention_block(cfg: LMConfig, p, x, cos, sin, positions=None):
    """x: (B,S,d) -> (B,S,d). Training/prefill path."""
    b, s, d = x.shape
    if cfg.mla is not None:
        return _mla_attention(cfg, p, x, cos, sin, positions)
    q = jnp.einsum("bsd,dhe->bshe", x, p["w_q"])
    k = jnp.einsum("bsd,dhe->bshe", x, p["w_k"])
    v = jnp.einsum("bsd,dhe->bshe", x, p["w_v"])
    if cfg.qkv_bias:
        q, k, v = q + p["b_q"], k + p["b_k"], v + p["b_v"]
    q = apply_rope(q, cos, sin, positions)
    k = apply_rope(k, cos, sin, positions)
    q = act.constrain(q, "dp", None, "tp", None)  # TP over query heads
    k = act.constrain(k, "dp", None, "tp", None)
    v = act.constrain(v, "dp", None, "tp", None)
    if cfg.attn_chunk_k and s > cfg.attn_chunk_k:
        o = attn_lib.chunked_attention(q, k, v, causal=True, chunk_k=cfg.attn_chunk_k)
    else:
        o = attn_lib.full_attention(q, k, v, causal=True)
    o = act.constrain(o, "dp", None, "tp", None)
    return jnp.einsum("bshe,hed->bsd", o, p["w_o"])


def _mla_attention(cfg: LMConfig, p, x, cos, sin, positions=None):
    m = cfg.mla
    b, s, d = x.shape
    cq = rms_norm(x @ p["w_dq"], p["q_norm"])
    q = jnp.einsum("bsr,rhe->bshe", cq, p["w_uq"])  # (B,S,H,nope+rope)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_dim], axis=-1)
    q_rope = apply_rope(q_rope, cos, sin, positions)

    ckv = rms_norm(x @ p["w_dkv"], p["kv_norm"])  # (B,S,r)
    k_rope = apply_rope((x @ p["w_kr"])[:, :, None, :], cos, sin, positions)  # (B,S,1,rope)
    k_nope = jnp.einsum("bsr,rhe->bshe", ckv, p["w_uk"])
    v = jnp.einsum("bsr,rhe->bshe", ckv, p["w_uv"])

    q_full = act.constrain(jnp.concatenate([q_nope, q_rope], axis=-1),
                           "dp", None, "tp", None)
    k_full = act.constrain(jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, s, cfg.n_heads, m.qk_rope_dim))], axis=-1
    ), "dp", None, "tp", None)
    v = act.constrain(v, "dp", None, "tp", None)
    scale = (m.qk_nope_dim + m.qk_rope_dim) ** -0.5
    if cfg.attn_chunk_k and s > cfg.attn_chunk_k:
        o = attn_lib.chunked_attention(q_full, k_full, v, causal=True,
                                       chunk_k=cfg.attn_chunk_k, scale=scale)
    else:
        o = attn_lib.full_attention(q_full, k_full, v, causal=True, scale=scale)
    return jnp.einsum("bshe,hed->bsd", o, p["w_o"])


def _ffn_block(cfg: LMConfig, p, x):
    """Dense or MoE FFN on (B,S,d). Returns (out, aux_loss)."""
    if "ffn" in p:
        f = p["ffn"]
        h = silu(x @ f["w_gate"]) * (x @ f["w_up"])
        h = act.constrain(h, "dp", None, "tp")  # TP over FFN hidden
        return h @ f["w_down"], jnp.zeros((), jnp.float32)
    b, s, d = x.shape
    cfg_moe = cfg.moe
    if cfg.moe_capacity_factor_override is not None:
        cfg_moe = dataclasses.replace(
            cfg_moe, capacity_factor=cfg.moe_capacity_factor_override
        )
    if act.enabled():
        ctx = act._current()
        dp_size = 1
        for a in ctx["dp"]:
            dp_size *= ctx["mesh"].shape[a]
        tp_size = ctx["mesh"].shape[ctx["tp"][0]]
        if b % dp_size == 0 and cfg_moe.n_experts % tp_size == 0:
            from .moe import moe_ffn_sharded

            # SP-preserving all-to-all expert parallelism (tokens never
            # leave their (dp, tp) shard except through the EP exchange).
            return moe_ffn_sharded(x, p["moe"], cfg_moe)
    y, aux = moe_ffn(x.reshape(b * s, d), p["moe"], cfg_moe,
                     n_groups=cfg.moe_groups)
    return y.reshape(b, s, d), aux


def _layer_fn(cfg: LMConfig, p, x, cos, sin, positions=None):
    """One transformer block. Returns (x_out, aux_loss).

    Row-parallel projection outputs (attention w_o, FFN w_down) are
    constrained straight to the sequence-parallel layout so GSPMD lowers
    their pending partial-sums as reduce-scatters instead of all-reduce +
    slice (halves the dominant TP collective)."""
    if cfg.parallel_block:
        h = _apply_norm(cfg, p["ln1"], x)
        a = _attention_block(cfg, p["attn"], h, cos, sin, positions)
        f, aux = _ffn_block(cfg, p, h)
        return x + act.constrain(a + f, "dp", "tp", None), aux
    a = _attention_block(cfg, p["attn"], _apply_norm(cfg, p["ln1"], x), cos, sin, positions)
    x = x + act.constrain(a, "dp", "tp", None)
    f, aux = _ffn_block(cfg, p, _apply_norm(cfg, p["ln2"], x))
    return x + act.constrain(f, "dp", "tp", None), aux


def forward_hidden(cfg: LMConfig, params, tokens) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """tokens: (B,S) -> hidden (B,S,d), total aux loss.

    The residual stream is sequence-sharded between layers (sequence
    parallelism): the scan carry -- which remat saves per layer -- is
    (B, S/tp, d) instead of (B, S, d)."""
    x = params["embed"][tokens]
    x = act.constrain(x, "dp", "tp", None)
    cos, sin = rope_frequencies(
        cfg.mla.qk_rope_dim if cfg.mla else cfg.head_dim,
        tokens.shape[1],
        cfg.rope_theta,
    )
    aux_total = jnp.zeros((), jnp.float32)
    for i in range(cfg.first_k_dense):
        x, aux = _layer_fn(cfg, params[f"dense_layer_{i}"], x, cos, sin)
        x = act.constrain(x, "dp", "tp", None)
        aux_total += aux

    if "layers" in params:
        def body(carry, layer_p):
            x, aux_acc = carry
            x = act.constrain(x, "dp", "tp", None)
            fn = _layer_fn
            if cfg.remat:
                fn = jax.checkpoint(
                    _layer_fn, policy=jax.checkpoint_policies.nothing_saveable,
                    static_argnums=(0,),
                )
            x, aux = fn(cfg, layer_p, x, cos, sin)
            return (x, aux_acc + aux), None

        (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), params["layers"])
    return _apply_norm(cfg, params["final_norm"], x), aux_total


def _unembed(cfg: LMConfig, params):
    return params["embed"].T if cfg.tie_embeddings else params["unembed"]


def loss_fn(cfg: LMConfig, params, batch) -> jnp.ndarray:
    """batch: {'tokens': (B,S), 'labels': (B,S)} -> scalar fp32 loss."""
    hidden, aux = forward_hidden(cfg, params, batch["tokens"])
    ce = chunked_softmax_xent(hidden, _unembed(cfg, params), batch["labels"],
                              chunk=min(cfg.loss_chunk, hidden.shape[1]),
                              real_vocab=cfg.vocab)
    return ce + aux


def make_train_step(
    cfg: LMConfig, optimizer, n_microbatches: int = 1,
    grad_accum_dtype=jnp.float32, grad_shardings=None,
):
    """Returns train_step(state, batch) -> (state, metrics).

    state = {'params', 'opt'}; batch tokens (B,S). With n_microbatches > 1,
    grads accumulate over a scan of microbatches (B must divide evenly);
    aggregation (optimizer step) runs once -- this is the 'Push/Update'
    aggregation op the Parameter Service places per-tensor.
    `grad_accum_dtype` trades accumulation precision for memory on the
    100B+ configs (bf16 accum halves the gradient-buffer HBM).
    `grad_shardings` (params-shaped tree of NamedShardings, or None) pins
    the gradient/accumulator layout -- needed when parameters are
    replicated along an axis (EP expert weights) but gradients must stay
    sharded (ZeRO-1), else the accumulator replicates too.
    """

    def _constrain_grads(grads):
        if grad_shardings is None:
            return grads
        return jax.tree_util.tree_map(
            lambda g, s: jax.lax.with_sharding_constraint(g, s)
            if s is not None else g,
            grads, grad_shardings,
        )

    def train_step(state, batch):
        params = state["params"]
        if n_microbatches == 1:
            loss, grads = jax.value_and_grad(lambda p: loss_fn(cfg, p, batch))(params)
            grads = _constrain_grads(grads)
        else:
            b = batch["tokens"].shape[0]
            mb = b // n_microbatches
            toks = batch["tokens"].reshape(n_microbatches, mb, -1)
            labs = batch["labels"].reshape(n_microbatches, mb, -1)

            def micro(carry, xs):
                loss_acc, grad_acc = carry
                t, l = xs
                loss, grads = jax.value_and_grad(
                    lambda p: loss_fn(cfg, p, {"tokens": t, "labels": l})
                )(params)
                grad_acc = _constrain_grads(jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(a.dtype), grad_acc, grads
                ))
                return (loss_acc + loss, grad_acc), None

            zeros = _constrain_grads(jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, grad_accum_dtype), params
            ))
            (loss, grads), _ = jax.lax.scan(
                micro, (jnp.zeros((), jnp.float32), zeros), (toks, labs)
            )
            loss = loss / n_microbatches
            grads = jax.tree_util.tree_map(lambda g: g / n_microbatches, grads)

        new_params, new_opt = optimizer.step(params, grads, state["opt"])
        metrics = {"loss": loss}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


# ================================================================= serving
def init_kv_cache(cfg: LMConfig, batch: int, max_len: int) -> Dict[str, Any]:
    dt = cfg.jdtype
    n_scan = cfg.n_layers - cfg.first_k_dense
    if cfg.mla is not None:
        m = cfg.mla
        mk = lambda L: {
            "ckv": jnp.zeros((L, batch, max_len, m.kv_lora_rank), dt),
            "k_rope": jnp.zeros((L, batch, max_len, m.qk_rope_dim), dt),
        }
    else:
        hk, dh = cfg.n_kv_heads, cfg.head_dim
        mk = lambda L: {
            "k": jnp.zeros((L, batch, max_len, hk, dh), dt),
            "v": jnp.zeros((L, batch, max_len, hk, dh), dt),
        }
    cache = {"scan": mk(n_scan)}
    if cfg.first_k_dense:
        cache["dense"] = mk(cfg.first_k_dense)
    cache["length"] = jnp.zeros((), jnp.int32)
    return cache


def _decode_attn_gqa(cfg, p, x, cache_k, cache_v, cache_len, cos, sin):
    """x: (B,1,d); caches (B,Smax,HK,Dh). Returns (out, new_k_row, new_v_row).
    cos/sin are single-row tables for the current position (index 0)."""
    pos = jnp.zeros((x.shape[0], 1), jnp.int32)
    q = jnp.einsum("bsd,dhe->bshe", x, p["w_q"])
    k = jnp.einsum("bsd,dhe->bshe", x, p["w_k"])
    v = jnp.einsum("bsd,dhe->bshe", x, p["w_v"])
    if cfg.qkv_bias:
        q, k, v = q + p["b_q"], k + p["b_k"], v + p["b_v"]
    q = apply_rope(q, cos, sin, pos)
    k = apply_rope(k, cos, sin, pos)
    ck = jax.lax.dynamic_update_slice_in_dim(cache_k, k, cache_len, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache_v, v, cache_len, axis=1)
    o = attn_lib.decode_attention(q, ck, cv, cache_len + 1)
    return jnp.einsum("bshe,hed->bsd", o, p["w_o"]), ck, cv


def _decode_attn_mla(cfg, p, x, cache_ckv, cache_kr, cache_len, cos, sin):
    """MLA absorbed decode: attention in latent space (no k/v expansion).
    cos/sin are single-row tables for the current position (index 0)."""
    m = cfg.mla
    b = x.shape[0]
    pos = jnp.zeros((b, 1), jnp.int32)
    cq = rms_norm(x @ p["w_dq"], p["q_norm"])
    q = jnp.einsum("bsr,rhe->bshe", cq, p["w_uq"])[:, 0]  # (B,H,nope+rope)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_dim], axis=-1)
    q_rope = apply_rope(q_rope[:, None], cos, sin, pos)[:, 0]

    ckv_new = rms_norm(x @ p["w_dkv"], p["kv_norm"])  # (B,1,r)
    kr_new = apply_rope((x @ p["w_kr"])[:, :, None, :], cos, sin, pos)[:, :, 0]  # (B,1,rope)
    ckv = jax.lax.dynamic_update_slice_in_dim(cache_ckv, ckv_new, cache_len, axis=1)
    kr = jax.lax.dynamic_update_slice_in_dim(cache_kr, kr_new, cache_len, axis=1)

    # Absorb W_uk into q: scores = (q_nope @ W_uk^T) . ckv + q_rope . k_rope
    q_lat = jnp.einsum("bhe,rhe->bhr", q_nope, p["w_uk"])  # (B,H,r)
    scale = (m.qk_nope_dim + m.qk_rope_dim) ** -0.5
    s = (jnp.einsum("bhr,bkr->bhk", q_lat, ckv)
         + jnp.einsum("bhe,bke->bhk", q_rope, kr)).astype(jnp.float32) * scale
    valid = jnp.arange(ckv.shape[1])[None] < (cache_len + 1)
    s = jnp.where(valid[:, None], s, attn_lib.NEG_INF)
    pr = jax.nn.softmax(s, axis=-1).astype(ckv.dtype)
    o_lat = jnp.einsum("bhk,bkr->bhr", pr, ckv)  # (B,H,r)
    o = jnp.einsum("bhr,rhe->bhe", o_lat, p["w_uv"])  # (B,H,v_dim)
    out = jnp.einsum("bhe,hed->bd", o, p["w_o"])[:, None]
    return out, ckv, kr


def make_serve_step(cfg: LMConfig):
    """decode: (params, cache, tokens (B,1)) -> (logits (B,V), new cache)."""

    def serve_step(params, cache, tokens):
        x = params["embed"][tokens]  # (B,1,d)
        cache_len = cache["length"]
        # Single-row rope table for the current position (avoids a
        # (max_len, d/2) table per decode step at 500k context).
        cos, sin = rope_row(
            cache_len, cfg.mla.qk_rope_dim if cfg.mla else cfg.head_dim,
            cfg.rope_theta,
        )
        new_cache: Dict[str, Any] = {"length": cache_len + 1}

        def run_layer(p, x, layer_cache):
            h = _apply_norm(cfg, p["ln1"], x)
            if cfg.mla is not None:
                a, ckv, kr = _decode_attn_mla(
                    cfg, p["attn"], h, layer_cache["ckv"], layer_cache["k_rope"],
                    cache_len, cos, sin)
                upd = {"ckv": ckv, "k_rope": kr}
            else:
                a, ck, cv = _decode_attn_gqa(
                    cfg, p["attn"], h, layer_cache["k"], layer_cache["v"],
                    cache_len, cos, sin)
                upd = {"k": ck, "v": cv}
            if cfg.parallel_block:
                f, _ = _ffn_block(cfg, p, h)
                return x + a + f, upd
            x = x + a
            f, _ = _ffn_block(cfg, p, _apply_norm(cfg, p["ln2"], x))
            return x + f, upd

        if cfg.first_k_dense:
            dense_upds = []
            for i in range(cfg.first_k_dense):
                lc = jax.tree_util.tree_map(lambda c: c[i], cache["dense"])
                x, upd = run_layer(params[f"dense_layer_{i}"], x, lc)
                dense_upds.append(upd)
            new_cache["dense"] = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *dense_upds
            )

        if "layers" in params:
            def body(x, xs):
                layer_p, layer_cache = xs
                x, upd = run_layer(layer_p, x, layer_cache)
                return x, upd

            x, scan_upd = jax.lax.scan(body, x, (params["layers"], cache["scan"]))
            new_cache["scan"] = scan_upd

        h = _apply_norm(cfg, params["final_norm"], x)
        logits = (h[:, 0] @ _unembed(cfg, params)).astype(jnp.float32)
        return logits[:, : cfg.vocab], new_cache

    return serve_step


def make_prefill(cfg: LMConfig):
    """prefill: (params, tokens (B,S)) -> (hidden (B,S,d),) -- inference
    forward (no loss); used by the prefill_32k shape."""

    def prefill(params, tokens):
        hidden, _ = forward_hidden(cfg, params, tokens)
        logits_last = (hidden[:, -1] @ _unembed(cfg, params)).astype(jnp.float32)
        return logits_last[:, : cfg.vocab]

    return prefill
