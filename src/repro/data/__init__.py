"""Data pipeline: synthetic generators + graph neighbor sampling."""

from .synthetic import (
    lm_batch,
    recsys_batch,
    dien_batch,
    sasrec_batch,
    random_graph,
    molecule_batch,
)
from .graph_sampler import NeighborSampler, build_csr

__all__ = [
    "lm_batch",
    "recsys_batch",
    "dien_batch",
    "sasrec_batch",
    "random_graph",
    "molecule_batch",
    "NeighborSampler",
    "build_csr",
]
