"""Real fanout neighbor sampler (GraphSAGE-style) for minibatch GNN training.

`build_csr` converts an edge list to CSR once; `NeighborSampler.sample`
draws a k-hop sampled subgraph around a seed batch with per-hop fanouts
(the assigned minibatch_lg shape uses fanout 15-10), returning fixed-size
padded arrays (edge_mask marks real edges) so the jitted train step never
re-traces.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import numpy as np


def build_csr(edge_src: np.ndarray, edge_dst: np.ndarray, n_nodes: int):
    """CSR of incoming edges: for each node, the list of its neighbors
    (message sources). Returns (indptr, indices)."""
    order = np.argsort(edge_dst, kind="stable")
    indices = edge_src[order]
    counts = np.bincount(edge_dst, minlength=n_nodes)
    indptr = np.zeros(n_nodes + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    return indptr, indices


@dataclass
class SampledBlock:
    """Padded sampled subgraph: local ids 0..n_active-1, seeds first."""

    node_ids: np.ndarray  # (max_nodes,) global ids (padded w/ 0)
    n_active: int
    edge_src: np.ndarray  # (max_edges,) local ids
    edge_dst: np.ndarray
    edge_mask: np.ndarray  # (max_edges,) bool
    seed_count: int


class NeighborSampler:
    def __init__(self, edge_src, edge_dst, n_nodes: int, fanouts: Sequence[int],
                 seed: int = 0):
        self.indptr, self.indices = build_csr(
            np.asarray(edge_src), np.asarray(edge_dst), n_nodes
        )
        self.n_nodes = n_nodes
        self.fanouts = list(fanouts)
        self.rng = np.random.default_rng(seed)

    def max_sizes(self, batch_nodes: int) -> Tuple[int, int]:
        """Padded (max_nodes, max_edges) for a given seed-batch size."""
        nodes, edges, frontier = batch_nodes, 0, batch_nodes
        for f in self.fanouts:
            edges += frontier * f
            frontier *= f
            nodes += frontier
        return nodes, edges

    def sample(self, seeds: np.ndarray) -> SampledBlock:
        seeds = np.asarray(seeds, dtype=np.int64)
        max_nodes, max_edges = self.max_sizes(len(seeds))

        local: Dict[int, int] = {int(g): i for i, g in enumerate(seeds)}
        node_ids: List[int] = list(map(int, seeds))
        es: List[int] = []
        ed: List[int] = []

        frontier = seeds
        for fanout in self.fanouts:
            next_frontier: List[int] = []
            for g in frontier:
                lo, hi = self.indptr[g], self.indptr[g + 1]
                deg = hi - lo
                if deg == 0:
                    continue
                take = min(fanout, deg)
                picks = self.indices[
                    lo + self.rng.choice(deg, size=take, replace=False)
                ]
                for nb in picks:
                    nb = int(nb)
                    if nb not in local:
                        local[nb] = len(node_ids)
                        node_ids.append(nb)
                        next_frontier.append(nb)
                    # message edge: neighbor -> node
                    es.append(local[nb])
                    ed.append(local[int(g)])
            frontier = np.asarray(next_frontier, dtype=np.int64)
            if len(frontier) == 0:
                break

        n_active, n_e = len(node_ids), len(es)
        pad_nodes = np.zeros(max_nodes, np.int32)
        pad_nodes[:n_active] = np.asarray(node_ids, np.int32)
        pe_src = np.zeros(max_edges, np.int32)
        pe_dst = np.zeros(max_edges, np.int32)
        mask = np.zeros(max_edges, bool)
        pe_src[:n_e] = np.asarray(es, np.int32)
        pe_dst[:n_e] = np.asarray(ed, np.int32)
        mask[:n_e] = True
        return SampledBlock(pad_nodes, n_active, pe_src, pe_dst, mask, len(seeds))

    def make_batch(self, block: SampledBlock, feats, labels) -> Dict:
        """Materialize the jit-ready minibatch dict from a sampled block."""
        label_mask = np.zeros(block.node_ids.shape[0], bool)
        label_mask[: block.seed_count] = True
        return {
            "feats": feats[block.node_ids],
            "edge_src": block.edge_src,
            "edge_dst": block.edge_dst,
            "edge_mask": block.edge_mask,
            "labels": labels[block.node_ids].astype(np.int32),
            "label_mask": label_mask,
        }
