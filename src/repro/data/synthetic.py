"""Synthetic batch generators (numpy-side host pipeline).

Real deployments stream from storage; every generator here is shaped and
typed exactly like the production input_specs so the same train/serve steps
run unmodified.
"""

from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import numpy as np


def lm_batch(rng: np.random.Generator, batch: int, seq: int, vocab: int) -> Dict:
    toks = rng.integers(0, vocab, size=(batch, seq), dtype=np.int32)
    labels = np.roll(toks, -1, axis=1)
    labels[:, -1] = -1  # masked
    return {"tokens": toks, "labels": labels}


def recsys_batch(
    rng: np.random.Generator, batch: int, n_dense: int, vocab_sizes: Sequence[int]
) -> Dict:
    dense = np.log1p(rng.exponential(1.0, size=(batch, n_dense))).astype(np.float32)
    sparse = np.stack(
        [rng.integers(0, v, size=batch, dtype=np.int32) for v in vocab_sizes], axis=1
    )
    labels = (rng.random(batch) < 0.25).astype(np.float32)
    return {"dense": dense, "sparse": sparse, "labels": labels}


def sasrec_batch(rng, batch: int, seq: int, n_items: int) -> Dict:
    seqs = rng.integers(1, n_items, size=(batch, seq), dtype=np.int32)
    pos = np.roll(seqs, -1, axis=1)
    pos[:, -1] = rng.integers(1, n_items, size=batch)
    neg = rng.integers(1, n_items, size=(batch, seq), dtype=np.int32)
    return {"seq": seqs, "pos": pos, "neg": neg}


def dien_batch(rng, batch: int, seq: int, n_items: int, n_cats: int) -> Dict:
    return {
        "hist_items": rng.integers(0, n_items, size=(batch, seq), dtype=np.int32),
        "hist_cats": rng.integers(0, n_cats, size=(batch, seq), dtype=np.int32),
        "target_item": rng.integers(0, n_items, size=batch, dtype=np.int32),
        "target_cat": rng.integers(0, n_cats, size=batch, dtype=np.int32),
        "labels": (rng.random(batch) < 0.5).astype(np.float32),
    }


def random_graph(
    rng, n_nodes: int, n_edges: int, d_feat: int, n_classes: int,
    power_law: bool = True,
) -> Dict:
    """Directed edge list with a skewed (power-law-ish) degree distribution,
    node features, labels, and a train mask."""
    if power_law:
        w = 1.0 / (np.arange(1, n_nodes + 1) ** 0.8)
        p = w / w.sum()
        dst = rng.choice(n_nodes, size=n_edges, p=p).astype(np.int32)
    else:
        dst = rng.integers(0, n_nodes, size=n_edges, dtype=np.int32)
    src = rng.integers(0, n_nodes, size=n_edges, dtype=np.int32)
    return {
        "feats": rng.standard_normal((n_nodes, d_feat)).astype(np.float32),
        "edge_src": src,
        "edge_dst": dst,
        "labels": rng.integers(0, n_classes, size=n_nodes, dtype=np.int32),
        "label_mask": (rng.random(n_nodes) < 0.3),
    }


def molecule_batch(
    rng, n_graphs: int, nodes_per_graph: int, edges_per_graph: int,
    d_feat: int, n_classes: int,
) -> Dict:
    """Batched small graphs (disjoint union) for graph classification."""
    n = n_graphs * nodes_per_graph
    e = n_graphs * edges_per_graph
    offs = np.repeat(np.arange(n_graphs) * nodes_per_graph, edges_per_graph)
    src = rng.integers(0, nodes_per_graph, size=e).astype(np.int32) + offs
    dst = rng.integers(0, nodes_per_graph, size=e).astype(np.int32) + offs
    return {
        "feats": rng.standard_normal((n, d_feat)).astype(np.float32),
        "edge_src": src.astype(np.int32),
        "edge_dst": dst.astype(np.int32),
        "graph_ids": np.repeat(np.arange(n_graphs), nodes_per_graph).astype(np.int32),
        "labels": rng.integers(0, n_classes, size=n_graphs, dtype=np.int32),
    }
