"""ArchSpec: uniform description of (architecture x input-shape) cells.

Every assigned architecture module under repro.configs exposes
`spec() -> ArchSpec`. The dry-run runner, smoke tests, and benchmarks
consume only this interface.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional


@dataclass(frozen=True)
class ShapeCell:
    """One (arch x shape) cell.

    kind: 'train' | 'prefill' | 'decode' | 'forward' | 'retrieval'
    model_overrides: dataclasses.replace kwargs applied to the model config
    for this cell (dtype, attention chunking, remat, ...).
    run_overrides: runner knobs (n_microbatches, cache length, ...).
    """

    name: str
    kind: str
    batch: int = 0
    seq: int = 0
    extras: Dict[str, Any] = field(default_factory=dict)
    model_overrides: Dict[str, Any] = field(default_factory=dict)
    run_overrides: Dict[str, Any] = field(default_factory=dict)


@dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str  # 'lm' | 'gnn' | 'recsys'
    model: Any  # base model config (LMConfig / GINConfig / DLRM... )
    cells: Dict[str, ShapeCell]
    recsys_kind: str = ""  # 'dlrm' | 'sasrec' | 'dien' for family == 'recsys'
    notes: str = ""

    def cell(self, name: str) -> ShapeCell:
        if name not in self.cells:
            raise KeyError(f"{self.arch_id} has no shape {name!r}; has {sorted(self.cells)}")
        return self.cells[name]


# Standard LM shape set (assigned): seq_len x global_batch.
def lm_cells(
    train_microbatches: int = 1,
    prefill_chunk: int = 1024,
    train_overrides: Optional[Dict[str, Any]] = None,
) -> Dict[str, ShapeCell]:
    # Chunked (online-softmax) attention in training keeps the S x S scores
    # out of HBM -- the jnp analog of the Pallas flash kernel the TPU build
    # uses; 512-wide KV chunks.
    t_over = {"dtype": "bfloat16", "attn_chunk_k": 512, "moe_groups": 256,
              **(train_overrides or {})}
    return {
        "train_4k": ShapeCell(
            "train_4k", "train", batch=256, seq=4096,
            model_overrides=t_over,
            run_overrides={"n_microbatches": train_microbatches},
        ),
        "prefill_32k": ShapeCell(
            "prefill_32k", "prefill", batch=32, seq=32768,
            model_overrides={"dtype": "bfloat16", "attn_chunk_k": prefill_chunk,
                             "max_seq_len": 32768, "moe_groups": 256},
        ),
        "decode_32k": ShapeCell(
            "decode_32k", "decode", batch=128, seq=32768,
            model_overrides={"dtype": "bfloat16", "max_seq_len": 32768,
                             "moe_groups": 128},
        ),
        "long_500k": ShapeCell(
            "long_500k", "decode", batch=1, seq=524288,
            model_overrides={"dtype": "bfloat16", "max_seq_len": 524288},
        ),
    }
