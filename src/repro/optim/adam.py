"""Adam / AdamW.

Moments are kept in fp32 regardless of parameter dtype (mixed-precision
training with bf16 params); the update is computed in fp32 and cast back.
`fused=True` routes the elementwise update through the Pallas agg_adam kernel
(interpret mode on CPU) -- numerically identical, used to validate the
kernel against this reference path.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .base import Optimizer


class AdamState(NamedTuple):
    mu: object
    nu: object
    count: jnp.ndarray


def _adam_update(p, g, mu, nu, count, lr, b1, b2, eps, wd):
    g32 = g.astype(jnp.float32)
    mu = b1 * mu + (1 - b1) * g32
    nu = b2 * nu + (1 - b2) * jnp.square(g32)
    t = count.astype(jnp.float32)
    mu_hat = mu / (1 - b1 ** t)
    nu_hat = nu / (1 - b2 ** t)
    upd = mu_hat / (jnp.sqrt(nu_hat) + eps)
    if wd:
        upd = upd + wd * p.astype(jnp.float32)
    new_p = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
    return new_p, mu, nu


def adam(
    lr: float,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    fused: bool = False,
) -> Optimizer:
    def init(params):
        zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamState(
            mu=jax.tree_util.tree_map(zeros32, params),
            nu=jax.tree_util.tree_map(zeros32, params),
            count=jnp.zeros((), jnp.int32),
        )

    def step(params, grads, state):
        count = state.count + 1
        if fused:
            from repro.kernels.agg_adam import ops as agg_ops

            def upd(p, g, mu, nu):
                return agg_ops.adam_update(
                    p, g, mu, nu, count, lr=lr, b1=b1, b2=b2, eps=eps, wd=weight_decay
                )
        else:
            def upd(p, g, mu, nu):
                return _adam_update(p, g, mu, nu, count, lr, b1, b2, eps, weight_decay)

        out = jax.tree_util.tree_map(upd, params, grads, state.mu, state.nu)
        # out is a pytree of (p, mu, nu) tuples; unzip it.
        treedef = jax.tree_util.tree_structure(params)
        flat = treedef.flatten_up_to(out)
        new_params = treedef.unflatten([o[0] for o in flat])
        new_mu = treedef.unflatten([o[1] for o in flat])
        new_nu = treedef.unflatten([o[2] for o in flat])
        return new_params, AdamState(new_mu, new_nu, count)

    return Optimizer(init=init, step=step, name="adam")


def adamw(lr: float, weight_decay: float = 0.01, **kw) -> Optimizer:
    return adam(lr, weight_decay=weight_decay, **kw)._replace(name="adamw")
