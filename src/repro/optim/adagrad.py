"""Adagrad -- the classic PS-era optimizer; standard for DLRM embeddings."""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .base import Optimizer


class AdagradState(NamedTuple):
    accum: object
    count: jnp.ndarray


def adagrad(lr: float, eps: float = 1e-10, initial_accum: float = 0.0) -> Optimizer:
    def init(params):
        return AdagradState(
            accum=jax.tree_util.tree_map(
                lambda p: jnp.full(p.shape, initial_accum, jnp.float32), params
            ),
            count=jnp.zeros((), jnp.int32),
        )

    def step(params, grads, state):
        def upd(p, g, a):
            g32 = g.astype(jnp.float32)
            a = a + jnp.square(g32)
            new_p = p.astype(jnp.float32) - lr * g32 / (jnp.sqrt(a) + eps)
            return new_p.astype(p.dtype), a

        out = jax.tree_util.tree_map(upd, params, grads, state.accum)
        treedef = jax.tree_util.tree_structure(params)
        flat = treedef.flatten_up_to(out)
        new_params = treedef.unflatten([o[0] for o in flat])
        new_accum = treedef.unflatten([o[1] for o in flat])
        return new_params, AdagradState(new_accum, state.count + 1)

    return Optimizer(init=init, step=step, name="adagrad")
