"""Hand-built optimizers (no optax offline).

Each factory returns an `Optimizer(init, step)` pair operating on pytrees.
`step(params, grads, state) -> (new_params, new_state)`. The Adam update can
route through the fused Pallas aggregation kernel (repro.kernels.agg_adam)
when `fused=True` -- that kernel is the paper's hot op (sum worker gradients
+ apply update in one pass over the tensor).
"""

from .base import Optimizer, OptState
from .sgd import sgd
from .adam import adam, adamw
from .adagrad import adagrad

__all__ = ["Optimizer", "OptState", "sgd", "adam", "adamw", "adagrad"]
