"""Optimizer interface shared by sgd/adam/adagrad and the PS runtime."""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax

PyTree = Any
OptState = Any


class Optimizer(NamedTuple):
    """A pytree optimizer.

    init(params) -> state
    step(params, grads, state) -> (new_params, new_state)
    """

    init: Callable[[PyTree], OptState]
    step: Callable[[PyTree, PyTree, OptState], Tuple[PyTree, OptState]]
    name: str = "optimizer"


def tree_zeros_like(params: PyTree, dtype=None) -> PyTree:
    import jax.numpy as jnp

    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, dtype or p.dtype), params
    )
