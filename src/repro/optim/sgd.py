"""SGD with optional momentum and weight decay."""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .base import Optimizer, tree_zeros_like


class SgdState(NamedTuple):
    momentum: object
    count: jnp.ndarray


def sgd(lr: float, momentum: float = 0.0, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        mom = tree_zeros_like(params) if momentum else None
        return SgdState(momentum=mom, count=jnp.zeros((), jnp.int32))

    def step(params, grads, state):
        if weight_decay:
            grads = jax.tree_util.tree_map(
                lambda g, p: g + weight_decay * p.astype(g.dtype), grads, params
            )
        if momentum:
            new_mom = jax.tree_util.tree_map(
                lambda m, g: momentum * m + g, state.momentum, grads
            )
            upd = new_mom
        else:
            new_mom = None
            upd = grads
        new_params = jax.tree_util.tree_map(
            lambda p, u: (p - lr * u.astype(p.dtype)), params, upd
        )
        return new_params, SgdState(new_mom, state.count + 1)

    return Optimizer(init=init, step=step, name="sgd")
