"""High-QPS read tier: pull-only parameter replicas fed by
publish-on-tick snapshots of the engines' shard spaces (PR 10).

The paper decouples aggregation from training so a SHARED service can
amortize bursty work; the ROADMAP's north star ("serve heavy traffic
from millions of users") means reads must dominate writes -- yet every
``engine.pull()`` contends with the tick engines' write path (it forces
ticks at the staleness bound, touches the live donated buffers, and
dies with a quarantined lane).  This module puts the PR-8 version
machinery behind a dedicated read tier:

  publish      every applying tick, each lane (a ``_ShardLane``; the
               flat engine is one unnamed lane) offers the hub an
               immutable ``(flat, version_vector, epoch)`` snapshot at a
               configurable ``publish_interval``.  Publishing is
               CO-LOCATED with the PR-7 rollback snapshot -- both fire
               pre-apply, so on ticks where the lane refreshes its
               rollback anchor the published ``flat`` IS the anchor's
               copy (no extra state copy); other publish ticks copy the
               one ``flat`` buffer only (never mu/nu/ef).
  pull         a :class:`ParameterReplica` serves ``pull(job_id)``
               (parameter pytree) and versioned ``pull(job_id,
               since_version=...)`` diffs (the PR-8 :class:`PullDiff`
               protocol, byte-compatible with the engines' own) from its
               held snapshots -- ZERO work on the write path.
  pull_batch   the batched lookup API: ``[(job_id, since_version), ...]``
               gathers every requested job's changed rows in ONE jitted
               concat+gather launch per replica instead of K sequential
               per-job pulls.
  staleness    ``max_staleness_ticks`` bounds how far a served snapshot
               may trail the lane's tick counter; a replica REFUSES to
               serve past the bound and forces a refresh
               (``ReadStats.n_forced_refreshes``).

Failure semantics mirror the engines':

* REPLANS -- the epoch fence.  A replan bumps the engine epoch; held
  snapshots (old geometry) are detected stale on the next serve and the
  replica resubscribes via a forced full publish.  Client-held
  ``PullVersion`` vectors cross the same fence and fall back to full.
* QUARANTINE -- a quarantined lane stops publishing, and a forced
  refresh of it is impossible; the replica keeps serving its LAST-GOOD
  snapshot with the serve flagged ``degraded``
  (``ReadStats.n_degraded_serves``).  This is the read tier's point:
  direct ``engine.pull()`` raises the lane's
  :class:`~repro.ps.faults.EngineQuarantinedError`, replicas stay up.

Usage::

    eng = rt.attach_engine(...)
    rs = ReplicaSet(eng, n_replicas=4, publish_interval=1,
                    max_staleness_ticks=8)
    ... train: every applying tick publishes ...
    params = rs.pull("job")               # round-robin over replicas
    diff = rs.pull("job", since_version=held_vector)
    diffs = rs.pull_batch([("a", va), ("b", 0), ...])  # one gather
    rs.refresh()                          # force-publish current state

Both runtimes surface per-replica :class:`ReadStats` under the
``"replicas"`` key of ``debug_stats()``.
"""

from __future__ import annotations

import time

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.ps.engine import PullDiff, PullVersion
from repro.ps.faults import QUARANTINED

__all__ = ["ParameterReplica", "ReadStats", "ReplicaSet", "ShardSnapshot"]

# The flat engine is one unnamed lane; its snapshots key on None.
_FLAT_LANE = None


@dataclass(frozen=True)
class ShardSnapshot:
    """One lane's published state: immutable by convention -- ``flat``
    is never mutated in place by the engine (rollback restores COPY the
    anchor; donated applies consume the live buffers, not this copy), so
    every subscribed replica shares the same arrays."""

    shard_id: Optional[str]  # None: the flat engine's single lane
    epoch: int  # plan epoch the geometry belongs to
    tick: int  # lane's applying-tick counter at publish (staleness base)
    seq: int  # hub-wide publish sequence number
    flat: Any  # (shard_len,) parameter buffer
    versions: np.ndarray  # per-``block_align``-block versions, full space


@dataclass
class ReadStats:
    """Per-replica serving counters (PR 10), surfaced by both runtimes'
    ``debug_stats()`` under ``"replicas"``."""

    n_pulls: int = 0  # single-job pulls served (full + diff)
    n_batches: int = 0  # pull_batch calls served
    n_batch_jobs: int = 0  # jobs served inside those batches
    n_full_serves: int = 0  # full-payload serves (bootstrap/fallback)
    n_diff_serves: int = 0  # changed-blocks-only serves
    bytes_served: int = 0  # payload bytes shipped (fp32 wire model)
    n_snapshots_seen: int = 0  # publishes this replica received
    n_forced_refreshes: int = 0  # staleness-bound / epoch-fence refreshes
    n_degraded_serves: int = 0  # serves from a quarantined lane's last-good
    serve_seconds: float = 0.0  # wall time inside pull/pull_batch
    # Snapshot age at serve time, in lane ticks: {staleness: serves}.
    staleness_hist: Dict[int, int] = field(default_factory=dict)

    @property
    def pulls_per_sec(self) -> float:
        """Jobs served per second of serve time (batched jobs count)."""
        if self.serve_seconds <= 0:
            return 0.0
        return (self.n_pulls + self.n_batch_jobs) / self.serve_seconds

    def _record_staleness(self, ticks: int) -> None:
        t = int(ticks)
        self.staleness_hist[t] = self.staleness_hist.get(t, 0) + 1


class ParameterReplica:
    """One pull-only serving endpoint: holds its own map of published
    :class:`ShardSnapshot` objects (shared immutable arrays -- N
    replicas cost one publish, not N copies) and serves reads from them
    without ever touching the engine's write path."""

    def __init__(self, hub: "ReplicaSet", replica_id: int):
        self.replica_id = int(replica_id)
        self._hub = hub
        self._snaps: Dict[Optional[str], ShardSnapshot] = {}
        self.stats = ReadStats()
        self._gather_fns: Dict[int, Any] = {}  # n_lanes -> jitted gather
        self.degraded_lanes: Tuple[Optional[str], ...] = ()

    # ------------------------------------------------------------ freshness
    def _ensure_fresh(self, keys: Sequence[Optional[str]]) -> bool:
        """Bring every named lane's snapshot within the epoch fence and
        the staleness bound; returns True when any serve had to fall
        back to a quarantined lane's last-good snapshot (degraded)."""
        hub = self._hub
        epoch = hub.epoch
        bound = hub.max_staleness_ticks
        stale: List[Optional[str]] = []
        degraded: List[Optional[str]] = []
        for key in keys:
            snap = self._snaps.get(key)
            if hub.lane_quarantined(key):
                # The lane will never tick (or publish) again.  A
                # matching-epoch snapshot is its last-good state: serve
                # it, flagged -- regardless of any staleness bound.  A
                # cross-epoch (or missing) snapshot has the WRONG
                # geometry -- nothing safe to serve.
                if snap is not None and snap.epoch == epoch:
                    degraded.append(key)
                    continue
                raise hub.lane_error(key)
            fence = snap is None or snap.epoch != epoch
            over = (not fence and bound is not None
                    and hub.lane_tick(key) - snap.tick > bound)
            if fence or over:
                stale.append(key)
        if stale:
            # Stale epoch -> resubscribe + full publish; over the
            # staleness bound -> refuse to serve, force a refresh.
            self.stats.n_forced_refreshes += 1
            hub.refresh(stale)
        self.degraded_lanes = tuple(degraded)
        max_stale = 0
        for key in keys:
            if key in self.degraded_lanes:
                continue
            snap = self._snaps[key]
            max_stale = max(max_stale, hub.lane_tick(key) - snap.tick)
        self.stats._record_staleness(max_stale)
        if degraded:
            self.stats.n_degraded_serves += 1
        return bool(degraded)

    def _publish(self, snap: ShardSnapshot) -> None:
        self._snaps[snap.shard_id] = snap
        self.stats.n_snapshots_seen += 1

    # ----------------------------------------------------------- single pull
    def pull(self, job_id: str, since_version=None):
        """Serve one job from held snapshots: a parameter pytree, or --
        with ``since_version`` -- a :class:`PullDiff` of the blocks whose
        published version moved past the client's vector (``0``
        bootstraps full).  Same protocol as ``engine.pull``, served from
        the read tier."""
        t0 = time.perf_counter()
        keys, layouts = self._hub.job_lanes(job_id)
        self._ensure_fresh(keys)
        if since_version is not None and isinstance(since_version,
                                                    PullVersion):
            # A client that last pulled from the ENGINE may hold versions
            # AHEAD of this replica's snapshot; serving a diff against
            # older published versions would silently report "no change".
            # Refuse and refresh to at least the client's view.
            vers = self._job_versions(keys, layouts)
            if (since_version.epoch == self._hub.epoch
                    and since_version.versions.size == vers.size
                    and np.any(since_version.versions > vers)):
                self.stats.n_forced_refreshes += 1
                self._hub.refresh([k for k in keys
                                   if k not in self.degraded_lanes])
        try:
            if since_version is None:
                out = self._serve_tree(job_id, keys, layouts)
            else:
                out = self._serve_diff(job_id, keys, layouts,
                                       since_version)
            self.stats.n_pulls += 1
            return out
        finally:
            self.stats.serve_seconds += time.perf_counter() - t0

    def _job_versions(self, keys, layouts) -> np.ndarray:
        parts = [self._snaps[k].versions[np.asarray(l.blocks)]
                 for k, l in zip(keys, layouts)]
        return parts[0].copy() if len(parts) == 1 else np.concatenate(parts)

    def _serve_tree(self, job_id, keys, layouts):
        from repro.ps.runtime import _unpack_slots

        layout, abstract = self._hub.job_layout_abstract(job_id)
        pieces = []
        for key, l in zip(keys, layouts):
            flat = self._snaps[key].flat
            pieces.append(flat.reshape(-1, l.block)[
                jnp.asarray(np.asarray(l.blocks))].reshape(-1))
        packed = pieces[0] if len(pieces) == 1 else jnp.concatenate(pieces)
        self.stats.n_full_serves += 1
        self.stats.bytes_served += 4 * int(layout.packed_len)
        return _unpack_slots(layout, packed, abstract)

    def _serve_diff(self, job_id, keys, layouts, since) -> PullDiff:
        vers = self._job_versions(keys, layouts)
        version = PullVersion(epoch=self._hub.epoch, versions=vers)
        blocks = {l.block for l in layouts}
        uniform = len(blocks) == 1
        packed_len = sum(int(np.asarray(l.blocks).size) * l.block
                         for l in layouts)
        bytes_full = 4 * packed_len
        full = (not uniform
                or not isinstance(since, PullVersion)
                or since.epoch != self._hub.epoch
                or since.versions.size != vers.size)
        if full:
            pieces = [self._snaps[k].flat.reshape(-1, l.block)[
                jnp.asarray(np.asarray(l.blocks))].reshape(-1)
                for k, l in zip(keys, layouts)]
            data = (pieces[0] if len(pieces) == 1
                    else jnp.concatenate(pieces))
            diff = PullDiff(
                job_id=job_id, version=version, full=True,
                block=(blocks.pop() if uniform else 0),
                block_ids=np.empty(0, np.int64), data=data,
                bytes_wire=bytes_full, bytes_full=bytes_full)
            self.stats.n_full_serves += 1
        else:
            block = blocks.pop()
            changed = vers > since.versions
            data_parts, id_parts = [], []
            off = 0
            for key, l in zip(keys, layouts):
                nb = int(np.asarray(l.blocks).size)
                sel = np.nonzero(changed[off:off + nb])[0]
                if sel.size:
                    flat = self._snaps[key].flat
                    data_parts.append(flat.reshape(-1, l.block)[
                        jnp.asarray(np.asarray(l.blocks)[sel])])
                    id_parts.append(off + sel)
                off += nb
            if data_parts:
                data = (jnp.concatenate(data_parts)
                        if len(data_parts) > 1 else data_parts[0])
                ids = np.concatenate(id_parts).astype(np.int64)
            else:
                data = jnp.zeros((0, block), jnp.float32)
                ids = np.empty(0, np.int64)
            diff = PullDiff(
                job_id=job_id, version=version, full=False, block=block,
                block_ids=ids, data=data,
                bytes_wire=4 * int(ids.size) * block,
                bytes_full=bytes_full)
            self.stats.n_diff_serves += 1
        self.stats.bytes_served += diff.bytes_wire
        return diff

    # ---------------------------------------------------------- batched pull
    def pull_batch(self, requests: Sequence[Tuple[str, Any]]
                   ) -> List[PullDiff]:
        """Serve K jobs in ONE jitted concat+gather launch: every
        requested job's needed rows (all owned blocks for a bootstrap or
        fallback, changed blocks for a held vector) collect into one
        global row-index array over the involved lanes' stacked
        snapshot matrices, one gather ships them all, and the rows split
        back into per-job :class:`PullDiff` results -- the K per-job
        gathers (and K python round-trips) of sequential pulls collapse
        to one.  Falls back to the per-job path only when the involved
        lanes disagree on ``block_align`` (mixed granularity has no
        single row width)."""
        t0 = time.perf_counter()
        try:
            reqs = [(j, since) for j, since in requests]
            lanes: List[Optional[str]] = []
            per_job = []
            for j, _ in reqs:
                keys, layouts = self._hub.job_lanes(j)
                per_job.append((keys, layouts))
                for k in keys:
                    if k not in lanes:
                        lanes.append(k)
            self._ensure_fresh(lanes)
            blocks = {l.block for _, layouts in per_job for l in layouts}
            out: List[PullDiff] = []
            if len(blocks) != 1:
                for (j, since), _ in zip(reqs, per_job):
                    out.append(self._serve_diff(
                        j, *self._hub.job_lanes(j),
                        since if since is not None else 0))
            else:
                out = self._serve_batch_uniform(reqs, per_job, lanes,
                                                blocks.pop())
            self.stats.n_batches += 1
            self.stats.n_batch_jobs += len(reqs)
            return out
        finally:
            self.stats.serve_seconds += time.perf_counter() - t0

    def _serve_batch_uniform(self, reqs, per_job, lanes, block):
        epoch = self._hub.epoch
        base: Dict[Optional[str], int] = {}
        rows_so_far = 0
        mats = []
        for key in lanes:
            base[key] = rows_so_far
            flat = self._snaps[key].flat
            rows_so_far += int(flat.shape[0]) // block
            mats.append(flat.reshape(-1, block))
        plan_rows: List[np.ndarray] = []  # global row ids, request order
        metas = []  # (job_id, version, full, ids, n_rows, bytes_full)
        for (j, since), (keys, layouts) in zip(reqs, per_job):
            vers = self._job_versions(keys, layouts)
            version = PullVersion(epoch=epoch, versions=vers)
            packed_len = sum(int(np.asarray(l.blocks).size) * block
                             for l in layouts)
            bytes_full = 4 * packed_len
            full = (not isinstance(since, PullVersion)
                    or since.epoch != epoch
                    or since.versions.size != vers.size)
            if full:
                g = np.concatenate(
                    [np.asarray(l.blocks) + base[k]
                     for k, l in zip(keys, layouts)])
                ids = np.empty(0, np.int64)
            else:
                changed = vers > since.versions
                g_parts, id_parts = [], []
                off = 0
                for k, l in zip(keys, layouts):
                    nb = int(np.asarray(l.blocks).size)
                    sel = np.nonzero(changed[off:off + nb])[0]
                    if sel.size:
                        g_parts.append(np.asarray(l.blocks)[sel] + base[k])
                        id_parts.append(off + sel)
                    off += nb
                g = (np.concatenate(g_parts) if g_parts
                     else np.empty(0, np.int64))
                ids = (np.concatenate(id_parts).astype(np.int64)
                       if id_parts else np.empty(0, np.int64))
            plan_rows.append(g.astype(np.int32))
            metas.append((j, version, full, ids, int(g.size), bytes_full))
        all_rows = (np.concatenate(plan_rows) if plan_rows
                    else np.empty(0, np.int32))
        fn = self._gather_fns.get(len(mats))
        if fn is None:
            def fn(ms, rows):
                mat = ms[0] if len(ms) == 1 else jnp.concatenate(ms)
                return mat[rows]

            fn = self._gather_fns[len(mats)] = jax.jit(fn)
        n_rows_total = int(all_rows.size)
        if n_rows_total:
            # Pad the row plan to the request set's total owned blocks (a
            # request-shape constant; also the bootstrap full pull's
            # shape) so the jitted gather compiles ONCE per batch shape
            # instead of retracing on every distinct changed-row count;
            # then split the wire payload back per job on the HOST --
            # device-side slicing would recompile an eager dynamic_slice
            # for every new (dirty pattern, job) shape.
            cap = sum(m[5] // (4 * block) for m in metas)
            padded = np.zeros(cap, np.int32)
            padded[:n_rows_total] = all_rows
            gathered = np.asarray(fn(tuple(mats), jnp.asarray(padded)))
        else:
            gathered = np.zeros((0, block), np.float32)
        out: List[PullDiff] = []
        off = 0
        for j, version, full, ids, n_rows, bytes_full in metas:
            rows = gathered[off:off + n_rows]
            off += n_rows
            if full:
                diff = PullDiff(
                    job_id=j, version=version, full=True, block=block,
                    block_ids=np.empty(0, np.int64),
                    data=jnp.asarray(rows.reshape(-1)),
                    bytes_wire=bytes_full, bytes_full=bytes_full)
                self.stats.n_full_serves += 1
            else:
                diff = PullDiff(
                    job_id=j, version=version, full=False, block=block,
                    block_ids=ids, data=jnp.asarray(rows),
                    bytes_wire=4 * n_rows * block, bytes_full=bytes_full)
                self.stats.n_diff_serves += 1
            self.stats.bytes_served += diff.bytes_wire
            out.append(diff)
        return out


class ReplicaSet:
    """N pull-only replicas subscribed to one tick engine.

    The set registers itself as the engine's replica hub: every applying
    tick the engine offers each lane for publication (pre-apply,
    co-located with the PR-7 rollback snapshot so a snapshot tick adds
    no extra state copy), and the hub re-publishes to every replica --
    the snapshots are shared immutable objects, so N replicas cost one
    copy.  Reads route round-robin via :meth:`pull` / :meth:`pull_batch`
    (or pick a replica directly from :attr:`replicas`)."""

    def __init__(self, engine, n_replicas: int = 2, *,
                 publish_interval: int = 1,
                 max_staleness_ticks: Optional[int] = None):
        if n_replicas < 1:
            raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")
        if publish_interval < 1:
            raise ValueError(
                f"publish_interval must be >= 1, got {publish_interval}")
        if max_staleness_ticks is not None and max_staleness_ticks < 0:
            raise ValueError(
                f"max_staleness_ticks must be >= 0 (None disables the "
                f"bound), got {max_staleness_ticks}")
        if getattr(engine, "_replica_hub", None) is not None:
            raise ValueError("engine already has a ReplicaSet attached")
        self.engine = engine
        self.publish_interval = int(publish_interval)
        self.max_staleness_ticks = (None if max_staleness_ticks is None
                                    else int(max_staleness_ticks))
        self._sharded = hasattr(engine, "_lanes")
        self._seq = 0
        self._since_pub: Dict[Optional[str], int] = {}
        self.n_publishes = 0
        self.n_reused_snapshot_copies = 0  # publishes riding the PR-7 copy
        self._rr = 0
        self.replicas: Tuple[ParameterReplica, ...] = tuple(
            ParameterReplica(self, i) for i in range(n_replicas))
        engine._replica_hub = self

    # ------------------------------------------------------- engine facing
    @property
    def epoch(self) -> int:
        return self.engine._epoch

    def _lane_keys(self) -> List[Optional[str]]:
        if not self._sharded:
            return [_FLAT_LANE]
        plan = self.engine.plan
        return [] if plan is None else list(plan.shard_ids)

    def lane_tick(self, key: Optional[str]) -> int:
        if key is _FLAT_LANE and not self._sharded:
            return self.engine.stats.n_ticks
        lane = self.engine._lanes.get(key)
        return 0 if lane is None else lane.stats.n_ticks

    def lane_quarantined(self, key: Optional[str]) -> bool:
        if key is _FLAT_LANE and not self._sharded:
            return self.engine.health == QUARANTINED
        lane = self.engine._lanes.get(key)
        return lane is not None and lane.health == QUARANTINED

    def lane_error(self, key: Optional[str]):
        if key is _FLAT_LANE and not self._sharded:
            return self.engine.quarantine_error
        return self.engine._lanes[key].quarantine_error

    def _lane_versions(self, key: Optional[str]) -> np.ndarray:
        eng = self.engine
        if key is _FLAT_LANE and not self._sharded:
            return eng._versions_array()
        return eng._lane_versions(eng._lane(key))

    def _live_flat(self, key: Optional[str]):
        if key is _FLAT_LANE and not self._sharded:
            return self.engine.runtime.state["flat"]
        return self.engine.runtime.states[key]["flat"]

    def _anchor_flat(self, key: Optional[str]):
        """The PR-7 rollback anchor's ``flat`` (already a copy), or None
        when the lane holds no snapshot."""
        if key is _FLAT_LANE and not self._sharded:
            snap = self.engine._snapshot
            return None if snap is None else snap[0]["flat"]
        lane = self.engine._lanes.get(key)
        return None if lane is None or lane.snapshot is None \
            else lane.snapshot["flat"]

    def on_tick(self, key: Optional[str], snapped: bool) -> None:
        """Engine hook, called once per applying tick of the named lane,
        PRE-apply (right after the lane's rollback-snapshot point).  The
        published state is therefore the result of every COMPLETED tick;
        with ``snapped`` the rollback anchor was refreshed this very
        tick and its ``flat`` copy is published as-is."""
        count = self._since_pub.get(key, 0) + 1
        snap = None
        for rep in self.replicas:
            snap = rep._snaps.get(key)
            break
        due = (count >= self.publish_interval
               or snap is None or snap.epoch != self.engine._epoch)
        if not due:
            self._since_pub[key] = count
            return
        if snapped:
            flat = self._anchor_flat(key)
            if flat is None:  # snapshots disabled mid-flight
                flat = self._live_flat(key).copy()
            else:
                self.n_reused_snapshot_copies += 1
        else:
            flat = self._live_flat(key).copy()
        self._publish(key, flat)
        self._since_pub[key] = 0

    def on_replan(self) -> None:
        """Engine hook: a replan landed (epoch bumped).  Held snapshots
        keep serving as last-good only behind the quarantine path; the
        next serve of any lane detects the stale epoch and resubscribes
        via a forced full publish."""
        self._since_pub.clear()

    # ---------------------------------------------------------- publication
    def _publish(self, key: Optional[str], flat) -> None:
        snap = ShardSnapshot(
            shard_id=key, epoch=self.engine._epoch,
            tick=self.lane_tick(key), seq=self._seq, flat=flat,
            versions=self._lane_versions(key).copy())
        self._seq += 1
        self.n_publishes += 1
        for rep in self.replicas:
            rep._publish(snap)

    def refresh(self, keys: Optional[Sequence[Optional[str]]] = None
                ) -> List[Optional[str]]:
        """Force-publish the CURRENT state of the named lanes (default:
        every live lane) -- the staleness-bound / epoch-fence refresh
        path, and the way to expose the final state after a drain (the
        on-tick publish is pre-apply, so it trails the in-flight tick).
        Quarantined lanes cannot republish (their last-good snapshot
        stands); returns the lanes actually published."""
        if keys is None:
            keys = self._lane_keys()
        published = []
        for key in keys:
            if self.lane_quarantined(key):
                continue
            self._publish(key, self._live_flat(key).copy())
            self._since_pub[key] = 0
            published.append(key)
        return published

    # ------------------------------------------------------------ job lookup
    def job_lanes(self, job_id: str):
        """(lane keys, per-lane JobLayouts) hosting the job, in shard
        order -- the flat engine is the single ``None`` lane."""
        plan = self.engine.plan
        if plan is None:
            raise ValueError("no plan compiled: the service hosts no jobs")
        layout = plan.job_layout(job_id)
        if self._sharded:
            return list(layout.shard_ids), list(layout.layouts)
        return [_FLAT_LANE], [layout]

    def job_layout_abstract(self, job_id: str):
        return (self.engine.plan.job_layout(job_id),
                self.engine.runtime._jobs[job_id]["abstract"])

    # -------------------------------------------------------------- serving
    def _next(self) -> ParameterReplica:
        rep = self.replicas[self._rr % len(self.replicas)]
        self._rr += 1
        return rep

    def pull(self, job_id: str, since_version=None):
        """Round-robin a replica and serve (see
        :meth:`ParameterReplica.pull`)."""
        return self._next().pull(job_id, since_version=since_version)

    def pull_batch(self, requests: Sequence[Tuple[str, Any]]
                   ) -> List[PullDiff]:
        """Round-robin a replica and serve the batch in one gather (see
        :meth:`ParameterReplica.pull_batch`)."""
        return self._next().pull_batch(requests)

    # ---------------------------------------------------------------- stats
    def stats(self) -> Dict[str, Any]:
        """Per-replica ReadStats (plus hub publish counters) as plain
        dicts -- the ``debug_stats()["replicas"]`` payload."""
        import dataclasses

        out: Dict[str, Any] = {
            "n_replicas": len(self.replicas),
            "publish_interval": self.publish_interval,
            "max_staleness_ticks": self.max_staleness_ticks,
            "n_publishes": self.n_publishes,
            "n_reused_snapshot_copies": self.n_reused_snapshot_copies,
        }
        for rep in self.replicas:
            d = dataclasses.asdict(rep.stats)
            d["pulls_per_sec"] = rep.stats.pulls_per_sec
            out[f"replica_{rep.replica_id}"] = d
        return out
