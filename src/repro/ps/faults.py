"""Fault vocabulary for the tick engines: quarantine errors and a
deterministic, seedable fault injector.

Parameter Service is a *shared* aggregation fleet: many jobs depend on
the same shard spaces, so a failed apply on one shard must not take the
whole engine down.  PR 7 replaces the engines' whole-process ``_poisoned``
flag with per-lane health (``HEALTHY`` / ``QUARANTINED``) plus
snapshot-based rollback (see ``repro.ps.engine``); this module holds the
pieces both the engine and its tests share:

``EngineQuarantinedError``
    Raised when work is blocked on a lane that stopped ticking.  Carries
    the shard id, the lane-local tick number, the pending job ids, and
    the ORIGINAL exception -- the old poisoned ``RuntimeError`` said none
    of that.

``FaultInjector``
    A deterministic fault schedule hookable at the engines' apply, push,
    and migration boundaries: fail the N-th apply on a shard, kill a
    shard outright, drop or duplicate a push piece, fail a migration.
    Rules count their OWN matching occurrences, so a schedule is a pure
    function of the call sequence -- the chaos tests replay it and
    compare against a fault-free twin bit for bit.  ``seed`` drives only
    the convenience random-schedule builder; armed rules are exact.

Injected faults raise :class:`InjectedFault` (a ``RuntimeError``), so
they route through exactly the recovery paths a real device/runtime
error would.

Rollback recovery is compression-safe (PR 8): a compressed-push job's
error-feedback buffer (``state["ef"]``) lives in the lane's donated
state, so the last-good snapshot captures it and a replay restarts the
EF recurrence from the exact residual it held -- at ``max_staleness=0``
a recovered compressed trajectory is bit-exact with a fault-free
compressed twin (see tests/test_faults.py).
"""

from __future__ import annotations

import math
import random
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

__all__ = [
    "HEALTHY",
    "QUARANTINED",
    "EngineQuarantinedError",
    "InjectedFault",
    "FaultInjector",
    "LeaseExpiredError",
    "ReplanAbortedError",
    "RetryPolicy",
]

# Lane health states (a lane is one shard space's service loop; the flat
# engine is a single unnamed lane).
HEALTHY = "healthy"
QUARANTINED = "quarantined"


class InjectedFault(RuntimeError):
    """The exception a :class:`FaultInjector` rule raises when it fires."""

    def __init__(self, kind: str, *, shard_id: Optional[str] = None,
                 job_id: Optional[str] = None, occurrence: int = 0):
        self.kind = kind
        self.shard_id = shard_id
        self.job_id = job_id
        self.occurrence = int(occurrence)
        where = f" on shard {shard_id!r}" if shard_id is not None else ""
        who = f" (job {job_id!r})" if job_id is not None else ""
        super().__init__(
            f"injected {kind} fault{where}{who} at occurrence "
            f"{occurrence}")


class EngineQuarantinedError(RuntimeError):
    """A lane exhausted its apply retries (or had no snapshot to roll
    back to) and stopped ticking.

    Attributes carry the triage context the old poisoned ``RuntimeError``
    lacked: ``shard_id`` (``None`` for the flat engine's single lane),
    ``tick`` (the lane-local tick count when it failed), ``job_ids``
    (the pushes in the failed apply), and ``original`` (the underlying
    exception).  Healthy lanes keep ticking; recover the quarantined one
    with ``ShardedServiceRuntime.recover_shard(shard_id)`` or restore a
    checkpoint.
    """

    def __init__(self, *, shard_id: Optional[str], tick: int, job_ids,
                 original: BaseException):
        self.shard_id = shard_id
        self.tick = int(tick)
        self.job_ids = tuple(job_ids)
        self.original = original
        lane = ("the engine's lane" if shard_id is None
                else f"shard {shard_id!r}")
        remedy = ("restore a checkpoint or re-seed the runtime"
                  if shard_id is None else
                  f"ShardedServiceRuntime.recover_shard({shard_id!r}) "
                  f"re-hosts it on the surviving fleet (or restore a "
                  f"checkpoint)")
        super().__init__(
            f"{lane} is quarantined: apply of jobs "
            f"{sorted(self.job_ids)} failed at lane tick {self.tick} "
            f"with {type(original).__name__}: {original}; its state was "
            f"restored to the last-good snapshot and healthy lanes keep "
            f"ticking -- {remedy}")


class ReplanAbortedError(RuntimeError):
    """A replan transaction exhausted its retries and was rolled back.

    ``ParameterService`` runs every registry mutation (register/exit/
    scale/evacuate) as a commit-or-abort transaction (PR 9): when a
    listener -- i.e. the data plane's quiesce -> migrate -> commit
    sequence -- fails, the registry is restored to its pre-transaction
    snapshot and the mutation is retried under a :class:`RetryPolicy`.
    This error means every attempt failed; control and data plane are
    left AGREEING on the old layout.  ``original`` carries the last
    underlying failure.
    """

    def __init__(self, op: str, attempts: int, original: BaseException):
        self.op = op
        self.attempts = int(attempts)
        self.original = original
        super().__init__(
            f"replan transaction {op!r} aborted after {attempts} "
            f"attempt(s): {type(original).__name__}: {original}; the "
            f"task registry was rolled back to its pre-transaction "
            f"snapshot, so control and data plane agree on the old "
            f"layout")


class LeaseExpiredError(RuntimeError):
    """A job's lease lapsed and the engine reclaimed it.

    Pushes and pulls renew a job's lease; a trainer that dies silently
    stops renewing, and ``expire_leases()`` cancels its queued pieces
    with this error and removes the job through the transactional
    replan path, freeing its space for the autoscaler.
    """

    def __init__(self, job_id: str, deadline: float, now: float):
        self.job_id = job_id
        self.deadline = float(deadline)
        self.now = float(now)
        super().__init__(
            f"job {job_id!r} lease expired at t={deadline:g} "
            f"(now t={now:g}): its trainer stopped pushing/pulling, so "
            f"the engine cancelled its queued pieces and reclaimed its "
            f"space -- re-register the job to resume")


@dataclass
class RetryPolicy:
    """Bounded-attempts + exponential-backoff retry schedule, shared by
    the apply path (PR 7's snapshot-rollback retries) and the replan
    transactions (PR 9).

    ``should_retry(failures)`` is consulted with the number of
    CONSECUTIVE failures so far (1-based); ``backoff(attempt)`` sleeps
    ``min(max_delay, base_delay * 2**(attempt-1))`` seconds.  The
    default ``base_delay=0.0`` disables sleeping (deterministic tests);
    ``sleep`` is injectable for the same reason.
    """

    max_retries: int = 2
    base_delay: float = 0.0
    max_delay: float = 1.0
    sleep: Callable[[float], None] = time.sleep

    def should_retry(self, failures: int) -> bool:
        return failures <= self.max_retries

    def delay(self, attempt: int) -> float:
        if self.base_delay <= 0.0:
            return 0.0
        return min(self.max_delay,
                   self.base_delay * (2.0 ** (max(attempt, 1) - 1)))

    def backoff(self, attempt: int) -> float:
        d = self.delay(attempt)
        if d > 0.0:
            self.sleep(d)
        return d


@dataclass
class _Rule:
    """One armed fault: fires on matching occurrences ``at`` through
    ``at + times - 1`` (1-based), counted per rule."""

    kind: str  # 'fail_apply' | 'drop_push' | 'duplicate_push' |
    #            'fail_migration'
    shard_id: Optional[str] = None  # None = any shard / the flat lane
    job_id: Optional[str] = None  # push rules: None = any job
    at: int = 1
    times: float = 1  # math.inf = permanent (a killed shard)
    seen: int = 0  # matching occurrences observed so far
    fired: int = 0
    # fail_migration only: None = fire at the migration BOUNDARY (before
    # any shard is relaid); K = fire mid-migration, after K shards of
    # the new plan have been relaid (abort-safety probe).
    after_shards: Optional[int] = None

    def matches(self, shard_id: Optional[str],
                job_id: Optional[str]) -> bool:
        if self.shard_id is not None and self.shard_id != shard_id:
            return False
        if self.job_id is not None and self.job_id != job_id:
            return False
        return True

    def observe(self) -> bool:
        """Count one matching occurrence; True if the rule fires on it."""
        self.seen += 1
        if self.seen >= self.at and self.fired < self.times:
            self.fired += 1
            return True
        return False


class FaultInjector:
    """Deterministic fault schedule for the tick engines.

    Arm rules, hand the injector to ``attach_engine(fault_injector=...)``
    (or an engine ctor), and every fired fault is recorded in ``log``::

        inj = FaultInjector(seed=7)
        inj.fail_apply(shard_id="c0/a1", at=3)   # 3rd apply on that lane
        inj.kill_shard("c0/a0", at=5)            # every apply from the 5th
        inj.drop_push(job_id="a", at=2)          # lose a's 2nd piece
        eng = rt.attach_engine(max_staleness=0, fault_injector=inj)

    Hooks (called by the engines; a rule firing raises
    :class:`InjectedFault` for apply/migration, or returns an action for
    pushes):

    * ``on_apply(shard_id)`` -- before each lane apply (``None`` for the
      flat engine's single lane).
    * ``on_push(job_id, shard_id)`` -- per enqueued piece; returns
      ``"deliver"``, ``"drop"``, or ``"duplicate"``.
    * ``on_migration(desc)`` -- at each state-migration boundary.
    * ``on_migration_progress(n_relaid, desc)`` -- after each shard of a
      sharded migration is relaid (mid-migration fail points).
    """

    def __init__(self, seed: int = 0):
        self.rng = random.Random(seed)
        self.rules: List[_Rule] = []
        self.log: List[Dict[str, Any]] = []  # every fired fault

    # -------------------------------------------------------------- arming
    def fail_apply(self, shard_id: Optional[str] = None, *, at: int = 1,
                   times: float = 1) -> "FaultInjector":
        """Fail the ``at``-th (1-based) apply on ``shard_id`` (any lane if
        None), ``times`` consecutive occurrences."""
        self.rules.append(_Rule("fail_apply", shard_id=shard_id, at=at,
                                times=times))
        return self

    def kill_shard(self, shard_id: Optional[str], *,
                   at: int = 1) -> "FaultInjector":
        """Permanently fail every apply on ``shard_id`` from its ``at``-th
        on -- the abrupt-shard-loss fault (drives quarantine, then
        ``recover_shard``)."""
        self.rules.append(_Rule("fail_apply", shard_id=shard_id, at=at,
                                times=math.inf))
        return self

    def drop_push(self, job_id: Optional[str] = None,
                  shard_id: Optional[str] = None, *, at: int = 1,
                  times: float = 1) -> "FaultInjector":
        """Silently lose a matching enqueued push piece (its future never
        resolves -- pair with ``PushFuture.result(timeout=...)``)."""
        self.rules.append(_Rule("drop_push", shard_id=shard_id,
                                job_id=job_id, at=at, times=times))
        return self

    def duplicate_push(self, job_id: Optional[str] = None,
                       shard_id: Optional[str] = None, *, at: int = 1,
                       times: float = 1) -> "FaultInjector":
        """Deliver a matching piece TWICE (an at-least-once delivery bug:
        the duplicate applies as an extra untracked push)."""
        self.rules.append(_Rule("duplicate_push", shard_id=shard_id,
                                job_id=job_id, at=at, times=times))
        return self

    def fail_migration(self, *, at: int = 1, times: float = 1,
                       after_shards: Optional[int] = None
                       ) -> "FaultInjector":
        """Fail the ``at``-th state migration.

        With ``after_shards=None`` (default) the fault fires at the
        migration BOUNDARY, before any shard is relaid.  With
        ``after_shards=K`` it fires MID-migration, once K shards of the
        new plan have been relaid -- ``migrate_sharded_state`` is
        functional over its input states, so an abort at that point must
        leave the old states untouched (the replan transaction's
        abort-safety probe).  ``at`` counts matching migrations, not
        shards.
        """
        self.rules.append(_Rule("fail_migration", at=at, times=times,
                                after_shards=after_shards))
        return self

    def random_apply_faults(self, n: int, shard_ids, *,
                            max_at: int = 20) -> "FaultInjector":
        """Arm ``n`` TRANSIENT apply faults at seed-deterministic (shard,
        occurrence) points -- the chaos tests' schedule builder."""
        sids = list(shard_ids)
        for _ in range(n):
            self.fail_apply(self.rng.choice(sids) if sids else None,
                            at=self.rng.randint(1, max_at))
        return self

    # --------------------------------------------------------------- hooks
    def _fire(self, rule: _Rule, shard_id, job_id) -> InjectedFault:
        fault = InjectedFault(rule.kind, shard_id=shard_id, job_id=job_id,
                              occurrence=rule.seen)
        self.log.append({"kind": rule.kind, "shard_id": shard_id,
                         "job_id": job_id, "occurrence": rule.seen})
        return fault

    def on_apply(self, shard_id: Optional[str]) -> None:
        """Raise InjectedFault if an armed apply rule fires on this
        occurrence for this lane."""
        for rule in self.rules:
            if rule.kind != "fail_apply" or not rule.matches(shard_id,
                                                             None):
                continue
            if rule.observe():
                raise self._fire(rule, shard_id, None)

    def on_push(self, job_id: str, shard_id: Optional[str] = None) -> str:
        """Per-piece delivery decision: 'deliver' | 'drop' | 'duplicate'
        (first firing rule wins)."""
        action = "deliver"
        for rule in self.rules:
            if rule.kind not in ("drop_push", "duplicate_push"):
                continue
            if not rule.matches(shard_id, job_id):
                continue
            if rule.observe() and action == "deliver":
                self._fire(rule, shard_id, job_id)
                action = ("drop" if rule.kind == "drop_push"
                          else "duplicate")
        return action

    def on_migration(self, desc: str = "") -> None:
        """Raise InjectedFault if an armed BOUNDARY migration rule fires
        (mid-migration rules wait for ``on_migration_progress``)."""
        for rule in self.rules:
            if rule.kind != "fail_migration" or rule.after_shards is not None:
                continue
            if rule.observe():
                raise self._fire(rule, None, desc or None)

    def on_migration_progress(self, n_relaid: int, desc: str = "") -> None:
        """Raise InjectedFault if a mid-migration rule armed for this
        progress point (``after_shards == n_relaid``) fires.  Called by
        ``migrate_sharded_state`` after each shard of the new plan is
        relaid; each matching call is one occurrence of the rule, so
        ``at`` counts migrations reaching that point."""
        for rule in self.rules:
            if rule.kind != "fail_migration" or rule.after_shards is None:
                continue
            if rule.after_shards != n_relaid:
                continue
            if rule.observe():
                raise self._fire(
                    rule, None,
                    f"{desc or 'migration'}@after_shards={n_relaid}")

    # ---------------------------------------------------------- inspection
    @property
    def n_fired(self) -> int:
        return len(self.log)

    def fire_counts(self) -> Dict[str, int]:
        """Fired-fault counts by rule kind (from ``log``) -- surfaced in
        the runtimes' ``debug_stats()``."""
        counts: Dict[str, int] = {}
        for entry in self.log:
            counts[entry["kind"]] = counts.get(entry["kind"], 0) + 1
        return counts
