"""ServicePlan: the single source of truth between control and data plane.

The control plane (repro.core.service.ParameterService) decides which
Aggregator hosts each ``(job_id, tensor_id)`` aggregation task; the data
plane executes pull/push/update against a *flat parameter space* laid out
across aggregator shards.  This module is the bridge: it compiles the live
``Aggregator.tasks`` mapping into a :class:`FlatPlan` whose segments are
keyed by ``(job_id, tensor_key)``, so one flat aggregation space can host
segments from *many* registered jobs at once and a replan is just a pair of
plans handed to ``repro.ps.elastic.migrate_flat_state``.

Kept deliberately JAX-free (numpy + core types only): the simulator and the
control plane can compile and diff plans without touching a device.
"""

from __future__ import annotations

import json
from dataclasses import dataclass
from functools import cached_property
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class TensorSpec:
    """Data-plane metadata for one aggregation task's tensor."""

    key: str  # pytree path key within the job's parameter tree
    shape: Tuple[int, ...]
    dtype: Any  # numpy-compatible dtype (jnp dtypes accepted)

    @property
    def size(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1


@dataclass(frozen=True)
class Segment:
    """One tensor's slice of the flat aggregation space.

    ``(job_id, key)`` is the identity used across replans; ``tensor_id``
    ties the segment back to the control plane's AggTask.
    """

    key: str
    shard: int
    offset: int  # element offset within the shard
    size: int
    shape: Tuple[int, ...]
    dtype: Any
    job_id: str = "flat"
    tensor_id: int = -1

    @property
    def skey(self) -> Tuple[str, str]:
        """Job-qualified identity, stable across replans."""
        return (self.job_id, self.key)


@dataclass(frozen=True)
class JobLayout:
    """Precompiled O(job)-cost access structure for one job of a plan.

    Everything here is plain numpy, computed once at plan time, so the hot
    path never rescans segments: ``own_idx`` drives the pull gather and the
    update scatter, ``blocks`` drives the block-owned Pallas kernel's
    scalar-prefetch grid, and ``slots`` place each tensor inside the packed
    (job-local) vector.
    """

    job_id: str
    block: int  # element granularity of block ownership
    n_total_blocks: int  # blocks in the whole flat space
    blocks: np.ndarray  # (n_blocks,) int32 owned block ids, ascending
    own_idx: np.ndarray  # (n_blocks*block,) int32 flat indices of owned lanes
    slots: Tuple[Tuple[str, int, int, Tuple[int, ...], Any], ...]
    # per segment, in packed order: (key, packed_start, size, shape, dtype)

    @property
    def packed_len(self) -> int:
        """Length of the packed (block-padded) job-local vector."""
        return int(self.own_idx.size)

    @property
    def payload_elements(self) -> int:
        return sum(size for _, _, size, _, _ in self.slots)

    @property
    def covers_all(self) -> bool:
        """True when the job owns every block of the flat space (single-job
        plans): gather/scatter degenerate to the identity."""
        return self.blocks.size == self.n_total_blocks


@dataclass(frozen=True)
class FlatPlan:
    """Physical layout of one shared flat aggregation space.

    ``shard_ids`` names the Aggregator backing each shard (empty for
    synthetic single-job plans built by ``build_flat_plan``).
    ``block_align`` is the element granularity at which each job's run of
    segments within a shard is padded (and the shard length rounded), so
    every ``block_align``-sized block of the flat space holds at most ONE
    job's payload -- the invariant the block-owned update path relies on.

    Per-job access structures (:meth:`payload_index`, :meth:`job_layout`)
    are compiled lazily and cached on the plan, so the data plane's hot
    path costs O(job bytes) instead of O(total space) per step.
    """

    n_shards: int
    shard_len: int  # padded elements per shard
    segments: Tuple[Segment, ...]  # in (shard, offset) order
    shard_ids: Tuple[str, ...] = ()
    block_align: int = 1  # job-run padding granularity (1 = legacy layout)

    @property
    def total_len(self) -> int:
        return self.n_shards * self.shard_len

    @property
    def payload_elements(self) -> int:
        return sum(s.size for s in self.segments)

    @cached_property
    def shard_segments(self) -> Tuple[Tuple[int, ...], ...]:
        """Per-shard segment indices in offset order (precomputed once, so
        flatten/unflatten are O(n_segments) instead of O(shards*segments))."""
        buckets: List[List[int]] = [[] for _ in range(self.n_shards)]
        for i, seg in enumerate(self.segments):
            buckets[seg.shard].append(i)
        for b in buckets:
            b.sort(key=lambda i: self.segments[i].offset)
        return tuple(tuple(b) for b in buckets)

    @cached_property
    def by_skey(self) -> Dict[Tuple[str, str], Segment]:
        return {s.skey: s for s in self.segments}

    @cached_property
    def job_ids(self) -> Tuple[str, ...]:
        seen: Dict[str, None] = {}
        for s in self.segments:
            seen.setdefault(s.job_id, None)
        return tuple(seen)

    def segments_of(self, job_id: str) -> Tuple[Segment, ...]:
        return tuple(s for s in self.segments if s.job_id == job_id)

    def start(self, seg: Segment) -> int:
        """Absolute element offset of a segment in the flat vector."""
        return seg.shard * self.shard_len + seg.offset

    # --------------------------------------- precompiled access structures
    @cached_property
    def _lane_owner(self) -> np.ndarray:
        """Per-lane owner: index into ``job_ids``, -1 on padding lanes."""
        owner = np.full(self.total_len, -1, np.int32)
        jix = {j: i for i, j in enumerate(self.job_ids)}
        for seg in self.segments:
            s = self.start(seg)
            owner[s : s + seg.size] = jix[seg.job_id]
        return owner

    @cached_property
    def _access_cache(self) -> Dict[Any, Any]:
        return {}

    def payload_index(self, job_id: Optional[str] = None) -> np.ndarray:
        """Flat positions of (the job's) payload lanes, in segment order.

        Exact per-lane gather/scatter map -- the fallback access structure
        when a plan is not block-exclusive (hand-built / legacy layouts);
        the hot path uses the coarser, memcpy-friendly :meth:`job_layout`
        blocks instead.  Cached per job; read-only.
        """
        key = ("payload", job_id)
        idx = self._access_cache.get(key)
        if idx is None:
            parts = [
                np.arange(self.start(s), self.start(s) + s.size, dtype=np.int32)
                for s in self.segments
                if job_id is None or s.job_id == job_id
            ]
            idx = (np.concatenate(parts) if parts
                   else np.zeros((0,), np.int32))
            idx.setflags(write=False)
            self._access_cache[key] = idx
        return idx

    def job_layout(self, job_id: str, block: Optional[int] = None) -> JobLayout:
        """Compile (and cache) the job's block-owned access structure.

        ``block`` defaults to the plan's ``block_align``.  Raises
        ``ValueError`` if the plan's layout is not block-exclusive at that
        granularity (some block mixes two jobs' payload), in which case the
        masked O(total-space) path is the only correct one.
        """
        block = self.block_align if block is None else block
        key = ("layout", job_id, block)
        cached = self._access_cache.get(key)
        if cached is not None:
            return cached
        if job_id not in self.job_ids:
            raise ValueError(f"job {job_id!r} has no segments in this plan")
        if block < 1 or self.shard_len % block:
            raise ValueError(
                f"block={block} does not divide shard_len={self.shard_len}")
        jix = list(self.job_ids).index(job_id)
        per_block = self._lane_owner.reshape(-1, block)
        mine = (per_block == jix).any(axis=1)
        foreign = ((per_block >= 0) & (per_block != jix)).any(axis=1)
        if bool((mine & foreign).any()):
            raise ValueError(
                f"plan is not block-exclusive at block={block}: job "
                f"{job_id!r} shares a block with another job (legacy "
                f"unaligned layout? recompile with block_align >= block)")
        blocks = np.nonzero(mine)[0].astype(np.int32)
        own_idx = (blocks[:, None].astype(np.int64) * block
                   + np.arange(block)).reshape(-1).astype(np.int32)
        slots = []
        for seg in self.segments:
            if seg.job_id != job_id:
                continue
            pstart = int(np.searchsorted(own_idx, self.start(seg)))
            slots.append((seg.key, pstart, seg.size, seg.shape, seg.dtype))
        slots.sort(key=lambda s: s[1])
        blocks.setflags(write=False)
        own_idx.setflags(write=False)
        layout = JobLayout(job_id=job_id, block=block,
                           n_total_blocks=self.total_len // block,
                           blocks=blocks, own_idx=own_idx,
                           slots=tuple(slots))
        self._access_cache[key] = layout
        return layout


@dataclass(frozen=True)
class ShardedJobLayout:
    """One job's access structure across ALL the shard spaces hosting it.

    ``layouts[i]`` is the per-shard :class:`JobLayout` inside shard space
    ``shard_ids[i]``; ``slots`` is the job's packed slot table over the
    CONCATENATION of those per-shard packed vectors (in ``shard_ids``
    order), so ``_pack_slots`` / ``_unpack_slots`` work on the combined
    vector unchanged.  ``piece_offsets[i] : piece_offsets[i] + piece
    length`` slices shard ``i``'s packed piece out of the combined vector.
    """

    job_id: str
    shard_ids: Tuple[str, ...]  # hosting Aggregators, in shard order
    shard_indices: Tuple[int, ...]  # indices into ShardedPlan.shards
    layouts: Tuple[JobLayout, ...]
    slots: Tuple[Tuple[str, int, int, Tuple[int, ...], Any], ...]
    piece_offsets: Tuple[int, ...]  # combined-vector start of each piece

    @property
    def packed_len(self) -> int:
        return sum(l.packed_len for l in self.layouts)

    @property
    def n_shards(self) -> int:
        return len(self.layouts)


@dataclass(frozen=True)
class ShardedPlan:
    """N per-Aggregator shard spaces (the sharded data plane's layout).

    Where :class:`FlatPlan` flattens every job into ONE shared space with a
    uniform ``shard_len`` (padding every Aggregator to the largest), a
    ShardedPlan gives each live Aggregator its OWN flat space -- a
    single-shard FlatPlan sized to that Aggregator's content -- so shard
    count changes what actually executes: each shard space ticks, migrates,
    and checkpoints independently, keyed by its stable ``agg_id``.
    """

    shards: Tuple[FlatPlan, ...]  # each n_shards=1, shard_ids=(agg_id,)

    @property
    def n_shards(self) -> int:
        return len(self.shards)

    @cached_property
    def shard_ids(self) -> Tuple[str, ...]:
        return tuple(sp.shard_ids[0] for sp in self.shards)

    @property
    def total_len(self) -> int:
        return sum(sp.total_len for sp in self.shards)

    @property
    def payload_elements(self) -> int:
        return sum(sp.payload_elements for sp in self.shards)

    @cached_property
    def job_ids(self) -> Tuple[str, ...]:
        seen: Dict[str, None] = {}
        for sp in self.shards:
            for j in sp.job_ids:
                seen.setdefault(j, None)
        return tuple(seen)

    @cached_property
    def _index_of(self) -> Dict[str, int]:
        return {sid: i for i, sid in enumerate(self.shard_ids)}

    def index_of(self, shard_id: str) -> Optional[int]:
        """Shard index backing ``shard_id`` (None if not in this plan)."""
        return self._index_of.get(shard_id)

    def shard_of(self, shard_id: str) -> FlatPlan:
        return self.shards[self._index_of[shard_id]]

    @cached_property
    def by_skey(self) -> Dict[Tuple[str, str], Tuple[str, Segment]]:
        """(job_id, key) -> (shard_id, segment): cross-shard identity map."""
        out: Dict[Tuple[str, str], Tuple[str, Segment]] = {}
        for sid, sp in zip(self.shard_ids, self.shards):
            for seg in sp.segments:
                out[seg.skey] = (sid, seg)
        return out

    def job_shards(self, job_id: str) -> Tuple[int, ...]:
        """Indices of the shards hosting any of the job's segments."""
        return tuple(i for i, sp in enumerate(self.shards)
                     if job_id in sp.job_ids)

    # --------------------------------------------- concatenated fleet view
    @cached_property
    def concat_offsets(self) -> Tuple[int, ...]:
        """Element offset of each shard space in the CONCATENATED fleet
        view (``shard_ids`` order): the base the single-launch fleet tick
        adds to a shard's local indices to address all lanes' state as
        one buffer."""
        offs: List[int] = []
        off = 0
        for sp in self.shards:
            offs.append(off)
            off += sp.total_len
        return tuple(offs)

    @cached_property
    def uniform_block_align(self) -> Optional[int]:
        """The common ``block_align`` of every shard space, or ``None``
        when shards disagree -- one fused fleet launch needs a single
        global block granularity across the concatenated view."""
        aligns = {sp.block_align for sp in self.shards}
        return aligns.pop() if len(aligns) == 1 else None

    def concat_view(self, shard_ids: Optional[Sequence[str]] = None
                    ) -> Tuple[Tuple[int, ...], int, int]:
        """(element offsets, total length, block) of the concatenated view
        over the given lanes (default: every shard, == ``concat_offsets``).

        Each shard's ``shard_len`` is a multiple of its ``block_align``,
        so with a uniform alignment the offsets are block-aligned and a
        shard-local block ``b`` maps to global block
        ``offset // block + b`` -- the per-block half of the fused fleet
        tick's scalar-prefetched table.  Raises ``ValueError`` when the
        participating shards do not share one ``block_align``.
        """
        if shard_ids is None:
            ids = list(self.shard_ids)
            shards = list(self.shards)
        else:
            ids = list(shard_ids)
            shards = [self.shard_of(sid) for sid in shard_ids]
        aligns = {sp.block_align for sp in shards}
        if len(aligns) != 1:
            by_align: Dict[int, List[str]] = {}
            for sid, sp in zip(ids, shards):
                by_align.setdefault(sp.block_align, []).append(sid)
            detail = "; ".join(
                f"block_align={a}: {', '.join(sids)}"
                for a, sids in sorted(by_align.items()))
            raise ValueError(
                f"concatenated view needs one block granularity across "
                f"the participating shards, but they disagree -- "
                f"{detail}.  Tick the fleet with fleet_tick='per_shard' "
                f"(one launch group per lane tolerates mixed "
                f"granularities), or recompile the plan with a uniform "
                f"pad_to to restore the single fused launch")
        block = aligns.pop()
        offs: List[int] = []
        off = 0
        for sp in shards:
            offs.append(off)
            off += sp.total_len
        return tuple(offs), off, block

    @cached_property
    def _layout_cache(self) -> Dict[str, ShardedJobLayout]:
        return {}

    def job_layout(self, job_id: str) -> ShardedJobLayout:
        """Compile (and cache) the job's cross-shard access structure."""
        cached = self._layout_cache.get(job_id)
        if cached is not None:
            return cached
        hosting = self.job_shards(job_id)
        if not hosting:
            raise ValueError(f"job {job_id!r} has no segments in this plan")
        layouts = tuple(self.shards[i].job_layout(job_id) for i in hosting)
        slots: List[Tuple[str, int, int, Tuple[int, ...], Any]] = []
        offsets: List[int] = []
        off = 0
        for l in layouts:
            offsets.append(off)
            for key, pstart, size, shape, dtype in l.slots:
                slots.append((key, off + pstart, size, shape, dtype))
            off += l.packed_len
        layout = ShardedJobLayout(
            job_id=job_id,
            shard_ids=tuple(self.shard_ids[i] for i in hosting),
            shard_indices=hosting, layouts=layouts, slots=tuple(slots),
            piece_offsets=tuple(offsets),
        )
        self._layout_cache[job_id] = layout
        return layout


def compile_sharded_plan(
    aggregators: Sequence[Any],
    specs: Optional[Mapping[str, Mapping[int, TensorSpec]]] = None,
    pad_to: int = 128,
) -> ShardedPlan:
    """Compile the live assignment into per-Aggregator shard spaces.

    Each Aggregator becomes ONE single-shard FlatPlan laid out exactly as
    :func:`compile_service_plan` lays that Aggregator out (same job-run
    alignment, same segment order), but with ``shard_len`` padded to the
    shard's OWN content instead of the fleet-wide maximum -- so with one
    Aggregator the shard space is bit-identical to the flat plan's, and
    with many there is no cross-shard padding coupling at all.
    """
    specs = specs or {}
    shards: List[FlatPlan] = []
    for agg in aggregators:
        segments: List[Segment] = []
        off = 0
        prev_job: Optional[str] = None
        for (job_id, tensor_id), task in sorted(agg.tasks.items()):
            if prev_job is not None and job_id != prev_job:
                off = -(-off // pad_to) * pad_to  # align the job-run start
            prev_job = job_id
            spec = specs.get(job_id, {}).get(tensor_id)
            if spec is None:
                n = max(1, task.nbytes // 4)
                spec = TensorSpec(task.name, (n,), np.float32)
            segments.append(
                Segment(spec.key, 0, off, spec.size, tuple(spec.shape),
                        spec.dtype, job_id=job_id, tensor_id=tensor_id)
            )
            off += spec.size
        shard_len = max(1, -(-max(1, off) // pad_to) * pad_to)
        shards.append(FlatPlan(
            n_shards=1, shard_len=shard_len, segments=tuple(segments),
            shard_ids=(getattr(agg, "agg_id", f"shard{len(shards)}"),),
            block_align=pad_to,
        ))
    return ShardedPlan(shards=tuple(shards))


def sharded_plan_to_json(plan: ShardedPlan) -> Dict[str, Any]:
    return {"shards": [plan_to_json(sp) for sp in plan.shards]}


def sharded_plan_from_json(obj: Mapping[str, Any]) -> ShardedPlan:
    return ShardedPlan(
        shards=tuple(plan_from_json(sp) for sp in obj["shards"]))


def plan_padding_waste(plan: FlatPlan) -> float:
    """Fraction of the flat space that is padding (imbalance cost)."""
    if plan.total_len <= 0:
        return 0.0
    return 1.0 - plan.payload_elements / plan.total_len


def segment_mask(plan: FlatPlan, job_id: Optional[str] = None) -> np.ndarray:
    """Boolean mask over the flat vector: True on (the job's) payload lanes."""
    mask = np.zeros(plan.total_len, dtype=bool)
    for seg in plan.segments:
        if job_id is None or seg.job_id == job_id:
            start = plan.start(seg)
            mask[start : start + seg.size] = True
    return mask


# ----------------------------------------------------------------- compile
def compile_service_plan(
    aggregators: Sequence[Any],
    specs: Optional[Mapping[str, Mapping[int, TensorSpec]]] = None,
    pad_to: int = 128,
) -> FlatPlan:
    """Compile the live control-plane assignment into a multi-job FlatPlan.

    One shard per Aggregator, in the given (stable) order; within a shard,
    segments are laid contiguously in ``(job_id, tensor_id)`` order so the
    layout is a pure function of the assignment.  Each job's run of
    segments is padded up to a ``pad_to`` boundary, so every ``pad_to``
    block of the flat space belongs to at most one job -- the invariant
    behind the block-owned O(job-bytes) update path (``job_layout``).
    ``specs`` supplies real shapes/dtypes per ``job_id -> tensor_id``;
    tasks without a bound spec (control-plane-only jobs, e.g. in the
    simulator) fall back to a 1-D float32 tensor sized from
    ``AggTask.nbytes``.
    """
    specs = specs or {}
    segments: List[Segment] = []
    shard_sizes: List[int] = []
    shard_ids: List[str] = []
    for shard, agg in enumerate(aggregators):
        off = 0
        prev_job: Optional[str] = None
        for (job_id, tensor_id), task in sorted(agg.tasks.items()):
            if prev_job is not None and job_id != prev_job:
                off = -(-off // pad_to) * pad_to  # align the job-run start
            prev_job = job_id
            spec = specs.get(job_id, {}).get(tensor_id)
            if spec is None:
                n = max(1, task.nbytes // 4)
                spec = TensorSpec(task.name, (n,), np.float32)
            segments.append(
                Segment(spec.key, shard, off, spec.size, tuple(spec.shape),
                        spec.dtype, job_id=job_id, tensor_id=tensor_id)
            )
            off += spec.size
        shard_sizes.append(off)
        shard_ids.append(getattr(agg, "agg_id", f"shard{shard}"))
    largest = max(shard_sizes, default=0)
    shard_len = max(1, -(-max(1, largest) // pad_to) * pad_to)
    return FlatPlan(
        n_shards=len(shard_ids),
        shard_len=shard_len,
        segments=tuple(segments),
        shard_ids=tuple(shard_ids),
        block_align=pad_to,
    )


# --------------------------------------------------------------- migration
def plan_migration_bytes(
    old: FlatPlan, new: FlatPlan, bytes_per_element: int = 12
) -> int:
    """Bytes that cross Aggregators between two plans (master copy + both
    Adam moments at 4 B each by default).

    Ownership is compared by ``shard_ids`` (the backing Aggregator) when
    both plans carry them: a shard *index* shift -- e.g. an emptied
    Aggregator dropping out of the list -- does not move any bytes off the
    segments' actual host.  Synthetic plans without shard_ids fall back to
    index comparison.  Segments only present in one plan are job
    arrivals/exits, not migrations, and are not counted."""
    by_id = bool(old.shard_ids) and bool(new.shard_ids)

    def owner(plan: FlatPlan, seg: Segment):
        return plan.shard_ids[seg.shard] if by_id else seg.shard

    moved = 0
    old_by = old.by_skey
    for seg in new.segments:
        prev = old_by.get(seg.skey)
        if prev is not None and owner(old, prev) != owner(new, seg):
            moved += seg.size * bytes_per_element
    return moved


# ----------------------------------------------------------- serialization
def plan_to_json(plan: FlatPlan) -> Dict[str, Any]:
    return {
        "n_shards": plan.n_shards,
        "shard_len": plan.shard_len,
        "shard_ids": list(plan.shard_ids),
        "block_align": plan.block_align,
        "segments": [
            {
                "key": s.key,
                "shard": s.shard,
                "offset": s.offset,
                "size": s.size,
                "shape": list(s.shape),
                "dtype": np.dtype(s.dtype).name,
                "job_id": s.job_id,
                "tensor_id": s.tensor_id,
            }
            for s in plan.segments
        ],
    }


def plan_from_json(obj: Mapping[str, Any]) -> FlatPlan:
    segments = tuple(
        Segment(
            key=s["key"],
            shard=int(s["shard"]),
            offset=int(s["offset"]),
            size=int(s["size"]),
            shape=tuple(s["shape"]),
            dtype=np.dtype(s["dtype"]),
            job_id=s.get("job_id", "flat"),
            tensor_id=int(s.get("tensor_id", -1)),
        )
        for s in obj["segments"]
    )
    return FlatPlan(
        n_shards=int(obj["n_shards"]),
        shard_len=int(obj["shard_len"]),
        segments=segments,
        shard_ids=tuple(obj.get("shard_ids", ())),
        block_align=int(obj.get("block_align", 1)),
    )


def plan_dumps(plan: FlatPlan) -> str:
    return json.dumps(plan_to_json(plan))


def plan_loads(text: str) -> FlatPlan:
    return plan_from_json(json.loads(text))
