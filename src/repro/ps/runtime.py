"""Paper-faithful PS data plane: one flat parameter space, many jobs.

The control plane's tensor->Aggregator assignment (repro.core.service)
compiles -- via ``ParameterService.compile_plan()`` / repro.ps.plan -- into
the *layout of a flat parameter vector* across aggregator shards, shared by
every registered job:

  pull    unflatten(flat)   -> all-gather of the job's segments
  push    flatten(grads)    -> reduce-scatter onto the owner layout
  update  elementwise Adam on the job's own segments only (masked when the
          flat space is shared; fused Pallas kernel on TPU,
          repro.kernels.agg_adam)

Segments are keyed by ``(job_id, tensor_key)``, so two jobs with identically
named tensors coexist in one space, and a control-plane replan is executed
by ``repro.ps.elastic.migrate_flat_state`` over a ``(old_plan, new_plan)``
pair without restarting either job.

``build_flat_plan`` remains as the standalone single-job path (ps-lite
round-robin vs AutoPS balanced placement): per-shard byte imbalance shows up
directly as extra all-gather bytes + wasted optimizer lanes -- the data-
plane realization of Fig. 7.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.assignment import (
    balanced_shard_assignment,
    round_robin_shard_assignment,
)
from repro.core.types import AggTask, JobProfile
from repro.ps.plan import (  # re-exported: canonical home is repro.ps.plan
    FlatPlan,
    Segment,
    TensorSpec,
    plan_padding_waste,
    segment_mask,
)

__all__ = [
    "FlatPlan",
    "Segment",
    "TensorSpec",
    "build_flat_plan",
    "flatten_tree",
    "unflatten_tree",
    "init_ps_state",
    "init_shared_state",
    "seed_job_params",
    "job_profile_from_tree",
    "make_ps_train_step",
    "plan_padding_waste",
]


def _leaf_key(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def tree_specs(tree) -> List[TensorSpec]:
    """Per-leaf TensorSpecs in pytree-flatten order."""
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [
        TensorSpec(_leaf_key(path), tuple(leaf.shape), leaf.dtype)
        for path, leaf in leaves
    ]


def job_profile_from_tree(
    job_id: str,
    tree,
    iteration_duration: float = 1.0,
    n_workers: int = 2,
    required_servers: int = 1,
    agg_throughput: float = 7e9,
    model: str = "custom",
) -> Tuple[JobProfile, Dict[int, TensorSpec]]:
    """Build the control-plane JobProfile + data-plane specs for a pytree.

    One AggTask per leaf, ``tensor_id`` = leaf index; ``exec_time`` is the
    profiled aggregation cost nbytes / agg_throughput (lower the throughput
    to model heavier aggregation work per byte).
    """
    specs = dict(enumerate(tree_specs(tree)))
    tasks = [
        AggTask(job_id, i, spec.key, nbytes=spec.size * 4,
                exec_time=spec.size * 4 / agg_throughput)
        for i, spec in specs.items()
    ]
    profile = JobProfile(job_id, model, iteration_duration, tasks,
                         n_workers=n_workers,
                         required_servers=required_servers)
    return profile, specs


def build_flat_plan(abstract_params, n_shards: int, mode: str = "balanced",
                    pad_to: int = 128, job_id: str = "flat") -> FlatPlan:
    """Standalone single-job plan: assign each tensor to a shard using the
    control plane's placement schemes, then lay segments contiguously."""
    leaves = jax.tree_util.tree_flatten_with_path(abstract_params)[0]
    tasks = []
    meta: Dict[int, Tuple[str, Tuple[int, ...], Any, int]] = {}
    for i, (path, leaf) in enumerate(leaves):
        size = int(np.prod(leaf.shape)) if leaf.shape else 1
        tasks.append(AggTask(job_id, i, _leaf_key(path), nbytes=size * 4,
                             exec_time=float(size)))
        meta[i] = (_leaf_key(path), tuple(leaf.shape), leaf.dtype, size)

    job = JobProfile(job_id, job_id, 1.0, tasks, required_servers=n_shards)
    if mode == "balanced":
        shards = balanced_shard_assignment(job, n_shards)
    elif mode == "round_robin":
        shards = round_robin_shard_assignment(job, n_shards)
    else:
        raise ValueError(f"unknown placement mode {mode!r}")

    segments: List[Segment] = []
    shard_sizes = []
    for s in range(n_shards):
        off = 0
        for task in shards[s]:
            key, shape, dtype, size = meta[task.tensor_id]
            segments.append(Segment(key, s, off, size, shape, dtype,
                                    job_id=job_id, tensor_id=task.tensor_id))
            off += size
        shard_sizes.append(off)
    shard_len = max(1, -(-max(shard_sizes) // pad_to) * pad_to)
    return FlatPlan(n_shards=n_shards, shard_len=shard_len,
                    segments=tuple(segments))


def flatten_tree(plan: FlatPlan, tree, dtype=jnp.float32,
                 job_id: Optional[str] = None) -> jnp.ndarray:
    """Pack a pytree into the plan's flat layout (push direction).

    With ``job_id`` given, only that job's segments are filled -- other
    jobs' lanes come out zero, so a per-job gradient vector never perturbs
    co-resident jobs.  Linear in the number of segments (per-shard segment
    indices are precomputed on the plan).
    """
    by_key = {
        _leaf_key(path): leaf
        for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]
    }
    parts: List[jnp.ndarray] = []
    for shard_idx in plan.shard_segments:
        used = 0
        for i in shard_idx:
            seg = plan.segments[i]
            if job_id is not None and seg.job_id != job_id:
                parts.append(jnp.zeros((seg.size,), dtype))
            else:
                parts.append(by_key[seg.key].reshape(-1).astype(dtype))
            used += seg.size
        if used < plan.shard_len:
            parts.append(jnp.zeros((plan.shard_len - used,), dtype))
    if not parts:
        return jnp.zeros((plan.total_len,), dtype)
    return jnp.concatenate(parts)


def unflatten_tree(plan: FlatPlan, flat: jnp.ndarray, abstract_params,
                   job_id: Optional[str] = None):
    """Unpack (a job's segments of) the flat vector into a pytree (pull)."""
    out_by_key = {}
    for seg in plan.segments:
        if job_id is not None and seg.job_id != job_id:
            continue
        start = plan.start(seg)
        out_by_key[seg.key] = jax.lax.slice(
            flat, (start,), (start + seg.size,)
        ).reshape(seg.shape).astype(seg.dtype)

    leaves, _ = jax.tree_util.tree_flatten_with_path(abstract_params)
    ordered = [out_by_key[_leaf_key(path)] for path, _ in leaves]
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(abstract_params), ordered
    )


# ------------------------------------------------------------------ PS step
def make_ps_train_step(
    model_loss: Callable[[Any, Any], jnp.ndarray],
    plan: FlatPlan,
    abstract_params,
    lr: float = 3e-4,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    push_compression: Optional[str] = None,  # None | 'bf16' | 'int8'
    fused_kernel: bool = False,
    job_id: Optional[str] = None,
):
    """Build the PS-mode train step.

    Single-job mode (``job_id=None``, legacy):
      state = {flat (N,), mu (N,), nu (N,), count, [ef (N,)]}

    Shared-service mode (``job_id`` given): the same flat/mu/nu buffers are
    shared by every job in the plan; this job's step touches ONLY its own
    segments (masked Adam) and keeps its own step counter in
    state["counts"][job_id], so co-resident jobs' moments and bias
    correction are untouched.

    All flat buffers are sharded P(aggregation axes) by the caller; the
    unflatten/flatten pair makes GSPMD emit the pull all-gather and push
    reduce-scatter onto the owner layout.
    """
    from repro.ps import act_sharding as act
    from repro.ps.compression import compress_decompress

    mask = None
    if job_id is not None:
        mask = jnp.asarray(segment_mask(plan, job_id))

    def _count(state):
        if job_id is None:
            return state["count"] + 1
        return state["counts"][job_id] + 1

    def step(state, batch):
        flat = state["flat"]
        params = unflatten_tree(plan, flat, abstract_params, job_id)  # PULL
        loss, grads = jax.value_and_grad(model_loss)(params, batch)
        gflat = flatten_tree(plan, grads, jnp.float32, job_id)  # PUSH
        if push_compression:
            ef = state["ef"]
            gflat = gflat + (ef if mask is None else jnp.where(mask, ef, 0.0))
            q = compress_decompress(gflat, push_compression)
            resid = gflat - q
            new_ef = resid if mask is None else jnp.where(mask, resid, ef)
            gflat = q if mask is None else jnp.where(mask, q, 0.0)
        gflat = act.constrain(gflat, "all")  # reduce-scatter point

        count = _count(state)
        if fused_kernel:
            from repro.kernels.agg_adam import ops as agg_ops

            new_flat, mu, nu = agg_ops.adam_update(
                flat, gflat, state["mu"], state["nu"], count,
                lr=lr, b1=b1, b2=b2, eps=eps, wd=0.0)
        else:
            mu = b1 * state["mu"] + (1 - b1) * gflat
            nu = b2 * state["nu"] + (1 - b2) * jnp.square(gflat)
            t = count.astype(jnp.float32)
            mu_hat = mu / (1 - b1 ** t)
            nu_hat = nu / (1 - b2 ** t)
            new_flat = flat - lr * mu_hat / (jnp.sqrt(nu_hat) + eps)
        if mask is not None:
            # Update only this job's lanes of the shared space.
            new_flat = jnp.where(mask, new_flat, flat)
            mu = jnp.where(mask, mu, state["mu"])
            nu = jnp.where(mask, nu, state["nu"])
        new_flat = act.constrain(new_flat, "all")

        new_state = dict(state)
        new_state.update(flat=new_flat, mu=mu, nu=nu)
        if job_id is None:
            new_state["count"] = count
        else:
            new_state["counts"] = dict(state["counts"], **{job_id: count})
        if push_compression:
            new_state["ef"] = new_ef
        return new_state, {"loss": loss}

    return step


def init_ps_state(plan: FlatPlan, params, push_compression=None):
    """Single-job state: flat buffers hold exactly this job's tensors."""
    flat = flatten_tree(plan, params, jnp.float32)
    state = {
        "flat": flat,
        "mu": jnp.zeros_like(flat),
        "nu": jnp.zeros_like(flat),
        "count": jnp.zeros((), jnp.int32),
    }
    if push_compression:
        state["ef"] = jnp.zeros_like(flat)
    return state


def init_shared_state(plan: FlatPlan, push_compression=None):
    """Empty shared-service state for a compiled multi-job plan; jobs are
    seeded into their own segments with :func:`seed_job_params`."""
    flat = jnp.zeros((plan.total_len,), jnp.float32)
    state = {
        "flat": flat,
        "mu": jnp.zeros_like(flat),
        "nu": jnp.zeros_like(flat),
        "counts": {},
    }
    if push_compression:
        state["ef"] = jnp.zeros_like(flat)
    return state


def seed_job_params(plan: FlatPlan, state, job_id: str, params):
    """Write a job's initial parameters into its segments of the shared flat
    space (fresh Adam moments + step counter for that job only)."""
    mask = jnp.asarray(segment_mask(plan, job_id))
    vec = flatten_tree(plan, params, jnp.float32, job_id)
    new_state = dict(state)
    new_state["flat"] = jnp.where(mask, vec, state["flat"])
    new_state["mu"] = jnp.where(mask, 0.0, state["mu"])
    new_state["nu"] = jnp.where(mask, 0.0, state["nu"])
    if "ef" in state:
        new_state["ef"] = jnp.where(mask, 0.0, state["ef"])
    new_state["counts"] = dict(state["counts"],
                               **{job_id: jnp.zeros((), jnp.int32)})
    return new_state
