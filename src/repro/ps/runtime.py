"""Paper-faithful PS data plane: one flat parameter space, many jobs.

The control plane's tensor->Aggregator assignment (repro.core.service)
compiles -- via ``ParameterService.compile_plan()`` / repro.ps.plan -- into
the *layout of a flat parameter vector* across aggregator shards, shared by
every registered job:

  pull    one gather of the job's lanes (plan-precompiled index map)
  push    one packed concatenate + one scatter onto the owner layout
  update  elementwise Adam on the job's OWNED lanes only -- O(job bytes),
          not O(total space); fused Pallas kernel iterates the job's owned
          blocks via a scalar-prefetched block-index operand on TPU
          (repro.kernels.agg_adam)

Every per-job access structure (gather/scatter index maps, owned-block
lists) is compiled once at plan time (repro.ps.plan.FlatPlan.payload_index
/ .job_layout), so the step's HLO op count is O(1) in the number of
co-resident segments and its FLOPs/bytes are proportional to the job's own
lanes.  ``update_mode="masked"`` keeps the legacy full-space masked path
for parity tests and benchmarks.

Segments are keyed by ``(job_id, tensor_key)``, so two jobs with identically
named tensors coexist in one space, and a control-plane replan is executed
by ``repro.ps.elastic.migrate_flat_state`` over a ``(old_plan, new_plan)``
pair without restarting either job.  ``repro.ps.engine`` builds on the
same access structures to batch MANY jobs' pending pushes into one
service-tick pass (``repro.kernels.agg_adam.aggregate_adam_multijob``).

``build_flat_plan`` remains as the standalone single-job path (ps-lite
round-robin vs AutoPS balanced placement): per-shard byte imbalance shows up
directly as extra all-gather bytes + wasted optimizer lanes -- the data-
plane realization of Fig. 7.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.assignment import (
    balanced_shard_assignment,
    round_robin_shard_assignment,
)
from repro.core.types import AggTask, JobProfile
from repro.ps.plan import (  # re-exported: canonical home is repro.ps.plan
    FlatPlan,
    Segment,
    TensorSpec,
    plan_padding_waste,
    segment_mask,
)

__all__ = [
    "FlatPlan",
    "Segment",
    "TensorSpec",
    "build_flat_plan",
    "flatten_tree",
    "unflatten_tree",
    "init_ps_state",
    "init_shared_state",
    "seed_job_params",
    "job_profile_from_tree",
    "make_ps_train_step",
    "plan_padding_waste",
]


def _leaf_key(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def tree_specs(tree) -> List[TensorSpec]:
    """Per-leaf TensorSpecs in pytree-flatten order."""
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [
        TensorSpec(_leaf_key(path), tuple(leaf.shape), leaf.dtype)
        for path, leaf in leaves
    ]


def job_profile_from_tree(
    job_id: str,
    tree,
    iteration_duration: float = 1.0,
    n_workers: int = 2,
    required_servers: int = 1,
    agg_throughput: float = 7e9,
    model: str = "custom",
) -> Tuple[JobProfile, Dict[int, TensorSpec]]:
    """Build the control-plane JobProfile + data-plane specs for a pytree.

    One AggTask per leaf, ``tensor_id`` = leaf index; ``exec_time`` is the
    profiled aggregation cost nbytes / agg_throughput (lower the throughput
    to model heavier aggregation work per byte).
    """
    specs = dict(enumerate(tree_specs(tree)))
    tasks = [
        AggTask(job_id, i, spec.key, nbytes=spec.size * 4,
                exec_time=spec.size * 4 / agg_throughput)
        for i, spec in specs.items()
    ]
    profile = JobProfile(job_id, model, iteration_duration, tasks,
                         n_workers=n_workers,
                         required_servers=required_servers)
    return profile, specs


def build_flat_plan(abstract_params, n_shards: int, mode: str = "balanced",
                    pad_to: int = 128, job_id: str = "flat") -> FlatPlan:
    """Standalone single-job plan: assign each tensor to a shard using the
    control plane's placement schemes, then lay segments contiguously."""
    leaves = jax.tree_util.tree_flatten_with_path(abstract_params)[0]
    tasks = []
    meta: Dict[int, Tuple[str, Tuple[int, ...], Any, int]] = {}
    for i, (path, leaf) in enumerate(leaves):
        size = int(np.prod(leaf.shape)) if leaf.shape else 1
        tasks.append(AggTask(job_id, i, _leaf_key(path), nbytes=size * 4,
                             exec_time=float(size)))
        meta[i] = (_leaf_key(path), tuple(leaf.shape), leaf.dtype, size)

    job = JobProfile(job_id, job_id, 1.0, tasks, required_servers=n_shards)
    if mode == "balanced":
        shards = balanced_shard_assignment(job, n_shards)
    elif mode == "round_robin":
        shards = round_robin_shard_assignment(job, n_shards)
    else:
        raise ValueError(f"unknown placement mode {mode!r}")

    segments: List[Segment] = []
    shard_sizes = []
    for s in range(n_shards):
        off = 0
        for task in shards[s]:
            key, shape, dtype, size = meta[task.tensor_id]
            segments.append(Segment(key, s, off, size, shape, dtype,
                                    job_id=job_id, tensor_id=task.tensor_id))
            off += size
        shard_sizes.append(off)
    shard_len = max(1, -(-max(shard_sizes) // pad_to) * pad_to)
    return FlatPlan(n_shards=n_shards, shard_len=shard_len,
                    segments=tuple(segments), block_align=pad_to)


def flatten_tree(plan: FlatPlan, tree, dtype=jnp.float32,
                 job_id: Optional[str] = None) -> jnp.ndarray:
    """Pack a pytree into the plan's flat layout (push direction).

    With ``job_id`` given, only that job's segments are filled -- other
    jobs' lanes come out zero, so a per-job gradient vector never perturbs
    co-resident jobs.  Consecutive foreign/padding lanes merge into ONE
    zero chunk each, so the concatenate has O(job segments + shards)
    operands -- independent of how many co-resident segments share the
    space (the old path emitted one chunk per co-resident segment).
    """
    by_key = {
        _leaf_key(path): leaf
        for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]
    }
    own = [seg for seg in plan.segments
           if job_id is None or seg.job_id == job_id]
    own.sort(key=plan.start)
    parts: List[jnp.ndarray] = []
    pos = 0
    for seg in own:
        start = plan.start(seg)
        if start > pos:  # merged gap: padding + other jobs' lanes
            parts.append(jnp.zeros((start - pos,), dtype))
        parts.append(by_key[seg.key].reshape(-1).astype(dtype))
        pos = start + seg.size
    if pos < plan.total_len:
        parts.append(jnp.zeros((plan.total_len - pos,), dtype))
    if not parts:
        return jnp.zeros((plan.total_len,), dtype)
    return jnp.concatenate(parts) if len(parts) > 1 else parts[0]


def unflatten_tree(plan: FlatPlan, flat: jnp.ndarray, abstract_params,
                   job_id: Optional[str] = None):
    """Unpack (a job's segments of) the flat vector into a pytree (pull).

    One contiguous slice per OWN segment -- O(job leaves), never O(total
    segments)."""
    out_by_key = {}
    for seg in plan.segments:
        if job_id is not None and seg.job_id != job_id:
            continue
        start = plan.start(seg)
        out_by_key[seg.key] = jax.lax.slice(
            flat, (start,), (start + seg.size,)
        ).reshape(seg.shape).astype(seg.dtype)

    leaves, _ = jax.tree_util.tree_flatten_with_path(abstract_params)
    ordered = [out_by_key[_leaf_key(path)] for path, _ in leaves]
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(abstract_params), ordered
    )


def _gather_owned(layout, vec: jnp.ndarray) -> jnp.ndarray:
    """Pull a job's owned lanes out of a full flat buffer -- ONE
    block-structured row gather (a memcpy per owned block, not a scalar
    loop per element); the identity when the job owns the whole space."""
    if layout.covers_all:
        return vec
    rows = vec.reshape(-1, layout.block)[jnp.asarray(layout.blocks)]
    return rows.reshape(-1)


def _layout_rows(layout):
    """Per-hosting-shard owned-block row indices of a ShardedJobLayout,
    hoisted to device ONCE at closure-build time (None where the shard
    gather is the identity)."""
    return tuple(None if l.covers_all else jnp.asarray(l.blocks)
                 for l in layout.layouts)


def _gather_pieces(layout, rows, flats):
    """One block-row gather per hosting shard of a ShardedJobLayout
    (``rows`` from :func:`_layout_rows`): the job's per-shard packed
    pieces, in shard order."""
    return [flat if r is None else
            flat.reshape(-1, l.block)[r].reshape(-1)
            for l, r, flat in zip(layout.layouts, rows, flats)]


def _gather_packed(layout, rows, flats):
    """The job's COMBINED packed vector across its hosting shards."""
    pieces = _gather_pieces(layout, rows, flats)
    return jnp.concatenate(pieces) if len(pieces) > 1 else pieces[0]


def _split_pieces(layout, g):
    """Slice a combined packed vector into per-hosting-shard pieces."""
    if layout.n_shards == 1:
        return (g,)
    return tuple(
        jax.lax.slice(g, (off,), (off + l.packed_len,))
        for l, off in zip(layout.layouts, layout.piece_offsets))


def _scatter_owned(layout, vec: jnp.ndarray, packed) -> jnp.ndarray:
    """Write a packed job-local vector back onto the owned lanes of a full
    flat buffer -- ONE block-structured row scatter (in place under
    donation)."""
    if layout.covers_all:
        return jnp.asarray(packed, vec.dtype).reshape(vec.shape)
    rows = jnp.asarray(packed, vec.dtype).reshape(-1, layout.block)
    return vec.reshape(-1, layout.block).at[jnp.asarray(layout.blocks)].set(
        rows, unique_indices=True, indices_are_sorted=True
    ).reshape(vec.shape)


# ------------------------------------------------------------------ PS step
def _adam_math(p32, g, mu0, nu0, count, *, lr, b1, b2, eps):
    """One fp32 Adam update in EXACTLY the fused kernel's arithmetic form
    (reciprocal-multiply bias correction, same operation grouping), so the
    unfused paths and the Pallas kernel agree bit-for-bit."""
    mu = b1 * mu0 + (1.0 - b1) * g
    nu = b2 * nu0 + (1.0 - b2) * g * g
    t = count.astype(jnp.float32)
    # The barriers materialize the bias-correction scalars: fused into the
    # elementwise loop, XLA recomputes ``b1 ** t`` per lane with the
    # vectorized pow approximation, whose last ulp differs from the scalar
    # lowering -- and differs BETWEEN program shapes, breaking masked /
    # block / Pallas bit-parity.  A standalone scalar pow is deterministic
    # (and free).
    bc1 = jax.lax.optimization_barrier(1.0 / (1.0 - b1 ** t))
    bc2 = jax.lax.optimization_barrier(1.0 / (1.0 - b2 ** t))
    mu_hat = mu * bc1
    nu_hat = nu * bc2
    # (lr*mu_hat)/denom - the sub sees a division, not a multiply, so XLA
    # cannot FMA-contract the update differently across program shapes.
    new_p = p32 - (lr * mu_hat) / (jnp.sqrt(nu_hat) + eps)
    return new_p, mu, nu


def _unpack_slots(layout, packed, abstract_params):
    """Packed job-local vector -> pytree (static, plan-independent slices)."""
    out_by_key = {
        key: jax.lax.slice(packed, (start,), (start + size,))
        .reshape(shape).astype(dtype)
        for key, start, size, shape, dtype in layout.slots
    }
    leaves, _ = jax.tree_util.tree_flatten_with_path(abstract_params)
    ordered = [out_by_key[_leaf_key(path)] for path, _ in leaves]
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(abstract_params), ordered)


def _pack_slots(layout, tree, dtype=jnp.float32):
    """Pytree -> packed job-local vector (zeros on intra-block padding)."""
    by_key = {
        _leaf_key(path): leaf
        for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]
    }
    parts, pos = [], 0
    for key, start, size, _, _ in layout.slots:
        if start > pos:
            parts.append(jnp.zeros((start - pos,), dtype))
        parts.append(by_key[key].reshape(-1).astype(dtype))
        pos = start + size
    if pos < layout.packed_len:
        parts.append(jnp.zeros((layout.packed_len - pos,), dtype))
    if not parts:
        return jnp.zeros((0,), dtype)
    return jnp.concatenate(parts) if len(parts) > 1 else parts[0]


def make_ps_train_step(
    model_loss: Callable[[Any, Any], jnp.ndarray],
    plan: FlatPlan,
    abstract_params,
    lr: float = 3e-4,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    push_compression: Optional[str] = None,  # None | 'bf16' | 'int8'
    fused_kernel: bool = False,
    job_id: Optional[str] = None,
    update_mode: str = "block",  # 'block' (O(job)) | 'masked' (legacy)
):
    """Build the PS-mode train step.

    Single-job mode (``job_id=None``, legacy):
      state = {flat (N,), mu (N,), nu (N,), count, [ef (N,)]}

    Shared-service mode (``job_id`` given): the same flat/mu/nu buffers are
    shared by every job in the plan; this job's step touches ONLY its own
    lanes and keeps its own step counter in state["counts"][job_id], so
    co-resident jobs' moments and bias correction are untouched.  With the
    default ``update_mode="block"`` the whole step runs in the job's packed
    domain -- pull is one gather through the plan's precompiled index map,
    push is one concatenate, the Adam update costs O(job bytes), and the
    results scatter back onto the job's owned lanes; ``fused_kernel=True``
    replaces the update with the block-owned Pallas kernel whose grid
    iterates only the job's owned blocks (scalar-prefetched block indices).
    ``update_mode="masked"`` keeps the legacy full-space ``jnp.where`` path
    (O(total space) per step) for parity tests and benchmarks.

    All flat buffers are sharded P(aggregation axes) by the caller; the
    gather/scatter pair makes GSPMD emit the pull all-gather and push
    reduce-scatter onto the owner layout.
    """
    from repro.ps import act_sharding as act
    from repro.ps.compression import compress_decompress

    if update_mode not in ("block", "masked"):
        raise ValueError(f"unknown update_mode {update_mode!r}")
    if job_id is not None and update_mode == "block":
        return _make_block_step(
            model_loss, plan, abstract_params, lr=lr, b1=b1, b2=b2, eps=eps,
            push_compression=push_compression, fused_kernel=fused_kernel,
            job_id=job_id)

    mask = None
    if job_id is not None:
        mask = jnp.asarray(segment_mask(plan, job_id))

    def _count(state):
        if job_id is None:
            return state["count"] + 1
        return state["counts"][job_id] + 1

    def step(state, batch):
        flat = state["flat"]
        params = unflatten_tree(plan, flat, abstract_params, job_id)  # PULL
        loss, grads = jax.value_and_grad(model_loss)(params, batch)
        gflat = flatten_tree(plan, grads, jnp.float32, job_id)  # PUSH
        if push_compression:
            ef = state["ef"]
            gflat = gflat + (ef if mask is None else jnp.where(mask, ef, 0.0))
            q = compress_decompress(gflat, push_compression)
            resid = gflat - q
            new_ef = resid if mask is None else jnp.where(mask, resid, ef)
            gflat = q if mask is None else jnp.where(mask, q, 0.0)
        gflat = act.constrain(gflat, "all")  # reduce-scatter point

        count = _count(state)
        if fused_kernel:
            from repro.kernels.agg_adam import ops as agg_ops

            new_flat, mu, nu = agg_ops.adam_update(
                flat, gflat, state["mu"], state["nu"], count,
                lr=lr, b1=b1, b2=b2, eps=eps, wd=0.0)
        else:
            new_flat, mu, nu = _adam_math(
                flat, gflat, state["mu"], state["nu"], count,
                lr=lr, b1=b1, b2=b2, eps=eps)
        if mask is not None:
            # Update only this job's lanes of the shared space.
            new_flat = jnp.where(mask, new_flat, flat)
            mu = jnp.where(mask, mu, state["mu"])
            nu = jnp.where(mask, nu, state["nu"])
        new_flat = act.constrain(new_flat, "all")

        new_state = dict(state)
        new_state.update(flat=new_flat, mu=mu, nu=nu)
        if job_id is None:
            new_state["count"] = count
        else:
            new_state["counts"] = dict(state["counts"], **{job_id: count})
        if push_compression:
            new_state["ef"] = new_ef
        return new_state, {"loss": loss}

    return step


def _make_block_step(model_loss, plan, abstract_params, *, lr, b1, b2, eps,
                     push_compression, fused_kernel, job_id):
    """O(job-bytes) shared-service step over the job's packed domain.

    The flat space never gets a full-length pass: pull gathers the job's
    owned lanes (one HLO gather), the update runs on the packed vector (or
    in the block-owned Pallas kernel), and three scatters write the owned
    lanes back.  Co-resident jobs' lanes are never read or written, so the
    HLO op count and the update FLOPs/bytes are independent of how many
    jobs share the space.
    """
    from repro.ps import act_sharding as act
    from repro.ps.compression import ef_transform

    layout = plan.job_layout(job_id)

    def step(state, batch):
        flat = state["flat"]
        packed_p = _gather_owned(layout, flat)  # PULL: one row gather
        params = _unpack_slots(layout, packed_p, abstract_params)
        loss, grads = jax.value_and_grad(model_loss)(params, batch)
        g = _pack_slots(layout, grads)  # PUSH: one concatenate
        if push_compression:
            # The SAME transform the tick engines' appliers run, so the
            # engine'd compressed trajectory matches step()'s bit-for-bit
            # (eager) -- see tests/test_fused_tick.py.
            g, resid = ef_transform(
                g, _gather_owned(layout, state["ef"]), push_compression)
        g = act.constrain(g, "all")  # reduce-scatter point

        count = state["counts"][job_id] + 1
        if fused_kernel:
            from repro.kernels.agg_adam import ops as agg_ops

            # The kernel DMAs the owned blocks of the FULL mu/nu buffers
            # itself (scalar-prefetched block indices); p goes in already
            # packed -- the pull materialized it, so re-gathering would
            # cost an extra O(job bytes) pass.
            new_p, mu, nu = agg_ops.block_adam_update(
                packed_p, g, state["mu"], state["nu"], count,
                block_idx=layout.blocks, block=layout.block,
                lr=lr, b1=b1, b2=b2, eps=eps, wd=0.0)
        else:
            new_p, mu, nu = _adam_math(
                packed_p, g, _gather_owned(layout, state["mu"]),
                _gather_owned(layout, state["nu"]), count,
                lr=lr, b1=b1, b2=b2, eps=eps)

        new_state = dict(state)
        new_state["flat"] = act.constrain(
            _scatter_owned(layout, flat, new_p), "all")
        new_state["mu"] = _scatter_owned(layout, state["mu"], mu)
        new_state["nu"] = _scatter_owned(layout, state["nu"], nu)
        if push_compression:
            new_state["ef"] = _scatter_owned(layout, state["ef"], resid)
        new_state["counts"] = dict(state["counts"], **{job_id: count})
        return new_state, {"loss": loss}

    return step


def init_ps_state(plan: FlatPlan, params, push_compression=None):
    """Single-job state: flat buffers hold exactly this job's tensors."""
    flat = flatten_tree(plan, params, jnp.float32)
    state = {
        "flat": flat,
        "mu": jnp.zeros_like(flat),
        "nu": jnp.zeros_like(flat),
        "count": jnp.zeros((), jnp.int32),
    }
    if push_compression:
        state["ef"] = jnp.zeros_like(flat)
    return state


def init_shared_state(plan: FlatPlan, needs_ef: bool = False):
    """Empty shared-service state for a compiled multi-job plan; jobs are
    seeded into their own segments with :func:`seed_job_params`.

    ``needs_ef`` allocates the shared error-feedback buffer used by jobs
    that push compressed gradients.
    """
    flat = jnp.zeros((plan.total_len,), jnp.float32)
    state = {
        "flat": flat,
        "mu": jnp.zeros_like(flat),
        "nu": jnp.zeros_like(flat),
        "counts": {},
    }
    if needs_ef:
        state["ef"] = jnp.zeros_like(flat)
    return state


def seed_job_params(plan: FlatPlan, state, job_id: str, params):
    """Write a job's initial parameters into its segments of the shared flat
    space (fresh Adam moments + step counter for that job only).  One
    block-structured row scatter per buffer through the plan's compiled
    layout; other jobs' lanes are untouched.  (Plans that are not
    block-exclusive -- hand-built or legacy-deserialized -- fall back to a
    per-lane scatter through ``payload_index``.)"""
    new_state = dict(state)
    try:
        layout = plan.job_layout(job_id)
    except ValueError:
        idx_np = plan.payload_index(job_id)
        idx = jnp.asarray(idx_np)
        put = dict(unique_indices=True,
                   indices_are_sorted=bool(np.all(np.diff(idx_np) > 0)))
        by_key = {
            _leaf_key(path): leaf
            for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]
        }
        parts = [by_key[s.key].reshape(-1).astype(jnp.float32)
                 for s in plan.segments if s.job_id == job_id]
        packed = (jnp.concatenate(parts) if len(parts) > 1 else
                  parts[0] if parts else jnp.zeros((0,), jnp.float32))
        new_state["flat"] = state["flat"].at[idx].set(packed, **put)
        new_state["mu"] = state["mu"].at[idx].set(0.0, **put)
        new_state["nu"] = state["nu"].at[idx].set(0.0, **put)
        if "ef" in state:
            new_state["ef"] = state["ef"].at[idx].set(0.0, **put)
    else:
        packed = _pack_slots(layout, params)

        def zeroed(buf):
            # A fresh zeros vector per buffer: with covers_all layouts
            # _scatter_owned returns its packed argument as-is, and a
            # shared zeros array would alias mu/nu -- the donated step
            # then trips "donate the same buffer twice".
            return _scatter_owned(
                layout, buf, jnp.zeros((layout.packed_len,), jnp.float32))

        new_state["flat"] = _scatter_owned(layout, state["flat"], packed)
        new_state["mu"] = zeroed(state["mu"])
        new_state["nu"] = zeroed(state["nu"])
        if "ef" in state:
            new_state["ef"] = zeroed(state["ef"])
    new_state["counts"] = dict(state["counts"],
                               **{job_id: jnp.zeros((), jnp.int32)})
    return new_state
