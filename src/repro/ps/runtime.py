"""Paper-faithful PS data plane: flat parameter space + per-tensor owners.

The control plane's tensor->Aggregator assignment (repro.core.assignment)
becomes the *layout of a flat parameter vector* across aggregator shards:

  pull    unflatten(flat)   -> all-gather of each shard's segments
  push    flatten(grads)    -> reduce-scatter onto the owner layout
  update  elementwise Adam on the local shard only (the aggregation op;
          fused Pallas kernel on TPU, repro.kernels.agg_adam)

ps-lite round-robin vs AutoPS balanced placement differ in per-shard byte
balance: every shard is padded to the largest shard, so imbalance shows up
directly as extra all-gather bytes + wasted optimizer lanes -- the data-
plane realization of Fig. 7.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.assignment import (
    balanced_shard_assignment,
    round_robin_shard_assignment,
)
from repro.core.types import AggTask, JobProfile


@dataclass(frozen=True)
class Segment:
    key: str  # pytree path key
    shard: int
    offset: int  # element offset within the shard
    size: int
    shape: Tuple[int, ...]
    dtype: Any


@dataclass(frozen=True)
class FlatPlan:
    n_shards: int
    shard_len: int  # padded elements per shard
    segments: Tuple[Segment, ...]  # in (shard, offset) order

    @property
    def total_len(self) -> int:
        return self.n_shards * self.shard_len

    @property
    def payload_elements(self) -> int:
        return sum(s.size for s in self.segments)


def _leaf_key(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def build_flat_plan(abstract_params, n_shards: int, mode: str = "balanced",
                    pad_to: int = 128) -> FlatPlan:
    """Assign each tensor to an aggregator shard using the control plane's
    placement schemes, then lay segments contiguously per shard."""
    leaves = jax.tree_util.tree_flatten_with_path(abstract_params)[0]
    tasks = []
    meta: Dict[int, Tuple[str, Tuple[int, ...], Any, int]] = {}
    for i, (path, leaf) in enumerate(leaves):
        size = int(np.prod(leaf.shape)) if leaf.shape else 1
        tasks.append(AggTask("flat", i, _leaf_key(path), nbytes=size * 4,
                             exec_time=float(size)))
        meta[i] = (_leaf_key(path), tuple(leaf.shape), leaf.dtype, size)

    job = JobProfile("flat", "flat", 1.0, tasks, required_servers=n_shards)
    if mode == "balanced":
        shards = balanced_shard_assignment(job, n_shards)
    elif mode == "round_robin":
        shards = round_robin_shard_assignment(job, n_shards)
    else:
        raise ValueError(f"unknown placement mode {mode!r}")

    segments: List[Segment] = []
    shard_sizes = []
    for s in range(n_shards):
        off = 0
        for task in shards[s]:
            key, shape, dtype, size = meta[task.tensor_id]
            segments.append(Segment(key, s, off, size, shape, dtype))
            off += size
        shard_sizes.append(off)
    shard_len = max(1, -(-max(shard_sizes) // pad_to) * pad_to)
    return FlatPlan(n_shards=n_shards, shard_len=shard_len,
                    segments=tuple(segments))


def plan_padding_waste(plan: FlatPlan) -> float:
    """Fraction of the flat space that is padding (imbalance cost)."""
    payload = sum(s.size for s in plan.segments)
    return 1.0 - payload / plan.total_len


def flatten_tree(plan: FlatPlan, tree, dtype=jnp.float32) -> jnp.ndarray:
    """Pack a pytree into the plan's flat layout (push direction)."""
    by_key = {
        _leaf_key(path): leaf
        for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]
    }
    parts: List[jnp.ndarray] = []
    for s in range(plan.n_shards):
        used = 0
        for seg in plan.segments:
            if seg.shard != s:
                continue
            parts.append(by_key[seg.key].reshape(-1).astype(dtype))
            used += seg.size
        if used < plan.shard_len:
            parts.append(jnp.zeros((plan.shard_len - used,), dtype))
    return jnp.concatenate(parts)


def unflatten_tree(plan: FlatPlan, flat: jnp.ndarray, abstract_params):
    """Unpack the flat vector into the original pytree (pull direction)."""
    out_by_key = {}
    for seg in plan.segments:
        start = seg.shard * plan.shard_len + seg.offset
        out_by_key[seg.key] = jax.lax.slice(
            flat, (start,), (start + seg.size,)
        ).reshape(seg.shape).astype(seg.dtype)

    leaves, treedef = jax.tree_util.tree_flatten_with_path(abstract_params)
    ordered = [out_by_key[_leaf_key(path)] for path, _ in leaves]
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(abstract_params), ordered
    )


# ------------------------------------------------------------------ PS step
def make_ps_train_step(
    model_loss: Callable[[Any, Any], jnp.ndarray],
    plan: FlatPlan,
    abstract_params,
    lr: float = 3e-4,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    push_compression: Optional[str] = None,  # None | 'bf16' | 'int8'
    fused_kernel: bool = False,
):
    """Build the PS-mode train step.

    state = {flat (N,), mu (N,), nu (N,), count, [ef (N,) error feedback]}
    All flat buffers are sharded P(aggregation axes) by the caller; the
    unflatten/flatten pair makes GSPMD emit the pull all-gather and push
    reduce-scatter onto the owner layout.
    """
    from repro.ps import act_sharding as act
    from repro.ps.compression import compress_decompress

    def step(state, batch):
        flat = state["flat"]
        params = unflatten_tree(plan, flat, abstract_params)  # PULL
        loss, grads = jax.value_and_grad(model_loss)(params, batch)
        gflat = flatten_tree(plan, grads, jnp.float32)  # PUSH
        if push_compression:
            gflat = gflat + state["ef"]
            q = compress_decompress(gflat, push_compression)
            new_ef = gflat - q
            gflat = q
        gflat = act.constrain(gflat, "all")  # reduce-scatter point

        count = state["count"] + 1
        if fused_kernel:
            from repro.kernels.agg_adam import ops as agg_ops

            new_flat, mu, nu = agg_ops.adam_update(
                flat, gflat, state["mu"], state["nu"], count,
                lr=lr, b1=b1, b2=b2, eps=eps, wd=0.0)
        else:
            mu = b1 * state["mu"] + (1 - b1) * gflat
            nu = b2 * state["nu"] + (1 - b2) * jnp.square(gflat)
            t = count.astype(jnp.float32)
            mu_hat = mu / (1 - b1 ** t)
            nu_hat = nu / (1 - b2 ** t)
            new_flat = flat - lr * mu_hat / (jnp.sqrt(nu_hat) + eps)
        new_flat = act.constrain(new_flat, "all")

        new_state = {"flat": new_flat, "mu": mu, "nu": nu, "count": count}
        if push_compression:
            new_state["ef"] = new_ef
        return new_state, {"loss": loss}

    return step


def init_ps_state(plan: FlatPlan, params, push_compression=None):
    flat = flatten_tree(plan, params, jnp.float32)
    state = {
        "flat": flat,
        "mu": jnp.zeros_like(flat),
        "nu": jnp.zeros_like(flat),
        "count": jnp.zeros((), jnp.int32),
    }
    if push_compression:
        state["ef"] = jnp.zeros_like(flat)
    return state
