"""Load-driven elastic scaling of the Aggregator fleet (paper §3.3.2).

The control plane already grows the fleet on job ARRIVAL (admit + revert
loop) and shrinks it on job EXIT (recycling).  This module closes the
paper's remaining loop -- "the number of Aggregators follows the measured
aggregation load" (Fig. 2 / Fig. 11, up to 75% CPU reduction) -- from the
DATA PLANE's side: the :class:`repro.ps.engine.ShardedTickEngine` exposes
one :class:`~repro.ps.engine.TickStats` per shard space, and the
:class:`ElasticScaler` turns the per-window deltas of those counters
(pieces applied = pushes/sec, queue occupancy = drain pressure) into
``ParameterService.scale_out`` / ``scale_in`` decisions:

    shard spaces tick  ->  per-shard TickStats  ->  observe() window
         ^                                               |
         |              (split_aggregator /              v
    sharded replan  <-  recycle_aggregators)  <-  desired fleet size

Every action is an ordinary control-plane replan, so the data plane
migrates shard states with the O(moved-bytes) sharded delta path and
untouched jobs tick straight through -- scaling is load-following AND
stall-free.

The policy is deliberately simple and deterministic (benchmarks and the
simulator replay it): the fleet targets ``ceil(load / shard_capacity)``
shards, where load is the window's applied pieces plus what is still
queued, clamped to ``[min_shards, max_shards]``, one fleet change per
``cooldown`` windows.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional


@dataclass(frozen=True)
class AutoscalerConfig:
    """Knobs of the load-following policy.

    ``shard_capacity`` is the pushes-per-window one shard space is sized
    to absorb (the paper's per-Aggregator CPU budget, in units of applied
    aggregation passes); ``headroom`` scales the demand before dividing,
    so 1.25 keeps the fleet ~20% under saturation.
    """

    shard_capacity: float = 64.0  # applied pieces per shard per window
    headroom: float = 1.0
    min_shards: int = 1
    max_shards: int = 64
    cooldown: int = 1  # observe() calls between fleet changes
    max_step: int = 2  # fleet changes at most this many shards per action


@dataclass
class ScaleDecision:
    """One observe() window's record (the benchmark's audit trail)."""

    window: int
    load: float  # applied-in-window + still-queued pieces
    per_shard: Dict[str, float]  # applied pieces per shard this window
    n_shards_before: int
    n_shards_after: int
    action: str  # 'grow' | 'shrink' | 'hold'
    relayout_bytes: int = 0  # shard bytes the action's migration moved
    quarantined: tuple = ()  # shards quarantined this window (forces hold)


class ElasticScaler:
    """Feedback loop from per-shard TickStats to the Aggregator fleet.

    Usage::

        rt = ShardedServiceRuntime(svc)
        eng = rt.attach_engine(max_staleness=0)
        scaler = ElasticScaler(rt, AutoscalerConfig(shard_capacity=32))
        for window in workload:
            for job, batch in window:
                eng.step(job, batch)
            eng.expire_leases()     # reclaim silent trainers first ...
            scaler.observe()        # ... so the fleet sees the freed load

    ``observe()`` is pull-based on purpose: the caller decides the window
    (wall clock, tick rounds, or trace epochs), so simulators, benchmarks
    and tests replay the identical policy deterministically.  Run the
    engine's ``expire_leases()`` sweep on the same cadence, BEFORE
    ``observe()``: a reclaimed job's queued pieces leave with it (both
    halves of the load signal drop -- no window applies them and the
    drain occupancy is cancelled), so the fleet shrinks away from dead
    trainers instead of holding capacity for their stalled queues
    (``scripts/replay_trace.py`` is the end-to-end demonstration).
    """

    def __init__(self, runtime, config: Optional[AutoscalerConfig] = None):
        self.runtime = runtime
        self.config = config or AutoscalerConfig()
        if self.config.min_shards < 1:
            raise ValueError("min_shards must be >= 1")
        if self.config.max_shards < self.config.min_shards:
            raise ValueError("max_shards must be >= min_shards")
        self.decisions: List[ScaleDecision] = []
        # Snapshot the engine's lifetime counters NOW: a scaler attached
        # to a warm engine must not read its whole history as the first
        # window's load (and fire a spurious scale-out).
        self._last_applied: Dict[str, int] = (
            {sid: s.n_applied for sid, s in runtime.engine.shard_stats()
             .items()} if runtime.engine is not None else {})
        self._since_action = self.config.cooldown  # allow an immediate act

    # ------------------------------------------------------------- signals
    def _engine(self):
        eng = self.runtime.engine
        if eng is None:
            raise RuntimeError(
                "ElasticScaler needs the runtime's ShardedTickEngine "
                "attached (runtime.attach_engine()) -- per-shard TickStats "
                "are its load signal")
        return eng

    def window_loads(self) -> Dict[str, float]:
        """Applied pieces per shard since the last observe() (and update
        the high-water marks): the pushes/sec half of the load signal."""
        eng = self._engine()
        loads: Dict[str, float] = {}
        for sid, stats in eng.shard_stats().items():
            seen = self._last_applied.get(sid, 0)
            loads[sid] = float(stats.n_applied - seen)
            self._last_applied[sid] = stats.n_applied
        # Shards that left the fleet stop contributing.
        for sid in list(self._last_applied):
            if sid not in loads:
                del self._last_applied[sid]
        return loads

    def queued_pieces(self) -> int:
        """Drain occupancy: pieces sitting in queues right now."""
        eng = self._engine()
        return sum(len(q) for lane in eng._lanes.values()
                   for q in lane.queues.values())

    # ------------------------------------------------------------ decision
    def observe(self) -> ScaleDecision:
        """Close one window: read the load, resize the fleet toward
        ``ceil(load * headroom / shard_capacity)``, record the decision."""
        cfg = self.config
        per_shard = self.window_loads()
        load = sum(per_shard.values()) + self.queued_pieces()
        n_before = self.runtime.n_shards
        # A degraded fleet is never resized: splits and merges migrate
        # shard state, and a quarantined lane's buffers are condemned --
        # recover it first (ShardedServiceRuntime.recover_shard), then
        # let load drive the fleet again.
        quarantined = tuple(self._engine().quarantined_shards())
        desired = max(
            cfg.min_shards,
            min(cfg.max_shards,
                int(math.ceil(load * cfg.headroom
                              / max(1e-9, cfg.shard_capacity)))))
        action = "hold"
        relayout = 0
        self._since_action += 1
        if (not quarantined and self._since_action >= cfg.cooldown
                and desired != n_before):
            step = max(1, min(cfg.max_step, abs(desired - n_before)))
            before_bytes = self.runtime.total_relayout_bytes
            if desired > n_before:
                if self.runtime.service.scale_out(step):
                    action = "grow"
            else:
                if self.runtime.service.scale_in(step):
                    action = "shrink"
            if action != "hold":
                self._since_action = 0
                relayout = self.runtime.total_relayout_bytes - before_bytes
        decision = ScaleDecision(
            window=len(self.decisions), load=load, per_shard=per_shard,
            n_shards_before=n_before, n_shards_after=self.runtime.n_shards,
            action=action, relayout_bytes=relayout,
            quarantined=quarantined)
        self.decisions.append(decision)
        return decision

    # ----------------------------------------------------------- accounting
    @property
    def n_actions(self) -> int:
        return sum(1 for d in self.decisions if d.action != "hold")

    def shard_timeline(self) -> List[int]:
        """Fleet size after each window (the Fig.-2-style series)."""
        return [d.n_shards_after for d in self.decisions]
