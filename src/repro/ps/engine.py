"""Service-tick execution engine: batched multi-job aggregation with
bounded staleness.

The paper's aggregation is a *shared service*: many jobs' bursty pushes
land on the same Aggregator CPUs and should be executed together, not as
one step-function per job.  PR 1 compiled the packing into one shared
FlatPlan and PR 2 made each job's step O(job bytes); this module adds the
service-side loop that actually batches them:

  submit_push  a job pushes its packed gradient into its bounded per-job
               queue and gets a :class:`PushFuture`; nothing is applied yet
  tick         the engine drains the HEAD push of every pending job and
               applies all of them in ONE batched pass over the shared
               flat space -- a single Pallas launch on TPU
               (``kernels.agg_adam.aggregate_adam_multijob``: concatenated
               owned-block index table + per-block job-slot map), a
               fused-scatter jnp pass in interpret mode
  pull         a job reads its own lanes; with ``max_staleness = s`` a job
               may run ``s`` steps ahead of the service before its pull
               blocks on (forces) the tick -- Dynamic-SSP-style bounded
               staleness; ``s = 0`` is BSP

Block exclusivity (every ``block_align`` block of the flat space belongs
to at most one job, the PR-2 invariant) is what makes the batched pass a
pure execution-order change: its result is bit-exact with applying the
same pushes as K sequential per-job block steps.  Below the measured
batching crossover (``min_batch_jobs``; BENCH_service_tick.json showed
the one-launch concatenation LOSING at 2 pending jobs) a tick dispatches
the same pushes as per-job block passes instead -- identical result,
cheaper program.

Replans are STALL-FREE: the runtime compiles a
:class:`repro.ps.elastic.MigrationDelta` for the plan pair and quiesces
ONLY the touched jobs (those whose segment layout changes) -- their
queued pushes apply against the OLD plan before the state migrates.
Untouched jobs keep their queues, their compiled programs, and their
tick cadence straight through the transition; a per-push EPOCH FENCE
(every queued push is tagged with the plan epoch it was packed under,
and untouched jobs' surviving pushes are re-tagged at each replan)
guarantees no push is ever applied across mismatched layouts, extending
the PR-3 invariant: the engine'd runtime stays bit-exact with the
unbatched one -- eager execution matches it bit-for-bit at any sizes,
and the jitted batched apply matches jitted sequential block updates
bit-for-bit at SIMD-even block sizes (fully-jitted END-TO-END runs
additionally see XLA:CPU's ~1-ulp cross-program fusion rounding, the
same caveat PR 2 documents for jitted block-vs-masked; see
tests/test_engine.py).

Usage::

    rt = ServiceRuntime(svc)
    eng = rt.attach_engine(max_staleness=1)
    rt.add_job("a", params_a, loss_a); rt.add_job("b", params_b, loss_b)
    for batch_a, batch_b in data:
        eng.step("a", batch_a)   # pull -> grad -> submit_push
        eng.step("b", batch_b)
        # pushes apply together at the next tick (forced by staleness,
        # queue pressure, an explicit eng.tick(), or fut.result())
    eng.drain()

PR 5 adds the SHARDED sibling: :class:`ShardedTickEngine` runs one
independent tick loop per Aggregator shard space (``tick_shard``), with a
job's push split into one piece per hosting shard -- see the class
docstring and docs/architecture.md.

PR 6 makes the hot path a SINGLE LAUNCH: the row scatters that used to
follow every batched apply are fused into the kernel itself
(``kernels.agg_adam.aggregate_adam_multijob_fused`` writes the updated
flat/mu/nu blocks in place via ``input_output_aliases``), and the sharded
engine gains :meth:`ShardedTickEngine.tick_fleet` -- every lane with
pending pieces ticks in ONE fused launch over the lanes' concatenated
states (``fleet_tick="fused"``, the default; ``"per_shard"`` keeps the
PR-5 loop as a bit-parity oracle).  ``TickStats.n_launches`` counts what
this buys.

PR 7 makes a failed apply SURVIVABLE.  The jitted appliers donate the
state buffers, so an exec failure may have deleted them mid-update;
earlier engines poisoned the WHOLE engine permanently.  Now every lane
(each shard space; the flat engine is one unnamed lane) keeps a
last-good SNAPSHOT of its state, refreshed every ``snapshot_interval``
applying ticks with the copy taken *before* the donated apply, plus a
replay log of the pushes applied since.  On an exec failure the lane
restores the snapshot, re-queues the failed heads AND the logged
pushes (in order, futures kept but never re-resolved), and replays them
on subsequent ticks -- at ``max_staleness=0`` the recovered trajectory
is bit-exact with a fault-free run, because sharded pieces carry their
submit-time step counts and flat counts recompute from the restored
state.  A lane that keeps failing (``max_apply_retries`` consecutive
rollbacks) is QUARANTINED: its state stays at the last-good snapshot,
``tick_shard`` skips it, ``tick_fleet`` drops it from the fused launch,
and blocked work (``drain``/``pull``/``result``) raises
:class:`repro.ps.faults.EngineQuarantinedError` naming the shard, tick,
jobs, and original exception.  A fused fleet launch cannot attribute
which lane failed, so its failure handler rolls back EVERY participating
lane and replays each with its own per-shard launch -- the faulty lane
fails (and retries or quarantines) in isolation while the rest re-apply
(``TickStats.n_fleet_fallbacks``).  ``ShardedServiceRuntime.
recover_shard`` turns a quarantined lane back into a healthy fleet via
the PR-4/5 migration machinery; a seedable
:class:`repro.ps.faults.FaultInjector` drives all of it
deterministically in tests and benchmarks.

PR 8 makes the wire path CHEAP.  Compressed-push jobs
(``push_compression="bf16"|"int8"``), which both engines previously
rejected, now flow through batched and fused fleet ticks: the shared
error-feedback buffer (``state["ef"]``, one per shard space under the
sharded engine -- a compressed job gets one EF round per hosting
shard's piece) lives next to flat/mu/nu in the engine's donated state,
so it rides snapshots, rollback replay, relayout migrations, and
checkpoints like any other state leaf, and appliers whose jobs are all
uncompressed compile the exact pre-PR-8 program (bit-exact default
path).  The transform itself is ONE shared function
(:func:`repro.ps.compression.ef_transform`), so the engine'd compressed
trajectory matches ``runtime.step()``'s compressed path bit-for-bit in
eager mode.  Pulls gain a versioned PARAMETER-DIFF protocol: every
applying tick stamps the applied jobs' owned blocks with a monotone
version (host-side numpy, one entry per ``block_align`` block;
rollbacks re-stamp so rewound blocks read as changed), and
``pull(job_id, since_version=<PullVersion>)`` ships only the changed
blocks as a :class:`PullDiff` -- full-pull fallback on the first call,
a plan-epoch change, or a mismatched vector.  ``TickStats`` carries the
transfer-byte accounting (``push_bytes_raw/wire``,
``pull_bytes_full/wire``, ``n_full_pulls``/``n_diff_pulls``), surfaced
by ``debug_stats()`` and measured in BENCH_wire.json
(benchmarks/wire_path.py).
"""

from __future__ import annotations

import time

from collections import deque
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.ps.compression import ef_transform, wire_bytes
from repro.ps.faults import (
    HEALTHY,
    QUARANTINED,
    EngineQuarantinedError,
    LeaseExpiredError,
)
from repro.ps.plan import FlatPlan
from repro.ps.runtime import (
    _gather_owned,
    _gather_packed,
    _layout_rows,
    _pack_slots,
    _scatter_owned,
    _split_pieces,
    _unpack_slots,
)

__all__ = ["PullDiff", "PullVersion", "PushFuture", "ServiceTickEngine",
           "ShardedTickEngine", "TickStats"]


class PushFuture:
    """Handle for one submitted push; resolves when a tick applies it.

    Under the sharded engine one push fans out into one PIECE per hosting
    shard (``parts``); the future resolves when the LAST piece applies.
    A push dropped without applying (a job removed with a queue that
    could not drain, or a piece lost with a dead shard) is CANCELLED:
    ``result()`` raises instead of forcing ticks forever.  A push whose
    applied effect was later DISCARDED by shard-loss recovery (it landed
    inside the lost lane's rollback window) keeps its resolved step but
    reports ``rolled_back`` -- re-push to land the update again.
    """

    __slots__ = ("job_id", "_engine", "_done", "_step", "_remaining",
                 "_cancelled", "_cancel_exc", "_rolled_back")

    def __init__(self, job_id: str, engine, parts: int = 1):
        self.job_id = job_id
        self._engine = engine
        self._done = False
        self._step = None
        self._remaining = int(parts)
        self._cancelled = None  # str reason once cancelled
        self._cancel_exc = None  # contextual exception behind the cancel
        self._rolled_back = False  # applied, then lost with a dead shard

    def done(self) -> bool:
        return self._done

    def cancelled(self) -> bool:
        return self._cancelled is not None

    @property
    def rolled_back(self) -> bool:
        """True if this push HAD applied but its effect was discarded by
        ``recover_shard`` (it was inside the lost shard's rollback
        window, at most ``snapshot_interval`` ticks deep)."""
        return self._rolled_back

    def result(self, timeout: Optional[float] = None) -> int:
        """Block (force service ticks) until applied; returns the job's
        1-based step count as of this push.

        ``timeout`` (seconds, wall clock): raise at the deadline if the
        push has not applied in time.  The error is CONTEXTUAL when the
        engine knows why the push is stuck: a push whose lane was
        quarantined mid-wait raises that lane's
        :class:`~repro.ps.faults.EngineQuarantinedError`, and a push
        whose job was lease-expired raises the stored
        :class:`~repro.ps.faults.LeaseExpiredError`; only an
        unexplained stall (e.g. a piece dropped in transit) raises a
        bare ``TimeoutError``.  With no timeout the call never spins
        forever either: if ticking makes no progress and the push cannot
        resolve, it raises the blocking lane's quarantine error (or a
        ``RuntimeError`` when the piece is simply gone).  A cancelled
        push without a stored exception raises ``RuntimeError``
        immediately.  Note the flat engine has a single lane, so its
        quarantine raises out of ``tick()`` itself regardless of
        ``timeout``."""
        deadline = (None if timeout is None
                    else time.monotonic() + float(timeout))
        while not self._done:
            if self._cancelled is not None:
                if self._cancel_exc is not None:
                    raise self._cancel_exc
                raise RuntimeError(
                    f"push for job {self.job_id!r} will never apply: "
                    f"{self._cancelled}")
            if deadline is not None and time.monotonic() >= deadline:
                stall = self._engine._stall_error(self.job_id)
                if isinstance(stall, EngineQuarantinedError):
                    raise stall
                raise TimeoutError(
                    f"push for job {self.job_id!r} still unapplied after "
                    f"{timeout} s (hosting lane quarantined, or a piece "
                    f"was dropped in transit)")
            if self._engine.tick() == 0 and not self._done:
                # No progress and still pending: either a rollback just
                # re-queued work (pieces remain on healthy lanes -- keep
                # ticking) or the push is stuck for good.
                stall = self._engine._stall_error(self.job_id)
                if stall is None:
                    continue
                if deadline is None:
                    raise stall
                time.sleep(0.001)  # wait out the timeout, don't hot-spin
        return self._step

    def _resolve(self, step: int) -> bool:
        """One piece applied; True if this transition completed the push
        (re-applying a rolled-back piece of an already-done future is a
        no-op, so replay never double-commits)."""
        if self._done:
            return False
        self._remaining -= 1
        if self._remaining <= 0:
            self._done = True
            self._step = int(step)
            return True
        return False

    def _unresolve(self) -> None:
        """A rollback un-applied one piece.  A still-pending future gets
        the part back (it must not complete until the replay re-applies
        it); a DONE future stays done -- its result was already
        observable, and the deterministic replay re-lands the identical
        update."""
        if not self._done:
            self._remaining += 1

    def _cancel(self, reason: str,
                exc: Optional[BaseException] = None) -> None:
        """Cancel with an optional contextual exception for ``result()``
        to re-raise (e.g. :class:`LeaseExpiredError`).  The FIRST
        cancellation wins -- later ones must not overwrite its context."""
        if not self._done and self._cancelled is None:
            self._cancelled = reason
            self._cancel_exc = exc


@dataclass
class TickStats:
    """Engine counters: how batched the service actually ran."""

    n_ticks: int = 0  # batched passes executed
    n_applied: int = 0  # pushes applied across all ticks
    n_launches: int = 0  # kernel/applier launches (the single-launch gauge)
    n_forced_staleness: int = 0  # ticks forced by a pull at the bound
    n_forced_capacity: int = 0  # ticks forced by a full push queue
    n_forced_replan: int = 0  # ticks forced to drain TOUCHED jobs on a replan
    n_per_job_dispatch: int = 0  # ticks dispatched as per-job passes (< K_min)
    n_replans: int = 0  # plan changes the engine rode through
    n_retagged: int = 0  # untouched pushes carried across a replan (fence)
    n_snapshots: int = 0  # last-good state copies taken (rollback anchors)
    n_rollbacks: int = 0  # failed applies recovered by snapshot restore
    n_replayed: int = 0  # applied pushes re-queued for replay by rollbacks
    n_quarantines: int = 0  # lanes that exhausted retries and stopped
    n_fleet_fallbacks: int = 0  # fused fleet failures replayed per-shard
    n_lease_expirations: int = 0  # jobs reclaimed by expire_leases (PR 9)
    # Wire accounting (PR 8).  Push bytes are counted at submit time with
    # the job's ``push_compression`` wire-size model (fp32 4 B/elem, bf16
    # 2, int8 1 + one fp32 scale per block); pull bytes count the payload
    # a pull shipped vs. what a full pull of the same slice costs.
    push_bytes_raw: int = 0  # fp32 bytes of every submitted push/piece
    push_bytes_wire: int = 0  # same pushes after each job's compression
    n_full_pulls: int = 0  # whole-slice pulls (incl. diff-pull fallbacks)
    n_diff_pulls: int = 0  # versioned pulls that shipped changed blocks only
    pull_bytes_wire: int = 0  # pull payload bytes actually shipped
    pull_bytes_full: int = 0  # what the same pulls cost as full pulls

    @property
    def mean_batch(self) -> float:
        """Mean jobs applied per tick (running counters, O(1) memory --
        the engine may tick for the service's whole lifetime)."""
        if not self.n_ticks:
            return 0.0
        return self.n_applied / self.n_ticks


@dataclass(frozen=True)
class PullVersion:
    """Opaque version vector one versioned pull returns: the plan epoch
    it was taken under plus one monotone version per owned block of the
    job (packed layout order, shard order for sharded jobs).  Hand it
    back as ``since_version`` to receive only the blocks that changed."""

    epoch: int
    versions: np.ndarray  # int64, one per owned block, layout order


@dataclass(frozen=True)
class PullDiff:
    """Result of ``pull(job_id, since_version=...)`` -- the SNIPPETS.md
    parameter-diff shape: only the owned blocks whose version moved past
    the client's vector, plus the new vector to hand back next time.

    ``full=True`` is the fallback (first pull, plan-epoch mismatch, or a
    stale/mismatched vector): ``data`` is the whole packed job vector.
    Otherwise ``data`` is ``(k, block)`` changed rows and ``block_ids``
    their job-local packed block indices; :meth:`apply` patches them onto
    the client's previous packed vector.  ``bytes_wire`` is what this
    pull shipped under the fp32 wire model, ``bytes_full`` what a full
    pull would have."""

    job_id: str
    version: PullVersion
    full: bool
    block: int
    block_ids: np.ndarray  # job-local packed block rows; empty when full
    data: Any  # (packed_len,) when full, else (k, block) changed rows
    bytes_wire: int
    bytes_full: int

    def apply(self, prev_packed):
        """Patch this diff onto the client's previous packed vector and
        return the up-to-date packed vector."""
        if self.full:
            return self.data
        if self.block_ids.size == 0:
            return prev_packed
        rows = prev_packed.reshape(-1, self.block)
        return rows.at[jnp.asarray(self.block_ids)].set(
            self.data, unique_indices=True,
            indices_are_sorted=True).reshape(-1)


def _copy_state(state):
    """Deep copy of one state dict, device buffers COPIED (not aliased):
    a snapshot must survive the donated apply that may consume -- or a
    failed apply that may delete -- the live buffers, and a restored
    copy must leave the pristine snapshot available for the NEXT
    rollback (replay re-donates the restored buffers)."""
    return jax.tree_util.tree_map(
        lambda x: x.copy() if hasattr(x, "copy") else x, state)


# ------------------------------------------------ shared applier building
def _flat_job_hp(info) -> Tuple[float, float, float, float]:
    """(lr, b1, b2, eps) of one flat-runtime job (Adam knobs ride in
    ``step_opts`` on the unsharded runtime)."""
    so = info["step_opts"]
    return (float(info["lr"]), float(so.get("b1", 0.9)),
            float(so.get("b2", 0.999)), float(so.get("eps", 1e-8)))


def _sharded_job_hp(info) -> Tuple[float, float, float, float]:
    """(lr, b1, b2, eps) of one sharded-runtime job (first-class fields)."""
    return (float(info["lr"]), float(info["b1"]), float(info["b2"]),
            float(info["eps"]))


def _fused_tables(layouts, infos, hp_of, base_blocks=None):
    """Bake the trace-time tables one fused multi-job apply needs: the
    concatenated owned-block index table, per-entry packed block counts,
    and per-entry ``(lr, b1, b2, eps)`` columns.

    ONE builder for every applier in this module -- the flat engine, the
    per-shard lane applier, and the fleet tick all route through it.  The
    fleet passes ``base_blocks`` (each entry's shard base offset, in
    blocks, into the concatenated fleet view) so a shard-local block
    table rebases to global block ids; single-space appliers leave it 0.
    """
    if base_blocks is None:
        base_blocks = (0,) * len(layouts)
    block_idx = np.concatenate(
        [l.blocks.astype(np.int32) + np.int32(b)
         for l, b in zip(layouts, base_blocks)])
    job_sizes = tuple(int(l.blocks.size) for l in layouts)
    lr, b1, b2, eps = zip(*(hp_of(i) for i in infos))
    return block_idx, job_sizes, (lr, b1, b2, eps)


def _fused_state_update(state, gs, counts, *, block, block_idx, job_sizes,
                        hps, interpret):
    """ONE fused launch over one state dict: aggregation + Adam + the
    in-place block writes for flat/mu/nu together (PR 6) -- the three
    post-apply row scatters earlier engines ran are gone.  ``gs`` is the
    per-entry packed gradient sequence (concatenated once inside the op:
    this exact program shape is what the bit-exactness tests pin down);
    ``counts`` must already be usable as traced int32 scalars."""
    from repro.kernels.agg_adam import ops as agg_ops

    lr, b1, b2, eps = hps
    new_p, new_mu, new_nu = agg_ops.multi_job_adam_update_fused(
        state["flat"], gs, state["mu"], state["nu"], counts,
        block_idx=block_idx, job_sizes=job_sizes, block=block,
        lr=lr, b1=b1, b2=b2, eps=eps, wd=0.0, interpret=interpret)
    return dict(state, flat=new_p, mu=new_mu, nu=new_nu)


class ServiceTickEngine:
    """Batched executor for one :class:`ServiceRuntime`'s shared state.

    Created via :meth:`ServiceRuntime.attach_engine`.  The engine owns the
    per-job push queues and the compiled batched appliers; the runtime
    keeps owning plan + state (and migrates them on replans, draining this
    engine first).
    """

    MAX_APPLIERS = 32  # compiled programs per plan (one per job subset)

    def __init__(self, runtime, *, max_staleness: int = 1,
                 queue_capacity: Optional[int] = None, jit: bool = True,
                 interpret: Optional[bool] = None, min_batch_jobs: int = 3,
                 snapshot_interval: int = 8, max_apply_retries: int = 1,
                 fault_injector=None, retry_policy=None,
                 lease_interval: Optional[float] = None, clock=None):
        if max_staleness < 0:
            raise ValueError(f"max_staleness must be >= 0, got {max_staleness}")
        if snapshot_interval < 0:
            raise ValueError(
                f"snapshot_interval must be >= 0 (0 disables rollback "
                f"recovery), got {snapshot_interval}")
        if lease_interval is not None and lease_interval <= 0:
            raise ValueError(
                f"lease_interval must be > 0 (None disables leases), "
                f"got {lease_interval}")
        self.runtime = runtime
        self.max_staleness = int(max_staleness)
        self.queue_capacity = (self.max_staleness + 1 if queue_capacity is None
                               else int(queue_capacity))
        if self.queue_capacity < 1:
            raise ValueError("queue_capacity must be >= 1")
        # Batching crossover: with fewer than this many pending jobs a
        # tick dispatches per-job block passes -- the one-launch
        # concatenation only wins once enough jobs share the pass
        # (BENCH_service_tick.json measured batched LOSING at 2 jobs,
        # 0.71x, and winning from 4 up).  Result is identical either
        # way (disjoint blocks commute); this is a pure cost knob.
        self.min_batch_jobs = int(min_batch_jobs)
        # Fault tolerance: a last-good state copy every this many
        # applying ticks bounds both the copy overhead (amortized) and
        # the rollback window a failure can lose; 0 disables snapshots
        # (a jitted exec failure then quarantines immediately, since the
        # donated buffers are unrecoverable).
        self.snapshot_interval = int(snapshot_interval)
        # Apply-retry schedule: ``retry_policy`` (repro.ps.faults
        # .RetryPolicy) wins over the legacy ``max_apply_retries`` count;
        # the attribute is kept in sync for introspection.
        if retry_policy is None:
            from repro.ps.faults import RetryPolicy

            retry_policy = RetryPolicy(max_retries=int(max_apply_retries))
        self.retry_policy = retry_policy
        self.max_apply_retries = int(retry_policy.max_retries)
        self.fault_injector = fault_injector
        # Job leases: pushes/pulls renew; ``expire_leases()`` reclaims
        # jobs whose trainers went silent.  ``clock`` is injectable so
        # chaos tests drive expiry deterministically.
        self.lease_interval = (None if lease_interval is None
                               else float(lease_interval))
        self._clock = clock if clock is not None else time.monotonic
        self._leases: Dict[str, float] = {}  # job -> expiry deadline
        self.stats = TickStats()
        self.health = HEALTHY
        self.quarantine_error: Optional[EngineQuarantinedError] = None
        self._snapshot = None  # (state copy, counts-mirror copy)
        self._snapshot_log: List[Tuple] = []  # (job, packed, fut) applied
        self._ticks_since_snapshot = 0
        self._failures = 0  # consecutive failed applies (reset on success)
        self._jit = jit
        self._interpret = interpret  # None = auto (jnp path off-TPU)
        self._epoch = 0  # bumped per plan change; fences queued pushes
        self._queues: Dict[str, deque] = {}
        # Diff-pull version tracking (PR 8): one monotone version per
        # ``block_align`` block of the flat space, stamped host-side on
        # every applying tick.  Reset on plan changes -- the version
        # vector carries the epoch, so stale clients fall back to a full
        # pull instead of misreading restarted versions.
        self._block_versions: Optional[np.ndarray] = None
        self._version_clock = 0
        # Python-side mirror of state["counts"]: futures resolve from it
        # without a device round-trip per tick.
        self._counts: Dict[str, int] = {}
        # Compiled caches, invalidated on every replan.
        self._appliers: Dict[Tuple[str, ...], Callable] = {}
        self._pull_fns: Dict[str, Callable] = {}
        self._grad_fns: Dict[str, Callable] = {}
        self._pack_fns: Dict[str, Callable] = {}
        # Read tier (PR 10): a ReplicaSet registers itself here and gets
        # offered a publishable snapshot every applying tick.
        self._replica_hub = None

    # ------------------------------------------------------------- plumbing
    @property
    def plan(self) -> Optional[FlatPlan]:
        return self.runtime.plan

    def _queue(self, job_id: str) -> deque:
        info = self.runtime._jobs.get(job_id)
        if info is None:
            raise ValueError(f"unknown job {job_id!r}: not registered with "
                             f"the runtime (have {sorted(self.runtime._jobs)})")
        if (info["step_opts"].get("push_compression")
                and "ef" not in self.runtime.state):
            # A job turned compressed after the state was built (e.g. a
            # restore from a pre-compression checkpoint): widen the state
            # with a zero error-feedback buffer -- exactly what the
            # runtime's replan path does when a compressed job joins.
            self.runtime.state = dict(
                self.runtime.state,
                ef=jnp.zeros_like(self.runtime.state["flat"]))
        if job_id not in self._counts:
            # One sync at first contact; ticks keep the mirror in step.
            self._counts[job_id] = int(jax.device_get(
                self.runtime.state["counts"][job_id]))
        self._renew_lease(job_id)
        return self._queues.setdefault(job_id, deque())

    def outstanding(self, job_id: str) -> int:
        """Pushes submitted by the job but not yet applied by a tick."""
        q = self._queues.get(job_id)
        return len(q) if q else 0

    # --------------------------------------------------------------- leases
    def _renew_lease(self, job_id: str) -> None:
        if self.lease_interval is not None:
            self._leases[job_id] = self._clock() + self.lease_interval

    def lease_deadline(self, job_id: str) -> Optional[float]:
        """The job's current lease expiry (None: leases off / no contact)."""
        return self._leases.get(job_id)

    def expire_leases(self) -> Tuple[str, ...]:
        """Reclaim every job whose lease has lapsed; returns their ids.

        Every push/pull renews the submitting job's lease, so only a
        trainer that went SILENT for a full ``lease_interval`` expires.
        Reclaim is graceful: queued pieces are cancelled with a
        contextual :class:`~repro.ps.faults.LeaseExpiredError` (held
        futures re-raise it), then the job leaves through
        ``runtime.remove_job`` -- i.e. the transactional replan path --
        so its space frees and the autoscaler sees the load drop.  If
        that replan itself aborts, the lease is re-armed one interval
        out and the reclaim retries at the next ``expire_leases()``."""
        if self.lease_interval is None:
            return ()
        now = self._clock()
        expired = tuple(sorted(
            j for j, deadline in self._leases.items()
            if deadline <= now and j in self.runtime._jobs))
        for job_id in expired:
            err = LeaseExpiredError(job_id, self._leases[job_id], now)
            q = self._queues.get(job_id)
            if q:
                for _, fut, _ in q:
                    if fut is not None:
                        fut._cancel(str(err), exc=err)
                q.clear()
            self._leases.pop(job_id, None)
            self.stats.n_lease_expirations += 1
            try:
                self.runtime.remove_job(job_id)
            except Exception:
                # Reclaim replan failed: re-arm the lease so the next
                # sweep retries instead of leaking the job forever.
                self._leases[job_id] = now + self.lease_interval
                raise
        return expired

    def quiesce_for_replan(self, touched) -> int:
        """Drain ONLY the touched jobs' queues ahead of a migration.

        Their queued pushes apply against the OLD plan (their layout is
        about to change); untouched jobs' queues -- and tick cadence --
        are left alone.  Returns pushes applied."""
        applied = 0
        while True:
            pending = [j for j in touched if self._queues.get(j)]
            if not pending:
                return applied
            self.stats.n_forced_replan += 1
            applied += self.tick(only=pending)

    def _on_plan_change(self, touched=None) -> None:
        """Replan landed: invalidate what the new plan breaks.

        ``touched=None`` (full quiesce: first plan, last exit, or a
        gather-path migration) drops every compiled structure and
        requires every queue empty.  With a delta's touched set, only
        the touched jobs' programs die; untouched jobs keep queues and
        compiled programs -- their layout is bit-identical in the new
        plan -- and their surviving pushes are re-tagged to the new
        epoch (the fence that proves no push crosses layouts)."""
        self._epoch += 1
        self.stats.n_replans += 1
        # A snapshot is a copy of the PRE-migration layout: restoring it
        # after the plan changed would resurrect dead geometry.  Drop it
        # (and its replay log); the rollback window restarts under the
        # new plan at the next applying tick.
        self._snapshot = None
        self._snapshot_log = []
        self._ticks_since_snapshot = 0
        # Block versions index the OLD geometry; the epoch bump already
        # invalidates every held PullVersion, so restart the vector.
        self._block_versions = None
        if self._replica_hub is not None:
            # Read-tier snapshots hold the old geometry too; the epoch
            # fence marks them stale and the next serve resubscribes.
            self._replica_hub.on_replan()
        if touched is None:
            assert not any(self._queues.values()), (
                "replan with queued pushes: runtime must drain the "
                "engine first")
            self._appliers.clear()
            self._pull_fns.clear()
            self._grad_fns.clear()
            self._pack_fns.clear()
            return
        touched = set(touched)
        for j in touched:
            assert not self._queues.get(j), (
                f"replan with queued pushes for TOUCHED job {j!r}: "
                f"quiesce_for_replan must drain it first")
        for j, q in self._queues.items():
            if q:  # untouched by construction: carry across the fence
                self.stats.n_retagged += len(q)
                self._queues[j] = deque(
                    (packed, fut, self._epoch) for packed, fut, _ in q)
        for j in touched:
            self._pull_fns.pop(j, None)
            self._grad_fns.pop(j, None)
            self._pack_fns.pop(j, None)
        self._appliers = {k: v for k, v in self._appliers.items()
                         if not touched.intersection(k)}

    def _forget_job(self, job_id: str) -> None:
        q = self._queues.pop(job_id, None)
        if q:
            # remove_job quiesces first, so a surviving push means the
            # drain was bypassed; cancel so held futures raise cleanly
            # instead of forcing ticks forever on an unknown job.
            for _, fut, _ in q:
                if fut is not None:
                    fut._cancel("job removed from the runtime with this "
                                "push still queued (drain was bypassed)")
        self._snapshot_log = [e for e in self._snapshot_log
                              if e[0] != job_id]
        self._counts.pop(job_id, None)
        self._leases.pop(job_id, None)
        self._pull_fns.pop(job_id, None)
        self._grad_fns.pop(job_id, None)
        self._pack_fns.pop(job_id, None)
        # Appliers embedding the job die with the next plan change, which
        # the runtime triggers right after; drop them eagerly anyway.
        self._appliers = {k: v for k, v in self._appliers.items()
                         if job_id not in k}

    # ------------------------------------------------------------ data path
    def pull(self, job_id: str, since_version=None):
        """The job's current parameters from the shared space.

        Bounded staleness: a job ``max_staleness`` steps ahead of the
        service blocks here -- the pull forces ticks until the job is back
        within the bound (one tick applies one queued push, so one
        suffices unless other jobs' queues run deeper).

        ``since_version`` switches to the VERSIONED DIFF protocol: pass
        the :class:`PullVersion` a previous versioned pull returned (or
        ``0`` to bootstrap) and get a :class:`PullDiff` holding only the
        owned blocks whose version moved, plus the new vector.  A stale
        or cross-epoch vector falls back to a full-payload diff; plain
        (``None``) pulls keep returning the parameter pytree."""
        if self.health == QUARANTINED:
            # No fallback: the state froze at the last-good snapshot and
            # will never advance, so serving it as if live would feed the
            # trainer silently stale parameters.  Read-tier replicas
            # (repro.ps.replica) are the degraded-serving path.
            raise self.quarantine_error
        self._queue(job_id)  # validates the job id
        while self.outstanding(job_id) > self.max_staleness:
            self.stats.n_forced_staleness += 1
            self.tick()
        if since_version is not None:
            return self._pull_versioned(job_id, since_version)
        layout = self.plan.job_layout(job_id)
        self.stats.n_full_pulls += 1
        self.stats.pull_bytes_wire += 4 * layout.packed_len
        self.stats.pull_bytes_full += 4 * layout.packed_len
        fn = self._pull_fns.get(job_id)
        if fn is None:
            plan = self.plan
            layout = plan.job_layout(job_id)
            abstract = self.runtime._jobs[job_id]["abstract"]
            rows = jnp.asarray(layout.blocks)

            def fn(flat, _layout=layout, _rows=rows, _abstract=abstract):
                packed = (flat if _layout.covers_all else
                          flat.reshape(-1, _layout.block)[_rows].reshape(-1))
                return _unpack_slots(_layout, packed, _abstract)

            if self._jit:
                fn = jax.jit(fn)
            self._pull_fns[job_id] = fn
        return fn(self.runtime.state["flat"])

    # ----------------------------------------------------- versioned pulls
    def _versions_array(self) -> np.ndarray:
        plan = self.plan
        nb = plan.total_len // plan.block_align
        if self._block_versions is None or self._block_versions.size != nb:
            self._block_versions = np.zeros(nb, np.int64)
        return self._block_versions

    def _stamp_blocks(self, jobs) -> None:
        """Advance the version clock and stamp every given job's owned
        blocks -- called once per applying tick (and on rollback, so a
        rewound block can never look unchanged to a diff client)."""
        if self.plan is None or not jobs:
            return
        versions = self._versions_array()
        self._version_clock += 1
        for j in jobs:
            versions[np.asarray(self.plan.job_layout(j).blocks)] = \
                self._version_clock

    def _pull_versioned(self, job_id: str, since) -> PullDiff:
        plan = self.plan
        layout = plan.job_layout(job_id)
        blocks = np.asarray(layout.blocks)
        vers = self._versions_array()[blocks].copy()
        version = PullVersion(epoch=self._epoch, versions=vers)
        bytes_full = 4 * layout.packed_len
        flat = self.runtime.state["flat"]
        full = (not isinstance(since, PullVersion)
                or since.epoch != self._epoch
                or since.versions.size != vers.size)
        if full:
            data = _gather_owned(layout, flat)
            diff = PullDiff(
                job_id=job_id, version=version, full=True,
                block=layout.block, block_ids=np.empty(0, np.int64),
                data=data, bytes_wire=bytes_full, bytes_full=bytes_full)
            self.stats.n_full_pulls += 1
        else:
            sel = np.nonzero(vers > since.versions)[0]
            if sel.size:
                data = flat.reshape(-1, layout.block)[
                    jnp.asarray(blocks[sel])]
            else:
                data = jnp.zeros((0, layout.block), flat.dtype)
            diff = PullDiff(
                job_id=job_id, version=version, full=False,
                block=layout.block, block_ids=sel.astype(np.int64),
                data=data, bytes_wire=4 * int(sel.size) * layout.block,
                bytes_full=bytes_full)
            self.stats.n_diff_pulls += 1
        self.stats.pull_bytes_wire += diff.bytes_wire
        self.stats.pull_bytes_full += bytes_full
        return diff

    def submit_push(self, job_id: str, grads) -> PushFuture:
        """Queue a job's gradient pytree for the next tick; returns a
        future.  A full queue exerts backpressure: the submit first forces
        ticks until a slot frees up."""
        q = self._queue(job_id)
        while len(q) >= self.queue_capacity:
            self.stats.n_forced_capacity += 1
            self.tick()
        fn = self._pack_fns.get(job_id)
        if fn is None:
            layout = self.plan.job_layout(job_id)
            fn = (lambda grads, _layout=layout:
                  _pack_slots(_layout, grads))
            if self._jit:
                fn = jax.jit(fn)
            self._pack_fns[job_id] = fn
        return self.submit_packed(job_id, fn(grads))

    def submit_packed(self, job_id: str, packed) -> PushFuture:
        """Queue an ALREADY-PACKED job-local gradient vector (the layout's
        packed domain, e.g. from a custom jitted grad program) for the
        next tick; same bounded queue and backpressure as
        :meth:`submit_push`."""
        q = self._queue(job_id)
        while len(q) >= self.queue_capacity:
            self.stats.n_forced_capacity += 1
            self.tick()
        return self._enqueue(q, job_id, packed)

    def _enqueue(self, q: deque, job_id: str, packed) -> PushFuture:
        fut = PushFuture(job_id, self)
        # Wire accounting: what this push costs as fp32 vs. under the
        # job's compression (bytes are spent whether or not the injector
        # later drops the push -- it models loss IN transit).
        n = int(packed.size)
        kind = self.runtime._jobs[job_id]["step_opts"].get("push_compression")
        self.stats.push_bytes_raw += 4 * n
        self.stats.push_bytes_wire += wire_bytes(n, kind)
        action = ("deliver" if self.fault_injector is None
                  else self.fault_injector.on_push(job_id, None))
        if action != "drop":
            q.append((packed, fut, self._epoch))
            if action == "duplicate":
                # An at-least-once delivery bug: the copy applies as an
                # extra, untracked push (fut=None -- nothing to resolve).
                q.append((packed, None, self._epoch))
        return fut

    def step(self, job_id: str, batch) -> Dict[str, Any]:
        """One engine-mode iteration: pull (staleness-bounded), compute
        loss/grads, submit the push.  The update lands at a later tick;
        ``metrics["future"]`` tracks it."""
        q = self._queue(job_id)
        while self.outstanding(job_id) > self.max_staleness:
            self.stats.n_forced_staleness += 1
            self.tick()
        while len(q) >= self.queue_capacity:
            self.stats.n_forced_capacity += 1
            self.tick()
        fn = self._grad_fns.get(job_id)
        if fn is None:
            plan = self.plan
            layout = plan.job_layout(job_id)
            info = self.runtime._jobs[job_id]
            abstract, loss_fn = info["abstract"], info["loss_fn"]
            rows = jnp.asarray(layout.blocks)

            def fn(flat, batch, _layout=layout, _rows=rows,
                   _abstract=abstract, _loss=loss_fn):
                packed = (flat if _layout.covers_all else
                          flat.reshape(-1, _layout.block)[_rows].reshape(-1))
                params = _unpack_slots(_layout, packed, _abstract)
                loss, grads = jax.value_and_grad(_loss)(params, batch)
                return loss, _pack_slots(_layout, grads)

            if self._jit:
                fn = jax.jit(fn)
            self._grad_fns[job_id] = fn
        loss, packed = fn(self.runtime.state["flat"], batch)
        return {"loss": loss, "future": self._enqueue(q, job_id, packed)}

    # ----------------------------------------------------------------- tick
    def tick(self, only=None) -> int:
        """One service tick: pop the head push of every pending job (or
        of the ``only`` subset during a replan quiesce) and apply them --
        in ONE batched pass when at least ``min_batch_jobs`` jobs are
        pending, as per-job block passes below that crossover (identical
        result, cheaper program).  Returns the number of jobs applied
        (0 = nothing pending)."""
        if self.health == QUARANTINED:
            raise self.quarantine_error
        pending = [j for j in self.runtime._jobs
                   if self._queues.get(j) and (only is None or j in only)]
        if not pending:
            return 0
        # Epoch fence: a queued push packed under a different plan epoch
        # must never reach the apply -- touched jobs are drained before
        # the plan changes and untouched survivors are re-tagged, so a
        # mismatch here is a protocol violation, not a recoverable state.
        for j in pending:
            if self._queues[j][0][2] != self._epoch:
                raise RuntimeError(
                    f"epoch fence: job {j!r} queued a push under plan "
                    f"epoch {self._queues[j][0][2]} but the engine is at "
                    f"{self._epoch}; a replan migrated this job's layout "
                    f"without draining its queue")
        if 1 < len(pending) < self.min_batch_jobs:
            # Below the batching crossover: the same pushes as per-job
            # passes (disjoint blocks commute, so the result is
            # bit-identical to the one-launch concatenation).
            groups = [(j,) for j in pending]
            self.stats.n_per_job_dispatch += 1
        else:
            groups = [tuple(pending)]
        # Refresh the lane snapshot BEFORE any donated apply can consume
        # the live buffers (queues are still intact, so the snapshot plus
        # the -- now empty -- replay log reconstructs this exact moment).
        snapped = self._maybe_snapshot()
        if self._replica_hub is not None:
            # Publish point for the read tier, co-located with the
            # rollback snapshot: on a refresh tick the hub rides the copy
            # just taken instead of making its own.
            self._replica_hub.on_tick(None, snapped)
        applied = 0
        for key in groups:
            heads = [self._queues[j].popleft() for j in key]
            try:
                applier = self._appliers.get(key)
                if applier is None:
                    applier = self._build_applier(key)
                    if len(self._appliers) >= self.MAX_APPLIERS:
                        # One program per pending-job SUBSET: bound the
                        # cache (FIFO eviction) so heterogeneous tick
                        # patterns can't accumulate 2^K compiled appliers.
                        self._appliers.pop(next(iter(self._appliers)))
                    self._appliers[key] = applier
                gs = tuple(packed for packed, _, _ in heads)
            except BaseException:
                # Build-time failure (e.g. a non-block-exclusive layout):
                # no device op ran, so re-queue the popped heads --
                # nothing is lost and a later tick can retry.
                for j, head in zip(key, heads):
                    self._queues[j].appendleft(head)
                raise
            try:
                if self.fault_injector is not None:
                    self.fault_injector.on_apply(None)
                self.runtime.state = applier(self.runtime.state, gs)
            except BaseException as exc:
                # Execution failure: the jitted applier DONATES the state
                # buffers, so they may already be deleted.  Re-queue the
                # heads, then roll the lane back to its last-good
                # snapshot and replay (or quarantine when retries are
                # exhausted / no snapshot exists) -- the rollback undoes
                # every group this tick already applied, so nothing from
                # this tick survives.
                for j, head in zip(key, heads):
                    self._queues[j].appendleft(head)
                self._handle_apply_failure(exc, key)
                self.stats.n_ticks += 1
                return 0
            self._failures = 0
            for j, (packed, fut, _) in zip(key, heads):
                self._counts[j] += 1
                if fut is not None:
                    fut._resolve(self._counts[j])
                self._snapshot_log.append((j, packed, fut))
            applied += len(key)
        self._stamp_blocks(pending)  # diff-pull clients see these as dirty
        self.stats.n_ticks += 1
        self.stats.n_applied += applied
        self.stats.n_launches += len(groups)
        self._ticks_since_snapshot += 1
        return applied

    # ------------------------------------------------------- fault recovery
    def _maybe_snapshot(self) -> bool:
        """Copy (state, counts mirror) as the rollback anchor, every
        ``snapshot_interval`` applying ticks, BEFORE the donated apply.
        Returns True when the anchor was refreshed this call (the read
        tier reuses its fresh copy instead of taking another)."""
        if self.snapshot_interval <= 0:
            return False
        if (self._snapshot is None
                or self._ticks_since_snapshot >= self.snapshot_interval):
            self._snapshot = (_copy_state(self.runtime.state),
                              dict(self._counts))
            self._snapshot_log = []
            self._ticks_since_snapshot = 0
            self.stats.n_snapshots += 1
            return True
        return False

    def _rollback(self) -> None:
        """Restore the last-good snapshot and re-queue the logged pushes
        IN FRONT of whatever is queued (per-job order preserved), so
        subsequent ticks replay the identical sequence.  Replayed
        futures ride along un-resolved-if-pending / kept-done-if-done;
        the snapshot itself stays pristine for a repeated rollback."""
        state_copy, counts_copy = self._snapshot
        self.runtime.state = _copy_state(state_copy)
        self._counts = dict(counts_copy)
        # The restore REWOUND every block the logged pushes had touched:
        # re-stamp them so a diff-pull client who saw the undone values
        # is told those blocks changed (versions only move forward).
        self._stamp_blocks({j for j, _, _ in self._snapshot_log})
        for j, packed, fut in reversed(self._snapshot_log):
            if fut is not None:
                fut._unresolve()
            self._queues.setdefault(j, deque()).appendleft(
                (packed, fut, self._epoch))
            self.stats.n_replayed += 1
        self._snapshot_log = []
        self._ticks_since_snapshot = 0
        self.stats.n_rollbacks += 1

    def _handle_apply_failure(self, exc: BaseException, key) -> None:
        """Roll back and return (the tick swallows the failure; later
        ticks replay), or quarantine/re-raise when recovery is off the
        table."""
        self._failures += 1
        can_roll = self._snapshot is not None
        if can_roll and self.retry_policy.should_retry(self._failures):
            self.retry_policy.backoff(self._failures)
            self._rollback()
            return
        if can_roll:
            self._rollback()  # leave last-good state installed
        elif not self._jit:
            # Eager with snapshots disabled: nothing was donated, the
            # state is intact -- surface the raw error, caller may retry.
            raise exc
        self.health = QUARANTINED
        self.quarantine_error = EngineQuarantinedError(
            shard_id=None, tick=self.stats.n_ticks, job_ids=key,
            original=exc)
        self.stats.n_quarantines += 1
        raise self.quarantine_error from exc

    def _stall_error(self, job_id: str) -> Optional[Exception]:
        """Why a zero-progress tick round cannot resolve this job's push:
        an exception to raise, or None when progress is still possible
        (e.g. a rollback just re-queued the work)."""
        if self.health == QUARANTINED:
            return self.quarantine_error
        if self._queues.get(job_id):
            return None
        return RuntimeError(
            f"push for job {job_id!r} can never resolve: no queued push "
            f"remains for it (piece dropped in transit?)")

    def drain(self, only=None) -> int:
        """Quiesce: tick until every (selected) queue is empty.  Returns
        pushes applied.  A tick round may legitimately apply nothing
        while a rollback replays, so the loop only stops when the
        selected queues are actually empty; a quarantined engine raises
        :class:`~repro.ps.faults.EngineQuarantinedError` out of
        ``tick``."""
        applied = 0
        while True:
            n = self.tick(only=only)
            applied += n
            if n:
                continue
            if not any(q for j, q in self._queues.items()
                       if only is None or j in only):
                return applied

    def _build_applier(self, job_ids: Tuple[str, ...]) -> Callable:
        """Compile the batched apply for one combination of pending jobs.

        All plan-derived structures (concatenated owned-block table,
        per-job packed sizes, hyperparameters) are baked in at build time;
        the returned function is (state, packed_grads) -> state with ONE
        fused launch writing the updated flat/mu/nu blocks in place --
        no separate row-scatter passes (PR 6).
        """
        plan = self.plan
        layouts = [plan.job_layout(j) for j in job_ids]
        infos = [self.runtime._jobs[j] for j in job_ids]
        block_idx, job_sizes, hps = _fused_tables(layouts, infos,
                                                  _flat_job_hp)
        block, interpret = plan.block_align, self._interpret
        # Compressed-push jobs (PR 8): each gets the EF transform against
        # its owned rows of the shared error-feedback buffer before the
        # fused update.  ``compressed`` is empty for the common case, and
        # that branch's program is IDENTICAL to the pre-compression
        # applier -- the parity tests pin this down.
        compressed = [(i, kind, layouts[i])
                      for i, info in enumerate(infos)
                      if (kind := info["step_opts"].get("push_compression"))]

        def apply(state, gs):
            counts = [state["counts"][j] + 1 for j in job_ids]
            if compressed:
                ef = state.get("ef")
                if ef is None:
                    # A rollback can restore a snapshot that predates the
                    # ef widening; the buffer was all-zero back then.
                    ef = jnp.zeros_like(state["flat"])
                gs = list(gs)
                for i, kind, layout in compressed:
                    gs[i], resid = ef_transform(
                        gs[i], _gather_owned(layout, ef), kind)
                    ef = _scatter_owned(layout, ef, resid)
                gs = tuple(gs)
            new_state = _fused_state_update(
                state, gs, counts, block=block, block_idx=block_idx,
                job_sizes=job_sizes, hps=hps, interpret=interpret)
            if compressed:
                new_state["ef"] = ef
            new_state["counts"] = dict(
                state["counts"], **{j: c for j, c in zip(job_ids, counts)})
            return new_state

        # Donate the shared state: flat/mu/nu update in place per tick.
        return jax.jit(apply, donate_argnums=(0,)) if self._jit else apply


# --------------------------------------------------------------- sharded
class _ShardLane:
    """One shard space's service loop state: its own queues, compiled
    appliers, TickStats -- and now its own health, rollback snapshot,
    and replay log (the unit of independent cadence is also the unit of
    failure isolation)."""

    __slots__ = ("shard_id", "queues", "appliers", "stats", "health",
                 "quarantine_error", "snapshot", "log",
                 "ticks_since_snapshot", "failures", "versions")

    def __init__(self, shard_id: str):
        self.shard_id = shard_id
        self.queues: Dict[str, deque] = {}  # job -> (piece, count, fut, ep)
        self.appliers: Dict[Tuple[str, ...], Callable] = {}
        self.stats = TickStats()
        self.health = HEALTHY
        self.quarantine_error: Optional[EngineQuarantinedError] = None
        self.snapshot = None  # last-good copy of this shard's state
        self.log: List[Tuple] = []  # (job, piece, count, fut) since copy
        self.ticks_since_snapshot = 0
        self.failures = 0  # consecutive failed applies (reset on success)
        self.versions: Optional[np.ndarray] = None  # per-block, diff pulls


class ShardedTickEngine:
    """Per-shard batched executor for one :class:`ShardedServiceRuntime`.

    Where :class:`ServiceTickEngine` runs ONE tick loop over one shared
    space, this engine runs one independent loop PER SHARD SPACE
    (``tick_shard``): a hot shard ticking fast never stalls a cold one,
    and the autoscaler reads each lane's :class:`TickStats` as its load
    signal.  A job's push splits into one packed PIECE per hosting shard,
    each tagged with the job's global step count at submit time -- Adam is
    elementwise, and each lane applies a job's pieces FIFO, so every lane
    preserves its lanes' per-element ``(gradient, step)`` sequence and the
    trajectory stays bit-exact with the unsharded engine no matter how
    shard cadences interleave.  ``tick()`` runs one round over every lane
    (the BSP convenience); staleness/capacity bounds are per job, taken
    over its hosting lanes.

    Replans reuse the flat engine's protocol: the runtime quiesces ONLY
    the jobs the sharded transition touches, surviving pushes are
    re-tagged across the per-push epoch fence, and lanes are keyed by the
    stable ``agg_id`` so an untouched job's queues and compiled programs
    ride straight through a neighboring shard's split or merge.

    ``fleet_tick`` selects how :meth:`tick` dispatches a round (PR 6):
    ``"fused"`` (the default) runs ONE fused launch over every lane with
    pending pieces -- the lanes' flat/mu/nu concatenate into one fleet
    view, the multi-job kernel runs once with globally-rebased block ids,
    and per-shard states slice back out -- while ``"per_shard"`` keeps
    the PR-5 one-launch-group-per-lane loop as a bit-parity oracle.  The
    attribute is mutable on purpose (benchmarks flip one engine between
    modes; the two paths keep separate applier caches).  Per-element math
    is identical either way, so the trajectories match bit-for-bit in
    eager mode.
    """

    MAX_APPLIERS = 32  # compiled programs per lane (one per job subset)

    def __init__(self, runtime, *, max_staleness: int = 1,
                 queue_capacity: Optional[int] = None, jit: bool = True,
                 interpret: Optional[bool] = None, min_batch_jobs: int = 3,
                 fleet_tick: str = "fused", snapshot_interval: int = 8,
                 max_apply_retries: int = 1, fault_injector=None,
                 retry_policy=None, lease_interval: Optional[float] = None,
                 clock=None):
        if max_staleness < 0:
            raise ValueError(f"max_staleness must be >= 0, got {max_staleness}")
        if fleet_tick not in ("fused", "per_shard"):
            raise ValueError(f"fleet_tick must be 'fused' or 'per_shard', "
                             f"got {fleet_tick!r}")
        if snapshot_interval < 0:
            raise ValueError(
                f"snapshot_interval must be >= 0 (0 disables rollback "
                f"recovery), got {snapshot_interval}")
        if lease_interval is not None and lease_interval <= 0:
            raise ValueError(
                f"lease_interval must be > 0 (None disables leases), "
                f"got {lease_interval}")
        self.runtime = runtime
        self.max_staleness = int(max_staleness)
        self.queue_capacity = (self.max_staleness + 1 if queue_capacity is None
                               else int(queue_capacity))
        if self.queue_capacity < 1:
            raise ValueError("queue_capacity must be >= 1")
        self.min_batch_jobs = int(min_batch_jobs)
        self.fleet_tick = fleet_tick
        # Per-LANE rollback anchors (see ServiceTickEngine): each shard
        # lane copies its state every this many of its own applying
        # ticks, so one shard's failure rolls back (and quarantines) that
        # lane alone.
        self.snapshot_interval = int(snapshot_interval)
        # Shared retry schedule (see ServiceTickEngine): retry_policy
        # wins over the legacy max_apply_retries count.
        if retry_policy is None:
            from repro.ps.faults import RetryPolicy

            retry_policy = RetryPolicy(max_retries=int(max_apply_retries))
        self.retry_policy = retry_policy
        self.max_apply_retries = int(retry_policy.max_retries)
        self.fault_injector = fault_injector
        # Job leases (see ServiceTickEngine.expire_leases).
        self.lease_interval = (None if lease_interval is None
                               else float(lease_interval))
        self._clock = clock if clock is not None else time.monotonic
        self._leases: Dict[str, float] = {}  # job -> expiry deadline
        self.stats = TickStats()  # fleet-aggregate counters
        self._jit = jit
        self._interpret = interpret
        self._epoch = 0
        self._version_clock = 0  # fleet-wide monotone diff-pull clock
        self._lanes: Dict[str, _ShardLane] = {}
        self._counts: Dict[str, int] = {}  # job step mirror (submit time)
        # Fleet appliers are keyed by the whole pending pattern
        # ((shard_id, jobs), ...) -- separate from the per-lane caches.
        self._fleet_appliers: Dict[Tuple, Callable] = {}
        self._pull_fns: Dict[str, Callable] = {}
        self._grad_fns: Dict[str, Callable] = {}
        self._pack_fns: Dict[str, Callable] = {}
        # Read tier (PR 10): a ReplicaSet registers itself here and gets
        # offered each ticking lane for publication.
        self._replica_hub = None

    # ------------------------------------------------------------- plumbing
    @property
    def plan(self):
        return self.runtime.splan

    def _lane(self, shard_id: str) -> _ShardLane:
        lane = self._lanes.get(shard_id)
        if lane is None:
            lane = self._lanes[shard_id] = _ShardLane(shard_id)
        return lane

    def _layout(self, job_id: str):
        info = self.runtime._jobs.get(job_id)
        if info is None:
            raise ValueError(f"unknown job {job_id!r}: not registered with "
                             f"the runtime (have {sorted(self.runtime._jobs)})")
        layout = self.plan.job_layout(job_id)
        if info.get("step_opts", {}).get("push_compression"):
            # Late-arriving compression (e.g. a restore from a
            # pre-compression checkpoint): widen each hosting shard's
            # state with a zero error-feedback buffer, mirroring the
            # runtime's replan-time widening.
            for sid in layout.shard_ids:
                st = self.runtime.states[sid]
                if "ef" not in st:
                    self.runtime.states[sid] = dict(
                        st, ef=jnp.zeros_like(st["flat"]))
        if job_id not in self._counts:
            self._counts[job_id] = int(jax.device_get(
                self.runtime.counts[job_id]))
        self._renew_lease(job_id)
        return layout

    # --------------------------------------------------------------- leases
    def _renew_lease(self, job_id: str) -> None:
        if self.lease_interval is not None:
            self._leases[job_id] = self._clock() + self.lease_interval

    def lease_deadline(self, job_id: str) -> Optional[float]:
        """The job's current lease expiry (None: leases off / no contact)."""
        return self._leases.get(job_id)

    def expire_leases(self) -> Tuple[str, ...]:
        """Reclaim every job whose lease has lapsed; returns their ids.

        Identical contract to :meth:`ServiceTickEngine.expire_leases`,
        with the job's queued PIECES cancelled on every hosting lane
        before the job leaves through the transactional replan path."""
        if self.lease_interval is None:
            return ()
        now = self._clock()
        expired = tuple(sorted(
            j for j, deadline in self._leases.items()
            if deadline <= now and j in self.runtime._jobs))
        for job_id in expired:
            err = LeaseExpiredError(job_id, self._leases[job_id], now)
            for lane in self._lanes.values():
                q = lane.queues.get(job_id)
                if q:
                    for _, _, fut, _ in q:
                        if fut is not None:
                            fut._cancel(str(err), exc=err)
                    q.clear()
            self._leases.pop(job_id, None)
            self.stats.n_lease_expirations += 1
            try:
                self.runtime.remove_job(job_id)
            except Exception:
                self._leases[job_id] = now + self.lease_interval
                raise
        return expired

    def outstanding(self, job_id: str) -> int:
        """Deepest per-shard queue of the job's not-yet-applied pieces."""
        deepest = 0
        for lane in self._lanes.values():
            q = lane.queues.get(job_id)
            if q:
                deepest = max(deepest, len(q))
        return deepest

    def shard_stats(self) -> Dict[str, TickStats]:
        """Per-shard TickStats (the autoscaler's load signal)."""
        return {sid: lane.stats for sid, lane in self._lanes.items()}

    # ---------------------------------------------------------- lane health
    def shard_health(self) -> Dict[str, str]:
        """Per-lane health: ``'healthy'`` or ``'quarantined'`` (the
        autoscaler refuses to resize a fleet with a quarantined lane)."""
        return {sid: lane.health for sid, lane in self._lanes.items()}

    def quarantined_shards(self) -> Tuple[str, ...]:
        return tuple(sid for sid, lane in self._lanes.items()
                     if lane.health == QUARANTINED)

    def _quarantine_blocking(
            self, only=None) -> Optional[EngineQuarantinedError]:
        """The quarantine error blocking the given jobs (any job when
        None): set when a quarantined lane still holds matching queued
        pieces -- no amount of ticking will ever apply them."""
        for lane in self._lanes.values():
            if lane.health != QUARANTINED:
                continue
            if any(q and (only is None or j in only)
                   for j, q in lane.queues.items()):
                return lane.quarantine_error
        return None

    def _has_pending(self, only=None) -> bool:
        return any(q and (only is None or j in only)
                   for lane in self._lanes.values()
                   for j, q in lane.queues.items())

    def _stall_error(self, job_id: str) -> Optional[Exception]:
        """Why a zero-progress tick round cannot resolve this job's push:
        an exception to raise, or None when progress is still possible
        (e.g. a rollback just re-queued the replay)."""
        exc = self._quarantine_blocking((job_id,))
        if exc is not None:
            return exc
        if any(lane.queues.get(job_id) for lane in self._lanes.values()):
            return None
        return RuntimeError(
            f"push for job {job_id!r} can never resolve: no queued piece "
            f"remains for it on any lane (piece dropped in transit?)")

    # ------------------------------------------------------------ data path
    def pull(self, job_id: str, since_version=None):
        """The job's parameters gathered across its hosting shards, after
        forcing tick rounds down to the staleness bound.

        ``since_version`` switches to the VERSIONED DIFF protocol (see
        :meth:`ServiceTickEngine.pull`): a :class:`PullDiff` of only the
        owned blocks whose version moved since the client's
        :class:`PullVersion` -- versions concatenate over the hosting
        shards in shard order, matching the packed piece order."""
        layout = self._layout(job_id)
        for sid in layout.shard_ids:
            lane = self._lanes.get(sid)
            if lane is not None and lane.health == QUARANTINED:
                # A hosting lane froze at its last-good snapshot and will
                # never advance: raise its error instead of serving
                # silently stale parameters.  Read-tier replicas
                # (repro.ps.replica) are the degraded-serving path.
                raise lane.quarantine_error
        while self.outstanding(job_id) > self.max_staleness:
            self.stats.n_forced_staleness += 1
            if self.tick() == 0:
                stall = self._stall_error(job_id)
                if stall is not None:
                    # The backlog lives on a quarantined lane: forcing
                    # more ticks can never drain it.
                    raise stall
        if since_version is not None:
            return self._pull_versioned(job_id, layout, since_version)
        self.stats.n_full_pulls += 1
        self.stats.pull_bytes_wire += 4 * layout.packed_len
        self.stats.pull_bytes_full += 4 * layout.packed_len
        fn = self._pull_fns.get(job_id)
        if fn is None:
            abstract = self.runtime._jobs[job_id]["abstract"]
            rows = _layout_rows(layout)

            def fn(flats, _layout=layout, _rows=rows, _abstract=abstract):
                p = _gather_packed(_layout, _rows, flats)
                return _unpack_slots(_layout, p, _abstract)

            if self._jit:
                fn = jax.jit(fn)
            self._pull_fns[job_id] = fn
        return fn(tuple(self.runtime.states[sid]["flat"]
                        for sid in layout.shard_ids))

    # ----------------------------------------------------- versioned pulls
    def _lane_versions(self, lane: _ShardLane) -> np.ndarray:
        sp = self.plan.shard_of(lane.shard_id)
        nb = sp.total_len // sp.block_align
        if lane.versions is None or lane.versions.size != nb:
            lane.versions = np.zeros(nb, np.int64)
        return lane.versions

    def _stamp_lane(self, lane: _ShardLane, jobs) -> None:
        """Advance the fleet-wide version clock and stamp the given jobs'
        owned blocks of THIS shard space (applying ticks and rollbacks --
        a rewound block must never look unchanged to a diff client)."""
        if self.plan is None or not jobs:
            return
        sp = self.plan.shard_of(lane.shard_id)
        versions = self._lane_versions(lane)
        self._version_clock += 1
        for j in jobs:
            if j in self.runtime._jobs:
                versions[np.asarray(sp.job_layout(j).blocks)] = \
                    self._version_clock

    def _pull_versioned(self, job_id: str, layout, since) -> PullDiff:
        # The job-local version vector: each hosting shard's versions of
        # the job's owned blocks, concatenated in shard order -- the same
        # order its packed pieces concatenate in, so job-local block row
        # i of the packed vector is entry i of the vector.
        parts = []
        for sid, l in zip(layout.shard_ids, layout.layouts):
            lane = self._lane(sid)
            parts.append(self._lane_versions(lane)[np.asarray(l.blocks)])
        vers = (np.concatenate(parts) if len(parts) > 1
                else parts[0].copy())
        version = PullVersion(epoch=self._epoch, versions=vers)
        bytes_full = 4 * layout.packed_len
        blocks = {l.block for l in layout.layouts}
        uniform = len(blocks) == 1
        full = (not uniform  # mixed granularity: no single row width
                or not isinstance(since, PullVersion)
                or since.epoch != self._epoch
                or since.versions.size != vers.size)
        if full:
            data = _gather_packed(
                layout, _layout_rows(layout),
                [self.runtime.states[sid]["flat"]
                 for sid in layout.shard_ids])
            diff = PullDiff(
                job_id=job_id, version=version, full=True,
                block=(blocks.pop() if uniform else 0),
                block_ids=np.empty(0, np.int64), data=data,
                bytes_wire=bytes_full, bytes_full=bytes_full)
            self.stats.n_full_pulls += 1
        else:
            block = blocks.pop()
            changed = vers > since.versions
            data_parts, id_parts = [], []
            off = 0  # job-local block row of this shard's first piece row
            for sid, l in zip(layout.shard_ids, layout.layouts):
                nb = int(np.asarray(l.blocks).size)
                sel = np.nonzero(changed[off:off + nb])[0]
                if sel.size:
                    flat = self.runtime.states[sid]["flat"]
                    data_parts.append(flat.reshape(-1, l.block)[
                        jnp.asarray(np.asarray(l.blocks)[sel])])
                    id_parts.append(off + sel)
                off += nb
            if data_parts:
                data = (jnp.concatenate(data_parts) if len(data_parts) > 1
                        else data_parts[0])
                ids = np.concatenate(id_parts).astype(np.int64)
            else:
                data = jnp.zeros((0, block), jnp.float32)
                ids = np.empty(0, np.int64)
            diff = PullDiff(
                job_id=job_id, version=version, full=False, block=block,
                block_ids=ids, data=data,
                bytes_wire=4 * int(ids.size) * block,
                bytes_full=bytes_full)
            self.stats.n_diff_pulls += 1
        self.stats.pull_bytes_wire += diff.bytes_wire
        self.stats.pull_bytes_full += bytes_full
        return diff

    def _enqueue(self, job_id: str, layout, pieces) -> PushFuture:
        count = self._counts[job_id] + 1
        self._counts[job_id] = count
        fut = PushFuture(job_id, self, parts=len(pieces))
        inj = self.fault_injector
        kind = self.runtime._jobs[job_id]["step_opts"].get("push_compression")
        for sid, piece in zip(layout.shard_ids, pieces):
            # Wire accounting per PIECE (each crosses to its own hosting
            # shard), on the fleet and the receiving lane's stats alike;
            # bytes are spent even when the injector drops the piece.
            n = int(piece.size)
            wire = wire_bytes(n, kind)
            self.stats.push_bytes_raw += 4 * n
            self.stats.push_bytes_wire += wire
            lane_stats = self._lane(sid).stats
            lane_stats.push_bytes_raw += 4 * n
            lane_stats.push_bytes_wire += wire
            action = "deliver" if inj is None else inj.on_push(job_id, sid)
            if action == "drop":
                # Lost in transit: the future keeps the part, so it can
                # never resolve -- result(timeout=...) surfaces it.
                continue
            q = self._lane(sid).queues.setdefault(job_id, deque())
            q.append((piece, count, fut, self._epoch))
            if action == "duplicate":
                # At-least-once delivery bug: the copy applies as an
                # extra untracked piece (fut=None).
                q.append((piece, count, None, self._epoch))
        return fut

    def _force_capacity(self, job_id: str, layout) -> None:
        while True:
            full = [sid for sid in layout.shard_ids
                    if len(self._lane(sid).queues.get(job_id, ()))
                    >= self.queue_capacity]
            if not full:
                return
            self.stats.n_forced_capacity += 1
            for sid in full:
                lane = self._lanes.get(sid)
                if lane is not None and lane.health == QUARANTINED:
                    # A full queue on a lane that will never tick again:
                    # fail the submit instead of spinning forever.
                    raise lane.quarantine_error
                self.tick_shard(sid)

    def submit_push(self, job_id: str, grads) -> PushFuture:
        """Queue a job's gradient pytree: one packed piece per hosting
        shard, applied by each shard's own ticks."""
        layout = self._layout(job_id)
        self._force_capacity(job_id, layout)
        fn = self._pack_fns.get(job_id)
        if fn is None:
            def fn(grads, _layout=layout):
                g = _pack_slots(_layout, grads)
                return _split_pieces(_layout, g)

            if self._jit:
                fn = jax.jit(fn)
            self._pack_fns[job_id] = fn
        return self._enqueue(job_id, layout, fn(grads))

    def step(self, job_id: str, batch) -> Dict[str, Any]:
        """One engine-mode iteration: staleness-bounded pull, loss/grads,
        one queued piece per hosting shard."""
        layout = self._layout(job_id)
        while self.outstanding(job_id) > self.max_staleness:
            self.stats.n_forced_staleness += 1
            if self.tick() == 0:
                stall = self._stall_error(job_id)
                if stall is not None:
                    raise stall
        self._force_capacity(job_id, layout)
        fn = self._grad_fns.get(job_id)
        if fn is None:
            info = self.runtime._jobs[job_id]
            abstract, loss_fn = info["abstract"], info["loss_fn"]
            rows = _layout_rows(layout)

            def fn(flats, batch, _layout=layout, _rows=rows,
                   _abstract=abstract, _loss=loss_fn):
                params = _unpack_slots(
                    _layout, _gather_packed(_layout, _rows, flats),
                    _abstract)
                loss, grads = jax.value_and_grad(_loss)(params, batch)
                return loss, _split_pieces(_layout, _pack_slots(_layout,
                                                                grads))

            if self._jit:
                fn = jax.jit(fn)
            self._grad_fns[job_id] = fn
        loss, pieces = fn(
            tuple(self.runtime.states[sid]["flat"]
                  for sid in layout.shard_ids), batch)
        return {"loss": loss,
                "future": self._enqueue(job_id, layout, pieces)}

    # ----------------------------------------------------------------- tick
    def tick_shard(self, shard_id: str, only=None) -> int:
        """One tick of ONE shard space: pop the head piece of every
        pending job on this lane and apply them in one per-shard pass
        (batched at/above ``min_batch_jobs`` pending jobs).  Other shards
        are untouched -- this is the independent cadence primitive, and
        the unit of failure isolation: a QUARANTINED lane is skipped
        (returns 0) so its neighbors' cadence never stalls."""
        lane = self._lanes.get(shard_id)
        if lane is None or lane.health == QUARANTINED:
            return 0
        pending = [j for j in self.runtime._jobs
                   if lane.queues.get(j) and (only is None or j in only)]
        if not pending:
            return 0
        for j in pending:
            if lane.queues[j][0][3] != self._epoch:
                raise RuntimeError(
                    f"epoch fence: job {j!r} queued a piece on shard "
                    f"{shard_id!r} under plan epoch {lane.queues[j][0][3]} "
                    f"but the engine is at {self._epoch}; a replan "
                    f"migrated this job's layout without draining it")
        if 1 < len(pending) < self.min_batch_jobs:
            groups = [(j,) for j in pending]
            lane.stats.n_per_job_dispatch += 1
        else:
            groups = [tuple(pending)]
        snapped = self._maybe_snapshot_lane(lane)
        if self._replica_hub is not None:
            # Read-tier publish point, co-located with the rollback
            # snapshot so a refresh tick's copy is shared, not repeated.
            self._replica_hub.on_tick(shard_id, snapped)
        applied = 0
        for key in groups:
            heads = [lane.queues[j].popleft() for j in key]
            try:
                applier = lane.appliers.get(key)
                if applier is None:
                    applier = self._build_applier(shard_id, key)
                    if len(lane.appliers) >= self.MAX_APPLIERS:
                        lane.appliers.pop(next(iter(lane.appliers)))
                    lane.appliers[key] = applier
                gs = tuple(piece for piece, _, _, _ in heads)
                counts = tuple(count for _, count, _, _ in heads)
            except BaseException:
                # Build-time failure: no device op ran; re-queue and let a
                # later tick retry.
                for j, head in zip(key, heads):
                    lane.queues[j].appendleft(head)
                raise
            try:
                if self.fault_injector is not None:
                    self.fault_injector.on_apply(shard_id)
                self.runtime.states[shard_id] = applier(
                    self.runtime.states[shard_id], gs, counts)
            except BaseException as exc:
                # Execution failure: the jitted applier DONATED this
                # shard's buffers.  Re-queue the heads, restore the
                # lane's last-good snapshot, and replay on later ticks
                # -- or quarantine THIS LANE ONLY when retries are
                # exhausted (neighbor lanes keep ticking either way).
                # The rollback undoes this tick's earlier groups too, so
                # nothing from this tick survives.
                for j, head in zip(key, heads):
                    lane.queues[j].appendleft(head)
                self._handle_lane_failure(lane, exc, key)
                lane.stats.n_ticks += 1
                self.stats.n_ticks += 1
                return 0
            lane.failures = 0
            for j, (piece, count, fut, _) in zip(key, heads):
                if fut is not None and fut._resolve(count):
                    # The push applied on its LAST hosting shard: commit
                    # the job's global step counter (per-shard states
                    # carry no counts -- the runtime owns them, and a
                    # checkpoint must see every applied push).  Only the
                    # done-TRANSITION commits: a replayed piece of an
                    # already-done future must not rewind the counter.
                    self.runtime.counts[j] = jnp.asarray(count, jnp.int32)
                lane.log.append((j, piece, count, fut))
            applied += len(key)
        self._stamp_lane(lane, pending)  # diff-pull dirty marks
        lane.stats.n_ticks += 1
        lane.stats.n_applied += applied
        lane.stats.n_launches += len(groups)
        lane.ticks_since_snapshot += 1
        self.stats.n_ticks += 1
        self.stats.n_applied += applied
        self.stats.n_launches += len(groups)
        return applied

    # ------------------------------------------------------- fault recovery
    def _maybe_snapshot_lane(self, lane: _ShardLane) -> bool:
        """Refresh this lane's rollback anchor every ``snapshot_interval``
        of ITS applying ticks, BEFORE the donated apply (queues intact,
        replay log emptied: snapshot + log reconstructs any later
        moment).  Returns True when the anchor was refreshed this call
        (the read tier reuses its fresh copy instead of taking another)."""
        if self.snapshot_interval <= 0:
            return False
        if (lane.snapshot is None
                or lane.ticks_since_snapshot >= self.snapshot_interval):
            lane.snapshot = _copy_state(self.runtime.states[lane.shard_id])
            lane.log = []
            lane.ticks_since_snapshot = 0
            lane.stats.n_snapshots += 1
            self.stats.n_snapshots += 1
            return True
        return False

    def _rollback_lane(self, lane: _ShardLane) -> None:
        """Restore the lane's last-good state and re-queue its logged
        pieces IN FRONT of the queued backlog (per-job order preserved):
        subsequent ticks replay the identical (piece, count) sequence,
        which is bit-exact because counts were fixed at submit time."""
        self.runtime.states[lane.shard_id] = _copy_state(lane.snapshot)
        # The restore rewound the logged jobs' blocks: re-stamp so diff
        # clients who saw the undone values are told they changed.
        self._stamp_lane(lane, {j for j, _, _, _ in lane.log})
        for j, piece, count, fut in reversed(lane.log):
            if fut is not None:
                fut._unresolve()
            lane.queues.setdefault(j, deque()).appendleft(
                (piece, count, fut, self._epoch))
            lane.stats.n_replayed += 1
            self.stats.n_replayed += 1
        lane.log = []
        lane.ticks_since_snapshot = 0
        lane.stats.n_rollbacks += 1
        self.stats.n_rollbacks += 1

    def _handle_lane_failure(self, lane: _ShardLane, exc: BaseException,
                             key) -> None:
        """Roll the lane back for replay, or quarantine it (stored, NOT
        raised: the point is that sibling lanes keep ticking -- blocked
        work surfaces the stored error via drain/pull/result)."""
        lane.failures += 1
        can_roll = lane.snapshot is not None
        if can_roll and self.retry_policy.should_retry(lane.failures):
            self.retry_policy.backoff(lane.failures)
            self._rollback_lane(lane)
            return
        if can_roll:
            self._rollback_lane(lane)  # leave last-good state installed
        elif not self._jit:
            # Eager with snapshots disabled: nothing was donated, the
            # shard state is intact -- surface the raw error.
            raise exc
        lane.health = QUARANTINED
        lane.quarantine_error = EngineQuarantinedError(
            shard_id=lane.shard_id, tick=lane.stats.n_ticks, job_ids=key,
            original=exc)
        lane.stats.n_quarantines += 1
        self.stats.n_quarantines += 1

    def tick(self, only=None) -> int:
        """One ROUND over the fleet.  With ``fleet_tick="fused"`` (the
        default) this is ONE fused launch covering every lane with
        pending pieces (:meth:`tick_fleet`); with ``"per_shard"`` it
        ticks every live shard once, one launch group per lane (the PR-5
        oracle path).  Returns pieces applied (0 = nothing pending
        anywhere)."""
        plan = self.plan
        if plan is None:
            return 0
        if self.fleet_tick == "fused":
            return self.tick_fleet(only=only)
        return sum(self.tick_shard(sid, only=only)
                   for sid in plan.shard_ids)

    def tick_fleet(self, only=None) -> int:
        """One FLEET tick: pop the head piece of every pending job on
        EVERY lane and apply all of them in ONE fused launch over the
        pending lanes' concatenated states.  Lanes with nothing pending
        are skipped mid-table -- they contribute neither state movement
        nor launch cost, and their cadence is untouched.  QUARANTINED
        lanes are excluded the same way (their backlog is frozen until
        recovery), so one dead shard never blocks the fleet launch.
        Returns pieces applied across the fleet (0 = nothing pending
        anywhere)."""
        plan = self.plan
        if plan is None:
            return 0
        entries = []
        for sid in plan.shard_ids:
            lane = self._lanes.get(sid)
            if lane is None or lane.health == QUARANTINED:
                continue
            pending = tuple(
                j for j in self.runtime._jobs
                if lane.queues.get(j) and (only is None or j in only))
            if not pending:
                continue
            for j in pending:
                if lane.queues[j][0][3] != self._epoch:
                    raise RuntimeError(
                        f"epoch fence: job {j!r} queued a piece on shard "
                        f"{sid!r} under plan epoch "
                        f"{lane.queues[j][0][3]} but the engine is at "
                        f"{self._epoch}; a replan migrated this job's "
                        f"layout without draining it")
            entries.append((sid, pending))
        if not entries:
            return 0
        key = tuple(entries)
        # Build BEFORE popping: a build failure (e.g. mixed block_align
        # across lanes) leaves every queue untouched for a later retry.
        applier = self._fleet_appliers.get(key)
        if applier is None:
            applier = self._build_fleet_applier(key)
            if len(self._fleet_appliers) >= self.MAX_APPLIERS:
                self._fleet_appliers.pop(next(iter(self._fleet_appliers)))
            self._fleet_appliers[key] = applier
        # Snapshot every participating lane BEFORE popping: queues are
        # intact, so each lane's (snapshot, empty log) anchors a rollback
        # of this very launch.
        for sid, _ in key:
            snapped = self._maybe_snapshot_lane(self._lanes[sid])
            if self._replica_hub is not None:
                self._replica_hub.on_tick(sid, snapped)
        popped = []  # (sid, job, head) in key order == table order
        for sid, jobs in key:
            lane = self._lanes[sid]
            for j in jobs:
                popped.append((sid, j, lane.queues[j].popleft()))
        gs = tuple(head[0] for _, _, head in popped)
        counts = tuple(head[1] for _, _, head in popped)
        states = tuple(self.runtime.states[sid] for sid, _ in key)
        try:
            if self.fault_injector is not None:
                for sid, _ in key:
                    self.fault_injector.on_apply(sid)
            new_states = applier(states, gs, counts)
        except BaseException as exc:
            # Execution failure: the jitted applier DONATED every pending
            # shard's buffers, and the fused launch cannot attribute
            # WHICH lane blew up.  Re-queue the heads, roll back every
            # participating lane to its own snapshot, then FALL BACK to
            # per-shard launches: the faulty lane fails (and retries or
            # quarantines) in isolation while the healthy rest re-apply.
            for sid, j, head in popped:
                self._lanes[sid].queues[j].appendleft(head)
            if self.snapshot_interval <= 0:
                # No rollback anchors.  Jitted buffers are gone for every
                # participating lane: quarantine them all (the pre-PR-7
                # poisoned behavior, scoped to the participants); eager
                # states are intact, so surface the raw error.
                if not self._jit:
                    raise
                for sid, jobs in key:
                    lane = self._lanes[sid]
                    lane.health = QUARANTINED
                    lane.quarantine_error = EngineQuarantinedError(
                        shard_id=sid, tick=lane.stats.n_ticks,
                        job_ids=jobs, original=exc)
                    lane.stats.n_quarantines += 1
                    self.stats.n_quarantines += 1
                self.stats.n_ticks += 1
                return 0
            self.stats.n_fleet_fallbacks += 1
            for sid, _ in key:
                self._rollback_lane(self._lanes[sid])
            applied = 0
            for sid, _ in key:
                applied += self.tick_shard(sid)
            self.stats.n_ticks += 1
            return applied
        for (sid, _), st in zip(key, new_states):
            self.runtime.states[sid] = st
        for sid, j, (piece, count, fut, _) in popped:
            lane = self._lanes[sid]
            lane.failures = 0
            if fut is not None and fut._resolve(count):
                # Applied on its LAST hosting shard: commit the job's
                # global step counter (the runtime owns counts); only
                # the done-transition commits (replay never rewinds).
                self.runtime.counts[j] = jnp.asarray(count, jnp.int32)
            lane.log.append((j, piece, count, fut))
        for sid, jobs in key:
            lane = self._lanes[sid]
            self._stamp_lane(lane, jobs)  # diff-pull dirty marks
            lane.stats.n_ticks += 1
            lane.stats.n_applied += len(jobs)
            lane.ticks_since_snapshot += 1
        self.stats.n_ticks += 1
        self.stats.n_applied += len(popped)
        self.stats.n_launches += 1  # the whole point: ONE launch per fleet
        return len(popped)

    def drain(self, only=None) -> int:
        """Tick rounds until every (selected) queue on every lane is
        empty.  Returns pieces applied.  A round may apply nothing while
        a rollback replays (the loop keeps ticking); pieces stuck on a
        QUARANTINED lane can never drain, so that raises the lane's
        :class:`~repro.ps.faults.EngineQuarantinedError` instead of
        spinning forever."""
        applied = 0
        while True:
            n = self.tick(only=only)
            applied += n
            if n:
                continue
            stuck = self._quarantine_blocking(only)
            if stuck is not None:
                raise stuck
            if not self._has_pending(only):
                return applied

    def quiesce_for_replan(self, touched) -> int:
        """Drain ONLY the touched jobs' pieces (on every lane) ahead of a
        sharded migration; untouched lanes and jobs keep their cadence.
        Raises the blocking lane's quarantine error if a touched piece is
        frozen on a dead lane (recover_shard purges the lost lane first,
        so this only fires on user-driven replans of a broken fleet)."""
        applied = 0
        while True:
            pending = [j for j in touched
                       if any(lane.queues.get(j)
                              for lane in self._lanes.values())]
            if not pending:
                return applied
            self.stats.n_forced_replan += 1
            n = self.tick(only=pending)
            applied += n
            if n == 0:
                stuck = self._quarantine_blocking(pending)
                if stuck is not None:
                    raise stuck

    # --------------------------------------------------------------- replan
    def _on_plan_change(self, touched=None) -> None:
        """Sharded replan landed: invalidate what the new plan breaks.

        Same fence protocol as the flat engine, per lane: ``touched=None``
        requires every queue empty and drops everything; with a touched
        set, only touched jobs' programs die, lanes whose Aggregator left
        the fleet are dropped (their jobs are touched by construction, so
        their queues are already drained), and untouched jobs' surviving
        pieces are re-tagged to the new epoch."""
        self._epoch += 1
        self.stats.n_replans += 1
        # Fleet appliers bake EVERY participating shard's length into the
        # concatenated-view offsets, so any plan change invalidates all
        # of them (per-lane appliers survive for untouched jobs).
        self._fleet_appliers.clear()
        # Lane snapshots copy the PRE-migration shard geometry: restoring
        # one after a replan would resurrect dead layouts.  Drop them all
        # (health survives -- a quarantined lane stays quarantined); the
        # rollback window restarts at each lane's next applying tick.
        for lane in self._lanes.values():
            lane.snapshot = None
            lane.log = []
            lane.ticks_since_snapshot = 0
            # Versions index the OLD shard geometry; the epoch bump
            # already sends every held PullVersion to the full-pull
            # fallback, so restart the vector.
            lane.versions = None
        if self._replica_hub is not None:
            # Read-tier snapshots hold the old geometry too; the epoch
            # fence marks them stale and the next serve resubscribes.
            self._replica_hub.on_replan()
        if touched is None:
            assert not any(q for lane in self._lanes.values()
                           for q in lane.queues.values()), (
                "replan with queued pieces: runtime must drain the "
                "engine first")
            self._lanes.clear()
            self._pull_fns.clear()
            self._grad_fns.clear()
            self._pack_fns.clear()
            return
        touched = set(touched)
        live = set(self.plan.shard_ids) if self.plan is not None else set()
        for sid in list(self._lanes):
            lane = self._lanes[sid]
            for j in touched:
                assert not lane.queues.get(j), (
                    f"replan with queued pieces for TOUCHED job {j!r} on "
                    f"shard {sid!r}: quiesce_for_replan must drain it")
            if sid not in live:
                assert not any(lane.queues.values()), (
                    f"shard {sid!r} left the fleet with queued pieces")
                del self._lanes[sid]
                continue
            for j, q in lane.queues.items():
                if q:  # untouched by construction: carry across the fence
                    self.stats.n_retagged += len(q)
                    lane.queues[j] = deque(
                        (piece, count, fut, self._epoch)
                        for piece, count, fut, _ in q)
            for j in touched:
                lane.queues.pop(j, None)
            lane.appliers = {k: v for k, v in lane.appliers.items()
                             if not touched.intersection(k)}
        for j in touched:
            self._pull_fns.pop(j, None)
            self._grad_fns.pop(j, None)
            self._pack_fns.pop(j, None)

    def _forget_job(self, job_id: str) -> None:
        for lane in self._lanes.values():
            q = lane.queues.pop(job_id, None)
            if q:
                for _, _, fut, _ in q:
                    if fut is not None:
                        fut._cancel(
                            "job removed from the runtime with this piece "
                            "still queued (drain was bypassed)")
            lane.log = [e for e in lane.log if e[0] != job_id]
            lane.appliers = {k: v for k, v in lane.appliers.items()
                             if job_id not in k}
        self._fleet_appliers = {
            k: v for k, v in self._fleet_appliers.items()
            if not any(job_id in jobs for _, jobs in k)}
        self._counts.pop(job_id, None)
        self._leases.pop(job_id, None)
        self._pull_fns.pop(job_id, None)
        self._grad_fns.pop(job_id, None)
        self._pack_fns.pop(job_id, None)

    # -------------------------------------------------------------- applier
    def _build_applier(self, shard_id: str, job_ids: Tuple[str, ...]):
        """Compile the batched apply for one shard space and one pending
        job combination.  Identical math to the flat engine's applier --
        one fused launch over THIS shard's buffers, updated blocks
        written in place (PR 6) -- except the per-job step counts arrive
        with the queued pieces (assigned at submit time), so inter-shard
        apply order cannot skew bias correction."""
        shard_plan = self.plan.shard_of(shard_id)
        layouts = [shard_plan.job_layout(j) for j in job_ids]
        infos = [self.runtime._jobs[j] for j in job_ids]
        block_idx, job_sizes, hps = _fused_tables(layouts, infos,
                                                  _sharded_job_hp)
        block, interpret = shard_plan.block_align, self._interpret
        # Compressed-push jobs (PR 8): the EF transform runs per HOSTING
        # SHARD against this shard's own ef buffer (one compressed piece
        # per shard).  Empty for the common case, whose program is
        # byte-identical to the pre-compression applier.
        compressed = [(i, kind, layouts[i])
                      for i, info in enumerate(infos)
                      if (kind := info["step_opts"].get("push_compression"))]

        def apply(state, gs, counts):
            # Counts arrive as the pieces' submit-time step numbers; lift
            # to arrays so eager mode matches the traced path exactly.
            counts = [jnp.asarray(c, jnp.int32) for c in counts]
            if compressed:
                ef = state.get("ef")
                if ef is None:
                    # A rollback can restore a snapshot predating the ef
                    # widening; the buffer was all-zero back then.
                    ef = jnp.zeros_like(state["flat"])
                gs = list(gs)
                for i, kind, layout in compressed:
                    gs[i], resid = ef_transform(
                        gs[i], _gather_owned(layout, ef), kind)
                    ef = _scatter_owned(layout, ef, resid)
                gs = tuple(gs)
            new_state = _fused_state_update(
                state, gs, counts, block=block, block_idx=block_idx,
                job_sizes=job_sizes, hps=hps, interpret=interpret)
            if compressed:
                new_state["ef"] = ef
            return new_state

        return jax.jit(apply, donate_argnums=(0,)) if self._jit else apply

    def _build_fleet_applier(self, key) -> Callable:
        """Compile the SINGLE-LAUNCH fleet apply for one pending pattern.

        ``key`` is ``((shard_id, (job, ...)), ...)`` over the lanes with
        pending pieces, in plan order.  The applier concatenates those
        lanes' flat/mu/nu into one fleet view, runs ONE fused multi-job
        launch whose block table is globally rebased (shard base offset
        // block + local block id), and slices the per-shard states back
        out -- one XLA program and one kernel launch no matter how many
        lanes ticked.  Block exclusivity holds globally because each
        shard's offset is block-aligned, so the launch is bit-exact with
        the per-shard oracle loop."""
        plan = self.plan
        sids = [sid for sid, _ in key]
        offsets, _, block = plan.concat_view(sids)
        lens = [plan.shard_of(sid).total_len for sid in sids]
        layouts, infos, bases = [], [], []
        # Compressed entries (PR 8): (entry index in gs, shard index in
        # ``states``, kind, shard-local layout).  The EF transform runs
        # per entry against ITS shard's own ef buffer -- ef never joins
        # the concatenated fleet view, so the common all-uncompressed
        # launch is byte-identical to the pre-compression program.
        compressed = []
        for si, ((sid, jobs), off) in enumerate(zip(key, offsets)):
            shard_plan = plan.shard_of(sid)
            for j in jobs:
                layout = shard_plan.job_layout(j)
                info = self.runtime._jobs[j]
                kind = info["step_opts"].get("push_compression")
                if kind:
                    compressed.append((len(layouts), si, kind, layout))
                layouts.append(layout)
                infos.append(info)
                bases.append(off // block)
        block_idx, job_sizes, hps = _fused_tables(
            layouts, infos, _sharded_job_hp, base_blocks=bases)
        interpret = self._interpret

        def cat(bufs):
            return jnp.concatenate(bufs) if len(bufs) > 1 else bufs[0]

        def apply(states, gs, counts):
            counts = [jnp.asarray(c, jnp.int32) for c in counts]
            efs = {}
            if compressed:
                gs = list(gs)
                for gi, si, kind, layout in compressed:
                    ef = efs.get(si)
                    if ef is None:
                        ef = states[si].get("ef")
                    if ef is None:  # snapshot predating the ef widening
                        ef = jnp.zeros_like(states[si]["flat"])
                    gs[gi], resid = ef_transform(
                        gs[gi], _gather_owned(layout, ef), kind)
                    efs[si] = _scatter_owned(layout, ef, resid)
                gs = tuple(gs)
            fleet = {k: cat([s[k] for s in states])
                     for k in ("flat", "mu", "nu")}
            new = _fused_state_update(
                fleet, gs, counts, block=block, block_idx=block_idx,
                job_sizes=job_sizes, hps=hps, interpret=interpret)
            return tuple(
                dict(st, flat=new["flat"][lo:lo + n],
                     mu=new["mu"][lo:lo + n], nu=new["nu"][lo:lo + n],
                     **({"ef": efs[i]} if i in efs else {}))
                for i, (st, lo, n) in enumerate(zip(states, offsets,
                                                    lens)))

        return jax.jit(apply, donate_argnums=(0,)) if self._jit else apply


