"""Service-tick execution engine: batched multi-job aggregation with
bounded staleness.

The paper's aggregation is a *shared service*: many jobs' bursty pushes
land on the same Aggregator CPUs and should be executed together, not as
one step-function per job.  PR 1 compiled the packing into one shared
FlatPlan and PR 2 made each job's step O(job bytes); this module adds the
service-side loop that actually batches them:

  submit_push  a job pushes its packed gradient into its bounded per-job
               queue and gets a :class:`PushFuture`; nothing is applied yet
  tick         the engine drains the HEAD push of every pending job and
               applies all of them in ONE batched pass over the shared
               flat space -- a single Pallas launch on TPU
               (``kernels.agg_adam.aggregate_adam_multijob``: concatenated
               owned-block index table + per-block job-slot map), a
               fused-scatter jnp pass in interpret mode
  pull         a job reads its own lanes; with ``max_staleness = s`` a job
               may run ``s`` steps ahead of the service before its pull
               blocks on (forces) the tick -- Dynamic-SSP-style bounded
               staleness; ``s = 0`` is BSP

Block exclusivity (every ``block_align`` block of the flat space belongs
to at most one job, the PR-2 invariant) is what makes the batched pass a
pure execution-order change: its result is bit-exact with applying the
same pushes as K sequential per-job block steps.  Below the measured
batching crossover (``min_batch_jobs``; BENCH_service_tick.json showed
the one-launch concatenation LOSING at 2 pending jobs) a tick dispatches
the same pushes as per-job block passes instead -- identical result,
cheaper program.

Replans are STALL-FREE: the runtime compiles a
:class:`repro.ps.elastic.MigrationDelta` for the plan pair and quiesces
ONLY the touched jobs (those whose segment layout changes) -- their
queued pushes apply against the OLD plan before the state migrates.
Untouched jobs keep their queues, their compiled programs, and their
tick cadence straight through the transition; a per-push EPOCH FENCE
(every queued push is tagged with the plan epoch it was packed under,
and untouched jobs' surviving pushes are re-tagged at each replan)
guarantees no push is ever applied across mismatched layouts, extending
the PR-3 invariant: the engine'd runtime stays bit-exact with the
unbatched one -- eager execution matches it bit-for-bit at any sizes,
and the jitted batched apply matches jitted sequential block updates
bit-for-bit at SIMD-even block sizes (fully-jitted END-TO-END runs
additionally see XLA:CPU's ~1-ulp cross-program fusion rounding, the
same caveat PR 2 documents for jitted block-vs-masked; see
tests/test_engine.py).

Usage::

    rt = ServiceRuntime(svc)
    eng = rt.attach_engine(max_staleness=1)
    rt.add_job("a", params_a, loss_a); rt.add_job("b", params_b, loss_b)
    for batch_a, batch_b in data:
        eng.step("a", batch_a)   # pull -> grad -> submit_push
        eng.step("b", batch_b)
        # pushes apply together at the next tick (forced by staleness,
        # queue pressure, an explicit eng.tick(), or fut.result())
    eng.drain()

PR 5 adds the SHARDED sibling: :class:`ShardedTickEngine` runs one
independent tick loop per Aggregator shard space (``tick_shard``), with a
job's push split into one piece per hosting shard -- see the class
docstring and docs/architecture.md.

PR 6 makes the hot path a SINGLE LAUNCH: the row scatters that used to
follow every batched apply are fused into the kernel itself
(``kernels.agg_adam.aggregate_adam_multijob_fused`` writes the updated
flat/mu/nu blocks in place via ``input_output_aliases``), and the sharded
engine gains :meth:`ShardedTickEngine.tick_fleet` -- every lane with
pending pieces ticks in ONE fused launch over the lanes' concatenated
states (``fleet_tick="fused"``, the default; ``"per_shard"`` keeps the
PR-5 loop as a bit-parity oracle).  ``TickStats.n_launches`` counts what
this buys.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.ps.plan import FlatPlan
from repro.ps.runtime import (
    _gather_packed,
    _layout_rows,
    _pack_slots,
    _split_pieces,
    _unpack_slots,
)

__all__ = ["PushFuture", "ServiceTickEngine", "ShardedTickEngine",
           "TickStats"]


class PushFuture:
    """Handle for one submitted push; resolves when a tick applies it.

    Under the sharded engine one push fans out into one PIECE per hosting
    shard (``parts``); the future resolves when the LAST piece applies.
    A push dropped without applying (a job removed with a queue that
    could not drain) is CANCELLED: ``result()`` raises instead of forcing
    ticks forever on a job the engine no longer knows.
    """

    __slots__ = ("job_id", "_engine", "_done", "_step", "_remaining",
                 "_cancelled")

    def __init__(self, job_id: str, engine, parts: int = 1):
        self.job_id = job_id
        self._engine = engine
        self._done = False
        self._step = None
        self._remaining = int(parts)
        self._cancelled = None  # str reason once cancelled

    def done(self) -> bool:
        return self._done

    def cancelled(self) -> bool:
        return self._cancelled is not None

    def result(self) -> int:
        """Block (force service ticks) until applied; returns the job's
        1-based step count as of this push.  Raises ``RuntimeError`` if
        the push was cancelled before it could apply."""
        while not self._done:
            if self._cancelled is not None:
                raise RuntimeError(
                    f"push for job {self.job_id!r} will never apply: "
                    f"{self._cancelled}")
            self._engine.tick()
        return self._step

    def _resolve(self, step: int) -> None:
        self._remaining -= 1
        if self._remaining <= 0:
            self._done = True
            self._step = int(step)

    def _cancel(self, reason: str) -> None:
        if not self._done:
            self._cancelled = reason


@dataclass
class TickStats:
    """Engine counters: how batched the service actually ran."""

    n_ticks: int = 0  # batched passes executed
    n_applied: int = 0  # pushes applied across all ticks
    n_launches: int = 0  # kernel/applier launches (the single-launch gauge)
    n_forced_staleness: int = 0  # ticks forced by a pull at the bound
    n_forced_capacity: int = 0  # ticks forced by a full push queue
    n_forced_replan: int = 0  # ticks forced to drain TOUCHED jobs on a replan
    n_per_job_dispatch: int = 0  # ticks dispatched as per-job passes (< K_min)
    n_replans: int = 0  # plan changes the engine rode through
    n_retagged: int = 0  # untouched pushes carried across a replan (fence)

    @property
    def mean_batch(self) -> float:
        """Mean jobs applied per tick (running counters, O(1) memory --
        the engine may tick for the service's whole lifetime)."""
        if not self.n_ticks:
            return 0.0
        return self.n_applied / self.n_ticks


# ------------------------------------------------ shared applier building
def _flat_job_hp(info) -> Tuple[float, float, float, float]:
    """(lr, b1, b2, eps) of one flat-runtime job (Adam knobs ride in
    ``step_opts`` on the unsharded runtime)."""
    so = info["step_opts"]
    return (float(info["lr"]), float(so.get("b1", 0.9)),
            float(so.get("b2", 0.999)), float(so.get("eps", 1e-8)))


def _sharded_job_hp(info) -> Tuple[float, float, float, float]:
    """(lr, b1, b2, eps) of one sharded-runtime job (first-class fields)."""
    return (float(info["lr"]), float(info["b1"]), float(info["b2"]),
            float(info["eps"]))


def _fused_tables(layouts, infos, hp_of, base_blocks=None):
    """Bake the trace-time tables one fused multi-job apply needs: the
    concatenated owned-block index table, per-entry packed block counts,
    and per-entry ``(lr, b1, b2, eps)`` columns.

    ONE builder for every applier in this module -- the flat engine, the
    per-shard lane applier, and the fleet tick all route through it.  The
    fleet passes ``base_blocks`` (each entry's shard base offset, in
    blocks, into the concatenated fleet view) so a shard-local block
    table rebases to global block ids; single-space appliers leave it 0.
    """
    if base_blocks is None:
        base_blocks = (0,) * len(layouts)
    block_idx = np.concatenate(
        [l.blocks.astype(np.int32) + np.int32(b)
         for l, b in zip(layouts, base_blocks)])
    job_sizes = tuple(int(l.blocks.size) for l in layouts)
    lr, b1, b2, eps = zip(*(hp_of(i) for i in infos))
    return block_idx, job_sizes, (lr, b1, b2, eps)


def _fused_state_update(state, gs, counts, *, block, block_idx, job_sizes,
                        hps, interpret):
    """ONE fused launch over one state dict: aggregation + Adam + the
    in-place block writes for flat/mu/nu together (PR 6) -- the three
    post-apply row scatters earlier engines ran are gone.  ``gs`` is the
    per-entry packed gradient sequence (concatenated once inside the op:
    this exact program shape is what the bit-exactness tests pin down);
    ``counts`` must already be usable as traced int32 scalars."""
    from repro.kernels.agg_adam import ops as agg_ops

    lr, b1, b2, eps = hps
    new_p, new_mu, new_nu = agg_ops.multi_job_adam_update_fused(
        state["flat"], gs, state["mu"], state["nu"], counts,
        block_idx=block_idx, job_sizes=job_sizes, block=block,
        lr=lr, b1=b1, b2=b2, eps=eps, wd=0.0, interpret=interpret)
    return dict(state, flat=new_p, mu=new_mu, nu=new_nu)


class ServiceTickEngine:
    """Batched executor for one :class:`ServiceRuntime`'s shared state.

    Created via :meth:`ServiceRuntime.attach_engine`.  The engine owns the
    per-job push queues and the compiled batched appliers; the runtime
    keeps owning plan + state (and migrates them on replans, draining this
    engine first).
    """

    MAX_APPLIERS = 32  # compiled programs per plan (one per job subset)

    def __init__(self, runtime, *, max_staleness: int = 1,
                 queue_capacity: Optional[int] = None, jit: bool = True,
                 interpret: Optional[bool] = None, min_batch_jobs: int = 3):
        if max_staleness < 0:
            raise ValueError(f"max_staleness must be >= 0, got {max_staleness}")
        self.runtime = runtime
        self.max_staleness = int(max_staleness)
        self.queue_capacity = (self.max_staleness + 1 if queue_capacity is None
                               else int(queue_capacity))
        if self.queue_capacity < 1:
            raise ValueError("queue_capacity must be >= 1")
        # Batching crossover: with fewer than this many pending jobs a
        # tick dispatches per-job block passes -- the one-launch
        # concatenation only wins once enough jobs share the pass
        # (BENCH_service_tick.json measured batched LOSING at 2 jobs,
        # 0.71x, and winning from 4 up).  Result is identical either
        # way (disjoint blocks commute); this is a pure cost knob.
        self.min_batch_jobs = int(min_batch_jobs)
        self.stats = TickStats()
        self._poisoned = False
        self._jit = jit
        self._interpret = interpret  # None = auto (jnp path off-TPU)
        self._epoch = 0  # bumped per plan change; fences queued pushes
        self._queues: Dict[str, deque] = {}
        # Python-side mirror of state["counts"]: futures resolve from it
        # without a device round-trip per tick.
        self._counts: Dict[str, int] = {}
        # Compiled caches, invalidated on every replan.
        self._appliers: Dict[Tuple[str, ...], Callable] = {}
        self._pull_fns: Dict[str, Callable] = {}
        self._grad_fns: Dict[str, Callable] = {}
        self._pack_fns: Dict[str, Callable] = {}

    # ------------------------------------------------------------- plumbing
    @property
    def plan(self) -> Optional[FlatPlan]:
        return self.runtime.plan

    def _queue(self, job_id: str) -> deque:
        info = self.runtime._jobs.get(job_id)
        if info is None:
            raise ValueError(f"unknown job {job_id!r}: not registered with "
                             f"the runtime (have {sorted(self.runtime._jobs)})")
        if info["step_opts"].get("push_compression"):
            raise NotImplementedError(
                "the tick engine's batched apply has no error-feedback "
                "buffer; step compressed-push jobs through runtime.step()")
        if job_id not in self._counts:
            # One sync at first contact; ticks keep the mirror in step.
            self._counts[job_id] = int(jax.device_get(
                self.runtime.state["counts"][job_id]))
        return self._queues.setdefault(job_id, deque())

    def outstanding(self, job_id: str) -> int:
        """Pushes submitted by the job but not yet applied by a tick."""
        q = self._queues.get(job_id)
        return len(q) if q else 0

    def quiesce_for_replan(self, touched) -> int:
        """Drain ONLY the touched jobs' queues ahead of a migration.

        Their queued pushes apply against the OLD plan (their layout is
        about to change); untouched jobs' queues -- and tick cadence --
        are left alone.  Returns pushes applied."""
        applied = 0
        while True:
            pending = [j for j in touched if self._queues.get(j)]
            if not pending:
                return applied
            self.stats.n_forced_replan += 1
            applied += self.tick(only=pending)

    def _on_plan_change(self, touched=None) -> None:
        """Replan landed: invalidate what the new plan breaks.

        ``touched=None`` (full quiesce: first plan, last exit, or a
        gather-path migration) drops every compiled structure and
        requires every queue empty.  With a delta's touched set, only
        the touched jobs' programs die; untouched jobs keep queues and
        compiled programs -- their layout is bit-identical in the new
        plan -- and their surviving pushes are re-tagged to the new
        epoch (the fence that proves no push crosses layouts)."""
        self._epoch += 1
        self.stats.n_replans += 1
        if touched is None:
            assert not any(self._queues.values()), (
                "replan with queued pushes: runtime must drain the "
                "engine first")
            self._appliers.clear()
            self._pull_fns.clear()
            self._grad_fns.clear()
            self._pack_fns.clear()
            return
        touched = set(touched)
        for j in touched:
            assert not self._queues.get(j), (
                f"replan with queued pushes for TOUCHED job {j!r}: "
                f"quiesce_for_replan must drain it first")
        for j, q in self._queues.items():
            if q:  # untouched by construction: carry across the fence
                self.stats.n_retagged += len(q)
                self._queues[j] = deque(
                    (packed, fut, self._epoch) for packed, fut, _ in q)
        for j in touched:
            self._pull_fns.pop(j, None)
            self._grad_fns.pop(j, None)
            self._pack_fns.pop(j, None)
        self._appliers = {k: v for k, v in self._appliers.items()
                         if not touched.intersection(k)}

    def _forget_job(self, job_id: str) -> None:
        q = self._queues.pop(job_id, None)
        if q:
            # remove_job quiesces first, so a surviving push means the
            # drain was bypassed; cancel so held futures raise cleanly
            # instead of forcing ticks forever on an unknown job.
            for _, fut, _ in q:
                fut._cancel("job removed from the runtime with this push "
                            "still queued (drain was bypassed)")
        self._counts.pop(job_id, None)
        self._pull_fns.pop(job_id, None)
        self._grad_fns.pop(job_id, None)
        self._pack_fns.pop(job_id, None)
        # Appliers embedding the job die with the next plan change, which
        # the runtime triggers right after; drop them eagerly anyway.
        self._appliers = {k: v for k, v in self._appliers.items()
                         if job_id not in k}

    # ------------------------------------------------------------ data path
    def pull(self, job_id: str):
        """The job's current parameters from the shared space.

        Bounded staleness: a job ``max_staleness`` steps ahead of the
        service blocks here -- the pull forces ticks until the job is back
        within the bound (one tick applies one queued push, so one
        suffices unless other jobs' queues run deeper)."""
        self._queue(job_id)  # validates the job id
        while self.outstanding(job_id) > self.max_staleness:
            self.stats.n_forced_staleness += 1
            self.tick()
        fn = self._pull_fns.get(job_id)
        if fn is None:
            plan = self.plan
            layout = plan.job_layout(job_id)
            abstract = self.runtime._jobs[job_id]["abstract"]
            rows = jnp.asarray(layout.blocks)

            def fn(flat, _layout=layout, _rows=rows, _abstract=abstract):
                packed = (flat if _layout.covers_all else
                          flat.reshape(-1, _layout.block)[_rows].reshape(-1))
                return _unpack_slots(_layout, packed, _abstract)

            if self._jit:
                fn = jax.jit(fn)
            self._pull_fns[job_id] = fn
        return fn(self.runtime.state["flat"])

    def submit_push(self, job_id: str, grads) -> PushFuture:
        """Queue a job's gradient pytree for the next tick; returns a
        future.  A full queue exerts backpressure: the submit first forces
        ticks until a slot frees up."""
        q = self._queue(job_id)
        while len(q) >= self.queue_capacity:
            self.stats.n_forced_capacity += 1
            self.tick()
        fn = self._pack_fns.get(job_id)
        if fn is None:
            layout = self.plan.job_layout(job_id)
            fn = (lambda grads, _layout=layout:
                  _pack_slots(_layout, grads))
            if self._jit:
                fn = jax.jit(fn)
            self._pack_fns[job_id] = fn
        return self.submit_packed(job_id, fn(grads))

    def submit_packed(self, job_id: str, packed) -> PushFuture:
        """Queue an ALREADY-PACKED job-local gradient vector (the layout's
        packed domain, e.g. from a custom jitted grad program) for the
        next tick; same bounded queue and backpressure as
        :meth:`submit_push`."""
        q = self._queue(job_id)
        while len(q) >= self.queue_capacity:
            self.stats.n_forced_capacity += 1
            self.tick()
        fut = PushFuture(job_id, self)
        q.append((packed, fut, self._epoch))
        return fut

    def step(self, job_id: str, batch) -> Dict[str, Any]:
        """One engine-mode iteration: pull (staleness-bounded), compute
        loss/grads, submit the push.  The update lands at a later tick;
        ``metrics["future"]`` tracks it."""
        q = self._queue(job_id)
        while self.outstanding(job_id) > self.max_staleness:
            self.stats.n_forced_staleness += 1
            self.tick()
        while len(q) >= self.queue_capacity:
            self.stats.n_forced_capacity += 1
            self.tick()
        fn = self._grad_fns.get(job_id)
        if fn is None:
            plan = self.plan
            layout = plan.job_layout(job_id)
            info = self.runtime._jobs[job_id]
            abstract, loss_fn = info["abstract"], info["loss_fn"]
            rows = jnp.asarray(layout.blocks)

            def fn(flat, batch, _layout=layout, _rows=rows,
                   _abstract=abstract, _loss=loss_fn):
                packed = (flat if _layout.covers_all else
                          flat.reshape(-1, _layout.block)[_rows].reshape(-1))
                params = _unpack_slots(_layout, packed, _abstract)
                loss, grads = jax.value_and_grad(_loss)(params, batch)
                return loss, _pack_slots(_layout, grads)

            if self._jit:
                fn = jax.jit(fn)
            self._grad_fns[job_id] = fn
        loss, packed = fn(self.runtime.state["flat"], batch)
        fut = PushFuture(job_id, self)
        q.append((packed, fut, self._epoch))
        return {"loss": loss, "future": fut}

    # ----------------------------------------------------------------- tick
    def tick(self, only=None) -> int:
        """One service tick: pop the head push of every pending job (or
        of the ``only`` subset during a replan quiesce) and apply them --
        in ONE batched pass when at least ``min_batch_jobs`` jobs are
        pending, as per-job block passes below that crossover (identical
        result, cheaper program).  Returns the number of jobs applied
        (0 = nothing pending)."""
        if self._poisoned:
            raise RuntimeError(
                "engine poisoned by a failed batched apply: the jitted "
                "applier donates the shared state buffers, so they may "
                "have been deleted mid-tick; restore/re-seed the "
                "runtime's state and attach a fresh engine before "
                "continuing")
        pending = [j for j in self.runtime._jobs
                   if self._queues.get(j) and (only is None or j in only)]
        if not pending:
            return 0
        # Epoch fence: a queued push packed under a different plan epoch
        # must never reach the apply -- touched jobs are drained before
        # the plan changes and untouched survivors are re-tagged, so a
        # mismatch here is a protocol violation, not a recoverable state.
        for j in pending:
            if self._queues[j][0][2] != self._epoch:
                raise RuntimeError(
                    f"epoch fence: job {j!r} queued a push under plan "
                    f"epoch {self._queues[j][0][2]} but the engine is at "
                    f"{self._epoch}; a replan migrated this job's layout "
                    f"without draining its queue")
        if 1 < len(pending) < self.min_batch_jobs:
            # Below the batching crossover: the same pushes as per-job
            # passes (disjoint blocks commute, so the result is
            # bit-identical to the one-launch concatenation).
            groups = [(j,) for j in pending]
            self.stats.n_per_job_dispatch += 1
        else:
            groups = [tuple(pending)]
        applied = 0
        for key in groups:
            heads = [self._queues[j].popleft() for j in key]
            try:
                applier = self._appliers.get(key)
                if applier is None:
                    applier = self._build_applier(key)
                    if len(self._appliers) >= self.MAX_APPLIERS:
                        # One program per pending-job SUBSET: bound the
                        # cache (FIFO eviction) so heterogeneous tick
                        # patterns can't accumulate 2^K compiled appliers.
                        self._appliers.pop(next(iter(self._appliers)))
                    self._appliers[key] = applier
                gs = tuple(packed for packed, _, _ in heads)
            except BaseException:
                # Build-time failure (e.g. a non-block-exclusive layout):
                # no device op ran, so re-queue the popped heads --
                # nothing is lost and a later tick can retry.
                for j, head in zip(key, heads):
                    self._queues[j].appendleft(head)
                raise
            try:
                self.runtime.state = applier(self.runtime.state, gs)
            except BaseException:
                # Execution failure: the jitted applier DONATES the state
                # buffers, so they may already be deleted -- no retry
                # against this state can succeed.  Re-queue the heads so
                # the pushes remain inspectable, and poison the engine so
                # later ticks (including PushFuture.result() loops) fail
                # fast with a clear message instead of spinning on dead
                # buffers.
                for j, head in zip(key, heads):
                    self._queues[j].appendleft(head)
                if self._jit:
                    self._poisoned = True
                raise
            for j, (_, fut, _) in zip(key, heads):
                self._counts[j] += 1
                fut._resolve(self._counts[j])
            applied += len(key)
        self.stats.n_ticks += 1
        self.stats.n_applied += applied
        self.stats.n_launches += len(groups)
        return applied

    def drain(self, only=None) -> int:
        """Quiesce: tick until every (selected) queue is empty.  Returns
        pushes applied."""
        applied = 0
        while True:
            n = self.tick(only=only)
            if n == 0:
                return applied
            applied += n

    def _build_applier(self, job_ids: Tuple[str, ...]) -> Callable:
        """Compile the batched apply for one combination of pending jobs.

        All plan-derived structures (concatenated owned-block table,
        per-job packed sizes, hyperparameters) are baked in at build time;
        the returned function is (state, packed_grads) -> state with ONE
        fused launch writing the updated flat/mu/nu blocks in place --
        no separate row-scatter passes (PR 6).
        """
        plan = self.plan
        layouts = [plan.job_layout(j) for j in job_ids]
        infos = [self.runtime._jobs[j] for j in job_ids]
        block_idx, job_sizes, hps = _fused_tables(layouts, infos,
                                                  _flat_job_hp)
        block, interpret = plan.block_align, self._interpret

        def apply(state, gs):
            counts = [state["counts"][j] + 1 for j in job_ids]
            new_state = _fused_state_update(
                state, gs, counts, block=block, block_idx=block_idx,
                job_sizes=job_sizes, hps=hps, interpret=interpret)
            new_state["counts"] = dict(
                state["counts"], **{j: c for j, c in zip(job_ids, counts)})
            return new_state

        # Donate the shared state: flat/mu/nu update in place per tick.
        return jax.jit(apply, donate_argnums=(0,)) if self._jit else apply


# --------------------------------------------------------------- sharded
class _ShardLane:
    """One shard space's service loop state: its own queues, compiled
    appliers, and TickStats -- the unit of independent cadence."""

    __slots__ = ("shard_id", "queues", "appliers", "stats")

    def __init__(self, shard_id: str):
        self.shard_id = shard_id
        self.queues: Dict[str, deque] = {}  # job -> (piece, count, fut, ep)
        self.appliers: Dict[Tuple[str, ...], Callable] = {}
        self.stats = TickStats()


class ShardedTickEngine:
    """Per-shard batched executor for one :class:`ShardedServiceRuntime`.

    Where :class:`ServiceTickEngine` runs ONE tick loop over one shared
    space, this engine runs one independent loop PER SHARD SPACE
    (``tick_shard``): a hot shard ticking fast never stalls a cold one,
    and the autoscaler reads each lane's :class:`TickStats` as its load
    signal.  A job's push splits into one packed PIECE per hosting shard,
    each tagged with the job's global step count at submit time -- Adam is
    elementwise, and each lane applies a job's pieces FIFO, so every lane
    preserves its lanes' per-element ``(gradient, step)`` sequence and the
    trajectory stays bit-exact with the unsharded engine no matter how
    shard cadences interleave.  ``tick()`` runs one round over every lane
    (the BSP convenience); staleness/capacity bounds are per job, taken
    over its hosting lanes.

    Replans reuse the flat engine's protocol: the runtime quiesces ONLY
    the jobs the sharded transition touches, surviving pushes are
    re-tagged across the per-push epoch fence, and lanes are keyed by the
    stable ``agg_id`` so an untouched job's queues and compiled programs
    ride straight through a neighboring shard's split or merge.

    ``fleet_tick`` selects how :meth:`tick` dispatches a round (PR 6):
    ``"fused"`` (the default) runs ONE fused launch over every lane with
    pending pieces -- the lanes' flat/mu/nu concatenate into one fleet
    view, the multi-job kernel runs once with globally-rebased block ids,
    and per-shard states slice back out -- while ``"per_shard"`` keeps
    the PR-5 one-launch-group-per-lane loop as a bit-parity oracle.  The
    attribute is mutable on purpose (benchmarks flip one engine between
    modes; the two paths keep separate applier caches).  Per-element math
    is identical either way, so the trajectories match bit-for-bit in
    eager mode.
    """

    MAX_APPLIERS = 32  # compiled programs per lane (one per job subset)

    def __init__(self, runtime, *, max_staleness: int = 1,
                 queue_capacity: Optional[int] = None, jit: bool = True,
                 interpret: Optional[bool] = None, min_batch_jobs: int = 3,
                 fleet_tick: str = "fused"):
        if max_staleness < 0:
            raise ValueError(f"max_staleness must be >= 0, got {max_staleness}")
        if fleet_tick not in ("fused", "per_shard"):
            raise ValueError(f"fleet_tick must be 'fused' or 'per_shard', "
                             f"got {fleet_tick!r}")
        self.runtime = runtime
        self.max_staleness = int(max_staleness)
        self.queue_capacity = (self.max_staleness + 1 if queue_capacity is None
                               else int(queue_capacity))
        if self.queue_capacity < 1:
            raise ValueError("queue_capacity must be >= 1")
        self.min_batch_jobs = int(min_batch_jobs)
        self.fleet_tick = fleet_tick
        self.stats = TickStats()  # fleet-aggregate counters
        self._poisoned = False
        self._jit = jit
        self._interpret = interpret
        self._epoch = 0
        self._lanes: Dict[str, _ShardLane] = {}
        self._counts: Dict[str, int] = {}  # job step mirror (submit time)
        # Fleet appliers are keyed by the whole pending pattern
        # ((shard_id, jobs), ...) -- separate from the per-lane caches.
        self._fleet_appliers: Dict[Tuple, Callable] = {}
        self._pull_fns: Dict[str, Callable] = {}
        self._grad_fns: Dict[str, Callable] = {}
        self._pack_fns: Dict[str, Callable] = {}

    # ------------------------------------------------------------- plumbing
    @property
    def plan(self):
        return self.runtime.splan

    def _lane(self, shard_id: str) -> _ShardLane:
        lane = self._lanes.get(shard_id)
        if lane is None:
            lane = self._lanes[shard_id] = _ShardLane(shard_id)
        return lane

    def _layout(self, job_id: str):
        info = self.runtime._jobs.get(job_id)
        if info is None:
            raise ValueError(f"unknown job {job_id!r}: not registered with "
                             f"the runtime (have {sorted(self.runtime._jobs)})")
        if info.get("step_opts", {}).get("push_compression"):
            raise ValueError(
                f"job {job_id!r} requests push_compression="
                f"{info['step_opts']['push_compression']!r}: the sharded "
                f"tick engine's batched apply has no error-feedback "
                f"buffer (the flat ServiceTickEngine rejects compressed "
                f"pushes the same way; step such jobs through "
                f"ServiceRuntime.step() on an unsharded runtime instead)")
        if job_id not in self._counts:
            self._counts[job_id] = int(jax.device_get(
                self.runtime.counts[job_id]))
        return self.plan.job_layout(job_id)

    def outstanding(self, job_id: str) -> int:
        """Deepest per-shard queue of the job's not-yet-applied pieces."""
        deepest = 0
        for lane in self._lanes.values():
            q = lane.queues.get(job_id)
            if q:
                deepest = max(deepest, len(q))
        return deepest

    def shard_stats(self) -> Dict[str, TickStats]:
        """Per-shard TickStats (the autoscaler's load signal)."""
        return {sid: lane.stats for sid, lane in self._lanes.items()}

    # ------------------------------------------------------------ data path
    def pull(self, job_id: str):
        """The job's parameters gathered across its hosting shards, after
        forcing tick rounds down to the staleness bound."""
        layout = self._layout(job_id)
        while self.outstanding(job_id) > self.max_staleness:
            self.stats.n_forced_staleness += 1
            self.tick()
        fn = self._pull_fns.get(job_id)
        if fn is None:
            abstract = self.runtime._jobs[job_id]["abstract"]
            rows = _layout_rows(layout)

            def fn(flats, _layout=layout, _rows=rows, _abstract=abstract):
                p = _gather_packed(_layout, _rows, flats)
                return _unpack_slots(_layout, p, _abstract)

            if self._jit:
                fn = jax.jit(fn)
            self._pull_fns[job_id] = fn
        return fn(tuple(self.runtime.states[sid]["flat"]
                        for sid in layout.shard_ids))

    def _enqueue(self, job_id: str, layout, pieces) -> PushFuture:
        count = self._counts[job_id] + 1
        self._counts[job_id] = count
        fut = PushFuture(job_id, self, parts=len(pieces))
        for sid, piece in zip(layout.shard_ids, pieces):
            self._lane(sid).queues.setdefault(job_id, deque()).append(
                (piece, count, fut, self._epoch))
        return fut

    def _force_capacity(self, job_id: str, layout) -> None:
        while True:
            full = [sid for sid in layout.shard_ids
                    if len(self._lane(sid).queues.get(job_id, ()))
                    >= self.queue_capacity]
            if not full:
                return
            self.stats.n_forced_capacity += 1
            for sid in full:
                self.tick_shard(sid)

    def submit_push(self, job_id: str, grads) -> PushFuture:
        """Queue a job's gradient pytree: one packed piece per hosting
        shard, applied by each shard's own ticks."""
        layout = self._layout(job_id)
        self._force_capacity(job_id, layout)
        fn = self._pack_fns.get(job_id)
        if fn is None:
            def fn(grads, _layout=layout):
                g = _pack_slots(_layout, grads)
                return _split_pieces(_layout, g)

            if self._jit:
                fn = jax.jit(fn)
            self._pack_fns[job_id] = fn
        return self._enqueue(job_id, layout, fn(grads))

    def step(self, job_id: str, batch) -> Dict[str, Any]:
        """One engine-mode iteration: staleness-bounded pull, loss/grads,
        one queued piece per hosting shard."""
        layout = self._layout(job_id)
        while self.outstanding(job_id) > self.max_staleness:
            self.stats.n_forced_staleness += 1
            self.tick()
        self._force_capacity(job_id, layout)
        fn = self._grad_fns.get(job_id)
        if fn is None:
            info = self.runtime._jobs[job_id]
            abstract, loss_fn = info["abstract"], info["loss_fn"]
            rows = _layout_rows(layout)

            def fn(flats, batch, _layout=layout, _rows=rows,
                   _abstract=abstract, _loss=loss_fn):
                params = _unpack_slots(
                    _layout, _gather_packed(_layout, _rows, flats),
                    _abstract)
                loss, grads = jax.value_and_grad(_loss)(params, batch)
                return loss, _split_pieces(_layout, _pack_slots(_layout,
                                                                grads))

            if self._jit:
                fn = jax.jit(fn)
            self._grad_fns[job_id] = fn
        loss, pieces = fn(
            tuple(self.runtime.states[sid]["flat"]
                  for sid in layout.shard_ids), batch)
        return {"loss": loss,
                "future": self._enqueue(job_id, layout, pieces)}

    # ----------------------------------------------------------------- tick
    def tick_shard(self, shard_id: str, only=None) -> int:
        """One tick of ONE shard space: pop the head piece of every
        pending job on this lane and apply them in one per-shard pass
        (batched at/above ``min_batch_jobs`` pending jobs).  Other shards
        are untouched -- this is the independent cadence primitive."""
        if self._poisoned:
            raise RuntimeError(
                "engine poisoned by a failed shard apply: the jitted "
                "applier donates the shard's state buffers, so they may "
                "have been deleted mid-tick; restore/re-seed the "
                "runtime's state and attach a fresh engine")
        lane = self._lanes.get(shard_id)
        if lane is None:
            return 0
        pending = [j for j in self.runtime._jobs
                   if lane.queues.get(j) and (only is None or j in only)]
        if not pending:
            return 0
        for j in pending:
            if lane.queues[j][0][3] != self._epoch:
                raise RuntimeError(
                    f"epoch fence: job {j!r} queued a piece on shard "
                    f"{shard_id!r} under plan epoch {lane.queues[j][0][3]} "
                    f"but the engine is at {self._epoch}; a replan "
                    f"migrated this job's layout without draining it")
        if 1 < len(pending) < self.min_batch_jobs:
            groups = [(j,) for j in pending]
            lane.stats.n_per_job_dispatch += 1
        else:
            groups = [tuple(pending)]
        applied = 0
        for key in groups:
            heads = [lane.queues[j].popleft() for j in key]
            try:
                applier = lane.appliers.get(key)
                if applier is None:
                    applier = self._build_applier(shard_id, key)
                    if len(lane.appliers) >= self.MAX_APPLIERS:
                        lane.appliers.pop(next(iter(lane.appliers)))
                    lane.appliers[key] = applier
                gs = tuple(piece for piece, _, _, _ in heads)
                counts = tuple(count for _, count, _, _ in heads)
            except BaseException:
                # Build-time failure: no device op ran; re-queue and let a
                # later tick retry.
                for j, head in zip(key, heads):
                    lane.queues[j].appendleft(head)
                raise
            try:
                self.runtime.states[shard_id] = applier(
                    self.runtime.states[shard_id], gs, counts)
            except BaseException:
                # Execution failure: the jitted applier DONATED this
                # shard's buffers -- poison so later ticks fail fast.
                for j, head in zip(key, heads):
                    lane.queues[j].appendleft(head)
                if self._jit:
                    self._poisoned = True
                raise
            for _, count, fut, _ in heads:
                fut._resolve(count)
                if fut.done():
                    # The push applied on its LAST hosting shard: commit
                    # the job's global step counter (per-shard states
                    # carry no counts -- the runtime owns them, and a
                    # checkpoint must see every applied push).
                    self.runtime.counts[fut.job_id] = jnp.asarray(
                        count, jnp.int32)
            applied += len(key)
        lane.stats.n_ticks += 1
        lane.stats.n_applied += applied
        lane.stats.n_launches += len(groups)
        self.stats.n_ticks += 1
        self.stats.n_applied += applied
        self.stats.n_launches += len(groups)
        return applied

    def tick(self, only=None) -> int:
        """One ROUND over the fleet.  With ``fleet_tick="fused"`` (the
        default) this is ONE fused launch covering every lane with
        pending pieces (:meth:`tick_fleet`); with ``"per_shard"`` it
        ticks every live shard once, one launch group per lane (the PR-5
        oracle path).  Returns pieces applied (0 = nothing pending
        anywhere)."""
        plan = self.plan
        if plan is None:
            return 0
        if self.fleet_tick == "fused":
            return self.tick_fleet(only=only)
        return sum(self.tick_shard(sid, only=only)
                   for sid in plan.shard_ids)

    def tick_fleet(self, only=None) -> int:
        """One FLEET tick: pop the head piece of every pending job on
        EVERY lane and apply all of them in ONE fused launch over the
        pending lanes' concatenated states.  Lanes with nothing pending
        are skipped mid-table -- they contribute neither state movement
        nor launch cost, and their cadence is untouched.  Returns pieces
        applied across the fleet (0 = nothing pending anywhere)."""
        if self._poisoned:
            raise RuntimeError(
                "engine poisoned by a failed fleet apply: the jitted "
                "applier donates every pending shard's state buffers, so "
                "they may have been deleted mid-tick; restore/re-seed "
                "the runtime's state and attach a fresh engine")
        plan = self.plan
        if plan is None:
            return 0
        entries = []
        for sid in plan.shard_ids:
            lane = self._lanes.get(sid)
            if lane is None:
                continue
            pending = tuple(
                j for j in self.runtime._jobs
                if lane.queues.get(j) and (only is None or j in only))
            if not pending:
                continue
            for j in pending:
                if lane.queues[j][0][3] != self._epoch:
                    raise RuntimeError(
                        f"epoch fence: job {j!r} queued a piece on shard "
                        f"{sid!r} under plan epoch "
                        f"{lane.queues[j][0][3]} but the engine is at "
                        f"{self._epoch}; a replan migrated this job's "
                        f"layout without draining it")
            entries.append((sid, pending))
        if not entries:
            return 0
        key = tuple(entries)
        # Build BEFORE popping: a build failure (e.g. mixed block_align
        # across lanes) leaves every queue untouched for a later retry.
        applier = self._fleet_appliers.get(key)
        if applier is None:
            applier = self._build_fleet_applier(key)
            if len(self._fleet_appliers) >= self.MAX_APPLIERS:
                self._fleet_appliers.pop(next(iter(self._fleet_appliers)))
            self._fleet_appliers[key] = applier
        popped = []  # (sid, job, head) in key order == table order
        for sid, jobs in key:
            lane = self._lanes[sid]
            for j in jobs:
                popped.append((sid, j, lane.queues[j].popleft()))
        gs = tuple(head[0] for _, _, head in popped)
        counts = tuple(head[1] for _, _, head in popped)
        states = tuple(self.runtime.states[sid] for sid, _ in key)
        try:
            new_states = applier(states, gs, counts)
        except BaseException:
            # Execution failure: the jitted applier DONATED every pending
            # shard's buffers -- re-queue the heads so the pieces stay
            # inspectable and poison so later ticks fail fast.
            for sid, j, head in popped:
                self._lanes[sid].queues[j].appendleft(head)
            if self._jit:
                self._poisoned = True
            raise
        for (sid, _), st in zip(key, new_states):
            self.runtime.states[sid] = st
        for _, _, (_, count, fut, _) in popped:
            fut._resolve(count)
            if fut.done():
                # Applied on its LAST hosting shard: commit the job's
                # global step counter (the runtime owns counts).
                self.runtime.counts[fut.job_id] = jnp.asarray(
                    count, jnp.int32)
        for sid, jobs in key:
            lane = self._lanes[sid]
            lane.stats.n_ticks += 1
            lane.stats.n_applied += len(jobs)
        self.stats.n_ticks += 1
        self.stats.n_applied += len(popped)
        self.stats.n_launches += 1  # the whole point: ONE launch per fleet
        return len(popped)

    def drain(self, only=None) -> int:
        """Tick rounds until every (selected) queue on every lane is
        empty.  Returns pieces applied."""
        applied = 0
        while True:
            n = self.tick(only=only)
            if n == 0:
                return applied
            applied += n

    def quiesce_for_replan(self, touched) -> int:
        """Drain ONLY the touched jobs' pieces (on every lane) ahead of a
        sharded migration; untouched lanes and jobs keep their cadence."""
        applied = 0
        while True:
            pending = [j for j in touched
                       if any(lane.queues.get(j)
                              for lane in self._lanes.values())]
            if not pending:
                return applied
            self.stats.n_forced_replan += 1
            applied += self.tick(only=pending)

    # --------------------------------------------------------------- replan
    def _on_plan_change(self, touched=None) -> None:
        """Sharded replan landed: invalidate what the new plan breaks.

        Same fence protocol as the flat engine, per lane: ``touched=None``
        requires every queue empty and drops everything; with a touched
        set, only touched jobs' programs die, lanes whose Aggregator left
        the fleet are dropped (their jobs are touched by construction, so
        their queues are already drained), and untouched jobs' surviving
        pieces are re-tagged to the new epoch."""
        self._epoch += 1
        self.stats.n_replans += 1
        # Fleet appliers bake EVERY participating shard's length into the
        # concatenated-view offsets, so any plan change invalidates all
        # of them (per-lane appliers survive for untouched jobs).
        self._fleet_appliers.clear()
        if touched is None:
            assert not any(q for lane in self._lanes.values()
                           for q in lane.queues.values()), (
                "replan with queued pieces: runtime must drain the "
                "engine first")
            self._lanes.clear()
            self._pull_fns.clear()
            self._grad_fns.clear()
            self._pack_fns.clear()
            return
        touched = set(touched)
        live = set(self.plan.shard_ids) if self.plan is not None else set()
        for sid in list(self._lanes):
            lane = self._lanes[sid]
            for j in touched:
                assert not lane.queues.get(j), (
                    f"replan with queued pieces for TOUCHED job {j!r} on "
                    f"shard {sid!r}: quiesce_for_replan must drain it")
            if sid not in live:
                assert not any(lane.queues.values()), (
                    f"shard {sid!r} left the fleet with queued pieces")
                del self._lanes[sid]
                continue
            for j, q in lane.queues.items():
                if q:  # untouched by construction: carry across the fence
                    self.stats.n_retagged += len(q)
                    lane.queues[j] = deque(
                        (piece, count, fut, self._epoch)
                        for piece, count, fut, _ in q)
            for j in touched:
                lane.queues.pop(j, None)
            lane.appliers = {k: v for k, v in lane.appliers.items()
                             if not touched.intersection(k)}
        for j in touched:
            self._pull_fns.pop(j, None)
            self._grad_fns.pop(j, None)
            self._pack_fns.pop(j, None)

    def _forget_job(self, job_id: str) -> None:
        for lane in self._lanes.values():
            q = lane.queues.pop(job_id, None)
            if q:
                for _, _, fut, _ in q:
                    fut._cancel(
                        "job removed from the runtime with this piece "
                        "still queued (drain was bypassed)")
            lane.appliers = {k: v for k, v in lane.appliers.items()
                             if job_id not in k}
        self._fleet_appliers = {
            k: v for k, v in self._fleet_appliers.items()
            if not any(job_id in jobs for _, jobs in k)}
        self._counts.pop(job_id, None)
        self._pull_fns.pop(job_id, None)
        self._grad_fns.pop(job_id, None)
        self._pack_fns.pop(job_id, None)

    # -------------------------------------------------------------- applier
    def _build_applier(self, shard_id: str, job_ids: Tuple[str, ...]):
        """Compile the batched apply for one shard space and one pending
        job combination.  Identical math to the flat engine's applier --
        one fused launch over THIS shard's buffers, updated blocks
        written in place (PR 6) -- except the per-job step counts arrive
        with the queued pieces (assigned at submit time), so inter-shard
        apply order cannot skew bias correction."""
        shard_plan = self.plan.shard_of(shard_id)
        layouts = [shard_plan.job_layout(j) for j in job_ids]
        infos = [self.runtime._jobs[j] for j in job_ids]
        block_idx, job_sizes, hps = _fused_tables(layouts, infos,
                                                  _sharded_job_hp)
        block, interpret = shard_plan.block_align, self._interpret

        def apply(state, gs, counts):
            # Counts arrive as the pieces' submit-time step numbers; lift
            # to arrays so eager mode matches the traced path exactly.
            counts = [jnp.asarray(c, jnp.int32) for c in counts]
            return _fused_state_update(
                state, gs, counts, block=block, block_idx=block_idx,
                job_sizes=job_sizes, hps=hps, interpret=interpret)

        return jax.jit(apply, donate_argnums=(0,)) if self._jit else apply

    def _build_fleet_applier(self, key) -> Callable:
        """Compile the SINGLE-LAUNCH fleet apply for one pending pattern.

        ``key`` is ``((shard_id, (job, ...)), ...)`` over the lanes with
        pending pieces, in plan order.  The applier concatenates those
        lanes' flat/mu/nu into one fleet view, runs ONE fused multi-job
        launch whose block table is globally rebased (shard base offset
        // block + local block id), and slices the per-shard states back
        out -- one XLA program and one kernel launch no matter how many
        lanes ticked.  Block exclusivity holds globally because each
        shard's offset is block-aligned, so the launch is bit-exact with
        the per-shard oracle loop."""
        plan = self.plan
        sids = [sid for sid, _ in key]
        offsets, _, block = plan.concat_view(sids)
        lens = [plan.shard_of(sid).total_len for sid in sids]
        layouts, infos, bases = [], [], []
        for (sid, jobs), off in zip(key, offsets):
            shard_plan = plan.shard_of(sid)
            for j in jobs:
                layouts.append(shard_plan.job_layout(j))
                infos.append(self.runtime._jobs[j])
                bases.append(off // block)
        block_idx, job_sizes, hps = _fused_tables(
            layouts, infos, _sharded_job_hp, base_blocks=bases)
        interpret = self._interpret

        def cat(bufs):
            return jnp.concatenate(bufs) if len(bufs) > 1 else bufs[0]

        def apply(states, gs, counts):
            fleet = {k: cat([s[k] for s in states])
                     for k in ("flat", "mu", "nu")}
            counts = [jnp.asarray(c, jnp.int32) for c in counts]
            new = _fused_state_update(
                fleet, gs, counts, block=block, block_idx=block_idx,
                job_sizes=job_sizes, hps=hps, interpret=interpret)
            return tuple(
                dict(st, flat=new["flat"][lo:lo + n],
                     mu=new["mu"][lo:lo + n], nu=new["nu"][lo:lo + n])
                for st, lo, n in zip(states, offsets, lens))

        return jax.jit(apply, donate_argnums=(0,)) if self._jit else apply


