"""ServiceRuntime: the data-plane executor of a shared ParameterService.

Owns ONE flat aggregation space (flat/mu/nu [+ per-job step counters]) laid
out by the service's compiled plan, with every registered job training
through its own owned blocks of that space (O(job-bytes) per step via the
plan's precompiled index maps; pass ``update_mode="masked"`` per job for
the legacy full-space path).  Subscribes to the control
plane's replan events: whenever ``register_job`` / ``job_exit`` /
``periodic_rebalance`` changes the tensor->Aggregator assignment, the
shared state is migrated onto the new layout (``migrate_flat_state``) and
every job's train step is rebuilt against the new plan -- no job restarts,
which is the paper's elastic-aggregation claim end to end:

    control plane packing  ->  ServicePlan  ->  shared flat state
         (Pseudocode 1)        (ps.plan)      (this module + runtime)

Usage::

    svc = ParameterService(total_budget=8)
    rt = ServiceRuntime(svc)
    rt.add_job("mlp", params_a, loss_a, required_servers=2)
    rt.add_job("lm", params_b, loss_b, required_servers=2)
    for batch in data:
        metrics = rt.step("mlp", batch)      # only mlp's segments change

Replans execute as DELTA migrations by default (``migration="delta"``):
the runtime compiles a :class:`repro.ps.elastic.MigrationDelta` for the
plan pair and relocates only the moved runs (O(moved bytes), one
run-copy pass -- repro.kernels.relayout), with the full-gather path
(``migration="gather"``) kept as the parity oracle.

With an attached :class:`repro.ps.engine.ServiceTickEngine`
(``rt.attach_engine()``), jobs instead submit pushes into per-job bounded
queues and the engine applies all pending jobs per tick in one batched
pass; replans are STALL-FREE for untouched jobs: only the jobs the
delta names as touched are quiesced (their queued pushes apply against
the old plan before the state migrates), everyone else keeps queues,
compiled programs, and tick cadence straight through the transition --
and training stays bit-exact with the per-job step path across
migrations (the engine's per-push epoch fence enforces it).
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.ps.elastic import (
    compile_migration_delta,
    migrate_flat_state,
    migrate_flat_state_delta,
    migration_bytes,
)
from repro.ps.plan import FlatPlan
from repro.ps.runtime import (
    init_shared_state,
    job_profile_from_tree,
    make_ps_train_step,
    seed_job_params,
    unflatten_tree,
)


class ServiceRuntime:
    """Shared flat-state executor bound to one ParameterService."""

    def __init__(self, service, jit: bool = True, migration: str = "delta"):
        if migration not in ("delta", "gather"):
            raise ValueError(f"unknown migration mode {migration!r}")
        self.service = service
        self.plan: Optional[FlatPlan] = None
        self.state: Optional[Dict[str, Any]] = None
        self.last_migration_bytes = 0  # cross-shard bytes (paper accounting)
        self.total_migration_bytes = 0
        self.last_relayout_bytes = 0  # flat-space bytes the delta path moved
        self.total_relayout_bytes = 0
        self.last_replan_touched: tuple = ()
        self.n_replans = 0
        self.migration = migration
        self._jit = jit
        self._jobs: Dict[str, Dict[str, Any]] = {}
        self._steps: Dict[str, Callable] = {}
        self._engine = None
        service.on_replan(self._on_replan)

    def attach_engine(self, **engine_opts):
        """Create (once) and return the service-tick engine for this
        runtime (see :class:`repro.ps.engine.ServiceTickEngine`): batched
        multi-job ticks with bounded staleness instead of per-job
        immediate steps."""
        from repro.ps.engine import ServiceTickEngine

        if self._engine is None:
            self._engine = ServiceTickEngine(self, **engine_opts)
        elif engine_opts:
            raise ValueError("engine already attached; cannot re-configure")
        return self._engine

    @property
    def engine(self):
        return self._engine

    # ----------------------------------------------------------------- jobs
    def add_job(
        self,
        job_id: str,
        params,
        loss_fn: Callable[[Any, Any], Any],
        *,
        iteration_duration: float = 1.0,
        n_workers: int = 2,
        required_servers: int = 1,
        agg_throughput: float = 7e9,
        lr: float = 3e-4,
        **step_opts,
    ) -> None:
        """Register a training job with the service and seed its parameters
        into the shared flat space.  Triggers a replan (and a migration of
        all co-resident jobs' state) if placement changes."""
        if job_id in self._jobs:
            raise ValueError(f"job {job_id} already in the runtime")
        abstract = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params
        )
        profile, specs = job_profile_from_tree(
            job_id, params,
            iteration_duration=iteration_duration,
            n_workers=n_workers,
            required_servers=required_servers,
            agg_throughput=agg_throughput,
        )
        self._jobs[job_id] = dict(
            loss_fn=loss_fn, abstract=abstract, lr=lr, step_opts=step_opts
        )
        try:
            self.service.register_job(profile, specs=specs)
        except Exception:
            self._jobs.pop(job_id, None)
            raise
        # The replan listener has already moved the shared state onto the
        # new plan; the new job's lanes are zero until seeded here.
        self.state = seed_job_params(self.plan, self.state, job_id, params)

    def remove_job(self, job_id: str) -> None:
        """Job exit: its segments are dropped from the plan; everyone else's
        state survives (possibly consolidated by Aggregator recycling).

        Raises ``ValueError`` for a job this runtime does not know,
        leaving runtime and service state untouched."""
        if job_id not in self._jobs:
            raise ValueError(
                f"unknown job {job_id!r}: not registered with this runtime "
                f"(have {sorted(self._jobs)})")
        if self._engine is not None:
            # Quiesce the EXITING job before its segments leave the plan:
            # its queued pushes apply against the old layout.  Co-resident
            # jobs keep their queues; the replan below only drains the
            # ones whose layout the exit actually disturbs.
            self._engine.quiesce_for_replan([job_id])
            self._engine._forget_job(job_id)
        self._jobs.pop(job_id)
        self._steps.pop(job_id, None)
        self.service.job_exit(job_id)
        if self.state is not None and job_id in self.state.get("counts", {}):
            counts = dict(self.state["counts"])
            counts.pop(job_id)
            self.state = dict(self.state, counts=counts)

    @property
    def job_ids(self):
        return tuple(self._jobs)

    # ------------------------------------------------------------- training
    def step(self, job_id: str, batch):
        """One pull->compute->push->update iteration for one job, against
        the shared state."""
        self.state, metrics = self._steps[job_id](self.state, batch)
        return metrics

    def params_of(self, job_id: str):
        """Current parameters of one job, pulled from the shared space."""
        return unflatten_tree(
            self.plan, self.state["flat"], self._jobs[job_id]["abstract"],
            job_id=job_id,
        )

    # --------------------------------------------------------------- replan
    def _needs_ef(self) -> bool:
        return any(info["step_opts"].get("push_compression")
                   for info in self._jobs.values())

    def _on_replan(self, old: Optional[FlatPlan], new: Optional[FlatPlan]):
        engine = self._engine
        if new is None:  # last job exited
            if engine is not None and self.state is not None:
                engine.drain()
            self.plan, self.state, self._steps = None, None, {}
            if engine is not None:
                engine._on_plan_change()
            return
        delta = None
        touched = None  # None = every job's layout may have changed
        if self.state is not None and old is not None:
            if self.migration == "delta":
                # Delta replan: quiesce ONLY the jobs whose layout the
                # transition disturbs -- their queued pushes apply
                # against the OLD plan; untouched jobs keep ticking.
                delta = compile_migration_delta(old, new)
                touched = set(delta.touched_jobs)
                if engine is not None:
                    engine.quiesce_for_replan(
                        [j for j in touched if j in self._jobs])
                self.state = migrate_flat_state_delta(
                    self.state, old, new, delta=delta)
                self.last_relayout_bytes = delta.moved_bytes()
                self.total_relayout_bytes += self.last_relayout_bytes
            else:
                # Full-gather oracle path: hard-quiesce everything.
                if engine is not None:
                    engine.drain()
                self.state = migrate_flat_state(self.state, old, new)
            moved = migration_bytes(old, new)
            self.last_migration_bytes = moved
            self.total_migration_bytes += moved
            self.n_replans += 1
            self.last_replan_touched = (tuple(sorted(touched))
                                        if touched is not None
                                        else tuple(self._jobs))
        else:
            if engine is not None and self.state is not None:
                engine.drain()
            self.state = init_shared_state(new, needs_ef=self._needs_ef())
        if self._needs_ef() and "ef" not in self.state:
            # A compressed job joined a runtime whose state predates it.
            self.state = dict(self.state,
                              ef=jnp.zeros_like(self.state["flat"]))
        self.plan = new
        if engine is not None:
            engine._on_plan_change(touched)
        steps: Dict[str, Callable] = {}
        for job_id, info in self._jobs.items():
            # An untouched block-mode job's step closes over a layout that
            # is bit-identical in the new plan: keep its compiled program
            # (no retrace, no stall).  Masked-mode jobs close over the
            # full space and rebuild on every plan change.
            if (touched is not None and job_id not in touched
                    and job_id in self._steps
                    and info["step_opts"].get("update_mode",
                                              "block") == "block"):
                steps[job_id] = self._steps[job_id]
                continue
            step = make_ps_train_step(
                info["loss_fn"], new, info["abstract"],
                lr=info["lr"], job_id=job_id, **info["step_opts"],
            )
            # Donate the shared state so flat/mu/nu update in place instead
            # of doubling peak memory on every step.
            steps[job_id] = (
                jax.jit(step, donate_argnums=(0,)) if self._jit else step
            )
        self._steps = steps
