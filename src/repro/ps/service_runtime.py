"""ServiceRuntime: the data-plane executor of a shared ParameterService.

Owns ONE flat aggregation space (flat/mu/nu [+ per-job step counters]) laid
out by the service's compiled plan, with every registered job training
through its own owned blocks of that space (O(job-bytes) per step via the
plan's precompiled index maps; pass ``update_mode="masked"`` per job for
the legacy full-space path).  Subscribes to the control
plane's replan events: whenever ``register_job`` / ``job_exit`` /
``periodic_rebalance`` changes the tensor->Aggregator assignment, the
shared state is migrated onto the new layout (``migrate_flat_state``) and
every job's train step is rebuilt against the new plan -- no job restarts,
which is the paper's elastic-aggregation claim end to end:

    control plane packing  ->  ServicePlan  ->  shared flat state
         (Pseudocode 1)        (ps.plan)      (this module + runtime)

Usage::

    svc = ParameterService(total_budget=8)
    rt = ServiceRuntime(svc)
    rt.add_job("mlp", params_a, loss_a, required_servers=2)
    rt.add_job("lm", params_b, loss_b, required_servers=2)
    for batch in data:
        metrics = rt.step("mlp", batch)      # only mlp's segments change

Replans execute as DELTA migrations by default (``migration="delta"``):
the runtime compiles a :class:`repro.ps.elastic.MigrationDelta` for the
plan pair and relocates only the moved runs (O(moved bytes), one
run-copy pass -- repro.kernels.relayout), with the full-gather path
(``migration="gather"``) kept as the parity oracle.

With an attached :class:`repro.ps.engine.ServiceTickEngine`
(``rt.attach_engine()``), jobs instead submit pushes into per-job bounded
queues and the engine applies all pending jobs per tick in one batched
pass; replans are STALL-FREE for untouched jobs: only the jobs the
delta names as touched are quiesced (their queued pushes apply against
the old plan before the state migrates), everyone else keeps queues,
compiled programs, and tick cadence straight through the transition --
and training stays bit-exact with the per-job step path across
migrations (the engine's per-push epoch fence enforces it).

:class:`ShardedServiceRuntime` is the PR-5 sibling: instead of one flat
space it gives every live Aggregator its OWN shard space, so the fleet
size set by the control plane (and by the load-driven
``repro.ps.autoscaler.ElasticScaler``) changes what actually executes.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.ps.compression import ef_transform
from repro.ps.faults import QUARANTINED

from repro.ps.elastic import (
    compile_migration_delta,
    migrate_flat_state,
    migrate_flat_state_delta,
    migrate_sharded_state,
    migration_bytes,
    plan_cache_stats,
    sharded_transition_summary,
)
from repro.ps.plan import FlatPlan, ShardedPlan
from repro.ps.runtime import (
    _adam_math,
    _gather_owned,
    _gather_packed,
    _gather_pieces,
    _layout_rows,
    _pack_slots,
    _scatter_owned,
    _split_pieces,
    _unpack_slots,
    init_shared_state,
    job_profile_from_tree,
    make_ps_train_step,
    seed_job_params,
    unflatten_tree,
)


class ServiceRuntime:
    """Shared flat-state executor bound to one ParameterService."""

    def __init__(self, service, jit: bool = True, migration: str = "delta"):
        if migration not in ("delta", "gather"):
            raise ValueError(f"unknown migration mode {migration!r}")
        self.service = service
        self.plan: Optional[FlatPlan] = None
        self.state: Optional[Dict[str, Any]] = None
        self.last_migration_bytes = 0  # cross-shard bytes (paper accounting)
        self.total_migration_bytes = 0
        self.last_relayout_bytes = 0  # flat-space bytes the delta path moved
        self.total_relayout_bytes = 0
        self.last_replan_touched: tuple = ()
        self.n_replans = 0
        self.migration = migration
        self._jit = jit
        self._jobs: Dict[str, Dict[str, Any]] = {}
        self._steps: Dict[str, Callable] = {}
        self._engine = None
        service.on_replan(self._on_replan)

    def attach_engine(self, **engine_opts):
        """Create (once) and return the service-tick engine for this
        runtime (see :class:`repro.ps.engine.ServiceTickEngine`): batched
        multi-job ticks with bounded staleness instead of per-job
        immediate steps."""
        from repro.ps.engine import ServiceTickEngine

        if self._engine is None:
            self._engine = ServiceTickEngine(self, **engine_opts)
        elif engine_opts:
            raise ValueError("engine already attached; cannot re-configure")
        return self._engine

    @property
    def engine(self):
        return self._engine

    def debug_stats(self) -> Dict[str, Any]:
        """One dict unifying the plan-pair cache, this runtime's migration
        counters, and the attached engine's TickStats (None detached)."""
        return _debug_stats(self, {"migration": self.migration})

    # ----------------------------------------------------------------- jobs
    def add_job(
        self,
        job_id: str,
        params,
        loss_fn: Callable[[Any, Any], Any],
        *,
        iteration_duration: float = 1.0,
        n_workers: int = 2,
        required_servers: int = 1,
        agg_throughput: float = 7e9,
        lr: float = 3e-4,
        **step_opts,
    ) -> None:
        """Register a training job with the service and seed its parameters
        into the shared flat space.  Triggers a replan (and a migration of
        all co-resident jobs' state) if placement changes."""
        if job_id in self._jobs:
            raise ValueError(f"job {job_id} already in the runtime")
        abstract = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params
        )
        profile, specs = job_profile_from_tree(
            job_id, params,
            iteration_duration=iteration_duration,
            n_workers=n_workers,
            required_servers=required_servers,
            agg_throughput=agg_throughput,
        )
        self._jobs[job_id] = dict(
            loss_fn=loss_fn, abstract=abstract, lr=lr, step_opts=step_opts
        )
        try:
            self.service.register_job(profile, specs=specs)
        except Exception:
            self._jobs.pop(job_id, None)
            raise
        # The replan listener has already moved the shared state onto the
        # new plan; the new job's lanes are zero until seeded here.
        self.state = seed_job_params(self.plan, self.state, job_id, params)

    def remove_job(self, job_id: str) -> None:
        """Job exit: its segments are dropped from the plan; everyone else's
        state survives (possibly consolidated by Aggregator recycling).

        Raises ``ValueError`` for a job this runtime does not know,
        leaving runtime and service state untouched."""
        if job_id not in self._jobs:
            raise ValueError(
                f"unknown job {job_id!r}: not registered with this runtime "
                f"(have {sorted(self._jobs)})")
        if self._engine is not None:
            # Quiesce the EXITING job before its segments leave the plan:
            # its queued pushes apply against the old layout.  Co-resident
            # jobs keep their queues; the replan below only drains the
            # ones whose layout the exit actually disturbs.
            self._engine.quiesce_for_replan([job_id])
            self._engine._forget_job(job_id)
        info = self._jobs.pop(job_id)
        step = self._steps.pop(job_id, None)
        try:
            self.service.job_exit(job_id)
        except Exception:
            # The exit replan aborted with the registry rolled back (see
            # ParameterService._transact): restore this runtime's entries
            # so both planes still agree the job is live.  Its queues
            # were already drained, so nothing was lost.
            self._jobs[job_id] = info
            if step is not None:
                self._steps[job_id] = step
            raise
        if self.state is not None and job_id in self.state.get("counts", {}):
            counts = dict(self.state["counts"])
            counts.pop(job_id)
            self.state = dict(self.state, counts=counts)

    @property
    def job_ids(self):
        return tuple(self._jobs)

    # ------------------------------------------------------------- training
    def step(self, job_id: str, batch):
        """One pull->compute->push->update iteration for one job, against
        the shared state."""
        self.state, metrics = self._steps[job_id](self.state, batch)
        return metrics

    def params_of(self, job_id: str):
        """Current parameters of one job, pulled from the shared space."""
        return unflatten_tree(
            self.plan, self.state["flat"], self._jobs[job_id]["abstract"],
            job_id=job_id,
        )

    # --------------------------------------------------------------- replan
    def _needs_ef(self) -> bool:
        return any(info["step_opts"].get("push_compression")
                   for info in self._jobs.values())

    def _on_replan(self, old: Optional[FlatPlan], new: Optional[FlatPlan]):
        engine = self._engine
        if new is None:  # last job exited
            if engine is not None and self.state is not None:
                engine.drain()
            self.plan, self.state, self._steps = None, None, {}
            if engine is not None:
                engine._on_plan_change()
            return
        # Everything up to the COMMIT below is computed into locals: the
        # migration functions are functional over the old state, so a
        # failure anywhere (e.g. an injected migration fault) leaves
        # plan/state/_steps on the old layout for the service's replan
        # transaction to roll the registry back against (PR 9).
        delta = None
        touched = None  # None = every job's layout may have changed
        relayout_bytes = 0
        migrated = self.state is not None and old is not None
        if migrated:
            if self.migration == "delta":
                # Delta replan: quiesce ONLY the jobs whose layout the
                # transition disturbs -- their queued pushes apply
                # against the OLD plan; untouched jobs keep ticking.
                delta = compile_migration_delta(old, new)
                touched = set(delta.touched_jobs)
                if engine is not None:
                    engine.quiesce_for_replan(
                        [j for j in touched if j in self._jobs])
                state = migrate_flat_state_delta(
                    self.state, old, new, delta=delta)
                relayout_bytes = delta.moved_bytes()
            else:
                # Full-gather oracle path: hard-quiesce everything.
                if engine is not None:
                    engine.drain()
                state = migrate_flat_state(self.state, old, new)
        else:
            if engine is not None and self.state is not None:
                engine.drain()
            state = init_shared_state(new, needs_ef=self._needs_ef())
        if self._needs_ef() and "ef" not in state:
            # A compressed job joined a runtime whose state predates it.
            state = dict(state, ef=jnp.zeros_like(state["flat"]))
        steps: Dict[str, Callable] = {}
        for job_id, info in self._jobs.items():
            # An untouched block-mode job's step closes over a layout that
            # is bit-identical in the new plan: keep its compiled program
            # (no retrace, no stall).  Masked-mode jobs close over the
            # full space and rebuild on every plan change.
            if (touched is not None and job_id not in touched
                    and job_id in self._steps
                    and info["step_opts"].get("update_mode",
                                              "block") == "block"):
                steps[job_id] = self._steps[job_id]
                continue
            step = make_ps_train_step(
                info["loss_fn"], new, info["abstract"],
                lr=info["lr"], job_id=job_id, **info["step_opts"],
            )
            # Donate the shared state so flat/mu/nu update in place instead
            # of doubling peak memory on every step.
            steps[job_id] = (
                jax.jit(step, donate_argnums=(0,)) if self._jit else step
            )
        # ---- COMMIT: the new layout becomes visible as a unit ----
        self.state = state
        if migrated:
            if delta is not None:
                self.last_relayout_bytes = relayout_bytes
                self.total_relayout_bytes += relayout_bytes
            moved = migration_bytes(old, new)
            self.last_migration_bytes = moved
            self.total_migration_bytes += moved
            self.n_replans += 1
            self.last_replan_touched = (tuple(sorted(touched))
                                        if touched is not None
                                        else tuple(self._jobs))
        self.plan = new
        if engine is not None:
            engine._on_plan_change(touched)
        self._steps = steps


# --------------------------------------------------------------------------
def _debug_stats(rt, extra_runtime: Dict[str, Any],
                 shards: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """Shared debug_stats assembly for both runtimes: plan-pair cache +
    migration counters + the service's replan-transaction counters + the
    attached engine's TickStats and fault-injector fire counts; the
    sharded runtime adds its per-shard section via ``shards``."""
    import dataclasses

    engine = rt._engine
    injector = engine.fault_injector if engine is not None else None
    out = {
        "plan_cache": plan_cache_stats(),
        "runtime": {
            "n_jobs": len(rt._jobs),
            "n_replans": rt.n_replans,
            "migration_bytes_total": rt.total_migration_bytes,
            "relayout_bytes_total": rt.total_relayout_bytes,
            "last_replan_touched": list(rt.last_replan_touched),
            **extra_runtime,
        },
        "transactions": {
            "n_replan_commits": rt.service.n_replan_commits,
            "n_replan_aborts": rt.service.n_replan_aborts,
            "n_replan_retries": rt.service.n_replan_retries,
        },
        "engine": (dataclasses.asdict(engine.stats)
                   if engine is not None else None),
        "faults": (None if injector is None else {
            "n_fired": injector.n_fired,
            "by_kind": injector.fire_counts(),
        }),
    }
    # Read tier (PR 10): per-replica ReadStats when a ReplicaSet is
    # attached to the engine.
    hub = getattr(engine, "_replica_hub", None)
    out["replicas"] = hub.stats() if hub is not None else None
    if shards is not None:
        out["shards"] = shards
    return out


@dataclass(frozen=True)
class RecoveryReport:
    """What one :meth:`ShardedServiceRuntime.recover_shard` call did.

    ``seeded_from`` names where the re-hosted segments' values came from:
    ``"snapshot"`` (the quarantined lane's state was restored to its
    last-good snapshot when it stopped -- the normal path, at most
    ``snapshot_interval`` ticks of rollback), ``"live"`` (the shard was
    healthy: a proactive decommission, no rollback at all), or
    ``"zeros"`` (quarantined with snapshots disabled under jit: the
    donated buffers are unrecoverable and the segments re-seed empty).
    ``rolled_back_pushes`` counts futures whose observed result was
    discarded with the lost lane (their ``rolled_back`` flag is set);
    ``cancelled_pushes`` counts still-pending pushes that can never
    apply (their futures raise); ``purged_sibling_pieces`` counts queued
    pieces removed from HEALTHY lanes because a sibling piece of the
    same push died with the victim (a push applies everywhere or
    nowhere).
    """

    shard_id: str
    seeded_from: str  # 'snapshot' | 'live' | 'zeros'
    rolled_back_pushes: int
    cancelled_pushes: int
    purged_sibling_pieces: int
    rehosted_segments: int
    rehosted_elements: int
    moved_tasks: int


def _init_shard_state(shard_plan: FlatPlan, needs_ef: bool = False):
    """Empty state for ONE shard space (no per-job counters: those are
    global to a job and live on the sharded runtime, not in any shard)."""
    flat = jnp.zeros((shard_plan.total_len,), jnp.float32)
    state = {"flat": flat, "mu": jnp.zeros_like(flat),
             "nu": jnp.zeros_like(flat)}
    if needs_ef:
        state["ef"] = jnp.zeros_like(flat)
    return state


def _make_sharded_step(model_loss, layout, abstract_params, *,
                       lr, b1, b2, eps, push_compression=None):
    """O(job-bytes) train step spanning ONLY the shards hosting the job.

    ``layout`` is the plan's :class:`repro.ps.plan.ShardedJobLayout`: the
    pull gathers each hosting shard's owned blocks and concatenates them
    (in shard order) into the job's packed domain; the Adam update runs
    per shard on that shard's piece with the job's GLOBAL step count --
    elementwise math, so splitting by shard is a pure layout change and
    the trajectory is bit-exact with the single-space block step.

    With ``push_compression`` each shard's piece runs one
    :func:`repro.ps.compression.ef_transform` round against THAT shard's
    ``ef`` rows before Adam -- the same per-hosting-shard recurrence the
    :class:`repro.ps.engine.ShardedTickEngine` appliers run, so engine
    and direct-step compressed trajectories agree bit-for-bit (eager).
    """

    rows = _layout_rows(layout)

    def step(shard_states, count, batch):
        packed = _gather_pieces(layout, rows,
                                [st["flat"] for st in shard_states])
        p = jnp.concatenate(packed) if len(packed) > 1 else packed[0]
        params = _unpack_slots(layout, p, abstract_params)
        loss, grads = jax.value_and_grad(model_loss)(params, batch)
        g = _pack_slots(layout, grads)
        new_count = count + 1
        new_states = []
        for l, st, pp, gj in zip(layout.layouts, shard_states, packed,
                                 _split_pieces(layout, g)):
            new_st = dict(st)
            if push_compression:
                ef = st.get("ef")
                if ef is None:
                    ef = jnp.zeros_like(st["flat"])
                gj, resid = ef_transform(gj, _gather_owned(l, ef),
                                         push_compression)
                new_st["ef"] = _scatter_owned(l, ef, resid)
            new_p, mu, nu = _adam_math(
                pp, gj, _gather_owned(l, st["mu"]),
                _gather_owned(l, st["nu"]), new_count,
                lr=lr, b1=b1, b2=b2, eps=eps)
            new_st.update(
                flat=_scatter_owned(l, st["flat"], new_p),
                mu=_scatter_owned(l, st["mu"], mu),
                nu=_scatter_owned(l, st["nu"], nu),
            )
            new_states.append(new_st)
        return tuple(new_states), new_count, {"loss": loss}

    return step


class ShardedServiceRuntime:
    """Per-Aggregator shard spaces executor bound to one ParameterService.

    The sharded sibling of :class:`ServiceRuntime`: instead of ONE flat
    space sized by the fleet-wide maximum, every live Aggregator owns an
    independent shard space (``states[agg_id]``), so Aggregator count
    changes what actually executes -- a job's step touches only the shards
    hosting its blocks, shard spaces tick on independent cadences under
    the :class:`repro.ps.engine.ShardedTickEngine`, and the fleet can grow
    and shrink with measured load (``repro.ps.autoscaler.ElasticScaler``
    closing the loop through ``service.scale_out`` / ``scale_in``).

    Replans -- including load-driven shard splits and merges -- migrate
    per-shard states with :func:`repro.ps.elastic.migrate_sharded_state`:
    surviving shards execute an O(moved-bytes) MigrationDelta on the
    relayout run-copy path and only the segments that changed Aggregator
    ship across shard spaces.  With ONE Aggregator the shard space is
    bit-identical to the flat runtime's, and the trajectory reproduces it
    bit-exactly (eager; jitted runs see the documented ~1-ulp XLA:CPU
    cross-program rounding).
    """

    def __init__(self, service, jit: bool = True):
        self.service = service
        self.splan: Optional[ShardedPlan] = None
        self.states: Dict[str, Dict[str, Any]] = {}
        self.counts: Dict[str, Any] = {}  # job -> global step counter
        self.last_migration_bytes = 0  # cross-Aggregator (paper accounting)
        self.total_migration_bytes = 0
        self.last_relayout_bytes = 0  # bytes the sharded delta path moved
        self.total_relayout_bytes = 0
        self.last_replan_touched: tuple = ()
        self.n_replans = 0
        self._jit = jit
        self._jobs: Dict[str, Dict[str, Any]] = {}
        self._steps: Dict[str, Any] = {}  # job -> (hosting shard_ids, fn)
        self._engine = None
        service.on_replan(self._on_replan)

    # ------------------------------------------------------------- plumbing
    @property
    def n_shards(self) -> int:
        return self.splan.n_shards if self.splan is not None else 0

    @property
    def shard_ids(self):
        return self.splan.shard_ids if self.splan is not None else ()

    @property
    def job_ids(self):
        return tuple(self._jobs)

    @property
    def engine(self):
        return self._engine

    def attach_engine(self, **engine_opts):
        """Create (once) and return the per-shard tick engine
        (:class:`repro.ps.engine.ShardedTickEngine`)."""
        from repro.ps.engine import ShardedTickEngine

        if self._engine is None:
            self._engine = ShardedTickEngine(self, **engine_opts)
        elif engine_opts:
            raise ValueError("engine already attached; cannot re-configure")
        return self._engine

    def debug_stats(self) -> Dict[str, Any]:
        """Plan-pair cache + migration counters + per-shard TickStats."""
        import dataclasses

        eng = self._engine
        return _debug_stats(
            self, {"n_shards": self.n_shards},
            shards=({sid: {**dataclasses.asdict(lane.stats),
                           "health": lane.health}
                     for sid, lane in eng._lanes.items()}
                    if eng is not None else {}))

    # ----------------------------------------------------------------- jobs
    def add_job(
        self,
        job_id: str,
        params,
        loss_fn: Callable[[Any, Any], Any],
        *,
        iteration_duration: float = 1.0,
        n_workers: int = 2,
        required_servers: int = 1,
        agg_throughput: float = 7e9,
        lr: float = 3e-4,
        b1: float = 0.9,
        b2: float = 0.999,
        eps: float = 1e-8,
        **step_opts,
    ) -> None:
        """Register a job and seed its parameters into the shards that the
        control plane assigned its tensors to.

        Extra ``step_opts`` ride on the job info for the attached engine;
        ``push_compression="bf16"|"int8"`` makes the job's pushes flow
        through the engines' error-feedback compression path (each
        hosting shard's state gains an ``ef`` buffer that migrates,
        snapshots, and checkpoints with flat/mu/nu)."""
        if job_id in self._jobs:
            raise ValueError(f"job {job_id} already in the runtime")
        abstract = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params
        )
        profile, specs = job_profile_from_tree(
            job_id, params,
            iteration_duration=iteration_duration,
            n_workers=n_workers,
            required_servers=required_servers,
            agg_throughput=agg_throughput,
        )
        self._jobs[job_id] = dict(
            loss_fn=loss_fn, abstract=abstract,
            lr=lr, b1=b1, b2=b2, eps=eps, step_opts=step_opts,
        )
        try:
            self.service.register_job(profile, specs=specs)
        except Exception:
            self._jobs.pop(job_id, None)
            raise
        self._seed_job(job_id, params)

    def remove_job(self, job_id: str) -> None:
        """Job exit: drop its segments from every hosting shard.  With an
        engine attached, the job's queued pushes are drained against the
        old layout first; any push that somehow survives is CANCELLED so a
        held future raises instead of spinning forever."""
        if job_id not in self._jobs:
            raise ValueError(
                f"unknown job {job_id!r}: not registered with this runtime "
                f"(have {sorted(self._jobs)})")
        if self._engine is not None:
            self._engine.quiesce_for_replan([job_id])
            self._engine._forget_job(job_id)
        info = self._jobs.pop(job_id)
        step = self._steps.pop(job_id, None)
        count = self.counts.pop(job_id, None)
        try:
            self.service.job_exit(job_id)
        except Exception:
            # Exit replan aborted, registry rolled back: restore this
            # runtime's entries so both planes agree the job is live
            # (its queues were drained before the attempt, nothing lost).
            self._jobs[job_id] = info
            if step is not None:
                self._steps[job_id] = step
            if count is not None:
                self.counts[job_id] = count
            raise

    def _seed_job(self, job_id: str, params) -> None:
        layout = self.splan.job_layout(job_id)
        packed = _pack_slots(layout, params)
        for sid, l, piece in zip(layout.shard_ids, layout.layouts,
                                 _split_pieces(layout, packed)):
            st = self.states[sid]
            new_st = dict(
                st,
                flat=_scatter_owned(l, st["flat"], piece),
                # Fresh zeros per buffer: with covers_all layouts the
                # scatter returns its packed argument, and one shared
                # zeros array would alias mu and nu.
                mu=_scatter_owned(
                    l, st["mu"], jnp.zeros((l.packed_len,), jnp.float32)),
                nu=_scatter_owned(
                    l, st["nu"], jnp.zeros((l.packed_len,), jnp.float32)),
            )
            if "ef" in st:
                new_st["ef"] = _scatter_owned(
                    l, st["ef"], jnp.zeros((l.packed_len,), jnp.float32))
            self.states[sid] = new_st
        self.counts[job_id] = jnp.zeros((), jnp.int32)

    # ------------------------------------------------------------- training
    def step(self, job_id: str, batch):
        """One pull->compute->push->update iteration for one job, touching
        only the shards that host its blocks."""
        hosting, fn = self._steps[job_id]
        states_in = tuple(self.states[sid] for sid in hosting)
        new_states, new_count, metrics = fn(
            states_in, self.counts[job_id], batch)
        for sid, st in zip(hosting, new_states):
            self.states[sid] = st
        self.counts[job_id] = new_count
        return metrics

    def params_of(self, job_id: str):
        """Current parameters of one job, pulled across its shards."""
        layout = self.splan.job_layout(job_id)
        packed = _gather_packed(
            layout, _layout_rows(layout),
            [self.states[sid]["flat"] for sid in layout.shard_ids])
        return _unpack_slots(layout, packed,
                             self._jobs[job_id]["abstract"])

    # ------------------------------------------------------------- recovery
    def recover_shard(self, agg_id: str) -> RecoveryReport:
        """Declare ONE Aggregator lost and re-host its segments on the
        surviving fleet -- the paper's §3.3 migration machinery used as
        the repair primitive.

        Works on a QUARANTINED lane (the usual path after an exec
        failure exhausted its retries: its state was already restored to
        the last-good snapshot when it stopped, so clients observe at
        most ``snapshot_interval`` ticks of rollback) or on a healthy
        shard (proactive decommission: queued pushes drain first and the
        LIVE state migrates, no rollback).  Pushes inside the rollback
        window surface it on their futures: already-observed results get
        ``rolled_back=True`` (re-push to land the update again),
        still-pending ones are cancelled, and sibling pieces of
        cancelled pushes are purged from healthy lanes so no push ever
        half-applies.  The re-host itself is an ordinary control-plane
        replan (``service.evacuate_aggregator``), so untouched jobs tick
        straight through it and the moved segments ride the O(moved
        bytes) sharded delta path.
        """
        if self.splan is None or agg_id not in self.splan.shard_ids:
            raise ValueError(
                f"unknown shard {agg_id!r}: not in the live fleet "
                f"(have {list(self.shard_ids)})")
        old_sp = self.splan.shard_of(agg_id)
        seeded_from = "live"
        rolled_back = cancelled = purged = 0
        eng = self._engine
        if eng is not None:
            lane = eng._lanes.get(agg_id)
            if lane is not None and lane.health != QUARANTINED:
                # Proactive decommission: land what's queued before the
                # shard leaves (its state is still good).
                while any(lane.queues.values()):
                    if eng.tick_shard(agg_id) == 0:
                        break  # staleness-stuck leftovers cancel below
            lane = eng._lanes.pop(agg_id, None)
        else:
            lane = None
        if lane is not None:
            if lane.health == QUARANTINED:
                seeded_from = ("snapshot" if lane.snapshot is not None
                               else "zeros")
                if lane.snapshot is None:
                    # Quarantined with snapshots disabled under jit: the
                    # donated buffers are gone for good -- the segments
                    # can only re-seed empty.
                    self.states[agg_id] = _init_shard_state(
                        old_sp, needs_ef=self._needs_ef())
            # The rollback window's pushes sit re-queued on the dead
            # lane.  DONE futures already surfaced a result that the
            # snapshot restore discarded -> flag rolled_back; pending
            # ones can never apply -> cancel, and purge their sibling
            # pieces from healthy lanes (a push applies everywhere or
            # nowhere).
            dead_futs = set()
            for q in lane.queues.values():
                for _, _, fut, _ in q:
                    if fut is None:
                        continue
                    if fut.done():
                        if not fut._rolled_back:
                            fut._rolled_back = True
                            rolled_back += 1
                    elif not fut.cancelled():
                        fut._cancel(
                            f"shard {agg_id!r} was lost with this piece "
                            f"queued (inside its rollback window); "
                            f"re-push after recovery")
                        cancelled += 1
                        dead_futs.add(id(fut))
            if dead_futs and eng is not None:
                for other in eng._lanes.values():
                    for j, q in list(other.queues.items()):
                        kept = deque(
                            e for e in q
                            if e[2] is None or id(e[2]) not in dead_futs)
                        purged += len(q) - len(kept)
                        other.queues[j] = kept
        # One control-plane replan does the rest: the victim's tasks move
        # to survivors, the new ShardedPlan drops its shard, and
        # migrate_sharded_state copies its (restored) segments onto the
        # new hosts.
        moved_tasks = self.service.evacuate_aggregator(agg_id)
        return RecoveryReport(
            shard_id=agg_id, seeded_from=seeded_from,
            rolled_back_pushes=rolled_back, cancelled_pushes=cancelled,
            purged_sibling_pieces=purged,
            rehosted_segments=len(old_sp.segments),
            rehosted_elements=old_sp.payload_elements,
            moved_tasks=moved_tasks)

    # ----------------------------------------------------------- checkpoint
    def save_checkpoint(self, directory, step: int, **kw):
        """Commit (shard map, every shard space, per-job step counters)
        atomically.  Drains the engine first: a queued push references
        the pre-tick state and would be lost by a restore."""
        from repro.checkpoint import save_sharded_checkpoint

        if self._engine is not None:
            self._engine.drain()
        if self._engine is not None and "extra_aux" not in kw:
            # Record fleet health at save time: a restore tool can warn
            # when the checkpoint was taken on a degraded fleet.
            kw["extra_aux"] = {"shard_health": self._engine.shard_health()}
        return save_sharded_checkpoint(
            directory, step, self.splan, self.states, self.counts, **kw)

    def restore_checkpoint(self, directory, step: int, **kw) -> None:
        """Restore shard states + counters from a sharded checkpoint,
        migrating them onto THIS runtime's current shard map if the saved
        fleet differed (the elastic-restart path).  Jobs must already be
        registered (the plan's layouts come from the live service)."""
        from repro.checkpoint import restore_sharded_checkpoint

        if self._engine is not None:
            self._engine.drain()
        _, states, counts = restore_sharded_checkpoint(
            directory, step, splan=self.splan, **kw)
        self.states = {sid: dict(st) for sid, st in states.items()}
        self.counts = dict(counts)
        if self._engine is not None:
            # The engine's submit-time step mirrors are stale; re-sync at
            # next contact.
            self._engine._counts.clear()

    # --------------------------------------------------------------- replan
    def _needs_ef(self) -> bool:
        return any(info["step_opts"].get("push_compression")
                   for info in self._jobs.values())

    def _on_replan(self, old_flat, new_flat):
        engine = self._engine
        if new_flat is None:  # last job exited
            if engine is not None and self.states:
                engine.drain()
            self.splan, self.states, self._steps = None, {}, {}
            self.counts = {}
            if engine is not None:
                engine._on_plan_change(None)
            return
        new = self.service.compile_sharded_plan()
        old = self.splan
        # Everything up to the COMMIT below is computed into locals:
        # ``migrate_sharded_state`` is functional over the old states, so
        # a failure at any fail point -- the migration boundary or after
        # K shards relaid -- leaves splan/states/_steps on the old layout
        # for the service's replan transaction to roll the registry back
        # against and retry (PR 9).
        touched = None  # None = every job's layout may have changed
        moved_elems = 0
        migrated = old is not None and bool(self.states)
        if migrated:
            _, touched_pre = sharded_transition_summary(old, new)
            if engine is not None:
                engine.quiesce_for_replan(
                    [j for j in touched_pre if j in self._jobs])
            states, moved_elems, touched_exec = migrate_sharded_state(
                self.states, old, new, needs_ef=self._needs_ef(),
                fault_injector=(engine.fault_injector
                                if engine is not None else None))
            touched = set(touched_exec)
        else:
            if engine is not None and self.states:
                engine.drain()
            states = {sid: _init_shard_state(sp,
                                             needs_ef=self._needs_ef())
                      for sid, sp in zip(new.shard_ids, new.shards)}
        if self._needs_ef():
            # A compressed job joined shards whose states predate it:
            # widen each with a zero error-feedback buffer (surviving
            # shards' migrated states keep theirs bit-exactly).
            for sid, st in states.items():
                if "ef" not in st:
                    states[sid] = dict(st, ef=jnp.zeros_like(st["flat"]))
        steps: Dict[str, Any] = {}
        for job_id, info in self._jobs.items():
            # An untouched job's layout is bit-identical on every hosting
            # shard: keep its compiled step (no retrace, no stall).
            if (touched is not None and job_id not in touched
                    and job_id in self._steps):
                steps[job_id] = self._steps[job_id]
                continue
            layout = new.job_layout(job_id)
            fn = _make_sharded_step(
                info["loss_fn"], layout, info["abstract"],
                lr=info["lr"], b1=info["b1"], b2=info["b2"],
                eps=info["eps"],
                push_compression=info["step_opts"].get("push_compression"))
            if self._jit:
                fn = jax.jit(fn, donate_argnums=(0,))
            steps[job_id] = (layout.shard_ids, fn)
        # ---- COMMIT: the new layout becomes visible as a unit ----
        self.states = states
        if migrated:
            self.last_relayout_bytes = moved_elems * 12
            self.total_relayout_bytes += self.last_relayout_bytes
            self.last_replan_touched = tuple(sorted(touched))
            self.n_replans += 1
            if old_flat is not None:
                moved = migration_bytes(old_flat, new_flat)
                self.last_migration_bytes = moved
                self.total_migration_bytes += moved
        self.splan = new
        if engine is not None:
            engine._on_plan_change(touched)
        self._steps = steps
