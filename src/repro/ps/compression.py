"""Gradient compression for the push path (beyond-paper optimization).

Block-wise quantization with error feedback (EF-SGD style): the residual of
each compression round is added to the next round's gradient, so the
compressed chain remains convergent. Wire format on a real deployment is
the quantized payload + one scale per block; here `compress_decompress`
returns the dequantized value (the JAX collective then carries bf16/int8-
sized traffic depending on where the cast is placed).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

BLOCK = 2048

# Wire-size model (bytes per element on a real deployment): fp32 ships 4,
# bf16 ships 2, int8 ships 1 plus one fp32 scale per BLOCK-sized block.
_SCALE_BYTES = 4


def wire_bytes(n: int, kind: Optional[str], block: int = BLOCK) -> int:
    """Bytes an ``n``-element packed gradient costs on the wire under
    ``kind`` (None = uncompressed fp32)."""
    if n < 0:
        raise ValueError(f"n must be >= 0, got {n}")
    if not kind:
        return 4 * n
    if kind == "bf16":
        return 2 * n
    if kind == "int8":
        return n + _SCALE_BYTES * (-(-n // block) if n else 0)
    raise ValueError(f"unknown compression {kind!r}")


def _block_scales(x: jnp.ndarray, block: int) -> jnp.ndarray:
    n = x.shape[0]
    nb = -(-n // block)
    pad = nb * block - n
    xb = jnp.pad(jnp.abs(x), (0, pad)).reshape(nb, block)
    return jnp.max(xb, axis=1)


def quantize_int8(x: jnp.ndarray, block: int = BLOCK):
    """x (N,) fp32 -> (q int8 (N,), scales (ceil(N/block),))."""
    n = x.shape[0]
    scales = _block_scales(x, block)
    safe = jnp.where(scales > 0, scales, 1.0)
    per_elem = jnp.repeat(safe, block)[:n]
    q = jnp.clip(jnp.round(x / per_elem * 127.0), -127, 127).astype(jnp.int8)
    return q, scales


def dequantize_int8(q: jnp.ndarray, scales: jnp.ndarray, block: int = BLOCK):
    n = q.shape[0]
    safe = jnp.where(scales > 0, scales, 1.0)
    per_elem = jnp.repeat(safe, block)[:n]
    return q.astype(jnp.float32) * per_elem / 127.0


def compress_decompress(x: jnp.ndarray, kind: str) -> jnp.ndarray:
    """Round-trip through the compressed representation."""
    if kind == "bf16":
        return x.astype(jnp.bfloat16).astype(jnp.float32)
    if kind == "int8":
        q, s = quantize_int8(x)
        return dequantize_int8(q, s)
    raise ValueError(f"unknown compression {kind!r}")


def ef_transform(g: jnp.ndarray, ef: jnp.ndarray, kind: str):
    """ONE error-feedback compression round: ``(g, ef) -> (q, resid)``.

    The residual of the previous round rides into this round's gradient
    before quantization, and what quantization loses becomes the next
    residual -- the EF-SGD recurrence.  This is THE transform: the
    runtime's compressed ``step()`` path and both tick engines' appliers
    call it, so their compressed trajectories agree bit-for-bit (eager)
    by construction.
    """
    g = g + ef
    q = compress_decompress(g, kind)
    return q, g - q


class ErrorFeedback:
    """Stateful wrapper for host-side loops (the jitted PS step keeps the
    residual in its own state; this class serves tests/examples)."""

    def __init__(self, shape):
        self.residual = jnp.zeros(shape, jnp.float32)

    def step(self, grad: jnp.ndarray, kind: str) -> jnp.ndarray:
        q, self.residual = ef_transform(grad, self.residual, kind)
        return q
