"""Per-tensor sharding assignment: the data-plane realization of the paper.

The control plane decides, per tensor, *where* its aggregation (gradient
reduction + optimizer update) lives. On a TPU mesh this is a sharding
choice: a tensor's optimizer state + master copy live on its owner shards
("model"/"data" axes), gradients reduce onto them (push), parameters
all-gather back (pull) -- all emitted by GSPMD from the per-tensor
NamedShardings this module produces.

Rules are name+shape based (tree_map_with_path), with divisibility guards:
a dim is sharded over an axis only when evenly divisible; otherwise the next
candidate dim is tried; tiny tensors (< `replicate_below` elements) stay
replicated -- matching the control plane's policy of not splitting small
aggregation tasks.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisName = Union[str, Tuple[str, ...]]

REPLICATE_BELOW = 1 << 16  # tensors under 64k elements are not worth sharding


def _axis_size(mesh: Mesh, axis: AxisName) -> int:
    if isinstance(axis, tuple):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]


def _divisible(dim: int, mesh: Mesh, axis: AxisName) -> bool:
    return dim % _axis_size(mesh, axis) == 0


def data_axes(mesh: Mesh) -> AxisName:
    """The batch axes: ("pod","data") multi-pod, "data" single-pod."""
    return ("pod", "data") if "pod" in mesh.axis_names else "data"


def all_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)


def _spec(mesh: Mesh, shape: Sequence[int], *assign: Tuple[int, AxisName]) -> P:
    """Build a PartitionSpec putting each axis on a dim if divisible."""
    parts: list = [None] * len(shape)
    for dim, axis in assign:
        if dim < len(shape) and parts[dim] is None and _divisible(shape[dim], mesh, axis):
            parts[dim] = axis
    return P(*parts)


def _leaf_name(path) -> str:
    keys = []
    for p in path:
        if hasattr(p, "key"):
            keys.append(str(p.key))
        elif hasattr(p, "idx"):
            keys.append(str(p.idx))
        elif hasattr(p, "name"):
            keys.append(str(p.name))
    return "/".join(keys)


def _lm_rule(mesh: Mesh, name: str, shape: Tuple[int, ...],
             opt: bool = False) -> P:
    dp, tp = data_axes(mesh), "model"
    nd = len(shape)
    last = name.rsplit("/", 1)[-1]
    stacked = 1 if "layers" in name else 0  # scanned leaves carry a leading L dim

    if int(np.prod(shape)) < REPLICATE_BELOW:
        return P()
    if last == "embed":
        return _spec(mesh, shape, (0, tp), (1, dp))
    if last == "unembed":
        return _spec(mesh, shape, (0, dp), (1, tp))
    if last in ("w_q", "w_k", "w_v"):
        # (lead?, d, h, dh): heads over tp if divisible, else head_dim over tp
        s = _spec(mesh, shape, (stacked + 0, dp), (stacked + 1, tp))
        if s[stacked + 1] is None:
            s_list = list(s)
            if _divisible(shape[stacked + 2], mesh, tp):
                s_list[stacked + 2] = tp
            s = P(*s_list)
        return s
    if last == "w_o":
        # (lead?, h, dh, d)
        s = _spec(mesh, shape, (stacked + 0, tp), (stacked + 2, dp))
        if s[stacked + 0] is None:
            s_list = list(s)
            if _divisible(shape[stacked + 1], mesh, tp):
                s_list[stacked + 1] = tp
            s = P(*s_list)
        return s
    if last in ("w_gate", "w_up"):
        if nd - stacked == 3:  # MoE experts: (lead?, E, d, f): EP over tp +
            # FSDP over dp. (Replicating experts over dp removes the per-
            # layer-per-microbatch weight all-gather but costs 27.8 GB/device
            # at deepseek scale -- measured and refuted; see EXPERIMENTS.)
            return _spec(mesh, shape, (stacked + 0, tp), (stacked + 1, dp))
        return _spec(mesh, shape, (stacked + 0, dp), (stacked + 1, tp))
    if last == "w_down":
        if nd - stacked == 3:  # (lead?, E, f, d)
            return _spec(mesh, shape, (stacked + 0, tp), (stacked + 2, dp))
        return _spec(mesh, shape, (stacked + 0, tp), (stacked + 1, dp))
    if last in ("shared_gate", "shared_up"):
        return _spec(mesh, shape, (stacked + 0, dp), (stacked + 1, tp))
    if last == "shared_down":
        return _spec(mesh, shape, (stacked + 0, tp), (stacked + 1, dp))
    if last == "router":
        return _spec(mesh, shape, (stacked + 0, dp))
    # MLA projections
    if last == "w_dq":
        return _spec(mesh, shape, (stacked + 0, dp), (stacked + 1, tp))
    if last == "w_dkv":
        return _spec(mesh, shape, (stacked + 0, dp), (stacked + 1, tp))
    if last == "w_kr":
        return _spec(mesh, shape, (stacked + 0, dp))
    if last in ("w_uq", "w_uk", "w_uv"):
        # (lead?, rank, H, dh)
        return _spec(mesh, shape, (stacked + 0, dp), (stacked + 1, tp))
    # Fallback: shard the two largest dims over tp/dp where divisible.
    dims = sorted(range(nd), key=lambda i: -shape[i])
    s: list = [None] * nd
    if _divisible(shape[dims[0]], mesh, tp):
        s[dims[0]] = tp
    for d in dims[1:]:
        if s[d] is None and _divisible(shape[d], mesh, dp):
            s[d] = dp
            break
    return P(*s)


def _recsys_rule(mesh: Mesh, name: str, shape: Tuple[int, ...]) -> P:
    rows_axes = all_axes(mesh)
    if int(np.prod(shape)) < REPLICATE_BELOW:
        return P()
    last = name.rsplit("/", 1)[-1]
    if "tables" in name or last in ("item_emb", "cat_emb"):
        # Huge embedding tables: row-shard over the full mesh (PS-style).
        if _divisible(shape[0], mesh, rows_axes):
            return P(rows_axes)
        # Pad-free fallback: shard over "model" only.
        if _divisible(shape[0], mesh, "model"):
            return P("model")
        return P()
    # Dense tower weights: replicate (they're small; data-parallel compute).
    dp = data_axes(mesh)
    if len(shape) == 2 and _divisible(shape[0], mesh, dp) and shape[0] >= 512:
        return _spec(mesh, shape, (0, dp), (1, "model"))
    return P()


def _gnn_rule(mesh: Mesh, name: str, shape: Tuple[int, ...]) -> P:
    return P()  # GIN weights are tiny; graph tensors are sharded, not params


def param_shardings(mesh: Mesh, abstract_params, family: str):
    """Pytree of NamedSharding matching `abstract_params` (eval_shape out)."""
    rule = {"lm": _lm_rule, "recsys": _recsys_rule, "gnn": _gnn_rule}[family]

    def assign(path, leaf):
        spec = rule(mesh, _leaf_name(path), tuple(leaf.shape))
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(assign, abstract_params)


def opt_state_shardings(mesh: Mesh, abstract_opt, param_shardings_tree, family: str):
    """Optimizer state: moments follow their parameter's sharding; scalars
    replicate. We re-run the name rules on the opt pytree (same leaf names
    appear under mu/nu/accum/momentum)."""
    rule = {"lm": _lm_rule, "recsys": _recsys_rule, "gnn": _gnn_rule}[family]

    def assign(path, leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        if family == "lm":
            spec = rule(mesh, _leaf_name(path), tuple(leaf.shape), opt=True)
        else:
            spec = rule(mesh, _leaf_name(path), tuple(leaf.shape))
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(assign, abstract_opt)


def batch_shardings(mesh: Mesh, abstract_batch, batch_dim_axes: Optional[AxisName] = None):
    """Shard the leading (batch) dim of every batch leaf over the data axes
    when divisible; replicate otherwise."""
    axes = batch_dim_axes if batch_dim_axes is not None else data_axes(mesh)

    def assign(leaf):
        if leaf.ndim == 0 or not _divisible(leaf.shape[0], mesh, axes):
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, P(axes))

    return jax.tree_util.tree_map(assign, abstract_batch)


def kv_cache_shardings(mesh: Mesh, abstract_cache, batch: int):
    """KV caches: batch over data axes when divisible; otherwise (and for the
    sequence dim) shard the cache length. layout (L, B, S, ...)."""
    dp = data_axes(mesh)

    def assign(leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        l_, b_, s_ = leaf.shape[0], leaf.shape[1], leaf.shape[2]
        if _divisible(b_, mesh, dp):
            spec: list = [None, dp, None] + [None] * (leaf.ndim - 3)
            if _divisible(s_, mesh, "model"):
                spec[2] = "model"
            return NamedSharding(mesh, P(*spec))
        # batch=1 (long-context): shard seq over every axis we can.
        axes = all_axes(mesh)
        if _divisible(s_, mesh, axes):
            return NamedSharding(mesh, P(None, None, axes))
        if _divisible(s_, mesh, "model"):
            return NamedSharding(mesh, P(None, None, "model"))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map(assign, abstract_cache)


def replicated(mesh: Mesh, abstract_tree):
    return jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), abstract_tree
    )
