"""Activation-sharding constraints, injectable per-mesh.

Model code calls `constrain(x, "dp", None, "tp", ...)` with symbolic axis
roles; when a mesh context has been `activate()`d the roles resolve to real
mesh axes and become `with_sharding_constraint`s; with no context (smoke
tests on 1 CPU device) they are no-ops. Dims that do not divide evenly by
the axis size degrade to None automatically, so the same model code serves
every mesh.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()


def _current():
    return getattr(_STATE, "ctx", None)


@contextlib.contextmanager
def activate(mesh: Mesh, enabled: bool = True):
    """Enable activation constraints for code traced inside this context.

    Roles: "dp" -> batch/data axes (("pod","data") if present), "tp" ->
    "model", "all" -> every axis.
    """
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    prev = _current()
    _STATE.ctx = {"mesh": mesh, "dp": tuple(dp), "tp": ("model",),
                  "all": tuple(mesh.axis_names)} if enabled else None
    try:
        yield
    finally:
        _STATE.ctx = prev


def _axis_size(mesh: Mesh, axes: Tuple[str, ...]) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def constrain(x, *roles: Optional[str]):
    """Apply a sharding constraint with symbolic axis roles (or None)."""
    ctx = _current()
    if ctx is None:
        return x
    mesh = ctx["mesh"]
    spec = []
    for dim, role in enumerate(roles):
        if role is None:
            spec.append(None)
            continue
        axes = ctx[role]
        if dim < x.ndim and x.shape[dim] % _axis_size(mesh, axes) == 0:
            spec.append(axes if len(axes) > 1 else axes[0])
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


def enabled() -> bool:
    return _current() is not None
