"""Elastic re-mesh + tensor migration in the data plane.

`migrate_flat_state` re-lays a PS flat state from one FlatPlan to another
(the data-plane half of the paper's tensor migration: the owner segments
move, everything else stays). `reshard_tree` moves any pytree onto new
shardings (elastic scale up/down, spot-instance drain from §6).

Both are expressible as pure gathers + device_put, so the runtime can issue
them while workers compute (the paper's hidden-copy window); the benchmark
(benchmarks/table3_migration.py) measures the visible stall against the
checkpoint-restart strawman.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from .runtime import FlatPlan


def _perm_old_to_new(old: FlatPlan, new: FlatPlan) -> np.ndarray:
    """index array `idx` with new_flat[i] = old_flat[idx[i]] (pad -> 0)."""
    old_by_key = {s.key: s for s in old.segments}
    idx = np.zeros(new.total_len, dtype=np.int64)
    for seg in new.segments:
        o = old_by_key[seg.key]
        src = o.shard * old.shard_len + o.offset
        dst = seg.shard * new.shard_len + seg.offset
        idx[dst : dst + seg.size] = np.arange(src, src + seg.size)
    return idx


def migrate_flat_state(state: Dict[str, Any], old: FlatPlan, new: FlatPlan):
    """Move a PS state onto a new assignment plan (tensor migration)."""
    idx = jnp.asarray(_perm_old_to_new(old, new))

    def move(x):
        if x.ndim == 0:
            return x
        return jnp.take(x, idx, axis=0)

    return {k: (move(v) if k != "count" else v) for k, v in state.items()}


def migration_bytes(old: FlatPlan, new: FlatPlan, bytes_per_element: int = 12) -> int:
    """Bytes that actually cross shards (master copy + both Adam moments)."""
    old_by_key = {s.key: s for s in old.segments}
    moved = 0
    for seg in new.segments:
        if old_by_key[seg.key].shard != seg.shard:
            moved += seg.size * bytes_per_element
    return moved


def reshard_tree(tree, shardings):
    """Move a pytree onto new shardings (elastic re-mesh / migration)."""
    return jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, s), tree, shardings
    )
