"""Elastic re-mesh + tensor migration in the data plane.

Two migration executors re-lay a PS flat state from one FlatPlan to
another (the data-plane half of the paper's tensor migration: the owner
segments move, everything else stays):

``migrate_flat_state``
    The full-gather ORACLE: one permutation gather over the whole new
    space.  Always correct, O(total bytes) per replan -- kept as the
    parity reference the delta path is tested against.

``migrate_flat_state_delta``
    The shipped O(moved-bytes) path: a :class:`MigrationDelta` compiled
    per plan pair reduces the transition to a run-length list of
    contiguous ``(src, dst, len)`` moves plus zero-runs for vacated
    lanes; only those runs are executed (a scalar-prefetched Pallas
    run-copy launch on TPU, ``dynamic_slice``/scatter jnp programs
    elsewhere -- repro.kernels.relayout).  Lanes that do not move are
    never touched, so a small job's arrival costs O(its own bytes), not
    O(every co-resident job's bytes).

    Contract: delta migration is bit-exact with the full-gather oracle
    on *valid* states -- states whose non-payload lanes are zero in
    every 1-D leaf.  That invariant is maintained by every official
    state constructor and mutator (``init_shared_state``,
    ``seed_job_params``, the train steps, and both migration paths), so
    it holds for any state the runtime ever owns.

Plans may be multi-job (compiled by ``ParameterService.compile_plan``):
segments are matched by their job-qualified key ``(job_id, tensor_key)``;
segments that only exist in the new plan (a job arrival) come out
zero-initialized, segments that only exist in the old plan (a job exit)
are dropped.  `reshard_tree` moves any pytree onto new shardings
(elastic scale up/down, spot drain from §6).

Compiled per-pair structures (permutations and deltas) live in one
size-bounded LRU cache: a long-lived service replanning periodically can
not leak one full-space index array per replan.  ``plan_cache_stats`` /
``set_plan_cache_limit`` expose and bound it.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .plan import FlatPlan, ShardedPlan, plan_migration_bytes, segment_mask


class PlanPerm(NamedTuple):
    """Precompiled (old -> new) lane permutation for one plan pair."""

    idx: np.ndarray  # (new.total_len,) int64 source lanes
    keep: np.ndarray  # (new.total_len,) bool: covered by a common segment
    all_kept: bool
    identity: bool  # the move is a no-op (every lane stays put)


class MigrationDelta(NamedTuple):
    """Compiled plan-pair transition: only what CHANGES, as runs.

    ``moves`` are maximal contiguous runs of kept lanes whose flat
    position changed (constant shift within a run); ``zeros`` are runs of
    lanes that held old payload at a position no common segment covers in
    the new plan (vacated by an exit or a relocation) and must read zero
    afterwards.  Everything else is stationary and is never touched.

    ``touched_blocks`` are the new-plan ``block_align`` block ids any
    move/zero run intersects, with ``stage_src``/``stage_keep`` the
    per-lane source map of exactly those blocks (packed, block order) --
    the operands of the one-launch kernel path.  ``touched_jobs`` is the
    control signal for stall-free replans: jobs whose segment layout
    differs between the plans (arrivals and exits included); a job NOT in
    it has a bit-identical layout in both plans, so its queued pushes and
    compiled programs remain valid across the migration.
    """

    old_len: int
    new_len: int
    block: int  # new plan's block_align
    moves: Tuple[Tuple[int, int, int], ...]  # (src, dst, length) runs
    zeros: Tuple[Tuple[int, int], ...]  # (dst, length) runs
    touched_jobs: Tuple[str, ...]
    touched_blocks: np.ndarray  # new-plan block ids hit by moves/zeros
    stage_src: np.ndarray  # (n_touched*block,) int64 source lane per lane
    stage_keep: np.ndarray  # (n_touched*block,) bool: lane carries payload
    moved_elements: int
    zeroed_elements: int

    @property
    def identity(self) -> bool:
        """Nothing to execute: same length, no moves, nothing vacated."""
        return (self.old_len == self.new_len and not self.moves
                and not self.zeros)

    @property
    def n_runs(self) -> int:
        return len(self.moves) + len(self.zeros)

    def moved_bytes(self, bytes_per_element: int = 12) -> int:
        """Bytes the delta path actually copies (master + both moments at
        4 B each by default -- same convention as :func:`migration_bytes`)."""
        return self.moved_elements * bytes_per_element


# ------------------------------------------------------- bounded pair cache
class _PlanPairCache:
    """Size-bounded LRU for per-plan-pair structures (perms + deltas).

    The old unbounded ``lru_cache`` leaked one full-space index array per
    replan in a long-lived service with periodic rebalance; this one
    evicts least-recently-used entries once the numpy payload exceeds
    ``max_bytes`` and exposes a stats hook.
    """

    def __init__(self, max_bytes: int = 256 << 20):
        self.max_bytes = int(max_bytes)
        self._entries: "OrderedDict[Any, Tuple[Any, int]]" = OrderedDict()
        self._bytes = 0
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    @staticmethod
    def _nbytes(value: Any) -> int:
        # Every entry pays a floor (its key strongly pins two FlatPlans)
        # plus its numpy AND python-tuple payload -- a 0-cost estimate
        # would never evict and quietly reintroduce the leak this cache
        # exists to fix.
        def size(v: Any) -> int:
            n = getattr(v, "nbytes", None)
            if n is not None:
                return int(n)
            if isinstance(v, tuple):
                return 56 + sum(size(x) for x in v)
            return 32

        fields = getattr(value, "_fields", None)
        payload = (sum(size(getattr(value, f)) for f in fields)
                   if fields else size(value))
        return 1024 + payload

    def get(self, key):
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                self.misses += 1
                return None
            self._entries.move_to_end(key)
            self.hits += 1
            return entry[0]

    def put(self, key, value) -> None:
        nbytes = self._nbytes(value)
        with self._lock:
            old = self._entries.pop(key, None)
            if old is not None:
                self._bytes -= old[1]
            self._entries[key] = (value, nbytes)
            self._bytes += nbytes
            while self._bytes > self.max_bytes and len(self._entries) > 1:
                _, (_, freed) = self._entries.popitem(last=False)
                self._bytes -= freed
                self.evictions += 1

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {
                "entries": len(self._entries),
                "bytes": self._bytes,
                "max_bytes": self.max_bytes,
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
            }

    def resize(self, max_bytes: int) -> None:
        with self._lock:
            self.max_bytes = int(max_bytes)
            while self._bytes > self.max_bytes and self._entries:
                _, (_, freed) = self._entries.popitem(last=False)
                self._bytes -= freed
                self.evictions += 1

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self._bytes = 0


_PAIR_CACHE = _PlanPairCache()


def plan_cache_stats() -> Dict[str, int]:
    """Hits/misses/evictions/bytes of the per-plan-pair structure cache."""
    return _PAIR_CACHE.stats()


def set_plan_cache_limit(max_bytes: int) -> None:
    """Bound the per-plan-pair cache; evicts immediately if over."""
    _PAIR_CACHE.resize(max_bytes)


def clear_plan_cache() -> None:
    _PAIR_CACHE.clear()


def _plan_perm(old: FlatPlan, new: FlatPlan) -> PlanPerm:
    """(idx, keep) with new_flat[i] = old_flat[idx[i]] where keep[i], else 0.

    Lanes not covered by a common segment (padding, or segments of a job
    that was not in the old plan) get keep=False.  Cached per
    ``(old, new)`` plan pair (plans are frozen/hashable), so periodic
    rebalances that bounce between the same layouts -- or that move
    nothing at all -- never recompute or re-trace the permutation.
    """
    key = ("perm", old, new)
    cached = _PAIR_CACHE.get(key)
    if cached is not None:
        return cached
    old_by_key = old.by_skey
    idx = np.zeros(new.total_len, dtype=np.int64)
    keep = np.zeros(new.total_len, dtype=bool)
    for seg in new.segments:
        o = old_by_key.get(seg.skey)
        if o is None:
            continue  # new job's segment: zero-initialized
        if o.size != seg.size:
            raise ValueError(
                f"segment {seg.skey} changed size {o.size} -> {seg.size}"
            )
        src = old.start(o)
        dst = new.start(seg)
        idx[dst : dst + seg.size] = np.arange(src, src + seg.size)
        keep[dst : dst + seg.size] = True
    all_kept = bool(keep.all())
    identity = (
        all_kept
        and old.total_len == new.total_len
        and bool((idx == np.arange(new.total_len)).all())
    )
    idx.setflags(write=False)
    keep.setflags(write=False)
    perm = PlanPerm(idx, keep, all_kept, identity)
    _PAIR_CACHE.put(key, perm)
    return perm


def _perm_old_to_new(old: FlatPlan, new: FlatPlan) -> Tuple[np.ndarray, np.ndarray]:
    """Back-compat view of :func:`_plan_perm` (idx, keep)."""
    perm = _plan_perm(old, new)
    return perm.idx, perm.keep


def _runs(mask: np.ndarray, shift: Optional[np.ndarray] = None):
    """Maximal runs of True lanes (splitting where ``shift`` changes).

    Yields (start, length) -- contiguous in the mask's index space and,
    when ``shift`` is given, of constant shift (so src is contiguous too).
    """
    pos = np.nonzero(mask)[0]
    if not pos.size:
        return []
    breaks = np.diff(pos) != 1
    if shift is not None:
        breaks |= np.diff(shift[pos]) != 0
    cut = np.nonzero(breaks)[0]
    starts = pos[np.concatenate([[0], cut + 1])]
    ends = pos[np.concatenate([cut, [pos.size - 1]])]
    return [(int(s), int(e - s + 1)) for s, e in zip(starts, ends)]


def _job_layout_sigs(plan: FlatPlan) -> Dict[str, Tuple]:
    """Per-job layout fingerprint: absolute (start, size, key) of every
    segment, the block granularity, and whether the job owns EVERY block
    of the space -- equal fingerprints mean the job's lanes, blocks,
    packed slots, and gather/scatter fast paths (``covers_all``) are
    identical in both plans, so every compiled program that closes over
    its JobLayout stays valid across the pair.

    O(segments log segments): owned-block counts come from merged block
    intervals, never materialized lane- or block-wise (plans can span
    hundreds of millions of lanes in the simulator).
    """
    block = max(1, plan.block_align)
    n_blocks_total = -(-plan.total_len // block)
    sigs: Dict[str, list] = {}
    spans: Dict[str, list] = {}
    for seg in plan.segments:
        start = plan.start(seg)
        sigs.setdefault(seg.job_id, []).append((start, seg.size, seg.key))
        spans.setdefault(seg.job_id, []).append(
            (start // block, (start + seg.size - 1) // block + 1))
    out = {}
    for j, v in sigs.items():
        n_owned, end = 0, -1
        for lo, hi in sorted(spans[j]):  # merged half-open block intervals
            lo = max(lo, end)
            if hi > lo:
                n_owned += hi - lo
                end = hi
        out[j] = (block, n_owned == n_blocks_total, tuple(sorted(v)))
    return out


def plan_transition_summary(old: FlatPlan, new: FlatPlan):
    """Segment-level view of a plan transition: O(segments), no lane
    arrays -- safe at simulator scale (hundreds of millions of lanes).

    Returns ``(moved_elements, touched_jobs)``.  ``moved_elements``
    equals the delta's exactly: a common segment relocates rigidly (its
    lanes share one shift), so the moved-lane count is the summed size
    of the segments whose absolute start changed.  ``touched_jobs`` is
    the same layout-fingerprint diff :func:`compile_migration_delta`
    reports.
    """
    key = ("summary", old, new)
    cached = _PAIR_CACHE.get(key)
    if cached is not None:
        return cached
    old_by_key = old.by_skey
    moved = 0
    for seg in new.segments:
        o = old_by_key.get(seg.skey)
        if o is None:
            continue
        if o.size != seg.size:
            raise ValueError(
                f"segment {seg.skey} changed size {o.size} -> {seg.size}")
        if old.start(o) != new.start(seg):
            moved += seg.size
    old_sigs = _job_layout_sigs(old)
    new_sigs = _job_layout_sigs(new)
    touched = tuple(sorted(
        j for j in set(old_sigs) | set(new_sigs)
        if old_sigs.get(j) != new_sigs.get(j)))
    summary = (moved, touched)
    _PAIR_CACHE.put(key, summary)
    return summary


def compile_migration_delta(old: FlatPlan, new: FlatPlan) -> MigrationDelta:
    """Compile the O(moved-bytes) transition for one plan pair (cached).

    Compilation itself is O(total lanes) numpy ONCE per pair (same cost
    class as the permutation it replaces); what it buys is that
    *execution* -- every replan, on device -- touches only the moved and
    vacated runs.
    """
    key = ("delta", old, new)
    cached = _PAIR_CACHE.get(key)
    if cached is not None:
        return cached
    perm = _plan_perm(old, new)
    old_len, new_len = old.total_len, new.total_len
    lanes = np.arange(new_len, dtype=np.int64)
    needs_copy = perm.keep & (perm.idx != lanes)

    # Vacated lanes: positions that held old payload but are not covered
    # (stationarily or by a copy) in the new plan.  On valid states every
    # other non-kept lane is already zero, so nothing else is written.
    old_payload = segment_mask(old)
    vacated = ~perm.keep
    vacated[old_len:] = False  # resize padding is born zero
    vacated[: min(old_len, new_len)] &= old_payload[: min(old_len, new_len)]

    shift = perm.idx - lanes
    moves = tuple(
        (int(perm.idx[s]), s, n) for s, n in _runs(needs_copy, shift))
    zeros = tuple(_runs(vacated))

    block = max(1, int(new.block_align))
    touched_lanes = needs_copy | vacated
    n_blocks_total = -(-new_len // block)
    padded = np.zeros(n_blocks_total * block, dtype=bool)
    padded[:new_len] = touched_lanes
    touched_blocks = np.nonzero(padded.reshape(-1, block).any(axis=1))[0]
    touched_blocks = touched_blocks.astype(np.int32)

    # Per-lane source map of the touched blocks only (kernel staging).
    own = (touched_blocks.astype(np.int64)[:, None] * block
           + np.arange(block)).reshape(-1)
    own_in = own[own < new_len]
    stage_src = np.zeros(own.size, dtype=np.int64)
    stage_keep = np.zeros(own.size, dtype=bool)
    stage_src[: own_in.size] = perm.idx[own_in]
    stage_keep[: own_in.size] = perm.keep[own_in]

    _, touched_jobs = plan_transition_summary(old, new)

    for arr in (touched_blocks, stage_src, stage_keep):
        arr.setflags(write=False)
    delta = MigrationDelta(
        old_len=old_len, new_len=new_len, block=block, moves=moves,
        zeros=zeros, touched_jobs=touched_jobs,
        touched_blocks=touched_blocks, stage_src=stage_src,
        stage_keep=stage_keep,
        moved_elements=int(needs_copy.sum()),
        zeroed_elements=int(vacated.sum()),
    )
    _PAIR_CACHE.put(key, delta)
    return delta


def migrate_flat_state(state: Dict[str, Any], old: FlatPlan, new: FlatPlan):
    """Full-gather migration oracle (O(total bytes) per replan).

    Every 1-D leaf of length ``old.total_len`` (flat, mu, nu, ef) is
    gathered onto the new layout; scalars (step counters, incl. the shared
    state's per-job ``counts``) pass through untouched.  Common segments
    are relocated bit-exactly.  Equal plans -- and permutations that turn
    out to be the identity (a rebalance that moved nothing) -- return the
    state untouched without dispatching a single device op.
    """
    if old == new:
        return state
    perm = _plan_perm(old, new)
    if perm.identity:
        return state
    idx = jnp.asarray(perm.idx)
    keep = jnp.asarray(perm.keep)

    def move(x):
        if getattr(x, "ndim", 0) != 1 or x.shape[0] != old.total_len:
            return x
        moved = jnp.take(x, idx, axis=0)
        if perm.all_kept:
            return moved
        return jnp.where(keep, moved, jnp.zeros((), x.dtype))

    return jax.tree_util.tree_map(move, state)


def migrate_flat_state_delta(
    state: Dict[str, Any],
    old: FlatPlan,
    new: FlatPlan,
    *,
    delta: Optional[MigrationDelta] = None,
    interpret: Optional[bool] = None,
):
    """O(moved-bytes) migration: execute only the compiled delta's runs.

    Bit-exact with :func:`migrate_flat_state` on valid states (non-payload
    lanes zero -- the invariant every runtime state satisfies).  All 1-D
    leaves of length ``old.total_len`` move in ONE pass
    (``repro.kernels.relayout``: a single scalar-prefetched run-copy
    launch on TPU, compiled ``dynamic_slice``/scatter programs off-TPU);
    everything else passes through untouched.
    """
    if old == new:
        return state
    if delta is None:
        delta = compile_migration_delta(old, new)
    if delta.identity:
        return state
    from repro.kernels.relayout import ops as relayout_ops

    keys = [k for k, v in state.items()
            if getattr(v, "ndim", 0) == 1 and v.shape[0] == delta.old_len]
    moved = relayout_ops.relayout(
        [state[k] for k in keys], delta, interpret=interpret)
    return dict(state, **dict(zip(keys, moved)))


# ------------------------------------------------------- sharded transitions
def sharded_transition_summary(old: ShardedPlan, new: ShardedPlan):
    """Segment-level view of a SHARDED plan transition: O(segments).

    Returns ``(moved_elements, touched_jobs)``.  Segment identity is the
    job-qualified key; a segment *moved* iff its ``(shard_id, offset)``
    home changed -- a shard joining or leaving the fleet does not "move"
    the segments that stayed put on their own Aggregator.  ``touched_jobs``
    diffs each job's per-shard layout fingerprint (keyed by the stable
    ``agg_id``), exactly the jobs whose compiled programs a migration
    invalidates; this is the oracle :func:`migrate_sharded_state`'s
    executed byte count is asserted against.
    """
    key = ("ssummary", old, new)
    cached = _PAIR_CACHE.get(key)
    if cached is not None:
        return cached
    old_by = old.by_skey
    moved = 0
    for sid, sp in zip(new.shard_ids, new.shards):
        for seg in sp.segments:
            prev = old_by.get(seg.skey)
            if prev is None:
                continue
            psid, pseg = prev
            if pseg.size != seg.size:
                raise ValueError(
                    f"segment {seg.skey} changed size "
                    f"{pseg.size} -> {seg.size}")
            if psid != sid or pseg.offset != seg.offset:
                moved += seg.size

    def sigs(plan: ShardedPlan) -> Dict[str, Dict[str, Tuple]]:
        out: Dict[str, Dict[str, Tuple]] = {}
        for sid, sp in zip(plan.shard_ids, plan.shards):
            for j, sig in _job_layout_sigs(sp).items():
                out.setdefault(j, {})[sid] = sig
        return out

    old_sigs, new_sigs = sigs(old), sigs(new)
    touched = tuple(sorted(
        j for j in set(old_sigs) | set(new_sigs)
        if old_sigs.get(j) != new_sigs.get(j)))
    summary = (moved, touched)
    _PAIR_CACHE.put(key, summary)
    return summary


def migrate_sharded_state(
    states: Dict[str, Dict[str, Any]],
    old: ShardedPlan,
    new: ShardedPlan,
    *,
    needs_ef: bool = False,
    interpret: Optional[bool] = None,
    fault_injector=None,
) -> Tuple[Dict[str, Dict[str, Any]], int, Tuple[str, ...]]:
    """Re-lay per-shard states onto a new ShardedPlan.

    ``states`` maps ``agg_id`` -> per-shard state dict whose 1-D leaves
    (flat/mu/nu[/ef]) have the shard's ``total_len``.  The transition
    decomposes into:

      * one :class:`MigrationDelta` per SURVIVING shard (same ``agg_id``
        in both plans) -- within-shard relocations, vacated-lane zeroing,
        and resizes execute on the ``repro.kernels.relayout`` run-copy
        path, O(that shard's moved bytes);
      * fresh zero spaces for shards that joined the fleet;
      * one contiguous slice copy per segment that changed Aggregator
        (the actual cross-shard traffic a split/merge ships).

    Returns ``(new_states, moved_elements, touched_jobs)``; the element
    count and touched set equal :func:`sharded_transition_summary`'s
    exactly -- the property the elastic-scaling benchmark asserts.

    Abort safety: the input ``states`` are never mutated -- each shard's
    relayout produces a NEW dict and arrivals scatter functionally -- so
    a fault at the boundary or at any mid-migration fail point leaves
    the caller's old states fully intact; nothing commits until the
    caller assigns the returned ``new_states``.
    """
    desc = f"sharded:{old.n_shards}->{new.n_shards}"
    if fault_injector is not None:
        # Chaos hook: a fault here models a migration dying BEFORE any
        # state moved (states untouched, caller's replan aborts).
        fault_injector.on_migration(desc)
    moved = 0
    touched: set = set()
    new_states: Dict[str, Dict[str, Any]] = {}
    old_ids = set(old.shard_ids)
    old_by = old.by_skey
    for sid, sp in zip(new.shard_ids, new.shards):
        prev = states.get(sid) if sid in old_ids else None
        if prev is not None:
            old_sp = old.shard_of(sid)
            delta = compile_migration_delta(old_sp, sp)
            st = migrate_flat_state_delta(
                prev, old_sp, sp, delta=delta, interpret=interpret)
            if st is prev:
                st = dict(prev)
            moved += delta.moved_elements
            touched.update(delta.touched_jobs)
        else:
            flat = jnp.zeros((sp.total_len,), jnp.float32)
            st = {"flat": flat, "mu": jnp.zeros_like(flat),
                  "nu": jnp.zeros_like(flat)}
            if needs_ef or any("ef" in s for s in states.values()):
                st["ef"] = jnp.zeros_like(flat)
        new_states[sid] = st
        # Cross-shard arrivals: segments whose old home was a DIFFERENT
        # Aggregator.  Their destination lanes are zero after the
        # within-shard pass (they are uncovered in the per-shard pair);
        # gather all of them and finish the move with ONE scatter per
        # leaf -- per-segment functional updates would copy the whole
        # destination buffer once per (segment, leaf).
        arrivals = []
        for seg in sp.segments:
            prev_home = old_by.get(seg.skey)
            if prev_home is None:
                continue  # new job's segment: stays zero until seeded
            psid, pseg = prev_home
            if psid == sid:
                continue  # same Aggregator: the per-shard delta covered it
            arrivals.append((seg, psid, pseg))
            moved += seg.size
            touched.add(seg.job_id)
        if arrivals:
            # Segments are in offset order within a shard plan, so the
            # concatenated destination index is sorted and unique.
            idx = jnp.asarray(np.concatenate([
                np.arange(seg.offset, seg.offset + seg.size, dtype=np.int64)
                for seg, _, _ in arrivals]))
            for k, buf in st.items():
                if getattr(buf, "ndim", 0) != 1:
                    continue
                pieces = [
                    jax.lax.slice(states[psid][k], (pseg.offset,),
                                  (pseg.offset + pseg.size,))
                    for _, psid, pseg in arrivals
                    if getattr(states[psid].get(k), "ndim", 0) == 1]
                if len(pieces) != len(arrivals):
                    continue  # leaf absent on some source shard: stay zero
                vals = (jnp.concatenate(pieces) if len(pieces) > 1
                        else pieces[0])
                st[k] = buf.at[idx].set(
                    vals, unique_indices=True, indices_are_sorted=True)
        if fault_injector is not None:
            # Mid-migration fail point: this shard is fully relaid
            # (delta + cross-shard arrivals); a fault here probes that
            # a partially-built new_states is simply discarded.
            fault_injector.on_migration_progress(len(new_states), desc)
    # Jobs that only exist on REMOVED shards (or left the fleet) are
    # touched too: diff the per-shard fingerprints like the summary does.
    _, sum_touched = sharded_transition_summary(old, new)
    touched.update(sum_touched)
    return new_states, moved, tuple(sorted(touched))


def migration_bytes(old: FlatPlan, new: FlatPlan, bytes_per_element: int = 12) -> int:
    """Bytes that actually cross shards (master copy + both Adam moments)."""
    return plan_migration_bytes(old, new, bytes_per_element)


def reshard_tree(tree, shardings):
    """Move a pytree onto new shardings (elastic re-mesh / migration)."""
    return jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, s), tree, shardings
    )
