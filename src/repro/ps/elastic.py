"""Elastic re-mesh + tensor migration in the data plane.

`migrate_flat_state` re-lays a PS flat state from one FlatPlan to another
(the data-plane half of the paper's tensor migration: the owner segments
move, everything else stays). Plans may be multi-job (compiled by
``ParameterService.compile_plan``): segments are matched by their
job-qualified key ``(job_id, tensor_key)``; segments that only exist in the
new plan (a job arrival) come out zero-initialized, segments that only
exist in the old plan (a job exit) are dropped. `reshard_tree` moves any
pytree onto new shardings (elastic scale up/down, spot drain from §6).

Both are expressible as pure gathers + device_put, so the runtime can issue
them while workers compute (the paper's hidden-copy window); the benchmark
(benchmarks/table3_migration.py) measures the visible stall against the
checkpoint-restart strawman.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .plan import FlatPlan, plan_migration_bytes


class PlanPerm(NamedTuple):
    """Precompiled (old -> new) lane permutation for one plan pair."""

    idx: np.ndarray  # (new.total_len,) int64 source lanes
    keep: np.ndarray  # (new.total_len,) bool: covered by a common segment
    all_kept: bool
    identity: bool  # the move is a no-op (every lane stays put)


@functools.lru_cache(maxsize=8)
def _plan_perm(old: FlatPlan, new: FlatPlan) -> PlanPerm:
    """(idx, keep) with new_flat[i] = old_flat[idx[i]] where keep[i], else 0.

    Lanes not covered by a common segment (padding, or segments of a job
    that was not in the old plan) get keep=False.  Cached per
    ``(old, new)`` plan pair (plans are frozen/hashable), so periodic
    rebalances that bounce between the same layouts -- or that move
    nothing at all -- never recompute or re-trace the permutation.
    """
    old_by_key = old.by_skey
    idx = np.zeros(new.total_len, dtype=np.int64)
    keep = np.zeros(new.total_len, dtype=bool)
    for seg in new.segments:
        o = old_by_key.get(seg.skey)
        if o is None:
            continue  # new job's segment: zero-initialized
        if o.size != seg.size:
            raise ValueError(
                f"segment {seg.skey} changed size {o.size} -> {seg.size}"
            )
        src = old.start(o)
        dst = new.start(seg)
        idx[dst : dst + seg.size] = np.arange(src, src + seg.size)
        keep[dst : dst + seg.size] = True
    all_kept = bool(keep.all())
    identity = (
        all_kept
        and old.total_len == new.total_len
        and bool((idx == np.arange(new.total_len)).all())
    )
    idx.setflags(write=False)
    keep.setflags(write=False)
    return PlanPerm(idx, keep, all_kept, identity)


def _perm_old_to_new(old: FlatPlan, new: FlatPlan) -> Tuple[np.ndarray, np.ndarray]:
    """Back-compat view of :func:`_plan_perm` (idx, keep)."""
    perm = _plan_perm(old, new)
    return perm.idx, perm.keep


def migrate_flat_state(state: Dict[str, Any], old: FlatPlan, new: FlatPlan):
    """Move a PS state onto a new service plan (tensor migration).

    Every 1-D leaf of length ``old.total_len`` (flat, mu, nu, ef) is
    gathered onto the new layout; scalars (step counters, incl. the shared
    state's per-job ``counts``) pass through untouched.  Common segments
    are relocated bit-exactly.  Equal plans -- and permutations that turn
    out to be the identity (a rebalance that moved nothing) -- return the
    state untouched without dispatching a single device op.
    """
    if old == new:
        return state
    perm = _plan_perm(old, new)
    if perm.identity:
        return state
    idx = jnp.asarray(perm.idx)
    keep = jnp.asarray(perm.keep)

    def move(x):
        if getattr(x, "ndim", 0) != 1 or x.shape[0] != old.total_len:
            return x
        moved = jnp.take(x, idx, axis=0)
        if perm.all_kept:
            return moved
        return jnp.where(keep, moved, jnp.zeros((), x.dtype))

    return jax.tree_util.tree_map(move, state)


def migration_bytes(old: FlatPlan, new: FlatPlan, bytes_per_element: int = 12) -> int:
    """Bytes that actually cross shards (master copy + both Adam moments)."""
    return plan_migration_bytes(old, new, bytes_per_element)


def reshard_tree(tree, shardings):
    """Move a pytree onto new shardings (elastic re-mesh / migration)."""
    return jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, s), tree, shardings
    )
