"""Data-plane Parameter Service runtime (JAX/SPMD).

plan.py          ServicePlan: compiles the control plane's live
                 tensor->Aggregator assignment into a multi-job FlatPlan
                 (segments keyed by (job_id, tensor_key), job runs padded
                 to block_align) plus cached per-job access structures
                 (payload_index, job_layout); pure numpy.
runtime.py       paper-faithful flat PS runtime: pull = one row gather of
                 the job's owned blocks, push = pack + row scatter,
                 update = block-owned Adam (O(job bytes) per step).
service_runtime.py  ServiceRuntime: one shared flat state for all jobs of
                 a ParameterService, migrated live on every replan.
engine.py        ServiceTickEngine: per-job bounded push queues + futures;
                 each tick drains all pending jobs and applies them in ONE
                 batched pass (single Pallas launch on TPU) under a
                 bounded-staleness (max_staleness) contract.
sharding.py      per-tensor sharding rules: the control plane's assignment
                 plan realized as NamedShardings (TP + FSDP "aggregation"
                 placement per tensor).
compression.py   int8 gradient compression with error feedback (push path).
elastic.py       tensor migration / elastic re-mesh via resharding.
"""

from .plan import (
    FlatPlan,
    JobLayout,
    Segment,
    TensorSpec,
    compile_service_plan,
    plan_from_json,
    plan_migration_bytes,
    plan_padding_waste,
    plan_to_json,
    segment_mask,
)

__all__ = [
    "FlatPlan",
    "JobLayout",
    "Segment",
    "TensorSpec",
    "compile_service_plan",
    "plan_from_json",
    "plan_migration_bytes",
    "plan_padding_waste",
    "plan_to_json",
    "segment_mask",
]
