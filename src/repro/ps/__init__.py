"""Data-plane Parameter Service runtime (JAX/SPMD).

plan.py          ServicePlan: compiles the control plane's live
                 tensor->Aggregator assignment into a multi-job FlatPlan
                 (segments keyed by (job_id, tensor_key), job runs padded
                 to block_align) plus cached per-job access structures
                 (payload_index, job_layout); pure numpy.  ShardedPlan:
                 one independently sized shard space per Aggregator
                 (compile_sharded_plan) with cross-shard job layouts.
runtime.py       paper-faithful flat PS runtime: pull = one row gather of
                 the job's owned blocks, push = pack + row scatter,
                 update = block-owned Adam (O(job bytes) per step).
service_runtime.py  ServiceRuntime: one shared flat state for all jobs of
                 a ParameterService, migrated live on every replan.
                 ShardedServiceRuntime: one state PER Aggregator shard
                 space, so fleet size changes what executes.
engine.py        ServiceTickEngine: per-job bounded push queues + futures;
                 each tick drains all pending jobs and applies them in ONE
                 batched pass (single Pallas launch on TPU) under a
                 bounded-staleness (max_staleness) contract.
                 ShardedTickEngine: one independent tick loop per shard
                 space (a hot shard never stalls a cold one).
autoscaler.py    ElasticScaler: per-shard TickStats -> scale_out/scale_in
                 decisions -- the fleet follows measured load (§3.3.2).
sharding.py      per-tensor sharding rules: the control plane's assignment
                 plan realized as NamedShardings (TP + FSDP "aggregation"
                 placement per tensor).
compression.py   int8 gradient compression with error feedback (push path).
elastic.py       tensor migration / elastic re-mesh via resharding.
"""

from .plan import (
    FlatPlan,
    JobLayout,
    Segment,
    ShardedJobLayout,
    ShardedPlan,
    TensorSpec,
    compile_service_plan,
    compile_sharded_plan,
    plan_from_json,
    plan_migration_bytes,
    plan_padding_waste,
    plan_to_json,
    segment_mask,
    sharded_plan_from_json,
    sharded_plan_to_json,
)

__all__ = [
    "FlatPlan",
    "JobLayout",
    "Segment",
    "ShardedJobLayout",
    "ShardedPlan",
    "TensorSpec",
    "compile_service_plan",
    "compile_sharded_plan",
    "plan_from_json",
    "plan_migration_bytes",
    "plan_padding_waste",
    "plan_to_json",
    "segment_mask",
    "sharded_plan_from_json",
    "sharded_plan_to_json",
]
