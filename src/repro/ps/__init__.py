"""Data-plane Parameter Service runtime (JAX/SPMD).

sharding.py     per-tensor sharding rules: the control plane's assignment
                plan realized as NamedShardings (TP + FSDP "aggregation"
                placement per tensor).
runtime.py      paper-faithful flat PS runtime: pull = all-gather,
                push = reduce-scatter, update shard-local on the owner
                segments chosen by the assignment plan.
compression.py  int8 gradient compression with error feedback (push path).
elastic.py      tensor migration / elastic re-mesh via resharding.
"""
