"""Architecture + workload configs.

- `paper_workloads`: the paper's four testbed models (AlexNet, VGG19,
  AWD-LM, BERT) as profiled aggregation jobs for the control plane/simulator.
- one module per assigned architecture (command_r_plus_104b.py, ...) exposing
  `config()` (full published dims) and `smoke_config()` (reduced).
- `registry`: name -> config constructors, used by --arch flags.
"""

from .registry import ARCHS, get_config, get_smoke_config  # noqa: F401
