"""dlrm-mlperf: MLPerf DLRM benchmark config (Criteo 1TB): n_dense=13
n_sparse=26 embed_dim=128 bot=13-512-256-128 top=1024-1024-512-256-1
interaction=dot. [arXiv:1906.00091; paper]

CRITEO_TB_VOCAB: the published per-field cardinalities of the Criteo
Terabyte dataset under MLPerf's max_ind_range=40M hashing (facebookresearch/
dlrm reference configuration).
"""

from __future__ import annotations

from repro.arch import ArchSpec, ShapeCell
from repro.models.recsys import DLRMConfig

CRITEO_TB_VOCAB = (
    39884406, 39043, 17289, 7420, 20263, 3, 7120, 1543, 63,
    38532951, 2953546, 403346, 10, 2208, 11938, 155, 4, 976, 14,
    39979771, 25641295, 39664984, 585935, 12972, 108, 36,
)


def config() -> DLRMConfig:
    return DLRMConfig(
        name="dlrm-mlperf", n_dense=13, n_sparse=26, embed_dim=128,
        bot_mlp=(512, 256, 128), top_mlp=(1024, 1024, 512, 256, 1),
        vocab_sizes=CRITEO_TB_VOCAB,
    )


def smoke_config() -> DLRMConfig:
    return DLRMConfig(
        name="dlrm-mlperf-smoke", n_dense=13, n_sparse=4, embed_dim=16,
        bot_mlp=(32, 16), top_mlp=(32, 16, 1), vocab_sizes=(1000, 50, 200, 3),
    )


def spec() -> ArchSpec:
    from .dlrm_rm2 import recsys_cells

    return ArchSpec(
        arch_id="dlrm-mlperf",
        family="recsys",
        recsys_kind="dlrm",
        model=config(),
        cells=recsys_cells(),
        notes="~188M embedding rows x 128 = 96 GB of tables; row-sharded "
              "over the full mesh (PS-style sharded EmbeddingBag).",
    )
