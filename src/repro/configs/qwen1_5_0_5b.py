"""qwen1.5-0.5b: 24L d_model=1024 16H (GQA kv=16 == MHA) d_ff=2816
vocab=151936 -- QKV bias, tied embeddings. [hf:Qwen/Qwen1.5-0.5B; hf]"""

from __future__ import annotations

import dataclasses

from repro.arch import ArchSpec, lm_cells
from repro.models.transformer import LMConfig


def config() -> LMConfig:
    return LMConfig(
        name="qwen1.5-0.5b",
        n_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        d_ff=2816,
        vocab=151936,
        qkv_bias=True,
        tie_embeddings=True,
        norm="rmsnorm",
        rope_theta=1_000_000.0,
        max_seq_len=32768,
        dtype="bfloat16",
    )


def smoke_config() -> LMConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=176,
        vocab=512, max_seq_len=128, dtype="float32", loss_chunk=16,
    )


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="qwen1.5-0.5b",
        family="lm",
        model=config(),
        cells=lm_cells(train_microbatches=1),
        notes="Small dense LM; vocab dominates params (QKV bias exercised).",
    )
