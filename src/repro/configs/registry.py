"""Architecture registry: --arch <id> -> config constructors.

Each arch module exposes `config()` (exact published dims) and
`smoke_config()` (reduced same-family config for CPU smoke tests).
Modules are imported lazily so that merely importing repro.configs does not
pull in JAX model code.
"""

from __future__ import annotations

import importlib
from typing import Any, Dict, List

# arch id -> module name under repro.configs
ARCHS: Dict[str, str] = {
    "command-r-plus-104b": "command_r_plus_104b",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "granite-8b": "granite_8b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "gin-tu": "gin_tu",
    "dlrm-rm2": "dlrm_rm2",
    "sasrec": "sasrec",
    "dien": "dien",
    "dlrm-mlperf": "dlrm_mlperf",
}


def _module(arch: str):
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return importlib.import_module(f"repro.configs.{ARCHS[arch]}")


def get_config(arch: str) -> Any:
    return _module(arch).config()


def get_smoke_config(arch: str) -> Any:
    return _module(arch).smoke_config()


def list_archs() -> List[str]:
    return sorted(ARCHS)
