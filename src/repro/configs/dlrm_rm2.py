"""dlrm-rm2: n_dense=13 n_sparse=26 embed_dim=64 bot=13-512-256-64
top=512-512-256-1 interaction=dot. [arXiv:1906.00091; paper]

Vocab sizes: the RM2-class model from the DLRM paper does not pin table
sizes; we use the public Criteo-Terabyte per-field cardinalities capped at
10M rows (documented synthetic choice) -- the skew across tables is the
property that matters for the paper's per-tensor aggregation placement.
"""

from __future__ import annotations

import dataclasses

from repro.arch import ArchSpec, ShapeCell
from repro.models.recsys import DLRMConfig
from .dlrm_mlperf import CRITEO_TB_VOCAB

VOCAB = tuple(min(v, 10_000_000) for v in CRITEO_TB_VOCAB)


def config() -> DLRMConfig:
    return DLRMConfig(
        name="dlrm-rm2", n_dense=13, n_sparse=26, embed_dim=64,
        bot_mlp=(512, 256, 64), top_mlp=(512, 512, 256, 1),
        vocab_sizes=VOCAB,
    )


def smoke_config() -> DLRMConfig:
    return DLRMConfig(
        name="dlrm-rm2-smoke", n_dense=13, n_sparse=4, embed_dim=8,
        bot_mlp=(16, 8), top_mlp=(16, 1), vocab_sizes=(100, 50, 200, 1000),
    )


def recsys_cells():
    return {
        "train_batch": ShapeCell("train_batch", "train", batch=65_536),
        "serve_p99": ShapeCell("serve_p99", "forward", batch=512),
        "serve_bulk": ShapeCell("serve_bulk", "forward", batch=262_144),
        "retrieval_cand": ShapeCell("retrieval_cand", "retrieval", batch=1,
                                    extras={"n_candidates": 1_000_000}),
    }


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="dlrm-rm2",
        family="recsys",
        recsys_kind="dlrm",
        model=config(),
        cells=recsys_cells(),
        notes="Skewed embedding tables: the paper's best-case workload for "
              "balanced per-tensor placement.",
    )
