"""granite-moe-1b-a400m: 24L d_model=1024 16H (GQA kv=8) d_ff=512
vocab=49155, MoE 32 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""

from __future__ import annotations

import dataclasses

from repro.arch import ArchSpec, lm_cells
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig


def config() -> LMConfig:
    return LMConfig(
        name="granite-moe-1b-a400m",
        n_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=8,
        d_ff=512,  # per-expert width
        vocab=49155,
        qkv_bias=False,
        tie_embeddings=True,
        norm="rmsnorm",
        rope_theta=10_000.0,
        max_seq_len=4096,
        dtype="bfloat16",
        moe=MoEConfig(n_experts=32, top_k=8, d_ff=512, capacity_factor=1.25),
    )


def smoke_config() -> LMConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        vocab=512, max_seq_len=128, dtype="float32", loss_chunk=16,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff=32, capacity_factor=1.5),
    )


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="granite-moe-1b-a400m",
        family="lm",
        model=config(),
        cells=lm_cells(train_microbatches=1),
        notes="Fine-grained MoE; experts are first-class aggregation tasks.",
    )
