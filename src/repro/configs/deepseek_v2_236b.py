"""deepseek-v2-236b: 60L d_model=5120 128H, MLA (kv_lora=512, q_lora=1536,
nope=128, rope=64, v=128), MoE 160 routed top-6 (d_ff=1536) + 2 shared,
first layer dense (d_ff=12288), vocab=102400. [arXiv:2405.04434; hf]"""

from __future__ import annotations

import dataclasses

from repro.arch import ArchSpec, lm_cells
from repro.models.moe import MoEConfig
from repro.models.transformer import LMConfig, MLAConfig


def config() -> LMConfig:
    return LMConfig(
        name="deepseek-v2-236b",
        n_layers=60,
        d_model=5120,
        n_heads=128,
        n_kv_heads=128,
        d_ff=12288,  # dense-layer FFN width (layer 0)
        vocab=102400,
        tie_embeddings=False,
        norm="rmsnorm",
        rope_theta=10_000.0,
        max_seq_len=16384,
        dtype="bfloat16",
        first_k_dense=1,
        mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, qk_nope_dim=128,
                      qk_rope_dim=64, v_head_dim=128),
        moe=MoEConfig(n_experts=160, top_k=6, d_ff=1536,
                      n_shared=2, d_ff_shared=3072, capacity_factor=1.25),
    )


def smoke_config() -> LMConfig:
    return dataclasses.replace(
        config(), n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=512, max_seq_len=128, dtype="float32", loss_chunk=16,
        first_k_dense=1,
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16,
                      qk_rope_dim=8, v_head_dim=16),
        moe=MoEConfig(n_experts=8, top_k=2, d_ff=32, n_shared=2,
                      d_ff_shared=64, capacity_factor=1.5),
    )


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="deepseek-v2-236b",
        family="lm",
        model=config(),
        cells=lm_cells(train_microbatches=16),
        notes="MLA compressed KV (absorbed decode) + 160-expert EP; the "
              "paper-representative MoE cell (expert tensors are migration "
              "units).",
    )
