"""command-r-plus-104b: 64L d_model=12288 96H (GQA kv=8) d_ff=33792
vocab=256000 -- GQA, no-bias, parallel attention+FFN residual (Cohere arch),
LayerNorm, tied embeddings. [hf:CohereForAI/c4ai-command-r-plus; unverified]
"""

from __future__ import annotations

import dataclasses

from repro.arch import ArchSpec, lm_cells
from repro.models.transformer import LMConfig


def config() -> LMConfig:
    return LMConfig(
        name="command-r-plus-104b",
        n_layers=64,
        d_model=12288,
        n_heads=96,
        n_kv_heads=8,
        d_ff=33792,
        vocab=256000,
        qkv_bias=False,
        tie_embeddings=True,
        parallel_block=True,
        norm="layernorm",
        rope_theta=75_000_000.0,
        max_seq_len=8192,
        dtype="bfloat16",
    )


def smoke_config() -> LMConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, d_ff=352,
        vocab=512, max_seq_len=128, dtype="float32", loss_chunk=16,
    )


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="command-r-plus-104b",
        family="lm",
        model=config(),
        cells=lm_cells(train_microbatches=16),
        notes="104B dense; largest dense cell; FSDP+TP+SP sharding.",
    )
