"""sasrec: embed_dim=50 n_blocks=2 n_heads=1 seq_len=50
interaction=self-attn-seq. [arXiv:1808.09781; paper]

Item vocabulary: the original paper evaluates on ML-1M (3.4k items); for
cluster-scale serving (retrieval_cand scores 1M candidates) we size the item
catalog at 1M rows (documented choice).
"""

from __future__ import annotations

import dataclasses

from repro.arch import ArchSpec, ShapeCell
from repro.models.recsys import SASRecConfig


def config() -> SASRecConfig:
    return SASRecConfig(name="sasrec", n_items=1_000_000, embed_dim=50,
                        n_blocks=2, n_heads=1, seq_len=50)


def smoke_config() -> SASRecConfig:
    return dataclasses.replace(config(), n_items=500, embed_dim=16, seq_len=10)


def spec() -> ArchSpec:
    from .dlrm_rm2 import recsys_cells

    return ArchSpec(
        arch_id="sasrec",
        family="recsys",
        recsys_kind="sasrec",
        model=config(),
        cells=recsys_cells(),
        notes="Sequential self-attention recommender; retrieval = last-state "
              "dot against the item table.",
    )
