"""gin-tu: GIN, n_layers=5 d_hidden=64 aggregator=sum eps=learnable.
[arXiv:1810.00826; paper]

d_feat / n_classes are per-shape (the four assigned graph workloads pin the
datasets): full_graph_sm = Cora (2708 nodes, 1433 feats, 7 classes);
minibatch_lg = Reddit (232,965 nodes, 114.6M edges, 602 feats, 41 classes,
fanout 15-10 sampled); ogb_products (2.45M nodes, 61.86M edges, 100 feats,
47 classes); molecule = MUTAG-like batched small graphs (7 feats, 2 classes).
"""

from __future__ import annotations

import dataclasses

from repro.arch import ArchSpec, ShapeCell
from repro.models.gnn import GINConfig


def config() -> GINConfig:
    return GINConfig(name="gin-tu", n_layers=5, d_hidden=64,
                     d_feat=1433, n_classes=7, learnable_eps=True)


def smoke_config() -> GINConfig:
    return dataclasses.replace(config(), n_layers=2, d_hidden=16, d_feat=8,
                               n_classes=4)


# per-shape dataset shapes
GRAPH_SHAPES = {
    "full_graph_sm": dict(n_nodes=2_708, n_edges=10_556, d_feat=1_433,
                          n_classes=7, task="node"),
    "minibatch_lg": dict(n_nodes=232_965, n_edges=114_615_892, d_feat=602,
                         n_classes=41, task="node", batch_nodes=1_024,
                         fanouts=(15, 10)),
    "ogb_products": dict(n_nodes=2_449_029, n_edges=61_859_140, d_feat=100,
                         n_classes=47, task="node"),
    "molecule": dict(n_nodes=30, n_edges=64, batch=128, d_feat=7,
                     n_classes=2, task="graph"),
}


def model_for_shape(shape: str) -> GINConfig:
    s = GRAPH_SHAPES[shape]
    return dataclasses.replace(
        config(), d_feat=s["d_feat"], n_classes=s["n_classes"], task=s["task"]
    )


def spec() -> ArchSpec:
    cells = {
        name: ShapeCell(name=name, kind="train", extras=dict(sh))
        for name, sh in GRAPH_SHAPES.items()
    }
    return ArchSpec(
        arch_id="gin-tu",
        family="gnn",
        model=config(),
        cells=cells,
        notes="Message passing = take + segment_sum; minibatch_lg uses the "
              "real fanout-(15,10) NeighborSampler. Aggregation placement "
              "applies (5 weight tensors) but is a small term vs graph "
              "scatter cost -- recorded, not skipped.",
    )
