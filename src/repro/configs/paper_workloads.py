"""The paper's four testbed workloads (§5.1) as profiled aggregation jobs.

Tensor inventories follow the published architectures (AlexNet, VGG19,
AWD-LSTM on WikiText-2, BERT-base). Iteration durations and aggregation
throughput are calibrated to the paper's published observations, since the
raw profiles are not public:

  * aggregation throughput 7 GB/s per server unit (consistent with VGG19's
    1s-2w average utilization of 16%, Fig. 2, at a ~1.0 s iteration);
  * per-(servers, workers) iteration durations chosen so that the packing
    results of Fig. 8 / Table 2 are decided by the same arithmetic the paper
    reports: AlexNet's short iteration -> high aggregation frequency -> extra
    Aggregator; VGG19's long iteration -> 4 jobs on 2 Aggregators.

Like MXNet's kvstore (bigarray_bound), tensors larger than `chunk_bytes` are
split into multiple aggregation tasks; ps-lite shards large tensors the same
way, so task granularity below whole-tensor is faithful to the baseline.
"""

from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

from repro.core.types import AggTask, JobProfile

AGG_THROUGHPUT = 7e9  # bytes/s of gradient summing + update per server unit
DEFAULT_CHUNK_BYTES = 16 << 20  # 16 MB, coarse kvstore-style big-array split
BYTES_PER_PARAM = 4  # fp32 gradients/parameters on the PS


def _conv(cin: int, cout: int, k: int = 3) -> int:
    return cin * cout * k * k


# (name, #params) per tensor --------------------------------------------------
ALEXNET_TENSORS: List[Tuple[str, int]] = [
    ("conv1.w", 96 * 3 * 11 * 11), ("conv1.b", 96),
    ("conv2.w", 256 * 48 * 5 * 5), ("conv2.b", 256),
    ("conv3.w", 384 * 256 * 3 * 3), ("conv3.b", 384),
    ("conv4.w", 384 * 192 * 3 * 3), ("conv4.b", 384),
    ("conv5.w", 256 * 192 * 3 * 3), ("conv5.b", 256),
    ("fc6.w", 9216 * 4096), ("fc6.b", 4096),
    ("fc7.w", 4096 * 4096), ("fc7.b", 4096),
    ("fc8.w", 4096 * 1000), ("fc8.b", 1000),
]

_VGG_CFG = [(3, 64), (64, 64), (64, 128), (128, 128),
            (128, 256), (256, 256), (256, 256), (256, 256),
            (256, 512), (512, 512), (512, 512), (512, 512),
            (512, 512), (512, 512), (512, 512), (512, 512)]
VGG19_TENSORS: List[Tuple[str, int]] = (
    [(f"conv{i}.w", _conv(cin, cout)) for i, (cin, cout) in enumerate(_VGG_CFG)]
    + [(f"conv{i}.b", cout) for i, (_, cout) in enumerate(_VGG_CFG)]
    + [("fc6.w", 25088 * 4096), ("fc6.b", 4096),
       ("fc7.w", 4096 * 4096), ("fc7.b", 4096),
       ("fc8.w", 4096 * 1000), ("fc8.b", 1000)]
)

AWDLM_TENSORS: List[Tuple[str, int]] = [
    ("embed.w", 33278 * 400),  # tied with decoder
    ("lstm0.w", 4 * 1150 * (400 + 1150)), ("lstm0.b", 4 * 1150),
    ("lstm1.w", 4 * 1150 * (1150 + 1150)), ("lstm1.b", 4 * 1150),
    ("lstm2.w", 4 * 400 * (1150 + 400)), ("lstm2.b", 4 * 400),
    ("decoder.b", 33278),
]

def _bert_tensors() -> List[Tuple[str, int]]:
    d, ff, L, vocab = 768, 3072, 12, 30522
    ts: List[Tuple[str, int]] = [
        ("embed.word", vocab * d), ("embed.pos", 512 * d), ("embed.type", 2 * d),
        ("embed.ln.g", d), ("embed.ln.b", d),
    ]
    for i in range(L):
        p = f"layer{i}."
        for w in ("q", "k", "v", "o"):
            ts += [(p + f"attn.{w}.w", d * d), (p + f"attn.{w}.b", d)]
        ts += [(p + "attn.ln.g", d), (p + "attn.ln.b", d),
               (p + "ffn.in.w", d * ff), (p + "ffn.in.b", ff),
               (p + "ffn.out.w", ff * d), (p + "ffn.out.b", d),
               (p + "ffn.ln.g", d), (p + "ffn.ln.b", d)]
    ts += [("pooler.w", d * d), ("pooler.b", d)]
    return ts

BERT_TENSORS: List[Tuple[str, int]] = _bert_tensors()

MODEL_TENSORS: Dict[str, List[Tuple[str, int]]] = {
    "alexnet": ALEXNET_TENSORS,
    "vgg19": VGG19_TENSORS,
    "awd-lm": AWDLM_TENSORS,
    "bert": BERT_TENSORS,
}

# Calibrated iteration durations: (model, n_servers, n_workers) -> seconds.
ITERATION_DURATION: Dict[Tuple[str, int, int], float] = {
    ("alexnet", 1, 2): 0.130, ("alexnet", 2, 2): 0.065, ("alexnet", 4, 4): 0.065,
    ("vgg19", 1, 2): 1.000, ("vgg19", 2, 2): 0.550, ("vgg19", 4, 4): 0.400,
    ("awd-lm", 1, 2): 0.150, ("awd-lm", 2, 2): 0.150, ("awd-lm", 4, 4): 0.150,
    ("bert", 1, 2): 0.250, ("bert", 2, 2): 0.250, ("bert", 4, 4): 0.250,
}


def model_bytes(model: str) -> int:
    return sum(p for _, p in MODEL_TENSORS[model]) * BYTES_PER_PARAM


def make_job(
    model: str,
    job_id: str,
    n_servers: int = 2,
    n_workers: int = 2,
    chunk_bytes: int = DEFAULT_CHUNK_BYTES,
    agg_throughput: float = AGG_THROUGHPUT,
) -> JobProfile:
    """Build the profiled JobProfile for one paper workload configuration."""
    if model not in MODEL_TENSORS:
        raise KeyError(f"unknown paper workload {model!r}")
    duration = ITERATION_DURATION.get((model, n_servers, n_workers))
    if duration is None:
        # Interpolate: scale the closest profiled config's duration.
        base = ITERATION_DURATION[(model, 2, 2)]
        duration = base
    tasks: List[AggTask] = []
    tid = 0
    for name, params in MODEL_TENSORS[model]:
        nbytes = params * BYTES_PER_PARAM
        n_chunks = max(1, -(-nbytes // chunk_bytes))  # ceil div
        per_chunk = nbytes // n_chunks
        for c in range(n_chunks):
            b = per_chunk if c < n_chunks - 1 else nbytes - per_chunk * (n_chunks - 1)
            tasks.append(
                AggTask(
                    job_id=job_id,
                    tensor_id=tid,
                    name=f"{name}[{c}]" if n_chunks > 1 else name,
                    nbytes=b,
                    exec_time=n_workers * b / agg_throughput,
                )
            )
            tid += 1
    return JobProfile(
        job_id=job_id,
        model=model,
        iteration_duration=duration,
        tasks=tasks,
        n_workers=n_workers,
        required_servers=n_servers,
    )


def standalone_utilization(model: str, n_servers: int, n_workers: int) -> float:
    """The Fig. 2 quantity for one configuration."""
    job = make_job(model, "probe", n_servers, n_workers)
    return job.standalone_utilization
