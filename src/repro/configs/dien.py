"""dien: embed_dim=18 seq_len=100 gru_dim=108 mlp=200-80 interaction=augru.
[arXiv:1809.03672; unverified]

Item/category vocab sized at 1M/10k (Taobao-scale, documented choice)."""

from __future__ import annotations

import dataclasses

from repro.arch import ArchSpec, ShapeCell
from repro.models.recsys import DIENConfig


def config() -> DIENConfig:
    return DIENConfig(name="dien", n_items=1_000_000, n_cats=10_000,
                      embed_dim=18, seq_len=100, gru_dim=108,
                      mlp_dims=(200, 80))


def smoke_config() -> DIENConfig:
    return dataclasses.replace(config(), n_items=500, n_cats=50, embed_dim=6,
                               seq_len=12, gru_dim=20, mlp_dims=(24, 8))


def spec() -> ArchSpec:
    from .dlrm_rm2 import recsys_cells

    return ArchSpec(
        arch_id="dien",
        family="recsys",
        recsys_kind="dien",
        model=config(),
        cells=recsys_cells(),
        notes="GRU interest extraction + AUGRU; recurrence is lax.scan; "
              "retrieval runs per-candidate AUGRU (heavy, sharded).",
    )
