"""granite-8b: 36L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=49152 --
llama-arch code model. [arXiv:2405.04324; hf]"""

from __future__ import annotations

import dataclasses

from repro.arch import ArchSpec, lm_cells
from repro.models.transformer import LMConfig


def config() -> LMConfig:
    return LMConfig(
        name="granite-8b",
        n_layers=36,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab=49152,
        qkv_bias=False,
        tie_embeddings=True,
        norm="rmsnorm",
        rope_theta=10_000_000.0,
        max_seq_len=8192,
        dtype="bfloat16",
    )


def smoke_config() -> LMConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, d_ff=448,
        vocab=512, max_seq_len=128, dtype="float32", loss_chunk=16,
    )


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="granite-8b",
        family="lm",
        model=config(),
        cells=lm_cells(train_microbatches=2),
        notes="Mid-size dense llama-arch.",
    )
