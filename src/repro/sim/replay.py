"""Chaos-soak trace replay: the fig11-style trace driven end to end
through ``ShardedServiceRuntime`` + ``ShardedTickEngine`` +
``ElasticScaler`` + ``FaultInjector`` (PR 9).

The harness buckets a Philly-like trace (``repro.sim.trace``) into fixed
windows and replays it against a REAL data plane: arrivals register jobs
(small synthetic trees -- the trace's 64 MB-chunk profiles contribute
only their arrival/exit/load structure), live jobs step through the tick
engine, exits remove jobs, the autoscaler resizes the fleet from
measured load, and an injected manual clock drives deterministic lease
expiry.  Two modes:

* ``chaos=True``: seeded apply faults, a boundary AND a mid-migration
  ``fail_migration``, a dropped push piece, a killed shard (recovered
  via ``recover_shard``), and a dead trainer that silently stops
  stepping until its lease reclaims it.  Every window asserts the
  control plane and data plane agree on the layout
  (``service.compile_sharded_plan() == runtime.splan``) -- the replan
  transaction's end-to-end guarantee.

* ``chaos=False``: the identical replay plus a FLAT eager
  ``ServiceRuntime`` twin stepping the same (job, batch) sequence; every
  window compares every live job's parameters bit for bit (the engine
  runs at ``max_staleness=0``, so any divergence is a migration or
  recovery bug, not staleness).

``scripts/replay_trace.py`` is the CLI; ``benchmarks/chaos_soak.py``
wraps :func:`report_rows` into the benchmark table (BENCH_chaos.json).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

__all__ = ["ManualClock", "ReplayConfig", "run_replay",
           "replan_overhead_micro", "report_rows"]


class ManualClock:
    """Injectable engine clock: one unit per replay window."""

    def __init__(self, now: float = 0.0):
        self.now = float(now)

    def __call__(self) -> float:
        return self.now


@dataclass
class ReplayConfig:
    """Knobs for one replay run (defaults are smoke-sized)."""

    # Trace shape (trace seconds; the replay clock is WINDOWS).
    n_jobs: int = 14
    seed: int = 0
    mean_interarrival: float = 60.0
    median_duration: float = 240.0
    sigma: float = 1.0
    max_duration: float = 1400.0
    trace_window: float = 120.0
    max_windows: int = 12
    # Data plane.
    steps_per_window: int = 2
    max_live: int = 6  # admission cap: keeps the toy fleet bounded
    plan_pad_to: int = 16
    total_budget: int = 64
    snapshot_interval: int = 4
    max_apply_retries: int = 3
    # Autoscaler.
    shard_capacity: float = 8.0
    max_shards: int = 4
    cooldown: int = 2
    # Leases (in replay-clock units = windows).
    lease_interval: float = 3.0
    # Chaos schedule.
    chaos: bool = True
    apply_fault_ats: tuple = (5, 11)  # transient, any lane
    migration_fault_at: int = 2  # Nth migration dies at the boundary
    mid_migration_fault_at: int = 3  # Nth migration dies after 1 shard
    drop_push_at: int = 7
    kill_window: Optional[int] = 5  # arm a kill on the last shard here
    dead_job_window: Optional[int] = 4  # a trainer goes silent here
    # Parity twin (only meaningful with chaos=False).
    parity_twin: bool = False


def _job_tree(index: int):
    """Small deterministic parameter tree for trace job ``jN`` -- the
    trace's real profiles are 64 MB-chunk scale, so the replay swaps in
    toy tensors and keeps only the trace's temporal structure."""
    import jax
    import numpy as np

    rng = np.random.default_rng(1000 + index)
    sizes = rng.choice([16, 24, 32, 48], size=int(rng.integers(2, 4)),
                       replace=True)
    ks = jax.random.split(jax.random.PRNGKey(index), len(sizes))
    return {f"t{i}": jax.random.normal(k, (int(n),))
            for i, (k, n) in enumerate(zip(ks, sizes))}


def _loss(params, batch):
    import jax.numpy as jnp

    return sum(jnp.sum((params[k] - batch["target"][k]) ** 2)
               for k in params)


def _params_equal(a, b) -> bool:
    import numpy as np

    return all(np.array_equal(np.asarray(a[k]), np.asarray(b[k]))
               for k in a)


def run_replay(cfg: ReplayConfig) -> Dict[str, Any]:
    """Replay the trace; returns the per-window log + invariant report.

    Raises only on harness bugs: injected faults are expected to be
    absorbed by the replan transactions, rollback recovery, shard
    recovery, and lease reclaim.  ``registry_divergence_windows`` counts
    windows where control and data plane disagreed on the layout -- the
    chaos acceptance criterion is that it stays 0.
    """
    import jax

    from repro.core import ParameterService
    from repro.ps.autoscaler import AutoscalerConfig, ElasticScaler
    from repro.ps.faults import EngineQuarantinedError, FaultInjector
    from repro.ps.service_runtime import ServiceRuntime, ShardedServiceRuntime
    from repro.sim.trace import philly_like_trace, window_schedule

    trace = philly_like_trace(
        n_jobs=cfg.n_jobs, mean_interarrival=cfg.mean_interarrival,
        median_duration=cfg.median_duration, sigma=cfg.sigma,
        max_duration=cfg.max_duration, seed=cfg.seed,
        chunk_bytes=1 << 12)
    windows = window_schedule(trace, cfg.trace_window,
                              max_windows=cfg.max_windows)
    exit_at = {}
    for w in windows:
        for j in w.exits:
            exit_at[j] = w.index

    clock = ManualClock()
    inj = FaultInjector(seed=cfg.seed)
    svc = ParameterService(total_budget=cfg.total_budget, n_clusters=1,
                           plan_pad_to=cfg.plan_pad_to)
    rt = ShardedServiceRuntime(svc, jit=False)
    eng = rt.attach_engine(
        max_staleness=0, jit=False, snapshot_interval=cfg.snapshot_interval,
        max_apply_retries=cfg.max_apply_retries, fault_injector=inj,
        lease_interval=cfg.lease_interval, clock=clock)
    scaler = ElasticScaler(rt, AutoscalerConfig(
        shard_capacity=cfg.shard_capacity, max_shards=cfg.max_shards,
        cooldown=cfg.cooldown))

    twin = None
    if cfg.parity_twin:
        twin = ServiceRuntime(
            ParameterService(total_budget=cfg.total_budget, n_clusters=1,
                             plan_pad_to=cfg.plan_pad_to), jit=False)

    if cfg.chaos:
        for at in cfg.apply_fault_ats:
            inj.fail_apply(None, at=int(at))
        inj.fail_migration(at=cfg.migration_fault_at)
        inj.fail_migration(at=cfg.mid_migration_fault_at, after_shards=1)
        inj.drop_push(at=cfg.drop_push_at)

    trees: Dict[str, Any] = {}
    targets: Dict[str, Any] = {}
    live: List[str] = []
    dead: set = set()  # trainers gone silent (chaos)
    reclaimed: set = set()  # lease-expired jobs
    read_vectors: Dict[str, Any] = {}  # reader's held PullVersions (PR 10)
    skipped_arrivals = 0
    n_exits = n_steps = n_reads = n_recoveries = 0
    dead_job = None
    dead_window = reclaim_window = None
    parity_violations = 0
    divergence = 0
    window_log: List[Dict[str, Any]] = []

    def add(jid: str) -> None:
        idx = int(jid[1:])
        tree = _job_tree(idx)
        trees[jid] = tree
        targets[jid] = jax.tree_util.tree_map(lambda p: p * 0 + 1.0, tree)
        nbytes = sum(4 * v.size for v in tree.values())
        kw = dict(lr=0.05, required_servers=1, agg_throughput=nbytes / 0.2)
        rt.add_job(jid, tree, _loss, **kw)
        if twin is not None:
            twin.add_job(jid, tree, _loss, **kw)
        live.append(jid)

    def step(jid: str) -> None:
        nonlocal n_recoveries
        try:
            eng.step(jid, {"target": targets[jid]})
        except EngineQuarantinedError:
            # A lane died mid-step: re-host the quarantined shard(s) on
            # the survivors (transactional replan) and retry once.
            for sid in eng.quarantined_shards():
                rt.recover_shard(sid)
                n_recoveries += 1
            eng.step(jid, {"target": targets[jid]})
        if twin is not None:
            twin.step(jid, {"target": targets[jid]})

    def read(jid: str) -> None:
        """One versioned pull per live job per window -- the read-path
        consumer that makes the soak price the pull wire (PR-8 counters;
        diff pulls across rollbacks/replans, full-pull fallbacks)."""
        nonlocal n_reads, n_recoveries
        try:
            diff = eng.pull(jid, since_version=read_vectors.get(jid, 0))
        except EngineQuarantinedError:
            # A hosting lane died before the read: re-host it (same
            # recovery path the trainer uses) and retry once.
            for sid in eng.quarantined_shards():
                rt.recover_shard(sid)
                n_recoveries += 1
            diff = eng.pull(jid, since_version=read_vectors.get(jid, 0))
        read_vectors[jid] = diff.version
        n_reads += 1

    for w in windows:
        clock.now = float(w.index)
        pulls_at_start = (eng.stats.n_full_pulls, eng.stats.n_diff_pulls,
                          eng.stats.pull_bytes_wire,
                          eng.stats.pull_bytes_full)
        for jid in w.arrivals:
            if len(live) >= cfg.max_live:
                skipped_arrivals += 1
                continue
            add(jid)
        if (cfg.chaos and cfg.kill_window is not None
                and w.index == cfg.kill_window and rt.n_shards >= 1):
            inj.kill_shard(rt.shard_ids[-1], at=1)
        if (cfg.chaos and cfg.dead_job_window is not None
                and w.index == cfg.dead_job_window and dead_job is None):
            # The live job with the LATEST scheduled exit goes silent:
            # only its lease can reclaim it.
            candidates = [j for j in live if j not in dead]
            if candidates:
                dead_job = max(
                    candidates,
                    key=lambda j: exit_at.get(j, cfg.max_windows + 1))
                dead.add(dead_job)
                dead_window = w.index
        for jid in list(live):
            if jid in dead or jid in reclaimed:
                continue
            for _ in range(cfg.steps_per_window):
                step(jid)
                n_steps += 1
        # Read path: the dead trainer's job is NOT read -- a pull renews
        # its lease, and the point of the dead-job scenario is that only
        # the lease reclaims it.
        for jid in list(live):
            if jid in dead or jid in reclaimed:
                continue
            read(jid)
        expired = eng.expire_leases()
        for jid in expired:
            reclaimed.add(jid)
            if jid in live:
                live.remove(jid)
            if jid == dead_job and reclaim_window is None:
                reclaim_window = w.index
        decision = scaler.observe()
        # Trace exits fire at window end; a dead trainer never calls
        # remove_job (that is the point -- its lease does the cleanup).
        for jid in w.exits:
            if jid not in live or jid in dead or jid in reclaimed:
                continue
            rt.remove_job(jid)
            if twin is not None:
                twin.remove_job(jid)
            live.remove(jid)
            n_exits += 1
        # ---- invariants ----
        if rt.splan is not None:
            agree = (svc.compile_sharded_plan() == rt.splan
                     and set(svc._jobs) == set(rt._jobs)
                     and set(eng._lanes) <= set(rt.splan.shard_ids))
        else:
            agree = not svc._jobs and not rt._jobs
        if not agree:
            divergence += 1
        window_parity = True
        if twin is not None:
            eng.drain()
            for jid in live:
                if not _params_equal(rt.params_of(jid),
                                     twin.params_of(jid)):
                    window_parity = False
            if not window_parity:
                parity_violations += 1
        window_log.append(dict(
            window=w.index, arrivals=len(w.arrivals), exits=len(w.exits),
            live=len(live), n_shards=rt.n_shards, action=decision.action,
            agree=bool(agree), parity=bool(window_parity),
            faults_fired=inj.n_fired,
            # PR-8 wire counters, this window's deltas: the soak prices
            # the read path alongside the chaos invariants.
            full_pulls=eng.stats.n_full_pulls - pulls_at_start[0],
            diff_pulls=eng.stats.n_diff_pulls - pulls_at_start[1],
            pull_bytes_wire=eng.stats.pull_bytes_wire - pulls_at_start[2],
            pull_bytes_full=eng.stats.pull_bytes_full - pulls_at_start[3]))

    return dict(
        windows=window_log,
        n_windows=len(windows),
        n_trace_jobs=len(trace),
        n_admitted=len(trees),
        n_skipped_arrivals=skipped_arrivals,
        n_exits=n_exits,
        n_steps=n_steps,
        n_reads=n_reads,
        n_full_pulls=eng.stats.n_full_pulls,
        n_diff_pulls=eng.stats.n_diff_pulls,
        pull_bytes_wire=eng.stats.pull_bytes_wire,
        pull_bytes_full=eng.stats.pull_bytes_full,
        n_recoveries=n_recoveries,
        faults_by_kind=inj.fire_counts(),
        n_faults_fired=inj.n_fired,
        n_replan_commits=svc.n_replan_commits,
        n_replan_aborts=svc.n_replan_aborts,
        n_replan_retries=svc.n_replan_retries,
        n_lease_expirations=eng.stats.n_lease_expirations,
        n_rollbacks=eng.stats.n_rollbacks,
        n_quarantines=eng.stats.n_quarantines,
        registry_divergence_windows=divergence,
        parity_violations=parity_violations,
        dead_job=dead_job,
        dead_window=dead_window,
        reclaim_window=reclaim_window,
        reclaim_latency_windows=(None if reclaim_window is None
                                 or dead_window is None
                                 else reclaim_window - dead_window),
        lease_interval=cfg.lease_interval,
        final_n_shards=rt.n_shards,
        final_live=sorted(live),
    )


def replan_overhead_micro(n_cycles: int = 3) -> Dict[str, float]:
    """Wall-clock cost of a RECOVERED replan (one injected migration
    fault -> abort -> registry rollback -> retry to success) vs a clean
    one, on identical scale-out transitions."""
    import jax

    from repro.core import ParameterService
    from repro.ps.faults import FaultInjector
    from repro.ps.service_runtime import ShardedServiceRuntime

    def build(inj=None):
        svc = ParameterService(total_budget=16, n_clusters=1,
                               plan_pad_to=16)
        rt = ShardedServiceRuntime(svc, jit=False)
        rt.attach_engine(max_staleness=0, jit=False, fault_injector=inj)
        for i, sizes in enumerate(((48, 16, 32), (32, 16), (48, 16))):
            ks = jax.random.split(jax.random.PRNGKey(i), len(sizes))
            tree = {f"t{k}": jax.random.normal(kk, (nn,))
                    for k, (kk, nn) in enumerate(zip(ks, sizes))}
            nbytes = sum(4 * v.size for v in tree.values())
            rt.add_job(f"m{i}", tree, _loss, lr=0.05, required_servers=1,
                       agg_throughput=nbytes / 0.2)
        return svc, rt

    def cycle_ms(svc, inj=None):
        # One warm-up cycle amortizes plan-pair-cache misses for both
        # variants identically.
        out = []
        for _ in range(n_cycles + 1):
            if inj is not None:
                inj.fail_migration(at=1)
                inj.rules[-1].seen = 0  # fresh rule per cycle
            t0 = time.perf_counter()
            svc.scale_out(1)
            out.append((time.perf_counter() - t0) * 1e3)
            svc.scale_in(1)
        return out[1:]

    svc_clean, _rt_clean = build()
    clean = cycle_ms(svc_clean)
    inj = FaultInjector()
    svc_chaos, _rt_chaos = build(inj)
    recovered = cycle_ms(svc_chaos, inj)
    clean_ms = sum(clean) / len(clean)
    recovered_ms = sum(recovered) / len(recovered)
    return dict(
        clean_ms=clean_ms,
        recovered_ms=recovered_ms,
        overhead_pct=100.0 * (recovered_ms / clean_ms - 1.0),
        aborts=svc_chaos.n_replan_aborts,
        retries=svc_chaos.n_replan_retries,
    )


def _pull_saving(report: Dict[str, Any]) -> float:
    """Shipped pull bytes as a fraction of the all-full-pull cost."""
    full = report.get("pull_bytes_full", 0)
    return report.get("pull_bytes_wire", 0) / full if full else 1.0


def report_rows(chaos: Dict[str, Any], parity: Dict[str, Any],
                micro: Optional[Dict[str, float]] = None):
    """Flatten two replay reports (+ the replan micro-bench) into the
    benchmark row shape: ``(name, value, derived-from)`` tuples."""
    lease_ok = (chaos["reclaim_latency_windows"] is not None
                and chaos["reclaim_latency_windows"]
                # one lease interval + the window sweep granularity
                <= int(chaos["lease_interval"]) + 1)
    rows = [
        ("chaos/windows", str(chaos["n_windows"]),
         "replay windows of the fig11-style trace under seeded chaos"),
        ("chaos/jobs_admitted", str(chaos["n_admitted"]),
         f"of {chaos['n_trace_jobs']} trace jobs "
         f"({chaos['n_skipped_arrivals']} skipped at the admission cap)"),
        ("chaos/steps", str(chaos["n_steps"]),
         "engine steps driven across all live jobs"),
        ("chaos/faults_fired", str(chaos["n_faults_fired"]),
         str(chaos["faults_by_kind"])),
        ("chaos/replan_aborts", str(chaos["n_replan_aborts"]),
         "replans rolled back on injected migration faults"),
        ("chaos/replan_retries", str(chaos["n_replan_retries"]),
         "aborted replans retried (all to success: the soak completed)"),
        ("chaos/rollbacks", str(chaos["n_rollbacks"]),
         "apply faults recovered by snapshot rollback"),
        ("chaos/shard_recoveries", str(chaos["n_recoveries"]),
         "killed shards re-hosted via recover_shard"),
        ("chaos/lease_expirations", str(chaos["n_lease_expirations"]),
         f"dead trainer {chaos['dead_job']!r} reclaimed"),
        ("chaos/reclaim_latency_windows",
         str(chaos["reclaim_latency_windows"]),
         "windows from trainer death to lease reclaim"),
        ("chaos/reclaimed_within_lease", str(int(lease_ok)),
         "acceptance: dead job reclaimed within one lease interval"),
        ("chaos/registry_divergence_windows",
         str(chaos["registry_divergence_windows"]),
         "windows where control and data plane disagreed"),
        ("chaos/zero_divergence",
         str(int(chaos["registry_divergence_windows"] == 0)),
         "acceptance: zero registry/runtime divergence under chaos"),
        ("chaos/reads", str(chaos["n_reads"]),
         "versioned pulls driven by the per-window read consumer"),
        ("chaos/read_full_pulls", str(chaos["n_full_pulls"]),
         "full-payload pulls (bootstraps + replan/rollback fallbacks)"),
        ("chaos/read_diff_pulls", str(chaos["n_diff_pulls"]),
         "pulls that shipped changed blocks only"),
        ("chaos/read_pull_bytes_wire", str(chaos["pull_bytes_wire"]),
         f"vs {chaos['pull_bytes_full']} B as all-full pulls "
         f"({_pull_saving(chaos):.2f}x of full)"),
        ("nofault/windows", str(parity["n_windows"]),
         "chaos-free replay vs a flat eager twin at s=0"),
        ("nofault/parity_violations", str(parity["parity_violations"]),
         "windows with any bit-level param divergence"),
        ("nofault/bit_exact", str(int(parity["parity_violations"] == 0)),
         "acceptance: no-fault replay bit-exact vs the chaos-free twin"),
    ]
    if micro is not None:
        rows += [
            ("replan/clean_ms", f"{micro['clean_ms']:.2f}",
             "mean wall ms of a fault-free scale-out replan"),
            ("replan/recovered_ms", f"{micro['recovered_ms']:.2f}",
             "same replan with one injected migration fault "
             "(abort -> rollback -> retry)"),
            ("replan/recovered_overhead_pct",
             f"{micro['overhead_pct']:.1f}",
             "recovered-replan overhead vs clean"),
        ]
    return rows
