from .simulator import ClusterSimulator, SimConfig, SimResult
from .trace import TraceJob, philly_like_trace

__all__ = ["ClusterSimulator", "SimConfig", "SimResult", "TraceJob",
           "philly_like_trace"]
