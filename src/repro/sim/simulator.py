"""Discrete-event simulator for Parameter Service at cluster scale.

Replays a job trace against the real control plane (ParameterService with
pMaster + cluster controllers + Pseudocode-1 assignment). Models the
paper's hybrid resource scaling: Aggregators freed by job exit are held in
an idle pool until the next periodic-scaling tick (which is why Fig. 11's
allocated/required ratio occasionally exceeds 1), while allocation is
on-demand. Job durations stretch by the predicted performance loss (a job
packed at 5% loss finishes 5% later), closing the loop between packing
decisions and trace timing.

With ``track_plans=True`` every placement change additionally compiles the
ServicePlan and accounts its data-plane consequences in the result: bytes
migrated across shards (paper accounting), padding waste, and the
delta-migration view (repro.ps.elastic.plan_transition_summary) -- bytes
actually moved by the run-copy path and how many resident jobs each
replan touches (stalls) vs rides past (stall-free).

With ``tick_interval > 0`` the simulator also accounts service-tick
batching (repro.ps.engine driven by a periodic tick): while J jobs run,
each pushes one update per effective iteration, but the engine applies
one pending push per job per batched pass -- so the service executes
``max_j(rate_j)`` passes per second instead of ``sum_j(rate_j)``.  A
tick-limited job's sustained push rate is one per tick (each tick frees
exactly one queue slot; the engine's ``max_staleness`` only sizes the
transient burst a job may run ahead, not its steady-state rate), so
rates are capped at ``1 / tick_interval``.  ``SimResult`` reports sequential vs batched
update-pass totals and the resulting batching factor for the Fig. 11
runs.
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.service import ParameterService
from repro.sim.trace import TraceJob


@dataclass
class SimConfig:
    total_budget: int = 4096
    n_clusters: int = 4
    loss_limit: float = 0.1
    scaling_period: float = 600.0  # idle Aggregators released on this tick
    sample_interval: float = 60.0  # Fig. 11 measures at 1-min intervals
    # Compile the ServicePlan after every placement change and account the
    # data-plane consequences (bytes migrated across shards, padding waste).
    track_plans: bool = False
    # Service-tick engine accounting: 0 = per-job immediate updates
    # (legacy); > 0 = the engine drains all pending jobs every
    # tick_interval seconds in one batched pass.  (The engine's
    # max_staleness knob sizes only the transient burst a job may run
    # ahead -- the sustained push rate of a tick-limited job is one per
    # tick regardless -- so it does not appear in this accounting.)
    tick_interval: float = 0.0
    # Wire accounting (PR 8).  ``push_compression`` prices every push
    # under repro.ps.compression.wire_bytes (None = fp32, "bf16" = 2B/
    # elem, "int8" = 1B/elem + scales); pushes themselves are unchanged
    # -- this is the transfer-byte model of the engines' compressed push
    # path.  With ``pull_interval > 0`` each running job is also pulled
    # by a reader every pull_interval seconds; a versioned diff pull
    # ships only the blocks that changed since the reader's last vector,
    # modeled as ``pull_dirty_fraction`` of the job's bytes (1.0 = every
    # pull is effectively full).
    push_compression: Optional[str] = None
    pull_interval: float = 0.0
    pull_dirty_fraction: float = 1.0
    # Read tier (PR 10).  With ``read_qps > 0`` a replica set of
    # ``n_read_replicas`` pull-only endpoints (repro.ps.replica) serves
    # an aggregate ``read_qps`` requests/sec, round-robin over the
    # running jobs.  Replicas hold snapshots published every
    # ``replica_publish_interval`` seconds (0 = every service tick, i.e.
    # ``tick_interval``): ONE publish is shared by every replica (the
    # ReplicaSet ships one immutable copy, not N), so the publish wire is
    # priced once per interval while reads scale with traffic; a served
    # read is on average half a publish interval stale.  Reads ship
    # ``pull_dirty_fraction`` of the job's bytes (versioned diff model,
    # same knob as engine pulls).
    read_qps: float = 0.0
    n_read_replicas: int = 1
    replica_publish_interval: float = 0.0


@dataclass
class SimResult:
    times: List[float] = field(default_factory=list)
    allocated: List[int] = field(default_factory=list)  # AutoPS servers (incl. idle pool)
    required: List[int] = field(default_factory=list)  # ps-lite requirement
    allocated_cpu_seconds: float = 0.0
    required_cpu_seconds: float = 0.0
    max_loss_seen: float = 0.0
    n_jobs_done: int = 0
    # Data-plane accounting from *compiled* ServicePlans (track_plans=True).
    migration_bytes_total: int = 0  # cross-Aggregator bytes (paper Table 3)
    n_replans: int = 0
    padding_waste: List[float] = field(default_factory=list)
    # Delta-migration accounting (track_plans=True): what each replan
    # actually costs on the data plane once transitions are executed as
    # compiled MigrationDeltas -- bytes = moved runs only, stalls = the
    # TOUCHED jobs only (untouched co-residents tick straight through).
    relayout_bytes_total: int = 0  # flat-space bytes the delta paths move
    replan_stalled_jobs: int = 0  # sum over replans of touched resident jobs
    replan_coresident_jobs: int = 0  # what a hard quiesce would have stalled
    # Service-tick engine accounting (tick_interval > 0).
    n_service_ticks: float = 0.0  # ticks elapsed while >= 1 job ran
    update_passes_sequential: float = 0.0  # one pass per push (per-job steps)
    update_passes_batched: float = 0.0  # one pass per tick round (engine)
    tick_limited_job_seconds: float = 0.0  # job-time spent at the staleness cap
    # Wire accounting (push_compression / pull_interval in SimConfig):
    # bytes every push would cost raw (fp32) vs on the modeled wire, and
    # bytes readers pull full vs as versioned diffs.
    push_bytes_raw: float = 0.0  # fp32 cost of every push
    push_bytes_wire: float = 0.0  # same pushes under push_compression
    pull_bytes_full: float = 0.0  # full-pull cost of the reader model
    pull_bytes_wire: float = 0.0  # versioned-diff cost (dirty fraction)
    # Read-tier accounting (read_qps > 0 in SimConfig): requests served
    # by the replica set, the bytes they shipped, the bytes the engines
    # published to feed the replicas (one shared copy per interval), and
    # the integral of snapshot age over served reads.
    reads_served: float = 0.0
    read_bytes_served: float = 0.0
    publish_bytes_total: float = 0.0
    read_staleness_seconds: float = 0.0  # sum over reads of snapshot age
    # Elastic-fleet CPU-tick accounting: each ALLOCATED Aggregator burns
    # one shard tick per tick_interval (its shard space wakes, drains,
    # applies) whether hot or cold -- so the integral of fleet size over
    # time, divided by the tick interval, is the CPU-ticks the elastic
    # (load-following) fleet consumed; a STATIC fleet provisioned for the
    # peak burns max_aggregators ticks every interval of the whole run.
    shard_tick_seconds: float = 0.0  # integral of allocated fleet size
    max_aggregators: int = 0  # peak fleet (the static fleet's size)
    elapsed_seconds: float = 0.0  # trace wall-clock covered

    @property
    def cpu_ticks_autoscaled(self) -> float:
        """Shard ticks the elastic fleet executed (tick_interval > 0)."""
        return self.shard_tick_seconds / self._tick  # set by the simulator

    @property
    def cpu_ticks_static(self) -> float:
        """Shard ticks a peak-sized always-on fleet would execute."""
        return self.max_aggregators * self.elapsed_seconds / self._tick

    @property
    def cpu_tick_reduction(self) -> float:
        """static / autoscaled CPU-ticks (>= 1: the Fig. 2/11 claim)."""
        if self.shard_tick_seconds <= 0:
            return 1.0
        return (self.max_aggregators * self.elapsed_seconds
                / self.shard_tick_seconds)

    _tick: float = 1.0  # tick_interval used (for the tick properties)
    _n_read_replicas: int = 1  # replica count used (read-tier properties)

    @property
    def cpu_time_saving(self) -> float:
        if self.required_cpu_seconds <= 0:
            return 0.0
        return 1.0 - self.allocated_cpu_seconds / self.required_cpu_seconds

    @property
    def mean_padding_waste(self) -> float:
        if not self.padding_waste:
            return 0.0
        return sum(self.padding_waste) / len(self.padding_waste)

    @property
    def replan_stall_free_fraction(self) -> float:
        """Fraction of (replan, resident job) pairs that did NOT stall
        under delta migration (1.0 = every replan was invisible to every
        co-resident job; 0.0 = hard-quiesce behavior)."""
        if self.replan_coresident_jobs <= 0:
            return 1.0
        return 1.0 - self.replan_stalled_jobs / self.replan_coresident_jobs

    @property
    def push_compression_ratio(self) -> float:
        """wire / raw push bytes (<= 1; 1.0 when nothing was pushed)."""
        if self.push_bytes_raw <= 0:
            return 1.0
        return self.push_bytes_wire / self.push_bytes_raw

    @property
    def pull_diff_saving(self) -> float:
        """1 - wire/full pull bytes (0 when the reader model is off)."""
        if self.pull_bytes_full <= 0:
            return 0.0
        return 1.0 - self.pull_bytes_wire / self.pull_bytes_full

    @property
    def reads_per_replica_per_sec(self) -> float:
        """Sustained serve rate one replica carried (read_qps > 0)."""
        if self.elapsed_seconds <= 0:
            return 0.0
        return (self.reads_served / self.elapsed_seconds
                / max(1, self._n_read_replicas))

    @property
    def mean_read_staleness_seconds(self) -> float:
        """Mean snapshot age a served read observed: half the publish
        interval under steady publishing (0 when the read tier is off)."""
        if self.reads_served <= 0:
            return 0.0
        return self.read_staleness_seconds / self.reads_served

    @property
    def read_publish_fanout(self) -> float:
        """Read bytes served per publish byte spent (the read-tier
        amortization claim: one shared publish feeds N replicas' worth
        of read traffic; higher = the tier pays for itself)."""
        if self.publish_bytes_total <= 0:
            return 0.0
        return self.read_bytes_served / self.publish_bytes_total

    @property
    def tick_batching_factor(self) -> float:
        """Sequential update passes per batched pass (>= 1): how many
        per-job step-functions one service tick replaces on average."""
        if self.update_passes_batched <= 0:
            return 1.0
        return self.update_passes_sequential / self.update_passes_batched

    def ratio_series(self) -> List[float]:
        return [a / r for a, r in zip(self.allocated, self.required) if r > 0]


class ClusterSimulator:
    def __init__(self, cfg: Optional[SimConfig] = None):
        # `cfg` must not default to SimConfig(): a dataclass default would be
        # shared by every simulator instance.
        self.cfg = SimConfig() if cfg is None else cfg
        cfg = self.cfg
        self.service = ParameterService(
            total_budget=cfg.total_budget,
            n_clusters=cfg.n_clusters,
            loss_limit=cfg.loss_limit,
        )
        self.idle_pool = 0  # released Aggregators awaiting the periodic tick
        self._last_plan = None

    def run(self, trace: List[TraceJob]) -> SimResult:
        cfg = self.cfg
        res = SimResult()
        res._tick = cfg.tick_interval if cfg.tick_interval > 0 else 1.0
        res._n_read_replicas = max(1, int(cfg.n_read_replicas))
        # Publish cadence of the read tier: explicit interval, else every
        # service tick, else 1 s (read_qps without any tick model).
        publish_period = (cfg.replica_publish_interval
                          if cfg.replica_publish_interval > 0
                          else res._tick)
        self._last_plan = None  # plan accounting must not leak across runs
        events: List[Tuple[float, int, str, Optional[TraceJob]]] = []
        for tj in trace:
            heapq.heappush(events, (tj.arrival, 0, tj.job_id, tj))
        if not events:
            return res
        t0 = events[0][0]
        heapq.heappush(events, (t0, 2, "__tick__", None))
        heapq.heappush(events, (t0, 3, "__sample__", None))

        running: Dict[str, TraceJob] = {}
        d_effs: Dict[str, float] = {}  # effective iteration durations
        last_t = t0
        if cfg.push_compression is not None:
            # Lazy like track_plan: the base simulator stays importable
            # without the JAX-backed data-plane modules.
            from repro.ps.compression import wire_bytes
        else:
            wire_bytes = None
        dirty = min(1.0, max(0.0, cfg.pull_dirty_fraction))
        horizon = max(tj.arrival for tj in trace) + 1.0
        pending_work = len(trace)  # arrivals + exits not yet processed

        def record_interval(now: float) -> None:
            nonlocal last_t
            dt = now - last_t
            if dt > 0:
                alloc = self.service.n_aggregators + self.idle_pool
                req = sum(j.profile.required_servers for j in running.values())
                res.allocated_cpu_seconds += alloc * dt
                res.required_cpu_seconds += req * dt
                res.shard_tick_seconds += self.service.n_aggregators * dt
                res.max_aggregators = max(res.max_aggregators,
                                          self.service.n_aggregators)
                res.elapsed_seconds += dt
                if cfg.tick_interval > 0 and running:
                    # Service-tick batching: each job pushes 1/d_eff
                    # updates per second; per-job steps would execute one
                    # pass per push, the engine executes one pass per tick
                    # round -- set by the FASTEST job, since a tick drains
                    # one queued push per job.  A tick-limited job
                    # sustains ONE push per tick (each tick frees exactly
                    # one queue slot; max_staleness only allows a
                    # transient burst), so rates cap at 1/tick_interval.
                    cap = 1.0 / cfg.tick_interval
                    rates = []
                    for jid in running:
                        r = 1.0 / max(1e-9, d_effs[jid])
                        if r > cap:
                            res.tick_limited_job_seconds += dt
                            r = cap
                        rates.append(r)
                    res.update_passes_sequential += dt * sum(rates)
                    res.update_passes_batched += dt * max(rates)
                    res.n_service_ticks += dt / cfg.tick_interval
                if running and (wire_bytes is not None
                                or cfg.pull_interval > 0):
                    # Wire model: each job pushes its gradient bytes once
                    # per effective iteration (tick-capped like above),
                    # and readers pull it every pull_interval seconds --
                    # full pulls raw, versioned diffs at the dirty
                    # fraction of its blocks.
                    cap = (1.0 / cfg.tick_interval
                           if cfg.tick_interval > 0 else float("inf"))
                    for jid, tj in running.items():
                        rate = min(cap, 1.0 / max(1e-9, d_effs[jid]))
                        nbytes = tj.profile.total_bytes
                        res.push_bytes_raw += dt * rate * nbytes
                        res.push_bytes_wire += dt * rate * (
                            wire_bytes(nbytes // 4, cfg.push_compression)
                            if wire_bytes is not None else nbytes)
                        if cfg.pull_interval > 0:
                            pulls = dt / cfg.pull_interval
                            res.pull_bytes_full += pulls * nbytes
                            res.pull_bytes_wire += pulls * nbytes * dirty
                if running and cfg.read_qps > 0:
                    # Read tier: read_qps requests/sec land round-robin
                    # on the running jobs, so each read ships the MEAN
                    # job's bytes (dirty fraction under the versioned
                    # reader model); publishing ships each running job's
                    # bytes ONCE per publish interval regardless of the
                    # replica count (one shared immutable snapshot), and
                    # a served read observes on average half a publish
                    # interval of snapshot staleness.
                    reads = dt * cfg.read_qps
                    mean_bytes = (sum(j.profile.total_bytes
                                      for j in running.values())
                                  / len(running))
                    res.reads_served += reads
                    res.read_bytes_served += reads * mean_bytes * dirty
                    res.publish_bytes_total += (
                        dt / publish_period
                        * sum(j.profile.total_bytes
                              for j in running.values()))
                    res.read_staleness_seconds += (
                        reads * publish_period / 2.0)
            last_t = now

        def track_plan() -> None:
            """Account the data-plane cost of the placement change that a
            job arrival/exit/tick just made, from the *compiled* plan."""
            if not cfg.track_plans:
                return
            from repro.ps.elastic import plan_transition_summary
            from repro.ps.plan import plan_migration_bytes, plan_padding_waste

            plan = self.service.compile_plan()
            if self._last_plan is not None:
                moved = plan_migration_bytes(self._last_plan, plan)
                if moved or plan != self._last_plan:
                    res.n_replans += 1
                res.migration_bytes_total += moved
                if plan != self._last_plan:
                    # Delta accounting (segment-level summary, O(segments)
                    # -- the lane-exact delta compile would materialize
                    # full-space index arrays at simulator scale): bytes =
                    # moved runs only; stalls = the touched resident jobs
                    # only (vs every resident job under a hard quiesce).
                    moved_elems, touched_jobs = plan_transition_summary(
                        self._last_plan, plan)
                    res.relayout_bytes_total += moved_elems * 12
                    touched = set(touched_jobs)
                    res.replan_stalled_jobs += sum(
                        1 for j in running if j in touched)
                    res.replan_coresident_jobs += len(running)
            if plan.n_shards:
                res.padding_waste.append(plan_padding_waste(plan))
            self._last_plan = plan

        while events:
            t, kind, jid, payload = heapq.heappop(events)
            record_interval(t)

            if kind == 0:  # arrival
                tj = payload
                before = self.service.n_aggregators
                self.service.register_job(tj.profile)
                grew = self.service.n_aggregators - before
                # On-demand allocations first consume the idle pool.
                reuse = min(self.idle_pool, max(0, grew))
                self.idle_pool -= reuse
                running[jid] = tj
                d_eff = self.service.predicted_iteration(jid)
                d_effs[jid] = d_eff
                loss = max(0.0, 1.0 - tj.profile.iteration_duration / d_eff)
                res.max_loss_seen = max(res.max_loss_seen, loss)
                finish = t + tj.duration / max(1e-9, (1.0 - loss))
                heapq.heappush(events, (finish, 1, jid, None))
                track_plan()
            elif kind == 1:  # exit
                pending_work -= 1
                if jid in running:
                    before = self.service.n_aggregators
                    self.service.job_exit(jid)
                    freed = before - self.service.n_aggregators
                    self.idle_pool += max(0, freed)
                    running.pop(jid)
                    d_effs.pop(jid, None)
                    res.n_jobs_done += 1
                    track_plan()
            elif kind == 2:  # periodic scaling tick: release idle servers
                self.idle_pool = 0
                self.service.periodic_rebalance()
                track_plan()
                if pending_work > 0:
                    heapq.heappush(events, (t + cfg.scaling_period, 2, jid, None))
            elif kind == 3:  # sampling
                alloc = self.service.n_aggregators + self.idle_pool
                req = sum(j.profile.required_servers for j in running.values())
                res.times.append(t)
                res.allocated.append(alloc)
                res.required.append(req)
                if pending_work > 0:
                    heapq.heappush(events, (t + cfg.sample_interval, 3, jid, None))

            if pending_work <= 0:
                break
        return res
