"""Synthetic Philly-like job trace.

The paper replays a 10-week trace from a 2000-GPU Microsoft cluster
(Jeon et al., ATC'19 -- the Philly trace). That trace is not shipped
offline, so we generate a synthetic one matching its published statistics:

  * inter-arrival: Poisson with diurnal modulation (day rate ~3x night);
  * durations: log-normal, median ~13 min with a heavy tail out to days
    (Philly: >50% jobs < 15 min, ~5% > 1 day), truncated at 7 days;
  * job mix: the four paper workloads x {1s-2w, 2s-2w, 4s-4w} configs,
    weighted toward small jobs (Philly: most jobs use few GPUs).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np

from repro.configs.paper_workloads import make_job
from repro.core.types import JobProfile

MODELS = ["alexnet", "vgg19", "awd-lm", "bert"]
CONFIGS: List[Tuple[int, int, float]] = [  # (servers, workers, weight)
    (1, 2, 0.5),
    (2, 2, 0.35),
    (4, 4, 0.15),
]


@dataclass(frozen=True)
class TraceJob:
    job_id: str
    arrival: float
    duration: float
    profile: JobProfile


def philly_like_trace(
    n_jobs: int = 1000,
    mean_interarrival: float = 30.0,
    median_duration: float = 780.0,
    sigma: float = 1.8,
    max_duration: float = 7 * 86400.0,
    seed: int = 0,
    chunk_bytes: int = 64 << 20,
) -> List[TraceJob]:
    rng = np.random.default_rng(seed)
    jobs: List[TraceJob] = []
    t = 0.0
    weights = np.array([w for _, _, w in CONFIGS])
    weights = weights / weights.sum()
    for i in range(n_jobs):
        # Diurnal modulation of the arrival rate.
        hour = (t / 3600.0) % 24.0
        rate_scale = 0.5 + 0.75 * (1 + np.sin((hour - 6) / 24 * 2 * np.pi))
        t += rng.exponential(mean_interarrival / max(rate_scale, 0.1))
        duration = min(
            float(np.exp(np.log(median_duration) + sigma * rng.standard_normal())),
            max_duration,
        )
        model = MODELS[rng.integers(len(MODELS))]
        si = rng.choice(len(CONFIGS), p=weights)
        servers, workers, _ = CONFIGS[si]
        profile = make_job(model, f"j{i}", servers, workers,
                           chunk_bytes=chunk_bytes)
        jobs.append(TraceJob(f"j{i}", t, duration, profile))
    return jobs


@dataclass(frozen=True)
class TraceWindow:
    """One fixed-width slice of a trace: who arrives, who exits, who is
    live at the window's END (arrivals-then-exits within a window, so a
    job that both arrives and exits inside it appears in both lists but
    not in ``live``)."""

    index: int
    t0: float
    t1: float
    arrivals: Tuple[str, ...]
    exits: Tuple[str, ...]
    live: Tuple[str, ...]


def window_schedule(jobs: List[TraceJob], window: float,
                    max_windows: Optional[int] = None) -> List[TraceWindow]:
    """Bucket a trace into fixed-width windows -- the replay harness's
    clock (scripts/replay_trace.py).  ``window`` is in trace seconds;
    ``max_windows`` truncates the schedule (jobs still live at the cut
    simply never exit within it)."""
    if window <= 0:
        raise ValueError(f"window must be > 0, got {window}")
    if not jobs:
        return []
    ends = {j.job_id: j.arrival + j.duration for j in jobs}
    horizon = max(ends.values())
    n = int(np.ceil(horizon / window))
    if max_windows is not None:
        n = min(n, int(max_windows))
    out: List[TraceWindow] = []
    for i in range(n):
        t0, t1 = i * window, (i + 1) * window
        arrivals = tuple(j.job_id for j in jobs if t0 <= j.arrival < t1)
        exits = tuple(j.job_id for j in jobs
                      if j.arrival < t1 and t0 <= ends[j.job_id] < t1)
        live = tuple(j.job_id for j in jobs
                     if j.arrival < t1 and ends[j.job_id] >= t1)
        out.append(TraceWindow(i, t0, t1, arrivals, exits, live))
    return out
