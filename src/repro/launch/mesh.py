"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state. Single pod: 16 x 16 = 256 chips (data, model).
Multi-pod: 2 x 16 x 16 = 512 chips (pod, data, model); the "pod" axis is an
extra data-parallel dimension whose collectives cross the inter-pod (DCN)
links -- the dry-run proves the HLO shards across it.

`make_mesh` / `make_abstract_mesh` paper over the jax API drift around
axis types (jax.sharding.AxisType only exists on newer jax; older
AbstractMesh takes (name, size) pairs).
"""

from __future__ import annotations

from typing import Sequence

import jax


def make_mesh(shape: Sequence[int], axes: Sequence[str]):
    """Version-compatible jax.make_mesh with Auto axis types when supported."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(
            tuple(shape), tuple(axes),
            axis_types=(axis_type.Auto,) * len(axes),
        )
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_abstract_mesh(shape: Sequence[int], axes: Sequence[str]):
    """Version-compatible AbstractMesh (rule logic only needs .shape)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.sharding.AbstractMesh(
            tuple(shape), tuple(axes),
            axis_types=(axis_type.Auto,) * len(axes),
        )
    return jax.sharding.AbstractMesh(tuple(zip(axes, shape)))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for CPU smoke runs (same axis names)."""
    return make_mesh((1, 1), ("data", "model"))
