"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state. Single pod: 16 x 16 = 256 chips (data, model).
Multi-pod: 2 x 16 x 16 = 512 chips (pod, data, model); the "pod" axis is an
extra data-parallel dimension whose collectives cross the inter-pod (DCN)
links -- the dry-run proves the HLO shards across it.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_host_mesh():
    """Single-device mesh for CPU smoke runs (same axis names)."""
    return jax.make_mesh(
        (1, 1), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )
