"""Serving driver: --arch <LM id>, batched decode with a KV cache.

  PYTHONPATH=src python -m repro.launch.serve --arch granite-8b --smoke \
      --batch 4 --prompt-len 16 --gen 32

By default the decode weights are read THROUGH the Parameter Service
read tier (PR 10): the model's parameters are hosted as one job in a
``ServiceRuntime``, a :class:`repro.ps.replica.ReplicaSet` of
``--replicas`` pull-only endpoints subscribes to its tick engine, and
the decode loop runs on a replica-served pull -- asserted bit-exact
against the hosted weights before any token is generated (the service
hosts fp32; bf16 params round-trip bf16 -> fp32 -> bf16 losslessly).
``--direct`` skips the service and decodes straight off ``init_params``,
the pre-PR-10 path.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry


def _pull_params_via_replicas(params, n_replicas: int):
    """Host ``params`` as one Parameter Service job and read them back
    through a fresh ReplicaSet; returns (replica-served params in the
    original dtypes, the ReplicaSet).  Asserts the served fp32 payload
    is bit-exact vs the hosted fp32 weights."""
    from repro.core import ParameterService
    from repro.ps.replica import ReplicaSet
    from repro.ps.service_runtime import ServiceRuntime

    # The service aggregates in fp32; bf16 -> fp32 is exact and the cast
    # back after the pull restores the original bits.
    hosted = jax.tree_util.tree_map(
        lambda x: x.astype(jnp.float32), params)
    svc = ParameterService(total_budget=16, n_clusters=1)
    rt = ServiceRuntime(svc, jit=False)
    eng = rt.attach_engine(max_staleness=0, jit=False)
    nbytes = sum(4 * int(v.size)
                 for v in jax.tree_util.tree_leaves(hosted))
    rt.add_job("lm", hosted, lambda p, b: 0.0, lr=0.0,
               required_servers=1, agg_throughput=nbytes / 0.2)
    rs = ReplicaSet(eng, n_replicas=n_replicas, publish_interval=1)
    rs.refresh()  # no tick has run yet: force the first publish
    served = rs.pull("lm")
    ok = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree_util.tree_leaves(served),
                        jax.tree_util.tree_leaves(hosted)))
    if not ok:
        raise AssertionError(
            "replica-served parameters diverge from the hosted weights")
    out = jax.tree_util.tree_map(
        lambda v, p: v.astype(p.dtype), served, params)
    return out, rs


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--replicas", type=int, default=2,
                    help="read-tier replica count the decode weights are "
                         "pulled through (default 2)")
    ap.add_argument("--direct", action="store_true",
                    help="skip the Parameter Service read tier and decode "
                         "straight off init_params")
    args = ap.parse_args()

    spec = registry._module(args.arch).spec()
    if spec.family != "lm":
        ap.error(f"{args.arch} is not an LM; serve supports decode archs")
    from repro.models import transformer as tf

    cfg = registry.get_smoke_config(args.arch) if args.smoke else spec.model
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    if not args.direct:
        params, rs = _pull_params_via_replicas(params, args.replicas)
        st = rs.replicas[0].stats
        print(f"[serve] weights read through {len(rs.replicas)} pull "
              f"replicas (bit-exact vs hosted): {st.n_full_serves} full "
              f"serve(s), {st.bytes_served} B served, "
              f"{rs.n_publishes} publish(es)")
    serve = jax.jit(tf.make_serve_step(cfg), donate_argnums=(1,))

    rng = np.random.default_rng(0)
    max_len = args.prompt_len + args.gen
    cache = tf.init_kv_cache(cfg, args.batch, max_len)
    prompt = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len), dtype=np.int32)
    )

    # Prefill via repeated decode (correct; the prefill_32k cell lowers the
    # batched prefill path used on real hardware).
    for i in range(args.prompt_len):
        logits, cache = serve(params, cache, prompt[:, i : i + 1])

    key = jax.random.PRNGKey(1)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, cache = serve(params, cache, tok)
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits / args.temperature)[:, None]
            tok = tok.astype(jnp.int32)
        else:
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    toks = args.batch * (args.gen - 1)
    print(f"[serve] generated {toks} tokens in {dt:.2f}s "
          f"({toks / max(dt, 1e-9):.1f} tok/s batch={args.batch})")
    ids = jnp.concatenate(out, axis=1)
    print("[serve] first sequence token ids:", np.asarray(ids[0])[:16], "...")


if __name__ == "__main__":
    main()
