"""Serving driver: --arch <LM id>, batched decode with a KV cache.

  PYTHONPATH=src python -m repro.launch.serve --arch granite-8b --smoke \
      --batch 4 --prompt-len 16 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    spec = registry._module(args.arch).spec()
    if spec.family != "lm":
        ap.error(f"{args.arch} is not an LM; serve supports decode archs")
    from repro.models import transformer as tf

    cfg = registry.get_smoke_config(args.arch) if args.smoke else spec.model
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    serve = jax.jit(tf.make_serve_step(cfg), donate_argnums=(1,))

    rng = np.random.default_rng(0)
    max_len = args.prompt_len + args.gen
    cache = tf.init_kv_cache(cfg, args.batch, max_len)
    prompt = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len), dtype=np.int32)
    )

    # Prefill via repeated decode (correct; the prefill_32k cell lowers the
    # batched prefill path used on real hardware).
    for i in range(args.prompt_len):
        logits, cache = serve(params, cache, prompt[:, i : i + 1])

    key = jax.random.PRNGKey(1)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, cache = serve(params, cache, tok)
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits / args.temperature)[:, None]
            tok = tok.astype(jnp.int32)
        else:
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    toks = args.batch * (args.gen - 1)
    print(f"[serve] generated {toks} tokens in {dt:.2f}s "
          f"({toks / max(dt, 1e-9):.1f} tok/s batch={args.batch})")
    ids = jnp.concatenate(out, axis=1)
    print("[serve] first sequence token ids:", np.asarray(ids[0])[:16], "...")


if __name__ == "__main__":
    main()
