import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and extract the roofline terms.

The two lines above MUST stay first: jax locks the device count on first
init, and the dry-run needs 512 placeholder host devices to build the
production meshes (16x16 single pod, 2x16x16 multi-pod). Smoke tests and
benchmarks never import this module, so they see 1 device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out results]

Per cell, writes results/<mesh>/<arch>__<shape>.json with:
  memory_analysis (per-device bytes), cost_analysis flops/bytes (per-device),
  collective traffic parsed from the partitioned HLO, MODEL_FLOPS, and the
  three roofline terms under TPU v5e constants.
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro.launch import hlo_cost, hlo_stats
from repro.launch.mesh import make_production_mesh

# TPU v5e roofline constants (per chip)
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s/link


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: Path,
             overrides=None) -> dict:
    from repro.launch.cells import build_cell

    mesh_name = "pod512" if multi_pod else "pod256"
    out_path = out_dir / mesh_name / f"{arch}__{shape}.json"
    out_path.parent.mkdir(parents=True, exist_ok=True)

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = 512 if multi_pod else 256
    rec = {"arch": arch, "shape": shape, "mesh": mesh_name, "chips": n_chips,
           "ok": False}
    t0 = time.time()
    try:
        cell = build_cell(arch, shape, mesh)
        if overrides:
            for k, v in overrides.items():
                setattr(cell, k, v)
        with mesh:
            lowered = cell.lower()
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            ma = compiled.memory_analysis()
            ca = compiled.cost_analysis() or {}
            hlo = compiled.as_text()
            # Trip-count-weighted cost walk (XLA's cost_analysis counts scan
            # bodies once; ours multiplies by known_trip_count).
            cost = hlo_cost.analyze(hlo)

        flops_dev = float(cost.flops)
        bytes_dev = float(cost.bytes)
        coll_dev = float(cost.total_collective)

        # Roofline terms (seconds; per-device quantities / per-chip rates)
        t_compute = flops_dev / PEAK_FLOPS
        t_memory = bytes_dev / HBM_BW
        t_coll = coll_dev / ICI_BW
        dominant = max(
            ("compute", t_compute), ("memory", t_memory), ("collective", t_coll),
            key=lambda kv: kv[1],
        )[0]
        model_flops = cell.model_flops_per_step
        hlo_flops_global = flops_dev * n_chips

        rec.update({
            "ok": True,
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "memory": {
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "alias_bytes": ma.alias_size_in_bytes,
                "peak_estimate_bytes": ma.argument_size_in_bytes
                + ma.output_size_in_bytes + ma.temp_size_in_bytes
                - ma.alias_size_in_bytes,
            },
            "per_device_flops": flops_dev,
            "per_device_bytes": bytes_dev,
            "per_device_collective_bytes": coll_dev,
            "collectives": {
                "counts": cost.coll_counts,
                "raw_bytes": cost.coll_raw,
                "traffic_bytes": cost.coll_traffic,
            },
            "xla_cost_analysis": {
                "flops_unweighted": float(ca.get("flops", 0.0)),
                "bytes_unweighted": float(ca.get("bytes accessed", 0.0)),
            },
            "roofline": {
                "t_compute_s": t_compute,
                "t_memory_s": t_memory,
                "t_collective_s": t_coll,
                "dominant": dominant,
                "bound_s": max(t_compute, t_memory, t_coll),
            },
            "model_flops_per_step": model_flops,
            "hlo_flops_global": hlo_flops_global,
            "useful_flops_ratio": (model_flops / hlo_flops_global
                                   if hlo_flops_global else 0.0),
            "roofline_fraction": (
                (model_flops / PEAK_FLOPS / n_chips)
                / max(t_compute, t_memory, t_coll)
                if max(t_compute, t_memory, t_coll) > 0 else 0.0
            ),
        })
    except Exception as e:  # noqa: BLE001 -- record the failure, don't die
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        rec["elapsed_s"] = round(time.time() - t0, 2)

    out_path.write_text(json.dumps(rec, indent=2))
    status = "OK " if rec["ok"] else "FAIL"
    frac = rec.get("roofline_fraction", 0.0)
    print(f"[{status}] {mesh_name} {arch:24s} {shape:14s} "
          f"compile={rec.get('compile_s', 0):7.1f}s "
          f"dominant={rec.get('roofline', {}).get('dominant', '-'):10s} "
          f"roofline={frac:6.1%}" if rec["ok"] else
          f"[{status}] {mesh_name} {arch} {shape}: {rec.get('error', '')[:200]}",
          flush=True)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()
    out_dir = Path(args.out)

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    if args.all:
        from repro.launch.cells import all_cells

        todo = list(all_cells())
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        todo = [(args.arch, args.shape)]

    n_fail = 0
    for multi_pod in meshes:
        mesh_name = "pod512" if multi_pod else "pod256"
        for arch, shape in todo:
            out_path = out_dir / mesh_name / f"{arch}__{shape}.json"
            if args.skip_existing and out_path.exists():
                rec = json.loads(out_path.read_text())
                if rec.get("ok"):
                    continue
            rec = run_cell(arch, shape, multi_pod, out_dir)
            n_fail += 0 if rec["ok"] else 1
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
