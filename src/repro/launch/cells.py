"""Cell builders: (arch x shape x mesh) -> jit-able step + abstract inputs.

Returns a `LoweredCell` carrying the function, ShapeDtypeStruct args,
in/out shardings and donation info, plus roofline metadata (MODEL_FLOPS).
This is the single place the dry-run, benchmarks, and perf loop construct
work from, so a sharding fix here fixes every consumer.
"""

from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.arch import ArchSpec, ShapeCell
from repro.configs import registry
from repro.optim import adagrad, adam
from repro.ps import sharding as shd


@dataclass
class LoweredCell:
    arch: str
    shape: str
    fn: Callable
    args: Tuple[Any, ...]  # ShapeDtypeStructs (pytrees)
    in_shardings: Tuple[Any, ...]
    donate_argnums: Tuple[int, ...]
    model_flops_per_step: float  # 6ND (dense) / 6 N_active D (MoE); fwd-only for serving
    mesh: Optional[Mesh] = None
    act_shard: bool = True  # activation-sharding constraints (SP/TP/EP)
    notes: str = ""

    def jitted(self):
        return jax.jit(
            self.fn,
            in_shardings=self.in_shardings,
            donate_argnums=self.donate_argnums,
        )

    def lower(self):
        from repro.ps import act_sharding

        if self.mesh is not None:
            with act_sharding.activate(self.mesh, enabled=self.act_shard):
                return self.jitted().lower(*self.args)
        return self.jitted().lower(*self.args)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), dtype)


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def get_spec(arch: str) -> ArchSpec:
    return registry._module(arch).spec()


# ==================================================================== LM cells
def _lm_cell(spec: ArchSpec, cell: ShapeCell, mesh: Mesh) -> LoweredCell:
    from repro.models import transformer as tf

    cfg = dataclasses.replace(spec.model, **cell.model_overrides)
    n_params = cfg.param_count
    n_active = cfg.active_param_count
    dp = shd.data_axes(mesh)

    abstract_params = jax.eval_shape(
        lambda: tf.init_params(cfg, jax.random.PRNGKey(0))
    )
    p_shard = shd.param_shardings(mesh, abstract_params, "lm")

    if cell.kind == "train":
        opt = adam(3e-4)
        n_micro = cell.run_overrides.get("n_microbatches", 1)
        accum_dt = jnp.bfloat16 if n_params > 5e10 else jnp.float32
        abstract_opt = jax.eval_shape(
            lambda: opt.init(tf.init_params(cfg, jax.random.PRNGKey(0)))
        )
        o_shard = shd.opt_state_shardings(mesh, abstract_opt, p_shard, "lm")
        # Gradients follow the optimizer-state layout (ZeRO-1): keeps EP
        # expert-weight grads dp-sharded even though the weights replicate.
        step = tf.make_train_step(cfg, opt, n_microbatches=n_micro,
                                  grad_accum_dtype=accum_dt,
                                  grad_shardings=o_shard.mu)
        state = {"params": abstract_params, "opt": abstract_opt}
        s_shard = {"params": p_shard, "opt": o_shard}
        batch = {
            "tokens": _sds((cell.batch, cell.seq), jnp.int32),
            "labels": _sds((cell.batch, cell.seq), jnp.int32),
        }
        b_shard = shd.batch_shardings(mesh, batch)
        flops = 6.0 * n_active * cell.batch * cell.seq
        return LoweredCell(spec.arch_id, cell.name, step, (state, batch),
                           (s_shard, b_shard), (0,), flops, mesh=mesh)

    if cell.kind == "prefill":
        fn = tf.make_prefill(cfg)
        toks = _sds((cell.batch, cell.seq), jnp.int32)
        t_shard = shd.batch_shardings(mesh, toks)
        flops = 2.0 * n_active * cell.batch * cell.seq
        return LoweredCell(spec.arch_id, cell.name, fn, (abstract_params, toks),
                           (p_shard, t_shard), (), flops, mesh=mesh)

    if cell.kind == "decode":
        fn = tf.make_serve_step(cfg)
        cache = jax.eval_shape(
            lambda: tf.init_kv_cache(cfg, cell.batch, cell.seq)
        )
        c_shard = shd.kv_cache_shardings(mesh, cache, cell.batch)
        toks = _sds((cell.batch, 1), jnp.int32)
        t_shard = shd.batch_shardings(mesh, toks)
        flops = 2.0 * n_active * cell.batch  # one token per sequence
        return LoweredCell(spec.arch_id, cell.name, fn,
                           (abstract_params, cache, toks),
                           (p_shard, c_shard, t_shard), (1,), flops, mesh=mesh)

    raise ValueError(f"unknown LM cell kind {cell.kind}")


# =================================================================== GNN cells
def _gnn_cell(spec: ArchSpec, cell: ShapeCell, mesh: Mesh) -> LoweredCell:
    from repro.configs import gin_tu
    from repro.models import gnn

    cfg = gin_tu.model_for_shape(cell.name)
    sh = cell.extras
    n_dev = int(np.prod(list(mesh.shape.values())))
    dp = shd.data_axes(mesh)
    axes_all = shd.all_axes(mesh)

    abstract_params = jax.eval_shape(
        lambda: gnn.init_params(cfg, jax.random.PRNGKey(0))
    )
    p_shard = shd.param_shardings(mesh, abstract_params, "gnn")

    opt = adam(1e-3)
    step = gnn.make_train_step(cfg, opt)
    abstract_opt = jax.eval_shape(
        lambda: opt.init(gnn.init_params(cfg, jax.random.PRNGKey(0)))
    )
    o_shard = shd.opt_state_shardings(mesh, abstract_opt, p_shard, "gnn")

    rep = NamedSharding(mesh, P())
    if cell.name == "molecule":
        n_nodes = sh["batch"] * sh["n_nodes"]  # 128 x 30
        n_edges = _round_up(sh["batch"] * sh["n_edges"], n_dev)
        batch = {
            "feats": _sds((n_nodes, sh["d_feat"]), jnp.float32),
            "edge_src": _sds((n_edges,), jnp.int32),
            "edge_dst": _sds((n_edges,), jnp.int32),
            "edge_mask": _sds((n_edges,), jnp.bool_),
            "graph_ids": _sds((n_nodes,), jnp.int32),
            "labels": _sds((sh["batch"],), jnp.int32),
        }
        b_shard = {
            "feats": NamedSharding(mesh, P(dp)),
            "edge_src": NamedSharding(mesh, P(axes_all)),
            "edge_dst": NamedSharding(mesh, P(axes_all)),
            "edge_mask": NamedSharding(mesh, P(axes_all)),
            "graph_ids": NamedSharding(mesh, P(dp)),
            "labels": NamedSharding(mesh, P(dp)),
        }
    else:
        if cell.name == "minibatch_lg":
            from repro.data.graph_sampler import NeighborSampler

            # fanout-(15,10) padded block sizes around 1024 seeds
            n_nodes = 1024 * (1 + 15 + 150)  # 169,984
            n_edges = 1024 * (15 + 150)  # 168,960
        else:
            n_nodes = _round_up(sh["n_nodes"], n_dev)
            n_edges = _round_up(sh["n_edges"], n_dev)
        feats_shard = (
            NamedSharding(mesh, P(axes_all))
            if n_nodes % n_dev == 0 and n_nodes >= (1 << 16)
            else rep
        )
        batch = {
            "feats": _sds((n_nodes, sh["d_feat"]), jnp.float32),
            "edge_src": _sds((n_edges,), jnp.int32),
            "edge_dst": _sds((n_edges,), jnp.int32),
            "edge_mask": _sds((n_edges,), jnp.bool_),
            "labels": _sds((n_nodes,), jnp.int32),
            "label_mask": _sds((n_nodes,), jnp.bool_),
        }
        b_shard = {
            "feats": feats_shard,
            "edge_src": NamedSharding(mesh, P(axes_all)),
            "edge_dst": NamedSharding(mesh, P(axes_all)),
            "edge_mask": NamedSharding(mesh, P(axes_all)),
            "labels": feats_shard if feats_shard is not rep else rep,
            "label_mask": feats_shard if feats_shard is not rep else rep,
        }
        b_shard["labels"] = NamedSharding(mesh, P(axes_all)) if n_nodes % n_dev == 0 else rep
        b_shard["label_mask"] = b_shard["labels"]

    state = {"params": abstract_params, "opt": abstract_opt}
    s_shard = {"params": p_shard, "opt": o_shard}
    # GNN "model flops": 2 x (edges x d + nodes x d x d_hidden) x layers x 3 (fwd+bwd)
    d = cfg.d_hidden
    flops = 3.0 * 2.0 * cfg.n_layers * (
        batch["edge_src"].shape[0] * d + batch["feats"].shape[0] * (cfg.d_feat if cfg.n_layers else d) * d
    )
    return LoweredCell(spec.arch_id, cell.name, step, (state, batch),
                       (s_shard, b_shard), (0,), flops, mesh=mesh)


# ================================================================ RecSys cells
def _recsys_cell(spec: ArchSpec, cell: ShapeCell, mesh: Mesh) -> LoweredCell:
    from repro.models import recsys

    cfg = spec.model
    dp = shd.data_axes(mesh)
    rep = NamedSharding(mesh, P())
    kind = spec.recsys_kind

    if kind == "dlrm":
        init = functools.partial(recsys.dlrm_init, cfg)
        loss = lambda p, b: recsys.dlrm_loss(cfg, p, b)
        opt = adagrad(0.01)
        dense_flops = 2 * sum(
            a * b for a, b in zip((cfg.n_dense,) + cfg.bot_mlp[:-1], cfg.bot_mlp)
        ) + 2 * sum(
            a * b for a, b in zip(
                (cfg.bot_mlp[-1] + cfg.n_pairs,) + cfg.top_mlp[:-1], cfg.top_mlp)
        )

        def batch_of(b):
            return {
                "dense": _sds((b, cfg.n_dense), jnp.float32),
                "sparse": _sds((b, cfg.n_sparse), jnp.int32),
                "labels": _sds((b,), jnp.float32),
            }

        fwd = lambda p, b: recsys.dlrm_forward(cfg, p, b["dense"], b["sparse"])
    elif kind == "sasrec":
        init = functools.partial(recsys.sasrec_init, cfg)
        loss = lambda p, b: recsys.sasrec_loss(cfg, p, b)
        opt = adam(1e-3)
        dense_flops = 2 * cfg.seq_len * (
            cfg.n_blocks * (4 * cfg.embed_dim ** 2 + 2 * cfg.embed_dim ** 2)
            + cfg.seq_len * cfg.embed_dim * cfg.n_blocks
        )

        def batch_of(b):
            return {
                "seq": _sds((b, cfg.seq_len), jnp.int32),
                "pos": _sds((b, cfg.seq_len), jnp.int32),
                "neg": _sds((b, cfg.seq_len), jnp.int32),
            }

        fwd = lambda p, b: recsys.sasrec_states(cfg, p, b["seq"])[:, -1]
    else:  # dien
        init = functools.partial(recsys.dien_init, cfg)
        loss = lambda p, b: recsys.dien_loss(cfg, p, b)
        opt = adam(1e-3)
        dense_flops = 2 * cfg.seq_len * (
            6 * (cfg.d_in + cfg.gru_dim) * cfg.gru_dim  # GRU + AUGRU
            + (cfg.gru_dim + cfg.d_in) * 80
        )

        def batch_of(b):
            return {
                "hist_items": _sds((b, cfg.seq_len), jnp.int32),
                "hist_cats": _sds((b, cfg.seq_len), jnp.int32),
                "target_item": _sds((b,), jnp.int32),
                "target_cat": _sds((b,), jnp.int32),
                "labels": _sds((b,), jnp.float32),
            }

        fwd = lambda p, b: recsys.dien_forward(cfg, p, b)

    abstract_params = jax.eval_shape(lambda: init(jax.random.PRNGKey(0)))
    p_shard = shd.param_shardings(mesh, abstract_params, "recsys")

    if cell.kind == "train":
        step = recsys.make_train_step(loss, opt)
        abstract_opt = jax.eval_shape(lambda: opt.init(init(jax.random.PRNGKey(0))))
        o_shard = shd.opt_state_shardings(mesh, abstract_opt, p_shard, "recsys")
        batch = batch_of(cell.batch)
        b_shard = shd.batch_shardings(mesh, batch)
        state = {"params": abstract_params, "opt": abstract_opt}
        s_shard = {"params": p_shard, "opt": o_shard}
        flops = 3.0 * cell.batch * dense_flops
        return LoweredCell(spec.arch_id, cell.name, step, (state, batch),
                           (s_shard, b_shard), (0,), flops, mesh=mesh)

    if cell.kind == "forward":
        batch = batch_of(cell.batch)
        b_shard = shd.batch_shardings(mesh, batch)
        flops = float(cell.batch) * dense_flops
        return LoweredCell(spec.arch_id, cell.name, fwd,
                           (abstract_params, batch), (p_shard, b_shard), (),
                           flops, mesh=mesh)

    if cell.kind == "retrieval":
        n_cand = cell.extras["n_candidates"]
        cand_shard = NamedSharding(mesh, P(dp))
        if kind == "sasrec":
            # Retrieval encodes ONE history then dots against N candidates.
            dense_flops = 2 * cfg.embed_dim
        if kind == "dlrm":
            fn = lambda p, d1, us, cand: recsys.dlrm_retrieval(cfg, p, d1, us, cand)
            args = (abstract_params,
                    _sds((1, cfg.n_dense), jnp.float32),
                    _sds((1, cfg.n_sparse - 1), jnp.int32),
                    _sds((n_cand,), jnp.int32))
            ins = (p_shard, rep, rep, cand_shard)
        elif kind == "sasrec":
            fn = lambda p, seq, cand: recsys.sasrec_retrieval(cfg, p, seq, cand)
            args = (abstract_params, _sds((1, cfg.seq_len), jnp.int32),
                    _sds((n_cand,), jnp.int32))
            ins = (p_shard, rep, cand_shard)
        else:
            fn = lambda p, hi, hc, ci, cc: recsys.dien_retrieval(cfg, p, hi, hc, ci, cc)
            args = (abstract_params,
                    _sds((cfg.seq_len,), jnp.int32),
                    _sds((cfg.seq_len,), jnp.int32),
                    _sds((n_cand,), jnp.int32),
                    _sds((n_cand,), jnp.int32))
            ins = (p_shard, rep, rep, cand_shard, cand_shard)
        flops = float(n_cand) * dense_flops
        return LoweredCell(spec.arch_id, cell.name, fn, args, ins, (), flops, mesh=mesh)

    raise ValueError(f"unknown recsys cell kind {cell.kind}")


def build_cell(arch: str, shape: str, mesh: Mesh) -> LoweredCell:
    spec = get_spec(arch)
    cell = spec.cell(shape)
    if spec.family == "lm":
        return _lm_cell(spec, cell, mesh)
    if spec.family == "gnn":
        return _gnn_cell(spec, cell, mesh)
    return _recsys_cell(spec, cell, mesh)


def all_cells():
    for arch in sorted(registry.ARCHS):
        spec = get_spec(arch)
        for shape in spec.cells:
            yield arch, shape
