"""Trip-count-weighted cost model over compiled (post-SPMD) HLO text.

XLA's cost_analysis() counts while-loop bodies ONCE (scan bodies are visited
a single time), which under-counts flops/bytes/collectives by the trip count
-- fatal for roofline math on scan-over-layers models. This walker parses
the HLO text, computes per-computation costs bottom-up, and multiplies while
bodies by the `known_trip_count` XLA records in backend_config.

Costs per op:
  dot            flops = 2 * numel(result) * prod(lhs contracting dims)
  fusion         bytes = result + operands; flops of the fused computation
  collectives    result bytes x ring-algorithm multipliers (group size G)
  while          trips x (body + cond) + own operands once
  other ops      bytes = result + operands (GTE/tuple/param/constant free)

Shapes are per-partition in partitioned HLO, so totals are per-device.
"""

from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "opaque": 0, "s2": 1, "u2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
    "f8e3m4": 1, "f4e2m1fn": 1, "f8e8m0fnu": 1,
}

_SHAPE_TOKEN = re.compile(r"([a-z0-9]+)\[([\d,]*)\](?:\{[^}]*\})?")
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^)]*\)|[a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?)\s*"
    r"([\w\-]+)\((.*)$"
)
_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_EXPLICIT_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_FREE_OPS = {
    "get-tuple-element", "tuple", "parameter", "constant", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}

_COLLECTIVE_KINDS = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
    "collective-permute-start", "reduce-scatter-start", "all-to-all-start",
    "ragged-all-to-all",
}


def _shape_bytes(shape_str: str) -> int:
    """Bytes of a shape string; tuple shapes sum their elements."""
    total = 0
    for m in _SHAPE_TOKEN.finditer(shape_str):
        dtype, dims = m.group(1), m.group(2)
        bpe = _DTYPE_BYTES.get(dtype)
        if bpe is None:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * bpe
    return total


def _shape_dims(shape_str: str) -> List[int]:
    m = _SHAPE_TOKEN.search(shape_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_traffic: Dict[str, float] = field(default_factory=dict)
    coll_raw: Dict[str, float] = field(default_factory=dict)
    coll_counts: Dict[str, float] = field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += mult * other.flops
        self.bytes += mult * other.bytes
        for d_self, d_other in (
            (self.coll_traffic, other.coll_traffic),
            (self.coll_raw, other.coll_raw),
            (self.coll_counts, other.coll_counts),
        ):
            for k, v in d_other.items():
                d_self[k] = d_self.get(k, 0.0) + mult * v

    @property
    def total_collective(self) -> float:
        return sum(self.coll_traffic.values())

    def to_dict(self) -> Dict:
        return {
            "flops": self.flops,
            "bytes": self.bytes,
            "collective_traffic_bytes": self.coll_traffic,
            "collective_raw_bytes": self.coll_raw,
            "collective_counts": self.coll_counts,
            "total_collective_bytes": self.total_collective,
        }


@dataclass
class _Op:
    name: str
    shape: str
    kind: str
    rest: str  # remainder of the line (operands + attrs)


def _collective_traffic(kind: str, nbytes: float, g: int) -> float:
    kind = kind.replace("-start", "")
    if g <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * (g - 1) / g * nbytes
    if kind == "all-gather":
        return (g - 1) / g * nbytes
    if kind == "reduce-scatter":
        return float(g - 1) * nbytes
    if kind in ("all-to-all", "ragged-all-to-all"):
        return (g - 1) / g * nbytes
    if kind == "collective-permute":
        return float(nbytes)
    return float(nbytes)


def _group_size(rest: str) -> int:
    m = _IOTA_GROUPS_RE.search(rest)
    if m:
        return int(m.group(2))
    m = _EXPLICIT_GROUPS_RE.search(rest)
    if m:
        return len(m.group(1).split(","))
    return 1


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps: Dict[str, List[_Op]] = {}
        self.entry: Optional[str] = None
        self._memo: Dict[str, Cost] = {}
        self._parse(hlo_text)
        # computations called from fusion ops: bytes counted at the call site
        self.fused: set = set()
        for ops in self.comps.values():
            for op in ops:
                if op.kind == "fusion":
                    m = _CALLS_RE.search(op.rest)
                    if m:
                        self.fused.add(m.group(1))

    # ------------------------------------------------------------- parsing
    def _parse(self, text: str) -> None:
        current: Optional[str] = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if not line:
                continue
            if not line.startswith(" ") and line.endswith("{"):
                m = _COMP_HEADER.match(line.strip())
                if m:
                    current = m.group(1)
                    self.comps[current] = []
                    if line.startswith("ENTRY"):
                        self.entry = current
                    continue
            if line.strip() == "}":
                current = None
                continue
            if current is None:
                continue
            m = _OP_LINE.match(line)
            if m:
                name, shape, kind, rest = m.groups()
                self.comps[current].append(_Op(name, shape, kind, rest))

    # ---------------------------------------------------------- evaluation
    def comp_cost(self, comp: str) -> Cost:
        if comp in self._memo:
            return self._memo[comp]
        self._memo[comp] = Cost()  # cycle guard
        ops = self.comps.get(comp, [])
        shapes = {op.name: op.shape for op in ops}
        cost = Cost()
        for op in ops:
            kind = op.kind
            if kind.endswith("-done"):
                continue
            if kind == "while":
                trips = 1.0
                m = _TRIP_RE.search(op.rest)
                if m:
                    trips = float(m.group(1))
                body = _BODY_RE.search(op.rest)
                cond = _COND_RE.search(op.rest)
                if body:
                    cost.add(self.comp_cost(body.group(1)), trips)
                if cond:
                    cost.add(self.comp_cost(cond.group(1)), trips)
                cost.bytes += _shape_bytes(op.shape)  # carry moves once
                continue
            if kind == "conditional":
                m = _BRANCHES_RE.search(op.rest)
                if m:
                    branches = _OPERAND_RE.findall(m.group(1))
                    if branches:
                        worst = max(
                            (self.comp_cost(b) for b in branches),
                            key=lambda c: c.flops + c.bytes,
                        )
                        cost.add(worst)
                continue
            if kind in ("call", "async-start"):
                m = _CALLS_RE.search(op.rest) or _BODY_RE.search(op.rest)
                if m:
                    cost.add(self.comp_cost(m.group(1)))
                continue
            if kind in _COLLECTIVE_KINDS:
                nbytes = _shape_bytes(op.shape)
                g = _group_size(op.rest)
                base = kind.replace("-start", "")
                traffic = _collective_traffic(kind, nbytes, g)
                cost.coll_traffic[base] = cost.coll_traffic.get(base, 0.0) + traffic
                cost.coll_raw[base] = cost.coll_raw.get(base, 0.0) + nbytes
                cost.coll_counts[base] = cost.coll_counts.get(base, 0.0) + 1
                cost.bytes += nbytes
                continue
            if kind == "fusion":
                m = _CALLS_RE.search(op.rest)
                inner_name = m.group(1) if m else None
                if inner_name:
                    inner = self.comp_cost(inner_name)
                    cost.flops += inner.flops  # dots inside fusions
                    cost.add(
                        Cost(coll_traffic=dict(inner.coll_traffic),
                             coll_raw=dict(inner.coll_raw),
                             coll_counts=dict(inner.coll_counts))
                    )
                cost.bytes += _shape_bytes(op.shape)
                sliced = self._sliced_params(inner_name) if inner_name else {}
                for i, operand in enumerate(self._operand_names(op)):
                    if i in sliced:
                        cost.bytes += sliced[i]  # indexed access: slice size
                    else:
                        cost.bytes += _shape_bytes(shapes.get(operand, ""))
                continue
            if kind == "dot":
                res = _shape_dims(op.shape)
                numel = 1
                for d in res:
                    numel *= d
                lhs_name = None
                names = self._operand_names(op)
                if names:
                    lhs_name = names[0]
                contract = 1
                mC = _LHS_CONTRACT_RE.search(op.rest)
                if mC and lhs_name and lhs_name in shapes:
                    lhs_dims = _shape_dims(shapes[lhs_name])
                    for ci in mC.group(1).split(","):
                        if ci:
                            idx = int(ci)
                            if idx < len(lhs_dims):
                                contract *= lhs_dims[idx]
                cost.flops += 2.0 * numel * contract
                cost.bytes += _shape_bytes(op.shape)
                for operand in names:
                    cost.bytes += _shape_bytes(shapes.get(operand, ""))
                continue
            if kind in _FREE_OPS:
                continue
            if kind == "dynamic-slice":
                # XLA reads only the slice, not the operand (and scan xs
                # indexing would otherwise count the whole stacked tensor
                # per trip -- measured 100x overcount on decode caches).
                cost.bytes += 2 * _shape_bytes(op.shape)
                continue
            if kind == "dynamic-update-slice":
                # In-place update: traffic ~ the updated region (operand 1).
                names = self._operand_names(op)
                upd = shapes.get(names[1], "") if len(names) > 1 else ""
                cost.bytes += 2 * _shape_bytes(upd)
                continue
            if kind == "gather":
                cost.bytes += 2 * _shape_bytes(op.shape)  # rows read+written
                continue
            if kind == "scatter":
                names = self._operand_names(op)
                upd = shapes.get(names[-1], "") if names else ""
                cost.bytes += 2 * _shape_bytes(upd) + _shape_bytes(op.shape)
                continue
            # generic op: result + operand bytes
            cost.bytes += _shape_bytes(op.shape)
            for operand in self._operand_names(op):
                cost.bytes += _shape_bytes(shapes.get(operand, ""))

        self._memo[comp] = cost
        return cost

    def _sliced_params(self, comp: str) -> Dict[int, float]:
        """Parameters of a fused computation consumed by indexed ops
        (dynamic-slice / gather / dynamic-update-slice): charge them at the
        touched-region size instead of full operand size (XLA reads only
        the slice; counting the stacked operand per scan trip overcounts
        ~trip_count x)."""
        if comp in getattr(self, "_sliced_memo", {}):
            return self._sliced_memo[comp]
        if not hasattr(self, "_sliced_memo"):
            self._sliced_memo: Dict[str, Dict[int, float]] = {}
        ops = self.comps.get(comp, [])
        param_index = {}
        for op in ops:
            if op.kind == "parameter":
                mnum = re.match(r"\s*(\d+)", op.rest)
                if mnum:
                    param_index[op.name] = int(mnum.group(1))
        out: Dict[int, float] = {}
        for op in ops:
            names = self._operand_names(op)
            if op.kind in ("dynamic-slice", "gather") and names:
                if names[0] in param_index:
                    out[param_index[names[0]]] = 2.0 * _shape_bytes(op.shape)
            elif op.kind == "dynamic-update-slice" and names:
                shapes_local = {o.name: o.shape for o in ops}
                upd = shapes_local.get(names[1], "") if len(names) > 1 else ""
                if names[0] in param_index:
                    out[param_index[names[0]]] = 2.0 * _shape_bytes(upd)
        self._sliced_memo[comp] = out
        return out

    def _operand_names(self, op: _Op) -> List[str]:
        # operands live before the first "), " attr boundary
        depth = 0
        end = len(op.rest)
        for i, ch in enumerate(op.rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                if depth == 0:
                    end = i
                    break
                depth -= 1
        return _OPERAND_RE.findall(op.rest[:end])

    def total(self) -> Cost:
        if self.entry is None:
            return Cost()
        return self.comp_cost(self.entry)


def analyze(hlo_text: str) -> Cost:
    return HloCostModel(hlo_text).total()


def top_collectives(hlo_text: str, n: int = 12):
    """Attribute collective traffic to op sources: returns the top-n
    (weighted_bytes, kind, per_device_shape, trip_multiplier, op_name)."""
    m = HloCostModel(hlo_text)
    mult: Dict[str, float] = {m.entry: 1.0}
    order = [m.entry]
    seen = {m.entry}
    items = []
    opname_re = re.compile(r'op_name="([^"]*)"')
    while order:
        comp = order.pop(0)
        cmult = mult.get(comp, 0.0)
        for op in m.comps.get(comp, []):
            rest = op.rest
            if op.kind == "while":
                trips = 1.0
                mm = _TRIP_RE.search(rest)
                if mm:
                    trips = float(mm.group(1))
                for r in (_BODY_RE.search(rest), _COND_RE.search(rest)):
                    if r:
                        c2 = r.group(1)
                        mult[c2] = mult.get(c2, 0.0) + cmult * trips
                        if c2 not in seen:
                            seen.add(c2)
                            order.append(c2)
            elif op.kind == "fusion":
                mm = _CALLS_RE.search(rest)
                if mm:
                    c2 = mm.group(1)
                    mult[c2] = mult.get(c2, 0.0) + cmult
                    if c2 not in seen:
                        seen.add(c2)
                        order.append(c2)
            elif op.kind in _COLLECTIVE_KINDS and not op.kind.endswith("-done"):
                nb = _shape_bytes(op.shape)
                g = _group_size(rest)
                tr = _collective_traffic(op.kind, nb, g)
                meta = opname_re.search(rest)
                items.append((tr * cmult, op.kind, op.shape[:48], cmult,
                              meta.group(1)[:120] if meta else ""))
    items.sort(reverse=True)
    return items[:n]
