"""Parse collective traffic out of compiled (post-SPMD) HLO text.

cost_analysis() has no collective numbers, so the roofline's collective term
comes from here: for every all-reduce / all-gather / reduce-scatter /
all-to-all / collective-permute op we take the per-partition result shape
(post-partitioning HLO shapes are per-device) and apply the standard ring-
algorithm byte multipliers:

  all-reduce       2 (G-1)/G x bytes     (reduce-scatter + all-gather)
  all-gather       (G-1)/G x out_bytes   (each device receives G-1 shards)
  reduce-scatter   (G-1) x out_bytes     (sends G-1 output-sized shards)
  all-to-all       (G-1)/G x bytes
  collective-permute  1 x bytes

G = replica group size, parsed from either explicit `{{0,1,...}}` lists or
iota `[n_groups,group_size]<=[...]` form.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_EXPLICIT_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _shape_bytes(shape_str: str) -> int:
    m = _SHAPE_RE.match(shape_str)
    if not m:
        return 0
    dtype, dims = m.group(1), m.group(2)
    if dtype == "tuple" or dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _result_bytes(line: str) -> int:
    """Bytes of the op's result: handles tuple results `(f32[..], f32[..])`."""
    lhs = line.split("=", 1)
    if len(lhs) != 2:
        return 0
    rhs = lhs[1].strip()
    if rhs.startswith("("):
        end = rhs.index(")")
        return sum(_shape_bytes(s.strip()) for s in rhs[1:end].split(","))
    return _shape_bytes(rhs)


def _group_size(line: str) -> int:
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        # [n_groups, group_size] <= [...]
        return int(m.group(2))
    m = _EXPLICIT_GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


@dataclass
class CollectiveStats:
    counts: Dict[str, int] = field(default_factory=dict)
    raw_bytes: Dict[str, int] = field(default_factory=dict)  # sum of result sizes
    traffic_bytes: Dict[str, float] = field(default_factory=dict)  # algo-adjusted
    max_group: Dict[str, int] = field(default_factory=dict)

    @property
    def total_traffic(self) -> float:
        return sum(self.traffic_bytes.values())

    def to_dict(self) -> Dict:
        return {
            "counts": self.counts,
            "raw_bytes": self.raw_bytes,
            "traffic_bytes": self.traffic_bytes,
            "max_group": self.max_group,
            "total_traffic": self.total_traffic,
        }


def _traffic(kind: str, nbytes: int, g: int) -> float:
    if g <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * (g - 1) / g * nbytes
    if kind == "all-gather":
        return (g - 1) / g * nbytes
    if kind == "reduce-scatter":
        return float(g - 1) * nbytes  # result is the scattered shard
    if kind == "all-to-all":
        return (g - 1) / g * nbytes
    if kind == "collective-permute":
        return float(nbytes)
    return float(nbytes)


def parse_collectives(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        s = line.strip()
        if not s or "=" not in s:
            continue
        for kind in _COLLECTIVES:
            token = f" {kind}("
            alt = f" {kind}-start("
            alt_done = f" {kind}-done("
            if token in s or alt in s:
                if alt_done in s:
                    continue
                nbytes = _result_bytes(s)
                g = _group_size(s)
                stats.counts[kind] = stats.counts.get(kind, 0) + 1
                stats.raw_bytes[kind] = stats.raw_bytes.get(kind, 0) + nbytes
                stats.traffic_bytes[kind] = stats.traffic_bytes.get(kind, 0.0) + _traffic(kind, nbytes, g)
                stats.max_group[kind] = max(stats.max_group.get(kind, 0), g)
                break
    return stats
