"""Training driver: --arch <id> end-to-end training with checkpointing.

Runs the real substrate end-to-end on whatever devices exist (CPU here;
the production mesh path is exercised by dryrun.py): synthetic data
pipeline -> jitted train step -> CheckpointManager (async saves, restart
from latest on relaunch) -> throughput/loss logging.

  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
      --steps 50 --smoke --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import registry
from repro.optim import adagrad, adam


def build(arch: str, smoke: bool, batch: int, seq: int):
    """Returns (init_state, train_step, batch_fn, tokens_per_batch)."""
    spec = registry._module(arch).spec()
    rng = np.random.default_rng(0)

    if spec.family == "lm":
        from repro.data import lm_batch
        from repro.models import transformer as tf

        cfg = registry.get_smoke_config(arch) if smoke else spec.model
        opt = adam(3e-4)
        step = tf.make_train_step(cfg, opt)

        def init_state():
            params = tf.init_params(cfg, jax.random.PRNGKey(0))
            return {"params": params, "opt": opt.init(params)}

        batch_fn = lambda: {k: jnp.asarray(v) for k, v in
                            lm_batch(rng, batch, seq, cfg.vocab).items()}
        return init_state, step, batch_fn, batch * seq

    if spec.family == "gnn":
        from repro.data import random_graph
        from repro.models import gnn

        cfg = registry.get_smoke_config(arch) if smoke else spec.model
        opt = adam(1e-3)
        step = gnn.make_train_step(cfg, opt)
        g = random_graph(rng, 512 if smoke else 2708, 4096 if smoke else 10556,
                         cfg.d_feat, cfg.n_classes)
        gb = {k: jnp.asarray(v) for k, v in g.items()}

        def init_state():
            params = gnn.init_params(cfg, jax.random.PRNGKey(0))
            return {"params": params, "opt": opt.init(params)}

        return init_state, step, lambda: gb, g["edge_src"].shape[0]

    # recsys
    from repro.data import dien_batch, recsys_batch, sasrec_batch
    from repro.models import recsys

    cfg = registry.get_smoke_config(arch) if smoke else spec.model
    if spec.recsys_kind == "dlrm":
        opt = adagrad(0.01)
        loss = lambda p, b: recsys.dlrm_loss(cfg, p, b)
        init = lambda: recsys.dlrm_init(cfg, jax.random.PRNGKey(0))
        batch_fn = lambda: {k: jnp.asarray(v) for k, v in
                            recsys_batch(rng, batch, cfg.n_dense,
                                         cfg.vocab_sizes).items()}
    elif spec.recsys_kind == "sasrec":
        opt = adam(1e-3)
        loss = lambda p, b: recsys.sasrec_loss(cfg, p, b)
        init = lambda: recsys.sasrec_init(cfg, jax.random.PRNGKey(0))
        batch_fn = lambda: {k: jnp.asarray(v) for k, v in
                            sasrec_batch(rng, batch, cfg.seq_len,
                                         cfg.n_items).items()}
    else:
        opt = adam(1e-3)
        loss = lambda p, b: recsys.dien_loss(cfg, p, b)
        init = lambda: recsys.dien_init(cfg, jax.random.PRNGKey(0))
        batch_fn = lambda: {k: jnp.asarray(v) for k, v in
                            dien_batch(rng, batch, cfg.seq_len, cfg.n_items,
                                       cfg.n_cats).items()}
    step = recsys.make_train_step(loss, opt)

    def init_state():
        params = init()
        return {"params": params, "opt": opt.init(params)}

    return init_state, step, batch_fn, batch


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(registry.ARCHS))
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-friendly)")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args()

    init_state, step, batch_fn, tokens = build(
        args.arch, args.smoke, args.batch, args.seq)
    step = jax.jit(step, donate_argnums=(0,))

    start = 0
    state = init_state()
    mgr = None
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir, save_every=args.ckpt_every)
        found, restored = mgr.restore_latest(jax.eval_shape(init_state))
        if found is not None:
            start, state = found + 1, restored
            print(f"[train] restored checkpoint step {found}", flush=True)

    t0 = time.time()
    for i in range(start, args.steps):
        state, metrics = step(state, batch_fn())
        if mgr is not None:
            mgr.maybe_save(i, state)
        if i % args.log_every == 0 or i == args.steps - 1:
            loss = float(metrics["loss"])
            dt = time.time() - t0
            rate = tokens * (i - start + 1) / max(dt, 1e-9)
            print(f"[train] step={i} loss={loss:.4f} items/s={rate:,.0f}",
                  flush=True)
    if mgr is not None:
        mgr.wait()
    print("[train] done", flush=True)


if __name__ == "__main__":
    main()
