"""Chaos soak (PR 9): the fig11-style trace replayed through the REAL
sharded data plane under a seeded fault schedule.

Three sections, all driven by ``repro.sim.replay`` (eager, CPU):

* ``chaos``: the seeded soak -- job arrivals/exits from the synthetic
  Philly-like trace, the autoscaler resizing the fleet from measured
  load, injected apply faults (snapshot rollback), a boundary AND a
  mid-migration ``fail_migration`` (replan transaction abort -> registry
  rollback -> retry), a dropped push piece, a killed shard
  (quarantine -> ``recover_shard``), and a dead trainer reclaimed by its
  lease.  Acceptance rows: zero registry/runtime divergence across every
  window, and the dead job reclaimed within one lease interval.

* ``nofault``: the identical replay with chaos off vs a FLAT eager
  ``ServiceRuntime`` twin -- every live job's parameters bit-exact every
  window at ``max_staleness=0``.

* ``replan``: wall-clock of a RECOVERED replan (one injected migration
  fault, abort + rollback + retry to success) vs a clean one.

Run: PYTHONPATH=src python benchmarks/run.py --only chaos \
         --json BENCH_chaos.json
"""

import os


def _smoke() -> bool:
    return bool(os.environ.get("HOTPATH_SMOKE"))


def rows():
    from repro.sim.replay import (ReplayConfig, replan_overhead_micro,
                                  report_rows, run_replay)

    windows = 8 if _smoke() else 12
    n_jobs = 10 if _smoke() else 14
    chaos = run_replay(ReplayConfig(chaos=True, max_windows=windows,
                                    n_jobs=n_jobs))
    parity = run_replay(ReplayConfig(chaos=False, parity_twin=True,
                                     max_windows=windows, n_jobs=n_jobs))
    micro = replan_overhead_micro(n_cycles=2 if _smoke() else 3)
    return report_rows(chaos, parity, micro)


if __name__ == "__main__":
    for name, value, derived in rows():
        print(f'{name},{value},"{derived}"')
