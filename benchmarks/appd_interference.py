"""Appendix D: mitigating network interference via reactive migration.

A background-flow-congested Aggregator is modeled as a capacity reduction
(its effective aggregation throughput drops by the interference factor).
AutoPS detects the loss and migrates the affected tensors to Aggregators
with spare capacity -- without new allocations (the paper's constraint)."""

from repro.configs.paper_workloads import make_job
from repro.core import perf_model
from repro.core.assignment import AssignmentConfig, assign_task
from repro.core.scaling import _NoAllocation, _refuse_allocation
from repro.core.types import Aggregator
from repro.core.assignment import balanced_shard_assignment


def _setup(model="vgg19", servers=4, congestion=0.25):
    job = make_job(model, "j", servers, 4)
    aggs = [Aggregator(f"a{i}") for i in range(servers)]
    shards = balanced_shard_assignment(job, servers)
    for i, agg in enumerate(aggs):
        for t in shards[i]:
            agg.add_task(t, job.iteration_duration)
    aggs[0].capacity = congestion  # interfered server
    return job, aggs


def _reactive_migrate(job, aggs, config=AssignmentConfig()):
    victim = aggs[0]
    moved = 0
    for task in sorted(victim.tasks.values(), key=lambda t: -t.exec_time):
        others = [a for a in aggs if a is not victim]
        try:
            assign_task(task, job, others, _refuse_allocation, config)
        except _NoAllocation:
            continue
        victim.remove_task(task.key)
        moved += 1
        if perf_model.predict_loss(job, aggs) < config.loss_limit:
            break
    return moved


def rows():
    out = []
    for congestion in (0.5, 0.25, 0.1):
        job, aggs = _setup(congestion=congestion)
        loss_before = perf_model.predict_loss(job, aggs)
        moved = _reactive_migrate(job, aggs)
        loss_after = perf_model.predict_loss(job, aggs)
        speedup = (1 - loss_before) and (1 - loss_after) / (1 - loss_before)
        out.append((f"appd/interference_{congestion}",
                    f"{speedup:.2f}x",
                    f"loss {loss_before:.3f}->{loss_after:.3f}, "
                    f"{moved} tensors migrated (paper: 5.6-14.3x at 32 flows)"))
    return out
