"""Elastic shard scaling: CPU-ticks of a load-following fleet vs a static
peak-sized one, plus the REAL data-plane cost and correctness of shard
split/merge transitions (paper §3.3.2, Fig. 2 / Fig. 11).

Two halves:

* ``trace``: the fig11-style Philly-like trace replayed through the
  cluster simulator with service-tick accounting -- each allocated
  Aggregator burns one shard tick per tick interval, a static fleet
  provisioned for the peak burns ``max_aggregators`` every interval.  The
  acceptance row asserts the elastic fleet consumes >= 2x fewer CPU-ticks
  (the paper reports up to 75% CPU reduction).

* ``dataplane``: a real :class:`ShardedServiceRuntime` +
  :class:`ShardedTickEngine` + :class:`ElasticScaler` driven through a
  3-phase load scenario (idle -> hot -> idle), with a FLAT eager
  ServiceRuntime stepping the identical gradient sequence as the parity
  oracle.  Every scaling transition's executed bytes are asserted equal
  to ``sharded_transition_summary`` (split/merge moves ONLY the compiled
  delta's bytes), and every job's parameters are compared bit-exactly
  after each phase (zero parity violations across fleet resizes).

Run: PYTHONPATH=src python benchmarks/run.py --only elastic_scaling \
         --json BENCH_elastic.json
"""

import os

N_JOBS_TRACE = 400
TICK_INTERVAL = 60.0


def _smoke() -> bool:
    return bool(os.environ.get("HOTPATH_SMOKE"))


def _trace_rows():
    from repro.sim import ClusterSimulator, SimConfig, philly_like_trace

    n_jobs = 80 if _smoke() else N_JOBS_TRACE
    trace = philly_like_trace(n_jobs=n_jobs, seed=1)
    res = ClusterSimulator(SimConfig(
        n_clusters=4, tick_interval=TICK_INTERVAL,
    )).run(trace)
    red = res.cpu_tick_reduction
    return [
        ("elastic/cpu_ticks_static", f"{res.cpu_ticks_static:.0f}",
         f"peak fleet ({res.max_aggregators} Aggregators) ticking for the "
         f"whole {res.elapsed_seconds / 3600:.1f}h trace"),
        ("elastic/cpu_ticks_autoscaled", f"{res.cpu_ticks_autoscaled:.0f}",
         "load-following fleet: integral of fleet size / tick interval"),
        ("elastic/cpu_tick_reduction", f"{red:.2f}",
         "static / autoscaled (paper: up to 75% CPU reduction => 4x)"),
        ("elastic/ticks_saving_2x", str(int(red >= 2.0)),
         "acceptance: elastic fleet consumes >= 2x fewer CPU-ticks"),
    ]


def _dataplane_rows():
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import ParameterService
    from repro.ps.autoscaler import AutoscalerConfig, ElasticScaler
    from repro.ps.elastic import sharded_transition_summary
    from repro.ps.service_runtime import ServiceRuntime, ShardedServiceRuntime

    def tree(key, sizes):
        ks = jax.random.split(key, len(sizes))
        return {f"t{i}": jax.random.normal(k, (n,))
                for i, (k, n) in enumerate(zip(ks, sizes))}

    def loss(params, batch):
        return sum(jnp.sum((params[k] - batch["target"][k]) ** 2)
                   for k in params)

    trees = {
        "a": tree(jax.random.PRNGKey(0), (96, 32, 64)),
        "b": tree(jax.random.PRNGKey(1), (64, 32)),
        "c": tree(jax.random.PRNGKey(2), (48, 16)),
    }
    targets = {j: jax.tree_util.tree_map(lambda p: p * 0 + 1.0, t)
               for j, t in trees.items()}

    def add_jobs(rt):
        for jid, t in trees.items():
            nb = sum(4 * v.size for v in t.values())
            rt.add_job(jid, t, loss, lr=0.05, required_servers=1,
                       agg_throughput=nb / 0.2)

    svc = ParameterService(total_budget=16, n_clusters=1, plan_pad_to=16)
    rt = ShardedServiceRuntime(svc, jit=False)
    eng = rt.attach_engine(max_staleness=0, jit=False)
    add_jobs(rt)
    scaler = ElasticScaler(rt, AutoscalerConfig(
        shard_capacity=12.0, max_shards=4, cooldown=1))

    ref = ServiceRuntime(
        ParameterService(total_budget=16, n_clusters=1, plan_pad_to=16),
        jit=False)
    add_jobs(ref)

    # Shard-count trajectory oracle: every observe() window's transition
    # must move exactly the compiled summary's bytes, and every phase end
    # must agree with the flat eager reference bit-for-bit.
    phases = [(3, 1), (4, 8), (4, 1)] if _smoke() else [(4, 1), (6, 8), (6, 1)]
    parity_violations = 0
    bytes_mismatches = 0
    n_grow = n_shrink = 0
    split_bytes = merge_bytes = 0
    max_shards_seen = 1
    for n_windows, steps_per_window in phases:
        for _ in range(n_windows):
            for _ in range(steps_per_window):
                for j in trees:
                    eng.step(j, {"target": targets[j]})
                    ref.step(j, {"target": targets[j]})
            eng.drain()
            old_plan = rt.splan
            decision = scaler.observe()
            if decision.action != "hold":
                moved_elems, _ = sharded_transition_summary(
                    old_plan, rt.splan)
                if decision.relayout_bytes != moved_elems * 12:
                    bytes_mismatches += 1
                if decision.action == "grow":
                    n_grow += 1
                    split_bytes += decision.relayout_bytes
                else:
                    n_shrink += 1
                    merge_bytes += decision.relayout_bytes
            max_shards_seen = max(max_shards_seen, rt.n_shards)
        for j in trees:
            p, q = rt.params_of(j), ref.params_of(j)
            for k in p:
                if not np.array_equal(np.asarray(p[k]), np.asarray(q[k])):
                    parity_violations += 1
    return [
        ("elastic/max_shards", str(max_shards_seen),
         "fleet peak under the hot phase (autoscaler-driven)"),
        ("elastic/final_shards", str(rt.n_shards),
         "fleet after the cool-down phase (merged back)"),
        ("elastic/scale_events", f"{n_grow}+{n_shrink}",
         "grow+shrink actions the scaler took"),
        ("elastic/split_moved_bytes", str(split_bytes),
         "shard bytes split transitions shipped (delta-executed)"),
        ("elastic/merge_moved_bytes", str(merge_bytes),
         "shard bytes merge transitions shipped (delta-executed)"),
        ("elastic/transition_bytes_match", str(int(bytes_mismatches == 0)),
         "acceptance: every split/merge moved exactly "
         "sharded_transition_summary bytes"),
        ("elastic/parity_violations", str(parity_violations),
         "acceptance: sharded+autoscaled trajectory vs flat eager "
         "reference, bit-exact (must be 0)"),
        ("elastic/launches_per_tick",
         f"{eng.stats.n_launches / max(eng.stats.n_ticks, 1):.2f}",
         f"fused fleet ticks: {eng.stats.n_launches} launches over "
         f"{eng.stats.n_ticks} ticks across every fleet size the scaler "
         f"visited"),
        ("elastic/single_launch_ticks",
         str(int(eng.stats.n_launches == eng.stats.n_ticks)),
         "acceptance: every fleet tick was exactly ONE fused launch, "
         "no matter how many shards were live"),
    ]


def rows():
    return _trace_rows() + _dataplane_rows()


if __name__ == "__main__":
    for name, value, derived in rows():
        print(f'{name},{value},"{derived}"')
