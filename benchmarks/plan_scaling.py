"""Plan-scaling microbenchmark: packing cost on a 2k-tensor, 256-shard plan.

The old `flatten_tree` rescanned every segment once per shard
(O(n_shards * n_segments) -- 512k segment visits here); precomputing
`FlatPlan.shard_segments` makes packing O(n_segments).  The host-side
packing loops are timed with numpy payloads to isolate the scan cost from
JAX op-dispatch overhead; the end-to-end `flatten_tree` time is reported
alongside.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ps.runtime import build_flat_plan, flatten_tree

N_TENSORS = 2000
N_SHARDS = 256


def _pack_quadratic(plan, by_key):
    """Pre-refactor reference: rescan all segments for every shard."""
    parts = []
    for s in range(plan.n_shards):
        used = 0
        for seg in plan.segments:
            if seg.shard != s:
                continue
            parts.append(by_key[seg.key])
            used += seg.size
        if used < plan.shard_len:
            parts.append(np.zeros(plan.shard_len - used, np.float32))
    return np.concatenate(parts)


def _pack_linear(plan, by_key):
    """Post-refactor: walk the precomputed per-shard segment lists."""
    parts = []
    for shard_idx in plan.shard_segments:
        used = 0
        for i in shard_idx:
            seg = plan.segments[i]
            parts.append(by_key[seg.key])
            used += seg.size
        if used < plan.shard_len:
            parts.append(np.zeros(plan.shard_len - used, np.float32))
    return np.concatenate(parts)


def _time(fn, repeats=5):
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def rows():
    rng = np.random.default_rng(0)
    sizes = rng.integers(8, 512, size=N_TENSORS)
    tree = {f"t{i:04d}": rng.standard_normal(n).astype(np.float32)
            for i, n in enumerate(sizes)}
    plan = build_flat_plan(tree, N_SHARDS, mode="balanced", pad_to=8)
    plan.shard_segments  # build the index outside the timed region

    by_key = dict(tree)
    t_quad = _time(lambda: _pack_quadratic(plan, by_key))
    t_lin = _time(lambda: _pack_linear(plan, by_key))
    np.testing.assert_array_equal(_pack_quadratic(plan, by_key),
                                  _pack_linear(plan, by_key))

    jtree = jax.tree_util.tree_map(jnp.asarray, tree)
    t_e2e = _time(
        lambda: jax.block_until_ready(flatten_tree(plan, jtree)), repeats=3)

    label = f"{N_TENSORS}t-{N_SHARDS}s"
    return [
        (f"plan/pack_quadratic_ms/{label}", f"{t_quad * 1e3:.1f}",
         "pre-refactor O(shards*segments) scan"),
        (f"plan/pack_linear_ms/{label}", f"{t_lin * 1e3:.1f}",
         f"precomputed shard_segments; {t_quad / max(t_lin, 1e-9):.1f}x faster"),
        (f"plan/flatten_tree_e2e_ms/{label}", f"{t_e2e * 1e3:.1f}",
         "end-to-end (JAX op dispatch dominates)"),
    ]
